"""Fig. 9a (dense LA) + Fig. 10 (multi-precision GEMM, expanding accum).

The paper sweeps FP64->FP8 with expanding accumulation; our sweep is
fp32/bf16/fp8 (DESIGN.md §6.3). CPU timing exercises the jitted xla path;
`derived` reports measured GFLOP/s and the per-precision TPU peak the
roofline uses.
"""
import jax
import jax.numpy as jnp
import numpy as np

from benchmarks.common import row, timeit
from repro.core import precision
from repro.kernels import ops


def run():
    rng = np.random.default_rng(0)
    m = k = n = 512
    a32 = jnp.asarray(rng.standard_normal((m, k)), jnp.float32)
    b32 = jnp.asarray(rng.standard_normal((k, n)), jnp.float32)
    flops = 2 * m * k * n

    gemm = jax.jit(lambda a, b: ops.gemm(a, b))
    t = timeit(gemm, a32, b32)
    row("fig9a_gemm_512", t, f"{flops / t / 1e9:.2f} GFLOP/s")

    # Fig. 10 sweep: numerics at each precision + projected TPU peak
    exact = np.asarray(a32 @ b32)
    for pol in ("fp32", "bf16", "fp8"):
        out = precision.expanding_gemm(a32, b32, pol, impl="ref")
        rel = float(np.linalg.norm(np.asarray(out, np.float32) - exact)
                    / np.linalg.norm(exact))
        peak = precision.peak_flops(pol)
        row(
            f"fig10_gemm_{pol}", t,
            f"rel_err={rel:.1e};tpu_peak={peak/1e12:.0f}TFLOP/s",
        )

    # blocked double-buffered GEMM (C4) at a memory-capped tile size
    from repro.core.pipeline import tiled_gemm

    big_a = jnp.asarray(rng.standard_normal((2048, 512)), jnp.float32)
    tg = jax.jit(lambda a, b: tiled_gemm(a, b, tile_m=512))
    t = timeit(tg, big_a, b32)
    row("fig9a_tiled_gemm_2048x512", t,
        f"{2 * 2048 * 512 * 512 / t / 1e9:.2f} GFLOP/s")
