"""Benchmark plumbing: timing + CSV emission.

Each module reproduces one paper table/figure on the framework's kernels.
The container is CPU-only, so wall-times are CPU numbers; every row also
carries a `derived` column with the figure-of-merit the paper reports
(GFLOP/s, GCOMP/s, tok/s, GB/s) computed from the measured time, plus
TPU-peak projections where the metric is roofline-derived.
"""
from __future__ import annotations

import time

import jax

ROWS: list[tuple[str, float, str]] = []


def timeit(fn, *args, reps: int = 5, warmup: int = 1) -> float:
    """Median wall-time per call in seconds (jit included via warmup)."""
    for _ in range(warmup):
        out = fn(*args)
    jax.block_until_ready(out)
    times = []
    for _ in range(reps):
        t0 = time.perf_counter()
        out = fn(*args)
        jax.block_until_ready(out)
        times.append(time.perf_counter() - t0)
    times.sort()
    return times[len(times) // 2]


def row(name: str, seconds: float, derived: str):
    ROWS.append((name, seconds * 1e6, derived))
    print(f"{name},{seconds * 1e6:.1f},{derived}", flush=True)
