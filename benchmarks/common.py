"""Benchmark plumbing: timing + CSV emission + machine-readable JSON rows.

Each module reproduces one paper table/figure on the framework's kernels.
The container is CPU-only, so wall-times are CPU numbers; every row also
carries a `derived` column with the figure-of-merit the paper reports
(GFLOP/s, GCOMP/s, tok/s, GB/s) computed from the measured time, plus
TPU-peak projections where the metric is roofline-derived.

Alongside the human CSV each ``row(...)`` call records a JSON row: the
same (name, us_per_call, derived) triple plus any structured metadata the
caller passes as keyword arguments (op, mesh tag, impl, overlap flag,
model estimates...). ``emit_json`` dumps the accumulated rows — that is
what ``benchmarks/run.py --json PATH`` writes and what the committed
``BENCH_mesh.json`` baseline holds.
"""
from __future__ import annotations

import json
import time

import jax

ROWS: list[tuple[str, float, str]] = []
JSON_ROWS: list[dict] = []


def timeit(fn, *args, reps: int = 5, warmup: int = 1) -> float:
    """Median wall-time per call in seconds (jit included via warmup)."""
    for _ in range(warmup):
        out = fn(*args)
    jax.block_until_ready(out)
    times = []
    for _ in range(reps):
        t0 = time.perf_counter()
        out = fn(*args)
        jax.block_until_ready(out)
        times.append(time.perf_counter() - t0)
    times.sort()
    return times[len(times) // 2]


def row(name: str, seconds: float, derived: str, **meta):
    """Emit one benchmark row: CSV to stdout, structured copy to JSON_ROWS.

    ``meta`` keys ride into the JSON row verbatim (op, mesh, impl,
    overlap, model seconds, errors...) so downstream tooling never has to
    re-parse the human ``derived`` string.
    """
    ROWS.append((name, seconds * 1e6, derived))
    JSON_ROWS.append(
        {"name": name, "us_per_call": seconds * 1e6, "derived": derived,
         **meta}
    )
    print(f"{name},{seconds * 1e6:.1f},{derived}", flush=True)


def emit_json(path: str) -> None:
    """Write every row recorded so far to ``path`` as deterministic
    (sorted keys, indented) JSON: ``{"backend": ..., "rows": [...]}``."""
    payload = {"backend": jax.default_backend(), "rows": JSON_ROWS}
    with open(path, "w") as f:
        json.dump(payload, f, indent=2, sort_keys=True)
        f.write("\n")
