"""Fig. 9c: sparse-dense matmul over the paper's density range (0.12%-2.8%),
unstructured operands, ELL and block-sparse (BSR/MXU) forms.

Both sparse operands are pytrees (EllMatrix / BsrMatrix) passed whole through
``jax.jit``; the impl comes from the registry default set in run.py.
"""
import jax
import jax.numpy as jnp
import numpy as np

from benchmarks.common import row, timeit
from repro.core import sparse as sp
from repro.kernels import ops


def run():
    rng = np.random.default_rng(0)
    R, C, F = 1024, 2048, 256
    for density in (0.0012, 0.01, 0.028):
        A = sp.random_ell(rng, R, C, density)
        D = jnp.asarray(rng.standard_normal((C, F)), jnp.float32)
        fn = jax.jit(lambda a, d: ops.spmm(a, d))
        t = timeit(fn, A, D)
        flops = 2 * A.values.size * F  # padded-ELL useful work
        row(f"fig9c_spmm_ell_d{density*100:.2f}pct", t,
            f"{flops / t / 1e9:.2f} GFLOP/s;nnz={A.nnz}")

        bsr = sp.ell_to_bsr(A, bm=8, bk=128)
        fn2 = jax.jit(lambda a, d: ops.bsr_spmm(a, d))
        t2 = timeit(fn2, bsr, D)
        tile_flops = 2 * bsr.tile_values.size * F
        row(f"fig9c_spmm_bsr_d{density*100:.2f}pct", t2,
            f"{tile_flops / t2 / 1e9:.2f} GFLOP/s;"
            f"tile_density={bsr.density:.3f}")
