"""Fig. 9c: sparse-dense matmul over the paper's density range (0.12%-2.8%),
unstructured operands, ELL and block-sparse (BSR/MXU) forms."""
import jax
import jax.numpy as jnp
import numpy as np

from benchmarks.common import row, timeit
from repro.core import sparse as sp
from repro.kernels import ops


def run():
    rng = np.random.default_rng(0)
    R, C, F = 1024, 2048, 256
    for density in (0.0012, 0.01, 0.028):
        A = sp.random_ell(rng, R, C, density)
        D = jnp.asarray(rng.standard_normal((C, F)), jnp.float32)
        av, ac = jnp.asarray(A.values), jnp.asarray(A.cols)
        fn = jax.jit(lambda v, c, d: ops.spmm(v, c, d, impl="xla"))
        t = timeit(fn, av, ac, D)
        flops = 2 * A.values.size * F  # padded-ELL useful work
        row(f"fig9c_spmm_ell_d{density*100:.2f}pct", t,
            f"{flops / t / 1e9:.2f} GFLOP/s;nnz={A.nnz}")

        dense_A = A.todense()
        bsr = sp.dense_to_bsr(dense_A, bm=8, bk=128)
        fn2 = jax.jit(lambda tv, tr, tc, d: ops.bsr_spmm(tv, tr, tc, d, R,
                                                         impl="xla"))
        t2 = timeit(fn2, jnp.asarray(bsr.tile_values),
                    jnp.asarray(bsr.tile_rows), jnp.asarray(bsr.tile_cols), D)
        tile_flops = 2 * bsr.tile_values.size * F
        row(f"fig9c_spmm_bsr_d{density*100:.2f}pct", t2,
            f"{tile_flops / t2 / 1e9:.2f} GFLOP/s;"
            f"tile_density={bsr.density:.3f}")
