"""Fig. 11: GCN layer (144x144 features) on citation-style graphs — the
paper's mixed dense + sparse-dense ML inference workload. The adjacency is
an EllMatrix pytree jitted straight through ``gcn.forward``."""
import jax
import jax.numpy as jnp
import numpy as np

from benchmarks.common import row, timeit
from repro.core import sparse as sp
from repro.models import gcn

GRAPHS = [("webkb", 877, 1.8), ("cora", 2708, 2.0), ("citeseer", 3327, 1.4)]
F = 144


def run():
    rng = np.random.default_rng(0)
    params = gcn.init_params(jax.random.PRNGKey(0), [F, F])
    for name, n, deg in GRAPHS:
        L = max(int(round(deg)) + 1, 2)
        cols = rng.integers(0, n, (n, L)).astype(np.int32)
        cols[:, 0] = np.arange(n)
        adj = sp.EllMatrix(
            jnp.full((n, L), 1.0 / L, jnp.float32), jnp.asarray(cols), (n, n)
        )
        feats = jnp.asarray(rng.standard_normal((n, F)), jnp.float32)
        fn = jax.jit(lambda a, x: gcn.forward(params, a, x))
        t = timeit(fn, adj, feats)
        flops = 2 * n * F * F + 2 * adj.values.size * F
        row(f"fig11_gcn_{name}", t,
            f"{flops / t / 1e9:.2f} GFLOP/s;nodes={n}")
