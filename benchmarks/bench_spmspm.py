"""Fig. 9d: sparse x sparse matmul by index intersection. Right matrices at
the paper's 1% density; figure of merit is index comparisons/s (GCOMP/s)."""
import jax
import jax.numpy as jnp
import numpy as np

from benchmarks.common import row, timeit
from repro.core import sparse as sp
from repro.kernels import ops, ref


def run():
    rng = np.random.default_rng(0)
    K = 2048
    for left_density in (0.0012, 0.01, 0.028):
        A = sp.random_ell(rng, 512, K, left_density)
        B = sp.random_ell(rng, 512, K, 0.01)  # paper: right at 1%
        args = (jnp.asarray(A.values), jnp.asarray(A.cols),
                jnp.asarray(B.values), jnp.asarray(B.cols))
        fn = jax.jit(lambda av, ac, bv, bc: ops.spmspm(av, ac, bv, bc, K,
                                                       impl="xla"))
        t = timeit(fn, *args)
        comps = ref.spmspm_comparisons(args[1], args[3])
        row(f"fig9d_spmspm_d{left_density*100:.2f}pct", t,
            f"{comps / t / 1e9:.2f} GCOMP/s")
