"""Fig. 9d: sparse x sparse matmul by index intersection. Right matrices at
the paper's 1% density; figure of merit is index comparisons/s (GCOMP/s)."""
import jax
import numpy as np

from benchmarks.common import row, timeit
from repro.core import sparse as sp
from repro.kernels import ops, ref


def run():
    rng = np.random.default_rng(0)
    K = 2048
    for left_density in (0.0012, 0.01, 0.028):
        A = sp.random_ell(rng, 512, K, left_density)
        B = sp.random_ell(rng, 512, K, 0.01)  # paper: right at 1%
        fn = jax.jit(lambda a, b: ops.spmspm(a, b, K))
        t = timeit(fn, A, B)
        comps = ref.spmspm_comparisons(A.cols, B.cols)
        row(f"fig9d_spmspm_d{left_density*100:.2f}pct", t,
            f"{comps / t / 1e9:.2f} GCOMP/s")
