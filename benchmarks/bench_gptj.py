"""Fig. 12: GPT-J inference in non-autoregressive (= prefill) mode, token
rate vs sequence length; attention runs the FlashAttention-2 dataflow."""
import jax
import jax.numpy as jnp
import numpy as np

from benchmarks.common import row, timeit
from repro.configs.base import get_config
from repro.models import registry

CFG = get_config("occamy-gptj", reduced=True).replace(
    num_layers=4, d_model=256, num_heads=4, num_kv_heads=4, head_dim=64,
    d_ff=1024, vocab_size=8192,
)


def run():
    params = registry.init_params(CFG, jax.random.PRNGKey(0))
    rng = np.random.default_rng(0)
    fwd = jax.jit(lambda p, b: registry.forward(p, CFG, b)[0])
    for seq in (128, 256, 512, 1024):
        tokens = jnp.asarray(rng.integers(0, CFG.vocab_size, (1, seq)),
                             jnp.int32)
        t = timeit(fwd, params, {"tokens": tokens})
        row(f"fig12_gptj_prefill_s{seq}", t, f"{seq / t:.1f} tok/s")
