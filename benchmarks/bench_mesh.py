"""Fig. 13 made executable: per-op sharded-vs-single-device rows.

For every op with a PartitionRule, times the op once on a single device and
once partitioned over the host mesh (``--mesh DxM`` or the three-axis
``--mesh PxDxM`` on benchmarks/run.py) — same ``ops.*`` signature, the mesh
passed as a kwarg. ``derived`` carries the speedup, the plan note (which
logical axis split over which levels, which collective fired), and the
topology-model collective seconds for the plan — total (``d2d_model``) and
per level (``coll_per_level``, intra-pod vs cross-pod) — so the
measured-vs-model comparison of the scaling story sits in one CSV row.

Two flash_attention rows run: the GPT-J-shaped batch/head case and a
``flash_attention_long`` long-context case (B=1, so the batch split cannot
engage) that exercises the sequence-parallel KV ring — its ``derived``
column carries the per-hop ppermute seconds the ring's (n-1) hops cost on
the ``data`` level.

CPU caveat: forced host devices share the machine, so wall-clock speedups
are NOT the point here — numerical agreement and the collective schedule
are; the model column carries the bandwidth-scaled expectation.
"""
import jax
import jax.numpy as jnp
import numpy as np

from benchmarks.common import row, timeit
from repro.core import sparse as sp
from repro.kernels import ops, partition, registry
from repro.launch import roofline


def _cases(rng):
    """(label, op, call(mesh) -> out, plan_args, plan_kwargs) rows; labels
    are unique per row (op names repeat for the long-context variant)."""
    f32 = jnp.float32
    a = jnp.asarray(rng.standard_normal((256, 256)), f32)
    b = jnp.asarray(rng.standard_normal((256, 256)), f32)
    q = jnp.asarray(rng.standard_normal((4, 8, 256, 64)), f32)
    k = jnp.asarray(rng.standard_normal((4, 8, 256, 64)), f32)
    v = jnp.asarray(rng.standard_normal((4, 8, 256, 64)), f32)
    # long context: B=1 blocks the batch split, so the data axis carries the
    # sequence — the ring seq-parallel row
    qL = jnp.asarray(rng.standard_normal((1, 8, 2048, 64)), f32)
    kL = jnp.asarray(rng.standard_normal((1, 4, 2048, 64)), f32)
    vL = jnp.asarray(rng.standard_normal((1, 4, 2048, 64)), f32)
    qd = jnp.asarray(rng.standard_normal((8, 8, 64)), f32)
    kd = jnp.asarray(rng.standard_normal((8, 8, 512, 64)), f32)
    vd = jnp.asarray(rng.standard_normal((8, 8, 512, 64)), f32)
    pos = jnp.full((8,), 511, jnp.int32)
    r = jnp.asarray(rng.standard_normal((1, 8, 512, 32)), f32)
    wl = jnp.asarray(-rng.uniform(0.01, 1.0, (1, 8, 512, 32)), f32)
    ell = sp.random_ell(rng, 1024, 1024, 0.02)
    dn = jnp.asarray(rng.standard_normal((1024, 64)), f32)
    bsr_dense = np.zeros((128, 1024), np.float32)
    bsr_dense[::2, ::9] = 1.0
    bsrA = sp.dense_to_bsr(bsr_dense, bm=8, bk=128)
    brhs = jnp.asarray(rng.standard_normal((1024, 64)), f32)
    sA = sp.random_ell(rng, 256, 512, 0.05)
    sB = sp.random_ell(rng, 256, 512, 0.05)
    grid = jnp.asarray(rng.standard_normal((64, 32, 32)), f32)
    offs = np.array([(0, 0, 0), (1, 0, 0), (-1, 0, 0), (0, 1, 0),
                     (0, 0, 1)], np.int32)
    w = np.full((5,), 0.2, np.float32)
    return [
        ("gemm", "gemm", lambda m: ops.gemm(a, b, mesh=m), (a, b), {}),
        ("flash_attention", "flash_attention",
         lambda m: ops.flash_attention(q, k, v, mesh=m), (q, k, v), {}),
        ("flash_attention_long", "flash_attention",
         lambda m: ops.flash_attention(qL, kL, vL, mesh=m), (qL, kL, vL), {}),
        ("decode_attention", "decode_attention",
         lambda m: ops.decode_attention(qd, kd, vd, pos, mesh=m),
         (qd, kd, vd, pos), {}),
        ("linear_attention", "linear_attention",
         lambda m: ops.linear_attention(r, r, r, wl, mesh=m)[0],
         (r, r, r, wl), {}),
        ("spmm", "spmm", lambda m: ops.spmm(ell, dn, mesh=m),
         (ell.values, ell.cols, dn), {}),
        ("bsr_spmm", "bsr_spmm", lambda m: ops.bsr_spmm(bsrA, brhs, mesh=m),
         (bsrA.tile_values, bsrA.tile_rows, bsrA.tile_cols, brhs),
         {"num_rows": bsrA.shape[0]}),
        ("spmspm", "spmspm", lambda m: ops.spmspm(sA, sB, 512, mesh=m),
         (sA.values, sA.cols, sB.values, sB.cols), {"contraction_dim": 512}),
        ("stencil", "stencil", lambda m: ops.stencil(grid, offs, w, mesh=m),
         (grid,), {"offsets": offs, "weights": w}),
    ]


def _overlap_cases(rng):
    """(label, op, call(mesh, overlap) -> out, plan_args, plan_kwargs) for
    the ops with an overlappable ring/halo schedule: the long-context
    flash ring and the halo-exchange stencil."""
    f32 = jnp.float32
    qL = jnp.asarray(rng.standard_normal((1, 8, 2048, 64)), f32)
    kL = jnp.asarray(rng.standard_normal((1, 4, 2048, 64)), f32)
    vL = jnp.asarray(rng.standard_normal((1, 4, 2048, 64)), f32)
    grid = jnp.asarray(rng.standard_normal((64, 32, 32)), f32)
    offs = np.array([(0, 0, 0), (1, 0, 0), (-1, 0, 0), (0, 1, 0),
                     (0, 0, 1)], np.int32)
    w = np.full((5,), 0.2, np.float32)
    return [
        ("flash_attention_long", "flash_attention",
         lambda m, ov: ops.flash_attention(qL, kL, vL, mesh=m, overlap=ov),
         (qL, kL, vL), {}),
        ("stencil", "stencil",
         lambda m, ov: ops.stencil(grid, offs, w, mesh=m, overlap=ov),
         (grid,), {"offsets": offs, "weights": w}),
    ]


def run(mesh=None):
    if mesh is None:
        return  # no --mesh: the sharded rows need a multi-device host mesh
    rng = np.random.default_rng(0)
    impl = registry.resolve_impl(None)
    levels = partition.partition_levels(mesh)
    levels_tag = "*".join(f"{a}{n}" for a, n in levels) or "none"
    for label, op, call, plan_args, plan_kwargs in _cases(rng):
        plan = partition.plan_for(op, mesh, *plan_args, **plan_kwargs)
        note = plan.note.replace(",", ";") if plan else "replicated"
        by_level = roofline.plan_collective_seconds_by_level(plan)
        d2d = sum(by_level.values())
        per_level = "/".join(
            f"{ax}={s * 1e6:.2f}us" for ax, s in by_level.items()
        ) or "none"
        f_single = jax.jit(lambda c=call: c(None))
        f_shard = jax.jit(lambda c=call: c(mesh))
        t_single = timeit(f_single, reps=3)
        t_shard = timeit(f_shard, reps=3)
        err = float(
            jnp.max(jnp.abs(jnp.asarray(f_shard()) - jnp.asarray(f_single())))
        )
        row(
            f"mesh_{label}", t_shard,
            f"single_us={t_single * 1e6:.1f};speedup={t_single / t_shard:.2f}x;"
            f"levels={levels_tag};{note};"
            f"d2d_model={d2d * 1e6:.2f}us;coll_per_level={per_level};"
            f"max_err={err:.1e}",
            op=op, mesh=levels_tag, impl=impl, overlap=None,
            single_us=t_single * 1e6, d2d_model_s=d2d, max_err=err, note=note,
        )

    # overlap-vs-sync rows: same op and mesh, only the ring/halo schedule
    # flips. On shared host devices the wall-clock delta is noise — the row
    # exists to pin numerical agreement and to carry the overlap model
    # (serial_s vs overlapped_s from the plan's hop count) next to the
    # measurements; dryrun --op-roofline owns the full roofline cells.
    for label, op, call, plan_args, plan_kwargs in _overlap_cases(rng):
        plan = partition.plan_for(op, mesh, *plan_args, **plan_kwargs)
        if plan is None or not plan.overlappable:
            continue
        d2d = roofline.plan_collective_seconds(plan)
        f_sync = jax.jit(lambda c=call: c(mesh, False))
        f_ovl = jax.jit(lambda c=call: c(mesh, True))
        t_sync = timeit(f_sync, reps=3)
        t_ovl = timeit(f_ovl, reps=3)
        err = float(
            jnp.max(jnp.abs(jnp.asarray(f_ovl()) - jnp.asarray(f_sync())))
        )
        ovl_s = roofline.overlapped_seconds(
            max(t_sync - d2d, 0.0), d2d, plan.hops
        )
        row(
            f"mesh_overlap_{label}", t_ovl,
            f"sync_us={t_sync * 1e6:.1f};hops={plan.hops};"
            f"d2d_model={d2d * 1e6:.2f}us;"
            f"model_overlapped_us={ovl_s * 1e6:.1f};max_err={err:.1e}",
            op=op, mesh=levels_tag, impl=impl, overlap=True,
            sync_us=t_sync * 1e6, hops=plan.hops, d2d_model_s=d2d,
            model_overlapped_s=ovl_s, max_err=err,
        )
