"""Fig. 13: D2D-link behaviour — (a) linear bandwidth degradation as lanes
are disabled, (b) effective bandwidth vs transfer size.

The TPU analogue of the D2D link is the pod axis. (a) maps to the elastic
re-mesh contract (throughput ~ surviving data-parallel ranks); (b) to the
ring-collective efficiency model from core/topology (latency-vs-bandwidth
regime, like the paper's 96% utilization at 16 kB transfers).
"""
import numpy as np

from benchmarks.common import row
from repro.core.topology import POD_LINK_BW, collective_seconds

LINK_LATENCY = 1e-6  # per-hop launch overhead (the paper's 61-cycle analogue)


def run():
    # (a) lane disabling -> linear degradation (38 PHYs in the paper)
    lanes = 38
    for disabled in (0, 8, 16, 24):
        frac = (lanes - disabled) / lanes
        row(f"fig13a_d2d_disable_{disabled}", LINK_LATENCY,
            f"{frac * POD_LINK_BW / 1e9:.2f} GB/s;linear_frac={frac:.2f}")

    # (b) effective bandwidth vs transfer size (latency-bound -> bw-bound)
    for size in (1024, 4096, 16384, 65536, 262144, 1048576):
        t = LINK_LATENCY + size / POD_LINK_BW
        eff = size / t
        row(f"fig13b_d2d_xfer_{size}B", t,
            f"{eff / 1e9:.2f} GB/s;util={eff / POD_LINK_BW:.2%}")

    # pod-axis gradient all-reduce cost (the framework's real D2D traffic)
    for gbytes in (0.1, 1.0, 2.45):  # up to grok-1's per-device param bytes
        t = collective_seconds("all_reduce", gbytes * 1e9, "pod", 2)
        row(f"fig13_pod_allreduce_{gbytes}GB", t,
            f"{2 * gbytes / t:.1f} GB/s effective")
