"""Fig. 13: D2D-link behaviour — (a) linear bandwidth degradation as lanes
are disabled, (b) effective bandwidth vs transfer size.

The TPU analogue of the D2D link is the pod axis. (a) maps to the elastic
re-mesh contract (throughput ~ surviving data-parallel ranks); (b) to the
ring-collective efficiency model from core/topology (latency-vs-bandwidth
regime, like the paper's 96% utilization at 16 kB transfers).

The pod-allreduce rows are MEASURED when the process sees more than one
device (benchmarks/run.py ``--mesh DxM`` forces a host-device mesh): a real
``shard_map`` psum runs over all devices as a 1-D pod axis, and the analytic
ring number rides along as ``model=`` metadata — Fig. 13b's measured-vs-model
column. Single-device runs keep the analytic rows (tagged accordingly).
"""
import jax
import jax.numpy as jnp
from jax.sharding import PartitionSpec as P

from benchmarks.common import row, timeit
from repro.core.topology import POD_LINK_BW, collective_seconds
from repro.parallel.compat import shard_map

LINK_LATENCY = 1e-6  # per-hop launch overhead (the paper's 61-cycle analogue)


def _measured_allreduce_rows():
    """psum over every host device as a 1-D pod axis; per-device buffer
    sizes kept CPU-friendly (the analytic model scales linearly anyway)."""
    n = jax.device_count()
    mesh = jax.make_mesh((n,), ("pod",))
    for mbytes in (1, 4, 16):
        per_dev = mbytes * (1 << 20)
        elems = per_dev // 4
        x = jnp.ones((n * elems,), jnp.float32)
        f = jax.jit(
            shard_map(
                lambda v: jax.lax.psum(v, "pod"),
                mesh=mesh, in_specs=P("pod"), out_specs=P("pod"),
                check_vma=False,
            )
        )
        t = timeit(f, x, reps=3)
        model = collective_seconds("all_reduce", per_dev, "pod", n)
        eff = 2 * per_dev * (n - 1) / n / t  # ring bytes actually moved
        yield (
            f"fig13b_pod_allreduce_{mbytes}MBx{n}", t,
            f"{eff / 1e9:.2f} GB/s measured;model={model * 1e6:.1f}us;"
            f"model_bw={POD_LINK_BW / 1e9:.0f}GB/s",
        )


def run():
    # (a) lane disabling -> linear degradation (38 PHYs in the paper)
    lanes = 38
    for disabled in (0, 8, 16, 24):
        frac = (lanes - disabled) / lanes
        row(f"fig13a_d2d_disable_{disabled}", LINK_LATENCY,
            f"{frac * POD_LINK_BW / 1e9:.2f} GB/s;linear_frac={frac:.2f}")

    # (b) effective bandwidth vs transfer size (latency-bound -> bw-bound)
    for size in (1024, 4096, 16384, 65536, 262144, 1048576):
        t = LINK_LATENCY + size / POD_LINK_BW
        eff = size / t
        row(f"fig13b_d2d_xfer_{size}B", t,
            f"{eff / 1e9:.2f} GB/s;util={eff / POD_LINK_BW:.2%}")

    # pod-axis gradient all-reduce (the framework's real D2D traffic):
    # measured over the forced host-device mesh when one exists, with the
    # analytic ring model alongside; analytic-only on a single device.
    if jax.device_count() > 1:
        for name, t, derived in _measured_allreduce_rows():
            row(name, t, derived)
    else:
        for gbytes in (0.1, 1.0, 2.45):  # up to grok-1's per-device params
            t = collective_seconds("all_reduce", gbytes * 1e9, "pod", 2)
            row(f"fig13_pod_allreduce_{gbytes}GB", t,
                f"{2 * gbytes / t:.1f} GB/s effective;model=analytic-only")
