"""Benchmark harness: one module per paper table/figure.

Prints ``name,us_per_call,derived`` CSV (see benchmarks/common.py for the
CPU-timing caveats and the derived figure-of-merit definitions).

Implementation selection is registry-global: the harness pins the ``xla``
impls (the lowering-representative blocked forms — Pallas cannot lower on
CPU) once here, scoped via ``registry.default_impl`` so nothing leaks past
the run. Override with ``REPRO_BENCH_IMPL=interpret`` etc.

``--autotune`` (or ``REPRO_AUTOTUNE=1``) runs the block-size autotuner
(repro.launch.autotune) before the benchmarks: if the tuning record already
exists it is loaded and applied deterministically — no re-search — otherwise
the search runs and persists it. Tuned-vs-default ``us_per_call`` deltas are
emitted as ``autotune_<op>`` CSV rows, and the benchmarks then run under the
tuned overrides.

``--mesh DxM`` backs a (data, model) mesh — and ``--mesh PxDxM`` the
three-axis (pod, data, model) hierarchy, where kernel partition plans
resolve two-level with per-level collective costing — with forced
host-platform devices (the flag must be decided before jax imports, which
is why argument parsing precedes the jax import here) and emits per-op
sharded-vs-single rows (benchmarks/bench_mesh.py), including the
``mesh_overlap_*`` rows comparing the overlapped ring/halo schedules
against their synchronous oracles. ``--mesh-only`` stops after those rows
(CI smoke for the multi-device job). When ``--autotune`` and ``--mesh``
combine, the tuner searches through the sharded dispatch and keys its
record by the local shard geometry (see repro/launch/autotune.py);
``--autotune-budget N`` caps how many candidates each case measures,
spent in roofline-prior order.

``--json PATH`` additionally writes every emitted row as machine-readable
JSON (structured op/mesh/impl/overlap metadata alongside the measured
microseconds) — the committed ``BENCH_mesh.json`` host-backend baseline
is produced by ``python -m benchmarks.run --mesh 4x2 --mesh-only --json
BENCH_mesh.json``.
"""
import argparse
import math
import os


def _parse_mesh(spec: str) -> tuple[int, ...]:
    """``DxM`` -> a (data, model) mesh; ``PxDxM`` -> (pod, data, model)."""
    try:
        dims = tuple(int(x) for x in spec.lower().split("x"))
    except ValueError:
        dims = ()
    if len(dims) not in (2, 3):
        raise SystemExit(
            f"--mesh expects DxM or PxDxM (e.g. 2x4 or 2x2x2), got {spec!r}"
        )
    if any(d < 1 for d in dims):
        raise SystemExit(f"--mesh axes must be >= 1, got {spec!r}")
    return dims


_MESH_AXES = {2: ("data", "model"), 3: ("pod", "data", "model")}


def main(argv=None) -> None:
    ap = argparse.ArgumentParser(description=__doc__)
    ap.add_argument("--autotune", action="store_true",
                    help="tune block sizes first (or load the existing record)")
    ap.add_argument("--autotune-record", default="autotune_record.json")
    ap.add_argument("--autotune-reps", type=int, default=3)
    ap.add_argument("--autotune-budget", type=int, default=None, metavar="N",
                    help="time at most N candidates per autotune case, "
                    "spent in roofline-prior order (the default geometry "
                    "is always measured)")
    ap.add_argument("--autotune-only", action="store_true",
                    help="emit the autotune rows and stop (CI smoke)")
    ap.add_argument("--json", default=None, metavar="PATH",
                    help="also write every row as machine-readable JSON "
                    "(benchmarks/common.py emit_json) to PATH on exit")
    ap.add_argument("--mesh", default=None, metavar="DxM|PxDxM",
                    help="(data, model) or (pod, data, model) mesh for the "
                         "sharded-vs-single rows; forces that many host "
                         "devices on CPU")
    ap.add_argument("--mesh-only", action="store_true",
                    help="emit the mesh rows and stop (CI smoke)")
    args = ap.parse_args(argv)
    if args.mesh_only and not args.mesh:
        raise SystemExit("--mesh-only needs --mesh DxM")

    mesh_shape = _parse_mesh(args.mesh) if args.mesh else None
    mesh_devices = math.prod(mesh_shape) if mesh_shape else 0
    if mesh_shape is not None:
        # the shared append-only bootstrap (launch/xla_flags.py): caller
        # flags survive, and a caller-chosen device count wins
        from repro.launch.xla_flags import ensure_host_device_count

        ensure_host_device_count(mesh_devices)

    import jax

    from repro.kernels import registry

    tune = (args.autotune or args.autotune_only
            or os.environ.get("REPRO_AUTOTUNE") == "1")

    impl = os.environ.get("REPRO_BENCH_IMPL")
    if impl is None:
        # xla is the CPU stand-in; on TPU let auto pick the Pallas kernels
        impl = "xla" if jax.default_backend() != "tpu" else "auto"

    mesh = None
    if mesh_shape is not None:
        from repro.launch.mesh import make_mesh

        if jax.device_count() < mesh_devices:
            raise SystemExit(
                f"--mesh {args.mesh} needs {mesh_devices} devices, have "
                f"{jax.device_count()} (is XLA_FLAGS already set?)"
            )
        mesh = make_mesh(mesh_shape, _MESH_AXES[len(mesh_shape)])

    def finish():
        # --json: dump every row recorded through benchmarks/common.row
        # (shared by all exit paths, including the --*-only CI smokes)
        if args.json:
            from benchmarks.common import emit_json

            emit_json(args.json)

    with registry.default_impl(impl):
        print("name,us_per_call,derived")
        if tune:
            from repro.launch import autotune as at

            record = None
            source = "loaded"
            if os.path.exists(args.autotune_record):
                record = at.load_record(args.autotune_record)
                if not at.record_matches_environment(record, mesh=mesh):
                    # tuned for a different backend/impl/mesh: re-search
                    # rather than silently mistune this one
                    record = None
            if record is None:
                # tuning under the mesh keys each entry by the LOCAL shard
                # geometry, so the record stays valid for the kernels the
                # sharded dispatch actually runs
                record = at.autotune(reps=args.autotune_reps, mesh=mesh,
                                     trial_budget=args.autotune_budget)
                at.save_record(record, args.autotune_record)
                source = "searched"
            at.apply_record(record, mesh=mesh)
            for op, d in sorted(at.record_deltas(record).items()):
                delta = ("n/a" if d["delta_pct"] is None
                         else f"{d['delta_pct']:+.1f}%")
                default_us = ("n/a" if d["default_us"] is None
                              else f"{d['default_us']:.1f}")
                tuned_us = ("n/a" if d["us_per_call"] is None
                            else f"{d['us_per_call']:.1f}")
                print(
                    f"autotune_{op},{tuned_us},"
                    f"default_us={default_us};delta={delta};"
                    f"blocks={'/'.join(f'{k}={v}' for k, v in sorted(d['blocks'].items()))};"
                    f"{source}",
                    flush=True,
                )
            if args.autotune_only:
                return finish()

        if mesh is not None:
            from benchmarks import bench_mesh

            bench_mesh.run(mesh)
            if args.mesh_only:
                return finish()

        from benchmarks import (bench_d2d, bench_gcn, bench_gemm, bench_gptj,
                                bench_precision, bench_spmm, bench_spmspm,
                                bench_stencil)

        for mod in (bench_gemm, bench_precision, bench_stencil, bench_spmm,
                    bench_spmspm, bench_gcn, bench_gptj, bench_d2d):
            mod.run()
        finish()


if __name__ == "__main__":
    main()
