"""Benchmark harness: one module per paper table/figure.

Prints ``name,us_per_call,derived`` CSV (see benchmarks/common.py for the
CPU-timing caveats and the derived figure-of-merit definitions).

Implementation selection is registry-global: the harness pins the ``xla``
impls (the lowering-representative blocked forms — Pallas cannot lower on
CPU) once here instead of threading ``impl=`` through every call site.
Override with ``REPRO_BENCH_IMPL=interpret`` etc.
"""
import os


def main() -> None:
    import jax

    from repro.kernels import registry

    impl = os.environ.get("REPRO_BENCH_IMPL")
    if impl is None:
        # xla is the CPU stand-in; on TPU let auto pick the Pallas kernels
        impl = "xla" if jax.default_backend() != "tpu" else "auto"
    registry.set_default_impl(impl)

    from benchmarks import (bench_d2d, bench_gcn, bench_gemm, bench_gptj,
                            bench_spmm, bench_spmspm, bench_stencil)

    print("name,us_per_call,derived")
    for mod in (bench_gemm, bench_stencil, bench_spmm, bench_spmspm,
                bench_gcn, bench_gptj, bench_d2d):
        mod.run()


if __name__ == "__main__":
    main()
