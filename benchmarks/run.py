"""Benchmark harness: one module per paper table/figure.

Prints ``name,us_per_call,derived`` CSV (see benchmarks/common.py for the
CPU-timing caveats and the derived figure-of-merit definitions).

Implementation selection is registry-global: the harness pins the ``xla``
impls (the lowering-representative blocked forms — Pallas cannot lower on
CPU) once here, scoped via ``registry.default_impl`` so nothing leaks past
the run. Override with ``REPRO_BENCH_IMPL=interpret`` etc.

``--autotune`` (or ``REPRO_AUTOTUNE=1``) runs the block-size autotuner
(repro.launch.autotune) before the benchmarks: if the tuning record already
exists it is loaded and applied deterministically — no re-search — otherwise
the search runs and persists it. Tuned-vs-default ``us_per_call`` deltas are
emitted as ``autotune_<op>`` CSV rows, and the benchmarks then run under the
tuned overrides.

``--mesh DxM`` backs a (data, model) mesh with forced host-platform devices
(the flag must be decided before jax imports, which is why argument parsing
precedes the jax import here) and emits per-op sharded-vs-single rows
(benchmarks/bench_mesh.py). ``--mesh-only`` stops after those rows (CI
smoke for the multi-device job).
"""
import argparse
import os


def _parse_mesh(spec: str) -> tuple[int, int]:
    try:
        d, m = (int(x) for x in spec.lower().split("x"))
    except ValueError:
        raise SystemExit(f"--mesh expects DxM (e.g. 2x4), got {spec!r}")
    if d < 1 or m < 1:
        raise SystemExit(f"--mesh axes must be >= 1, got {spec!r}")
    return d, m


def main(argv=None) -> None:
    ap = argparse.ArgumentParser(description=__doc__)
    ap.add_argument("--autotune", action="store_true",
                    help="tune block sizes first (or load the existing record)")
    ap.add_argument("--autotune-record", default="autotune_record.json")
    ap.add_argument("--autotune-reps", type=int, default=3)
    ap.add_argument("--autotune-only", action="store_true",
                    help="emit the autotune rows and stop (CI smoke)")
    ap.add_argument("--mesh", default=None, metavar="DxM",
                    help="(data, model) mesh for the sharded-vs-single rows; "
                         "forces DxM host devices on CPU")
    ap.add_argument("--mesh-only", action="store_true",
                    help="emit the mesh rows and stop (CI smoke)")
    args = ap.parse_args(argv)
    if args.mesh_only and not args.mesh:
        raise SystemExit("--mesh-only needs --mesh DxM")

    mesh_shape = _parse_mesh(args.mesh) if args.mesh else None
    if mesh_shape is not None:
        n = mesh_shape[0] * mesh_shape[1]
        flags = os.environ.get("XLA_FLAGS", "")
        if "--xla_force_host_platform_device_count" not in flags:
            os.environ["XLA_FLAGS"] = (
                flags + f" --xla_force_host_platform_device_count={n}"
            ).strip()

    import jax

    from repro.kernels import registry

    tune = (args.autotune or args.autotune_only
            or os.environ.get("REPRO_AUTOTUNE") == "1")

    impl = os.environ.get("REPRO_BENCH_IMPL")
    if impl is None:
        # xla is the CPU stand-in; on TPU let auto pick the Pallas kernels
        impl = "xla" if jax.default_backend() != "tpu" else "auto"

    with registry.default_impl(impl):
        print("name,us_per_call,derived")
        if tune:
            from repro.launch import autotune as at

            record = None
            source = "loaded"
            if os.path.exists(args.autotune_record):
                record = at.load_record(args.autotune_record)
                if not at.record_matches_environment(record):
                    # tuned for a different backend/impl: re-search rather
                    # than silently mistune this one
                    record = None
            if record is None:
                record = at.autotune(reps=args.autotune_reps)
                at.save_record(record, args.autotune_record)
                source = "searched"
            at.apply_record(record)
            for op, d in sorted(at.record_deltas(record).items()):
                delta = ("n/a" if d["delta_pct"] is None
                         else f"{d['delta_pct']:+.1f}%")
                default_us = ("n/a" if d["default_us"] is None
                              else f"{d['default_us']:.1f}")
                tuned_us = ("n/a" if d["us_per_call"] is None
                            else f"{d['us_per_call']:.1f}")
                print(
                    f"autotune_{op},{tuned_us},"
                    f"default_us={default_us};delta={delta};"
                    f"blocks={'/'.join(f'{k}={v}' for k, v in sorted(d['blocks'].items()))};"
                    f"{source}",
                    flush=True,
                )
            if args.autotune_only:
                return

        if mesh_shape is not None:
            from benchmarks import bench_mesh
            from repro.launch.mesh import make_mesh

            n = mesh_shape[0] * mesh_shape[1]
            if jax.device_count() < n:
                raise SystemExit(
                    f"--mesh {args.mesh} needs {n} devices, have "
                    f"{jax.device_count()} (is XLA_FLAGS already set?)"
                )
            bench_mesh.run(make_mesh(mesh_shape, ("data", "model")))
            if args.mesh_only:
                return

        from benchmarks import (bench_d2d, bench_gcn, bench_gemm, bench_gptj,
                                bench_spmm, bench_spmspm, bench_stencil)

        for mod in (bench_gemm, bench_stencil, bench_spmm, bench_spmspm,
                    bench_gcn, bench_gptj, bench_d2d):
            mod.run()


if __name__ == "__main__":
    main()
