"""Benchmark harness: one module per paper table/figure.

Prints ``name,us_per_call,derived`` CSV (see benchmarks/common.py for the
CPU-timing caveats and the derived figure-of-merit definitions).
"""


def main() -> None:
    from benchmarks import (bench_d2d, bench_gcn, bench_gemm, bench_gptj,
                            bench_spmm, bench_spmspm, bench_stencil)

    print("name,us_per_call,derived")
    for mod in (bench_gemm, bench_stencil, bench_spmm, bench_spmspm,
                bench_gcn, bench_gptj, bench_d2d):
        mod.run()


if __name__ == "__main__":
    main()
