"""Fig. 10 precision ladder: GFLOP/s + numerics per policy x op x impl.

Sweeps the ``core.precision`` policies through the scaled kernel paths of
every op that grew one (gemm, flash_attention, decode_attention) on both
CPU-runnable impls (xla blocked forms, interpret-mode Pallas). Each row
reports the measured CPU GFLOP/s, the modeled per-policy TPU peak
(``precision.peak_flops`` — the flop ceiling the dry-run roofline sweep
prices cells against), and the numerics: ``max_err`` / ``rel_err`` against
the fp32 oracle on the SAME operands, so the accuracy cost of each rung of
the width ladder sits next to its throughput claim.

The committed ``BENCH_precision.json`` baseline is produced by::

    PYTHONPATH=src python -m benchmarks.bench_precision --json BENCH_precision.json

CI re-asserts the ladder's modeled ordering from that file without
devices: the fp8 gemm row's ``flops_s`` must be >= 2x the bf16 row's.
"""
import argparse

import jax
import jax.numpy as jnp
import numpy as np

from benchmarks.common import emit_json, row, timeit
from repro.core import precision
from repro.kernels import ops, ref

POLICY_NAMES = ("fp32", "bf16", "fp8", "fp8_e5m2")
IMPLS = ("xla", "interpret")


def _err(got, want) -> tuple[float, float]:
    got = np.asarray(got, np.float32)
    want = np.asarray(want, np.float32)
    max_err = float(np.max(np.abs(got - want)))
    rel = float(
        np.linalg.norm(got - want) / max(np.linalg.norm(want), 1e-30)
    )
    return max_err, rel


def run():
    rng = np.random.default_rng(0)

    m = k = n = 256
    a = jnp.asarray(rng.standard_normal((m, k)), jnp.float32)
    b = jnp.asarray(rng.standard_normal((k, n)), jnp.float32)

    B, H, K, S, D = 1, 4, 4, 128, 64
    q = jnp.asarray(rng.standard_normal((B, H, S, D)), jnp.float32)
    kf = jnp.asarray(rng.standard_normal((B, K, S, D)), jnp.float32)
    vf = jnp.asarray(rng.standard_normal((B, K, S, D)), jnp.float32)

    Bd, Sd = 2, 256
    qd = jnp.asarray(rng.standard_normal((Bd, H, D)), jnp.float32)
    kc = jnp.asarray(rng.standard_normal((Bd, K, Sd, D)), jnp.float32)
    vc = jnp.asarray(rng.standard_normal((Bd, K, Sd, D)), jnp.float32)
    pos = jnp.full((Bd,), Sd - 1, jnp.int32)

    cases = [
        ("gemm", 2 * m * k * n,
         ref.gemm_ref(a, b, jnp.float32),
         lambda pol, impl: lambda *xs: ops.gemm(
             *xs, precision=pol, impl=impl),
         (a, b)),
        ("flash_attention", 4 * B * H * S * S * D,
         ref.mha_ref(q, kf, vf, causal=True),
         lambda pol, impl: lambda *xs: ops.flash_attention(
             *xs, causal=True, precision=pol, impl=impl),
         (q, kf, vf)),
        ("decode_attention", 4 * Bd * H * Sd * D,
         ref.decode_attention_ref(qd, kc, vc, pos),
         lambda pol, impl: lambda *xs: ops.decode_attention(
             *xs, precision=pol, impl=impl),
         (qd, kc, vc, pos)),
    ]

    for op, flops, oracle, make, operands in cases:
        for pol in POLICY_NAMES:
            peak = precision.peak_flops(pol)
            for impl in IMPLS:
                fn = make(pol, impl)
                if impl == "xla":
                    fn = jax.jit(fn)
                t = timeit(fn, *operands, reps=3)
                max_err, rel = _err(fn(*operands), oracle)
                row(
                    f"precision_{op}_{pol}_{impl}", t,
                    f"{flops / t / 1e9:.2f} GFLOP/s;"
                    f"peak={peak / 1e12:.0f}TFLOP/s;max_err={max_err:.2e}",
                    op=op, impl=impl, precision=pol, flops=flops,
                    flops_s=peak, measured_flops_s=flops / t,
                    max_err=max_err, rel_err=rel,
                )


def main(argv=None) -> None:
    """CLI: run the sweep; ``--json PATH`` also writes the structured rows
    (the committed ``BENCH_precision.json`` baseline)."""
    ap = argparse.ArgumentParser(description=__doc__)
    ap.add_argument("--json", default=None, metavar="PATH")
    args = ap.parse_args(argv)
    print("name,us_per_call,derived")
    run()
    if args.json:
        emit_json(args.json)


if __name__ == "__main__":
    main()
