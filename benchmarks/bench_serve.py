"""Serving-engine benchmark: continuous batching under Poisson load.

Drives the ``repro.serving`` engine — continuous-batching scheduler over a
paged KV cache — with a seeded open-loop arrival process and reports the
serving figures of merit: decode throughput (tok/s), request latency
percentiles (p50/p99, in *engine steps* — virtual time), preemption and
admission counts, and the block-ledger audit (leaked blocks must be 0).

Arrivals are Poisson in virtual time: request r arrives at step
``cumsum(Exp(1/lam))_r`` — deterministic given ``--seed``. EOS is disabled,
so retirement timing is pure scheduler arithmetic and the admission trace
``(step, rid, slot)*`` is a machine-independent function of the seed; the
committed ``BENCH_serve.json`` pins its hash and CI re-asserts it without
devices (same seed -> same admission trace, on any machine).

The committed baseline is produced by::

    PYTHONPATH=src python -m benchmarks.bench_serve --json BENCH_serve.json

``--smoke`` asserts the CI serving-job invariants (nonzero completions,
zero leaked blocks, finite p99) and exits nonzero on violation.
"""
import argparse
import hashlib
import time

import jax
import numpy as np

from benchmarks.common import emit_json, row
from repro.configs.base import get_config
from repro.models import registry as model_registry
from repro.serving.engine import Request, ServingEngine


def poisson_requests(rng, *, n, lam, vocab, prompt_lens=(4, 24),
                     gen_lens=(4, 16), priorities=(0, 0, 0, 1)):
    """Seeded open-loop workload: ``n`` requests with Exp(1/lam)
    inter-arrival steps (a Poisson process in virtual time), uniform
    prompt/gen lengths and a priority mix. Deterministic given ``rng``."""
    t = 0.0
    reqs = []
    for rid in range(n):
        t += rng.exponential(1.0 / lam)
        plen = int(rng.integers(prompt_lens[0], prompt_lens[1] + 1))
        reqs.append(Request(
            rid=rid,
            prompt=tuple(int(x) for x in rng.integers(1, vocab, plen)),
            max_new_tokens=int(rng.integers(gen_lens[0], gen_lens[1] + 1)),
            priority=int(priorities[rng.integers(0, len(priorities))]),
            arrival=int(t),
        ))
    return reqs


def trace_hash(engine) -> str:
    """SHA-256 over the admission trace — the reproducibility artifact."""
    return hashlib.sha256(
        repr(engine.scheduler.admission_trace()).encode()
    ).hexdigest()


def run(args):
    cfg = get_config(args.arch, reduced=True)
    params = model_registry.init_params(cfg, jax.random.PRNGKey(0))
    rng = np.random.default_rng(args.seed)
    reqs = poisson_requests(rng, n=args.requests, lam=args.rate,
                            vocab=cfg.vocab_size)

    engine = ServingEngine.with_model(
        cfg, params,
        num_blocks=args.num_blocks, block_size=args.block_size,
        max_slots=args.slots, max_blocks_per_seq=args.max_blocks_per_seq,
        eos_id=None,  # no EOS: the trace is scheduler arithmetic only
    )
    for r in reqs:
        engine.submit(r)

    t0 = time.perf_counter()
    engine.run(max_steps=args.max_steps)
    wall = time.perf_counter() - t0

    tokens = sum(len(v) for v in engine.completed.values())
    lat = np.array(sorted(engine.latency_steps.values()), np.float64)
    p50 = float(np.percentile(lat, 50)) if len(lat) else float("nan")
    p99 = float(np.percentile(lat, 99)) if len(lat) else float("nan")
    events = engine.scheduler.events
    preempts = sum(1 for e in events if e[0] == "preempt")
    leaked = engine.leaked_blocks()
    thash = trace_hash(engine)

    row("serve/throughput", wall / max(tokens, 1),
        f"{tokens / wall:.1f} tok/s",
        tokens=tokens, wall_s=wall, arch=args.arch, seed=args.seed,
        requests=args.requests, completed=len(engine.completed),
        steps=engine.step_count)
    row("serve/latency", wall / max(engine.step_count, 1),
        f"p50={p50:.0f} p99={p99:.0f} steps",
        p50_steps=p50, p99_steps=p99, preemptions=preempts,
        leaked_blocks=leaked, trace_sha256=thash,
        num_blocks=args.num_blocks, block_size=args.block_size,
        slots=args.slots)

    print(f"completed={len(engine.completed)}/{args.requests} "
          f"tokens={tokens} steps={engine.step_count} "
          f"preemptions={preempts} leaked={leaked}")
    print(f"trace_sha256={thash}")

    if args.smoke:
        assert len(engine.completed) > 0, "smoke: no requests completed"
        assert leaked == 0, f"smoke: {leaked} leaked blocks"
        assert np.isfinite(p99), "smoke: p99 latency not finite"
        assert len(engine.completed) == args.requests, (
            f"smoke: only {len(engine.completed)}/{args.requests} finished"
        )
        print("smoke OK")
    return 0


def main(argv=None):
    ap = argparse.ArgumentParser(description=__doc__.splitlines()[0])
    ap.add_argument("--arch", default="gemma-2b")
    ap.add_argument("--requests", type=int, default=24)
    ap.add_argument("--rate", type=float, default=1.5,
                    help="mean arrivals per engine step")
    ap.add_argument("--seed", type=int, default=0)
    ap.add_argument("--num-blocks", type=int, default=12)
    ap.add_argument("--block-size", type=int, default=8)
    ap.add_argument("--slots", type=int, default=4)
    ap.add_argument("--max-blocks-per-seq", type=int, default=6)
    ap.add_argument("--max-steps", type=int, default=5000)
    ap.add_argument("--json", default=None)
    ap.add_argument("--smoke", action="store_true")
    args = ap.parse_args(argv)
    rc = run(args)
    if args.json:
        emit_json(args.json)
    return rc


if __name__ == "__main__":
    raise SystemExit(main())
