"""Fig. 9b: stencil codes via indirect offset streams (SARIS analogue).

Paper grids: 64^2 tiles (2D) and 16^3 tiles (3D); shapes include j2d5pt,
j3d7pt, j3d27pt and higher-radius stars.
"""
import jax
import jax.numpy as jnp
import numpy as np

from benchmarks.common import row, timeit
from repro.kernels import ops


def star(radius, dims=3):
    offs = [[0, 0, 0]]
    for a in range(dims):
        for r in range(1, radius + 1):
            for s in (1, -1):
                o = [0, 0, 0]
                o[a] = s * r
                offs.append(o)
    return np.asarray(offs)


BOX27 = np.asarray([[dx, dy, dz] for dx in (-1, 0, 1) for dy in (-1, 0, 1)
                    for dz in (-1, 0, 1)])


def run():
    rng = np.random.default_rng(0)
    cases = {
        "j2d5pt_64x64": ((64, 64, 1), star(1, 2)),
        "j2d9pt_64x64": ((64, 64, 1), star(2, 2)),
        "j3d7pt_16c": ((16, 16, 16), star(1, 3)),
        "j3d13pt_16c": ((16, 16, 16), star(2, 3)),
        "j3d27pt_16c": ((16, 16, 16), BOX27),
    }
    for name, (shape, offs) in cases.items():
        g = jnp.asarray(rng.standard_normal(shape), jnp.float32)
        w = rng.standard_normal(len(offs)).astype(np.float32)
        fn = jax.jit(lambda x, offs=offs, w=w: ops.stencil(x, offs, w))
        t = timeit(fn, g)
        flops = 2 * g.size * len(offs)
        row(f"fig9b_{name}", t,
            f"{flops / t / 1e9:.2f} GFLOP/s;{len(offs)}pt")
