"""pydocstyle-lite for the documented public surfaces.

The partitioning layer and the autotuner are the modules users drive
directly (docs/partitioning.md documents them), so their public surface
carries a documentation contract: every exported class and function has a
real docstring, every parameter is mentioned by name, and dataclass fields
are described. Scoped deliberately — this is not a repo-wide style gate.
"""
import dataclasses
import importlib
import inspect
import re

import pytest

CHECKED_MODULES = ("repro.kernels.partition", "repro.launch.autotune")
MIN_DOC_LEN = 30


def _public_members(mod):
    for name, obj in vars(mod).items():
        if name.startswith("_"):
            continue
        if not (inspect.isfunction(obj) or inspect.isclass(obj)):
            continue
        if getattr(obj, "__module__", None) != mod.__name__:
            continue  # re-exports are documented at their home module
        yield name, obj


def _mentions(doc: str, param: str) -> bool:
    return re.search(rf"\b{re.escape(param)}\b", doc) is not None


def _param_names(obj):
    sig = inspect.signature(obj)
    for p in sig.parameters.values():
        if p.name in ("self", "cls"):
            continue
        yield p.name


@pytest.mark.parametrize("module_name", CHECKED_MODULES)
def test_module_docstring(module_name):
    mod = importlib.import_module(module_name)
    assert mod.__doc__ and len(mod.__doc__.strip()) >= MIN_DOC_LEN, (
        f"{module_name} needs a module docstring"
    )


@pytest.mark.parametrize("module_name", CHECKED_MODULES)
def test_public_surface_is_documented(module_name):
    mod = importlib.import_module(module_name)
    problems = []
    saw_any = False
    for name, obj in _public_members(mod):
        saw_any = True
        doc = inspect.getdoc(obj) or ""
        if len(doc) < MIN_DOC_LEN:
            problems.append(f"{name}: missing or trivial docstring")
            continue
        if inspect.isclass(obj):
            if dataclasses.is_dataclass(obj):
                for f in dataclasses.fields(obj):
                    if not _mentions(doc, f.name):
                        problems.append(
                            f"{name}: dataclass field {f.name!r} "
                            f"undocumented"
                        )
        else:
            for param in _param_names(obj):
                if not _mentions(doc, param):
                    problems.append(
                        f"{name}: parameter {param!r} not mentioned in "
                        f"docstring"
                    )
    assert saw_any, f"{module_name} exports nothing public?"
    assert not problems, (
        f"{module_name} public-surface doc contract violated:\n  "
        + "\n  ".join(problems)
    )
