"""pydocstyle-lite for the documented public surfaces — thin wrapper.

The partitioning layer and the autotuner are the modules users drive
directly (docs/partitioning.md documents them), so their public surface
carries a documentation contract: a real module docstring, every exported
class and function documented, every parameter mentioned by name, and
dataclass fields described. The contract itself now lives in the static
checker's ``docstring-contract`` rule (src/repro/analysis/ast_rules.py) —
these tests keep the invariant in the tier-1 suite, per checked module,
with the same names they have always had. Positive coverage (the rule
firing on seeded violations) lives in tests/test_analysis.py.
"""
import pytest

from repro.analysis import run_rules

# module name -> the rel-path suffix the analyzer reports findings under
CHECKED_MODULES = {
    "repro.kernels.partition": "kernels/partition.py",
    "repro.launch.autotune": "launch/autotune.py",
}


def _findings_for(suffix):
    return [
        f for f in run_rules(["docstring-contract"])
        if f.path.endswith(suffix)
    ]


@pytest.mark.parametrize("module_name", sorted(CHECKED_MODULES))
def test_module_docstring(module_name):
    suffix = CHECKED_MODULES[module_name]
    problems = [
        f for f in _findings_for(suffix) if "module docstring" in f.message
    ]
    assert problems == [], "\n".join(f.format() for f in problems)


@pytest.mark.parametrize("module_name", sorted(CHECKED_MODULES))
def test_public_surface_is_documented(module_name):
    suffix = CHECKED_MODULES[module_name]
    problems = _findings_for(suffix)
    assert problems == [], "\n".join(f.format() for f in problems)
