"""Serving battery: scheduler policy, block ledger, preempt/resume
round-trips, interleaving equivalence, and the serve.py prefill trace
regression.

Most of the battery drives the engine with ``StubModel`` — a deterministic
host-only token recurrence — so the scheduler properties run in
milliseconds with no compilation. Two tests go through the real paged
transformer to pin the device-side halves (bitwise preempt/resume and
interleaving invariance) at model scale.

The interleaving property (any admission-order interleaving yields token
streams identical to isolated decoding) runs under ``hypothesis`` when the
package is present and falls back to a seeded randomized sweep of the same
property otherwise — the container image does not ship hypothesis.
"""
import dataclasses

import numpy as np
import pytest

from repro.serving.engine import Request, ServingEngine, StubModel
from repro.serving.scheduler import (
    NULL_BLOCK,
    BlockAllocator,
    ContinuousBatchingScheduler,
)

try:
    from hypothesis import given, settings
    from hypothesis import strategies as st
    HAVE_HYPOTHESIS = True
except ImportError:  # container image has no hypothesis; seeded sweep below
    HAVE_HYPOTHESIS = False


def _random_requests(seed, n, *, vocab=251, max_prompt=8, max_new=12,
                     max_arrival=10, priorities=2):
    rng = np.random.default_rng(seed)
    return [
        Request(
            rid=rid,
            prompt=tuple(int(x) for x in
                         rng.integers(1, vocab,
                                      int(rng.integers(1, max_prompt + 1)))),
            max_new_tokens=int(rng.integers(1, max_new + 1)),
            priority=int(rng.integers(0, priorities)),
            arrival=int(rng.integers(0, max_arrival + 1)),
        )
        for rid in range(n)
    ]


def _engine(reqs, *, num_blocks=9, block_size=4, max_slots=3,
            max_blocks_per_seq=6):
    eng = ServingEngine(StubModel(), num_blocks=num_blocks,
                        block_size=block_size, max_slots=max_slots,
                        max_blocks_per_seq=max_blocks_per_seq)
    for r in reqs:
        eng.submit(r)
    return eng


# ---------------------------------------------------------------------------
# Allocator ledger
# ---------------------------------------------------------------------------


def test_allocator_basic_ledger():
    a = BlockAllocator(8)
    assert a.available() == 7  # NULL_BLOCK reserved
    got = a.alloc(1, 3)
    assert got is not None and NULL_BLOCK not in got
    assert a.owned_by(1) == sorted(got)
    assert a.alloc(2, 5) is None  # short: nothing popped
    assert a.available() == 4
    a.release(1, got)
    assert a.available() == 7 and a.owned_by(1) == []
    assert a.check() == []


def test_allocator_release_wrong_owner_raises():
    a = BlockAllocator(8)
    got = a.alloc(1, 2)
    with pytest.raises(RuntimeError, match="not owned"):
        a.release(2, got)


def test_allocator_fifo_determinism():
    a, b = BlockAllocator(16), BlockAllocator(16)
    for alloc in (a, b):
        x = alloc.alloc(1, 5)
        alloc.release(1, x[::-1])
        alloc.alloc(2, 3)
    assert list(a.free) == list(b.free)
    assert a.owned_by(2) == b.owned_by(2)


# ---------------------------------------------------------------------------
# Scheduler policy
# ---------------------------------------------------------------------------


def test_fcfs_within_priority_class():
    sched = ContinuousBatchingScheduler(num_blocks=32, block_size=4,
                                        max_slots=2)
    for rid, arrival in [(0, 5), (1, 2), (2, 2), (3, 0)]:
        sched.submit(Request(rid=rid, prompt=(1,), max_new_tokens=4,
                             arrival=arrival))
    admitted = sched.admit(10)
    # two slots: earliest arrivals first, rid breaks the tie at arrival 2
    assert [s.rid for s in admitted] == [3, 1]


def test_priority_classes_served_highest_first():
    sched = ContinuousBatchingScheduler(num_blocks=32, block_size=4,
                                        max_slots=2)
    sched.submit(Request(rid=0, prompt=(1,), max_new_tokens=4, priority=0,
                         arrival=0))
    sched.submit(Request(rid=1, prompt=(1,), max_new_tokens=4, priority=5,
                         arrival=3))
    assert [s.rid for s in sched.admit(10)] == [1, 0]


def test_head_of_line_blocks_no_skip():
    # rid 9 holds one block; rid 0 then needs 3 of the 2 remaining, and
    # rid 1 needs only 1 — FCFS means rid 1 must NOT jump the queue
    sched = ContinuousBatchingScheduler(num_blocks=4, block_size=4,
                                        max_slots=3)
    sched.submit(Request(rid=9, prompt=(1,), max_new_tokens=2, arrival=0))
    assert [s.rid for s in sched.admit(0)] == [9]
    sched.submit(Request(rid=0, prompt=tuple(range(1, 10)),
                         max_new_tokens=2, arrival=1))
    sched.submit(Request(rid=1, prompt=(1,), max_new_tokens=2, arrival=2))
    assert sched.admit(5) == []
    assert len(sched.admission_trace()) == 1  # only rid 9 ever admitted


def test_unsatisfiable_request_rejected_at_submit():
    sched = ContinuousBatchingScheduler(num_blocks=4, block_size=4,
                                        max_slots=2)
    with pytest.raises(ValueError, match="never fit"):
        sched.submit(Request(rid=0, prompt=tuple(range(1, 14)),
                             max_new_tokens=8, arrival=0))
    with pytest.raises(ValueError, match="duplicate"):
        sched.submit(Request(rid=1, prompt=(1,), max_new_tokens=1))
        sched.submit(Request(rid=1, prompt=(1,), max_new_tokens=1))


def test_admission_trace_is_seed_deterministic():
    t1 = _engine(_random_requests(11, 20))
    t2 = _engine(_random_requests(11, 20))
    t1.run()
    t2.run()
    assert t1.scheduler.admission_trace() == t2.scheduler.admission_trace()
    assert t1.completed == t2.completed


# ---------------------------------------------------------------------------
# No starvation / leaks
# ---------------------------------------------------------------------------


def test_no_starvation_under_tight_pool():
    # pool tight enough that preemption is constant; every request must
    # still finish, and nothing may be preempted unboundedly
    eng = _engine(_random_requests(5, 30, max_prompt=4, max_new=8),
                  num_blocks=7, block_size=2,
                  max_blocks_per_seq=None, max_slots=3)
    out = eng.run(max_steps=20_000)
    assert len(out) == 30
    preempts = sum(1 for e in eng.scheduler.events if e[0] == "preempt")
    assert preempts > 0, "scenario must actually exercise preemption"
    worst = max(s.preemptions for s in eng.scheduler.finished.values())
    assert worst <= 10, f"a request was preempted {worst} times"
    assert eng.leaked_blocks() == 0


def test_no_block_leak_after_1k_requests():
    eng = _engine(_random_requests(99, 1000, max_arrival=400, max_new=6),
                  num_blocks=17, block_size=4, max_slots=5,
                  max_blocks_per_seq=4)
    out = eng.run(max_steps=100_000)
    assert len(out) == 1000
    assert eng.leaked_blocks() == 0
    assert eng.scheduler.allocator.check() == []
    # every block release is accounted: grows+admits == retires+preempts
    ev = eng.scheduler.events
    allocated = sum(len(e[4]) for e in ev if e[0] == "admit") + \
        sum(1 for e in ev if e[0] == "grow")
    freed = sum(len(e[4]) for e in ev if e[0] in ("retire", "preempt"))
    assert allocated == freed


# ---------------------------------------------------------------------------
# Preempt/resume + interleaving equivalence (Stub level)
# ---------------------------------------------------------------------------


def test_preempt_resume_roundtrip_bitwise_stub():
    reqs = _random_requests(21, 14, max_prompt=4, max_new=8)
    tight = _engine(reqs, num_blocks=7, block_size=2, max_slots=3)
    roomy = _engine([dataclasses.replace(r) for r in reqs],
                    num_blocks=64, block_size=2, max_slots=3)
    out_t, out_r = tight.run(max_steps=20_000), roomy.run(max_steps=20_000)
    assert sum(1 for e in tight.scheduler.events if e[0] == "preempt") > 0
    assert sum(1 for e in roomy.scheduler.events if e[0] == "preempt") == 0
    assert out_t == out_r  # token streams survive preemption bit-for-bit


def _check_interleaving_matches_isolated(seed):
    """The property: whatever admission interleaving a workload produces,
    each request's token stream equals its isolated-decode stream."""
    reqs = _random_requests(seed, 10, max_new=8, max_arrival=6)
    eng = _engine(reqs, num_blocks=11, block_size=2, max_slots=4,
                  max_blocks_per_seq=8)
    out = eng.run(max_steps=20_000)
    assert eng.leaked_blocks() == 0
    for r in reqs:
        solo = _engine([dataclasses.replace(r, arrival=0, priority=0)],
                       num_blocks=11, block_size=2, max_slots=4,
                       max_blocks_per_seq=8)
        assert solo.run()[r.rid] == out[r.rid], r.rid


if HAVE_HYPOTHESIS:

    @settings(max_examples=25, deadline=None)
    @given(st.integers(min_value=0, max_value=2**31 - 1))
    def test_interleaving_equivalent_to_isolated_decode(seed):
        _check_interleaving_matches_isolated(seed)

else:

    @pytest.mark.parametrize("seed", range(25))
    def test_interleaving_equivalent_to_isolated_decode(seed):
        _check_interleaving_matches_isolated(seed)


# ---------------------------------------------------------------------------
# Real paged model: engine-level bitwise invariants
# ---------------------------------------------------------------------------


@pytest.fixture(scope="module")
def dense_model():
    import jax

    from repro.configs.base import get_config
    from repro.models import registry as mreg

    cfg = get_config("gemma-2b", reduced=True)
    return cfg, mreg.init_params(cfg, jax.random.PRNGKey(0))


def _real_engine(cfg, params, reqs, *, num_blocks, block_size=4,
                 max_slots=3, max_blocks_per_seq=6):
    eng = ServingEngine.with_model(
        cfg, params, num_blocks=num_blocks, block_size=block_size,
        max_slots=max_slots, max_blocks_per_seq=max_blocks_per_seq)
    for r in reqs:
        eng.submit(r)
    return eng


@pytest.mark.slow
def test_real_model_interleaving_and_preemption_bitwise(dense_model):
    cfg, params = dense_model
    rng = np.random.default_rng(17)
    reqs = [
        Request(rid=rid,
                prompt=tuple(int(x) for x in
                             rng.integers(1, cfg.vocab_size, 4 + rid % 4)),
                max_new_tokens=6, arrival=rid // 2)
        for rid in range(5)
    ]
    tight = _real_engine(cfg, params, reqs, num_blocks=8)
    out = tight.run(max_steps=500)
    assert sum(1 for e in tight.scheduler.events if e[0] == "preempt") > 0
    assert tight.leaked_blocks() == 0

    roomy = _real_engine(cfg, params,
                         [dataclasses.replace(r) for r in reqs],
                         num_blocks=40)
    out_roomy = roomy.run(max_steps=500)
    assert out == out_roomy  # preempt/resume round-trip is bitwise

    solo = _real_engine(cfg, params,
                        [dataclasses.replace(reqs[2], arrival=0)],
                        num_blocks=40)
    assert solo.run(max_steps=500)[2] == out[2]  # interleaving-invariant


@pytest.mark.slow
def test_real_model_fp8_cache_serves(dense_model):
    cfg, params = dense_model
    rng = np.random.default_rng(23)
    reqs = [
        Request(rid=rid,
                prompt=tuple(int(x) for x in
                             rng.integers(1, cfg.vocab_size, 5)),
                max_new_tokens=4, arrival=0)
        for rid in range(2)
    ]
    eng = ServingEngine.with_model(
        cfg, params, num_blocks=16, block_size=4, max_slots=2,
        max_blocks_per_seq=6, precision="fp8")
    assert eng.model.cache.quantized
    for r in reqs:
        eng.submit(r)
    out = eng.run(max_steps=200)
    assert len(out) == 2 and all(len(v) == 4 for v in out.values())
    assert eng.leaked_blocks() == 0


# ---------------------------------------------------------------------------
# serve.py prefill regression: the prompt loop must be one jitted scan
# ---------------------------------------------------------------------------


def test_serve_recurrent_prefill_traces_once(monkeypatch):
    import jax.numpy as jnp

    from repro.configs.base import get_config
    from repro.launch import serve
    from repro.models import registry as mreg

    cfg = get_config("rwkv6-3b", reduced=True)
    params = mreg.init_params(cfg, __import__("jax").random.PRNGKey(0))

    calls = {"n": 0}
    real = mreg.decode_step

    def counting(*a, **kw):
        calls["n"] += 1
        return real(*a, **kw)

    monkeypatch.setattr(mreg, "decode_step", counting)
    B, S0, gen = 2, 8, 3
    tokens = jnp.asarray(
        np.random.default_rng(0).integers(1, cfg.vocab_size, (B, S0)),
        jnp.int32)
    out = serve.generate(cfg, params, tokens, gen, S0 + gen + 1)
    assert out.shape == (B, S0 + gen)
    # one trace for the scanned prefill + one for the jitted decode step;
    # the old per-token Python loop called it S0 (=8) times for the prompt
    assert calls["n"] <= 3, (
        f"decode_step entered Python {calls['n']} times for S0={S0}: the "
        f"prompt loop is not a single jitted scan"
    )
