"""repro.analysis: every rule fires on its seeded fixture, every checker
helper flags seeded-bad artifacts, and the real tree comes back clean."""
import json
import pathlib

import pytest

from repro.analysis import cli, registered_rules, run_rules
from repro.analysis.plan_rules import (
    check_accum_widening,
    check_hop_schedule,
    check_mesh_cases,
    check_plan,
    check_program,
)

FIXTURES = pathlib.Path(__file__).parent / "analysis_fixtures"


# ---------------------------------------------------------------------------
# Registry shape
# ---------------------------------------------------------------------------


def test_rule_inventory():
    rules = registered_rules()
    by_tier = {"ast": [], "plan": [], "model": []}
    for r in rules:
        by_tier[r.tier].append(r.name)
    assert len(by_tier["ast"]) >= 5, by_tier
    assert len(by_tier["plan"]) >= 3, by_tier
    assert len(by_tier["model"]) >= 3, by_tier
    assert len(rules) == len({r.name for r in rules})  # unique names
    assert all(r.doc for r in rules), "every rule carries a --list summary"


def test_unknown_rule_raises():
    with pytest.raises(KeyError, match="unknown rules"):
        run_rules(["not-a-rule"])


# ---------------------------------------------------------------------------
# AST tier: positive tests — each rule fires on its seeded fixture
# ---------------------------------------------------------------------------

AST_FIXTURE_CASES = [
    ("single-pallas-site", "pallas_site", 1, "outside core/streams.py"),
    ("block-geometry-registry-only", "block_geometry", 4, "bk=512"),
    ("no-environ-in-kernels", "environ", 2, "os.environ"),
    ("xla-flags-append-only", "xla_flags", 2, "clobbers caller flags"),
    ("axis-name-vocabulary", "axis_vocab", 2, "'rows'"),
    ("docstring-contract", "docstring", 3, "missing or trivial docstring"),
    ("warn-category", "warncat", 2, "explicit category"),
]


@pytest.mark.parametrize(
    "rule,subdir,count,needle", AST_FIXTURE_CASES,
    ids=[c[0] for c in AST_FIXTURE_CASES],
)
def test_rule_fires_on_fixture(rule, subdir, count, needle):
    findings = run_rules([rule], root=FIXTURES / subdir)
    assert len(findings) == count, [f.format() for f in findings]
    assert all(f.rule == rule for f in findings)
    assert any(needle in f.message for f in findings), (
        needle, [f.message for f in findings]
    )


def test_rules_stay_in_their_lane():
    # a fixture seeded for one rule is clean under every other AST rule —
    # proves findings are attributable, not cross-talk
    ast_rules = [r.name for r in registered_rules() if r.tier == "ast"]
    for rule, subdir, *_ in AST_FIXTURE_CASES:
        others = [n for n in ast_rules if n != rule]
        findings = run_rules(others, root=FIXTURES / subdir)
        # the docstring fixture's module is also a kernels/partition.py by
        # path, so the axis-vocab rule parses it for AXIS_VOCAB — absence
        # falls back to the default vocabulary, yielding no findings; any
        # finding here is genuine cross-talk
        assert findings == [], [f.format() for f in findings]


def test_parse_error_reported_not_raised(tmp_path):
    (tmp_path / "broken.py").write_text("def f(:\n")
    findings = run_rules(["single-pallas-site"], root=tmp_path)
    assert [f.rule for f in findings] == ["parse-error"]


# ---------------------------------------------------------------------------
# Plan tier: checker helpers flag seeded-bad artifacts
# ---------------------------------------------------------------------------


def test_hop_schedule_clean_paths():
    from repro.parallel.collectives import ring_schedule

    for hops in (1, 2, 3, 8):
        for overlap in (False, True):
            for remote in (False, True):
                ev = ring_schedule(hops, overlap=overlap, remote_copy=remote)
                assert check_hop_schedule(ev, hops, remote_copy=remote) == []


def test_hop_schedule_alias_hazard():
    from repro.parallel.collectives import HopEvent

    # send of hop 1 lands in buffer 0 — which still holds unfolded hop 0:
    # the merge of hop 0 would race the landing of hop 1
    events = (
        HopEvent("send", 1, 0, 0),
        HopEvent("fold", 0, 0),
        HopEvent("fold", 1, 0),
    )
    problems = check_hop_schedule(events, 2)
    assert any("alias hazard" in p for p in problems), problems


def test_hop_schedule_unwaited_dma():
    from repro.parallel.collectives import HopEvent

    # remote_copy path whose consuming fold is not ordered after dma_wait
    events = (
        HopEvent("dma_start", 1, 0, 1),
        HopEvent("fold", 0, 0),
        HopEvent("fold", 1, 1),  # consumes before any dma_wait
        HopEvent("dma_wait", 1, None, 1),
    )
    problems = check_hop_schedule(events, 2, remote_copy=True)
    assert any("before its DMA semaphore wait" in p for p in problems), problems


def test_hop_schedule_fold_order_and_coverage():
    from repro.parallel.collectives import HopEvent

    events = (HopEvent("fold", 0, 0),)  # hops=2 but only hop 0 folded
    problems = check_hop_schedule(events, 2)
    assert any("do not cover" in p for p in problems), problems

    events = (
        HopEvent("send", 1, 0, 1),
        HopEvent("fold", 1, 1),  # folds out of order
        HopEvent("fold", 0, 0),
    )
    problems = check_hop_schedule(events, 2)
    assert any("fold order broken" in p for p in problems), problems


def test_hop_schedule_stale_send():
    from repro.parallel.collectives import HopEvent

    # hop 2's send reads buffer 1 before hop 1 ever landed there
    events = (
        HopEvent("send", 2, 1, 0),
        HopEvent("fold", 0, 0),
    )
    problems = check_hop_schedule(events, 1)
    assert any("expected hop 1" in p for p in problems), problems


def test_check_program_flags_overflow_and_structure():
    import jax
    import jax.numpy as jnp

    from repro.core.streams import AffineStream, StreamProgram

    huge = AffineStream((4096, 4096), lambda i: (i, 0), dtype=jnp.float32)
    program = StreamProgram(
        name="hog", body=lambda *_: None, grid=(4,),
        in_streams=(huge,), out_streams=(huge,),
        out_shapes=(jax.ShapeDtypeStruct((16384, 4096), jnp.float32),),
    )
    problems = check_program(program)
    assert any("VMEM budget" in p for p in problems), problems
    assert check_program(program, budget_bytes=2**40) == []

    bad = StreamProgram(
        name="malformed", body=lambda *_: None, grid=(0,),
        in_streams=(AffineStream((8, -1), lambda i, j: (i, j)),),
        out_streams=(),
        out_shapes=(jax.ShapeDtypeStruct((8,), jnp.float32),),
    )
    problems = check_program(bad, budget_bytes=2**40)
    assert any("grid must be positive" in p for p in problems)
    assert any("out_streams" in p for p in problems)
    assert any("non-positive extent" in p for p in problems)
    assert any("index_map takes" in p for p in problems)


def test_check_mesh_cases_flags_dead_end():
    from repro.launch.op_cases import op_roofline_cases

    gemm = [c for c in op_roofline_cases() if c[0] == "gemm"]
    # 4096x4096 operands on a 5-way model axis: no rung divides, the
    # ladder exhausts, the call silently replicates — exactly the dead end
    problems = check_mesh_cases(gemm, {"model": 5})
    assert any("ladder dead-end" in p for p in problems), problems
    assert check_mesh_cases(gemm, {"data": 16, "model": 16}) == []


def test_check_plan_flags_vocabulary_drift():
    from repro.kernels.partition import CollectiveCost, PartitionPlan

    bogus = PartitionPlan(
        op="bogus", levels=(("rows", 4),), in_specs=(), out_specs=None,
        local_fn=lambda *a: None,
        collectives=(CollectiveCost("gossip", "rows", -1, n=4),),
        overlappable=True, hops=1,
    )
    problems = check_plan(bogus, {"data": 16, "model": 16})
    assert any("outside AXIS_VOCAB" in p for p in problems), problems
    assert any("not priceable" in p for p in problems)
    assert any("negative nbytes" in p for p in problems)
    assert any("hops=1" in p for p in problems)


def test_check_accum_widening_requires_wide_landing_site():
    import jax
    import jax.numpy as jnp

    from repro.core.streams import AffineStream, StreamProgram

    def prog(in_dt, out_dt, scratch=()):
        def st(dt):
            return AffineStream((8, 8), lambda i: (i, 0), dtype=dt)

        return StreamProgram(
            name="narrow", body=lambda *_: None, grid=(2,),
            in_streams=(st(in_dt),), out_streams=(st(out_dt),),
            out_shapes=(jax.ShapeDtypeStruct((16, 8), out_dt),),
            scratch=scratch,
        )

    # fp8 streams in, fp8 stream out, no scratch: the accumulate would
    # saturate in the narrow format — the seeded-bad case
    problems = check_accum_widening(
        prog(jnp.float8_e4m3fn, jnp.float8_e4m3fn)
    )
    assert any("no fp32+ accumulator" in p for p in problems), problems
    # widening through an fp32 out stream satisfies the contract...
    assert check_accum_widening(prog(jnp.bfloat16, jnp.float32)) == []
    # ...as does an fp32 VMEM scratch accumulator (the blocked kernels)
    assert check_accum_widening(prog(
        jnp.float8_e5m2, jnp.float8_e5m2,
        scratch=(jax.ShapeDtypeStruct((8, 8), jnp.float32),),
    )) == []
    # full-width programs and integer (index) streams are exempt
    assert check_accum_widening(prog(jnp.float32, jnp.float32)) == []
    assert check_accum_widening(prog(jnp.int8, jnp.int8)) == []
    # the registered rule sweeps the full suite, scaled cases included
    assert "accum-dtype-widening" in {r.name for r in registered_rules()}


# ---------------------------------------------------------------------------
# The real tree is clean, and the CLI speaks both formats
# ---------------------------------------------------------------------------


def test_real_tree_is_clean():
    findings = run_rules()  # all rules, both tiers, default root
    assert findings == [], "\n".join(f.format() for f in findings)


def test_cli_json_format(capsys):
    code = cli.main(["--rules", "single-pallas-site", "--format", "json"])
    report = json.loads(capsys.readouterr().out)
    assert code == 0
    assert report["count"] == 0 and report["findings"] == []
    assert report["rules"] == ["single-pallas-site"]


def test_cli_findings_exit_code(capsys):
    code = cli.main([
        "--rules", "warn-category", "--root", str(FIXTURES / "warncat"),
        "--format", "json",
    ])
    report = json.loads(capsys.readouterr().out)
    assert code == 1
    assert report["count"] == 2
    assert all(f["rule"] == "warn-category" for f in report["findings"])


def test_cli_unknown_rule_exit_code(capsys):
    assert cli.main(["--rules", "nope"]) == 2
    assert "unknown rules" in capsys.readouterr().err


def test_cli_list(capsys):
    assert cli.main(["--list"]) == 0
    out = capsys.readouterr().out
    for r in registered_rules():
        assert r.name in out


# ---------------------------------------------------------------------------
# check_paged_coverage: the serving ledger audit flags seeded corruption
# ---------------------------------------------------------------------------


def _serving_sched(**kw):
    from repro.serving.scheduler import ContinuousBatchingScheduler, Request

    defaults = dict(num_blocks=9, block_size=4, max_slots=3,
                    max_blocks_per_seq=6)
    defaults.update(kw)
    sched = ContinuousBatchingScheduler(**defaults)
    for rid in range(6):
        sched.submit(Request(rid=rid, prompt=(1, 2, 3),
                             max_new_tokens=5, arrival=rid % 3))
    return sched


def _tok(seq, step):
    return (seq.generated[-1] + 1) % 17 if seq.generated else 1


def test_check_paged_coverage_clean_on_honest_scheduler():
    from repro.analysis.plan_rules import check_paged_coverage

    assert check_paged_coverage(_serving_sched(), _tok) == []


def test_check_paged_coverage_flags_missing_growth():
    from repro.analysis.plan_rules import check_paged_coverage

    sched = _serving_sched()
    sched.ensure_block = lambda seq, step: True  # never grows the table
    problems = check_paged_coverage(sched, _tok)
    assert any("covers only" in p for p in problems), problems


def test_check_paged_coverage_flags_null_block_in_live_prefix():
    from repro.analysis.plan_rules import check_paged_coverage
    from repro.serving.scheduler import NULL_BLOCK

    sched = _serving_sched()
    orig = sched.allocator.alloc

    def corrupt(rid, n):
        got = orig(rid, n)
        if got and rid == 2:
            got[0] = NULL_BLOCK  # hand the scratch page to a live prefix
        return got

    sched.allocator.alloc = corrupt
    problems = check_paged_coverage(sched, _tok)
    assert any("NULL_BLOCK" in p for p in problems), problems


def test_check_paged_coverage_flags_double_ownership():
    from repro.analysis.plan_rules import check_paged_coverage

    sched = _serving_sched()
    orig_admit = sched.admit

    def alias_admit(step):
        admitted = orig_admit(step)
        running = list(sched.running.values())
        if len(running) >= 2:
            running[1].blocks[0] = running[0].blocks[0]  # alias a page
        return admitted

    sched.admit = alias_admit
    problems = check_paged_coverage(sched, _tok)
    assert any("owned by both" in p or "!= allocator ledger" in p
               for p in problems), problems
