"""Tier-C model checker: the explorer's abstract scheduler bisimulates the
real one, every seeded-bad fixture fires with an exact count, the real
substrate explores clean past the 10^3-state bar, and the CLI's budget /
exit-code / jax-free contracts hold.

The bisimulation test runs under ``hypothesis`` when the package is
present and falls back to a seeded randomized sweep of the same property
otherwise — the container image does not ship hypothesis.
"""
import dataclasses
import importlib.util
import json
import pathlib
import subprocess
import sys

import numpy as np
import pytest

from repro.analysis import cli, explore
from repro.analysis.explore import (
    Budget,
    RequestSpec,
    SchedulerConfig,
    SchedulerModel,
    explore_hop_interleavings,
)
from repro.serving.scheduler import (
    NULL_BLOCK,
    ContinuousBatchingScheduler,
    Request,
    apply_action,
    canonical_state,
)

try:
    from hypothesis import given, settings
    from hypothesis import strategies as st
    HAVE_HYPOTHESIS = True
except ImportError:  # container image has no hypothesis; seeded sweep below
    HAVE_HYPOTHESIS = False

FIXTURES = pathlib.Path(__file__).parent / "analysis_fixtures"
REPO = pathlib.Path(__file__).resolve().parents[1]


def _load_fixture(rel):
    path = FIXTURES / rel
    spec = importlib.util.spec_from_file_location(path.stem, path)
    mod = importlib.util.module_from_spec(spec)
    spec.loader.exec_module(mod)
    return mod


def test_null_block_constant_mirrors_scheduler():
    # explore.py deliberately does not import the serving package (jax);
    # the mirrored constant must never drift
    assert explore.NULL_BLOCK == NULL_BLOCK


# ---------------------------------------------------------------------------
# Bisimulation: the abstract model never drifts from the real scheduler
# ---------------------------------------------------------------------------


def _random_config(rng):
    block_size = int(rng.integers(1, 4))
    num_blocks = int(rng.integers(4, 9))
    limit = num_blocks - 1
    specs = []
    for rid in range(int(rng.integers(1, 5))):
        for _ in range(20):  # rejection-sample until it fits the pool
            p = int(rng.integers(1, 5))
            m = int(rng.integers(1, 5))
            if -(-(p + m) // block_size) <= limit:
                specs.append(RequestSpec(
                    rid=rid, prompt_len=p, max_new_tokens=m,
                    priority=int(rng.integers(0, 3))))
                break
    return SchedulerConfig(
        num_blocks=num_blocks, block_size=block_size,
        max_slots=int(rng.integers(1, 4)), requests=tuple(specs))


def _check_bisimulation(seed):
    """Drive model and real scheduler through one random action walk and
    assert lock-step equality of canonical ledgers and admission traces."""
    rng = np.random.default_rng(seed)
    cfg = _random_config(rng)
    model = SchedulerModel(cfg)
    state = model.initial()
    sched = ContinuousBatchingScheduler(
        num_blocks=cfg.num_blocks, block_size=cfg.block_size,
        max_slots=cfg.max_slots)
    requests = {
        r.rid: Request(rid=r.rid, prompt=(1,) * r.prompt_len,
                       max_new_tokens=r.max_new_tokens, priority=r.priority)
        for r in cfg.requests
    }
    model_trace, step = [], 0
    for step in range(400):
        actions = model.actions(state)
        if not actions:
            break
        action = actions[int(rng.integers(len(actions)))]
        state, problems, admits = model.apply(state, action)
        assert problems == [], (seed, action, problems)
        real_admits = apply_action(sched, action, step, requests=requests)
        assert admits == real_admits, (seed, step, action)
        model_trace.extend((step, rid, slot) for rid, slot in admits)
        assert model.ledger_view(state) == canonical_state(sched), (
            seed, step, action)
    assert tuple(model_trace) == sched.admission_trace(), seed
    assert sched.allocator.check() == []
    if not model.actions(state):  # drained: both sides fully retired
        assert sched.idle() and sched.leaked_blocks() == 0
        assert set(sched.finished) == {r.rid for r in cfg.requests}


if HAVE_HYPOTHESIS:

    @settings(max_examples=40, deadline=None)
    @given(st.integers(min_value=0, max_value=2**31 - 1))
    def test_model_bisimulates_real_scheduler(seed):
        _check_bisimulation(seed)

else:

    @pytest.mark.parametrize("seed", range(40))
    def test_model_bisimulates_real_scheduler(seed):
        _check_bisimulation(seed)


# ---------------------------------------------------------------------------
# Exhaustive exploration: clean on the shipped configs, >10^3 states
# ---------------------------------------------------------------------------


def test_scheduler_configs_explore_clean_past_state_bar():
    total = 0
    preempting = 0
    for tag, cfg in explore.SCHEDULER_CONFIGS:
        problems, stats = explore.explore(SchedulerModel(cfg))
        assert problems == [], (tag, problems)
        assert not stats.truncated, tag
        assert stats.states > 0 and stats.transitions >= stats.states - 1
        total += stats.states
        m = SchedulerModel(cfg)
        seen, stack = {m.initial()}, [m.initial()]
        while stack:
            s = stack.pop()
            for a in m.actions(s):
                nxt, _, _ = m.apply(s, a)
                if nxt not in seen:
                    seen.add(nxt)
                    stack.append(nxt)
            if any(seq[2] > 0 for _sl, seq in s[1]):
                preempting += 1
                stack.clear()
    # the acceptance bar: the explorer provably visits >10^3 distinct
    # canonical states, and the space includes preemption-scarred ones
    assert total > 1000, total
    assert preempting, "bounded configs never exercise preemption"


def test_starvation_detector_fires_when_bound_tightened():
    # the shipped configs' true bypass bound is small (waited <= 2); with
    # the bound tightened below it the liveness detector must fire, which
    # proves the detector is live rather than vacuous
    _tag, cfg = explore.SCHEDULER_CONFIGS[0]
    problems, _ = explore.explore(
        SchedulerModel(dataclasses.replace(cfg, starvation_bound=0)))
    assert any("starvation" in p for p in problems), problems


def test_explore_budget_truncates_and_reports():
    _tag, cfg = explore.SCHEDULER_CONFIGS[1]
    problems, stats = explore.explore(
        SchedulerModel(cfg), Budget(max_states=50, max_depth=64))
    assert stats.truncated and stats.states <= 50
    assert problems == []  # truncation is stats, not a violation string


def test_model_rejects_unsatisfiable_request():
    with pytest.raises(ValueError, match="can never fit"):
        SchedulerModel(SchedulerConfig(
            num_blocks=3, block_size=1, max_slots=1,
            requests=(RequestSpec(rid=0, prompt_len=4, max_new_tokens=4),)))


# ---------------------------------------------------------------------------
# Seeded-bad fixtures: exact finding counts
# ---------------------------------------------------------------------------


def test_bad_preempt_fixture_double_free_detected():
    bad = _load_fixture("scheduler_model/bad_preempt.py")
    problems, stats = explore.explore(bad.BadPreemptModel(bad.CONFIG))
    assert len(problems) == 2, problems
    assert any("double-free" in p for p in problems), problems
    assert all("[after:" in p for p in problems), (
        "findings must carry a counterexample trace", problems)
    # the pristine model on the same config is clean: the finding is
    # attributable to the seeded preempt bug, not the config
    clean, _ = explore.explore(SchedulerModel(bad.CONFIG))
    assert clean == []


def test_bad_hop_schedule_fixture_race_detected():
    bad = _load_fixture("hop_schedule/bad_schedule.py")
    problems, _stats = explore_hop_interleavings(bad.EVENTS, bad.HOPS)
    assert len(problems) == 1, problems
    assert "races" in problems[0] and "has not landed" in problems[0]


def test_real_ring_schedules_race_free_under_all_interleavings():
    from repro.parallel.collectives import ring_schedule

    for hops in (1, 2, 3, 8):
        for overlap in (False, True):
            for remote in (False, True):
                ev = ring_schedule(hops, overlap=overlap, remote_copy=remote)
                problems, stats = explore_hop_interleavings(ev, hops)
                assert problems == [], (hops, overlap, remote, problems)
                assert not stats.truncated


def test_unwaited_dma_is_structural_finding():
    from repro.parallel.collectives import HopEvent

    events = (
        HopEvent("dma_start", 1, 0, 1),
        HopEvent("fold", 0, 0),
        HopEvent("fold", 1, 1),  # and no dma_wait anywhere
    )
    problems, _ = explore_hop_interleavings(events, 2)
    assert any("no dma_wait" in p for p in problems), problems
    assert any("races" in p for p in problems), problems


def test_bad_precision_fixture_counts():
    from repro.analysis.model_rules import (
        check_dtype_dataflow,
        check_quantized_pool,
    )

    bad = _load_fixture("precision_flow/bad_program.py")
    problems = check_dtype_dataflow(bad.make_program())
    assert len(problems) == 2, problems
    assert any("accumulation" in p for p in problems), problems
    assert any("no fp32 scale stream" in p for p in problems), problems

    pool_problems = check_quantized_pool(bad.make_pool())
    assert len(pool_problems) == 2, pool_problems  # k side and v side
    assert all("bypass the per-row scales" in p for p in pool_problems)


def test_dtype_dataflow_clean_on_scaled_program_and_pool():
    import jax.numpy as jnp

    from repro.analysis.model_rules import (
        check_dtype_dataflow,
        check_quantized_pool,
    )
    from repro.core import precision as prec
    from repro.kernels.gemm import gemm_scaled_program
    from repro.serving.paged_cache import init_paged_cache

    class _Cfg:
        num_layers, num_kv_heads, dtype = 1, 2, "float32"

        def resolved_head_dim(self):
            return 8

    policy = prec.resolve("fp8")
    program = gemm_scaled_program(
        128, 128, 128, 64, 64, 64, compute_dtype=policy.compute_dtype,
        out_dtype=jnp.float32, accum_dtype=policy.accum_dtype)
    assert check_dtype_dataflow(program, policy) == []

    assert check_quantized_pool(init_paged_cache(
        _Cfg(), num_blocks=3, block_size=2, policy="fp8")) == []
    assert check_quantized_pool(init_paged_cache(
        _Cfg(), num_blocks=3, block_size=2)) == []


def test_quantized_pool_scale_shape_and_dtype_checked():
    import jax.numpy as jnp

    from repro.analysis.model_rules import check_quantized_pool
    from repro.serving.paged_cache import PagedKVCache

    shape = (1, 3, 2, 2, 4)
    good_scale = jnp.ones(shape[:-1] + (1,), jnp.float32)
    cache = PagedKVCache(
        k_pool=jnp.zeros(shape, jnp.float8_e4m3fn),
        v_pool=jnp.zeros(shape, jnp.float8_e4m3fn),
        k_scale=jnp.ones((1, 3, 2, 1, 1), jnp.float32),  # wrong rows
        v_scale=good_scale.astype(jnp.bfloat16),         # wrong dtype
        block_size=2, policy="fp8")
    problems = check_quantized_pool(cache)
    assert any("not per-row" in p for p in problems), problems
    assert any("not float32" in p for p in problems), problems


# ---------------------------------------------------------------------------
# CLI: budget flag, exit codes, stats reporting, jax-free paths
# ---------------------------------------------------------------------------


def test_cli_reports_model_stats_in_json(capsys):
    code = cli.main(["--rules", "scheduler-model", "--format", "json"])
    report = json.loads(capsys.readouterr().out)
    assert code == 0 and report["findings"] == []
    per_run = report["stats"]["scheduler-model"]
    assert set(per_run) == {t for t, _ in explore.SCHEDULER_CONFIGS}
    assert sum(s["states"] for s in per_run.values()) > 1000
    assert all(not s["truncated"] for s in per_run.values())


def test_cli_budget_exhaustion_is_exit_3_not_a_pass(capsys):
    code = cli.main(["--rules", "scheduler-model", "--budget", "40,64",
                     "--format", "json"])
    report = json.loads(capsys.readouterr().out)
    assert code == 3
    kinds = {f["kind"] for f in report["findings"]}
    assert kinds == {"budget-exhausted"}, report["findings"]
    assert any(s["truncated"]
               for s in report["stats"]["scheduler-model"].values())


def test_cli_bad_budget_is_usage_error(capsys):
    assert cli.main(["--budget", "nope"]) == 2
    assert "budget must be" in capsys.readouterr().err
    assert Budget.parse("500,9").max_depth == 9
    assert Budget.parse("500").max_states == 500
    with pytest.raises(ValueError):
        Budget.parse("0")


def test_cli_stays_jax_free_for_list_errors_and_scheduler_model(tmp_path):
    # --list, unknown-rule, bad-budget and the full scheduler-model run
    # must all work with jax unimportable (satellite: the CLI's cheap
    # paths never pay for the accelerator stack)
    script = tmp_path / "probe.py"
    script.write_text(
        "import sys\n"
        "class Block:\n"
        "    def find_spec(self, name, path=None, target=None):\n"
        "        if name == 'jax' or name.startswith('jax.'):\n"
        "            raise ImportError('jax blocked')\n"
        "        return None\n"
        "sys.meta_path.insert(0, Block())\n"
        "from repro.analysis import cli\n"
        "assert cli.main(['--list']) == 0\n"
        "assert cli.main(['--rules', 'nope']) == 2\n"
        "assert cli.main(['--budget', 'junk']) == 2\n"
        "assert cli.main(['--rules', 'scheduler-model']) == 0\n"
        "assert 'jax' not in sys.modules\n"
        "print('JAXFREE-OK')\n"
    )
    proc = subprocess.run(
        [sys.executable, str(script)], capture_output=True, text=True,
        env={"PYTHONPATH": str(REPO / "src"), "PATH": "/usr/bin:/bin"})
    assert proc.returncode == 0, proc.stderr
    assert "JAXFREE-OK" in proc.stdout
