"""Partitioning-layer tests: PartitionRule resolution (device-free, via
partition.MeshSpec), sharded-vs-single-device numerical equivalence for every
partitioned op (subprocess with 8 forced host devices, like
test_distribution.py), halo-exchange correctness at block boundaries,
replication fallback on indivisible shapes, and the host_device_mesh
graceful-degradation contract."""
import json
import os
import subprocess
import sys
import textwrap

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.kernels import ops, partition, registry
from repro.launch import roofline


@pytest.fixture(autouse=True)
def _clean_registry_state():
    yield
    registry.set_default_impl(None)
    registry.clear_block_overrides()


S = jax.ShapeDtypeStruct
MESH8 = partition.MeshSpec({"data": 2, "model": 4})
MESH_2POD = partition.MeshSpec({"pod": 2, "data": 2, "model": 4})


# ---------------------------------------------------------------------------
# Rule resolution (no devices needed: plans resolve from shapes alone)
# ---------------------------------------------------------------------------


def test_every_block_table_op_has_a_partition_rule():
    assert set(partition.partitioned_ops()) == set(registry._BLOCK_DEFAULTS)


def test_partition_axis_prefers_model():
    assert partition.partition_axis(MESH8) == "model"
    assert partition.partition_axis(partition.MeshSpec({"pod": 2, "x": 4})) == "x"


def test_partition_levels_resolution():
    assert partition.partition_levels(MESH8) == (("model", 4),)
    assert partition.partition_levels(MESH_2POD) == (("pod", 2), ("model", 4))
    # size-1 axes drop out of the level stack
    assert partition.partition_levels(
        partition.MeshSpec({"pod": 1, "data": 2, "model": 4})
    ) == (("model", 4),)
    assert partition.partition_levels(
        partition.MeshSpec({"pod": 2, "data": 2, "model": 1})
    ) == (("pod", 2),)
    assert partition.partition_levels(
        partition.MeshSpec({"data": 1, "model": 1})
    ) == ()


def test_gemm_two_level_plan_and_per_level_costs():
    f32 = jnp.float32
    plan = partition.plan_for(
        "gemm", MESH_2POD, S((32, 64), f32), S((64, 16), f32))
    assert plan.levels == (("pod", 2), ("model", 4)) and plan.n == 8
    assert plan.axis == ("pod", "model")
    assert "k-sharded" in plan.note and "pod=2+model=4" in plan.note
    # hierarchical all-reduce: intra-pod psum fires first, then the D2D hop,
    # each costed at its own level's ring size
    assert [(c.kind, c.axis, c.n) for c in plan.collectives] == [
        ("all_reduce", "model", 4), ("all_reduce", "pod", 2)]
    assert all(c.nbytes == 32 * 16 * 4 for c in plan.collectives)
    by_level = roofline.plan_collective_seconds_by_level(plan)
    assert set(by_level) == {"model", "pod"}
    # same payload, but the pod hop rides the narrow D2D link: the 2-ring at
    # half bandwidth must out-cost nothing implicitly — check against the
    # topology model directly
    from repro.core import topology

    nb = 32 * 16 * 4
    assert by_level["model"] == pytest.approx(
        topology.collective_seconds("all_reduce", nb, "model", 4))
    assert by_level["pod"] == pytest.approx(
        topology.collective_seconds("all_reduce", nb, "pod", 2))
    assert roofline.plan_collective_seconds(plan) == pytest.approx(
        by_level["model"] + by_level["pod"])


def test_fallback_ladder_drops_pod_level_before_replicating():
    f32 = jnp.float32
    # 4 kv heads divide model=4 but not pod*model=8: the ladder drops the
    # pod level and head-shards intra-pod (composed with B over data)
    q, kv = S((2, 8, 32, 16), f32), S((2, 4, 32, 16), f32)
    plan = partition.plan_for("flash_attention", MESH_2POD, q, kv, kv)
    assert plan is not None
    assert plan.levels == (("data", 2), ("model", 4))
    # 8 kv heads divide pod*model=8: full head placement + batch over data
    kv8 = S((2, 8, 32, 16), f32)
    plan = partition.plan_for("flash_attention", MESH_2POD, q, kv8, kv8)
    assert plan.levels == (("pod", 2), ("data", 2), ("model", 4))
    # TP-hostile heads: the head split drops but B over data survives
    kv5 = S((2, 5, 32, 16), f32)
    q20 = S((2, 20, 32, 16), f32)
    plan = partition.plan_for("flash_attention", MESH_2POD, q20, kv5, kv5)
    assert plan.levels == (("data", 2),)
    assert "batch-sharded" in plan.note and "head" not in plan.note
    # nothing divides at all (B=1, odd seq, hostile heads): replicate
    q1 = S((1, 5, 33, 16), f32)
    kv1 = S((1, 5, 33, 16), f32)
    assert partition.plan_for("flash_attention", MESH_2POD, q1, kv1, kv1) is None


def test_stencil_two_level_distinguishes_pod_boundary_hop():
    f32 = jnp.float32
    offs = np.array([(-1, 0, 0), (0, 0, 0), (1, 0, 0)], np.int32)
    w = np.ones((3,), np.float32)
    plan = partition.plan_for(
        "stencil", MESH_2POD, S((32, 8, 8), f32), offsets=offs, weights=w)
    assert plan.levels == (("pod", 2), ("model", 4))
    assert "pod boundary hop" in plan.note
    # two intra-pod ring hops (model axis) + two cross-pod boundary hops
    kinds = [(c.kind, c.axis, c.n) for c in plan.collectives]
    assert kinds == [("permute", "model", 4), ("permute", "model", 4),
                     ("permute", "pod", 2), ("permute", "pod", 2)]
    by_level = roofline.plan_collective_seconds_by_level(plan)
    assert by_level["pod"] > 0 and by_level["model"] > 0
    # single-level meshes keep the flat note (no phantom pod hop)
    plan1 = partition.plan_for(
        "stencil", MESH8, S((32, 8, 8), f32), offsets=offs, weights=w)
    assert "pod boundary hop" not in plan1.note
    assert {c.axis for c in plan1.collectives} == {"model"}


def test_two_level_sparse_rules_divide_over_pod_times_model():
    f32, i32 = jnp.float32, jnp.int32
    plan = partition.plan_for(
        "spmm", MESH_2POD, S((64, 8), f32), S((64, 8), i32), S((32, 4), f32))
    assert plan.levels == (("pod", 2), ("model", 4))
    plan = partition.plan_for(
        "bsr_spmm", MESH_2POD, S((8, 8, 128), f32), S((8,), i32),
        S((8,), i32), S((256, 16), f32), num_rows=64)
    assert [(c.axis, c.n) for c in plan.collectives] == [("model", 4),
                                                         ("pod", 2)]
    # rows divide model but not pod*model: ladder lands on the model level
    plan = partition.plan_for(
        "spmm", MESH_2POD, S((36, 8), f32), S((36, 8), i32), S((32, 4), f32))
    assert plan is not None and plan.levels == (("model", 4),)


def test_local_operand_structs_shard_geometry():
    f32 = jnp.float32
    a, b = S((256, 256), f32), S((256, 256), f32)
    plan = partition.plan_for("gemm", MESH8, a, b)
    la, lb = partition.local_operand_structs(plan, MESH8, (a, b))
    assert la.shape == (256, 64) and lb.shape == (64, 256)  # K/4 each side
    plan2 = partition.plan_for("gemm", MESH_2POD, a, b)
    la2, lb2 = partition.local_operand_structs(plan2, MESH_2POD, (a, b))
    assert la2.shape == (256, 32) and lb2.shape == (32, 256)  # K/(2*4)
    # replication passes shapes through whole; None holes are skipped
    structs = partition.local_operand_structs(None, MESH8, (a, None, b))
    assert [s.shape for s in structs] == [(256, 256), (256, 256)]


def test_gemm_rule_k_shard_then_m_shard_then_replicate():
    f32 = jnp.float32
    plan = partition.plan_for("gemm", MESH8, S((32, 64), f32), S((64, 16), f32))
    assert plan.axis == "model" and plan.n == 4
    assert "k-sharded" in plan.note
    assert plan.collectives[0].kind == "all_reduce"
    assert plan.collectives[0].nbytes == 32 * 16 * 4  # fp32 accum partials
    # K=61 resists, M=32 divides: degrade to row sharding, no collective
    plan = partition.plan_for("gemm", MESH8, S((32, 61), f32), S((61, 16), f32))
    assert "m-row-sharded" in plan.note and plan.collectives == ()
    # nothing divides: replicate
    assert partition.plan_for(
        "gemm", MESH8, S((30, 61), f32), S((61, 16), f32)) is None


def test_attention_rules_are_gqa_aware():
    f32 = jnp.float32
    q, kv = S((2, 8, 32, 16), f32), S((2, 4, 32, 16), f32)
    plan = partition.plan_for("flash_attention", MESH8, q, kv, kv)
    assert plan is not None and "head-sharded" in plan.note
    # 20 q heads but 5 kv heads on a 4-way axis: never split a GQA group
    # across devices (the paper's TP-hostile head counts) — the head split
    # drops, but B over the data axis still composes
    q5, kv5 = S((2, 20, 32, 16), f32), S((2, 5, 32, 16), f32)
    plan = partition.plan_for("flash_attention", MESH8, q5, kv5, kv5)
    assert plan.levels == (("data", 2),) and "head" not in plan.note
    pos = S((2,), jnp.int32)
    assert partition.plan_for(
        "decode_attention", MESH8, S((2, 8, 16), f32), kv, kv, pos
    ) is not None
    plan = partition.plan_for(
        "decode_attention", MESH8, S((2, 20, 16), f32), kv5, kv5, pos
    )
    assert plan.levels == (("data", 2),) and "head" not in plan.note
    # a truly hostile decode (odd batch too) replicates
    assert partition.plan_for(
        "decode_attention", MESH8, S((3, 20, 16), f32),
        S((3, 5, 32, 16), f32), S((3, 5, 32, 16), f32), S((3,), jnp.int32)
    ) is None


def test_linear_attention_rule_head_divisibility():
    f32 = jnp.float32
    ok = tuple(S((1, 8, 64, 8), f32) for _ in range(4))
    assert partition.plan_for("linear_attention", MESH8, *ok) is not None
    bad = tuple(S((1, 6, 64, 8), f32) for _ in range(4))
    assert partition.plan_for("linear_attention", MESH8, *bad) is None


def test_sparse_rules_row_and_tile_divisibility():
    f32, i32 = jnp.float32, jnp.int32
    assert partition.plan_for(
        "spmm", MESH8, S((64, 8), f32), S((64, 8), i32), S((32, 4), f32)
    ) is not None
    assert partition.plan_for(
        "spmm", MESH8, S((62, 8), f32), S((62, 8), i32), S((32, 4), f32)
    ) is None
    plan = partition.plan_for(
        "bsr_spmm", MESH8, S((8, 8, 128), f32), S((8,), i32), S((8,), i32),
        S((256, 16), f32), num_rows=64,
    )
    assert plan is not None and plan.collectives[0].kind == "all_reduce"
    assert partition.plan_for(
        "bsr_spmm", MESH8, S((6, 8, 128), f32), S((6,), i32), S((6,), i32),
        S((256, 16), f32), num_rows=64,
    ) is None


def test_stencil_rule_halo_metadata():
    f32 = jnp.float32
    offs = np.array([(-2, 0, 0), (0, 0, 0), (1, 0, 0)], np.int32)
    w = np.ones((3,), np.float32)
    plan = partition.plan_for(
        "stencil", MESH8, S((16, 8, 8), f32), offsets=offs, weights=w
    )
    assert "halo h=2" in plan.note
    # two boundary-plane permutes of h*Y*Z fp32 each
    assert [c.kind for c in plan.collectives] == ["permute", "permute"]
    assert all(c.nbytes == 2 * 8 * 8 * 4 for c in plan.collectives)
    # halo wider than a slab (|dx|=5 > 16/4): replicate, never multi-hop
    wide = np.array([(-5, 0, 0), (0, 0, 0)], np.int32)
    assert partition.plan_for(
        "stencil", MESH8, S((16, 8, 8), f32),
        offsets=wide, weights=np.ones((2,), np.float32),
    ) is None
    # X itself indivisible
    assert partition.plan_for(
        "stencil", MESH8, S((18, 8, 8), f32), offsets=offs, weights=w
    ) is None


def test_plan_costing_feeds_roofline_d2d_term():
    f32 = jnp.float32
    plan = partition.plan_for(
        "gemm", MESH8, S((1024, 4096), f32), S((4096, 1024), f32))
    d2d = roofline.plan_collective_seconds(plan)
    assert d2d > 0.0
    assert roofline.op_collective_seconds(
        "gemm", MESH8, S((1024, 4096), f32), S((4096, 1024), f32)) == d2d
    # replicated ops move no D2D bytes
    assert roofline.op_collective_seconds(
        "gemm", MESH8, S((30, 61), f32), S((61, 16), f32)) == 0.0
    terms = roofline.roofline_terms(1e6, 1e6, 0.0, d2d_s=d2d)
    assert terms["d2d_s"] == d2d and "dominant" in terms
    # the d2d term participates in dominance
    big = roofline.roofline_terms(1.0, 1.0, 0.0, d2d_s=1e9)
    assert big["dominant"] == "d2d_s"


def test_meshspec_plans_but_does_not_execute(rng):
    a = jnp.asarray(rng.standard_normal((32, 64)), jnp.float32)
    b = jnp.asarray(rng.standard_normal((64, 16)), jnp.float32)
    with pytest.raises(TypeError, match="needs a device mesh"):
        partition.sharded_call("gemm", MESH8, a, b)


def test_single_axis_mesh_replicates(rng):
    a = jnp.asarray(rng.standard_normal((8, 8)), jnp.float32)
    trivial = partition.MeshSpec({"data": 1, "model": 1})
    assert partition.plan_for("gemm", trivial, a, a) is None
    # and ops.* still runs (plain kernel_call fallback) via the mesh kwarg
    got = ops.gemm(a, a, mesh=trivial, impl="ref", out_dtype=jnp.float32)
    np.testing.assert_allclose(np.asarray(got), np.asarray(a @ a),
                               rtol=1e-5, atol=1e-5)


def test_dryrun_op_roofline_cells():
    from repro.launch import dryrun

    cells = dryrun.op_roofline_cells(multi_pod=False)
    assert {c["op"] for c in cells} == set(partition.partitioned_ops())
    for c in cells:
        assert c["partition"] != "replicated", c["op"]
        assert c["roofline"]["dominant"] in (
            "compute_s", "memory_s", "collective_s", "d2d_s")
    by_op = {c["op"]: c for c in cells}
    # the split-K gemm and the tile-sharded bsr carry psum D2D bytes
    assert by_op["gemm"]["d2d_bytes"] > 0
    assert by_op["bsr_spmm"]["d2d_bytes"] > 0
    assert by_op["stencil"]["d2d_bytes"] > 0  # halo planes
    # the B=1 long-context flash cell rides the KV ring: its (n-1) per-hop
    # ppermutes (x2: k and v) are priced into the data level
    fa = by_op["flash_attention"]
    assert "ring seq-parallel" in fa["partition"]
    assert fa["d2d_bytes"] > 0
    assert fa["collective_s_per_level"].get("data", 0) > 0


def test_dryrun_op_roofline_multi_pod_emits_per_level_seconds():
    from repro.launch import dryrun

    cells = dryrun.op_roofline_cells(multi_pod=True)
    assert {c["op"] for c in cells} == set(partition.partitioned_ops())
    by_op = {c["op"]: c for c in cells}
    # every cell carries the per-level breakdown (empty only if no collective)
    for c in cells:
        assert "collective_s_per_level" in c and "partition_levels" in c
    # hierarchical psums price intra-pod (model/ICI) vs cross-pod (pod/D2D)
    for op in ("gemm", "bsr_spmm", "stencil"):
        per = by_op[op]["collective_s_per_level"]
        assert per.get("model", 0) > 0 and per.get("pod", 0) > 0, op
        assert by_op[op]["partition_levels"] == ["pod=2", "model=16"]
        total = sum(per.values())
        assert by_op[op]["roofline"]["d2d_s"] == pytest.approx(total)
    # 16 kv heads resist pod*model=32: the ladder drops the pod level. The
    # B=1 long-context flash cell then rides the sequence-parallel KV ring
    # over the data axis (heads intra-pod), pricing its per-hop ppermutes
    assert by_op["flash_attention"]["partition_levels"] == [
        "data=16", "model=16"]
    assert "ring seq-parallel" in by_op["flash_attention"]["partition"]
    assert by_op["flash_attention"]["collective_s_per_level"]["data"] > 0
    # decode (B=8) and linear attention (B=1) have no ring: head-only plans
    for op in ("decode_attention", "linear_attention"):
        assert by_op[op]["partition_levels"] == ["model=16"], op
        assert "pod" not in by_op[op]["collective_s_per_level"]


# ---------------------------------------------------------------------------
# decode_attention: the blocked xla impl (single device)
# ---------------------------------------------------------------------------


@pytest.mark.parametrize("window", [0, 7])
def test_decode_attention_blocked_xla_matches_ref(rng, window):
    q = jnp.asarray(rng.standard_normal((2, 8, 16)), jnp.float32)
    k = jnp.asarray(rng.standard_normal((2, 4, 50, 16)), jnp.float32)
    v = jnp.asarray(rng.standard_normal((2, 4, 50, 16)), jnp.float32)
    pos = jnp.asarray([5, 49], jnp.int32)
    want = ops.decode_attention(q, k, v, pos, impl="ref", window=window)
    for bs in (8, 16, 64):  # 64 > S exercises the clamp
        got = ops.decode_attention(q, k, v, pos, impl="xla", window=window,
                                   bs=bs)
        np.testing.assert_allclose(np.asarray(got), np.asarray(want),
                                   rtol=1e-4, atol=1e-4)


def test_decode_attention_unrolled_matches_scan(rng):
    q = jnp.asarray(rng.standard_normal((1, 4, 8)), jnp.float32)
    k = jnp.asarray(rng.standard_normal((1, 2, 33, 8)), jnp.float32)
    v = jnp.asarray(rng.standard_normal((1, 2, 33, 8)), jnp.float32)
    pos = jnp.asarray([30], jnp.int32)
    want = ops.decode_attention(q, k, v, pos, impl="xla", bs=8)
    with registry.unroll_inner():
        got = ops.decode_attention(q, k, v, pos, impl="xla", bs=8)
    np.testing.assert_allclose(np.asarray(got), np.asarray(want),
                               rtol=1e-5, atol=1e-5)


def test_decode_attention_override_reaches_xla_impl(rng, monkeypatch):
    import repro.kernels.xla as xla_mod

    q = jnp.asarray(rng.standard_normal((1, 4, 8)), jnp.float32)
    k = jnp.asarray(rng.standard_normal((1, 2, 32, 8)), jnp.float32)
    pos = jnp.asarray([31], jnp.int32)
    captured = {}
    orig = xla_mod.decode_attention_xla

    def spy(*a, **kw):
        captured["bs"] = kw.get("bs")
        return orig(*a, **kw)

    monkeypatch.setattr(xla_mod, "decode_attention_xla", spy)
    registry.set_block_override("decode_attention", bs=16)
    ops.decode_attention(q, k, k, pos, impl="xla")
    assert captured["bs"] == 16
    ops.decode_attention(q, k, k, pos, impl="xla", bs=8)  # explicit wins
    assert captured["bs"] == 8


# ---------------------------------------------------------------------------
# host_device_mesh graceful degradation (single device is enough)
# ---------------------------------------------------------------------------


def test_host_device_mesh_degrades_with_warning():
    from repro.launch.mesh import host_device_mesh

    n = len(jax.devices())
    with pytest.warns(UserWarning, match="degrading to tp="):
        mesh = host_device_mesh(tp=n + 3)  # cannot divide; 1 always fits
    assert mesh.shape["model"] <= n
    assert mesh.shape["data"] * mesh.shape["model"] == n


def test_use_mesh_does_not_leak_into_model_mesh():
    """use_mesh keys kernels only: current_mesh() — which the model-level
    shard_map paths (moe dispatch, ssm halo shift) read — must stay None, or
    a kernel-only mesh context would silently re-route model internals."""
    from repro.parallel import sharding as sh

    fake = object()  # plans never dereference devices, a sentinel suffices
    with sh.use_mesh(fake):
        assert sh.kernel_mesh() is fake
        assert sh.current_mesh() is None
    assert sh.kernel_mesh() is None


def test_autotune_suite_covers_every_block_table_op():
    """PR 2's invariant, kept: every op the registry advertises as tunable
    has an autotune case (decode_attention included)."""
    from repro.launch import autotune as at

    assert set(at.DEFAULT_SUITE) == set(registry._BLOCK_DEFAULTS)
    # the decode feasibility probe scales with bs and respects clamping
    case = at.DEFAULT_SUITE["decode_attention"](np.random.default_rng(0))
    small = case.program({"bs": 128}).vmem_bytes()
    big = case.program({"bs": 1024}).vmem_bytes()
    assert small < big
    assert case.program({"bs": 4096}).vmem_bytes() == big  # clamped to S


def test_host_device_mesh_rejects_invalid_tp():
    from repro.launch.mesh import host_device_mesh

    with pytest.raises(ValueError, match="not a valid mesh factorisation"):
        host_device_mesh(tp=0)
    with pytest.raises(ValueError, match="not a valid mesh factorisation"):
        host_device_mesh(tp=1, pods=0)
    mesh = host_device_mesh(tp=1)  # exact fit: no warning path
    assert mesh.shape["model"] == 1
    assert tuple(mesh.axis_names) == ("data", "model")  # pods=1: legacy shape


def test_host_device_mesh_three_axis_construction():
    from repro.launch.mesh import host_device_mesh

    n = len(jax.devices())
    # an exactly-dividing pod request yields the (pod, data, model) hierarchy
    # with no warning; with 1 device the pod axis degrades to 1 but the axis
    # names stay stable for pod-aware callers
    if n % 2 == 0:
        import warnings as _w

        with _w.catch_warnings():
            _w.simplefilter("error")
            mesh = host_device_mesh(tp=1, pods=2)
        assert mesh.shape["pod"] == 2
    else:
        with pytest.warns(UserWarning, match="degrading to tp="):
            mesh = host_device_mesh(tp=1, pods=2)
        assert mesh.shape["pod"] == 1
    assert tuple(mesh.axis_names) == ("pod", "data", "model")
    assert mesh.shape["pod"] * mesh.shape["data"] * mesh.shape["model"] == n
    # the mesh feeds the partition layer: pod level present iff pod > 1
    levels = partition.partition_levels(mesh)
    if mesh.shape["pod"] > 1:
        assert levels[0] == ("pod", mesh.shape["pod"])
    else:
        assert all(a != "pod" for a, _ in levels)


def test_host_device_mesh_degrades_when_pod_times_tp_indivisible():
    from repro.launch.mesh import host_device_mesh

    n = len(jax.devices())
    # pods*tp cannot divide n (both exceed it): degrade both with a warning
    with pytest.warns(UserWarning, match="degrading to tp="):
        mesh = host_device_mesh(tp=n + 1, pods=n + 1)
    assert tuple(mesh.axis_names) == ("pod", "data", "model")
    assert mesh.shape["pod"] * mesh.shape["data"] * mesh.shape["model"] == n
    assert mesh.shape["pod"] <= n and mesh.shape["model"] <= n


# ---------------------------------------------------------------------------
# Sharded execution: numerical equivalence on 8 forced host devices
# (subprocess so the device-count flag never leaks into this process)
# ---------------------------------------------------------------------------

_EQUIV = textwrap.dedent(
    """
    import os
    os.environ["XLA_FLAGS"] = "--xla_force_host_platform_device_count=8"
    import json
    import jax, jax.numpy as jnp, numpy as np
    from repro.core import sparse as sp
    from repro.kernels import ops, partition
    from repro.models import gcn
    from repro.parallel import sharding as sh

    mesh = jax.make_mesh((2, 4), ("data", "model"))
    rng = np.random.default_rng(0)
    f32 = jnp.float32
    out = {"ok": [], "fallbacks": []}

    def check(name, got, want, tol=1e-4):
        pairs = zip(got, want) if isinstance(got, tuple) else [(got, want)]
        err = max(float(jnp.max(jnp.abs(jnp.asarray(g) - jnp.asarray(w))))
                  for g, w in pairs)
        assert err < tol, (name, err)
        out["ok"].append(name)

    a = jnp.asarray(rng.standard_normal((32, 64)), f32)
    b = jnp.asarray(rng.standard_normal((64, 32)), f32)
    q = jnp.asarray(rng.standard_normal((2, 8, 32, 16)), f32)
    kv = jnp.asarray(rng.standard_normal((2, 4, 32, 16)), f32)
    qd = jnp.asarray(rng.standard_normal((2, 8, 16)), f32)
    pos = jnp.asarray([5, 30], jnp.int32)
    r = jnp.asarray(rng.standard_normal((1, 4, 64, 8)), f32)
    wl = jnp.asarray(-rng.uniform(0.01, 1.0, (1, 4, 64, 8)), f32)
    u = jnp.asarray(rng.standard_normal((4, 8)), f32)
    ell = sp.random_ell(rng, 64, 32, 0.1)
    dn = jnp.asarray(rng.standard_normal((32, 8)), f32)
    bsr_dense = np.zeros((16, 256), np.float32)
    bsr_dense[::3, ::17] = 1.0
    bsrA = sp.dense_to_bsr(bsr_dense, bm=8, bk=128)
    brhs = jnp.asarray(rng.standard_normal((256, 16)), f32)
    sA, sB = sp.random_ell(rng, 32, 64, 0.1), sp.random_ell(rng, 64, 64, 0.1)
    grid = jnp.asarray(rng.standard_normal((16, 8, 8)), f32)
    # offsets reach ACROSS slab boundaries (|dx|=2 on 4-plane slabs): the
    # halo-exchange correctness case, incl. the periodic wrap at the ends
    offs = np.array([(-2, 0, 0), (0, 0, 0), (1, 1, 0), (2, 0, 1)], np.int32)
    w = np.array([0.2, 0.3, 0.4, 0.1], np.float32)

    # decode_attention's stream impls are the ref form, so all four impl
    # names run on CPU for it; stream ops cover interpret/xla/ref (the
    # pallas entry is the same StreamProgram, compiled)
    for impl in ("interpret", "xla", "ref"):
        check(f"gemm[{impl}]",
              ops.gemm(a, b, mesh=mesh, impl=impl, out_dtype=f32),
              ops.gemm(a, b, impl="ref", out_dtype=f32))
        check(f"flash[{impl}]",
              ops.flash_attention(q, kv, kv, mesh=mesh, impl=impl),
              ops.flash_attention(q, kv, kv, impl="ref"))
        check(f"linattn_rwkv[{impl}]",
              ops.linear_attention(r, r, r, wl, u, mesh=mesh, impl=impl),
              ops.linear_attention(r, r, r, wl, u, impl="ref"))
        check(f"linattn_ssd[{impl}]",
              ops.linear_attention(r, r, r, wl, mesh=mesh, impl=impl),
              ops.linear_attention(r, r, r, wl, impl="ref"))
        check(f"spmm[{impl}]", ops.spmm(ell, dn, mesh=mesh, impl=impl),
              ops.spmm(ell, dn, impl="ref"))
        check(f"bsr_spmm[{impl}]",
              ops.bsr_spmm(bsrA, brhs, mesh=mesh, impl=impl),
              ops.bsr_spmm(bsrA, brhs, impl="xla"))
        check(f"spmspm[{impl}]",
              ops.spmspm(sA, sB, 64, mesh=mesh, impl=impl),
              ops.spmspm(sA, sB, 64, impl="ref"))
        check(f"stencil[{impl}]",
              ops.stencil(grid, offs, w, mesh=mesh, impl=impl),
              ops.stencil(grid, offs, w, impl="ref"))
    for impl in ("pallas", "interpret", "xla", "ref"):
        check(f"decode[{impl}]",
              ops.decode_attention(qd, kv, kv, pos, mesh=mesh, impl=impl),
              ops.decode_attention(qd, kv, kv, pos, impl="ref"))

    # gemm k-shard must preserve an explicit narrower out_dtype
    got16 = ops.gemm(a, b, mesh=mesh, impl="xla", out_dtype=jnp.bfloat16)
    assert got16.dtype == jnp.bfloat16
    out["ok"].append("gemm[out_dtype]")

    # replication fallback on indivisible shapes: same signature, same
    # answer. B=1 + TP-hostile heads + odd seq defeats head, batch AND the
    # seq-parallel ring
    q5 = jnp.asarray(rng.standard_normal((1, 5, 15, 8)), f32)
    check("fallback_flash",
          ops.flash_attention(q5, q5, q5, mesh=mesh, impl="xla"),
          ops.flash_attention(q5, q5, q5, impl="ref"))
    ell62 = sp.random_ell(rng, 62, 32, 0.1)
    check("fallback_spmm", ops.spmm(ell62, dn, mesh=mesh, impl="xla"),
          ops.spmm(ell62, dn, impl="ref"))
    for name, args in (("flash", (q5, q5, q5)), ("spmm",
                       (ell62.values, ell62.cols, dn))):
        op = "flash_attention" if name == "flash" else "spmm"
        assert partition.plan_for(op, mesh, *args) is None
        out["fallbacks"].append(name)

    # halo exchange at every slab width that divides X=16
    for tp in (2, 4, 8):
        m2 = jax.make_mesh((8 // tp, tp), ("data", "model"))
        check(f"stencil_halo_tp{tp}",
              ops.stencil(grid, offs, w, mesh=m2, impl="interpret"),
              ops.stencil(grid, offs, w, impl="ref"))

    # row-sharded GCN end to end (explicit mesh kwarg AND use_mesh context)
    feats = jnp.asarray(rng.standard_normal((64, 16)), f32)
    params = gcn.init_params(jax.random.PRNGKey(0), [16, 32, 8])
    adj = sp.random_ell(rng, 64, 64, 0.05)
    want = gcn.forward(params, adj, feats)
    check("gcn_mesh_kwarg",
          jax.jit(lambda p, a_, f_: gcn.forward(p, a_, f_, mesh=mesh))(
              params, adj, feats), want)
    with sh.use_mesh(mesh):
        check("gcn_use_mesh", gcn.forward(params, adj, feats), want)
    assert sh.kernel_mesh() is None  # context restored
    print("RESULT:" + json.dumps(out))
    """
)


def test_sharded_equivalence_all_ops():
    env = dict(os.environ)
    env["PYTHONPATH"] = os.path.join(os.path.dirname(__file__), "..", "src")
    proc = subprocess.run(
        [sys.executable, "-c", _EQUIV],
        capture_output=True, text=True, env=env, timeout=900,
    )
    assert proc.returncode == 0, proc.stderr[-3000:]
    line = [ln for ln in proc.stdout.splitlines() if ln.startswith("RESULT:")][-1]
    out = json.loads(line[len("RESULT:"):])
    # every partitioned op x impl combination ran and matched
    for op_tag in ("gemm", "flash", "linattn_rwkv", "linattn_ssd", "spmm",
                   "bsr_spmm", "spmspm", "stencil"):
        for impl in ("interpret", "xla", "ref"):
            assert f"{op_tag}[{impl}]" in out["ok"], (op_tag, impl)
    for impl in ("pallas", "interpret", "xla", "ref"):
        assert f"decode[{impl}]" in out["ok"]
    assert set(out["fallbacks"]) == {"flash", "spmm"}
    assert {"stencil_halo_tp2", "stencil_halo_tp4", "stencil_halo_tp8",
            "gcn_mesh_kwarg", "gcn_use_mesh"} <= set(out["ok"])


# Three-axis variant: the same every-op x every-impl equivalence on a
# (pod, data, model) = 2x2x2 mesh, where plans resolve TWO-LEVEL (joint
# pod x model sharding, hierarchical psums, cross-pod halo hop) and the
# level ladder drops to model-only for pod-indivisible shapes.
_EQUIV_3AX = textwrap.dedent(
    """
    import os
    os.environ["XLA_FLAGS"] = "--xla_force_host_platform_device_count=8"
    import json
    import jax, jax.numpy as jnp, numpy as np
    from repro.core import sparse as sp
    from repro.kernels import ops, partition

    mesh = jax.make_mesh((2, 2, 2), ("pod", "data", "model"))
    rng = np.random.default_rng(0)
    f32 = jnp.float32
    out = {"ok": [], "two_level": [], "ladder": []}

    def check(name, got, want, tol=1e-4):
        pairs = zip(got, want) if isinstance(got, tuple) else [(got, want)]
        err = max(float(jnp.max(jnp.abs(jnp.asarray(g) - jnp.asarray(w))))
                  for g, w in pairs)
        assert err < tol, (name, err)
        out["ok"].append(name)

    a = jnp.asarray(rng.standard_normal((32, 64)), f32)
    b = jnp.asarray(rng.standard_normal((64, 32)), f32)
    q = jnp.asarray(rng.standard_normal((2, 8, 32, 16)), f32)
    kv = jnp.asarray(rng.standard_normal((2, 4, 32, 16)), f32)
    qd = jnp.asarray(rng.standard_normal((2, 8, 16)), f32)
    pos = jnp.asarray([5, 30], jnp.int32)
    r = jnp.asarray(rng.standard_normal((1, 4, 64, 8)), f32)
    wl = jnp.asarray(-rng.uniform(0.01, 1.0, (1, 4, 64, 8)), f32)
    u = jnp.asarray(rng.standard_normal((4, 8)), f32)
    ell = sp.random_ell(rng, 64, 32, 0.1)
    dn = jnp.asarray(rng.standard_normal((32, 8)), f32)
    bsr_dense = np.zeros((16, 256), np.float32)
    bsr_dense[::3, ::17] = 1.0
    bsrA = sp.dense_to_bsr(bsr_dense, bm=8, bk=128)
    brhs = jnp.asarray(rng.standard_normal((256, 16)), f32)
    sA, sB = sp.random_ell(rng, 32, 64, 0.1), sp.random_ell(rng, 64, 64, 0.1)
    grid = jnp.asarray(rng.standard_normal((16, 8, 8)), f32)
    # |dx|=2 on 4-plane slabs: halo planes cross slab AND pod boundaries
    offs = np.array([(-2, 0, 0), (0, 0, 0), (1, 1, 0), (2, 0, 1)], np.int32)
    w = np.array([0.2, 0.3, 0.4, 0.1], np.float32)

    # every op resolves two-level here: pod*model = 4 divides K=64, kv=4
    # heads, H=4, 64 rows, 4 tiles, 32 rows, X=16. Attention rules also
    # compose B=2 over the data axis (three levels); linattn has B=1
    two_level_cases = [
        ("gemm", (a, b), {}, (("pod", 2), ("model", 2))),
        ("flash", (q, kv, kv), {}, (("pod", 2), ("data", 2), ("model", 2))),
        ("decode", (qd, kv, kv, pos), {},
         (("pod", 2), ("data", 2), ("model", 2))),
        ("linattn", (r, r, r, wl), {}, (("pod", 2), ("model", 2))),
        ("spmm", (ell.values, ell.cols, dn), {}, (("pod", 2), ("model", 2))),
        ("bsr_spmm", (bsrA.tile_values, bsrA.tile_rows, bsrA.tile_cols,
                      brhs), {"num_rows": 16}, (("pod", 2), ("model", 2))),
        ("spmspm", (sA.values, sA.cols, sB.values, sB.cols),
         {"contraction_dim": 64}, (("pod", 2), ("model", 2))),
        ("stencil", (grid,), {"offsets": offs, "weights": w},
         (("pod", 2), ("model", 2))),
    ]
    op_names = {"linattn": "linear_attention", "flash": "flash_attention",
                "decode": "decode_attention"}
    for tag, args, kw, want_levels in two_level_cases:
        plan = partition.plan_for(op_names.get(tag, tag), mesh, *args, **kw)
        assert plan.levels == want_levels, (tag, plan.levels)
        out["two_level"].append(tag)

    # the ladder on a live mesh: kv=2 heads / 38 rows resist pod*model=4
    # but divide model=2 -> dropped-pod plans that still execute correctly
    kv2 = jnp.asarray(rng.standard_normal((2, 2, 32, 16)), f32)
    plan = partition.plan_for("flash_attention", mesh, q, kv2, kv2)
    assert plan.levels == (("data", 2), ("model", 2)), plan.levels
    check("ladder_flash",
          ops.flash_attention(q, kv2, kv2, mesh=mesh, impl="xla"),
          ops.flash_attention(q, kv2, kv2, impl="ref"))
    out["ladder"].append("flash")
    ell38 = sp.random_ell(rng, 38, 32, 0.1)
    plan = partition.plan_for("spmm", mesh, ell38.values, ell38.cols, dn)
    assert plan.levels == (("model", 2),), plan.levels
    check("ladder_spmm", ops.spmm(ell38, dn, mesh=mesh, impl="xla"),
          ops.spmm(ell38, dn, impl="ref"))
    out["ladder"].append("spmm")

    for impl in ("interpret", "xla", "ref"):
        check(f"gemm[{impl}]",
              ops.gemm(a, b, mesh=mesh, impl=impl, out_dtype=f32),
              ops.gemm(a, b, impl="ref", out_dtype=f32))
        check(f"flash[{impl}]",
              ops.flash_attention(q, kv, kv, mesh=mesh, impl=impl),
              ops.flash_attention(q, kv, kv, impl="ref"))
        check(f"linattn_rwkv[{impl}]",
              ops.linear_attention(r, r, r, wl, u, mesh=mesh, impl=impl),
              ops.linear_attention(r, r, r, wl, u, impl="ref"))
        check(f"linattn_ssd[{impl}]",
              ops.linear_attention(r, r, r, wl, mesh=mesh, impl=impl),
              ops.linear_attention(r, r, r, wl, impl="ref"))
        check(f"spmm[{impl}]", ops.spmm(ell, dn, mesh=mesh, impl=impl),
              ops.spmm(ell, dn, impl="ref"))
        check(f"bsr_spmm[{impl}]",
              ops.bsr_spmm(bsrA, brhs, mesh=mesh, impl=impl),
              ops.bsr_spmm(bsrA, brhs, impl="xla"))
        check(f"spmspm[{impl}]",
              ops.spmspm(sA, sB, 64, mesh=mesh, impl=impl),
              ops.spmspm(sA, sB, 64, impl="ref"))
        check(f"stencil[{impl}]",
              ops.stencil(grid, offs, w, mesh=mesh, impl=impl),
              ops.stencil(grid, offs, w, impl="ref"))
    for impl in ("pallas", "interpret", "xla", "ref"):
        check(f"decode[{impl}]",
              ops.decode_attention(qd, kv, kv, pos, mesh=mesh, impl=impl),
              ops.decode_attention(qd, kv, kv, pos, impl="ref"))
    print("RESULT:" + json.dumps(out))
    """
)


def test_sharded_equivalence_all_ops_three_axis():
    env = dict(os.environ)
    env["PYTHONPATH"] = os.path.join(os.path.dirname(__file__), "..", "src")
    proc = subprocess.run(
        [sys.executable, "-c", _EQUIV_3AX],
        capture_output=True, text=True, env=env, timeout=900,
    )
    assert proc.returncode == 0, proc.stderr[-3000:]
    line = [ln for ln in proc.stdout.splitlines() if ln.startswith("RESULT:")][-1]
    out = json.loads(line[len("RESULT:"):])
    for op_tag in ("gemm", "flash", "linattn_rwkv", "linattn_ssd", "spmm",
                   "bsr_spmm", "spmspm", "stencil"):
        for impl in ("interpret", "xla", "ref"):
            assert f"{op_tag}[{impl}]" in out["ok"], (op_tag, impl)
    for impl in ("pallas", "interpret", "xla", "ref"):
        assert f"decode[{impl}]" in out["ok"]
    # the joint pod x model plans actually engaged (not a silent fallback)
    assert set(out["two_level"]) == {"gemm", "flash", "decode", "linattn",
                                     "spmm", "bsr_spmm", "spmspm", "stencil"}
    assert set(out["ladder"]) == {"flash", "spmm"}
    assert {"ladder_flash", "ladder_spmm"} <= set(out["ok"])


# ---------------------------------------------------------------------------
# Sequence-parallel ring flash attention: device-free plan units, the merge
# and per-shard q_offset math on one device, and 8-device equivalence
# ---------------------------------------------------------------------------


def test_attention_levels_vocabulary():
    # the data axis slots between pod and model for the attention family;
    # the default vocabulary (partition_levels) is untouched
    assert partition.attention_levels(MESH8) == (("data", 2), ("model", 4))
    assert partition.attention_levels(MESH_2POD) == (
        ("pod", 2), ("data", 2), ("model", 4))
    assert partition.partition_levels(MESH8) == (("model", 4),)
    # size-1 or missing data axes drop out
    assert partition.attention_levels(
        partition.MeshSpec({"data": 1, "model": 4})) == (("model", 4),)
    assert partition.attention_levels(
        partition.MeshSpec({"model": 4})) == (("model", 4),)


def test_flash_ring_rule_resolution():
    f32 = jnp.float32
    qL = S((1, 8, 256, 16), f32)
    kL = S((1, 4, 256, 16), f32)
    # B=1 blocks the batch split: the data axis carries the sequence
    plan = partition.plan_for("flash_attention", MESH8, qL, kL, kL)
    assert "ring seq-parallel" in plan.note and "head-sharded" in plan.note
    assert plan.levels == (("data", 2), ("model", 4))
    # (n-1) hops x (k and v): per-hop permutes priced on the data level at
    # the local shard payload
    assert len(plan.collectives) == 2 * (2 - 1)
    kv_local = 1 * (4 // 4) * (256 // 2) * 16 * 4
    assert all(
        c == partition.CollectiveCost("permute", "data", kv_local, 2)
        for c in plan.collectives
    )
    assert roofline.plan_collective_seconds_by_level(plan)["data"] > 0
    # a lookback window prunes whole tail hops statically: of 8 ring steps
    # only ceil((33+31)/32) = 2 kernel steps (1 rotation) survive
    wide = partition.MeshSpec({"data": 8, "model": 1})
    plan = partition.plan_for("flash_attention", wide, qL, kL, kL, window=33)
    assert "1 kv hops" in plan.note
    assert len(plan.collectives) == 2 * 1
    # batch sharding is preferred over the ring when B divides
    qB = S((2, 8, 256, 16), f32)
    kB = S((2, 4, 256, 16), f32)
    plan = partition.plan_for("flash_attention", MESH8, qB, kB, kB)
    assert "batch-sharded" in plan.note and "ring" not in plan.note
    # the ring declines bounded masks at nonzero q_offset (the wrap would
    # alias past positions) and cross-attention (Sq != Sk)
    plan = partition.plan_for(
        "flash_attention", MESH8, qL, kL, kL, causal=True, q_offset=7)
    assert plan is not None and "ring" not in plan.note  # head-only
    qX = S((1, 8, 128, 16), f32)
    plan = partition.plan_for(
        "flash_attention", MESH8, qX, kL, kL, causal=False)
    assert plan is None or "ring" not in plan.note
    # ...but an unbounded (causal=False, window=0) ring tolerates q_offset
    plan = partition.plan_for(
        "flash_attention", MESH8, qL, kL, kL, causal=False, q_offset=7)
    assert "ring seq-parallel" in plan.note


def test_online_softmax_merge_reconstructs_full_softmax(rng):
    from repro.parallel.collectives import NEG_LSE, online_softmax_merge

    q = jnp.asarray(rng.standard_normal((1, 4, 32, 8)), jnp.float32)
    k = jnp.asarray(rng.standard_normal((1, 2, 32, 8)), jnp.float32)
    v = jnp.asarray(rng.standard_normal((1, 2, 32, 8)), jnp.float32)
    for kw in (dict(causal=True), dict(causal=True, window=5),
               dict(causal=False)):
        want = ops.flash_attention(q, k, v, impl="ref", **kw)
        # split KV in half; the second half's mask needs q shifted LEFT by
        # the split point (the same q_offset hook the ring uses per hop)
        half = 16
        o = jnp.zeros(q.shape, jnp.float32)
        lse = jnp.full(q.shape[:-1], NEG_LSE, jnp.float32)
        for j, off in ((0, 0), (1, -half)):
            o_t, lse_t = ops.flash_attention(
                q, k[:, :, j * half:(j + 1) * half],
                v[:, :, j * half:(j + 1) * half],
                impl="ref", return_lse=True,
                **{**kw, "q_offset": kw.get("q_offset", 0) + off},
            )
            o, lse = online_softmax_merge(o, lse, o_t, lse_t)
        np.testing.assert_allclose(
            np.asarray(o.astype(q.dtype)), np.asarray(want),
            rtol=1e-5, atol=1e-5,
        )


@pytest.mark.parametrize("kw", [
    dict(causal=True), dict(causal=True, window=9),
    dict(causal=False), dict(causal=False, window=9),
])
def test_ring_per_shard_q_offset_single_device_simulation(rng, kw):
    """The ring's per-(rank, hop) masking, simulated without devices: rank
    ``me``'s hop ``t`` runs the kernel at static ``q_offset = t*c`` on the
    KV chunk of rank ``(me - t) % d``; under causal/window masking the
    wrapped hops (me < t) merge as no-ops. Folding every rank's hops must
    reproduce the full single-device attention row-for-row."""
    from repro.parallel.collectives import NEG_LSE, online_softmax_merge

    d, c = 4, 16
    S_ = d * c
    q = jnp.asarray(rng.standard_normal((1, 4, S_, 8)), jnp.float32)
    k = jnp.asarray(rng.standard_normal((1, 2, S_, 8)), jnp.float32)
    v = jnp.asarray(rng.standard_normal((1, 2, S_, 8)), jnp.float32)
    want = ops.flash_attention(q, k, v, impl="ref", **kw)
    bounded = kw.get("causal") or kw.get("window", 0)
    outs = []
    for me in range(d):
        q_l = q[:, :, me * c:(me + 1) * c]
        o = jnp.zeros(q_l.shape, jnp.float32)
        lse = jnp.full(q_l.shape[:-1], NEG_LSE, jnp.float32)
        for t in range(d):
            src = (me - t) % d
            o_t, lse_t = ops.flash_attention(
                q_l, k[:, :, src * c:(src + 1) * c],
                v[:, :, src * c:(src + 1) * c],
                impl="ref", return_lse=True, q_offset=t * c, **kw,
            )
            if bounded and t and me < t:  # wrapped: KV chunk is in the future
                continue
            o, lse = online_softmax_merge(o, lse, o_t, lse_t)
        outs.append(o)
    got = jnp.concatenate(outs, axis=2).astype(q.dtype)
    np.testing.assert_allclose(np.asarray(got), np.asarray(want),
                               rtol=1e-4, atol=1e-4)


# 8-device subprocess suite for the data-axis attention rules: ring flash
# vs single device across causal x window x GQA (including a TP-hostile
# head count that forces the head rule onto the ladder), batch-composed
# plans, and the ring_scan_carry threading unit.
_EQUIV_RING = textwrap.dedent(
    """
    import os
    os.environ["XLA_FLAGS"] = "--xla_force_host_platform_device_count=8"
    import json
    import jax, jax.numpy as jnp, numpy as np
    from jax.sharding import PartitionSpec as P
    from repro.kernels import ops, partition
    from repro.parallel.collectives import ring_scan_carry
    from repro.parallel.compat import shard_map

    mesh = jax.make_mesh((4, 2), ("data", "model"))
    rng = np.random.default_rng(0)
    f32 = jnp.float32
    out = {"ok": [], "ring": [], "batch": []}

    def check(name, got, want, tol=1e-4):
        err = float(jnp.max(jnp.abs(jnp.asarray(got) - jnp.asarray(want))))
        assert err < tol, (name, err)
        out["ok"].append(name)

    # B=1 forces the ring; 8 q heads / 2 kv heads = GQA groups of 4
    q = jnp.asarray(rng.standard_normal((1, 8, 64, 16)), f32)
    kv = jnp.asarray(rng.standard_normal((1, 2, 64, 16)), f32)
    # TP-hostile: 5 kv heads resist model=2, so the head rule drops off the
    # ladder and the data level carries the ring alone
    qh = jnp.asarray(rng.standard_normal((1, 10, 64, 16)), f32)
    kvh = jnp.asarray(rng.standard_normal((1, 5, 64, 16)), f32)

    cases = [("gqa", q, kv, kv), ("hostile", qh, kvh, kvh)]
    kws = [dict(causal=True), dict(causal=True, window=9),
           dict(causal=False), dict(causal=False, window=9)]
    for tag, qq, kk, vv in cases:
        for kw in kws:
            plan = partition.plan_for("flash_attention", mesh, qq, kk, vv, **kw)
            assert "ring seq-parallel" in plan.note, (tag, kw, plan.note)
            if tag == "hostile":
                assert plan.levels == (("data", 4),), plan.levels
            else:
                assert plan.levels == (("data", 4), ("model", 2))
            for impl in ("interpret", "xla", "ref"):
                name = f"ring_{tag}[{impl}]" + (
                    f"w{kw.get('window', 0)}c{int(kw['causal'])}")
                check(name,
                      ops.flash_attention(qq, kk, vv, mesh=mesh, impl=impl, **kw),
                      ops.flash_attention(qq, kk, vv, impl="ref", **kw))
            out["ring"].append(f"{tag}_w{kw.get('window', 0)}c{int(kw['causal'])}")

    # ring + return_lse through the sharded path
    o, lse = ops.flash_attention(q, kv, kv, mesh=mesh, impl="xla",
                                 return_lse=True)
    ow, lw = ops.flash_attention(q, kv, kv, impl="ref", return_lse=True)
    check("ring_lse_o", o, ow)
    check("ring_lse", lse, lw, tol=1e-3)

    # batch-composed plans: B over data x heads over model
    qb = jnp.asarray(rng.standard_normal((4, 8, 32, 16)), f32)
    kvb = jnp.asarray(rng.standard_normal((4, 2, 32, 16)), f32)
    plan = partition.plan_for("flash_attention", mesh, qb, kvb, kvb)
    assert "batch-sharded" in plan.note and "head-sharded" in plan.note
    check("batch_flash", ops.flash_attention(qb, kvb, kvb, mesh=mesh, impl="xla"),
          ops.flash_attention(qb, kvb, kvb, impl="ref"))
    out["batch"].append("flash")
    qd = jnp.asarray(rng.standard_normal((4, 8, 16)), f32)
    pos = jnp.asarray([5, 30, 12, 31], jnp.int32)
    plan = partition.plan_for("decode_attention", mesh, qd, kvb, kvb, pos)
    assert "batch-sharded" in plan.note
    check("batch_decode",
          ops.decode_attention(qd, kvb, kvb, pos, mesh=mesh, impl="xla"),
          ops.decode_attention(qd, kvb, kvb, pos, impl="ref"))
    out["batch"].append("decode")
    r = jnp.asarray(rng.standard_normal((4, 4, 64, 8)), f32)
    wl = jnp.asarray(-rng.uniform(0.01, 1.0, (4, 4, 64, 8)), f32)
    plan = partition.plan_for("linear_attention", mesh, r, r, r, wl)
    assert "batch-sharded" in plan.note
    got = ops.linear_attention(r, r, r, wl, mesh=mesh, impl="xla")
    want = ops.linear_attention(r, r, r, wl, impl="ref")
    check("batch_linattn_o", got[0], want[0])
    check("batch_linattn_s", got[1], want[1])
    out["batch"].append("linattn")

    # ring_scan_carry threads the TRUE carry rank to rank (the fixed
    # primitive: the old single-ppermute version delivered each rank only
    # its neighbour's locally-seeded state)
    xs = jnp.asarray(rng.standard_normal((8, 4)), f32)

    def chunk(s, x):  # running prefix-sum recurrence over the local chunk
        ys = s + jnp.cumsum(x[0])
        return ys[-1], ys[None]

    def local(x_l):
        ys, s = ring_scan_carry(chunk, x_l, jnp.float32(0.0), "data", 4)
        return ys, s[None]

    ys, s_fin = shard_map(
        local, mesh=mesh, in_specs=(P("data", None),),
        out_specs=(P("data", None), P("data")), check_vma=False,
    )(xs[:4])
    want = jnp.cumsum(xs[:4].reshape(-1)).reshape(4, 4)
    check("ring_scan_carry_ys", ys, want, tol=1e-5)
    check("ring_scan_carry_final", s_fin[-1], want[-1, -1], tol=1e-5)
    print("RESULT:" + json.dumps(out))
    """
)


def test_ring_and_batch_attention_equivalence_8dev():
    env = dict(os.environ)
    env["PYTHONPATH"] = os.path.join(os.path.dirname(__file__), "..", "src")
    proc = subprocess.run(
        [sys.executable, "-c", _EQUIV_RING],
        capture_output=True, text=True, env=env, timeout=900,
    )
    assert proc.returncode == 0, proc.stderr[-3000:]
    line = [ln for ln in proc.stdout.splitlines() if ln.startswith("RESULT:")][-1]
    out = json.loads(line[len("RESULT:"):])
    # every mask x GQA x impl ring combination ran and matched
    for tag in ("gqa", "hostile"):
        for impl in ("interpret", "xla", "ref"):
            for c, w in ((1, 0), (1, 9), (0, 0), (0, 9)):
                assert f"ring_{tag}[{impl}]w{w}c{c}" in out["ok"], (tag, impl)
    assert set(out["batch"]) == {"flash", "decode", "linattn"}
    assert {"ring_lse_o", "ring_lse", "ring_scan_carry_ys",
            "ring_scan_carry_final"} <= set(out["ok"])
