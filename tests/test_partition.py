"""Partitioning-layer tests: PartitionRule resolution (device-free, via
partition.MeshSpec), sharded-vs-single-device numerical equivalence for every
partitioned op (subprocess with 8 forced host devices, like
test_distribution.py), halo-exchange correctness at block boundaries,
replication fallback on indivisible shapes, and the host_device_mesh
graceful-degradation contract."""
import json
import os
import subprocess
import sys
import textwrap

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.kernels import ops, partition, registry
from repro.launch import roofline


@pytest.fixture(autouse=True)
def _clean_registry_state():
    yield
    registry.set_default_impl(None)
    registry.clear_block_overrides()


S = jax.ShapeDtypeStruct
MESH8 = partition.MeshSpec({"data": 2, "model": 4})


# ---------------------------------------------------------------------------
# Rule resolution (no devices needed: plans resolve from shapes alone)
# ---------------------------------------------------------------------------


def test_every_block_table_op_has_a_partition_rule():
    assert set(partition.partitioned_ops()) == set(registry._BLOCK_DEFAULTS)


def test_partition_axis_prefers_model():
    assert partition.partition_axis(MESH8) == "model"
    assert partition.partition_axis(partition.MeshSpec({"pod": 2, "x": 4})) == "x"


def test_gemm_rule_k_shard_then_m_shard_then_replicate():
    f32 = jnp.float32
    plan = partition.plan_for("gemm", MESH8, S((32, 64), f32), S((64, 16), f32))
    assert plan.axis == "model" and plan.n == 4
    assert "k-sharded" in plan.note
    assert plan.collectives[0].kind == "all_reduce"
    assert plan.collectives[0].nbytes == 32 * 16 * 4  # fp32 accum partials
    # K=61 resists, M=32 divides: degrade to row sharding, no collective
    plan = partition.plan_for("gemm", MESH8, S((32, 61), f32), S((61, 16), f32))
    assert "m-row-sharded" in plan.note and plan.collectives == ()
    # nothing divides: replicate
    assert partition.plan_for(
        "gemm", MESH8, S((30, 61), f32), S((61, 16), f32)) is None


def test_attention_rules_are_gqa_aware():
    f32 = jnp.float32
    q, kv = S((2, 8, 32, 16), f32), S((2, 4, 32, 16), f32)
    plan = partition.plan_for("flash_attention", MESH8, q, kv, kv)
    assert plan is not None and "head-sharded" in plan.note
    # 20 q heads but 5 kv heads on a 4-way axis: replicate, never split a
    # GQA group across devices (the paper's TP-hostile head counts)
    q5, kv5 = S((2, 20, 32, 16), f32), S((2, 5, 32, 16), f32)
    assert partition.plan_for("flash_attention", MESH8, q5, kv5, kv5) is None
    pos = S((2,), jnp.int32)
    assert partition.plan_for(
        "decode_attention", MESH8, S((2, 8, 16), f32), kv, kv, pos
    ) is not None
    assert partition.plan_for(
        "decode_attention", MESH8, S((2, 20, 16), f32), kv5, kv5, pos
    ) is None


def test_linear_attention_rule_head_divisibility():
    f32 = jnp.float32
    ok = tuple(S((1, 8, 64, 8), f32) for _ in range(4))
    assert partition.plan_for("linear_attention", MESH8, *ok) is not None
    bad = tuple(S((1, 6, 64, 8), f32) for _ in range(4))
    assert partition.plan_for("linear_attention", MESH8, *bad) is None


def test_sparse_rules_row_and_tile_divisibility():
    f32, i32 = jnp.float32, jnp.int32
    assert partition.plan_for(
        "spmm", MESH8, S((64, 8), f32), S((64, 8), i32), S((32, 4), f32)
    ) is not None
    assert partition.plan_for(
        "spmm", MESH8, S((62, 8), f32), S((62, 8), i32), S((32, 4), f32)
    ) is None
    plan = partition.plan_for(
        "bsr_spmm", MESH8, S((8, 8, 128), f32), S((8,), i32), S((8,), i32),
        S((256, 16), f32), num_rows=64,
    )
    assert plan is not None and plan.collectives[0].kind == "all_reduce"
    assert partition.plan_for(
        "bsr_spmm", MESH8, S((6, 8, 128), f32), S((6,), i32), S((6,), i32),
        S((256, 16), f32), num_rows=64,
    ) is None


def test_stencil_rule_halo_metadata():
    f32 = jnp.float32
    offs = np.array([(-2, 0, 0), (0, 0, 0), (1, 0, 0)], np.int32)
    w = np.ones((3,), np.float32)
    plan = partition.plan_for(
        "stencil", MESH8, S((16, 8, 8), f32), offsets=offs, weights=w
    )
    assert "halo h=2" in plan.note
    # two boundary-plane permutes of h*Y*Z fp32 each
    assert [c.kind for c in plan.collectives] == ["permute", "permute"]
    assert all(c.nbytes == 2 * 8 * 8 * 4 for c in plan.collectives)
    # halo wider than a slab (|dx|=5 > 16/4): replicate, never multi-hop
    wide = np.array([(-5, 0, 0), (0, 0, 0)], np.int32)
    assert partition.plan_for(
        "stencil", MESH8, S((16, 8, 8), f32),
        offsets=wide, weights=np.ones((2,), np.float32),
    ) is None
    # X itself indivisible
    assert partition.plan_for(
        "stencil", MESH8, S((18, 8, 8), f32), offsets=offs, weights=w
    ) is None


def test_plan_costing_feeds_roofline_d2d_term():
    f32 = jnp.float32
    plan = partition.plan_for(
        "gemm", MESH8, S((1024, 4096), f32), S((4096, 1024), f32))
    d2d = roofline.plan_collective_seconds(plan)
    assert d2d > 0.0
    assert roofline.op_collective_seconds(
        "gemm", MESH8, S((1024, 4096), f32), S((4096, 1024), f32)) == d2d
    # replicated ops move no D2D bytes
    assert roofline.op_collective_seconds(
        "gemm", MESH8, S((30, 61), f32), S((61, 16), f32)) == 0.0
    terms = roofline.roofline_terms(1e6, 1e6, 0.0, d2d_s=d2d)
    assert terms["d2d_s"] == d2d and "dominant" in terms
    # the d2d term participates in dominance
    big = roofline.roofline_terms(1.0, 1.0, 0.0, d2d_s=1e9)
    assert big["dominant"] == "d2d_s"


def test_meshspec_plans_but_does_not_execute(rng):
    a = jnp.asarray(rng.standard_normal((32, 64)), jnp.float32)
    b = jnp.asarray(rng.standard_normal((64, 16)), jnp.float32)
    with pytest.raises(TypeError, match="needs a device mesh"):
        partition.sharded_call("gemm", MESH8, a, b)


def test_single_axis_mesh_replicates(rng):
    a = jnp.asarray(rng.standard_normal((8, 8)), jnp.float32)
    trivial = partition.MeshSpec({"data": 1, "model": 1})
    assert partition.plan_for("gemm", trivial, a, a) is None
    # and ops.* still runs (plain kernel_call fallback) via the mesh kwarg
    got = ops.gemm(a, a, mesh=trivial, impl="ref", out_dtype=jnp.float32)
    np.testing.assert_allclose(np.asarray(got), np.asarray(a @ a),
                               rtol=1e-5, atol=1e-5)


def test_dryrun_op_roofline_cells():
    from repro.launch import dryrun

    cells = dryrun.op_roofline_cells(multi_pod=False)
    assert {c["op"] for c in cells} == set(partition.partitioned_ops())
    for c in cells:
        assert c["partition"] != "replicated", c["op"]
        assert c["roofline"]["dominant"] in (
            "compute_s", "memory_s", "collective_s", "d2d_s")
    by_op = {c["op"]: c for c in cells}
    # the split-K gemm and the tile-sharded bsr carry psum D2D bytes
    assert by_op["gemm"]["d2d_bytes"] > 0
    assert by_op["bsr_spmm"]["d2d_bytes"] > 0
    assert by_op["stencil"]["d2d_bytes"] > 0  # halo planes


# ---------------------------------------------------------------------------
# decode_attention: the blocked xla impl (single device)
# ---------------------------------------------------------------------------


@pytest.mark.parametrize("window", [0, 7])
def test_decode_attention_blocked_xla_matches_ref(rng, window):
    q = jnp.asarray(rng.standard_normal((2, 8, 16)), jnp.float32)
    k = jnp.asarray(rng.standard_normal((2, 4, 50, 16)), jnp.float32)
    v = jnp.asarray(rng.standard_normal((2, 4, 50, 16)), jnp.float32)
    pos = jnp.asarray([5, 49], jnp.int32)
    want = ops.decode_attention(q, k, v, pos, impl="ref", window=window)
    for bs in (8, 16, 64):  # 64 > S exercises the clamp
        got = ops.decode_attention(q, k, v, pos, impl="xla", window=window,
                                   bs=bs)
        np.testing.assert_allclose(np.asarray(got), np.asarray(want),
                                   rtol=1e-4, atol=1e-4)


def test_decode_attention_unrolled_matches_scan(rng):
    q = jnp.asarray(rng.standard_normal((1, 4, 8)), jnp.float32)
    k = jnp.asarray(rng.standard_normal((1, 2, 33, 8)), jnp.float32)
    v = jnp.asarray(rng.standard_normal((1, 2, 33, 8)), jnp.float32)
    pos = jnp.asarray([30], jnp.int32)
    want = ops.decode_attention(q, k, v, pos, impl="xla", bs=8)
    with registry.unroll_inner():
        got = ops.decode_attention(q, k, v, pos, impl="xla", bs=8)
    np.testing.assert_allclose(np.asarray(got), np.asarray(want),
                               rtol=1e-5, atol=1e-5)


def test_decode_attention_override_reaches_xla_impl(rng, monkeypatch):
    import repro.kernels.xla as xla_mod

    q = jnp.asarray(rng.standard_normal((1, 4, 8)), jnp.float32)
    k = jnp.asarray(rng.standard_normal((1, 2, 32, 8)), jnp.float32)
    pos = jnp.asarray([31], jnp.int32)
    captured = {}
    orig = xla_mod.decode_attention_xla

    def spy(*a, **kw):
        captured["bs"] = kw.get("bs")
        return orig(*a, **kw)

    monkeypatch.setattr(xla_mod, "decode_attention_xla", spy)
    registry.set_block_override("decode_attention", bs=16)
    ops.decode_attention(q, k, k, pos, impl="xla")
    assert captured["bs"] == 16
    ops.decode_attention(q, k, k, pos, impl="xla", bs=8)  # explicit wins
    assert captured["bs"] == 8


# ---------------------------------------------------------------------------
# host_device_mesh graceful degradation (single device is enough)
# ---------------------------------------------------------------------------


def test_host_device_mesh_degrades_with_warning():
    from repro.launch.mesh import host_device_mesh

    n = len(jax.devices())
    with pytest.warns(UserWarning, match="degrading to tp="):
        mesh = host_device_mesh(tp=n + 3)  # cannot divide; 1 always fits
    assert mesh.shape["model"] <= n
    assert mesh.shape["data"] * mesh.shape["model"] == n


def test_use_mesh_does_not_leak_into_model_mesh():
    """use_mesh keys kernels only: current_mesh() — which the model-level
    shard_map paths (moe dispatch, ssm halo shift) read — must stay None, or
    a kernel-only mesh context would silently re-route model internals."""
    from repro.parallel import sharding as sh

    fake = object()  # plans never dereference devices, a sentinel suffices
    with sh.use_mesh(fake):
        assert sh.kernel_mesh() is fake
        assert sh.current_mesh() is None
    assert sh.kernel_mesh() is None


def test_autotune_suite_covers_every_block_table_op():
    """PR 2's invariant, kept: every op the registry advertises as tunable
    has an autotune case (decode_attention included)."""
    from repro.launch import autotune as at

    assert set(at.DEFAULT_SUITE) == set(registry._BLOCK_DEFAULTS)
    # the decode feasibility probe scales with bs and respects clamping
    case = at.DEFAULT_SUITE["decode_attention"](np.random.default_rng(0))
    small = case.program({"bs": 128}).vmem_bytes()
    big = case.program({"bs": 1024}).vmem_bytes()
    assert small < big
    assert case.program({"bs": 4096}).vmem_bytes() == big  # clamped to S


def test_host_device_mesh_rejects_invalid_tp():
    from repro.launch.mesh import host_device_mesh

    with pytest.raises(ValueError, match="not a valid model-axis size"):
        host_device_mesh(tp=0)
    mesh = host_device_mesh(tp=1)  # exact fit: no warning path
    assert mesh.shape["model"] == 1


# ---------------------------------------------------------------------------
# Sharded execution: numerical equivalence on 8 forced host devices
# (subprocess so the device-count flag never leaks into this process)
# ---------------------------------------------------------------------------

_EQUIV = textwrap.dedent(
    """
    import os
    os.environ["XLA_FLAGS"] = "--xla_force_host_platform_device_count=8"
    import json
    import jax, jax.numpy as jnp, numpy as np
    from repro.core import sparse as sp
    from repro.kernels import ops, partition
    from repro.models import gcn
    from repro.parallel import sharding as sh

    mesh = jax.make_mesh((2, 4), ("data", "model"))
    rng = np.random.default_rng(0)
    f32 = jnp.float32
    out = {"ok": [], "fallbacks": []}

    def check(name, got, want, tol=1e-4):
        pairs = zip(got, want) if isinstance(got, tuple) else [(got, want)]
        err = max(float(jnp.max(jnp.abs(jnp.asarray(g) - jnp.asarray(w))))
                  for g, w in pairs)
        assert err < tol, (name, err)
        out["ok"].append(name)

    a = jnp.asarray(rng.standard_normal((32, 64)), f32)
    b = jnp.asarray(rng.standard_normal((64, 32)), f32)
    q = jnp.asarray(rng.standard_normal((2, 8, 32, 16)), f32)
    kv = jnp.asarray(rng.standard_normal((2, 4, 32, 16)), f32)
    qd = jnp.asarray(rng.standard_normal((2, 8, 16)), f32)
    pos = jnp.asarray([5, 30], jnp.int32)
    r = jnp.asarray(rng.standard_normal((1, 4, 64, 8)), f32)
    wl = jnp.asarray(-rng.uniform(0.01, 1.0, (1, 4, 64, 8)), f32)
    u = jnp.asarray(rng.standard_normal((4, 8)), f32)
    ell = sp.random_ell(rng, 64, 32, 0.1)
    dn = jnp.asarray(rng.standard_normal((32, 8)), f32)
    bsr_dense = np.zeros((16, 256), np.float32)
    bsr_dense[::3, ::17] = 1.0
    bsrA = sp.dense_to_bsr(bsr_dense, bm=8, bk=128)
    brhs = jnp.asarray(rng.standard_normal((256, 16)), f32)
    sA, sB = sp.random_ell(rng, 32, 64, 0.1), sp.random_ell(rng, 64, 64, 0.1)
    grid = jnp.asarray(rng.standard_normal((16, 8, 8)), f32)
    # offsets reach ACROSS slab boundaries (|dx|=2 on 4-plane slabs): the
    # halo-exchange correctness case, incl. the periodic wrap at the ends
    offs = np.array([(-2, 0, 0), (0, 0, 0), (1, 1, 0), (2, 0, 1)], np.int32)
    w = np.array([0.2, 0.3, 0.4, 0.1], np.float32)

    # decode_attention's stream impls are the ref form, so all four impl
    # names run on CPU for it; stream ops cover interpret/xla/ref (the
    # pallas entry is the same StreamProgram, compiled)
    for impl in ("interpret", "xla", "ref"):
        check(f"gemm[{impl}]",
              ops.gemm(a, b, mesh=mesh, impl=impl, out_dtype=f32),
              ops.gemm(a, b, impl="ref", out_dtype=f32))
        check(f"flash[{impl}]",
              ops.flash_attention(q, kv, kv, mesh=mesh, impl=impl),
              ops.flash_attention(q, kv, kv, impl="ref"))
        check(f"linattn_rwkv[{impl}]",
              ops.linear_attention(r, r, r, wl, u, mesh=mesh, impl=impl),
              ops.linear_attention(r, r, r, wl, u, impl="ref"))
        check(f"linattn_ssd[{impl}]",
              ops.linear_attention(r, r, r, wl, mesh=mesh, impl=impl),
              ops.linear_attention(r, r, r, wl, impl="ref"))
        check(f"spmm[{impl}]", ops.spmm(ell, dn, mesh=mesh, impl=impl),
              ops.spmm(ell, dn, impl="ref"))
        check(f"bsr_spmm[{impl}]",
              ops.bsr_spmm(bsrA, brhs, mesh=mesh, impl=impl),
              ops.bsr_spmm(bsrA, brhs, impl="xla"))
        check(f"spmspm[{impl}]",
              ops.spmspm(sA, sB, 64, mesh=mesh, impl=impl),
              ops.spmspm(sA, sB, 64, impl="ref"))
        check(f"stencil[{impl}]",
              ops.stencil(grid, offs, w, mesh=mesh, impl=impl),
              ops.stencil(grid, offs, w, impl="ref"))
    for impl in ("pallas", "interpret", "xla", "ref"):
        check(f"decode[{impl}]",
              ops.decode_attention(qd, kv, kv, pos, mesh=mesh, impl=impl),
              ops.decode_attention(qd, kv, kv, pos, impl="ref"))

    # gemm k-shard must preserve an explicit narrower out_dtype
    got16 = ops.gemm(a, b, mesh=mesh, impl="xla", out_dtype=jnp.bfloat16)
    assert got16.dtype == jnp.bfloat16
    out["ok"].append("gemm[out_dtype]")

    # replication fallback on indivisible shapes: same signature, same answer
    q5 = jnp.asarray(rng.standard_normal((2, 5, 16, 8)), f32)
    check("fallback_flash",
          ops.flash_attention(q5, q5, q5, mesh=mesh, impl="xla"),
          ops.flash_attention(q5, q5, q5, impl="ref"))
    ell62 = sp.random_ell(rng, 62, 32, 0.1)
    check("fallback_spmm", ops.spmm(ell62, dn, mesh=mesh, impl="xla"),
          ops.spmm(ell62, dn, impl="ref"))
    for name, args in (("flash", (q5, q5, q5)), ("spmm",
                       (ell62.values, ell62.cols, dn))):
        op = "flash_attention" if name == "flash" else "spmm"
        assert partition.plan_for(op, mesh, *args) is None
        out["fallbacks"].append(name)

    # halo exchange at every slab width that divides X=16
    for tp in (2, 4, 8):
        m2 = jax.make_mesh((8 // tp, tp), ("data", "model"))
        check(f"stencil_halo_tp{tp}",
              ops.stencil(grid, offs, w, mesh=m2, impl="interpret"),
              ops.stencil(grid, offs, w, impl="ref"))

    # row-sharded GCN end to end (explicit mesh kwarg AND use_mesh context)
    feats = jnp.asarray(rng.standard_normal((64, 16)), f32)
    params = gcn.init_params(jax.random.PRNGKey(0), [16, 32, 8])
    adj = sp.random_ell(rng, 64, 64, 0.05)
    want = gcn.forward(params, adj, feats)
    check("gcn_mesh_kwarg",
          jax.jit(lambda p, a_, f_: gcn.forward(p, a_, f_, mesh=mesh))(
              params, adj, feats), want)
    with sh.use_mesh(mesh):
        check("gcn_use_mesh", gcn.forward(params, adj, feats), want)
    assert sh.kernel_mesh() is None  # context restored
    print("RESULT:" + json.dumps(out))
    """
)


def test_sharded_equivalence_all_ops():
    env = dict(os.environ)
    env["PYTHONPATH"] = os.path.join(os.path.dirname(__file__), "..", "src")
    proc = subprocess.run(
        [sys.executable, "-c", _EQUIV],
        capture_output=True, text=True, env=env, timeout=900,
    )
    assert proc.returncode == 0, proc.stderr[-3000:]
    line = [l for l in proc.stdout.splitlines() if l.startswith("RESULT:")][-1]
    out = json.loads(line[len("RESULT:"):])
    # every partitioned op x impl combination ran and matched
    for op_tag in ("gemm", "flash", "linattn_rwkv", "linattn_ssd", "spmm",
                   "bsr_spmm", "spmspm", "stencil"):
        for impl in ("interpret", "xla", "ref"):
            assert f"{op_tag}[{impl}]" in out["ok"], (op_tag, impl)
    for impl in ("pallas", "interpret", "xla", "ref"):
        assert f"decode[{impl}]" in out["ok"]
    assert set(out["fallbacks"]) == {"flash", "spmm"}
    assert {"stencil_halo_tp2", "stencil_halo_tp4", "stencil_halo_tp8",
            "gcn_mesh_kwarg", "gcn_use_mesh"} <= set(out["ok"])
