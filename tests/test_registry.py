"""Dispatch-layer and formats-layer tests: kernel registry resolution,
pytree sparse formats, and the StreamProgram substrate metadata."""
import os

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.core import sparse as sp
from repro.core import streams
from repro.kernels import ops, registry


@pytest.fixture(autouse=True)
def _clean_registry_state():
    yield
    registry.set_default_impl(None)
    registry.clear_block_overrides()


# ---------------------------------------------------------------------------
# Registry: errors and resolution precedence
# ---------------------------------------------------------------------------


def test_unknown_op_raises():
    with pytest.raises(KeyError, match="unknown kernel op"):
        registry.kernel_call("not_an_op", 1, 2)


def test_unknown_impl_raises():
    with pytest.raises(ValueError, match="unknown impl"):
        registry.kernel_call("gemm", None, None, impl="cuda")
    with pytest.raises(ValueError, match="unknown impl"):
        registry.set_default_impl("cuda")


def test_register_rejects_auto():
    with pytest.raises(ValueError):
        registry.register_kernel("gemm", impl="auto")


def test_all_ops_registered_with_all_impls():
    for op in ("gemm", "flash_attention", "linear_attention", "spmm",
               "bsr_spmm", "spmspm", "stencil", "decode_attention"):
        assert registry.implementations(op) == [
            "interpret", "pallas", "ref", "xla"
        ], op


def test_impl_precedence_env_default_arg(monkeypatch):
    probe = "_test_precedence_probe"
    for impl in ("pallas", "interpret", "xla", "ref"):
        registry.register_kernel(probe, impl=impl)(lambda _i=impl: _i)
    try:
        # no signal at all: auto => xla on CPU
        monkeypatch.delenv("REPRO_KERNEL_IMPL", raising=False)
        assert registry.kernel_call(probe) == "xla"
        # env var beats auto
        monkeypatch.setenv("REPRO_KERNEL_IMPL", "ref")
        assert registry.kernel_call(probe) == "ref"
        # set_default_impl beats the env var
        registry.set_default_impl("interpret")
        assert registry.kernel_call(probe) == "interpret"
        # explicit argument beats everything
        assert registry.kernel_call(probe, impl="pallas") == "pallas"
    finally:
        registry._REGISTRY.pop(probe, None)  # don't leak the probe op


def test_block_override_table():
    assert registry.block_defaults("gemm")["bm"] == 256
    registry.set_block_override("gemm", bm=128)
    assert registry.block_defaults("gemm")["bm"] == 128
    assert registry.block_defaults("gemm")["bn"] == 256  # untouched
    registry.clear_block_overrides("gemm")
    assert registry.block_defaults("gemm")["bm"] == 256
    with pytest.raises(ValueError, match="no block parameters"):
        registry.set_block_override("gemm", bogus=1)
    with pytest.raises(KeyError, match="no block-size table"):
        registry.set_block_override("gem", bm=512)  # typo'd op: loud, not a no-op


def test_linear_attention_chunk_overflow_guard(rng):
    r = jnp.asarray(rng.standard_normal((1, 1, 64, 4)), jnp.float32)
    wl = jnp.zeros((1, 1, 64, 4), jnp.float32)
    with pytest.raises(ValueError, match="overflows fp32"):
        ops.linear_attention(r, r, r, wl, impl="xla", chunk=64)
    registry.set_block_override("linear_attention", chunk=64)
    with pytest.raises(ValueError, match="overflows fp32"):
        ops.linear_attention(r, r, r, wl, impl="xla")
    # ref runs the exact scan: chunk is irrelevant, so no guard
    o, _ = ops.linear_attention(r, r, r, wl, impl="ref", chunk=64)
    assert bool(jnp.all(jnp.isfinite(o)))


def test_block_override_feeds_kernels(rng):
    a = jnp.asarray(rng.standard_normal((64, 64)), jnp.float32)
    b = jnp.asarray(rng.standard_normal((64, 64)), jnp.float32)
    want = np.asarray(a @ b)
    registry.set_block_override("gemm", bm=32, bk=32, bn=32)
    got = ops.gemm(a, b, impl="interpret", out_dtype=jnp.float32)
    np.testing.assert_allclose(np.asarray(got), want, rtol=1e-5, atol=1e-5)


def test_resolve_blocks_precedence():
    # default layer
    assert registry.resolve_blocks("gemm")["bm"] == 256
    # override layer beats default
    registry.set_block_override("gemm", bm=128)
    assert registry.resolve_blocks("gemm")["bm"] == 128
    # explicit kwarg beats override; None falls through
    resolved = registry.resolve_blocks("gemm", bm=64, bk=None)
    assert resolved == {"bm": 64, "bk": 256, "bn": 256}
    with pytest.raises(ValueError, match="no block parameters"):
        registry.resolve_blocks("gemm", bogus=1)
    with pytest.raises(KeyError, match="no block-size table"):
        registry.resolve_blocks("gem")


def test_block_override_scoped_context():
    registry.set_block_override("gemm", bm=128)
    with registry.block_override("gemm", bm=32, bk=32):
        assert registry.block_defaults("gemm")["bm"] == 32
        assert registry.block_defaults("gemm")["bk"] == 32
    # prior override restored exactly, including the untouched key
    assert registry.block_defaults("gemm") == {"bm": 128, "bk": 256, "bn": 256}
    registry.clear_block_overrides("gemm")
    with registry.block_override("gemm", bm=32):
        pass
    assert registry.block_defaults("gemm")["bm"] == 256  # no leak


def _observed_grid(monkeypatch, module_name, call):
    """Run ``call`` with the kernel module's stream_compute spied on and
    return the StreamProgram grid that actually executed."""
    import importlib

    from repro.core import streams

    mod = importlib.import_module(module_name)
    captured = {}
    orig = streams.stream_compute

    def spy(program, *operands, **kw):
        captured["grid"] = program.grid
        return orig(program, *operands, **kw)

    monkeypatch.setattr(mod, "stream_compute", spy)
    call()
    return captured["grid"]


def _geometry_cases(rng):
    """(op, kernel module, override, expected grid, call) for every op in the
    block table: the override must change the actually-executed geometry."""
    f32 = jnp.float32
    a = jnp.asarray(rng.standard_normal((64, 64)), f32)
    qkv = [jnp.asarray(rng.standard_normal((1, 2, 64, 8)), f32)
           for _ in range(3)]
    rkvw = [jnp.asarray(rng.standard_normal((1, 1, 64, 8)), f32)
            for _ in range(3)] + [
        jnp.asarray(-rng.uniform(0.01, 1.0, (1, 1, 64, 8)), f32)]
    ellA = sp.random_ell(rng, 64, 32, 0.1)
    spd = jnp.asarray(rng.standard_normal((32, 8)), f32)
    bsr_dense = np.zeros((16, 256), np.float32)
    bsr_dense[::3, ::17] = 1.0
    bsrA = sp.dense_to_bsr(bsr_dense, bm=8, bk=128)
    bsr_rhs = jnp.asarray(rng.standard_normal((256, 64)), f32)
    iA, iB = sp.random_ell(rng, 32, 64, 0.1), sp.random_ell(rng, 64, 64, 0.1)
    grid3 = jnp.asarray(rng.standard_normal((16, 8, 8)), f32)
    offs = np.array([(0, 0, 0), (1, 0, 0)], np.int32)
    w = np.array([0.5, 0.5], np.float32)
    T = len(bsrA.tile_rows)
    return [
        ("gemm", "repro.kernels.gemm", dict(bm=16, bk=32, bn=16), (4, 4, 2),
         lambda: ops.gemm(a, a, impl="interpret")),
        ("flash_attention", "repro.kernels.flash_attention",
         dict(bq=16, bk=32), (1, 2, 4, 2),
         lambda: ops.flash_attention(*qkv, impl="interpret")),
        ("linear_attention", "repro.kernels.rwkv6", dict(chunk=16), (1, 4),
         lambda: ops.linear_attention(*rkvw, impl="interpret")),
        ("spmm", "repro.kernels.spmm", dict(bm=16), (4,),
         lambda: ops.spmm(ellA, spd, impl="interpret")),
        ("bsr_spmm", "repro.kernels.spmm", dict(bf=32), (2, T),
         lambda: ops.bsr_spmm(bsrA, bsr_rhs, impl="interpret")),
        ("spmspm", "repro.kernels.spmspm", dict(bm=16, bn=32), (2, 2),
         lambda: ops.spmspm(iA, iB, 64, impl="interpret")),
        ("stencil", "repro.kernels.stencil", dict(bx=4), (4,),
         lambda: ops.stencil(grid3, offs, w, impl="interpret")),
    ]


def test_block_override_changes_geometry_for_every_op(rng, monkeypatch):
    cases = _geometry_cases(rng)
    # decode_attention is xla-blocked, not stream-programmed: its override
    # path is covered by test_partition.test_decode_attention_override_reaches_xla_impl
    assert {c[0] for c in cases} == set(registry._BLOCK_DEFAULTS) - {
        "decode_attention"
    }
    for op, module, override, want_grid, call in cases:
        registry.clear_block_overrides()
        registry.set_block_override(op, **override)
        got = _observed_grid(monkeypatch, module, call)
        assert got == want_grid, (op, got, want_grid)


def test_flash_attention_override_reaches_xla_impl(rng, monkeypatch):
    """Split-brain regression: set_block_override and an explicit arg must
    reach the xla impl identically (the old ops.py block_k=512 literal only
    reached xla, and pallas silently ignored block_k=)."""
    import repro.kernels.xla as xla_mod

    q = jnp.asarray(rng.standard_normal((1, 2, 64, 8)), jnp.float32)
    captured = {}
    orig = xla_mod.flash_attention_xla

    def spy(*a, **kw):
        captured["bk"] = kw.get("bk")
        return orig(*a, **kw)

    monkeypatch.setattr(xla_mod, "flash_attention_xla", spy)
    registry.set_block_override("flash_attention", bk=16)
    ops.flash_attention(q, q, q, impl="xla")
    assert captured["bk"] == 16
    ops.flash_attention(q, q, q, impl="xla", bk=32)  # explicit beats override
    assert captured["bk"] == 32
    ops.flash_attention(q, q, q, impl="xla", block_k=8)  # historical alias
    assert captured["bk"] == 8
    with pytest.raises(TypeError, match="disagree"):
        ops.flash_attention(q, q, q, impl="xla", bk=8, block_k=16)


def test_flash_attention_explicit_bk_same_result_across_impls(rng):
    q = jnp.asarray(rng.standard_normal((1, 2, 64, 8)), jnp.float32)
    want = ops.flash_attention(q, q, q, impl="ref")
    got_xla = ops.flash_attention(q, q, q, impl="xla", bk=16)
    got_int = ops.flash_attention(q, q, q, impl="interpret", bk=16)
    np.testing.assert_allclose(np.asarray(got_xla), np.asarray(want),
                               rtol=1e-4, atol=1e-4)
    np.testing.assert_allclose(np.asarray(got_int), np.asarray(want),
                               rtol=1e-4, atol=1e-4)


def test_block_resolution_single_path():
    """Single-path invariant, now owned by the static checker: ops.py
    carries no block-size literals, every block-tabled op resolves through
    registry.resolve_blocks, and no kernel impl module keeps private
    block_defaults plumbing or an environment escape hatch (the
    REPRO_UNROLL_GRID regression). Positive coverage — proof the rules
    actually fire — lives in tests/test_analysis.py."""
    from repro.analysis import run_rules

    findings = run_rules(
        ["block-geometry-registry-only", "no-environ-in-kernels"]
    )
    assert findings == [], "\n".join(f.format() for f in findings)


def test_unrolled_flash_blocks_route_through_registry(rng):
    """The unrolled (roofline) flash path honours set_block_override and
    explicit bq/bk exactly like the scan path — no private geometry."""
    import repro.kernels.xla as xla_mod

    q = jnp.asarray(rng.standard_normal((1, 2, 64, 8)), jnp.float32)
    want = ops.flash_attention(q, q, q, impl="ref")
    with registry.unroll_inner():
        registry.set_block_override("flash_attention", bq=16, bk=32)
        got = ops.flash_attention(q, q, q, impl="xla")
        np.testing.assert_allclose(np.asarray(got), np.asarray(want),
                                   rtol=1e-4, atol=1e-4)
        # explicit kwarg beats the override, same as every other impl
        got = ops.flash_attention(q, q, q, impl="xla", bq=8, bk=8)
        np.testing.assert_allclose(np.asarray(got), np.asarray(want),
                                   rtol=1e-4, atol=1e-4)
        # geometry actually reached the unrolled loop: a bq that doesn't
        # divide Sq exercises its padding path
        registry.set_block_override("flash_attention", bq=48, bk=48)
        got = ops.flash_attention(q, q, q, impl="xla")
        np.testing.assert_allclose(np.asarray(got), np.asarray(want),
                                   rtol=1e-4, atol=1e-4)


def test_default_impl_context_manager():
    assert registry.resolve_impl(None) in ("pallas", "xla")  # auto
    with registry.default_impl("ref"):
        assert registry.resolve_impl(None) == "ref"
        with registry.default_impl("interpret"):
            assert registry.resolve_impl(None) == "interpret"
        assert registry.resolve_impl(None) == "ref"
    assert registry.resolve_impl(None) in ("pallas", "xla")  # restored
    with pytest.raises(ValueError, match="unknown impl"):
        with registry.default_impl("cuda"):
            pass
    # a raise inside the scope still restores
    with pytest.raises(RuntimeError):
        with registry.default_impl("ref"):
            raise RuntimeError
    assert registry.resolve_impl(None) in ("pallas", "xla")


# ---------------------------------------------------------------------------
# Formats: pytree round trips (including all-zero rows)
# ---------------------------------------------------------------------------


def _random_dense(rng, r, c, density, zero_rows=()):
    dense = np.zeros((r, c), np.float32)
    mask = rng.random((r, c)) < density
    dense[mask] = rng.standard_normal(mask.sum())
    for zr in zero_rows:
        dense[zr] = 0.0
    return dense


@pytest.mark.parametrize("zero_rows", [(), (0, 3, 7)])
def test_dense_roundtrip_all_formats(rng, zero_rows):
    dense = _random_dense(rng, 16, 256, 0.05, zero_rows)
    for convert in (sp.dense_to_ell, sp.dense_to_csr,
                    lambda d: sp.dense_to_bsr(d, bm=8, bk=128)):
        A = convert(dense)
        np.testing.assert_allclose(np.asarray(A.todense()), dense, err_msg=str(convert))


def test_conversion_path_csr_ell_bsr(rng):
    # rows 0-7 all zero: the whole first 8-row block is empty, exercising the
    # empty-tile insertion in csr_to_bsr
    dense = _random_dense(rng, 16, 256, 0.04, zero_rows=tuple(range(8)) + (9,))
    ell = sp.dense_to_ell(dense)
    csr = sp.ell_to_csr(ell)
    np.testing.assert_allclose(np.asarray(csr.todense()), dense)
    ell2 = sp.csr_to_ell(csr)
    np.testing.assert_allclose(np.asarray(ell2.todense()), dense)
    bsr = sp.csr_to_bsr(csr, bm=8, bk=128)
    np.testing.assert_allclose(np.asarray(bsr.todense()), dense)
    np.testing.assert_allclose(np.asarray(sp.bsr_to_csr(bsr).todense()), dense)
    np.testing.assert_allclose(
        np.asarray(sp.bsr_to_ell(sp.ell_to_bsr(ell)).todense()), dense
    )


def test_ell_padding_never_contributes(rng):
    # padded slots alias column 0 with value 0: col 0's true value must
    # survive the aliased scatter-adds exactly
    dense = _random_dense(rng, 8, 64, 0.1)
    dense[:, 0] = 7.0  # every row has a real entry at the aliased column
    A = sp.dense_to_ell(dense, max_nnz=32)  # force padding slots
    got = np.asarray(A.todense())
    assert np.all(got[:, 0] == 7.0)
    np.testing.assert_allclose(got, dense)
    # the micro-assert itself: zeroing all padded slots changes nothing
    mask = np.asarray(A.values) != 0
    stripped = sp.EllMatrix(
        jnp.where(jnp.asarray(mask), A.values, 0.0), A.cols, A.shape
    )
    np.testing.assert_allclose(np.asarray(stripped.todense()), got)


def test_dense_to_ell_honors_wide_max_nnz(rng):
    dense = _random_dense(rng, 4, 8, 0.5)
    A = sp.dense_to_ell(dense, max_nnz=12)  # wider than the matrix itself
    assert A.values.shape == (4, 12) and A.cols.shape == (4, 12)
    np.testing.assert_allclose(np.asarray(A.todense()), dense)


def test_dense_to_ell_rejects_narrow_max_nnz():
    # row 1 has 5 nonzeros; a narrower max_nnz must be loud, never a silent
    # drop of the overflow entries
    dense = np.zeros((3, 8), np.float32)
    dense[1, :5] = 1.0
    dense[2, :2] = 1.0
    with pytest.raises(ValueError, match=r"row 1 has 5 nonzeros > max_nnz=3"):
        sp.dense_to_ell(dense, max_nnz=3)
    # exactly-fitting width still works
    np.testing.assert_allclose(
        np.asarray(sp.dense_to_ell(dense, max_nnz=5).todense()), dense
    )


def test_csr_to_ell_rejects_narrow_max_nnz():
    dense = np.zeros((4, 8), np.float32)
    dense[2, :6] = 2.0
    csr = sp.dense_to_csr(dense)
    with pytest.raises(ValueError, match=r"row 2 has 6 nonzeros > max_nnz=4"):
        sp.csr_to_ell(csr, max_nnz=4)
    np.testing.assert_allclose(
        np.asarray(sp.csr_to_ell(csr, max_nnz=6).todense()), dense
    )


def test_launchers_append_xla_flags(monkeypatch):
    """Regression: hillclimb (PR 2) and dryrun (this PR) used to clobber any
    caller-set XLA_FLAGS with a bare ``os.environ[...] = ...`` assignment.
    Both now route through launch.xla_flags.ensure_host_device_count."""
    import importlib

    import repro.launch.dryrun as dr
    import repro.launch.hillclimb as hc

    for mod in (hc, dr):
        monkeypatch.setenv("XLA_FLAGS", "--xla_dump_to=/tmp/x")
        importlib.reload(mod)
        flags = os.environ["XLA_FLAGS"].split()
        assert "--xla_dump_to=/tmp/x" in flags, mod.__name__
        assert "--xla_force_host_platform_device_count=512" in flags
        importlib.reload(mod)  # idempotent: appending twice adds nothing
        assert os.environ["XLA_FLAGS"].split().count(
            "--xla_force_host_platform_device_count=512"
        ) == 1
        # a caller-chosen device count survives (no conflicting append)
        monkeypatch.setenv(
            "XLA_FLAGS", "--xla_force_host_platform_device_count=8"
        )
        importlib.reload(mod)
        assert os.environ["XLA_FLAGS"] == \
            "--xla_force_host_platform_device_count=8", mod.__name__


def test_launchers_never_assign_xla_flags_directly():
    """XLA_FLAGS is only ever APPENDED via the shared bootstrap, never
    assigned a fresh literal (the clobber pattern that silently discarded
    user flags) — enforced tree-wide by the static checker's
    xla-flags-append-only rule; this wrapper keeps the invariant in the
    tier-1 suite."""
    import pathlib

    from repro.analysis import run_rules

    findings = run_rules(["xla-flags-append-only"])
    assert findings == [], "\n".join(f.format() for f in findings)
    # the one place that may write the variable is the append-only helper
    import repro.launch.xla_flags as xf

    helper = pathlib.Path(xf.__file__).read_text()
    assert "existing" in helper and "_DEVICE_FLAG" in helper


def test_formats_are_pytrees(rng):
    dense = _random_dense(rng, 16, 256, 0.05)
    ell = sp.dense_to_ell(dense)
    bsr = sp.dense_to_bsr(dense)
    csr = sp.dense_to_csr(dense)
    assert len(jax.tree_util.tree_leaves(ell)) == 2
    assert len(jax.tree_util.tree_leaves(bsr)) == 3
    assert len(jax.tree_util.tree_leaves(csr)) == 3
    # shape is static aux data: it survives flatten/unflatten
    flat, treedef = jax.tree_util.tree_flatten(ell)
    assert jax.tree_util.tree_unflatten(treedef, flat).shape == (16, 256)


def test_ell_jit_traces_without_densifying(rng):
    R, C, F = 24, 512, 8
    dense = _random_dense(rng, R, C, 0.02)
    A = sp.dense_to_ell(dense)
    D = jnp.asarray(rng.standard_normal((C, F)), jnp.float32)

    @jax.jit
    def agg(A, D):
        return ops.spmm(A, D, impl="ref")

    got = agg(A, D)
    np.testing.assert_allclose(
        np.asarray(got), dense @ np.asarray(D), rtol=1e-4, atol=1e-4
    )
    # keyword form of the overload behaves identically
    got_kw = ops.spmm(A, dense=D, impl="ref")
    np.testing.assert_allclose(np.asarray(got_kw), np.asarray(got),
                               rtol=1e-5, atol=1e-5)
    with pytest.raises(TypeError, match="required"):
        ops.spmm(A)
    # half-migrated old-style call: extra operands must be loud, not ignored
    with pytest.raises(TypeError, match="extra operand"):
        ops.spmm(A, A.cols, D)
    # no (R, C) dense adjacency anywhere in the traced program
    jaxpr = str(jax.make_jaxpr(lambda A, D: ops.spmm(A, D, impl="ref"))(A, D))
    assert f"{R},{C}" not in jaxpr


def test_bsr_jit_roundtrip(rng):
    dense = _random_dense(rng, 64, 256, 0.03)
    bsr = sp.dense_to_bsr(dense, bm=8, bk=128)
    D = jnp.asarray(rng.standard_normal((256, 16)), jnp.float32)
    got = jax.jit(lambda a, d: ops.bsr_spmm(a, d))(bsr, D)
    np.testing.assert_allclose(
        np.asarray(got), dense @ np.asarray(D), rtol=1e-4, atol=1e-4
    )
    # keyword form of the overload behaves identically
    got_kw = ops.bsr_spmm(bsr, dense=D, impl="xla")
    np.testing.assert_allclose(np.asarray(got_kw), np.asarray(got),
                               rtol=1e-5, atol=1e-5)
    with pytest.raises(TypeError, match="required"):
        ops.bsr_spmm(bsr)
    with pytest.raises(TypeError, match="extra operands"):
        ops.bsr_spmm(bsr, bsr.tile_rows, bsr.tile_cols, D, 64)


def test_spmspm_accepts_ell_operands(rng):
    A = sp.random_ell(rng, 32, 128, 0.1)
    B = sp.random_ell(rng, 48, 128, 0.1)
    from repro.kernels import ref

    want = ref.spmspm_ref(A.values, A.cols, B.values, B.cols, 128)
    got = jax.jit(lambda a, b: ops.spmspm(a, b, 128))(A, B)
    np.testing.assert_allclose(np.asarray(got), np.asarray(want),
                               rtol=1e-4, atol=1e-4)
    # keyword form of the overload must behave identically
    got_kw = ops.spmspm(A, B, contraction_dim=128, impl="xla")
    np.testing.assert_allclose(np.asarray(got_kw), np.asarray(want),
                               rtol=1e-4, atol=1e-4)
    with pytest.raises(TypeError, match="contraction_dim"):
        ops.spmspm(A, B)
    with pytest.raises(TypeError, match="extra operands"):
        ops.spmspm(A, B, 128, contraction_dim=128)
    with pytest.raises(TypeError, match="must also be an EllMatrix"):
        ops.spmspm(A, B.values, 128)


# ---------------------------------------------------------------------------
# Streams: program metadata
# ---------------------------------------------------------------------------


def test_stream_program_metadata():
    from repro.kernels.gemm import gemm_program

    prog = gemm_program(
        256, 256, 256, 128, 128, 128,
        a_dtype=jnp.bfloat16, b_dtype=jnp.float32,
        out_dtype=jnp.float32, accum_dtype=jnp.float32,
    )
    assert prog.steps == 2 * 2 * 2
    # per step: one bf16 A tile, one f32 B tile, one f32 output tile
    per_step = 128 * 128 * 2 + 2 * (128 * 128 * 4)
    assert prog.traffic_bytes() == per_step * prog.steps
    assert prog.in_streams[0].bytes_per_step == 128 * 128 * 2
    assert prog.in_streams[1].bytes_per_step == 128 * 128 * 4


def test_stream_compute_multi_output(rng):
    # the linear-attention program: two output streams through one launch
    r = jnp.asarray(rng.standard_normal((1, 2, 32, 8)), jnp.float32)
    k = jnp.asarray(rng.standard_normal((1, 2, 32, 8)), jnp.float32)
    v = jnp.asarray(rng.standard_normal((1, 2, 32, 8)), jnp.float32)
    wl = jnp.asarray(-rng.uniform(0.01, 2.0, (1, 2, 32, 8)), jnp.float32)
    o, S = ops.linear_attention(r, k, v, wl, impl="interpret", chunk=16)
    from repro.kernels import ref

    o_ref, s_ref = ref.linear_attention_scan_ref(
        r, k, v, jnp.maximum(wl, ops.W_LOG_FLOOR), None, None
    )
    np.testing.assert_allclose(np.asarray(o), np.asarray(o_ref),
                               rtol=1e-4, atol=1e-4)
    np.testing.assert_allclose(np.asarray(S), np.asarray(s_ref),
                               rtol=1e-4, atol=1e-4)


def test_no_pallas_call_outside_streams():
    """The substrate invariant: core/streams.py is the only pallas_call
    site — enforced by the static checker's single-pallas-site rule."""
    from repro.analysis import run_rules

    findings = run_rules(["single-pallas-site"])
    assert findings == [], "\n".join(f.format() for f in findings)
