"""Per-arch smoke tests (required): every assigned architecture instantiates
a REDUCED same-family config and runs one forward + one train step on CPU,
asserting output shapes and no NaNs. Plus decode-vs-forward consistency."""
import jax
import jax.numpy as jnp
import pytest

from repro.configs.base import SHAPES, all_arch_ids, get_config
from repro.models import layers as L
from repro.models import multimodal, registry, transformer
from repro.runtime import train_loop

ARCHS = all_arch_ids()


@pytest.mark.parametrize("arch", ARCHS)
def test_smoke_forward_shapes_no_nan(arch):
    cfg = get_config(arch, reduced=True)
    params = registry.init_params(cfg, jax.random.PRNGKey(0))
    B, S = 2, 32
    batch = registry.make_batch(cfg, SHAPES["train_4k"], batch_override=B,
                                seq_override=S)
    logits, aux = registry.forward(params, cfg, batch)
    assert logits.shape == (B, S, L.padded_vocab(cfg.vocab_size))
    assert not bool(jnp.any(jnp.isnan(logits)))
    assert not bool(jnp.isnan(aux))


@pytest.mark.parametrize("arch", ARCHS)
def test_smoke_train_step(arch):
    cfg = get_config(arch, reduced=True)
    state = train_loop.init_train_state(cfg, jax.random.PRNGKey(0))
    step = jax.jit(train_loop.make_train_step(cfg))
    batch = registry.make_batch(cfg, SHAPES["train_4k"], batch_override=2,
                                seq_override=16)
    state, metrics = step(state, batch)
    assert float(metrics["loss"]) > 0 and not jnp.isnan(metrics["loss"])
    assert float(metrics["grad_norm"]) > 0
    assert int(state["opt"]["step"]) == 1
    # params actually moved
    moved = any(
        float(jnp.max(jnp.abs(a.astype(jnp.float32) - b.astype(jnp.float32)))) > 0
        for a, b in zip(jax.tree.leaves(state["params"]),
                        jax.tree.leaves(
                            train_loop.init_train_state(
                                cfg, jax.random.PRNGKey(0))["params"]))
    )
    assert moved


@pytest.mark.parametrize("arch", ARCHS)
def test_smoke_decode_step(arch):
    cfg = get_config(arch, reduced=True)
    params = registry.init_params(cfg, jax.random.PRNGKey(0))
    cache = registry.init_cache(cfg, 2, 16)
    batch = registry.make_batch(cfg, SHAPES["decode_32k"], batch_override=2,
                                seq_override=16)
    logits, cache2 = registry.decode_step(params, cfg, cache, batch)
    assert logits.shape == (2, L.padded_vocab(cfg.vocab_size))
    assert not bool(jnp.any(jnp.isnan(logits)))
    assert jax.tree.structure(cache2) == jax.tree.structure(cache)


@pytest.mark.parametrize(
    "arch",
    ["qwen3-14b", "grok-1-314b", "hymba-1.5b", "rwkv6-3b", "whisper-large-v3"],
)
def test_decode_matches_forward(arch):
    """Incremental decode must reproduce teacher-forced logits exactly."""
    cfg = get_config(arch, reduced=True)
    if cfg.num_experts:
        cfg = cfg.replace(capacity_factor=8.0)  # no drops => exact match
    params = registry.init_params(cfg, jax.random.PRNGKey(0))
    S = 10
    b = registry.make_batch(cfg, SHAPES["prefill_32k"], batch_override=2,
                            seq_override=S)
    full, _ = registry.forward(params, cfg, b)
    cache = registry.init_cache(cfg, 2, S)
    if cfg.family == "audio":
        ck, cv = multimodal.build_cross_cache(params, cfg, b["frames"])
        cache["cross_k"], cache["cross_v"] = ck, cv
    step = jax.jit(lambda p, c, db: registry.decode_step(p, cfg, c, db))
    outs = []
    for t in range(S):
        db = {"token": b["tokens"][:, t],
              "position": jnp.full((2,), t, jnp.int32)}
        lg, cache = step(params, cache, db)
        outs.append(lg)
    dec = jnp.stack(outs, 1)
    err = float(jnp.max(jnp.abs(dec - full.astype(jnp.float32))))
    scale = float(jnp.max(jnp.abs(full)))
    assert err / scale < 2e-2, err / scale


def test_vlm_prefill_then_decode():
    cfg = get_config("pixtral-12b", reduced=True)
    params = registry.init_params(cfg, jax.random.PRNGKey(0))
    S, P = 12, cfg.num_patches
    b = registry.make_batch(cfg, SHAPES["prefill_32k"], batch_override=2,
                            seq_override=S)
    full, _ = registry.forward(params, cfg, b)
    plog, cache = transformer.prefill_step(
        params, cfg, {"tokens": b["tokens"][:, :4], "patches": b["patches"]},
        max_len=S,
    )
    errs = [float(jnp.max(jnp.abs(
        plog.astype(jnp.float32) - full[:, : P + 4].astype(jnp.float32))))]
    step = jax.jit(lambda p, c, db: registry.decode_step(p, cfg, c, db))
    for t in range(4, S - P):
        db = {"token": b["tokens"][:, t],
              "position": jnp.full((2,), P + t, jnp.int32)}
        lg, cache = step(params, cache, db)
        errs.append(float(jnp.max(jnp.abs(lg - full[:, P + t].astype(jnp.float32)))))
    assert max(errs) / float(jnp.max(jnp.abs(full))) < 2e-2


def test_sliding_window_matches_full_for_short_seq():
    """window >= seq must equal full attention exactly."""
    cfg = get_config("qwen3-14b", reduced=True)
    params = registry.init_params(cfg, jax.random.PRNGKey(0))
    b = registry.make_batch(cfg, SHAPES["prefill_32k"], batch_override=2,
                            seq_override=8)
    full, _ = registry.forward(params, cfg, b)
    win, _ = registry.forward(params, cfg.replace(sliding_window=64), b)
    assert float(jnp.max(jnp.abs(full.astype(jnp.float32) -
                                 win.astype(jnp.float32)))) < 1e-4


def test_vocab_padding_never_predicted():
    cfg = get_config("whisper-large-v3", reduced=True).replace(vocab_size=500)
    params = registry.init_params(cfg, jax.random.PRNGKey(0))
    b = registry.make_batch(cfg, SHAPES["train_4k"], batch_override=1,
                            seq_override=8)
    loss = registry.loss_fn(params, cfg, b)
    assert jnp.isfinite(loss)  # padded tail masked to -1e30, not NaN
