"""Paged decode attention: the paged path must be *bitwise* equal to the
contiguous oracle (same online-softmax scan over the same values, only
addressed through a block table), across impl x GQA x window x precision,
and the cache-sharded ring decode must be bitwise-replicated across ranks.

The equivalence construction: a contiguous cache (B, K, S, D) with
S = NB * bs is cut into NB pages per sequence and scattered into a pool at
arbitrary physical indices; the block table maps logical page j back to
its physical slot. Pool page extent pins bs, so both paths stream
identical (bs x D) tiles through the identical scan body.
"""
import json
import os
import subprocess
import sys
import textwrap

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.core import precision as prec
from repro.kernels import ops
from repro.serving.paged_cache import NULL_BLOCK, init_paged_cache
from repro.serving.ring_decode import ring_decode_reference


def _paged_setup(rng, *, B=3, H=8, K=4, S=64, D=16, bs=16, policy=None):
    """Contiguous cache + the equivalent paged pool/table. Returns
    (q, k, v, position, k_pool, v_pool, k_scale, v_scale, table)."""
    q = jnp.asarray(rng.standard_normal((B, H, D)), jnp.float32)
    k = jnp.asarray(rng.standard_normal((B, K, S, D)), jnp.float32)
    v = jnp.asarray(rng.standard_normal((B, K, S, D)), jnp.float32)
    position = jnp.asarray(rng.integers(1, S, B), jnp.int32)

    nb = S // bs
    P_pool = B * nb + 1  # + the null page
    perm = rng.permutation(B * nb) + 1  # physical slots, never NULL_BLOCK
    table = np.zeros((B, nb), np.int32)
    k_pool = np.zeros((P_pool, K, bs, D), np.float32)
    v_pool = np.zeros((P_pool, K, bs, D), np.float32)
    for b in range(B):
        for j in range(nb):
            phys = int(perm[b * nb + j])
            table[b, j] = phys
            k_pool[phys] = np.asarray(k[b, :, j * bs:(j + 1) * bs])
            v_pool[phys] = np.asarray(v[b, :, j * bs:(j + 1) * bs])
    k_scale = v_scale = None
    kp, vp = jnp.asarray(k_pool), jnp.asarray(v_pool)
    if policy == "prequant":
        kq, ks, vq, vs = prec.quantize_kv_cache(kp, vp, "fp8")
        kp, vp, k_scale, v_scale = kq, vq, ks, vs
    return q, k, v, position, kp, vp, k_scale, v_scale, jnp.asarray(table)


@pytest.mark.parametrize("impl", ["xla", "ref", "interpret"])
@pytest.mark.parametrize("gqa_k", [1, 4])
@pytest.mark.parametrize("window", [0, 13])
def test_paged_bitwise_vs_contiguous(rng, impl, gqa_k, window):
    q, k, v, pos, kp, vp, _, _, tbl = _paged_setup(rng, K=gqa_k)
    want = ops.decode_attention(q, k, v, pos, window=window, impl=impl,
                                bs=16)
    got = ops.decode_attention(q, kp, vp, pos, window=window, impl=impl,
                               paged=True, block_table=tbl)
    np.testing.assert_array_equal(np.asarray(got), np.asarray(want))


@pytest.mark.parametrize("impl", ["xla", "ref"])
@pytest.mark.parametrize("policy", ["fp8", "bf16"])
def test_paged_bitwise_quantize_at_use(rng, impl, policy):
    # per-row quantization (axis=-1, block=D) is layout-independent, so
    # quantize-at-use over pool pages == quantize-at-use over the
    # contiguous cache, bitwise
    q, k, v, pos, kp, vp, _, _, tbl = _paged_setup(rng)
    want = ops.decode_attention(q, k, v, pos, precision=policy, impl=impl,
                                bs=16)
    got = ops.decode_attention(q, kp, vp, pos, precision=policy, impl=impl,
                               paged=True, block_table=tbl)
    np.testing.assert_array_equal(np.asarray(got), np.asarray(want))


@pytest.mark.parametrize("impl", ["xla", "ref"])
def test_paged_prequantized_pool_bitwise(rng, impl):
    # pools stored narrow (values + per-row scales) skip quantize and
    # dequantize identically to quantize-at-use on the same pages
    q, k, v, pos, kp, vp, ks, vs, tbl = _paged_setup(rng, policy="prequant")
    want = ops.decode_attention(q, k, v, pos, precision="fp8", impl=impl,
                                bs=16)
    got = ops.decode_attention(q, kp, vp, pos, impl=impl,
                               paged=True, block_table=tbl,
                               k_scale=ks, v_scale=vs)
    np.testing.assert_array_equal(np.asarray(got), np.asarray(want))


@pytest.mark.parametrize("impl", ["xla", "ref"])
def test_paged_return_lse_bitwise(rng, impl):
    q, k, v, pos, kp, vp, _, _, tbl = _paged_setup(rng)
    wo, wl = ops.decode_attention(q, k, v, pos, impl=impl, return_lse=True,
                                  bs=16)
    go, gl = ops.decode_attention(q, kp, vp, pos, impl=impl, paged=True,
                                  block_table=tbl, return_lse=True)
    np.testing.assert_array_equal(np.asarray(go), np.asarray(wo))
    np.testing.assert_array_equal(np.asarray(gl), np.asarray(wl))
    assert gl.dtype == jnp.float32 and gl.shape == q.shape[:2]


def test_paged_validation_errors(rng):
    q, k, v, pos, kp, vp, ks, vs, tbl = _paged_setup(rng, policy="prequant")
    with pytest.raises(TypeError, match="block_table"):
        ops.decode_attention(q, kp, vp, pos, paged=True)
    with pytest.raises(TypeError, match="paged"):
        ops.decode_attention(q, k, v, pos, block_table=tbl)
    with pytest.raises(TypeError, match="k_scale"):
        ops.decode_attention(q, k, v, pos, k_scale=ks, v_scale=vs)
    with pytest.raises(ValueError, match="pools"):
        ops.decode_attention(q, kp, vp[:-1], pos, paged=True,
                             block_table=tbl)


def test_null_block_rows_are_exact_noops(rng):
    # duplicate the null page into a live slot's UNREACHED table tail:
    # positions mask those reads, so output is unchanged bitwise
    q, k, v, pos, kp, vp, _, _, tbl = _paged_setup(rng, S=64, bs=16)
    pos_short = jnp.minimum(pos, 15)  # only logical page 0 is ever live
    want = ops.decode_attention(q, kp, vp, pos_short, paged=True,
                                block_table=tbl)
    tbl_null = np.asarray(tbl).copy()
    tbl_null[:, 1:] = NULL_BLOCK
    got = ops.decode_attention(q, kp, vp, pos_short, paged=True,
                               block_table=jnp.asarray(tbl_null))
    np.testing.assert_array_equal(np.asarray(got), np.asarray(want))


# ---------------------------------------------------------------------------
# PagedKVCache pytree + round-trips
# ---------------------------------------------------------------------------


class _Cfg:
    num_layers, num_kv_heads, vocab_size = 2, 4, 128
    dtype = "float32"

    def resolved_head_dim(self):
        return 16


@pytest.mark.parametrize("policy", [None, "fp8"])
def test_paged_cache_roundtrips(rng, policy):
    cache = init_paged_cache(_Cfg(), num_blocks=8, block_size=4,
                             policy=policy)
    assert cache.num_blocks == 8 and cache.quantized == (policy is not None)

    # pytree: flatten/unflatten preserves aux + children identity
    leaves, tree = jax.tree.flatten(cache)
    back = jax.tree.unflatten(tree, leaves)
    assert back.block_size == 4 and back.policy == policy

    nl, K, bs, hd = 2, 4, 4, 16
    k_rows = jnp.asarray(rng.standard_normal((nl, 3, K, bs, hd)), jnp.float32)
    v_rows = jnp.asarray(rng.standard_normal((nl, 3, K, bs, hd)), jnp.float32)
    ids = jnp.asarray([2, 5, 7], jnp.int32)
    cache = cache.write_prompt(ids, k_rows, v_rows)

    # gather -> restore into different physical pages is bitwise
    payload = jax.device_get(cache.gather_blocks(ids))
    ids2 = jnp.asarray([1, 3, 6], jnp.int32)
    cache2 = cache.restore_blocks(ids2, payload)
    np.testing.assert_array_equal(
        np.asarray(cache2.k_pool[:, ids2]), np.asarray(cache.k_pool[:, ids]))
    np.testing.assert_array_equal(
        np.asarray(cache2.v_pool[:, ids2]), np.asarray(cache.v_pool[:, ids]))
    if policy:
        np.testing.assert_array_equal(
            np.asarray(cache2.k_scale[:, ids2]),
            np.asarray(cache.k_scale[:, ids]))


def test_paged_cache_quantized_write_matches_oracle(rng):
    # write_prompt under a policy stores exactly quantize_kv_cache's output
    cache = init_paged_cache(_Cfg(), num_blocks=8, block_size=4, policy="fp8")
    nl, K, bs, hd = 2, 4, 4, 16
    k_rows = jnp.asarray(rng.standard_normal((nl, 2, K, bs, hd)), jnp.float32)
    v_rows = jnp.asarray(rng.standard_normal((nl, 2, K, bs, hd)), jnp.float32)
    ids = jnp.asarray([4, 6], jnp.int32)
    cache = cache.write_prompt(ids, k_rows, v_rows)
    kq, ks, vq, vs = prec.quantize_kv_cache(k_rows, v_rows, "fp8")
    np.testing.assert_array_equal(
        np.asarray(cache.k_pool[:, ids]), np.asarray(kq))
    np.testing.assert_array_equal(
        np.asarray(cache.k_scale[:, ids]), np.asarray(ks))
    np.testing.assert_array_equal(
        np.asarray(cache.v_scale[:, ids]), np.asarray(vs))


# ---------------------------------------------------------------------------
# Model layer: decode_step_paged vs contiguous decode_step
# ---------------------------------------------------------------------------


def test_decode_step_paged_bitwise_vs_contiguous():
    from repro.configs.base import get_config
    from repro.models import registry as mreg, transformer

    cfg = get_config("gemma-2b", reduced=True)
    params = mreg.init_params(cfg, jax.random.PRNGKey(0))
    rng = np.random.default_rng(3)
    B, S0, bs, nb = 2, 8, 4, 4
    max_len = nb * bs
    tokens = jnp.asarray(rng.integers(1, cfg.vocab_size, (B, S0)), jnp.int32)

    _, cache = transformer.prefill_step(params, cfg, {"tokens": tokens},
                                        max_len)
    nl = cfg.num_layers
    K = cfg.num_kv_heads
    hd = cfg.resolved_head_dim()

    # paged mirror: pool pages <- contiguous cache pages, shuffled physical
    paged = init_paged_cache(cfg, num_blocks=B * nb + 1, block_size=bs)
    perm = rng.permutation(B * nb) + 1
    table = np.zeros((B, nb), np.int32)
    kp = np.zeros((nl, B * nb + 1, K, bs, hd), np.float32)
    vp = np.zeros_like(kp)
    for b in range(B):
        for j in range(nb):
            phys = int(perm[b * nb + j])
            table[b, j] = phys
            kp[:, phys] = np.asarray(cache["k"][:, b, :, j * bs:(j + 1) * bs])
            vp[:, phys] = np.asarray(cache["v"][:, b, :, j * bs:(j + 1) * bs])
    import dataclasses
    paged = dataclasses.replace(paged, k_pool=jnp.asarray(kp),
                                v_pool=jnp.asarray(vp))

    tok = jnp.asarray(rng.integers(1, cfg.vocab_size, B), jnp.int32)
    posn = jnp.full((B,), S0, jnp.int32)
    # pin the contiguous scan to the pool's page extent so both paths
    # stream identical (bs x D) tiles (bitwise needs matching partitions)
    from repro.kernels import registry as kreg
    with kreg.block_override("decode_attention", bs=bs):
        want, _ = transformer.decode_step(
            params, cfg, cache, {"token": tok, "position": posn})
    got, paged2 = transformer.decode_step_paged(
        params, cfg, paged,
        {"token": tok, "position": posn, "block_table": jnp.asarray(table)})
    np.testing.assert_array_equal(np.asarray(got), np.asarray(want))
    # the step wrote this position's K/V into the right page row
    assert not np.array_equal(np.asarray(paged2.k_pool),
                              np.asarray(paged.k_pool))


# ---------------------------------------------------------------------------
# Ring decode: single-device merge-chain oracle + 8-device subprocess
# ---------------------------------------------------------------------------


def test_ring_reference_allclose_contiguous(rng):
    q, k, v, pos, kp, vp, _, _, tbl = _paged_setup(rng, S=64, bs=16)
    # ring table convention: entries index the owning rank's LOCAL pool.
    # Rebuild per-rank local pools by slicing logical pages per rank.
    n, nb = 2, 4
    nb_l = nb // n
    B = int(tbl.shape[0])
    kp_l, vp_l, tbl_l = _localize(np.asarray(kp), np.asarray(vp),
                                  np.asarray(tbl), n)
    want = ops.decode_attention(q, k, v, pos)
    got = ring_decode_reference(q, jnp.asarray(kp_l), jnp.asarray(vp_l),
                                jnp.asarray(tbl_l), pos, n)
    np.testing.assert_allclose(np.asarray(got), np.asarray(want),
                               atol=2e-6, rtol=2e-6)
    assert nb_l * n == nb and B == 3


def _localize(kp, vp, tbl, n):
    """Re-home a global paged layout to the ring convention: rank r's local
    pool holds the pages behind table columns [r*nb_l, (r+1)*nb_l), and
    those columns index the local pool. Returns (k_pools, v_pools, table)
    with pools concatenated in rank order (what shard_map splits)."""
    B, nb = tbl.shape
    nb_l = nb // n
    K, bs, D = kp.shape[1:]
    p_l = B * nb_l + 1
    k_out = np.zeros((n * p_l, K, bs, D), kp.dtype)
    v_out = np.zeros_like(k_out)
    t_out = np.zeros((B, nb), np.int32)
    for r in range(n):
        nxt = 1  # local slot 0 is each rank's null page
        for b in range(B):
            for j in range(r * nb_l, (r + 1) * nb_l):
                k_out[r * p_l + nxt] = kp[tbl[b, j]]
                v_out[r * p_l + nxt] = vp[tbl[b, j]]
                t_out[b, j] = nxt
                nxt += 1
    return k_out, v_out, t_out


_RING_SUBPROCESS = textwrap.dedent(
    """
    import os
    os.environ["XLA_FLAGS"] = "--xla_force_host_platform_device_count=8"
    import json
    import jax
    import jax.numpy as jnp
    import numpy as np

    from repro.kernels import ops
    from repro.serving import ring_decode as rd

    rng = np.random.default_rng(0)
    B, H, K, D, bs, nb, n = 3, 8, 4, 16, 8, 8, 4
    S = nb * bs
    q = jnp.asarray(rng.standard_normal((B, H, D)), jnp.float32)
    k = jnp.asarray(rng.standard_normal((B, K, S, D)), jnp.float32)
    v = jnp.asarray(rng.standard_normal((B, K, S, D)), jnp.float32)
    pos = jnp.asarray(rng.integers(1, S, B), jnp.int32)

    nb_l = nb // n
    p_l = B * nb_l + 1
    kp = np.zeros((n * p_l, K, bs, D), np.float32)
    vp = np.zeros_like(kp)
    tbl = np.zeros((B, nb), np.int32)
    for r in range(n):
        nxt = 1
        for b in range(B):
            for j in range(r * nb_l, (r + 1) * nb_l):
                kp[r * p_l + nxt] = np.asarray(k[b, :, j * bs:(j + 1) * bs])
                vp[r * p_l + nxt] = np.asarray(v[b, :, j * bs:(j + 1) * bs])
                tbl[b, j] = nxt
                nxt += 1
    kp, vp, tbl = jnp.asarray(kp), jnp.asarray(vp), jnp.asarray(tbl)

    mesh = jax.make_mesh((4, 2), ("data", "model"))
    got = rd.ring_decode(q, kp, vp, tbl, pos, mesh, axis="data")
    sync = rd.ring_decode(q, kp, vp, tbl, pos, mesh, axis="data",
                          overlap=False)
    want = rd.ring_decode_reference(q, kp, vp, tbl, pos, n)
    contig = ops.decode_attention(q, k, v, pos)
    out = {
        "ring_vs_ref_bitwise": bool(
            np.array_equal(np.asarray(got), np.asarray(want))),
        "overlap_invariant": bool(
            np.array_equal(np.asarray(got), np.asarray(sync))),
        "ring_vs_contig_err": float(
            np.max(np.abs(np.asarray(got) - np.asarray(contig)))),
    }
    print("RESULT:" + json.dumps(out))
    """
)


@pytest.mark.slow
def test_ring_decode_8dev_bitwise_vs_reference():
    env = dict(os.environ)
    env["PYTHONPATH"] = os.path.join(os.path.dirname(__file__), "..", "src")
    proc = subprocess.run(
        [sys.executable, "-c", _RING_SUBPROCESS],
        capture_output=True, text=True, env=env, timeout=900,
    )
    assert proc.returncode == 0, proc.stderr[-3000:]
    line = [ln for ln in proc.stdout.splitlines()
            if ln.startswith("RESULT:")][-1]
    out = json.loads(line[len("RESULT:"):])
    assert out["ring_vs_ref_bitwise"], out
    assert out["overlap_invariant"], out
    assert out["ring_vs_contig_err"] < 1e-5, out
