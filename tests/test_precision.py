"""Precision ladder tests: blockwise quantization round-trips, the scaled
kernel paths against the fp32 oracles across every CPU impl, the
policy-aware cost model (dtype aliases, peak-flops override, dry-run
sweep cells), gradient-compression unbiasedness, and the sharded fp8
paths on forced host devices (subprocess, like test_partition)."""
import json
import os
import subprocess
import sys
import textwrap

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.core import precision as prec
from repro.kernels import ops, ref


def _rel(got, want):
    g = np.asarray(got, np.float32)
    w = np.asarray(want, np.float32)
    return float(np.linalg.norm(g - w) / max(np.linalg.norm(w), 1e-30))


# ---------------------------------------------------------------------------
# Policy resolution + blockwise quantization
# ---------------------------------------------------------------------------


def test_resolve_policy_seam():
    assert prec.resolve(None) is None
    p = prec.resolve("fp8")
    assert p.compute_dtype == jnp.float8_e4m3fn and p.scale_block == 128
    assert prec.resolve(p) is p
    with pytest.raises(KeyError, match="known:"):
        prec.resolve("fp4")
    assert prec.supported_policies("gemm") == (
        "fp32", "bf16", "fp8", "fp8_e5m2"
    )
    assert prec.supported_policies("spmm") == ("fp32",)


@pytest.mark.parametrize("pol,tol", [("fp8", 0.05), ("fp8_e5m2", 0.12)])
def test_quantize_blockwise_roundtrip(rng, pol, tol):
    x = jnp.asarray(rng.standard_normal((5, 300)), jnp.float32)
    vals, scales = prec.quantize_blockwise(x, pol, axis=-1, block=128)
    assert vals.dtype == prec.resolve(pol).compute_dtype
    assert vals.shape == x.shape
    assert scales.shape == (5, 3) and scales.dtype == jnp.float32  # ceil(300/128)
    deq = prec.dequantize_blockwise(vals, scales, axis=-1, block=128)
    assert deq.dtype == jnp.float32
    assert _rel(deq, x) < tol
    # per-block scaling: every scaled value fits the narrow format's range
    fmax = float(jnp.finfo(prec.resolve(pol).compute_dtype).max)
    assert float(jnp.max(jnp.abs(jnp.asarray(vals, jnp.float32)))) <= fmax


def test_quantize_wide_policies_are_plain_casts(rng):
    x = jnp.asarray(rng.standard_normal((4, 64)), jnp.float32)
    vals, scales = prec.quantize_blockwise(x, "bf16", axis=-1)
    assert vals.dtype == jnp.bfloat16
    assert scales.shape == (4, 1)  # scale_block=0: one whole-axis unit scale
    np.testing.assert_array_equal(np.asarray(scales), 1.0)
    deq = prec.dequantize_blockwise(vals, scales, axis=-1)
    np.testing.assert_array_equal(
        np.asarray(deq), np.asarray(x.astype(jnp.bfloat16), np.float32)
    )


def test_quantize_zero_blocks_roundtrip_exactly():
    x = jnp.zeros((2, 256), jnp.float32)
    vals, scales = prec.quantize_blockwise(x, "fp8", axis=-1, block=128)
    np.testing.assert_array_equal(np.asarray(scales), 1.0)  # not 0/0
    deq = prec.dequantize_blockwise(vals, scales, axis=-1, block=128)
    np.testing.assert_array_equal(np.asarray(deq), 0.0)


def test_dequantize_ragged_axis_needs_explicit_block(rng):
    # K=160 quantized at block=64 -> nb=3 with a ragged final block; the
    # inferred block ceil(160/3)=54 would misalign every scale boundary
    # (the bug the explicit ``block=`` parameter exists for)
    x = jnp.asarray(rng.standard_normal((8, 160)), jnp.float32)
    vals, scales = prec.quantize_blockwise(x, "fp8", axis=1, block=64)
    assert scales.shape == (8, 3)
    good = prec.dequantize_blockwise(vals, scales, axis=1, block=64)
    assert _rel(good, x) < 0.05
    assert _rel(
        prec.dequantize_blockwise(vals, scales, axis=1), x
    ) > _rel(good, x)


def test_quantize_kv_cache_layout(rng):
    k = jnp.asarray(rng.standard_normal((2, 4, 32, 16)), jnp.float32)
    v = jnp.asarray(rng.standard_normal((2, 4, 32, 16)), jnp.float32)
    kq, ks, vq, vs = prec.quantize_kv_cache(k, v, "fp8")
    assert kq.dtype == jnp.float8_e4m3fn and kq.shape == k.shape
    assert ks.shape == (2, 4, 32, 1)  # one fp32 scale per cached token row
    assert _rel(prec.dequantize_blockwise(kq, ks, axis=-1), k) < 0.05
    assert _rel(prec.dequantize_blockwise(vq, vs, axis=-1), v) < 0.05


# ---------------------------------------------------------------------------
# Scaled kernels vs the fp32 oracle, across every CPU impl
# ---------------------------------------------------------------------------

_GEMM_TOL = {"fp32": 1e-5, "bf16": 0.02, "fp8": 0.1, "fp8_e5m2": 0.2}


@pytest.mark.parametrize("pol", ["fp32", "bf16", "fp8", "fp8_e5m2"])
def test_scaled_gemm_cross_impl(rng, pol):
    a = jnp.asarray(rng.standard_normal((96, 160)), jnp.float32)
    b = jnp.asarray(rng.standard_normal((160, 80)), jnp.float32)
    oracle = ref.gemm_ref(a, b, jnp.float32)
    outs = {
        impl: ops.gemm(a, b, precision=pol, impl=impl, bk=64)
        for impl in ("xla", "interpret", "ref")
    }
    for impl, got in outs.items():
        assert got.dtype == jnp.float32
        assert _rel(got, oracle) < _GEMM_TOL[pol], (impl, _rel(got, oracle))
    # the impls implement ONE quantization scheme: they agree far tighter
    # with each other than any of them does with the unquantized oracle
    for impl in ("xla", "interpret"):
        assert _rel(outs[impl], outs["ref"]) < 1e-4, impl


@pytest.mark.parametrize("pol,tol", [("bf16", 0.02), ("fp8", 0.1)])
def test_scaled_flash_attention_cross_impl(rng, pol, tol):
    q = jnp.asarray(rng.standard_normal((1, 4, 64, 32)), jnp.float32)
    kv = jnp.asarray(rng.standard_normal((1, 2, 64, 32)), jnp.float32)
    oracle = ref.mha_ref(q, kv, kv, causal=True)
    outs = {
        impl: ops.flash_attention(q, kv, kv, causal=True, precision=pol,
                                  impl=impl)
        for impl in ("xla", "interpret", "ref")
    }
    for impl, got in outs.items():
        assert got.dtype == jnp.float32  # scaled path always widens out
        assert _rel(got, oracle) < tol, (impl, _rel(got, oracle))
    for impl in ("xla", "interpret"):
        assert _rel(outs[impl], outs["ref"]) < 1e-4, impl


@pytest.mark.parametrize("pol,tol", [("bf16", 0.02), ("fp8", 0.1)])
def test_scaled_decode_attention_cross_impl(rng, pol, tol):
    q = jnp.asarray(rng.standard_normal((2, 8, 16)), jnp.float32)
    kv = jnp.asarray(rng.standard_normal((2, 4, 32, 16)), jnp.float32)
    pos = jnp.asarray([5, 30], jnp.int32)
    oracle = ref.decode_attention_ref(q, kv, kv, pos)
    outs = {
        impl: ops.decode_attention(q, kv, kv, pos, precision=pol, impl=impl)
        for impl in ("xla", "interpret", "ref")
    }
    for impl, got in outs.items():
        assert _rel(got, oracle) < tol, (impl, _rel(got, oracle))
    assert _rel(outs["xla"], outs["ref"]) < 1e-4


def test_precision_none_is_the_exact_legacy_path(rng):
    a = jnp.asarray(rng.standard_normal((32, 64)), jnp.float32)
    b = jnp.asarray(rng.standard_normal((64, 16)), jnp.float32)
    legacy = ops.gemm(a, b, impl="xla")
    np.testing.assert_array_equal(
        np.asarray(ops.gemm(a, b, impl="xla", precision=None)),
        np.asarray(legacy),
    )
    # fp32 *policy* runs the scaled machinery with unit scales: numerically
    # equivalent, reassociated over K blocks
    assert _rel(ops.gemm(a, b, impl="xla", precision="fp32"), legacy) < 1e-5


# ---------------------------------------------------------------------------
# Cost model: dtype aliases, peak-flops override, sweep cells
# ---------------------------------------------------------------------------


def test_collective_bytes_fp8_and_bf16_alias_spellings():
    from repro.launch import roofline

    # every fp8 spelling XLA emits is one byte — a missing entry silently
    # fell back to 4 B/elem and quadrupled low-precision collective bytes
    for alias in ("f8e4m3", "f8e3m4", "f8e4m3fn", "f8e4m3fnuz",
                  "f8e4m3b11fnuz", "f8e5m2", "f8e5m2fnuz", "s4", "u4"):
        assert roofline._DTYPE_BYTES[alias] == 1, alias
    hlo = textwrap.dedent("""
        %big = f8e5m2fnuz[256] parameter(0)
        %ag = f8e4m3[128,64] all-gather(%x), replica_groups={}
        %ar = bf16[256] all-reduce(%y), to_apply=%sum
        %rs = f8e5m2fnuz[64] reduce-scatter(%big), dimensions={0}
    """)
    got = roofline.collective_bytes(hlo)
    assert got["by_kind"]["all-gather"] == 128 * 64 * 1
    assert got["by_kind"]["all-reduce"] == 2.0 * 256 * 2
    assert got["by_kind"]["reduce-scatter"] == 256 * 1  # operand side
    assert got["total"] == 8192 + 1024 + 256


def test_roofline_terms_peak_flops_override():
    from repro.launch import roofline

    base = roofline.roofline_terms(1e12, 0.0, 0.0)
    fp8 = roofline.roofline_terms(
        1e12, 0.0, 0.0, peak_flops=prec.peak_flops("fp8")
    )
    assert fp8["compute_s"] == pytest.approx(
        base["compute_s"] * roofline.PEAK_FLOPS / prec.peak_flops("fp8")
    )
    ov = roofline.overlapped_terms(
        1e12, 0.0, 0.0, d2d_s=0.0, hops=4,
        peak_flops=prec.peak_flops("fp8"),
    )
    assert ov["compute_s"] == fp8["compute_s"]


def test_op_roofline_cells_precision_sweep():
    from repro.launch.dryrun import op_roofline_cells

    f32 = {c["op"]: c for c in op_roofline_cells(precision="fp32")}
    fp8 = {c["op"]: c for c in op_roofline_cells(precision="fp8")}
    g32, g8 = f32["gemm"], fp8["gemm"]
    assert g8["precision"] == "fp8" and g32["precision"] == "fp32"
    # 4x flop ceiling: same flops, a quarter of the compute time
    assert g32["roofline"]["compute_s"] >= 2 * g8["roofline"]["compute_s"]
    # narrow storage (+ one fp32 scale per 128 elems) and bf16 psum reduce
    assert g8["bytes_per_device"] <= 0.5 * g32["bytes_per_device"]
    assert g8["d2d_bytes"] <= 0.5 * g32["d2d_bytes"]
    assert "bfloat16 reduce" in g8["partition"]
    # the ring's per-hop KV permutes shrink with the storage width too
    fa32, fa8 = f32["flash_attention"], fp8["flash_attention"]
    assert fa8["d2d_bytes"] <= 0.5 * fa32["d2d_bytes"]
    # ops without a scaled path keep their full-precision cell
    assert fp8["stencil"]["precision"] == "fp32"
    # no-precision cells carry no precision key at all (legacy output)
    assert "precision" not in op_roofline_cells()[0]


def test_docgen_dispatch_table_lists_precisions():
    from repro.launch import docgen

    text = docgen.generate()
    assert "| precisions |" in text
    assert "| `gemm` | " in text and "fp32, bf16, fp8, fp8_e5m2" in text
    # fp32-only ops say so (no scaled path advertised)
    line = next(ln for ln in text.splitlines() if ln.startswith("| `stencil`"))
    assert line.rstrip().endswith("| fp32 |")


# ---------------------------------------------------------------------------
# Gradient compression: error feedback stays unbiased per policy
# ---------------------------------------------------------------------------


@pytest.mark.parametrize("pol", ["bf16", "fp8"])
def test_compression_error_feedback_telescopes(rng, pol):
    from repro.optim import compression

    grads = {
        "w": jnp.asarray(rng.standard_normal((4, 300)), jnp.float32),
        "b": jnp.asarray(rng.standard_normal((7,)), jnp.float32),
        "s": jnp.asarray(rng.standard_normal(()), jnp.float32),
    }
    err = compression.init_error_state(grads)
    total = jax.tree.map(jnp.zeros_like, grads)
    steps = 6
    for _ in range(steps):
        sent, err = compression.compress_decompress(grads, err, policy=pol)
        assert jax.tree.structure(sent) == jax.tree.structure(grads)
        total = jax.tree.map(lambda t, s: t + s, total, sent)
    # unbiasedness: what was sent plus the final residual is EXACTLY the
    # sum of the true gradients (the round-trip error telescopes)
    for leaf, g in (("w", grads["w"]), ("b", grads["b"]), ("s", grads["s"])):
        np.testing.assert_allclose(
            np.asarray(total[leaf] + err[leaf]),
            steps * np.asarray(g),
            rtol=2e-5, atol=2e-5,
        )


def test_compression_default_policy_is_legacy_bf16(rng):
    from repro.optim import compression

    g = {"w": jnp.asarray(rng.standard_normal((8, 16)), jnp.float32)}
    e = compression.init_error_state(g)
    sent, _ = compression.compress_decompress(g, e)  # positional callers
    np.testing.assert_array_equal(
        np.asarray(sent["w"]),
        np.asarray(g["w"].astype(jnp.bfloat16), np.float32),
    )


# ---------------------------------------------------------------------------
# Sharded fp8: the scaled paths under real shard_map plans (subprocess so
# the forced-device-count flag never leaks into this process)
# ---------------------------------------------------------------------------

_SHARDED = textwrap.dedent(
    """
    import os
    os.environ["XLA_FLAGS"] = "--xla_force_host_platform_device_count=8"
    import json
    import jax, jax.numpy as jnp, numpy as np
    from repro.kernels import ops, partition

    rng = np.random.default_rng(0)
    f32 = jnp.float32
    out = {"ok": []}

    def check(name, got, want, tol):
        g = np.asarray(got, np.float32)
        w = np.asarray(want, np.float32)
        rel = float(np.linalg.norm(g - w) / np.linalg.norm(w))
        assert rel < tol, (name, rel)
        out["ok"].append(name)

    # fp8 gemm over a genuine 2-way K-shard (model=2): per-shard
    # quantization + fp32 accumulate + bf16-reduce psum epilogue
    a = jnp.asarray(rng.standard_normal((64, 256)), f32)
    b = jnp.asarray(rng.standard_normal((256, 48)), f32)
    mesh = jax.make_mesh((1, 2), ("data", "model"))
    plan = partition.plan_for("gemm", mesh, a, b, precision="fp8")
    assert "k-sharded" in plan.note and "bfloat16 reduce" in plan.note, plan.note
    want = ops.gemm(a, b, impl="ref", out_dtype=f32)
    for impl in ("xla", "interpret"):
        got = ops.gemm(a, b, mesh=mesh, impl=impl, precision="fp8", bk=64)
        single = ops.gemm(a, b, impl=impl, precision="fp8", bk=64)
        check(f"gemm_fp8[{impl}]", got, want, 0.1)
        check(f"gemm_fp8_vs_single[{impl}]", got, single, 0.02)

    # fp8 flash over batch x kv-head sharding (data=2, model=4)
    q = jnp.asarray(rng.standard_normal((2, 8, 32, 16)), f32)
    kv = jnp.asarray(rng.standard_normal((2, 4, 32, 16)), f32)
    mesh8 = jax.make_mesh((2, 4), ("data", "model"))
    want = ops.flash_attention(q, kv, kv, impl="ref")
    for impl in ("xla", "interpret"):
        got = ops.flash_attention(q, kv, kv, mesh=mesh8, impl=impl,
                                  precision="fp8")
        check(f"flash_fp8[{impl}]", got, want, 0.1)

    # fp8 flash on the B=1 sequence-parallel KV ring (data=4): per-hop
    # quantization inside the ring fold
    q1 = jnp.asarray(rng.standard_normal((1, 4, 64, 16)), f32)
    kv1 = jnp.asarray(rng.standard_normal((1, 2, 64, 16)), f32)
    mesh42 = jax.make_mesh((4, 2), ("data", "model"))
    plan = partition.plan_for("flash_attention", mesh42, q1, kv1, kv1,
                              precision="fp8")
    assert "ring seq-parallel" in plan.note, plan.note
    want = ops.flash_attention(q1, kv1, kv1, impl="ref")
    got = ops.flash_attention(q1, kv1, kv1, mesh=mesh42, impl="xla",
                              precision="fp8")
    check("flash_fp8_ring", got, want, 0.1)
    print("RESULT:" + json.dumps(out))
    """
)


def test_sharded_fp8_equivalence_subprocess():
    env = dict(os.environ)
    env["PYTHONPATH"] = os.path.join(os.path.dirname(__file__), "..", "src")
    proc = subprocess.run(
        [sys.executable, "-c", _SHARDED],
        capture_output=True, text=True, env=env, timeout=900,
    )
    assert proc.returncode == 0, proc.stderr[-3000:]
    line = [ln for ln in proc.stdout.splitlines() if ln.startswith("RESULT:")][-1]
    out = json.loads(line[len("RESULT:"):])
    for impl in ("xla", "interpret"):
        assert f"gemm_fp8[{impl}]" in out["ok"]
        assert f"gemm_fp8_vs_single[{impl}]" in out["ok"]
        assert f"flash_fp8[{impl}]" in out["ok"]
    assert "flash_fp8_ring" in out["ok"]
