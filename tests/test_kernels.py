"""Per-kernel allclose vs the pure-jnp oracles (interpret mode), with
shape/dtype sweeps as required for every Pallas kernel."""
import jax.numpy as jnp
import numpy as np
import pytest

from repro.core import sparse as sp
from repro.kernels import ops, ref

RTOL = 2e-2  # bf16 sweeps
ATOL = 1e-4


def allclose(got, want, rtol=1e-5, atol=1e-5):
    np.testing.assert_allclose(
        np.asarray(got, np.float32), np.asarray(want, np.float32),
        rtol=rtol, atol=atol,
    )


# ---------------------------------------------------------------------------
# GEMM: shape x dtype sweep
# ---------------------------------------------------------------------------


@pytest.mark.parametrize("m,k,n", [(32, 32, 32), (100, 70, 130), (256, 128, 64)])
@pytest.mark.parametrize("dtype", [jnp.float32, jnp.bfloat16])
def test_gemm_sweep(rng, m, k, n, dtype):
    a = jnp.asarray(rng.standard_normal((m, k)), dtype)
    b = jnp.asarray(rng.standard_normal((k, n)), dtype)
    got = ops.gemm(a, b, impl="interpret", out_dtype=jnp.float32)
    want = ref.gemm_ref(a, b, out_dtype=jnp.float32)
    allclose(got, want, rtol=RTOL, atol=1e-2)


def test_gemm_fp8_expanding(rng):
    a = jnp.asarray(rng.standard_normal((64, 64)), jnp.float8_e4m3fn)
    b = jnp.asarray(rng.standard_normal((64, 64)), jnp.float8_e4m3fn)
    got = ops.gemm(a, b, impl="interpret", out_dtype=jnp.float32)
    want = ref.gemm_ref(a, b, out_dtype=jnp.float32)
    allclose(got, want, rtol=1e-3, atol=1e-3)


# ---------------------------------------------------------------------------
# FlashAttention: masks x GQA x offsets x dtypes
# ---------------------------------------------------------------------------


@pytest.mark.parametrize("h,kv,sq,sk", [(4, 2, 50, 50), (8, 1, 33, 65), (4, 4, 128, 128)])
@pytest.mark.parametrize("kw", [
    dict(causal=True), dict(causal=True, window=7), dict(causal=False),
    dict(causal=False, window=7), dict(causal=True, q_offset=13),
])
def test_flash_attention(rng, h, kv, sq, sk, kw):
    q = jnp.asarray(rng.standard_normal((2, h, sq, 16)), jnp.float32)
    k = jnp.asarray(rng.standard_normal((2, kv, sk, 16)), jnp.float32)
    v = jnp.asarray(rng.standard_normal((2, kv, sk, 16)), jnp.float32)
    want = ref.mha_ref(q, k, v, **kw)
    allclose(ops.flash_attention(q, k, v, impl="interpret", **kw), want,
             rtol=1e-4, atol=1e-4)
    allclose(ops.flash_attention(q, k, v, impl="xla", block_k=16, **kw), want,
             rtol=1e-4, atol=1e-4)
    with ops.unrolled_inner():
        allclose(ops.flash_attention(q, k, v, impl="xla", **kw), want,
                 rtol=1e-4, atol=1e-4)


def test_noncausal_window_never_attends_future(rng):
    """Regression: ``causal=False, window>0`` used to leave the future
    unmasked (no upper position bound) in the pallas kernel, both xla forms
    and ref, while every docstring described a lookback window. The shared
    semantics: a window bounds attention to ``(q_pos - window, q_pos]``, so
    perturbing FUTURE k/v must never change the output — including through
    the block early-out, exercised with blocks smaller than the window."""
    q = jnp.asarray(rng.standard_normal((1, 4, 48, 8)), jnp.float32)
    k = jnp.asarray(rng.standard_normal((1, 2, 48, 8)), jnp.float32)
    v = jnp.asarray(rng.standard_normal((1, 2, 48, 8)), jnp.float32)
    # poison everything after position 20: rows <= 20 must not move
    k_p = k.at[:, :, 21:].add(100.0)
    v_p = v.at[:, :, 21:].add(100.0)
    kw = dict(causal=False, window=6)
    for impl, extra in (("ref", {}), ("xla", {}), ("interpret", {}),
                        ("xla", dict(bq=8, bk=8)),
                        ("interpret", dict(bq=8, bk=8))):
        a = ops.flash_attention(q, k, v, impl=impl, **kw, **extra)
        b = ops.flash_attention(q, k_p, v_p, impl=impl, **kw, **extra)
        np.testing.assert_allclose(
            np.asarray(a[:, :, :21]), np.asarray(b[:, :, :21]),
            rtol=1e-5, atol=1e-5, err_msg=f"{impl} {extra}",
        )
    with ops.unrolled_inner():
        a = ops.flash_attention(q, k, v, impl="xla", bq=8, bk=8, **kw)
        b = ops.flash_attention(q, k_p, v_p, impl="xla", bq=8, bk=8, **kw)
        np.testing.assert_allclose(
            np.asarray(a[:, :, :21]), np.asarray(b[:, :, :21]),
            rtol=1e-5, atol=1e-5, err_msg="unrolled",
        )
    # and the semantics agree across every impl against ref
    want = ops.flash_attention(q, k, v, impl="ref", **kw)
    for impl in ("xla", "interpret"):
        got = ops.flash_attention(q, k, v, impl=impl, bq=8, bk=8, **kw)
        np.testing.assert_allclose(np.asarray(got), np.asarray(want),
                                   rtol=1e-4, atol=1e-4, err_msg=impl)


def test_flash_attention_bf16(rng):
    q = jnp.asarray(rng.standard_normal((1, 4, 64, 32)), jnp.bfloat16)
    k = jnp.asarray(rng.standard_normal((1, 2, 64, 32)), jnp.bfloat16)
    v = jnp.asarray(rng.standard_normal((1, 2, 64, 32)), jnp.bfloat16)
    got = ops.flash_attention(q, k, v, impl="interpret", causal=True)
    want = ref.mha_ref(q, k, v, causal=True)
    allclose(got, want, rtol=5e-2, atol=5e-2)


# ---------------------------------------------------------------------------
# Linear attention (RWKV6/SSD): modes x shapes, state handoff
# ---------------------------------------------------------------------------


@pytest.mark.parametrize("mode", ["rwkv", "ssd"])
@pytest.mark.parametrize("t,n,m", [(40, 8, 12), (64, 16, 16), (33, 8, 8)])
def test_linear_attention(rng, mode, t, n, m):
    r = jnp.asarray(rng.standard_normal((2, 3, t, n)), jnp.float32)
    k = jnp.asarray(rng.standard_normal((2, 3, t, n)), jnp.float32)
    v = jnp.asarray(rng.standard_normal((2, 3, t, m)), jnp.float32)
    wl = jnp.asarray(-rng.uniform(0.001, 2.0, (2, 3, t, n)), jnp.float32)
    u = None if mode == "ssd" else jnp.asarray(rng.standard_normal((3, n)), jnp.float32)
    s0 = jnp.asarray(rng.standard_normal((2, 3, n, m)), jnp.float32)
    o_ref, s_ref = ref.linear_attention_scan_ref(r, k, v, wl, u, s0)
    for impl in ("xla", "interpret"):
        o, s = ops.linear_attention(r, k, v, wl, u, s0, impl=impl, chunk=16)
        allclose(o, o_ref, rtol=1e-4, atol=1e-4)
        allclose(s, s_ref, rtol=1e-4, atol=1e-4)


def test_linear_attention_step_matches_scan(rng):
    r = jnp.asarray(rng.standard_normal((2, 3, 5, 8)), jnp.float32)
    k = jnp.asarray(rng.standard_normal((2, 3, 5, 8)), jnp.float32)
    v = jnp.asarray(rng.standard_normal((2, 3, 5, 8)), jnp.float32)
    wl = jnp.asarray(-rng.uniform(0.01, 2.0, (2, 3, 5, 8)), jnp.float32)
    u = jnp.asarray(rng.standard_normal((3, 8)), jnp.float32)
    o_ref, s_ref = ref.linear_attention_scan_ref(r, k, v, wl, u, None)
    S = jnp.zeros((2, 3, 8, 8))
    for t in range(5):
        o_t, S = ops.linear_attention_step(
            r[:, :, t], k[:, :, t], v[:, :, t], wl[:, :, t], u, S
        )
        allclose(o_t, o_ref[:, :, t], rtol=1e-4, atol=1e-4)
    allclose(S, s_ref, rtol=1e-4, atol=1e-4)


# ---------------------------------------------------------------------------
# SpMM (ELL + BSR), SpMSpM, stencil
# ---------------------------------------------------------------------------


@pytest.mark.parametrize("r,c,density", [(64, 96, 0.1), (128, 256, 0.02), (30, 50, 0.3)])
def test_spmm_ell(rng, r, c, density):
    A = sp.random_ell(rng, r, c, density)
    D = jnp.asarray(rng.standard_normal((c, 40)), jnp.float32)
    got = ops.spmm(jnp.asarray(A.values), jnp.asarray(A.cols), D, impl="interpret")
    want = jnp.asarray(A.todense()) @ D
    allclose(got, want, rtol=1e-4, atol=1e-4)


@pytest.mark.parametrize("bm,bk", [(8, 128), (16, 64)])
def test_bsr_spmm(rng, bm, bk):
    dense_A = np.zeros((64, 256), np.float32)
    mask = rng.random((64, 256)) < 0.05
    dense_A[mask] = rng.standard_normal(mask.sum())
    bsr = sp.dense_to_bsr(dense_A, bm=bm, bk=bk)
    D = jnp.asarray(rng.standard_normal((256, 96)), jnp.float32)
    want = jnp.asarray(dense_A) @ D
    for impl in ("interpret", "xla"):
        got = ops.bsr_spmm(
            jnp.asarray(bsr.tile_values), jnp.asarray(bsr.tile_rows),
            jnp.asarray(bsr.tile_cols), D, 64, impl=impl,
        )
        allclose(got, want, rtol=1e-4, atol=1e-4)


@pytest.mark.parametrize("r,c,k", [(48, 56, 128), (16, 128, 64)])
def test_spmspm(rng, r, c, k):
    A = sp.random_ell(rng, r, k, 0.1)
    B = sp.random_ell(rng, c, k, 0.1)
    args = (jnp.asarray(A.values), jnp.asarray(A.cols),
            jnp.asarray(B.values), jnp.asarray(B.cols), k)
    want = ref.spmspm_ref(*args)
    for impl in ("interpret", "xla"):
        allclose(ops.spmspm(*args, impl=impl), want, rtol=1e-4, atol=1e-4)


STAR = np.array([[0, 0, 0], [1, 0, 0], [-1, 0, 0], [0, 1, 0], [0, -1, 0],
                 [0, 0, 1], [0, 0, -1]])
BOX27 = np.array([[dx, dy, dz] for dx in (-1, 0, 1) for dy in (-1, 0, 1)
                  for dz in (-1, 0, 1)])
STAR_R2 = np.array([[0, 0, 0]] + [
    [s * r if a == 0 else 0, s * r if a == 1 else 0, s * r if a == 2 else 0]
    for a in range(3) for r in (1, 2) for s in (1, -1)
])


@pytest.mark.parametrize("offsets", [STAR, BOX27, STAR_R2],
                         ids=["star7", "box27", "star13_r2"])
@pytest.mark.parametrize("shape", [(16, 16, 16), (8, 32, 32)])
def test_stencil(rng, offsets, shape):
    g = jnp.asarray(rng.standard_normal(shape), jnp.float32)
    w = rng.standard_normal(len(offsets)).astype(np.float32)
    got = ops.stencil(g, offsets, w, impl="interpret")
    want = ref.stencil_ref(g, offsets, w)
    allclose(got, want, rtol=1e-4, atol=1e-4)
