"""Runtime-layer tests: checkpoint/restart, fault tolerance, data pipeline."""
import os

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.configs.base import SHAPES, get_config
from repro.data.synthetic import batch_at_step
from repro.runtime import checkpoint as ckpt
from repro.runtime import train_loop
from repro.runtime.fault_tolerance import FailureInjector, StragglerMonitor

CFG = get_config("occamy-gptj", reduced=True)


def test_checkpoint_roundtrip(tmp_path):
    state = train_loop.init_train_state(CFG, jax.random.PRNGKey(0))
    path = ckpt.save(str(tmp_path), 7, state)
    assert os.path.isdir(path)
    assert ckpt.latest_step(str(tmp_path)) == 7
    restored = ckpt.restore(str(tmp_path), 7, state)
    for a, b in zip(jax.tree.leaves(state), jax.tree.leaves(restored)):
        np.testing.assert_array_equal(np.asarray(a), np.asarray(b))


def test_checkpoint_roundtrip_sparse_pytree(tmp_path):
    """C7 meets Sec. II-A: a state dict holding registered sparse pytrees
    (EllMatrix / BsrMatrix) survives save/restore — leaves come back
    bit-identical, static aux (logical shape) comes from state_like, and
    todense() agrees, so sparse operands checkpoint like any dense leaf."""
    from repro.core.sparse import dense_to_bsr, random_ell

    rng = np.random.default_rng(0)
    ell = random_ell(rng, R=32, C=64, density=0.25)
    dense = np.zeros((16, 256), np.float32)
    dense[:8, :128] = rng.standard_normal((8, 128)).astype(np.float32)
    bsr = dense_to_bsr(dense, bm=8, bk=128)
    state = {"adjacency": ell, "weights": bsr,
             "step": jnp.asarray(3, jnp.int32)}

    path = ckpt.save(str(tmp_path), 3, state)
    assert os.path.isdir(path)
    restored = ckpt.restore(str(tmp_path), 3, state)

    assert isinstance(restored["adjacency"], type(ell))
    assert isinstance(restored["weights"], type(bsr))
    assert restored["adjacency"].shape == ell.shape
    assert restored["weights"].shape == bsr.shape
    for a, b in zip(jax.tree.leaves(state), jax.tree.leaves(restored)):
        np.testing.assert_array_equal(np.asarray(a), np.asarray(b))
    np.testing.assert_array_equal(
        np.asarray(restored["adjacency"].todense()), np.asarray(ell.todense())
    )
    np.testing.assert_array_equal(
        np.asarray(restored["weights"].todense()), np.asarray(bsr.todense())
    )


def test_data_stream_deterministic_resume():
    """(seed, step) contract: batch at step N identical however we got there."""
    b1 = batch_at_step(CFG, SHAPES["train_4k"], seed=3, step=17,
                       batch_override=2, seq_override=16)
    b2 = batch_at_step(CFG, SHAPES["train_4k"], seed=3, step=17,
                       batch_override=2, seq_override=16)
    np.testing.assert_array_equal(b1["tokens"], b2["tokens"])
    b3 = batch_at_step(CFG, SHAPES["train_4k"], seed=3, step=18,
                       batch_override=2, seq_override=16)
    assert not np.array_equal(b1["tokens"], b3["tokens"])


def test_crash_restart_resumes_and_finishes(tmp_path):
    """End-to-end C7: crash mid-run, restart resumes from checkpoint at the
    right step and data position, training completes."""
    kw = dict(num_steps=12, batch_override=2, seq_override=16,
              ckpt_dir=str(tmp_path), ckpt_every=5, log_every=100,
              log_fn=lambda *a: None)
    with pytest.raises(RuntimeError):
        train_loop.run_training(
            CFG, SHAPES["train_4k"],
            failure_injector=FailureInjector({8: "crash"}), **kw)
    assert ckpt.latest_step(str(tmp_path)) == 5
    state, losses, _ = train_loop.run_training(CFG, SHAPES["train_4k"], **kw)
    assert len(losses) == 12 - 5  # resumed from step 5
    assert int(state["opt"]["step"]) == 12


def test_restarted_run_matches_uninterrupted(tmp_path):
    """Determinism across restart: same final loss as a straight run."""
    kw = dict(num_steps=8, batch_override=2, seq_override=16,
              log_every=100, log_fn=lambda *a: None)
    _, straight, _ = train_loop.run_training(CFG, SHAPES["train_4k"], **kw)
    with pytest.raises(RuntimeError):
        train_loop.run_training(
            CFG, SHAPES["train_4k"], ckpt_dir=str(tmp_path), ckpt_every=4,
            failure_injector=FailureInjector({6: "crash"}), **kw)
    _, resumed, _ = train_loop.run_training(
        CFG, SHAPES["train_4k"], ckpt_dir=str(tmp_path), ckpt_every=4, **kw)
    np.testing.assert_allclose(straight[-1], resumed[-1], rtol=1e-4)


def test_straggler_monitor():
    m = StragglerMonitor(threshold=2.0)
    for _ in range(10):
        assert not m.observe(0.1)
    assert m.observe(0.5)  # 5x EWMA
    assert m.events == 1
    assert not m.should_exclude
    m.observe(0.5), m.observe(0.5)
    assert m.should_exclude


def test_microbatched_grads_match_full_batch():
    from repro.core.pipeline import microbatched
    from repro.models import registry

    params = registry.init_params(CFG, jax.random.PRNGKey(0))
    batch = registry.make_batch(CFG, SHAPES["train_4k"], batch_override=4,
                                seq_override=16)
    def lg(p, b):
        return jax.value_and_grad(lambda q: registry.loss_fn(q, CFG, b))(p)

    l_full, g_full = lg(params, batch)
    l_micro, g_micro = microbatched(lg, 2)(params, batch)
    np.testing.assert_allclose(float(l_full), float(l_micro), rtol=1e-5)
    for a, b in zip(jax.tree.leaves(g_full), jax.tree.leaves(g_micro)):
        np.testing.assert_allclose(np.asarray(a, np.float32),
                                   np.asarray(b, np.float32),
                                   rtol=1e-3, atol=1e-3)


def test_grad_compression_training_still_descends():
    state, losses, _ = train_loop.run_training(
        CFG, SHAPES["train_4k"], num_steps=15, batch_override=2,
        seq_override=16, grad_compression=True, log_every=100,
        log_fn=lambda *a: None)
    assert losses[-1] < losses[0]
