"""Autotuner tests: the VMEM feasibility model, candidate pruning, the
never-worse-than-default selection rule, and the tuning-record round trip."""
import json

import jax.numpy as jnp
import numpy as np
import pytest

from repro.kernels import registry
from repro.launch import autotune as at


@pytest.fixture(autouse=True)
def _clean_registry_state():
    yield
    registry.set_default_impl(None)
    registry.clear_block_overrides()


def _rng():
    return np.random.default_rng(0)


# ---------------------------------------------------------------------------
# VMEM feasibility model
# ---------------------------------------------------------------------------


def test_vmem_bytes_gemm_arithmetic():
    from repro.kernels.gemm import gemm_program

    prog = gemm_program(
        256, 256, 256, 128, 128, 128,
        a_dtype=jnp.float32, b_dtype=jnp.float32,
        out_dtype=jnp.float32, accum_dtype=jnp.float32,
    )
    tile = 128 * 128 * 4
    # three double-buffered f32 tile streams + one f32 accumulator scratch
    assert prog.vmem_bytes() == 3 * 2 * tile + tile


def test_vmem_bytes_counts_scratch_dtype():
    from repro.kernels.flash_attention import flash_attention_program

    prog = flash_attention_program(
        1, 1, 1, 64, 8, 4, 4, 16, 16, jnp.float32, jnp.float32, jnp.float32,
        scale=1.0, causal=True, window=0, q_offset=0, sk=64,
    )
    streams = 2 * (16 * 8 * 4) * 4  # q, k, v, o blocks double-buffered
    scratch = (16 * 1 + 16 * 1 + 16 * 8) * 4  # m, l, acc f32 scratch
    assert prog.vmem_bytes() == streams + scratch


# ---------------------------------------------------------------------------
# Search: pruning + selection
# ---------------------------------------------------------------------------


def test_autotune_prunes_infeasible_before_timing():
    case = at.DEFAULT_SUITE["gemm"](_rng())
    timed_blocks = []

    def fake_time(case_, blocks):
        timed_blocks.append(dict(blocks))
        return 1.0

    # 500 kB budget: the 256-cube default (1.8 MB) is infeasible, 64/128 fit
    entry = at.autotune_case(
        case, budget_bytes=500_000, time_candidate=fake_time
    )
    assert any(p["blocks"]["bm"] == 256 for p in entry["pruned"])
    assert all(p["vmem_bytes"] > 500_000 for p in entry["pruned"])
    assert all(b["bm"] != 256 for b in timed_blocks)  # never compiled/timed
    assert entry["default_us"] is None  # default itself was infeasible
    assert entry["blocks"]["bm"] in (64, 128)


def test_autotune_selection_never_worse_than_default():
    case = at.DEFAULT_SUITE["gemm"](_rng())

    # default (256-cube) measures fastest: selection must keep it
    entry = at.autotune_case(
        case, time_candidate=lambda c, b: float(1000 - b["bm"]),
    )
    assert entry["blocks"] == entry["default_blocks"]
    assert entry["us_per_call"] == entry["default_us"]

    # a non-default candidate measures fastest: selection takes it, and the
    # recorded tuned time is never above the default's
    entry = at.autotune_case(
        case, time_candidate=lambda c, b: float(b["bm"]),
    )
    assert entry["blocks"]["bm"] == 64
    assert entry["us_per_call"] <= entry["default_us"]


def test_autotune_restores_overrides_after_search():
    case = at.DEFAULT_SUITE["gemm"](_rng())
    registry.set_block_override("gemm", bm=128)
    at.autotune_case(case, time_candidate=lambda c, b: 1.0)
    # the search staged candidates through block_override scopes only
    assert registry.block_defaults("gemm")["bm"] == 128


# ---------------------------------------------------------------------------
# Record: save/load/apply round trip
# ---------------------------------------------------------------------------


def _toy_record():
    rng = _rng()
    entries = {}
    for name in ("gemm", "flash_attention"):
        case = at.DEFAULT_SUITE[name](rng)
        entries[at.case_key(case.op, case.args, "cpu", "xla")] = (
            at.autotune_case(
                case,
                time_candidate=lambda c, b: float(sum(b.values())),
            )
        )
    return {"version": at.RECORD_VERSION, "backend": "cpu", "impl": "xla",
            "entries": entries}


def test_record_roundtrip_applies_same_selections(tmp_path):
    record = _toy_record()
    path = str(tmp_path / "rec.json")
    at.save_record(record, path)
    loaded = at.load_record(path)
    assert loaded == json.loads(json.dumps(record))  # JSON-stable

    registry.clear_block_overrides()
    applied = at.apply_record(loaded)
    # reloading reproduces the exact selections, through the override seam
    assert applied == {
        e["op"]: e["blocks"] for e in record["entries"].values()
    }
    for e in record["entries"].values():
        assert registry.block_defaults(e["op"]) == e["blocks"]


def test_apply_record_rejects_foreign_environment():
    record = _toy_record()
    record["backend"] = "tpu"  # tuned elsewhere
    with pytest.raises(ValueError, match="re-run the autotuner"):
        at.apply_record(record)
    assert registry.block_defaults("gemm", overrides=True) == \
        registry.block_defaults("gemm", overrides=False)  # nothing applied
    at.apply_record(record, force=True)  # explicit escape hatch works


def test_autotune_rejects_unknown_ops_subset():
    with pytest.raises(KeyError, match="unknown autotune ops"):
        at.autotune(["gemmm"], suite=at.DEFAULT_SUITE)


def test_all_pruned_entry_survives_reporting():
    case = at.DEFAULT_SUITE["gemm"](_rng())
    entry = at.autotune_case(
        case, budget_bytes=1, time_candidate=lambda c, b: 1.0
    )
    assert entry["timed"] == [] and entry["us_per_call"] is None
    assert entry["blocks"] == entry["default_blocks"]  # falls back to default
    record = {"version": at.RECORD_VERSION, "backend": "cpu", "impl": "xla",
              "entries": {"k": entry}}
    deltas = at.record_deltas(record)  # must not crash on None times
    assert deltas["gemm"]["us_per_call"] is None
    assert deltas["gemm"]["delta_pct"] is None


def test_load_record_rejects_unknown_version(tmp_path):
    record = _toy_record()
    record["version"] = 99
    path = str(tmp_path / "bad.json")
    at.save_record(record, path)
    with pytest.raises(ValueError, match="version"):
        at.load_record(path)


def test_record_deltas_math():
    record = _toy_record()
    for e in record["entries"].values():  # synthetic, deterministic times
        e["us_per_call"], e["default_us"] = 50.0, 100.0
        e["blocks"] = dict(e["default_blocks"], **{"bm": 1}) \
            if "bm" in e["default_blocks"] else e["blocks"]
    deltas = at.record_deltas(record)
    for op, d in deltas.items():
        assert d["delta_pct"] == -50.0
        assert d["us_per_call"] <= d["default_us"]
    assert deltas["gemm"]["non_default"]


def test_case_key_is_shape_and_dtype_specific():
    a = jnp.zeros((4, 8), jnp.float32)
    b = jnp.zeros((4, 8), jnp.bfloat16)
    k1 = at.case_key("gemm", (a,), "cpu", "xla")
    k2 = at.case_key("gemm", (b,), "cpu", "xla")
    assert k1 != k2
    assert "4x8" in k1 and "float32" in k1 and "cpu" in k1 and "xla" in k1


# ---------------------------------------------------------------------------
# Tuning under a mesh: records key by the LOCAL shard geometry
# (regression for the ROADMAP bug: global-shape keys made mesh-tuned
# records indistinguishable from — and silently interchangeable with —
# single-device ones, despite tuning entirely different kernel shapes)
# ---------------------------------------------------------------------------


def _mesh_2x4():
    from repro.kernels import partition

    return partition.MeshSpec({"data": 2, "model": 4})


def test_autotune_record_keys_by_local_shard_geometry():
    mesh = _mesh_2x4()
    rec = at.autotune(["gemm"], mesh=mesh, time_candidate=lambda c, b: 1.0)
    (key,) = rec["entries"]
    # the 256x256x256 gemm K-shards 4-way over model: the tuned geometry is
    # the 256x64 / 64x256 local tiles, and the record key says so
    assert "256x64" in key and "64x256" in key and "256x256" not in key
    assert rec["mesh"] == "2x4"
    rec_flat = at.autotune(["gemm"], time_candidate=lambda c, b: 1.0)
    (key_flat,) = rec_flat["entries"]
    assert "256x256" in key_flat and rec_flat.get("mesh") is None
    assert key != key_flat


def test_autotune_mesh_keys_ops_with_plan_kwargs():
    # ops whose PartitionRule needs keyword operands (num_rows, offsets,
    # contraction_dim) must still resolve local geometry for keying
    mesh = _mesh_2x4()
    rec = at.autotune(["bsr_spmm", "spmspm", "stencil"], mesh=mesh,
                      time_candidate=lambda c, b: 1.0)
    keys = sorted(rec["entries"])
    by_op = {k.split("|")[0]: k for k in keys}
    # stencil: X=64 x-sharded 4-way -> 16-plane slabs key the record
    assert by_op["stencil"].split("|")[1].startswith("16x32x32")
    # spmspm: A rows 128 -> 32 per device; B replicated stays whole
    assert "32x" in by_op["spmspm"] and "128x" in by_op["spmspm"]


def test_local_case_shapes_replicated_plan_matches_flat_key():
    # a case whose plan resolves to replication keys exactly like the
    # unmeshed case: same local kernel, same evidence, same record entry
    rng = _rng()
    case = at.DEFAULT_SUITE["flash_attention"](rng)
    case.mesh = _mesh_2x4()  # 4 heads on a 4-way axis shards; force a miss
    case.args = tuple(
        jnp.zeros((1, 5, 63, 16), jnp.float32) for _ in range(3)
    )  # 5 kv heads: TP-hostile; B=1 and odd seq defeat batch AND ring
    shapes = at.local_case_shapes(case, "xla")
    assert [s.shape for s in shapes] == [a.shape for a in case.args]


def test_local_case_shapes_ring_plan_keys_by_seq_shard():
    # the default flash case (B=1, Sq=Sk=256) rides the seq-parallel ring
    # under a mesh: the record keys by the per-device Q/KV chunk geometry
    rng = _rng()
    case = at.DEFAULT_SUITE["flash_attention"](rng)
    case.mesh = _mesh_2x4()
    shapes = at.local_case_shapes(case, "xla")
    # data=2 halves the sequence; model=4 shards the 4 heads
    assert [s.shape for s in shapes] == [(1, 1, 128, 64)] * 3


def test_record_matches_environment_is_mesh_aware(tmp_path):
    record = _toy_record()  # tuned without a mesh
    assert at.record_matches_environment(record)
    assert not at.record_matches_environment(record, mesh=_mesh_2x4())
    with pytest.raises(ValueError, match="re-run the autotuner"):
        at.apply_record(record, mesh=_mesh_2x4())
    record["mesh"] = "2x4"
    assert at.record_matches_environment(record, mesh=_mesh_2x4())
    at.apply_record(record, mesh=_mesh_2x4())  # applies cleanly when tuned
    assert not at.record_matches_environment(record)  # and not flat anymore


# ---------------------------------------------------------------------------
# Precision-scoped entries (the policy suite: gemm@fp8, gemm@bf16)
# ---------------------------------------------------------------------------


def test_precision_suite_entries_never_collide_with_legacy():
    rec = at.autotune(["gemm", "gemm@fp8", "gemm@bf16"], suite=at.full_suite(),
                      time_candidate=lambda c, b: 1.0)
    keys = sorted(rec["entries"])
    assert len(keys) == 3
    legacy = [k for k in keys if not (k.endswith("|fp8")
                                      or k.endswith("|bf16"))]
    assert len(legacy) == 1
    # the scaled cases dispatch the SAME fp32 operands as the legacy case
    # (quantization happens inside the impl): everything up to the policy
    # suffix is identical, and only the suffix keeps the entries apart
    for k in keys:
        if k not in legacy:
            assert k.rsplit("|", 1)[0] == legacy[0], (k, legacy)
    assert {e["precision"] for e in rec["entries"].values()} == \
        {None, "fp8", "bf16"}
    # reporting disambiguates the policy-scoped rows as op@policy
    deltas = at.record_deltas(rec)
    assert {"gemm", "gemm@fp8", "gemm@bf16"} <= set(deltas)


def test_apply_record_never_cross_applies_policies():
    rec = at.autotune(["gemm", "gemm@fp8", "gemm@bf16"], suite=at.full_suite(),
                      time_candidate=lambda c, b: 1.0)
    # force a distinct winner per policy so cross-application is observable
    want = {None: 256, "fp8": 64, "bf16": 128}
    for e in rec["entries"].values():
        e["blocks"] = dict(e["blocks"], bm=want[e["precision"]])
    for pol, bm in want.items():
        registry.clear_block_overrides()
        applied = at.apply_record(rec, precision=pol)
        # exactly the matching entry applies: an fp8-tuned geometry is not
        # evidence about the unscaled kernel (or bf16's), and vice versa
        assert set(applied) == {"gemm"} and applied["gemm"]["bm"] == bm
        assert registry.block_defaults("gemm")["bm"] == bm


# ---------------------------------------------------------------------------
# Consumer-scoped entries (the shape-class suite: flash_attention#prefill,
# flash_attention#decode, decode_attention#decode)
# ---------------------------------------------------------------------------


def test_consumer_suite_entries_never_collide():
    rec = at.autotune(
        ["decode_attention", "decode_attention#decode",
         "flash_attention#prefill", "flash_attention#decode"],
        suite=at.full_suite(), time_candidate=lambda c, b: 1.0)
    keys = sorted(rec["entries"])
    assert len(keys) == 4
    # decode_attention probes the SAME operand geometry tagged and
    # untagged: only the #consumer suffix keeps the entries apart
    da = [k for k in keys if k.startswith("decode_attention")]
    assert len(da) == 2
    tagged = next(k for k in da if k.endswith("#decode"))
    untagged = next(k for k in da if not k.endswith("#decode"))
    assert tagged == untagged + "#decode"
    # the two flash consumers differ in BOTH the tag and the q geometry
    # (prefill B x S rows vs decode's single row)
    fa = [k for k in keys if k.startswith("flash_attention")]
    assert {k.rsplit("#", 1)[1] for k in fa} == {"prefill", "decode"}
    assert {e.get("consumer") for e in rec["entries"].values()} == \
        {None, "prefill", "decode"}
    # reporting disambiguates the consumer-scoped rows as op#consumer
    deltas = at.record_deltas(rec)
    assert {"decode_attention", "decode_attention#decode",
            "flash_attention#prefill", "flash_attention#decode"} <= \
        set(deltas)


def test_apply_record_never_cross_applies_consumers():
    rec = at.autotune(
        ["decode_attention", "decode_attention#decode"],
        suite=at.full_suite(), time_candidate=lambda c, b: 1.0)
    # force a distinct winner per consumer so cross-application shows
    want = {None: 1024, "decode": 128}
    for e in rec["entries"].values():
        e["blocks"] = dict(e["blocks"], bs=want[e["consumer"]])
    for consumer, bs in want.items():
        registry.clear_block_overrides()
        applied = at.apply_record(rec, consumer=consumer)
        # exactly the matching entry applies: a prefill-shape geometry is
        # not evidence about the decode step's one-row grid, and a legacy
        # untagged entry never leaks into a consumer-scoped session
        assert set(applied) == {"decode_attention"}
        assert applied["decode_attention"]["bs"] == bs
        assert registry.block_defaults("decode_attention")["bs"] == bs
    registry.clear_block_overrides()


def test_legacy_records_without_consumer_field_apply_as_untagged():
    # records written before the consumer axis lack the key entirely:
    # entry.get("consumer") is None, so they match consumer=None only
    rec = at.autotune(["decode_attention"], suite=at.full_suite(),
                      time_candidate=lambda c, b: 1.0)
    for e in rec["entries"].values():
        del e["consumer"]  # simulate a pre-consumer-axis record
    registry.clear_block_overrides()
    assert set(at.apply_record(rec)) == {"decode_attention"}
    registry.clear_block_overrides()
    assert at.apply_record(rec, consumer="decode") == {}
    registry.clear_block_overrides()
