"""End-to-end behaviour tests for the full system."""
import jax
import jax.numpy as jnp
import numpy as np

from repro.configs.base import SHAPES, get_config
from repro.launch.serve import generate
from repro.models import gcn, registry
from repro.runtime import train_loop


def test_training_reduces_loss():
    cfg = get_config("gemma-2b", reduced=True).replace(
        learning_rate=3e-3, warmup_steps=5)
    _, losses, _ = train_loop.run_training(
        cfg, SHAPES["train_4k"], num_steps=30, batch_override=4,
        seq_override=32, log_every=100, log_fn=lambda *a: None)
    assert losses[-1] < losses[0] - 0.1, (losses[0], losses[-1])


def test_generate_end_to_end():
    cfg = get_config("occamy-gptj", reduced=True)
    params = registry.init_params(cfg, jax.random.PRNGKey(0))
    tokens = jnp.asarray(
        np.random.default_rng(0).integers(0, cfg.vocab_size, (2, 8)), jnp.int32)
    out = generate(cfg, params, tokens, gen_len=6, max_len=16)
    assert out.shape == (2, 14)
    assert bool(jnp.all(out[:, :8] == tokens))
    assert bool(jnp.all((out >= 0) & (out < cfg.vocab_size)))


def test_generate_ssm_and_hybrid():
    for arch in ("rwkv6-3b", "hymba-1.5b"):
        cfg = get_config(arch, reduced=True)
        params = registry.init_params(cfg, jax.random.PRNGKey(0))
        tokens = jnp.asarray(
            np.random.default_rng(0).integers(0, cfg.vocab_size, (2, 6)),
            jnp.int32)
        out = generate(cfg, params, tokens, gen_len=4, max_len=12)
        assert out.shape == (2, 10)


def test_gcn_layer_mixed_dense_sparse():
    """The paper's GCN workload: aggregation via spmm + dense recombination."""
    from repro.core import sparse

    rng = np.random.default_rng(0)
    n, f = 64, 16
    adj = sparse.random_ell(rng, n, n, 0.05)
    feats = jnp.asarray(rng.standard_normal((n, f)), jnp.float32)
    params = gcn.init_params(jax.random.PRNGKey(0), [f, f, f])
    # adjacency is an EllMatrix pytree: jit the whole mixed forward
    out = jax.jit(lambda a, x: gcn.forward(params, a, x))(adj, feats)
    assert out.shape == (n, f)
    assert bool(jnp.all(jnp.isfinite(out)))
    # oracle check against densified adjacency
    a_dense = jnp.asarray(adj.todense())
    want = feats
    for i, w in enumerate(params):
        want = a_dense @ (want @ w)
        if i < len(params) - 1:
            want = jax.nn.relu(want)
    np.testing.assert_allclose(np.asarray(out), np.asarray(want),
                               rtol=1e-3, atol=1e-3)


def test_elastic_remesh_state_survives():
    from repro.runtime.fault_tolerance import elastic_remesh, reshard_state

    cfg = get_config("gemma-2b", reduced=True)
    state = train_loop.init_train_state(cfg, jax.random.PRNGKey(0))
    mesh, new_dp = elastic_remesh(data_parallel=1, model_parallel=1,
                                  lost_ranks=0)
    assert new_dp == 1
    state2 = reshard_state(state, cfg, mesh)
    for a, b in zip(jax.tree.leaves(state), jax.tree.leaves(state2)):
        np.testing.assert_array_equal(np.asarray(a), np.asarray(b))


def test_moe_capacity_drops_are_bounded():
    """With cf=1.0 and uniform routing, drops stay a small fraction."""
    from repro.models import moe

    cfg = get_config("phi3.5-moe-42b-a6.6b", reduced=True).replace(
        capacity_factor=1.0)
    params = registry.init_params(cfg, jax.random.PRNGKey(0))
    lp = {k: v[0] for k, v in params["layers"].items()
          if k.startswith("moe") or k == "router"}
    x = jnp.asarray(np.random.default_rng(0).standard_normal(
        (2, 64, cfg.d_model)), jnp.float32)
    out, aux = moe.moe_mlp(lp, x, cfg)
    assert out.shape == x.shape
    # dropped tokens produce zero output rows; most rows must be nonzero
    nonzero = float(jnp.mean(jnp.any(out != 0, axis=-1)))
    assert nonzero > 0.5, nonzero
