"""Fixture partition module: long enough to satisfy the module-docstring
check, so the member-level findings below are the only ones."""
import dataclasses


def plan_for(op, mesh):
    # SEEDED VIOLATION (docstring-contract): public function, no docstring
    return None


def sharded_call(op, mesh, *operands):
    """Dispatch the op over the mesh — a docstring long enough to pass the
    length gate but incomplete: ``op`` and ``mesh`` appear, while the
    variadic positional parameter is never named, seeding the
    parameter-coverage finding."""
    return None


@dataclasses.dataclass(frozen=True)
class PartitionPlan:
    """A resolved partitioning of one op call; documents ``op`` but says
    nothing about the second field, seeding the field-coverage finding."""

    op: str
    levels: tuple
