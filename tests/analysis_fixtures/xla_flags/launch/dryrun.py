# SEEDED VIOLATIONS (xla-flags-append-only): a launcher that clobbers
# caller-set XLA_FLAGS with a bare assignment and never routes through the
# shared append-only bootstrap helper.
import os

os.environ["XLA_FLAGS"] = "--xla_force_host_platform_device_count=512"


def main():
    return os.environ["XLA_FLAGS"]
