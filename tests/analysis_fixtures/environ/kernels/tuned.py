# SEEDED VIOLATIONS (no-environ-in-kernels): a kernel module reading the
# process environment, both spellings.
import os


def tuned_block(x):
    bm = int(os.environ.get("SECRET_BM", "128"))
    bn = int(os.getenv("SECRET_BN", "128"))
    return x, bm, bn
