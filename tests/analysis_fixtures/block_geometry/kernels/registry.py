# Fixture registry: one op in the block table, so the single-path coverage
# half of block-geometry-registry-only has something to demand of ops.py.
_BLOCK_DEFAULTS = {
    "gemm": {"bm": 256, "bk": 256, "bn": 256},
}


def resolve_blocks(op, **explicit):
    return dict(_BLOCK_DEFAULTS[op], **explicit)
