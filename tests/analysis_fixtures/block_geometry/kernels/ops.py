# SEEDED VIOLATION (block-geometry-registry-only): "gemm" is in the
# fixture registry's block table but this ops.py never routes its blocks
# through the registry's resolution helper — split-brain geometry.


def gemm(a, b):
    return a @ b
