# SEEDED VIOLATIONS (block-geometry-registry-only), one per line flagged:
# a block-size integer literal in a call, private block_defaults plumbing,
# and the REPRO_UNROLL_GRID environment escape hatch.


def _inner(x, bk=None):
    return x


def flashy(x):
    y = _inner(x, bk=512)
    table = {"flashy": {"bk": 512}}

    def block_defaults(op):
        return table[op]

    flag = "REPRO_UNROLL_GRID"
    return y, block_defaults("flashy"), flag
