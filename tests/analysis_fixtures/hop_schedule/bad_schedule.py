"""Seeded-bad hop schedule: semaphore wait deferred past the fold.

``EVENTS`` issues hop 1's RDMA copy, folds hop 0, then folds hop 1
*before* waiting on the copy's semaphore. Replayed in program order the
fold happens to read the right buffer — but the landing is asynchronous:
in the interleaving where the fabric delivers late, the fold reads a
buffer whose copy has not landed. The plan tier's single-trace replay
flags the missing wait-before-fold ordering; the model tier's
``explore_hop_interleavings`` proves the *race* — it exhibits the legal
reordering in the finding's counterexample trace.

Imported by ``tests/test_explore.py``; the ``overlap-interleavings``
engine must report exactly one race here and none on any published
``ring_schedule``.
"""
from repro.parallel.collectives import HopEvent

HOPS = 2

EVENTS = (
    HopEvent("dma_start", 1, 0, 1),  # issue hop 1's copy into buffer 1
    HopEvent("fold", 0, 0),          # fold hop 0 from buffer 0
    HopEvent("fold", 1, 1),          # BUG: consumes buffer 1 pre-wait
    HopEvent("dma_wait", 1, None, 1),
)
