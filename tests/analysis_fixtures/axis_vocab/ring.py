# SEEDED VIOLATIONS (axis-name-vocabulary): collectives over axis names
# the partition layer never produces.
import jax


def rowwise_sum(x):
    total = jax.lax.psum(x, "rows")
    me = jax.lax.axis_index("shard")
    return total, me
