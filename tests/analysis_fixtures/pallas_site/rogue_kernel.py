# SEEDED VIOLATION (single-pallas-site): a second pallas_call launch site
# outside core/streams.py.
from jax.experimental import pallas as pl


def rogue_launch(body, x):
    return pl.pallas_call(body, out_shape=x)(x)
