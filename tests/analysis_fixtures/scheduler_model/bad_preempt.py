"""Seeded-bad scheduler model: double-free on preempt.

``BadPreemptModel`` overrides ``SchedulerModel._preempt`` to release the
victim's blocks to the free list TWICE — the classic paged-cache ledger
bug where the eviction path both pushes the blocks and forgets they were
already pushed. Only an interleaving that actually *preempts* exposes it,
which is exactly what the exhaustive explorer finds and a happy-path
trace never does.

Imported (not just parsed) by ``tests/test_explore.py``: the
``scheduler-model`` rule's engine must report the double-free with an
exact finding count on ``CONFIG`` and stay silent on the pristine model.
"""
from repro.analysis.explore import RequestSpec, SchedulerConfig, SchedulerModel

# tight pool + two slots so decode growth must evict: rid 0 holds two
# blocks across steps (max_new 3 keeps it non-terminal) while rid 1's two
# admission blocks drain the pool, so rid 0's third-block growth preempts
# — and every preemption goes through the seeded-bad release path
CONFIG = SchedulerConfig(
    num_blocks=5, block_size=1, max_slots=2, requests=(
        RequestSpec(rid=0, prompt_len=1, max_new_tokens=3, priority=0),
        RequestSpec(rid=1, prompt_len=2, max_new_tokens=2, priority=0),
    ))


class BadPreemptModel(SchedulerModel):
    """SchedulerModel whose preempt path frees the victim's blocks twice."""

    def _preempt(self, queues, running, free, vslot, vblocks):
        super()._preempt(queues, running, free, vslot, vblocks)
        free.extend(vblocks)  # the bug: released again
