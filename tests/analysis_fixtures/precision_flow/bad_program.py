"""Seeded-bad precision dataflow: fp8 streams, fp16 accumulator, no scales.

``make_program()`` builds a StreamProgram that streams fp8 values but
accumulates in float16 and carries no fp32 scale streams — both halves of
the block-scaling contract broken at once (saturating accumulation AND
narrowing without a scale), so ``check_dtype_dataflow`` must report
exactly two problems. ``make_pool()`` builds a PagedKVCache whose value
pools are fp8 with ``k_scale``/``v_scale`` dropped — the quantized-pool
bypass ``check_quantized_pool`` must flag once per pool side.

Imported by ``tests/test_explore.py`` (needs jax for the dtypes; fixture
factories are functions so importing the module stays cheap).
"""
import jax
import jax.numpy as jnp

from repro.core.streams import AffineStream, StreamProgram
from repro.serving.paged_cache import PagedKVCache

BM = BN = BK = 8


def make_program() -> StreamProgram:
    """fp8 gemm tile with a float16 accumulator and no scale streams."""
    f8 = jnp.float8_e4m3fn
    return StreamProgram(
        name="bad_fp8_gemm",
        body=lambda a, b, o, acc: None,
        grid=(2, 2, 2),
        in_streams=(
            AffineStream((BM, BK), lambda i, j, k: (i, k), dtype=f8),
            AffineStream((BK, BN), lambda i, j, k: (k, j), dtype=f8),
        ),
        out_streams=(
            AffineStream((BM, BN), lambda i, j, k: (i, j),
                         dtype=jnp.float16),
        ),
        out_shapes=(jax.ShapeDtypeStruct((2 * BM, 2 * BN), jnp.float16),),
        scratch=(jax.ShapeDtypeStruct((BM, BN), jnp.float16),),  # BUG
    )


def make_pool() -> PagedKVCache:
    """fp8 KV pools whose per-row scales were dropped."""
    shape = (1, 3, 2, 2, 4)  # (nl, P, K, bs, hd)
    return PagedKVCache(
        k_pool=jnp.zeros(shape, jnp.float8_e4m3fn),
        v_pool=jnp.zeros(shape, jnp.float8_e4m3fn),
        k_scale=None,  # BUG: quantized reads bypass the scales
        v_scale=None,
        block_size=2,
        policy="fp8",
    )
