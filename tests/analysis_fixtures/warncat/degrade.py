# SEEDED VIOLATIONS (warn-category): warnings.warn without an explicit
# category — an anonymous UserWarning nobody can filter on.
import warnings
from warnings import warn


def degrade(msg):
    warnings.warn(msg)
    warn(f"also anonymous: {msg}")
