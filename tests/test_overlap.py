"""Latency-tolerant ring overlap: equivalence + warm-start autotune tests.

The overlapped schedules (double-buffered ring in parallel/collectives.py,
zigzag causal KV ring and split halo stencil in kernels/partition.py) must
be DROP-IN: every ``overlap=True`` path has its synchronous oracle behind
``overlap=False``, and the two must agree exactly — overlap only moves
*when* the hop transfer is issued, never what is computed. The 8-device
checks run in a subprocess with forced host devices (like
tests/test_partition.py) so the device-count flag never leaks.

The autotune half pins the warm-start contract: feasible candidates are
measured in roofline-prior order (the analytic top pick first) and a
``trial_budget`` cuts the modeled-slow tail while the default geometry
always stays measured.
"""
import json
import os
import subprocess
import sys
import textwrap

import numpy as np
import pytest

_OVERLAP_EQUIV = textwrap.dedent(
    """
    import os
    os.environ["XLA_FLAGS"] = "--xla_force_host_platform_device_count=8"
    import json
    import jax, jax.numpy as jnp, numpy as np
    from jax.sharding import PartitionSpec as P
    from repro.kernels import ops, partition
    from repro.parallel.collectives import ring_scan_carry
    from repro.parallel.compat import shard_map

    mesh = jax.make_mesh((4, 2), ("data", "model"))
    rng = np.random.default_rng(0)
    f32 = jnp.float32
    out = {"ok": [], "exact": [], "notes": {}}

    def check(name, got, want, tol=1e-4):
        err = float(jnp.max(jnp.abs(jnp.asarray(got) - jnp.asarray(want))))
        assert err < tol, (name, err)
        out["ok"].append(name)

    def check_exact(name, got, want):
        # overlap vs sync: same math in the same order, only the hop
        # transfer is issued earlier -- must agree bitwise
        err = float(jnp.max(jnp.abs(jnp.asarray(got) - jnp.asarray(want))))
        assert err == 0.0, (name, err)
        out["exact"].append(name)

    # B=1 forces the ring; Sq=64 over data=4 gives 8 zigzag half-chunks
    q = jnp.asarray(rng.standard_normal((1, 8, 64, 16)), f32)
    kv = jnp.asarray(rng.standard_normal((1, 2, 64, 16)), f32)

    # mask matrix: zigzag engages only on plain-causal; windowed and
    # non-causal fall back to the legacy hop schedule (still overlapped)
    kws = [dict(causal=True), dict(causal=True, window=9),
           dict(causal=False), dict(causal=False, window=9)]
    for kw in kws:
        tag = f"w{kw.get('window', 0)}c{int(kw['causal'])}"
        plan = partition.plan_for("flash_attention", mesh, q, kv, kv, **kw)
        zig = kw["causal"] and not kw.get("window", 0)
        assert ("zigzag" in plan.note) == zig, (kw, plan.note)
        # a lookback window prunes wrapped hops (w=9 < the 16-row chunk
        # leaves 2); every variant keeps at least one hop to overlap
        assert plan.overlappable and plan.hops >= 2, (kw, plan.note)
        if zig:
            assert plan.hops == 4, plan.note
        out["notes"][tag] = plan.note
        for impl in ("interpret", "xla", "ref"):
            want = ops.flash_attention(q, kv, kv, impl="ref", **kw)
            o_ovl, lse_ovl = ops.flash_attention(
                q, kv, kv, mesh=mesh, impl=impl, overlap=True,
                return_lse=True, **kw)
            o_sync, lse_sync = ops.flash_attention(
                q, kv, kv, mesh=mesh, impl=impl, overlap=False,
                return_lse=True, **kw)
            check(f"ring[{impl}]{tag}", o_ovl, want)
            check_exact(f"ring_o[{impl}]{tag}", o_ovl, o_sync)
            check_exact(f"ring_lse[{impl}]{tag}", lse_ovl, lse_sync)

    # zigzag explicitly disabled: the legacy causal ring, still overlapped
    plan = partition.plan_for(
        "flash_attention", mesh, q, kv, kv, zigzag=False)
    assert "zigzag" not in plan.note and plan.overlappable
    check("ring_nozig",
          ops.flash_attention(q, kv, kv, mesh=mesh, impl="xla", zigzag=False),
          ops.flash_attention(q, kv, kv, impl="ref"))
    check_exact(
        "ring_nozig_sync",
        ops.flash_attention(q, kv, kv, mesh=mesh, impl="xla", zigzag=False,
                            overlap=True),
        ops.flash_attention(q, kv, kv, mesh=mesh, impl="xla", zigzag=False,
                            overlap=False))

    # zigzag-ineligible sequence length (Sq=68 splits over d=4 but not
    # into 2*d=8 half-chunks): must silently fall back and still match
    q68 = jnp.asarray(rng.standard_normal((1, 8, 68, 16)), f32)
    kv68 = jnp.asarray(rng.standard_normal((1, 2, 68, 16)), f32)
    plan = partition.plan_for("flash_attention", mesh, q68, kv68, kv68)
    assert "zigzag" not in plan.note, plan.note
    check("ring_s68",
          ops.flash_attention(q68, kv68, kv68, mesh=mesh, impl="xla"),
          ops.flash_attention(q68, kv68, kv68, impl="ref"))

    # stencil: split-halo overlapped schedule vs the fused sync oracle
    grid = jnp.asarray(rng.standard_normal((32, 8, 8)), f32)
    offs = np.array([(0, 0, 0), (2, 0, 0), (-2, 0, 0), (0, 1, 0)], np.int32)
    w = np.full((4,), 0.25, np.float32)
    plan = partition.plan_for("stencil", mesh, grid, offsets=offs, weights=w)
    assert "(overlapped)" in plan.note and plan.hops == 2, plan.note
    out["notes"]["stencil"] = plan.note
    for impl in ("interpret", "xla", "ref"):
        s_ovl = ops.stencil(grid, offs, w, mesh=mesh, impl=impl, overlap=True)
        s_sync = ops.stencil(grid, offs, w, mesh=mesh, impl=impl,
                             overlap=False)
        check(f"stencil[{impl}]", s_ovl,
              ops.stencil(grid, offs, w, impl="ref"))
        check_exact(f"stencil_sync[{impl}]", s_ovl, s_sync)
    plan = partition.plan_for(
        "stencil", mesh, grid, offsets=offs, weights=w, overlap=False)
    assert "(overlapped)" not in plan.note and not plan.overlappable

    # ring_scan_carry: the double-buffered carry thread vs the sync loop
    xs = jnp.asarray(rng.standard_normal((8, 4)), f32)

    def chunk(s, x):
        ys = s + jnp.cumsum(x[0])
        return ys[-1], ys[None]

    def local(ov):
        def f(x_l):
            ys, s = ring_scan_carry(chunk, x_l, jnp.float32(0.0), "data", 4,
                                    overlap=ov)
            return ys, s[None]
        return f

    run = lambda ov: shard_map(
        local(ov), mesh=mesh, in_specs=(P("data", None),),
        out_specs=(P("data", None), P("data")), check_vma=False,
    )(xs[:4])
    ys_o, s_o = run(True)
    ys_s, s_s = run(False)
    check_exact("carry_ys", ys_o, ys_s)
    check_exact("carry_final", s_o, s_s)
    check("carry_semantics", ys_o,
          jnp.cumsum(xs[:4].reshape(-1)).reshape(4, 4), tol=1e-5)
    print("RESULT:" + json.dumps(out))
    """
)


def test_overlap_equivalence_8dev():
    env = dict(os.environ)
    env["PYTHONPATH"] = os.path.join(os.path.dirname(__file__), "..", "src")
    proc = subprocess.run(
        [sys.executable, "-c", _OVERLAP_EQUIV],
        capture_output=True, text=True, env=env, timeout=900,
    )
    assert proc.returncode == 0, proc.stderr[-3000:]
    line = [ln for ln in proc.stdout.splitlines() if ln.startswith("RESULT:")][-1]
    out = json.loads(line[len("RESULT:"):])
    # every mask x impl combination matched the single-device reference AND
    # agreed bitwise (o and lse) with its synchronous oracle
    for impl in ("interpret", "xla", "ref"):
        for c, w in ((1, 0), (1, 9), (0, 0), (0, 9)):
            assert f"ring[{impl}]w{w}c{c}" in out["ok"]
            assert f"ring_o[{impl}]w{w}c{c}" in out["exact"]
            assert f"ring_lse[{impl}]w{w}c{c}" in out["exact"]
        assert f"stencil[{impl}]" in out["ok"]
        assert f"stencil_sync[{impl}]" in out["exact"]
    assert "zigzag" in out["notes"]["w0c1"]
    assert "(overlapped)" in out["notes"]["stencil"]
    assert {"ring_nozig", "ring_s68", "carry_semantics"} <= set(out["ok"])
    assert {"ring_nozig_sync", "carry_ys", "carry_final"} <= set(out["exact"])


def test_ring_scan_replays_the_published_schedule():
    """ring_scan executes exactly the HopEvent sequence ring_schedule
    returns — the artifact the repro.analysis overlap-schedule rule
    checks IS the executed schedule, by construction."""
    from repro.parallel import collectives

    calls = []

    def fake_send(x):
        calls.append(("send", x))
        return x + 100

    orig = collectives._hop_send
    collectives._hop_send = lambda axis, n, remote: fake_send
    try:
        folds = []
        collectives.ring_scan(
            lambda carry, block, t: folds.append((t, int(block))) or carry,
            carry=0, block=0, axis="data", n=4, overlap=True,
        )
    finally:
        collectives._hop_send = orig
    # folds consumed hops 0..3 in order, each reading the t-hops-rotated
    # block (one +100 per hop), exactly as the schedule prescribes
    assert folds == [(0, 0), (1, 100), (2, 200), (3, 300)]
    assert len(calls) == 3  # n-1 transfers, issued one hop ahead


def test_remote_copy_fallback_warns_once():
    """remote_copy=True off-TPU degrades to ppermute with one (and only
    one) ReproDegradeWarning — never a silent transport swap."""
    import warnings

    from repro.diagnostics import ReproDegradeWarning, reset_degrade_warnings
    from repro.parallel import collectives

    reset_degrade_warnings()
    try:
        with pytest.warns(ReproDegradeWarning, match="falling back to ppermute"):
            collectives._hop_send("data", 4, True)
        with warnings.catch_warnings():
            warnings.simplefilter("error")  # one-shot: second call is silent
            collectives._hop_send("data", 4, True)
    finally:
        reset_degrade_warnings()


# ---------------------------------------------------------------------------
# Autotune warm start: roofline-prior ordering + trial budget
# ---------------------------------------------------------------------------


def _case():
    from repro.launch import autotune as at

    return at._gemm_case(np.random.default_rng(0))


def test_autotune_measures_prior_top_pick_first():
    from repro.launch import autotune as at

    case = _case()
    entry = at.autotune_case(case, time_candidate=lambda c, b: 1.0)
    priors = [t["prior_s"] for t in entry["timed"]]
    assert priors == sorted(priors)
    # the analytic top pick is the first candidate measured
    all_priors = priors + [s["prior_s"] for s in entry["skipped_by_budget"]]
    assert entry["timed"][0]["prior_s"] == min(all_priors)
    assert entry["timed"][0]["prior_s"] == pytest.approx(
        at.candidate_prior_seconds(case, entry["timed"][0]["blocks"])
    )


def test_autotune_trial_budget_caps_measurements():
    from repro.launch import autotune as at

    case = _case()
    full = at.autotune_case(case, time_candidate=lambda c, b: 1.0)
    n_feasible = len(full["timed"])
    assert n_feasible >= 3  # the gemm case has a real candidate table

    entry = at.autotune_case(
        case, trial_budget=1, time_candidate=lambda c, b: 1.0
    )
    timed_blocks = [t["blocks"] for t in entry["timed"]]
    # prior top pick measured, defaults force-included, everything else
    # skipped with its prior recorded for the audit trail
    assert entry["timed"][0]["blocks"] == full["timed"][0]["blocks"]
    assert entry["default_blocks"] in timed_blocks
    assert len(timed_blocks) <= 2
    assert len(entry["skipped_by_budget"]) == n_feasible - len(timed_blocks)
    assert all(s["blocks"] not in timed_blocks
               for s in entry["skipped_by_budget"])
    assert entry["trial_budget"] == 1


def test_autotune_budget_keeps_default_selection_invariant():
    from repro.launch import autotune as at

    case = _case()
    # adversarial timer: the prior's top pick measures SLOWER than default;
    # under a budget of 1 the default must still be present so the
    # strictly-faster rule can keep it
    defaults = __import__("repro.kernels.registry", fromlist=["registry"]) \
        .block_defaults(case.op, overrides=False)
    entry = at.autotune_case(
        case, trial_budget=1,
        time_candidate=lambda c, b: 1.0 if b == defaults else 2.0,
    )
    assert entry["blocks"] == defaults
    assert entry["default_us"] is not None
