# NOTE: no XLA_FLAGS here on purpose — smoke tests and benches must see the
# real single-device CPU; only launch/dryrun.py (and the subprocess-based
# distribution tests) force a multi-device host platform.
import numpy as np
import pytest


@pytest.fixture
def rng():
    return np.random.default_rng(0)
