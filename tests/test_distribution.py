"""Distribution tests: sharding rules for every arch + a real multi-device
lower/compile, run in a subprocess so the host-device-count flag never leaks
into the other tests' single-device view."""
import json
import os
import subprocess
import sys
import textwrap

import pytest

from repro.configs.base import all_arch_ids

_SUBPROCESS = textwrap.dedent(
    """
    import os
    os.environ["XLA_FLAGS"] = "--xla_force_host_platform_device_count=8"
    import json
    import jax
    import jax.numpy as jnp
    from jax.sharding import NamedSharding, PartitionSpec as P

    from repro.configs.base import all_arch_ids, get_config, SHAPES
    from repro.models import registry
    from repro.parallel import sharding as sh
    from repro.runtime import train_loop

    mesh = jax.make_mesh((4, 2), ("data", "model"))
    out = {"specs_ok": [], "lowered": []}

    # 1) sharding rules produce valid NamedShardings for every FULL config
    for arch in all_arch_ids():
        cfg = get_config(arch)
        tree = registry.param_shapes(cfg)
        for mode in ("train", "serve"):
            specs = sh.param_specs(cfg, tree, mesh, mode)
            def check(leaf, spec):
                s = NamedSharding(mesh, spec)
                s.shard_shape(leaf.shape)  # raises if indivisible
            jax.tree.map(check, tree, specs,
                         is_leaf=lambda x: isinstance(x, P))
        out["specs_ok"].append(arch)

    # 2) real lower+compile of reduced train and decode steps on the mesh
    for arch in ("qwen3-14b", "grok-1-314b", "rwkv6-3b"):
        cfg = get_config(arch, reduced=True)
        tree = registry.param_shapes(cfg)
        pspecs = sh.param_specs(cfg, tree, mesh, "train")
        state = train_loop.train_state_struct(cfg)
        sspecs = {"params": pspecs, "opt": {"m": pspecs, "v": pspecs,
                                            "step": P()}}
        batch = {
            "tokens": jax.ShapeDtypeStruct((8, 32), jnp.int32),
            "labels": jax.ShapeDtypeStruct((8, 32), jnp.int32),
        }
        bspecs = sh.batch_specs(cfg, batch, mesh)
        with sh.activation_sharding(sh.default_activation_specs(cfg, mesh, "train")):
            fn = train_loop.make_train_step(cfg)
            lowered = jax.jit(
                fn,
                in_shardings=(sh.named(mesh, sspecs), sh.named(mesh, bspecs)),
            ).lower(state, batch)
            lowered.compile()
        out["lowered"].append(arch)
    print("RESULT:" + json.dumps(out))
    """
)


@pytest.mark.slow
def test_sharding_rules_and_multidevice_compile():
    env = dict(os.environ)
    env["PYTHONPATH"] = os.path.join(os.path.dirname(__file__), "..", "src")
    proc = subprocess.run(
        [sys.executable, "-c", _SUBPROCESS],
        capture_output=True, text=True, env=env, timeout=900,
    )
    assert proc.returncode == 0, proc.stderr[-3000:]
    line = [ln for ln in proc.stdout.splitlines() if ln.startswith("RESULT:")][-1]
    out = json.loads(line[len("RESULT:"):])
    assert set(out["specs_ok"]) == set(all_arch_ids())
    assert out["lowered"] == ["qwen3-14b", "grok-1-314b", "rwkv6-3b"]


def test_activation_constrain_noop_without_context():
    import jax.numpy as jnp

    from repro.parallel.sharding import constrain

    x = jnp.ones((4, 4))
    assert constrain(x, "residual") is x


def test_dp_axes_and_pick():
    """Divisibility chooser degrades to replication, never fails."""
    from repro.parallel.sharding import pick

    class FakeMesh:
        shape = {"data": 16, "model": 16}
        axis_names = ("data", "model")

    m = FakeMesh()
    assert pick(m, 32, "model") == "model"
    assert pick(m, 20, "model") is None  # 20 heads on 16-way TP -> replicate
    assert pick(m, 20, "model", ("data",)) is None
    assert pick(m, 512, ("data", "model")) == ("data", "model")


@pytest.mark.slow
def test_halo_shift_matches_baseline_on_sharded_mesh():
    """halo_shift exchanges only the boundary column over `model`; outputs
    must equal the plain shift exactly on a sequence-sharded mesh."""
    script = textwrap.dedent(
        """
        import os
        os.environ["XLA_FLAGS"] = "--xla_force_host_platform_device_count=8"
        import jax, jax.numpy as jnp, numpy as np
        from repro.configs.base import get_config, SHAPES
        from repro.models import registry
        from repro.parallel import sharding as sh

        mesh = jax.make_mesh((2, 4), ("data", "model"))
        cfg0 = get_config("rwkv6-3b", reduced=True)
        params = registry.init_params(cfg0, jax.random.PRNGKey(0))
        batch = registry.make_batch(cfg0, SHAPES["train_4k"],
                                    batch_override=2, seq_override=16)
        outs = {}
        for halo in (False, True):
            cfg = cfg0.replace(halo_shift=halo)
            with sh.activation_sharding(
                sh.default_activation_specs(cfg, mesh, "train")):
                fn = jax.jit(lambda p, b: registry.forward(p, cfg, b)[0])
                outs[halo] = np.asarray(fn(params, batch))
        err = float(np.max(np.abs(outs[True] - outs[False])))
        assert err < 1e-4, err
        print("RESULT:ok", err)
        """
    )
    env = dict(os.environ)
    env["PYTHONPATH"] = os.path.join(os.path.dirname(__file__), "..", "src")
    proc = subprocess.run([sys.executable, "-c", script],
                          capture_output=True, text=True, env=env, timeout=600)
    assert proc.returncode == 0, proc.stderr[-2000:]
    assert "RESULT:ok" in proc.stdout
