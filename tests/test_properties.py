"""Hypothesis property tests on system invariants."""
import jax
import jax.numpy as jnp
import numpy as np
import pytest

pytest.importorskip("hypothesis", reason="property tests need hypothesis")
from hypothesis import given, settings, strategies as st

from repro.core import sparse as sp
from repro.kernels import ops, ref
from repro.models import layers as L
from repro.optim import adamw, compression

SETTINGS = dict(max_examples=6, deadline=None)


@settings(**SETTINGS)
@given(
    t=st.sampled_from([7, 16, 33]),
    n=st.sampled_from([4, 8]),
    chunk=st.sampled_from([8, 16]),
    seed=st.integers(0, 2**16),
    ssd=st.booleans(),
)
def test_chunked_linattn_equals_exact_scan(t, n, chunk, seed, ssd):
    """The chunked algorithm is algebraically identical to the per-token
    recurrence for any decay in the clamp range — the core kernel invariant."""
    rng = np.random.default_rng(seed)
    r = jnp.asarray(rng.standard_normal((1, 2, t, n)), jnp.float32)
    k = jnp.asarray(rng.standard_normal((1, 2, t, n)), jnp.float32)
    v = jnp.asarray(rng.standard_normal((1, 2, t, n)), jnp.float32)
    wl = jnp.asarray(-rng.uniform(0.0, 2.5, (1, 2, t, n)), jnp.float32)
    u = None if ssd else jnp.asarray(rng.standard_normal((2, n)), jnp.float32)
    o_ref, s_ref = ref.linear_attention_scan_ref(r, k, v, wl, u, None)
    o, s = ops.linear_attention(r, k, v, wl, u, impl="xla", chunk=chunk)
    np.testing.assert_allclose(o, o_ref, rtol=2e-4, atol=2e-4)
    np.testing.assert_allclose(s, s_ref, rtol=2e-4, atol=2e-4)


@settings(**SETTINGS)
@given(
    sq=st.sampled_from([1, 17, 40]),
    sk=st.sampled_from([5, 33]),
    window=st.sampled_from([0, 7]),
    seed=st.integers(0, 2**16),
)
def test_flash_matches_naive_any_shape(sq, sk, window, seed):
    rng = np.random.default_rng(seed)
    q = jnp.asarray(rng.standard_normal((1, 2, sq, 8)), jnp.float32)
    k = jnp.asarray(rng.standard_normal((1, 1, sk, 8)), jnp.float32)
    v = jnp.asarray(rng.standard_normal((1, 1, sk, 8)), jnp.float32)
    got = ops.flash_attention(q, k, v, impl="xla", block_k=8, causal=True,
                              window=window)
    want = ref.mha_ref(q, k, v, causal=True, window=window)
    np.testing.assert_allclose(got, want, rtol=1e-4, atol=1e-4)


@settings(**SETTINGS)
@given(
    b=st.sampled_from([1, 2]), s=st.sampled_from([4, 9]),
    v=st.sampled_from([17, 100]),
    seed=st.integers(0, 2**16),
)
def test_cross_entropy_matches_take_along_axis(b, s, v, seed):
    """One-hot-product loss (TP-shardable) == naive gather loss."""
    rng = np.random.default_rng(seed)
    vp = L.padded_vocab(v)
    logits = jnp.asarray(rng.standard_normal((b, s, vp)), jnp.float32)
    labels = jnp.asarray(rng.integers(0, v, (b, s)), jnp.int32)
    got = L.cross_entropy_loss(logits, labels, v)
    lf = jnp.where(jnp.arange(vp) >= v, -1e30, logits)
    logz = jax.nn.logsumexp(lf, axis=-1)
    gold = jnp.take_along_axis(lf, labels[..., None], axis=-1)[..., 0]
    want = jnp.mean(logz - gold)
    np.testing.assert_allclose(float(got), float(want), rtol=1e-5)


@settings(**SETTINGS)
@given(
    r=st.sampled_from([4, 24]), c=st.sampled_from([16, 64]),
    density=st.floats(0.02, 0.5), seed=st.integers(0, 2**16),
)
def test_ell_roundtrip_and_spmm(r, c, density, seed):
    rng = np.random.default_rng(seed)
    A = sp.random_ell(rng, r, c, density)
    assert A.todense().shape == (r, c)
    D = jnp.asarray(rng.standard_normal((c, 8)), jnp.float32)
    got = ref.spmm_ref(jnp.asarray(A.values), jnp.asarray(A.cols), D)
    np.testing.assert_allclose(got, A.todense() @ np.asarray(D),
                               rtol=1e-4, atol=1e-4)


@settings(**SETTINGS)
@given(seed=st.integers(0, 2**16), steps=st.integers(1, 8))
def test_compression_error_feedback_is_lossless_in_sum(seed, steps):
    """Error feedback: sum of compressed grads -> sum of true grads (the
    residual never exceeds one quantization step)."""
    rng = np.random.default_rng(seed)
    g_true = [rng.standard_normal((8, 8)).astype(np.float32) for _ in range(steps)]
    err = jnp.zeros((8, 8))
    total_sent = jnp.zeros((8, 8))
    for g in g_true:
        sent, err = compression.compress_decompress(jnp.asarray(g), err)
        total_sent = total_sent + sent
    total_true = jnp.asarray(np.sum(g_true, axis=0))
    # residual bounded by one bf16 ulp of the last value, not accumulated
    assert float(jnp.max(jnp.abs(total_sent + err - total_true))) < 1e-3


@settings(**SETTINGS)
@given(seed=st.integers(0, 2**16))
def test_bsr_covers_every_row_block(seed):
    rng = np.random.default_rng(seed)
    dense = np.zeros((32, 256), np.float32)
    mask = rng.random((32, 256)) < 0.03
    dense[mask] = 1.0
    bsr = sp.dense_to_bsr(dense, bm=8, bk=128)
    assert set(bsr.tile_rows.tolist()) == set(range(4))  # kernel-init invariant
    np.testing.assert_allclose(bsr.todense(), dense)


@settings(max_examples=8, deadline=None)
@given(seed=st.integers(0, 2**16))
def test_adamw_descends_quadratic(seed):
    """Optimizer sanity: AdamW reduces a convex quadratic."""
    from repro.configs.base import get_config

    cfg = get_config("gemma-2b", reduced=True).replace(
        learning_rate=0.1, warmup_steps=1, weight_decay=0.0)
    rng = np.random.default_rng(seed)
    params = {"w": jnp.asarray(rng.standard_normal((8,)), jnp.float32)}
    opt = adamw.init_state(params)
    def loss(p):
        return jnp.sum(p["w"] ** 2)

    l0 = float(loss(params))
    for _ in range(30):
        g = jax.grad(loss)(params)
        params, opt, _ = adamw.apply_updates(cfg, params, g, opt)
    assert float(loss(params)) < l0 * 0.5
