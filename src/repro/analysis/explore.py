"""Tier-C bounded model checking: exhaustive, device-free exploration.

The AST tier reads source text and the plan tier checks one resolved
artifact; this tier closes the remaining gap — *concurrent* artifacts whose
bugs live in interleavings a single trace never exercises. Two systems are
modeled, both pure Python (no jax import anywhere in this module):

- :class:`SchedulerModel` — an abstract twin of
  ``serving.scheduler.ContinuousBatchingScheduler``. Every transition
  (submit / admit / decode-with-preemption) is a hashable
  ``(state, action) -> state`` step; the explorer enumerates *all* action
  interleavings for small bounded configs and checks the block-ledger
  safety invariants (no double alloc/free, no NULL_BLOCK ownership, slot
  cap, coverage) in every reached state plus a bounded-liveness starvation
  detector. The model is kept honest by a bisimulation test that drives it
  and the real scheduler through identical workloads via
  ``scheduler.apply_action`` / ``scheduler.canonical_state``.

- :func:`explore_hop_interleavings` — a race detector over
  ``collectives.ring_schedule``. The published ``HopEvent`` list fixes
  *program order*, but an RDMA copy (``dma_start`` … ``dma_wait``) lands
  asynchronously: its completion is a separate nondeterministic event the
  explorer may schedule anywhere after issue. A fold that reads a buffer
  whose copy has not landed in *some* legal reordering is a race, even if
  the single replayed trace (plan tier's ``check_hop_schedule``) is clean.

Both sit on one engine: :func:`explore` — depth-bounded DFS with memoized
canonical state hashing and an explicit :class:`Budget`, so CI runs are
deterministic and budget exhaustion is a reported outcome, never a silent
pass.
"""
from __future__ import annotations

import dataclasses

# mirrors serving.scheduler.NULL_BLOCK — NOT imported, because pulling the
# serving package would drag jax into the jax-free CLI paths (--list, usage
# errors, the scheduler-model rule); tests pin the two constants together
NULL_BLOCK = 0


# -- budget + stats -----------------------------------------------------------


@dataclasses.dataclass(frozen=True)
class Budget:
    """Exploration ceiling: distinct canonical states and DFS depth.

    CI passes an explicit budget so the gate is deterministic; when either
    ceiling truncates the search the caller gets ``stats.truncated`` and
    must surface it (the CLI maps it to exit code 3 and a distinct
    ``budget-exhausted`` finding — an unexplored state space is an unknown,
    not a pass).
    """

    max_states: int = 200_000
    max_depth: int = 64

    @classmethod
    def parse(cls, text: str) -> "Budget":
        """Parse the CLI form ``STATES`` or ``STATES,DEPTH``."""
        parts = [p.strip() for p in str(text).split(",")]
        if len(parts) not in (1, 2) or not all(p.isdigit() for p in parts):
            raise ValueError(
                f"budget must be STATES or STATES,DEPTH, got {text!r}")
        states = int(parts[0])
        depth = int(parts[1]) if len(parts) == 2 else cls.max_depth
        if states < 1 or depth < 1:
            raise ValueError(f"budget values must be >= 1, got {text!r}")
        return cls(max_states=states, max_depth=depth)


@dataclasses.dataclass
class Stats:
    """Counters from one :func:`explore` run (surfaced in ``--format json``
    and the text summary — the >10^3-states acceptance evidence)."""

    states: int = 0  # distinct canonical states visited
    transitions: int = 0
    max_depth: int = 0
    truncated: bool = False
    violations: int = 0

    def as_dict(self) -> dict:
        return dataclasses.asdict(self)

    def merge(self, other: "Stats") -> None:
        self.states += other.states
        self.transitions += other.transitions
        self.max_depth = max(self.max_depth, other.max_depth)
        self.truncated = self.truncated or other.truncated
        self.violations += other.violations


def _fmt_action(action) -> str:
    if isinstance(action, tuple):
        return (action[0] if len(action) == 1 else
                f"{action[0]}({','.join(str(a) for a in action[1:])})")
    return str(action)


def format_trace(trace) -> str:
    """Render a counterexample action sequence for a finding message."""
    return " ; ".join(_fmt_action(a) for a in trace)


# -- generic bounded explorer -------------------------------------------------


def explore(system, budget: Budget | None = None):
    """Exhaustively explore ``system``'s action graph within ``budget``.

    ``system`` protocol (all states hashable):

    - ``initial()`` -> state
    - ``actions(state)`` -> iterable of enabled actions
    - ``step(state, action)`` -> ``(state', problems)`` where ``problems``
      is a list of violation strings raised *by the transition itself*
    - ``check(state)`` -> list of invariant-violation strings
    - ``at_leaf(state)`` -> violations checked only where no action is
      enabled (drain/terminal conditions)

    Depth-bounded DFS with memoized canonical hashing: a state re-reached
    at a depth no smaller than before is not re-expanded. Each distinct
    problem string is reported once, annotated with the first
    counterexample action trace that produced it; a state that violates an
    invariant is not expanded further (one bug, one report — not a cascade
    of corrupted descendants). Returns ``(problems, stats)`` where
    ``problems`` is a sorted list of annotated violation strings.
    """
    budget = budget or Budget()
    stats = Stats()
    problems: dict[str, str] = {}  # key -> key + counterexample trace

    def note(key: str, trace) -> None:
        if key not in problems:
            problems[key] = (f"{key} [after: {format_trace(trace)}]"
                             if trace else key)

    init = system.initial()
    seen = {init: 0}  # state -> min depth reached at
    stats.states = 1
    init_bad = list(system.check(init))
    for p in init_bad:
        note(p, ())
    if not init_bad:
        if not list(system.actions(init)):
            for p in system.at_leaf(init):
                note(p, ())
        # frame: (state, enabled-actions list, next-action index)
        stack = [(init, list(system.actions(init)), 0)]
        trace: list = []
        while stack:
            state, acts, idx = stack[-1]
            if idx >= len(acts):
                stack.pop()
                if trace:
                    trace.pop()
                continue
            stack[-1] = (state, acts, idx + 1)
            action = acts[idx]
            nxt, step_bad = system.step(state, action)
            stats.transitions += 1
            bad = list(step_bad) + list(system.check(nxt))
            for p in bad:
                note(p, trace + [action])
            if bad:
                continue  # don't explore past a corrupted state
            depth = len(stack)
            prev = seen.get(nxt)
            if prev is not None and prev <= depth:
                continue
            if prev is None:
                if len(seen) >= budget.max_states:
                    stats.truncated = True
                    break
                stats.states += 1
            seen[nxt] = depth
            stats.max_depth = max(stats.max_depth, depth)
            nxt_acts = list(system.actions(nxt))
            if not nxt_acts:
                for p in system.at_leaf(nxt):
                    note(p, trace + [action])
                continue
            if depth >= budget.max_depth:
                stats.truncated = True
                continue
            stack.append((nxt, nxt_acts, 0))
            trace.append(action)
    stats.violations = len(problems)
    return sorted(problems.values()), stats


class System:
    """Optional base for explorable systems: no-op hooks."""

    def check(self, state):
        return []

    def at_leaf(self, state):
        return []


# -- scheduler model ----------------------------------------------------------


@dataclasses.dataclass(frozen=True)
class RequestSpec:
    """Bounded-model request: like ``scheduler.Request`` but arrival-free
    (the *submit action's* position in the interleaving is the arrival)."""

    rid: int
    prompt_len: int
    max_new_tokens: int
    priority: int = 0


@dataclasses.dataclass(frozen=True)
class SchedulerConfig:
    """One bounded configuration the model checker explores exhaustively."""

    num_blocks: int
    block_size: int
    max_slots: int
    requests: tuple  # of RequestSpec
    starvation_bound: int = 8  # max admit-pass bypasses while queued


# seq tuple layout inside a model state (see SchedulerModel docstring)
_RID, _GEN, _PRE, _RANK, _BLOCKS, _WAITED = range(6)


class SchedulerModel(System):
    """Abstract twin of ``ContinuousBatchingScheduler`` over immutable
    tuple states.

    State shape (everything hashable, absolute time abstracted away)::

        state   = (queues, running, pending, free, finished)
        queues  = ((priority, (seq, …)), …)   nonempty, ascending priority
        running = ((slot, seq), …)            ascending slot
        pending = (rid, …)                    submitted, not yet queued
        free    = (block, …)                  allocator FIFO order
        finished= (rid, …)                    sorted
        seq     = (rid, n_generated, preemptions, adm_rank, blocks, waited)

    ``adm_rank`` is the dense rank of the admission step over the running
    set (re-normalized after every transition), which preserves the
    most-recently-admitted victim ordering while merging states reached at
    different wall-steps. ``waited`` counts admit passes that admitted
    *someone else* while this sequence stayed queued — the bounded-liveness
    starvation detector (model-only; ``ledger_view`` strips it for
    comparison against ``scheduler.canonical_state``).

    Semantics mirror the real class exactly — FCFS within class, highest
    class first, head-of-line no-skip admission, FIFO block pool,
    lowest-priority most-recently-admitted victim, preempted sequences
    re-queued at the class *front* — and the bisimulation test in
    ``tests/test_explore.py`` holds the two in lock-step.
    """

    def __init__(self, config: SchedulerConfig):
        self.config = config
        self.specs = {r.rid: r for r in config.requests}
        if len(self.specs) != len(config.requests):
            raise ValueError("duplicate rids in config")
        limit = config.num_blocks - 1
        for r in config.requests:
            total = -(-(r.prompt_len + r.max_new_tokens) // config.block_size)
            if total > limit:
                raise ValueError(
                    f"request {r.rid} can never fit: needs {total} blocks, "
                    f"pool has {limit}")

    # -- state helpers --------------------------------------------------

    def initial(self):
        free = tuple(b for b in range(self.config.num_blocks)
                     if b != NULL_BLOCK)
        return ((), (), (), free, ())

    def _submitted_rids(self, state):
        queues, running, pending, _free, finished = state
        rids = set(pending) | set(finished)
        rids.update(s[_RID] for _p, seqs in queues for s in seqs)
        rids.update(s[_RID] for _slot, s in running)
        return rids

    def _needed_now(self, seq) -> int:
        """Blocks covering the cached prefix plus the next decode write
        (``Sequence.blocks_needed_now``); admission allocates at least 1."""
        spec = self.specs[seq[_RID]]
        pos = spec.prompt_len + seq[_GEN] - 1
        return max(1, pos // self.config.block_size + 1)

    def _normalize(self, queues, running, pending, free, finished):
        """Rebuild the canonical tuple state: drop empty classes, sort by
        slot, and re-compress adm_rank to dense ranks (rank order is
        preserved; same-rank ties stay tied)."""
        ranks = {r: i for i, r in enumerate(
            sorted({s[_RANK] for s in running.values()}))}
        run = tuple(
            (slot, s[:_RANK] + (ranks[s[_RANK]],) + s[_RANK + 1:])
            for slot, s in sorted(running.items()))
        q = tuple((prio, tuple(seqs)) for prio, seqs in sorted(queues.items())
                  if seqs)
        return (q, run, tuple(pending), tuple(free), tuple(sorted(finished)))

    # -- actions --------------------------------------------------------

    def actions(self, state):
        queues, running, pending, _free, _finished = state
        acts = [("submit", rid) for rid in sorted(
            set(self.specs) - self._submitted_rids(state))]
        if pending or queues:
            acts.append(("admit",))
        acts.extend(("decode", slot) for slot, _s in running)
        return acts

    def step(self, state, action):
        nxt, problems, _admits = self.apply(state, action)
        return nxt, problems

    def apply(self, state, action):
        """Like :meth:`step` but also returns the ``(rid, slot)`` pairs an
        admit pass admitted — the bisimulation test compares them against
        ``scheduler.apply_action``'s return value."""
        kind = action[0]
        if kind == "submit":
            return self._submit(state, action[1])
        if kind == "admit":
            return self._admit(state)
        if kind == "decode":
            return self._decode(state, action[1])
        raise ValueError(f"unknown action {action!r}")

    # -- transitions ----------------------------------------------------

    def _submit(self, state, rid):
        queues, running, pending, free, finished = state
        nxt = (queues, running, pending + (rid,), free, finished)
        return nxt, [], []

    def _fresh_seq(self, rid):
        return (rid, 0, 0, -1, (), 0)

    def _admit(self, state):
        queues_t, running_t, pending, free_t, finished = state
        queues = {prio: list(seqs) for prio, seqs in queues_t}
        for rid in pending:  # all pending arrive: submit stamped arrival=now
            prio = self.specs[rid].priority
            queues.setdefault(prio, []).append(self._fresh_seq(rid))
        running = dict(running_t)
        free = list(free_t)
        new_rank = 1 + max((s[_RANK] for s in running.values()), default=-1)
        admitted = []
        problems = []
        while True:
            if len(running) >= self.config.max_slots:
                break
            prios = [p for p in sorted(queues, reverse=True) if queues[p]]
            if not prios:
                break
            head = queues[prios[0]][0]
            n = self._needed_now(head)
            if len(free) < n:
                break  # head-of-line short on blocks: FCFS, no skip
            queues[prios[0]].pop(0)
            blocks, free = tuple(free[:n]), free[n:]
            slot = min(s for s in range(self.config.max_slots)
                       if s not in running)
            running[slot] = (head[_RID], head[_GEN], head[_PRE], new_rank,
                             blocks, 0)
            admitted.append((head[_RID], slot))
        if admitted:  # bounded liveness: queued seqs were bypassed
            bound = self.config.starvation_bound
            for prio, seqs in queues.items():
                for i, s in enumerate(seqs):
                    waited = min(s[_WAITED] + 1, bound + 1)
                    if waited > bound:
                        problems.append(
                            f"starvation: rid {s[_RID]} bypassed by "
                            f"{bound + 1} admit passes while queued")
                    seqs[i] = s[:_WAITED] + (waited,)
        else:
            # admission progress: if the policy's next pick has a slot and
            # blocks, the pass must not leave it queued
            prios = [p for p in sorted(queues, reverse=True) if queues[p]]
            if (prios and len(running) < self.config.max_slots
                    and len(free) >= self._needed_now(queues[prios[0]][0])):
                problems.append(
                    f"admit pass left admissible head rid "
                    f"{queues[prios[0]][0][_RID]} queued")
        nxt = self._normalize(queues, running, pending=(), free=free,
                              finished=finished)
        return nxt, problems, admitted

    def _pick_victim(self, running):
        """Slot of the lowest-priority most-recently-admitted sequence
        (``adm_rank`` orders exactly like ``admitted_at``; rank ties — same
        admit pass — break by rid, as in the real scheduler)."""
        return max(running, key=lambda slot: (
            -self.specs[running[slot][_RID]].priority,
            running[slot][_RANK], running[slot][_RID]))

    def _requeue_front(self, queues, seq):
        """Preemption re-entry: the FRONT of the class queue — combined
        with FCFS admission this is what bounds bypasses (a model that
        appends instead drifts from the real scheduler and is caught by
        the bisimulation test)."""
        prio = self.specs[seq[_RID]].priority
        queues.setdefault(prio, []).insert(0, seq)

    def _preempt(self, queues, running, free, vslot, vblocks):
        """Evict the victim in ``vslot``: release ``vblocks`` to the pool
        and re-queue it at its class front with the generated prefix kept.
        A method (not inlined in ``_decode``) so seeded-bad fixtures can
        break exactly this transition — the double-free fixture overrides
        it to release the blocks twice."""
        victim = running.pop(vslot)
        free.extend(vblocks)
        self._requeue_front(queues, (
            victim[_RID], victim[_GEN], victim[_PRE] + 1, -1, (),
            victim[_WAITED]))

    def _decode(self, state, slot):
        queues_t, running_t, pending, free_t, finished = state
        queues = {prio: list(seqs) for prio, seqs in queues_t}
        running = dict(running_t)
        free = list(free_t)
        problems: list = []
        seq = running[slot]
        spec = self.specs[seq[_RID]]
        pos = spec.prompt_len + seq[_GEN] - 1
        blocks = list(seq[_BLOCKS])
        preempted_self = False
        while pos // self.config.block_size >= len(blocks):
            if free:
                blocks.append(free.pop(0))
                continue
            vslot = self._pick_victim(running)
            # a self-victim releases its *grown* table, not the stale one
            vblocks = (tuple(blocks) if vslot == slot
                       else running[vslot][_BLOCKS])
            self._preempt(queues, running, free, vslot, vblocks)
            if vslot == slot:
                preempted_self = True
                break
        if not preempted_self:
            gen = seq[_GEN] + 1
            if gen >= spec.max_new_tokens:  # retire
                free.extend(blocks)
                finished = finished + (seq[_RID],)
                del running[slot]
            else:
                running[slot] = (seq[_RID], gen, seq[_PRE], seq[_RANK],
                                 tuple(blocks), seq[_WAITED])
        nxt = self._normalize(queues, running, pending, free, finished)
        return nxt, problems, []

    # -- invariants -----------------------------------------------------

    def check(self, state):
        queues, running, pending, free, finished = state
        problems = []
        cfg = self.config
        pool = set(range(cfg.num_blocks)) - {NULL_BLOCK}
        if len(set(free)) != len(free):
            problems.append("double-free: duplicate blocks on the free list")
        live: list = []
        for _slot, s in running:
            live.extend(s[_BLOCKS])
            if len(set(s[_BLOCKS])) != len(s[_BLOCKS]):
                problems.append(
                    f"double-alloc: rid {s[_RID]} holds a block twice")
        if len(set(live)) != len(live):
            problems.append("double-alloc: block owned by two sequences")
        if NULL_BLOCK in set(free) | set(live):
            problems.append("NULL_BLOCK entered the pool or a block table")
        stray = (set(free) | set(live)) - pool
        if stray - {NULL_BLOCK}:
            problems.append(f"blocks outside the pool: {sorted(stray)}")
        if set(free) & set(live):
            problems.append(
                f"double-free: blocks both free and owned: "
                f"{sorted(set(free) & set(live))}")
        if len(free) + len(set(live)) != len(pool):
            n = len(free) + len(set(live))
            word = "leak" if n < len(pool) else "double-entry"
            problems.append(
                f"ledger {word}: free+owned covers {n} block slots, the "
                f"pool has {len(pool)}")
        if len(running) > cfg.max_slots:
            problems.append(
                f"slot overflow: {len(running)} running > "
                f"max_slots={cfg.max_slots}")
        if len({slot for slot, _s in running}) != len(running):
            problems.append("two sequences share a decode slot")
        for _slot, s in running:
            spec = self.specs[s[_RID]]
            cached = spec.prompt_len + max(0, s[_GEN] - 1)
            if len(s[_BLOCKS]) * cfg.block_size < cached:
                problems.append(
                    f"coverage: rid {s[_RID]} cached {cached} tokens in "
                    f"{len(s[_BLOCKS])} block(s)")
        for _prio, seqs in queues:
            for s in seqs:
                if s[_BLOCKS]:
                    problems.append(
                        f"queued rid {s[_RID]} still owns blocks")
        rids = list(pending) + list(finished)
        rids += [s[_RID] for _p, seqs in queues for s in seqs]
        rids += [s[_RID] for _slot, s in running]
        if len(set(rids)) != len(rids):
            problems.append("rid present in two lifecycle stages at once")
        return problems

    def at_leaf(self, state):
        _queues, _running, _pending, free, finished = state
        problems = []
        if set(finished) != set(self.specs):
            problems.append(
                f"drained without finishing rids "
                f"{sorted(set(self.specs) - set(finished))}")
        if set(free) != set(range(self.config.num_blocks)) - {NULL_BLOCK}:
            problems.append("drained with blocks missing from the pool")
        return problems

    # -- bisimulation seam ----------------------------------------------

    @staticmethod
    def ledger_view(state):
        """State minus the model-only ``waited`` counters — directly
        comparable with ``scheduler.canonical_state(sched)``."""
        queues, running, pending, free, finished = state
        strip = lambda s: s[:_WAITED]  # noqa: E731 - local tuple slicer
        q = tuple((prio, tuple(strip(s) for s in seqs))
                  for prio, seqs in queues)
        run = tuple((slot, strip(s)) for slot, s in running)
        return (q, run, pending, free, finished)


# the bounded configs the `scheduler-model` rule explores exhaustively:
# small enough to finish inside the CI budget, rich enough to reach
# admission-blocking, preemption chains, self-preemption and drains
# (together >10^3 distinct canonical states — asserted by the tests)
SCHEDULER_CONFIGS = (
    ("tight-pool", SchedulerConfig(
        num_blocks=5, block_size=1, max_slots=2, requests=(
            RequestSpec(rid=0, prompt_len=1, max_new_tokens=3, priority=0),
            RequestSpec(rid=1, prompt_len=2, max_new_tokens=2, priority=0),
            RequestSpec(rid=2, prompt_len=1, max_new_tokens=2, priority=1),
        ))),
    ("mixed-priority", SchedulerConfig(
        num_blocks=6, block_size=2, max_slots=3, requests=(
            RequestSpec(rid=0, prompt_len=2, max_new_tokens=4, priority=0),
            RequestSpec(rid=1, prompt_len=1, max_new_tokens=2, priority=2),
            RequestSpec(rid=2, prompt_len=3, max_new_tokens=3, priority=1),
            RequestSpec(rid=3, prompt_len=1, max_new_tokens=1, priority=0),
        ))),
)


# -- overlap hop-schedule interleavings ---------------------------------------


class HopInterleavings(System):
    """All legal reorderings of one ``ring_schedule`` event list.

    Core events (send / fold / dma_start / dma_wait) execute in program
    order — that part the schedule fixes. What it does NOT fix is when an
    RDMA copy *lands*: ``dma_start`` only issues the descriptor, so the
    landing is modeled as a separate ``("land", hop)`` action the explorer
    may interleave anywhere after issue. ``dma_wait`` is the only ordering
    edge — it blocks until its hop has landed. A fold whose buffer version
    is wrong in any reachable interleaving is a race: with the events as
    published, some legal DMA timing lets the fold read hop t's buffer
    before the copy completed (or after a later copy clobbered it).

    State: ``(pc, versions, landed, inflight)`` with ``versions`` the
    (buffer -> hop) map as a sorted tuple. Synchronous sends update the
    version at execution; DMA copies update it at *landing*.
    """

    def __init__(self, events, hops: int):
        self.events = tuple(events)
        self.hops = hops
        # folds completed before each pc (length len+1: landings can be
        # scheduled after the last core event), in program order —
        # pc-derived, so it stays out of the hashed state
        folded = set()
        self._folded_before = [frozenset(folded)]
        for ev in self.events:
            if ev.kind == "fold":
                folded.add(ev.hop)
            self._folded_before.append(frozenset(folded))

    def initial(self):
        # buffer 0 starts holding the local shard: hop 0, already arrived
        return (0, ((0, 0),), (), ())

    def actions(self, state):
        pc, _versions, landed, inflight = state
        acts = [("land", hop, dst) for dst, hop in inflight]
        if pc < len(self.events):
            ev = self.events[pc]
            if ev.kind == "dma_wait":
                # enabled only once the copy landed; a wait with no issued
                # copy at all is a structural bug -> let it execute and flag
                if ev.hop in landed or not any(
                        h == ev.hop for _d, h in inflight):
                    acts.append(("exec",))
            else:
                acts.append(("exec",))
        return acts

    def step(self, state, action):
        pc, versions_t, landed, inflight = state
        versions = dict(versions_t)
        problems = []
        folded = self._folded_before[pc]
        if action[0] == "land":
            hop, dst = action[1], action[2]
            old = versions.get(dst)
            if old is not None and old not in folded and old != hop:
                problems.append(
                    f"hop {hop} copy lands over buffer {dst} while hop "
                    f"{old} is still unfolded (fold races the DMA)")
            versions[dst] = hop
            landed = tuple(sorted(set(landed) | {hop}))
            inflight = tuple(p for p in inflight if p != (dst, hop))
            return self._pack(pc, versions, landed, inflight), problems
        ev = self.events[pc]
        if ev.kind == "send":
            if versions.get(ev.src) != ev.hop - 1:
                problems.append(
                    f"send of hop {ev.hop} reads buffer {ev.src} holding "
                    f"hop {versions.get(ev.src)}")
            old = versions.get(ev.dst)
            if old is not None and old not in folded:
                problems.append(
                    f"send of hop {ev.hop} overwrites buffer {ev.dst} "
                    f"while hop {old} is still unfolded")
            versions[ev.dst] = ev.hop  # synchronous: arrives at execution
        elif ev.kind == "dma_start":
            if versions.get(ev.src) != ev.hop - 1:
                problems.append(
                    f"dma_start of hop {ev.hop} reads buffer {ev.src} "
                    f"holding hop {versions.get(ev.src)}")
            inflight = inflight + ((ev.dst, ev.hop),)
        elif ev.kind == "dma_wait":
            if ev.hop not in landed:
                # only reachable when no matching dma_start was issued
                problems.append(
                    f"dma_wait for hop {ev.hop} has no matching dma_start")
        elif ev.kind == "fold":
            got = versions.get(ev.src)
            if got != ev.hop:
                inflt = any(h == ev.hop for _d, h in inflight)
                why = ("its copy has not landed" if inflt else
                       f"the buffer holds hop {got}")
                problems.append(
                    f"fold of hop {ev.hop} races buffer {ev.src}: {why} "
                    f"in a legal interleaving")
        return self._pack(pc + 1, versions, landed, inflight), problems

    @staticmethod
    def _pack(pc, versions, landed, inflight):
        return (pc, tuple(sorted(versions.items())), tuple(sorted(landed)),
                tuple(sorted(inflight)))

def explore_hop_interleavings(events, hops: int,
                              budget: Budget | None = None):
    """Race-check one hop schedule under all legal DMA timings.

    Static shape checks run first — every hop folded exactly once, every
    issued copy eventually waited on (an un-waited DMA can land after the
    schedule "completes") — then :func:`explore` enumerates the
    interleavings. Returns ``(problems, stats)`` like :func:`explore`.
    """
    problems = []
    fold_counts: dict[int, int] = {}
    started: list[int] = []
    waited: list[int] = []
    for ev in events:
        if ev.kind == "fold":
            fold_counts[ev.hop] = fold_counts.get(ev.hop, 0) + 1
        elif ev.kind == "dma_start":
            started.append(ev.hop)
        elif ev.kind == "dma_wait":
            waited.append(ev.hop)
    for hop in range(hops):
        n = fold_counts.pop(hop, 0)
        if n != 1:
            problems.append(f"hop {hop} folded {n} times (expected once)")
    for hop, n in sorted(fold_counts.items()):
        problems.append(f"fold of out-of-range hop {hop} (x{n})")
    for hop in sorted(set(started) - set(waited)):
        problems.append(
            f"dma_start of hop {hop} has no dma_wait — the copy can land "
            f"at any point after the schedule ends")
    explored, stats = explore(HopInterleavings(events, hops), budget)
    stats.violations += len(problems)
    return problems + explored, stats
