"""``python -m repro.analysis`` — run the static checker (see cli.py)."""
import sys

from repro.analysis.cli import main

sys.exit(main())
