"""Tier-A rules: AST lint over the source tree (no repro/jax imports).

Each rule here promotes an invariant the repo previously enforced with a
grep-style assertion buried in a test — or never enforced at all — into a
named, fixture-testable check:

  single-pallas-site            core/streams.py is the only pallas_call site
  block-geometry-registry-only  block sizes come from the registry, nowhere else
  no-environ-in-kernels         kernel modules never read the environment
  xla-flags-append-only         XLA_FLAGS is only written by the append helper
  axis-name-vocabulary          collective axis literals ∈ partition.AXIS_VOCAB
  docstring-contract            the documented public surfaces stay documented
  warn-category                 every warnings.warn passes an explicit category

Rules match files by path heuristics relative to the scanned root (``rel``
suffix / directory-segment checks), so the same rule runs identically over
the real tree and over the seeded-violation fixture trees in
``tests/analysis_fixtures``.
"""
from __future__ import annotations

import ast
import re

from repro.analysis.base import Context, Finding, SourceFile, register_rule

# fallback vocabulary when the scanned tree carries no kernels/partition.py
# (fixture trees); the real tree's AXIS_VOCAB assignment wins when present
DEFAULT_AXIS_VOCAB = ("pod", "data", "model")

BLOCK_PARAMS = frozenset(
    {"block_k", "bq", "bk", "bm", "bn", "bf", "bx", "bs", "chunk"}
)

# collective name -> positional index of its axis-name argument
_COLLECTIVES = {
    "psum": 1, "pmean": 1, "ppermute": 1, "all_gather": 1,
    "psum_scatter": 1, "all_to_all": 1, "axis_index": 0,
}

MIN_DOC_LEN = 30
# rel-path suffixes carrying the documentation contract (the modules
# docs/partitioning.md documents as the user-facing surface)
DOC_CONTRACT_SUFFIXES = ("kernels/partition.py", "launch/autotune.py")


def _chain(node: ast.AST) -> str:
    """Dotted-name form of an attribute chain (``jax.lax.psum``), or ""."""
    parts = []
    while isinstance(node, ast.Attribute):
        parts.append(node.attr)
        node = node.value
    if isinstance(node, ast.Name):
        parts.append(node.id)
        return ".".join(reversed(parts))
    return ""


def _in_dir(src: SourceFile, name: str) -> bool:
    return name in src.rel.split("/")[:-1]


def _basename(src: SourceFile) -> str:
    return src.rel.rsplit("/", 1)[-1]


@register_rule("single-pallas-site", tier="ast")
def single_pallas_site(ctx: Context) -> list[Finding]:
    """core/streams.py is the only module that may touch pl.pallas_call.

    The substrate invariant behind the whole kernel layer: backend
    concerns (compiler params, scalar prefetch, interpret mode) live in
    exactly one launch site, so every kernel is a StreamProgram and none
    grows a private pallas path.
    """
    out = []
    for src in ctx.files:
        if _basename(src) == "streams.py":
            continue
        seen = set()
        for node in ast.walk(src.tree):
            line = None
            if isinstance(node, ast.Attribute) and node.attr == "pallas_call":
                line = node.lineno
            elif isinstance(node, ast.Name) and node.id == "pallas_call":
                line = node.lineno
            elif isinstance(node, (ast.Import, ast.ImportFrom)) and any(
                a.name == "pallas_call" or (a.asname == "pallas_call")
                for a in node.names
            ):
                line = node.lineno
            if line is not None and line not in seen:
                seen.add(line)
                out.append(Finding(
                    "single-pallas-site", src.rel, line,
                    "pallas_call outside core/streams.py — the substrate's "
                    "single launch site",
                ))
    return out


def _block_defaults_ops(ctx: Context) -> list[str]:
    """Keys of the ``_BLOCK_DEFAULTS`` table in the tree's registry.py."""
    reg = ctx.find("kernels/registry.py")
    if reg is None:
        return []
    for node in reg.tree.body:
        if (
            isinstance(node, (ast.Assign, ast.AnnAssign))
            and isinstance(node.value, ast.Dict)
        ):
            targets = node.targets if isinstance(node, ast.Assign) else [node.target]
            if any(
                isinstance(t, ast.Name) and t.id == "_BLOCK_DEFAULTS"
                for t in targets
            ):
                return [
                    k.value for k in node.value.keys
                    if isinstance(k, ast.Constant) and isinstance(k.value, str)
                ]
    return []


@register_rule("block-geometry-registry-only", tier="ast")
def block_geometry_registry_only(ctx: Context) -> list[Finding]:
    """Block geometry has one source of truth: registry.resolve_blocks.

    In kernel-layer modules (``kernels/``, minus the registry itself and
    the partition rules): no block-size keyword gets an integer literal, no
    module keeps private ``block_defaults`` plumbing, and nothing reads the
    ``REPRO_UNROLL_GRID`` escape hatch (the historical regression where the
    unrolled flash path derived bq/bk from a raw env var). Additionally,
    every op in the registry's ``_BLOCK_DEFAULTS`` table must resolve
    through ``resolve_blocks("<op>"`` in ops.py — the single-path check.
    """
    out = []
    for src in ctx.files:
        if not _in_dir(src, "kernels"):
            continue
        base = _basename(src)
        if base in ("registry.py", "partition.py"):
            continue
        for node in ast.walk(src.tree):
            if isinstance(node, ast.Call):
                for kw in node.keywords:
                    if (
                        kw.arg in BLOCK_PARAMS
                        and isinstance(kw.value, ast.Constant)
                        and isinstance(kw.value.value, int)
                    ):
                        out.append(Finding(
                            "block-geometry-registry-only", src.rel,
                            kw.value.lineno,
                            f"block-size literal {kw.arg}={kw.value.value} "
                            f"bypasses registry.resolve_blocks",
                        ))
            elif (
                isinstance(node, (ast.Attribute, ast.Name))
                and getattr(node, "attr", getattr(node, "id", None))
                == "block_defaults"
            ):
                out.append(Finding(
                    "block-geometry-registry-only", src.rel, node.lineno,
                    "private block_defaults plumbing in a kernel impl "
                    "module; geometry flows through resolve_blocks only",
                ))
            elif (
                isinstance(node, ast.Constant)
                and node.value == "REPRO_UNROLL_GRID"
            ):
                out.append(Finding(
                    "block-geometry-registry-only", src.rel, node.lineno,
                    "REPRO_UNROLL_GRID escape hatch: geometry must never "
                    "come from the environment",
                ))
    ops_src = ctx.find("kernels/ops.py")
    if ops_src is not None:
        for op in _block_defaults_ops(ctx):
            if f'resolve_blocks("{op}"' not in ops_src.text:
                out.append(Finding(
                    "block-geometry-registry-only", ops_src.rel, 0,
                    f"op {op!r} has a block table but ops.py never calls "
                    f'resolve_blocks("{op}", ...) — split-brain geometry',
                ))
    return out


@register_rule("no-environ-in-kernels", tier="ast")
def no_environ_in_kernels(ctx: Context) -> list[Finding]:
    """Kernel modules never read the process environment.

    The registry owns the only sanctioned env knob (``REPRO_KERNEL_IMPL``,
    impl selection — not geometry); any other ``os.environ`` / ``os.getenv``
    in ``kernels/`` is configuration smuggled past the dispatch layer.
    """
    out = []
    for src in ctx.files:
        if not _in_dir(src, "kernels") or _basename(src) == "registry.py":
            continue
        for node in ast.walk(src.tree):
            hit = None
            if isinstance(node, ast.Attribute) and _chain(node) == "os.environ":
                hit = "os.environ"
            elif (
                isinstance(node, ast.Call)
                and _chain(node.func) == "os.getenv"
            ):
                hit = "os.getenv"
            if hit:
                out.append(Finding(
                    "no-environ-in-kernels", src.rel, node.lineno,
                    f"{hit} in a kernel module; only the registry reads "
                    f"the environment (impl selection)",
                ))
    return out


@register_rule("xla-flags-append-only", tier="ast")
def xla_flags_append_only(ctx: Context) -> list[Finding]:
    """XLA_FLAGS is only ever appended via launch.xla_flags, never assigned.

    A bare ``os.environ["XLA_FLAGS"] = ...`` outside the helper clobbers
    caller-set flags (the regression both launchers shipped once). The
    launchers themselves (dryrun, hillclimb, benchmarks/run.py) must route
    through ``ensure_host_device_count``.
    """
    out = []
    for src in ctx.files:
        base = _basename(src)
        if base == "xla_flags.py":
            continue
        for node in ast.walk(src.tree):
            targets = []
            if isinstance(node, ast.Assign):
                targets = node.targets
            elif isinstance(node, ast.AugAssign):
                targets = [node.target]
            for t in targets:
                if (
                    isinstance(t, ast.Subscript)
                    and _chain(t.value) == "os.environ"
                    and isinstance(t.slice, ast.Constant)
                    and t.slice.value == "XLA_FLAGS"
                ):
                    out.append(Finding(
                        "xla-flags-append-only", src.rel, node.lineno,
                        "direct write to os.environ['XLA_FLAGS'] clobbers "
                        "caller flags; use launch.xla_flags",
                    ))
        is_launcher = (
            base in ("dryrun.py", "hillclimb.py") and _in_dir(src, "launch")
        ) or src.rel.endswith("benchmarks/run.py")
        if is_launcher and "ensure_host_device_count" not in src.text:
            out.append(Finding(
                "xla-flags-append-only", src.rel, 0,
                "launcher does not bootstrap via ensure_host_device_count",
            ))
    return out


def _axis_vocab(ctx: Context) -> tuple:
    """The tree's ``AXIS_VOCAB`` assignment (kernels/partition.py), else
    the fallback ``DEFAULT_AXIS_VOCAB``."""
    part = ctx.find("kernels/partition.py")
    if part is not None:
        for node in part.tree.body:
            if isinstance(node, ast.Assign) and any(
                isinstance(t, ast.Name) and t.id == "AXIS_VOCAB"
                for t in node.targets
            ) and isinstance(node.value, ast.Tuple):
                return tuple(
                    e.value for e in node.value.elts
                    if isinstance(e, ast.Constant)
                )
    return DEFAULT_AXIS_VOCAB


@register_rule("axis-name-vocabulary", tier="ast")
def axis_name_vocabulary(ctx: Context) -> list[Finding]:
    """Collective axis-name literals come from partition's vocabulary.

    Every string literal passed as the axis of ``psum`` / ``ppermute`` /
    ``all_gather`` / ``axis_index`` / ... must be an axis name the
    partition layer produces (``AXIS_VOCAB``: the C5 pod/data/model
    hierarchy). A typo'd or ad-hoc axis name fails only at shard_map trace
    time on a matching mesh — this catches it statically.
    """
    vocab = _axis_vocab(ctx)
    out = []
    for src in ctx.files:
        for node in ast.walk(src.tree):
            if not isinstance(node, ast.Call):
                continue
            name = _chain(node.func).rsplit(".", 1)[-1] or getattr(
                node.func, "id", ""
            )
            if name not in _COLLECTIVES:
                continue
            idx = _COLLECTIVES[name]
            axis_arg = None
            if len(node.args) > idx:
                axis_arg = node.args[idx]
            else:
                for kw in node.keywords:
                    if kw.arg in ("axis_name", "axis"):
                        axis_arg = kw.value
            literals = []
            if isinstance(axis_arg, ast.Constant) and isinstance(
                axis_arg.value, str
            ):
                literals = [axis_arg]
            elif isinstance(axis_arg, ast.Tuple):
                literals = [
                    e for e in axis_arg.elts
                    if isinstance(e, ast.Constant)
                    and isinstance(e.value, str)
                ]
            for lit in literals:
                if lit.value not in vocab:
                    out.append(Finding(
                        "axis-name-vocabulary", src.rel, lit.lineno,
                        f"{name} over axis {lit.value!r}: not in the "
                        f"partition vocabulary {vocab}",
                    ))
    return out


def _mentions(doc: str, name: str) -> bool:
    return re.search(rf"\b{re.escape(name)}\b", doc) is not None


def _fn_params(node: ast.FunctionDef) -> list[str]:
    a = node.args
    names = [p.arg for p in (*a.posonlyargs, *a.args)]
    if a.vararg:
        names.append(a.vararg.arg)
    names += [p.arg for p in a.kwonlyargs]
    if a.kwarg:
        names.append(a.kwarg.arg)
    return [n for n in names if n not in ("self", "cls")]


def _is_dataclass(node: ast.ClassDef) -> bool:
    for dec in node.decorator_list:
        target = dec.func if isinstance(dec, ast.Call) else dec
        name = _chain(target) or getattr(target, "id", "")
        if name.rsplit(".", 1)[-1] == "dataclass":
            return True
    return False


@register_rule("docstring-contract", tier="ast")
def docstring_contract(ctx: Context) -> list[Finding]:
    """The documented public surfaces keep their documentation contract.

    For the modules docs/partitioning.md presents as the user-facing API
    (kernels/partition.py, launch/autotune.py): a real module docstring,
    a ≥30-char docstring on every public top-level function and class,
    every parameter mentioned by name, and every dataclass field described
    — the same contract tests/test_docstrings.py enforces at runtime,
    reimplemented over the AST so it also runs on fixture trees.
    """
    out = []

    def bad(src, line, msg):
        out.append(Finding("docstring-contract", src.rel, line, msg))

    for src in ctx.files:
        if not src.rel.endswith(DOC_CONTRACT_SUFFIXES):
            continue
        mod_doc = ast.get_docstring(src.tree) or ""
        if len(mod_doc.strip()) < MIN_DOC_LEN:
            bad(src, 1, "missing or trivial module docstring")
        for node in src.tree.body:
            if not isinstance(
                node, (ast.FunctionDef, ast.AsyncFunctionDef, ast.ClassDef)
            ) or node.name.startswith("_"):
                continue
            doc = ast.get_docstring(node) or ""
            if len(doc) < MIN_DOC_LEN:
                bad(src, node.lineno,
                    f"{node.name}: missing or trivial docstring")
                continue
            if isinstance(node, ast.ClassDef):
                if _is_dataclass(node):
                    for stmt in node.body:
                        if isinstance(stmt, ast.AnnAssign) and isinstance(
                            stmt.target, ast.Name
                        ) and not _mentions(doc, stmt.target.id):
                            bad(src, stmt.lineno,
                                f"{node.name}: dataclass field "
                                f"{stmt.target.id!r} undocumented")
            else:
                for param in _fn_params(node):
                    if not _mentions(doc, param):
                        bad(src, node.lineno,
                            f"{node.name}: parameter {param!r} not "
                            f"mentioned in docstring")
    return out


@register_rule("warn-category", tier="ast")
def warn_category(ctx: Context) -> list[Finding]:
    """Every warnings.warn call passes an explicit warning category.

    Degrade paths speak through ``diagnostics.warn_degrade`` (the
    ``ReproDegradeWarning`` channel); any other ``warnings.warn`` must at
    least name its category so callers can filter on it. A bare
    single-argument warn is an anonymous UserWarning nobody can target.
    """
    out = []
    for src in ctx.files:
        bare_warn_imported = any(
            isinstance(node, ast.ImportFrom)
            and node.module == "warnings"
            and any(a.name == "warn" for a in node.names)
            for n in [src.tree]
            for node in ast.walk(n)
        )
        for node in ast.walk(src.tree):
            if not isinstance(node, ast.Call):
                continue
            chain = _chain(node.func)
            is_warn = chain == "warnings.warn" or (
                bare_warn_imported
                and isinstance(node.func, ast.Name)
                and node.func.id == "warn"
            )
            if not is_warn:
                continue
            has_category = len(node.args) >= 2 or any(
                kw.arg == "category" for kw in node.keywords
            )
            if not has_category:
                out.append(Finding(
                    "warn-category", src.rel, node.lineno,
                    "warnings.warn without an explicit category; use "
                    "diagnostics.warn_degrade (degrade paths) or pass a "
                    "category callers can filter on",
                ))
    return out
