"""CLI for the static checker: ``python -m repro.analysis``.

Runs the selected rules over the tree and prints findings one per line
(or as a JSON report with ``--format json`` — the form the CI lint job
parses; model-tier exploration stats ride along in its ``stats`` block).
Exit status: 0 clean, 1 findings, 2 usage error (unknown rule / bad
budget), 3 exploration budget exhausted with no other findings — an
unchecked state space is an unknown, never a silent pass.

``--list`` and usage errors stay import-light: rule bodies import the
substrate (jax) lazily, so listing rules or mistyping a name never pays
for — or requires — a working accelerator stack.
"""
from __future__ import annotations

import argparse
import json
import sys

from repro.analysis.base import registered_rules, run_rules


def main(argv=None) -> int:
    """Entry point; ``argv`` defaults to sys.argv. Returns the exit code."""
    ap = argparse.ArgumentParser(
        prog="python -m repro.analysis",
        description="three-tier static checker: AST lint over the source "
        "tree, plan/schedule checks on the resolved substrate, and "
        "bounded model checking of the scheduler and overlap schedules",
    )
    ap.add_argument("--rules", default=None,
                    help="comma-separated rule names (default: all)")
    ap.add_argument("--root", default=None,
                    help="source tree for AST rules (default: the repo "
                    "root; plan/model rules always check the installed "
                    "package)")
    ap.add_argument("--budget", default=None, metavar="STATES[,DEPTH]",
                    help="model-tier exploration ceiling: max distinct "
                    "states and optional max DFS depth per exploration "
                    "(default: explore.Budget(); exhaustion exits 3)")
    ap.add_argument("--format", choices=("text", "json"), default="text")
    ap.add_argument("--list", action="store_true",
                    help="list registered rules (name, tier, summary) "
                    "and exit")
    args = ap.parse_args(argv)

    if args.list:
        for rule in registered_rules():
            print(f"{rule.name:32s} [{rule.tier:5s}]  {rule.doc}")
        return 0

    budget = None
    if args.budget is not None:
        from repro.analysis.explore import Budget

        try:
            budget = Budget.parse(args.budget)
        except ValueError as e:
            print(e.args[0], file=sys.stderr)
            return 2

    names = args.rules.split(",") if args.rules else None
    stats: dict = {}
    try:
        findings = run_rules(names, root=args.root, budget=budget,
                             stats=stats)
    except KeyError as e:
        print(e.args[0], file=sys.stderr)
        return 2

    violations = [f for f in findings if f.kind == "violation"]
    exhausted = [f for f in findings if f.kind == "budget-exhausted"]
    explored = sum(s["states"] for per_rule in stats.values()
                   for s in per_rule.values())
    if args.format == "json":
        print(json.dumps({
            "rules": names or [r.name for r in registered_rules()],
            "count": len(findings),
            "findings": [
                {"rule": f.rule, "path": f.path, "line": f.line,
                 "message": f.message, "kind": f.kind}
                for f in findings
            ],
            "stats": stats,
        }, indent=2))
    else:
        for f in findings:
            print(f.format())
        if stats:
            print(f"explored {explored} distinct states across "
                  f"{sum(len(v) for v in stats.values())} model-tier "
                  f"exploration(s)", file=sys.stderr)
        if findings:
            print(f"{len(findings)} finding(s)"
                  + (f" ({len(exhausted)} budget-exhausted)"
                     if exhausted else ""), file=sys.stderr)
    if violations:
        return 1
    return 3 if exhausted else 0


if __name__ == "__main__":
    sys.exit(main())
