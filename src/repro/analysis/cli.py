"""CLI for the static checker: ``python -m repro.analysis``.

Runs the selected rules over the tree and prints findings one per line
(or as a JSON report with ``--format json`` — the form the CI lint job
parses). Exit status: 0 clean, 1 findings, 2 usage error (unknown rule).
"""
from __future__ import annotations

import argparse
import json
import sys

from repro.analysis import ast_rules, plan_rules  # noqa: F401  (register)
from repro.analysis.base import registered_rules, run_rules


def main(argv=None) -> int:
    """Entry point; ``argv`` defaults to sys.argv. Returns the exit code."""
    ap = argparse.ArgumentParser(
        prog="python -m repro.analysis",
        description="two-tier static checker: AST lint over the source "
        "tree plus plan/schedule checks on the resolved substrate",
    )
    ap.add_argument("--rules", default=None,
                    help="comma-separated rule names (default: all)")
    ap.add_argument("--root", default=None,
                    help="source tree for AST rules (default: the repo "
                    "root; plan rules always check the installed package)")
    ap.add_argument("--format", choices=("text", "json"), default="text")
    ap.add_argument("--list", action="store_true",
                    help="list registered rules and exit")
    args = ap.parse_args(argv)

    if args.list:
        for rule in registered_rules():
            print(f"{rule.name:32s} [{rule.tier}]  {rule.doc}")
        return 0

    names = args.rules.split(",") if args.rules else None
    try:
        findings = run_rules(names, root=args.root)
    except KeyError as e:
        print(e.args[0], file=sys.stderr)
        return 2

    if args.format == "json":
        print(json.dumps({
            "rules": names or [r.name for r in registered_rules()],
            "count": len(findings),
            "findings": [
                {"rule": f.rule, "path": f.path, "line": f.line,
                 "message": f.message}
                for f in findings
            ],
        }, indent=2))
    else:
        for f in findings:
            print(f.format())
        if findings:
            print(f"{len(findings)} finding(s)", file=sys.stderr)
    return 1 if findings else 0


if __name__ == "__main__":
    sys.exit(main())
