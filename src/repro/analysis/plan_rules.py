"""Tier-B rules: checks on resolved plans, programs, and schedules.

No devices, no compilation: everything here runs on the same device-free
artifacts the dry-run uses — ``collectives.ring_schedule`` event lists,
``StreamProgram`` objects built by the autotune suite's case factories,
and partition plans resolved against ``partition.MeshSpec``. The point is
to check the *exact executed artifact*: ``ring_scan`` replays the very
schedule the overlap-schedule rule verifies, and the VMEM rule prices the
very programs ``stream_compute`` launches.

  overlap-schedule     ring schedules are hazard-free (buffer aliasing,
                       DMA-wait ordering, fold coverage/order)
  vmem-budget          every suite program fits the VMEM budget at the
                       registry's default block geometry, and validates
  mesh-divisibility    every partitioned op resolves a plan on both
                       production meshes (no silent-replication dead end)
  plan-collective-axes plan levels and collective costs stay inside the
                       mesh/vocabulary/kind vocabularies
  accum-dtype-widening every suite program streaming sub-fp32 floating
                       operands declares an fp32+ accumulator (scratch or
                       out stream) — the expanding-accumulation contract

The ``check_*`` helpers are the public seam: rules call them over the
live substrate, tests call them over seeded-bad inputs.
"""
from __future__ import annotations

import warnings

from repro.analysis.base import Context, Finding, register_rule

# the production meshes every partitioned op must resolve on (DESIGN.md C5:
# single-pod 16x16 and the two-pod D2D hierarchy)
PRODUCTION_MESH_SHAPES = (
    {"data": 16, "model": 16},
    {"pod": 2, "data": 16, "model": 16},
)

# CollectiveCost.kind vocabulary (topology.collective_seconds pricing table)
COLLECTIVE_KINDS = frozenset(
    {"all_reduce", "all_gather", "reduce_scatter", "permute"}
)


def check_hop_schedule(events, hops: int, *, remote_copy: bool = False):
    """Verify one ring schedule against the double-buffer discipline.

    Args: ``events`` — ``collectives.HopEvent`` sequence (the schedule
    ``ring_scan`` replays); ``hops`` — the ring length the schedule must
    cover; ``remote_copy`` — whether the transport is the RDMA pair
    (``dma_start``/``dma_wait``) rather than a synchronous ``send``.

    Returns problem strings (empty = hazard-free). Checked invariants:
    every transfer of hop t reads the buffer holding hop t-1 and must not
    land in a buffer whose hop has not been folded yet (the overlap alias
    hazard — the merge of hop t racing the landing of hop t+1); every
    fold of hop t reads the buffer holding exactly hop t, AFTER its DMA
    semaphore wait when the transport is RDMA; folds cover 0..hops-1 in
    order; no dma_start is left without its dma_wait.
    """
    problems: list[str] = []
    versions = {0: 0}   # buffer -> the hop whose block it holds
    arrived = {0}       # hops whose data is visible (DMA complete / sync)
    pending: dict = {}  # buffer -> hop of an un-waited dma_start
    folded: list[int] = []
    for ev in events:
        if ev.kind in ("send", "dma_start"):
            t = ev.hop
            if versions.get(ev.src) != t - 1:
                problems.append(
                    f"hop {t} {ev.kind} reads buffer {ev.src} holding hop "
                    f"{versions.get(ev.src)}, expected hop {t - 1}"
                )
            dst_hop = versions.get(ev.dst)
            if dst_hop is not None and dst_hop < hops and dst_hop not in folded:
                problems.append(
                    f"hop {t} {ev.kind} lands in buffer {ev.dst} still "
                    f"holding unfolded hop {dst_hop} (overlap alias hazard)"
                )
            versions[ev.dst] = t
            if ev.kind == "dma_start":
                pending[ev.dst] = t
            else:
                arrived.add(t)
        elif ev.kind == "dma_wait":
            started = pending.pop(ev.dst, None)
            if started != ev.hop:
                problems.append(
                    f"dma_wait for hop {ev.hop} on buffer {ev.dst} without "
                    f"a matching dma_start"
                )
            else:
                arrived.add(ev.hop)
        elif ev.kind == "fold":
            t = ev.hop
            held = versions.get(ev.src)
            if held != t:
                problems.append(
                    f"fold of hop {t} reads buffer {ev.src} holding hop "
                    f"{held}"
                )
            elif t not in arrived:
                problems.append(
                    f"fold of hop {t} consumes buffer {ev.src} before its "
                    f"DMA semaphore wait — unordered RDMA read"
                )
            expected = folded[-1] + 1 if folded else 0
            if t != expected:
                problems.append(
                    f"fold order broken: hop {t} folded after {folded}"
                )
            folded.append(t)
        else:
            problems.append(f"unknown event kind {ev.kind!r}")
    if sorted(set(folded)) != list(range(hops)):
        problems.append(
            f"folds {sorted(set(folded))} do not cover hops 0..{hops - 1}"
        )
    if pending:
        problems.append(
            f"dma_start without dma_wait on buffers {sorted(pending)}"
        )
    return problems


@register_rule("overlap-schedule", tier="plan")
def overlap_schedule(ctx: Context) -> list[Finding]:
    """Every schedule ring_scan can replay is hazard-free.

    Sweeps ``ring_schedule`` over hop counts 1..8 x {overlap, sync} x
    {ppermute, remote_copy} and runs ``check_hop_schedule`` on each — the
    schedule checked is the schedule executed, by construction.
    """
    from repro.parallel.collectives import ring_schedule

    out = []
    for hops in range(1, 9):
        for overlap in (False, True):
            for remote in (False, True):
                events = ring_schedule(
                    hops, overlap=overlap, remote_copy=remote
                )
                for p in check_hop_schedule(events, hops, remote_copy=remote):
                    out.append(Finding(
                        "overlap-schedule", "repro.parallel.collectives", 0,
                        f"ring_schedule(hops={hops}, overlap={overlap}, "
                        f"remote_copy={remote}): {p}",
                    ))
    return out


def check_program(program, *, budget_bytes: int | None = None):
    """Structural + VMEM feasibility problems of one StreamProgram.

    Args: ``program`` — the StreamProgram to check; ``budget_bytes`` — the
    VMEM ceiling (None = ``autotune.VMEM_BUDGET_BYTES``). Returns problem
    strings: everything ``StreamProgram.validate(strict=True)`` reports,
    plus an overflow entry when the double-buffered residency exceeds the
    budget.
    """
    from repro.launch.autotune import VMEM_BUDGET_BYTES

    budget = VMEM_BUDGET_BYTES if budget_bytes is None else budget_bytes
    problems = list(program.validate(strict=True))
    vmem = program.vmem_bytes()
    if vmem > budget:
        problems.append(
            f"{program.name}: vmem_bytes()={vmem} exceeds the "
            f"{budget}-byte VMEM budget at default geometry"
        )
    return problems


def _suite_programs():
    """Yield ``(suite_name, program)`` for every ``autotune.full_suite()``
    case's StreamProgram at the registry's pristine default geometry —
    the shared sweep of the vmem-budget and accum-dtype-widening rules
    (``full_suite`` so the policy-scoped scaled-path programs are swept
    too, under their ``op@policy`` suite names)."""
    import numpy as np

    from repro.kernels import registry
    from repro.launch import autotune

    rng = np.random.default_rng(0)
    for name, factory in sorted(autotune.full_suite().items()):
        case = factory(rng)
        blocks = registry.block_defaults(case.op, overrides=False)
        yield name, case.program(blocks)


@register_rule("vmem-budget", tier="plan")
def vmem_budget(ctx: Context) -> list[Finding]:
    """Default block geometry fits VMEM for every suite program.

    Builds each autotune suite case's StreamProgram at the registry's
    pristine defaults (``block_defaults(op, overrides=False)``) and runs
    ``check_program``: an op whose default geometry overflows VMEM would
    make the autotuner's baseline un-measurable and the production default
    un-launchable on hardware.
    """
    out = []
    for name, program in _suite_programs():
        for p in check_program(program):
            out.append(Finding(
                "vmem-budget", f"repro.launch.autotune:{name}", 0, p,
            ))
    return out


def check_accum_widening(program):
    """Expanding-accumulation problems of one StreamProgram.

    A program streaming sub-fp32 *floating* operands (fp8/bf16 values)
    must carry the running sum at fp32 or wider — the paper's widening
    sum-dot-product contract (C6/Fig. 10): narrow-format throughput is
    only usable when the accumulator does not saturate. Structurally that
    means at least one fp32+ floating landing site: a VMEM scratch (the
    blocked kernels' accumulator) or an fp32+ out stream (single-pass
    kernels that write widened results directly). Integer streams (index
    operands) and full-width programs are exempt. Returns problem strings.
    """
    import jax.numpy as jnp

    def _floating(dt):
        return dt is not None and jnp.issubdtype(jnp.dtype(dt), jnp.floating)

    def _width(dt):
        return jnp.dtype(dt).itemsize

    narrow = [
        s for s in program.in_streams
        if _floating(s.dtype) and _width(s.dtype) < 4
    ]
    if not narrow:
        return []
    wide_scratch = any(
        _floating(getattr(s, "dtype", None)) and _width(s.dtype) >= 4
        for s in program.scratch
    )
    wide_out = any(
        _floating(s.dtype) and _width(s.dtype) >= 4
        for s in program.out_streams
    )
    if wide_scratch or wide_out:
        return []
    widths = sorted({str(jnp.dtype(s.dtype)) for s in narrow})
    return [
        f"{program.name}: streams sub-fp32 floating operands ({', '.join(widths)}) "
        f"but declares no fp32+ accumulator — no floating scratch or out "
        f"stream is >= 4 bytes wide, so the expanding accumulation the "
        f"narrow format requires has nowhere to live"
    ]


@register_rule("accum-dtype-widening", tier="plan")
def accum_dtype_widening(ctx: Context) -> list[Finding]:
    """Sub-fp32 suite programs declare a full-width accumulator.

    Runs ``check_accum_widening`` over every ``autotune.full_suite()``
    program (which includes the policy-scoped scaled-path cases): a
    low-precision kernel whose StreamProgram carries neither an fp32+
    scratch nor an fp32+ out stream would accumulate in the narrow format
    and saturate — exactly the failure mode the precision ladder's
    expanding accumulation exists to prevent.
    """
    out = []
    for name, program in _suite_programs():
        for p in check_accum_widening(program):
            out.append(Finding(
                "accum-dtype-widening", f"repro.launch.autotune:{name}", 0, p,
            ))
    return out


def check_mesh_cases(cases, mesh_shape: dict):
    """Resolve every case's plan on one mesh; return problem strings.

    Args: ``cases`` — ``(op, args, kwargs, ...)`` tuples in the
    ``op_cases.op_roofline_cases`` format; ``mesh_shape`` — the
    ``{axis: size}`` MeshSpec shape to resolve against. A case whose
    ladder exhausts (plan None — silent replication) is a problem, as is a
    resolved plan whose level sizes disagree with the mesh.
    """
    from repro.kernels import partition

    mesh = partition.MeshSpec(dict(mesh_shape))
    tag = "x".join(f"{a}={s}" for a, s in mesh_shape.items())
    problems = []
    for op, args, kwargs, *_ in cases:
        with warnings.catch_warnings():
            warnings.simplefilter("ignore")
            plan = partition.plan_for(op, mesh, *args, **kwargs)
        if plan is None:
            problems.append(
                f"{op}: partition ladder dead-ends on mesh ({tag}) — every "
                f"rung declined, the call silently replicates"
            )
            continue
        for axis, size in plan.levels:
            if axis not in mesh.shape:
                problems.append(
                    f"{op}: plan level axis {axis!r} not in mesh ({tag})"
                )
            elif int(mesh.shape[axis]) % size != 0:
                problems.append(
                    f"{op}: plan level {axis}={size} does not divide the "
                    f"mesh axis ({tag})"
                )
    return problems


@register_rule("mesh-divisibility", tier="plan")
def mesh_divisibility(ctx: Context) -> list[Finding]:
    """Every partitioned op plans cleanly on both production meshes.

    Resolves the shared ``op_cases`` table against the single-pod 16x16
    and two-pod 2x16x16 MeshSpecs and flags ladder dead-ends (silent
    replication) and level/mesh size mismatches. Also a coverage gate:
    every op with a registered PartitionRule must appear in the case
    table, so a new partitioned op cannot dodge the check.
    """
    from repro.kernels import ops as _ops  # noqa: F401  (registers rules)
    from repro.kernels import partition
    from repro.launch.op_cases import op_roofline_cases

    out = []
    cases = op_roofline_cases()
    covered = {c[0] for c in cases}
    for op in partition.partitioned_ops():
        if op not in covered:
            out.append(Finding(
                "mesh-divisibility", "repro.launch.op_cases", 0,
                f"partitioned op {op!r} has no op_roofline_cases entry — "
                f"its production-mesh plans are unchecked",
            ))
    for shape in PRODUCTION_MESH_SHAPES:
        for p in check_mesh_cases(cases, shape):
            out.append(Finding(
                "mesh-divisibility", "repro.kernels.partition", 0, p,
            ))
    return out


def check_plan(plan, mesh_shape: dict):
    """Vocabulary problems of one resolved PartitionPlan.

    Args: ``plan`` — the PartitionPlan; ``mesh_shape`` — the ``{axis:
    size}`` shape it resolved against. Checks every level axis and every
    ``CollectiveCost`` against the partition vocabulary: axes must be
    mesh axes in ``AXIS_VOCAB``, kinds must be priceable by
    ``topology.collective_seconds``, payloads non-negative, and an
    overlappable plan must declare the hop count its pipeline amortises.
    """
    from repro.kernels.partition import AXIS_VOCAB

    problems = []
    name = plan.op
    for axis, _size in plan.levels:
        if axis not in AXIS_VOCAB:
            problems.append(
                f"{name}: level axis {axis!r} outside AXIS_VOCAB {AXIS_VOCAB}"
            )
        if axis not in mesh_shape:
            problems.append(
                f"{name}: level axis {axis!r} not an axis of the mesh"
            )
    for c in plan.collectives:
        if c.kind not in COLLECTIVE_KINDS:
            problems.append(
                f"{name}: collective kind {c.kind!r} not priceable "
                f"(known: {sorted(COLLECTIVE_KINDS)})"
            )
        if c.axis not in AXIS_VOCAB or c.axis not in mesh_shape:
            problems.append(
                f"{name}: collective over axis {c.axis!r} outside the "
                f"mesh/vocabulary"
            )
        if c.nbytes < 0 or c.n < 0:
            problems.append(
                f"{name}: collective {c.kind} has negative nbytes/n"
            )
    if plan.overlappable and plan.hops < 2:
        problems.append(
            f"{name}: overlappable plan declares hops={plan.hops}; the "
            f"overlap model needs >= 2 pipeline stages to hide anything"
        )
    return problems


@register_rule("plan-collective-axes", tier="plan")
def plan_collective_axes(ctx: Context) -> list[Finding]:
    """Resolved plans only speak the partition vocabulary.

    Runs ``check_plan`` on every op_cases plan over both production
    meshes: level axes and collective-cost axes must be mesh axes from
    ``AXIS_VOCAB``, collective kinds must be priceable, and overlap
    metadata must be self-consistent — the contract the roofline and
    topology layers assume without checking.
    """
    from repro.kernels import ops as _ops  # noqa: F401  (registers rules)
    from repro.kernels import partition
    from repro.launch.op_cases import op_roofline_cases

    out = []
    for shape in PRODUCTION_MESH_SHAPES:
        mesh = partition.MeshSpec(dict(shape))
        for op, args, kwargs, *_ in op_roofline_cases():
            with warnings.catch_warnings():
                warnings.simplefilter("ignore")
                plan = partition.plan_for(op, mesh, *args, **kwargs)
            if plan is None:
                continue  # mesh-divisibility owns the dead-end finding
            for p in check_plan(plan, shape):
                out.append(Finding(
                    "plan-collective-axes", "repro.kernels.partition", 0, p,
                ))
    return out


def check_paged_coverage(scheduler, token_for, *, steps: int = 2000):
    """Drive one continuous-batching ``scheduler`` to drain and audit the
    paged-cache ledger invariants every step.

    Args: ``scheduler`` — a ``serving.scheduler.ContinuousBatchingScheduler``
    with requests already submitted; ``token_for(seq, step)`` — the
    synthetic next-token function (the replay needs *a* stream, not a
    model); ``steps`` — drain bound (a scheduler that cannot drain is
    itself a finding).

    Checked at every step (the properties the device gather relies on —
    any violation means ``decode_attention``'s block-table gather reads
    another sequence's pages or an unwritten one):

      - live block ownership is disjoint across running sequences and
        consistent with the allocator's ledger
      - every running sequence's block list covers exactly the logical
        blocks its cached positions occupy (prefix-coverage: entry j holds
        positions [j*bs, (j+1)*bs))
      - ``NULL_BLOCK`` never appears in a live block list
      - the allocator's free+owned sets partition the pool (its own
        ``check``)

    and at drain: every submitted request finished, zero leaked blocks.
    Returns problem strings (empty = invariants hold).
    """
    from repro.serving.scheduler import NULL_BLOCK

    problems: list[str] = []
    bs = scheduler.block_size
    step = 0
    while not scheduler.idle() and step < steps and not problems:
        for seq in scheduler.admit(step):
            scheduler.record_token(seq, token_for(seq, step))
            if scheduler.should_retire(seq, None):
                scheduler.retire(seq, step)
        for slot in sorted(scheduler.running):
            seq = scheduler.running.get(slot)
            if seq is None or not scheduler.ensure_block(seq, step):
                continue
            scheduler.record_token(seq, token_for(seq, step))
            if scheduler.should_retire(seq, None):
                scheduler.retire(seq, step)

        owned_all: dict[int, int] = {}
        for seq in scheduler.running.values():
            if NULL_BLOCK in seq.blocks:
                problems.append(
                    f"step {step}: rid {seq.rid} holds NULL_BLOCK in a "
                    f"live block list"
                )
            need = seq.tokens_cached()
            have = len(seq.blocks) * bs
            if have < need:
                problems.append(
                    f"step {step}: rid {seq.rid} caches {need} positions "
                    f"but its table covers only {have}"
                )
            if sorted(seq.blocks) != scheduler.allocator.owned_by(seq.rid):
                problems.append(
                    f"step {step}: rid {seq.rid} block list "
                    f"{sorted(seq.blocks)} != allocator ledger "
                    f"{scheduler.allocator.owned_by(seq.rid)}"
                )
            for b in seq.blocks:
                if b in owned_all:
                    problems.append(
                        f"step {step}: block {b} owned by both rid "
                        f"{owned_all[b]} and rid {seq.rid}"
                    )
                owned_all[b] = seq.rid
        problems.extend(
            f"step {step}: {p}" for p in scheduler.allocator.check()
        )
        step += 1

    if not scheduler.idle() and not problems:
        problems.append(
            f"scheduler did not drain in {steps} steps "
            f"(running={sorted(s.rid for s in scheduler.running.values())})"
        )
    if scheduler.idle():
        leaked = scheduler.leaked_blocks()
        if leaked:
            problems.append(f"drained with {leaked} leaked blocks")
        unfinished = scheduler._seen_rids - set(scheduler.finished)
        if unfinished:
            problems.append(
                f"drained but requests never finished: {sorted(unfinished)}"
            )
    return problems


@register_rule("paged-gather-coverage", tier="plan")
def paged_gather_coverage(ctx: Context) -> list[Finding]:
    """The serving scheduler's block ledger upholds the gather contract.

    Replays seeded synthetic workloads — including a pool tight enough to
    force preemption and a mixed-priority mix — through the real
    ``ContinuousBatchingScheduler`` (pure Python, device-free) and runs
    ``check_paged_coverage`` on each: the device-side block-table gather
    in paged ``decode_attention`` is only correct if ownership stays
    disjoint, tables prefix-cover the cached positions, and NULL_BLOCK
    stays out of live prefixes. A violation here is a cross-sequence KV
    read waiting to happen.
    """
    import random

    from repro.serving.scheduler import ContinuousBatchingScheduler, Request

    def token_for(seq, step):
        return (seq.generated[-1] * 31 + 7) % 97 if seq.generated else 1

    out = []
    scenarios = {
        "tight-pool": dict(num_blocks=7, block_size=4, max_slots=3,
                           max_blocks_per_seq=5),
        "roomy-pool": dict(num_blocks=64, block_size=8, max_slots=8,
                           max_blocks_per_seq=None),
    }
    for name, kw in scenarios.items():
        rng = random.Random(name)
        sched = ContinuousBatchingScheduler(**kw)
        for rid in range(24):
            sched.submit(Request(
                rid=rid,
                prompt=tuple(rng.randrange(1, 97)
                             for _ in range(rng.randrange(1, 9))),
                max_new_tokens=rng.randrange(1, 10),
                priority=rng.randrange(0, 3),
                arrival=rng.randrange(0, 12),
            ))
        for p in check_paged_coverage(sched, token_for):
            out.append(Finding(
                "paged-gather-coverage", "repro.serving.scheduler", 0,
                f"[{name}] {p}",
            ))
    return out
