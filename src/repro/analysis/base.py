"""Rule registry and source-tree model for the static checker.

Mirrors the ``kernels/registry.py`` idiom: rules register themselves into a
module-level table via a decorator, callers select by name, and unknown
names fail loudly with the known-name list. Three tiers share the table:

  - ``ast`` rules parse the source tree (no repro imports, no jax) and
    check syntactic invariants — the grep-style assertions that used to
    live inline in tests, promoted to reusable, fixture-testable checks.
  - ``plan`` rules import the live substrate and check *resolved
    artifacts* — ring schedules, StreamPrograms, partition plans — on
    device-free MeshSpecs, so they run anywhere the tests run.
  - ``model`` rules exhaustively explore bounded *state spaces*
    (``analysis.explore``): scheduler action interleavings, DMA landing
    orders, dtype dataflow — checking every reachable state, not one
    trace. They honor ``Context.budget`` and report exploration stats
    through ``Context.record_stats``.

Every rule takes a ``Context`` and returns ``Finding`` records; an empty
run is the green state CI gates on.
"""
from __future__ import annotations

import ast
import dataclasses
import pathlib
from typing import Callable

# directories never scanned by AST rules: generated/vcs trees, and tests —
# tests/analysis_fixtures holds deliberately-seeded violations
EXCLUDED_DIRS = frozenset(
    {".git", ".github", "__pycache__", "tests", ".pytest_cache", "docs"}
)


@dataclasses.dataclass(frozen=True)
class Finding:
    """One violation. Fields: ``rule`` — the reporting rule's registered
    name; ``path`` — offending file, relative to the scanned root (plan
    rules, which check resolved objects rather than files, use a module
    path like ``repro.kernels.partition``); ``line`` — 1-based source line
    (0 when no source location applies); ``message`` — what is wrong and
    why it matters; ``kind`` — ``"violation"`` for real findings, or
    ``"budget-exhausted"`` when a model-tier exploration was truncated
    (the state space is unchecked, which the CLI maps to its own exit
    code rather than pass or fail)."""

    rule: str
    path: str
    line: int
    message: str
    kind: str = "violation"

    def format(self) -> str:
        """Render as the one-line ``rule: path:line: message`` CLI form."""
        loc = f"{self.path}:{self.line}" if self.line else self.path
        return f"{self.rule}: {loc}: {self.message}"


@dataclasses.dataclass(frozen=True)
class SourceFile:
    """One parsed file of the scanned tree. Fields: ``path`` — absolute
    path; ``rel`` — posix-style path relative to the scanned root (what
    rule heuristics match on); ``text`` — the source; ``tree`` — the
    parsed ``ast.Module``."""

    path: pathlib.Path
    rel: str
    text: str
    tree: ast.Module


class Context:
    """What a rule run sees: the scanned ``root`` and its parsed files.

    Files are loaded lazily on first access and cached; files that fail to
    parse become ``parse_errors`` findings (reported once per run) instead
    of aborting the sweep. Plan/model rules ignore the tree entirely —
    they exist in the same Context so one CLI invocation runs every tier.
    Model-tier rules additionally read ``budget`` (an ``explore.Budget``
    or None for the default) and report per-exploration counters through
    ``record_stats``; the accumulated ``stats`` mapping is what the CLI
    surfaces as the finding summary / ``--format json`` stats block.
    """

    def __init__(self, root: pathlib.Path, *, budget=None):
        self.root = pathlib.Path(root)
        self._files: list[SourceFile] | None = None
        self.parse_errors: list[Finding] = []
        self.budget = budget
        self.stats: dict[str, dict] = {}  # rule -> {tag: Stats.as_dict()}

    def record_stats(self, rule: str, tag: str, stats) -> None:
        """Record one exploration's counters (an ``explore.Stats``)."""
        self.stats.setdefault(rule, {})[tag] = stats.as_dict()

    @property
    def files(self) -> list[SourceFile]:
        """The tree's parsed ``SourceFile`` records, sorted by ``rel``."""
        if self._files is None:
            self._files = []
            for path in sorted(self.root.rglob("*.py")):
                parts = path.relative_to(self.root).parts
                if any(p in EXCLUDED_DIRS for p in parts[:-1]):
                    continue
                rel = "/".join(parts)
                text = path.read_text()
                try:
                    tree = ast.parse(text, filename=str(path))
                except SyntaxError as e:
                    self.parse_errors.append(Finding(
                        "parse-error", rel, e.lineno or 0,
                        f"not parseable: {e.msg}",
                    ))
                    continue
                self._files.append(SourceFile(path, rel, text, tree))
        return self._files

    def find(self, suffix: str) -> SourceFile | None:
        """The unique file whose ``rel`` ends with ``suffix``, or None."""
        hits = [f for f in self.files if f.rel.endswith(suffix)]
        return hits[0] if len(hits) == 1 else None


@dataclasses.dataclass(frozen=True)
class Rule:
    """A registered check. Fields: ``name`` — kebab-case id used on the
    CLI; ``tier`` — ``"ast"`` (source-tree lint), ``"plan"`` (resolved
    schedule/plan check) or ``"model"`` (exhaustive bounded exploration);
    ``fn`` — ``fn(ctx) -> list[Finding]``; ``doc`` — the one-line summary
    shown by ``--list``."""

    name: str
    tier: str
    fn: Callable
    doc: str


_RULES: dict[str, Rule] = {}


def register_rule(name: str, *, tier: str) -> Callable:
    """Decorator: ``@register_rule("single-pallas-site", tier="ast")``.

    Args: ``name`` — the rule's CLI id (must be unique); ``tier`` — one of
    ``"ast"`` / ``"plan"`` / ``"model"``. The decorated function's first
    docstring line becomes the rule's ``--list`` summary.
    """
    if tier not in ("ast", "plan", "model"):
        raise ValueError(
            f"unknown tier {tier!r}; one of ('ast', 'plan', 'model')")

    def deco(fn: Callable) -> Callable:
        if name in _RULES:
            raise ValueError(f"duplicate rule name {name!r}")
        doc = (fn.__doc__ or "").strip().splitlines()[0] if fn.__doc__ else ""
        _RULES[name] = Rule(name, tier, fn, doc)
        return fn

    return deco


def _ensure_rule_modules() -> None:
    # rules live in sibling modules and register on import; importing them
    # here (not in __init__) keeps `from repro.analysis import Finding`
    # cheap while making registered_rules()/run_rules() self-sufficient
    from repro.analysis import ast_rules, model_rules, plan_rules  # noqa: F401

TIER_ORDER = ("ast", "plan", "model")


def registered_rules() -> list[Rule]:
    """Every registered rule, ast tier first, then plan, then model."""
    _ensure_rule_modules()
    return sorted(_RULES.values(),
                  key=lambda r: (TIER_ORDER.index(r.tier), r.name))


def default_root() -> pathlib.Path:
    """The repo root this package is installed from (three levels above
    ``src/repro/analysis``) — the tree a bare ``python -m repro.analysis``
    scans, covering ``src/`` and ``benchmarks/`` in one sweep."""
    return pathlib.Path(__file__).resolve().parents[3]


def run_rules(rules=None, root=None, *, budget=None,
              stats=None) -> list[Finding]:
    """Run the selected rules and return every finding.

    Args: ``rules`` — iterable of rule names (None = all registered;
    unknown names raise KeyError listing the known ones); ``root`` — the
    source tree AST rules scan (None = ``default_root()``; plan and model
    rules check the installed substrate regardless); ``budget`` — an
    ``explore.Budget`` for model-tier explorations (None = each rule's
    default); ``stats`` — optional dict the per-exploration counters are
    merged into (``rule -> tag -> counters``). Parse failures in the tree
    are returned as ``parse-error`` findings alongside rule findings.
    """
    table = {r.name: r for r in registered_rules()}
    if rules is None:
        selected = list(table.values())
    else:
        unknown = [n for n in rules if n not in table]
        if unknown:
            raise KeyError(
                f"unknown rules {unknown}; known: {sorted(table)}"
            )
        selected = [table[n] for n in rules]
    ctx = Context(pathlib.Path(root) if root else default_root(),
                  budget=budget)
    findings: list[Finding] = []
    for rule in selected:
        findings.extend(rule.fn(ctx))
    if stats is not None:
        stats.update(ctx.stats)
    return list(ctx.parse_errors) + findings
