"""Tier-C rules: exhaustive bounded model checking over live substrate.

Where the plan tier checks one resolved artifact or replays one trace,
these rules enumerate *state spaces* with ``analysis.explore`` and check
every reached state — the interleavings a single trace or test seed never
visits:

  scheduler-model        explore ALL submit/admit/decode interleavings of
                         the continuous-batching scheduler's abstract twin
                         on small bounded configs; block-ledger safety +
                         bounded-liveness (starvation) in every state
  overlap-interleavings  explore ALL legal DMA-landing timings of every
                         ring hop schedule (hops 1-8 x overlap x
                         remote_copy, plus the plan-derived zigzag/plain
                         ring schedules) — a race detector, not a replay
  dtype-dataflow         abstract interpretation of (dtype, scale-carried)
                         lattice values through every autotune suite
                         StreamProgram and the paged KV pools: narrowing
                         without a scale, fp8 folded outside an fp32
                         accumulator, quantized-pool reads without per-row
                         scales

The ``check_*`` helpers and ``explore.*Model`` classes are the public
seam: rules sweep the live substrate, tests feed the same helpers
seeded-bad fixtures (``tests/analysis_fixtures/``). Rule functions import
jax lazily so ``--list``/usage-error CLI paths stay import-light; the
scheduler-model rule needs no jax at all.
"""
from __future__ import annotations

from repro.analysis import explore
from repro.analysis.base import Context, Finding, register_rule


def _explored_findings(rule: str, path: str, tag: str, problems, stats,
                       ctx: Context) -> list:
    """Wrap one exploration's problems as findings, record its stats, and
    surface budget exhaustion as a distinct ``budget-exhausted`` finding
    (never a silent pass — the CLI maps it to exit code 3)."""
    ctx.record_stats(rule, tag, stats)
    out = [Finding(rule, path, 0, f"{tag}: {p}") for p in problems]
    if stats.truncated:
        out.append(Finding(
            rule, path, 0,
            f"{tag}: exploration truncated at {stats.states} states / "
            f"depth {stats.max_depth} — budget exhausted, the remaining "
            f"state space is UNCHECKED (raise --budget)",
            kind="budget-exhausted",
        ))
    return out


@register_rule("scheduler-model", tier="model")
def scheduler_model(ctx: Context) -> list[Finding]:
    """Exhaustively model-check the continuous-batching scheduler.

    Explores every submit/admit/decode interleaving of
    ``explore.SchedulerModel`` (the abstract twin the bisimulation test
    locks to ``serving.scheduler``) over the bounded
    ``explore.SCHEDULER_CONFIGS``, checking the block-ledger safety
    invariants in every reached state — no double alloc/free, no
    NULL_BLOCK ownership, slot cap, prefix coverage, rid lifecycle
    disjointness — plus starvation bounds and clean drains at every leaf.
    Pure Python: no jax anywhere on this path.
    """
    out = []
    for tag, config in explore.SCHEDULER_CONFIGS:
        problems, stats = explore.explore(
            explore.SchedulerModel(config), ctx.budget)
        out.extend(_explored_findings(
            "scheduler-model", "repro.serving.scheduler", tag, problems,
            stats, ctx))
    return out


@register_rule("overlap-interleavings", tier="model")
def overlap_interleavings(ctx: Context) -> list[Finding]:
    """Race-check ring schedules under ALL legal DMA timings.

    The plan tier's ``overlap-schedule`` replays each ``ring_schedule``
    event list once, in program order. This rule explores every
    interleaving the schedule actually permits — an RDMA copy lands
    whenever the fabric delivers it, so ``explore_hop_interleavings``
    schedules each landing nondeterministically and flags any ordering
    where a fold (or a later transfer) touches a buffer whose copy has
    not landed. Sweeps hops 1..8 x {overlap, sync} x {ppermute,
    remote_copy}, plus the hop counts of the production flash-attention
    ring plans resolved with zigzag on and off — the schedule checked is
    the schedule ``ring_scan`` executes.
    """
    import warnings

    from repro.parallel.collectives import ring_schedule

    out = []
    sweeps = {(hops, overlap, remote): "ring_schedule"
              for hops in range(1, 9)
              for overlap in (False, True)
              for remote in (False, True)}

    # the executed artifact: production-mesh ring plans, zigzag on/off
    from repro.kernels import ops as _ops  # noqa: F401  (registers rules)
    from repro.kernels import partition
    from repro.launch.op_cases import op_roofline_cases

    case = next(c for c in op_roofline_cases() if c[0] == "flash_attention")
    _op, args, kwargs = case[0], case[1], case[2]
    mesh = partition.MeshSpec({"data": 16, "model": 16})
    for zig in (False, True):
        with warnings.catch_warnings():
            warnings.simplefilter("ignore")
            plan = partition.plan_for(
                "flash_attention", mesh, *args, **kwargs, zigzag=zig)
        if plan is None or plan.hops < 2:
            out.append(Finding(
                "overlap-interleavings", "repro.kernels.partition", 0,
                f"flash_attention ring plan (zigzag={zig}) did not resolve "
                f"with >= 2 hops — its schedule cannot be race-checked"))
            continue
        for overlap in (False, True):
            for remote in (False, True):
                sweeps.setdefault(
                    (plan.hops, overlap, remote), f"ring plan zigzag={zig}")

    for (hops, overlap, remote), origin in sorted(
            sweeps.items(), key=lambda kv: kv[0]):
        events = ring_schedule(hops, overlap=overlap, remote_copy=remote)
        problems, stats = explore.explore_hop_interleavings(
            events, hops, ctx.budget)
        tag = (f"{origin}(hops={hops}, overlap={overlap}, "
               f"remote_copy={remote})")
        out.extend(_explored_findings(
            "overlap-interleavings", "repro.parallel.collectives", tag,
            problems, stats, ctx))
    return out


# -- dtype dataflow -----------------------------------------------------------


def check_dtype_dataflow(program, policy=None):
    """Abstract-interpret one StreamProgram's dtype/scale dataflow.

    Each stream carries a lattice value ``(class, width, scaled?)`` where
    class is integer or floating and ``scaled?`` marks narrow value
    streams accompanied by an fp32 scale stream (an fp32 in-stream with an
    extent-1 block dimension — the ``gemm_scaled_program`` layout, where
    per-block scales ride (bm, 1)/(1, bn) panels next to the values).
    Propagation: value streams meet at the widest floating landing site
    (scratch accumulator or out stream). Flagged, per the paper's widening
    sum-dot-product contract (C6/Fig. 10) and the block-scaling scheme:

    - fp8 value streams folding into a sub-fp32 accumulator (saturation:
      expanding accumulation has nowhere to live) — generalizes the plan
      tier's ``accum-dtype-widening`` to any narrow float, with the
      accumulator *width* named
    - narrowing without a scale: fp8 value streams (in or out) with no
      scale stream beside them — the narrow format's dynamic range is
      unusable without the per-block scale factors
    - a block-scaled ``policy`` (``scale_block > 0``) whose program
      streams no scales at all

    ``policy`` is a resolved ``core.precision.Precision`` or None.
    Returns problem strings.
    """
    import jax.numpy as jnp

    def lattice(dt):
        if dt is None:
            return None
        d = jnp.dtype(dt)
        if jnp.issubdtype(d, jnp.floating):
            return ("f", d.itemsize)
        return ("i", d.itemsize)

    def floats(streams):
        out = []
        for s in streams:
            v = lattice(getattr(s, "dtype", None))
            if v and v[0] == "f":
                out.append((s, v[1]))
        return out

    in_f = floats(program.in_streams)
    scale_streams = [
        s for s, w in in_f
        if w >= 4 and any(int(b) == 1 for b in s.block_shape)
    ]
    value_in = [(s, w) for s, w in in_f if s not in scale_streams]
    narrow_in = [(s, w) for s, w in value_in if w == 1]
    out_f = floats(program.out_streams)
    narrow_out = [(s, w) for s, w in out_f if w == 1]
    acc_widths = [w for _s, w in floats(program.scratch)]
    acc_widths += [w for _s, w in out_f]
    acc = max(acc_widths, default=None)

    problems = []
    if narrow_in:
        n = len(narrow_in)
        if acc is None:
            problems.append(
                f"{program.name}: {n} fp8 value stream(s) but no floating "
                f"accumulator site at all (no scratch, no float out)")
        elif acc < 4:
            problems.append(
                f"{program.name}: {n} fp8 value stream(s) fold into a "
                f"{acc}-byte accumulator — the expanding accumulation "
                f"needs an fp32+ scratch or out stream")
    if (narrow_in or narrow_out) and not scale_streams:
        where = "in" if narrow_in else "out"
        problems.append(
            f"{program.name}: fp8 {where}-stream(s) carry no fp32 scale "
            f"stream — narrowing without a scale loses the dynamic range "
            f"block scaling exists to keep")
    if (policy is not None and policy.scale_block > 0
            and lattice(policy.compute_dtype)[1] < 2 and not scale_streams):
        problems.append(
            f"{program.name}: policy {policy.name!r} block-scales every "
            f"{policy.scale_block} elements but the program streams no "
            f"scales")
    return problems


def check_quantized_pool(cache):
    """Scale-coverage problems of one ``PagedKVCache``.

    A pool holding sub-fp16 floating values is only readable through its
    per-row scales: ``decode_attention``'s gather dequantizes each cached
    row as ``value * scale``. Flags pools whose values are narrow but
    whose ``k_scale``/``v_scale`` is missing, mis-shaped (must be the pool
    shape with a trailing extent-1 scale-per-row dim), or non-fp32.
    Returns problem strings.
    """
    import jax.numpy as jnp

    problems = []
    for side in ("k", "v"):
        pool = getattr(cache, f"{side}_pool")
        scale = getattr(cache, f"{side}_scale")
        d = jnp.dtype(pool.dtype)
        narrow = jnp.issubdtype(d, jnp.floating) and d.itemsize < 2
        if not narrow:
            continue
        if scale is None:
            problems.append(
                f"{side}_pool holds {d.name} values but {side}_scale is "
                f"None — quantized reads bypass the per-row scales")
            continue
        want = tuple(pool.shape[:-1]) + (1,)
        if tuple(scale.shape) != want:
            problems.append(
                f"{side}_scale shape {tuple(scale.shape)} is not per-row "
                f"{want} — gathered rows dequantize with the wrong scale")
        if jnp.dtype(scale.dtype) != jnp.dtype(jnp.float32):
            problems.append(
                f"{side}_scale dtype {jnp.dtype(scale.dtype).name} is not "
                f"float32")
    return problems


@register_rule("dtype-dataflow", tier="model")
def dtype_dataflow(ctx: Context) -> list[Finding]:
    """Dtype/scale dataflow holds across every suite program and KV pool.

    Runs ``check_dtype_dataflow`` over every ``autotune.full_suite()``
    case's StreamProgram (at pristine default geometry, each under its
    case's resolved precision policy) and ``check_quantized_pool`` over
    paged KV pools initialized under each quantizing policy — so an fp8
    path that drops its scales or narrows its accumulator is a lint
    finding, not a silent numerics regression.
    """
    import numpy as np

    from repro.core import precision as prec
    from repro.kernels import registry
    from repro.launch import autotune
    from repro.serving import paged_cache

    out = []
    rng = np.random.default_rng(0)
    for name, factory in sorted(autotune.full_suite().items()):
        case = factory(rng)
        blocks = registry.block_defaults(case.op, overrides=False)
        policy = prec.resolve(case.precision) if case.precision else None
        for p in check_dtype_dataflow(case.program(blocks), policy):
            out.append(Finding(
                "dtype-dataflow", f"repro.launch.autotune:{name}", 0, p))

    class _PoolCfg:
        num_layers, num_kv_heads, dtype = 1, 2, "float32"

        def resolved_head_dim(self):
            return 8

    for pol in [None] + [n for n, p in sorted(prec.POLICIES.items())
                         if p.scale_block > 0]:
        cache = paged_cache.init_paged_cache(
            _PoolCfg(), num_blocks=3, block_size=2, policy=pol)
        for p in check_quantized_pool(cache):
            out.append(Finding(
                "dtype-dataflow",
                f"repro.serving.paged_cache:policy={pol}", 0, p))
    return out
