"""Static checker for substrate invariants and overlap-schedule hazards.

Two tiers behind one rule registry (``base.register_rule``, mirroring the
kernel registry's idiom):

  - **AST rules** (``ast_rules``): parse the source tree and enforce the
    syntactic invariants the substrate depends on — single pallas_call
    site, registry-only block geometry, append-only XLA_FLAGS, collective
    axis names from the partition vocabulary, the documented-surface
    contract, explicit warning categories.
  - **Plan rules** (``plan_rules``): check *resolved artifacts* with no
    devices — ring schedules for double-buffer aliasing and DMA-wait
    ordering, StreamPrograms against the VMEM budget, partition plans for
    ladder dead-ends and vocabulary drift on the production meshes.

Drive it as ``python -m repro.analysis`` (see ``cli``); CI gates on a
clean run, and tests/test_analysis.py proves every rule fires on the
seeded violations in tests/analysis_fixtures. Import cost is deliberate:
this ``__init__`` pulls only the stdlib-based registry; the plan tier
imports jax lazily inside each rule.
"""
from repro.analysis.base import (  # noqa: F401
    Context,
    Finding,
    Rule,
    default_root,
    register_rule,
    registered_rules,
    run_rules,
)
