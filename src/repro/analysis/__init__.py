"""Static checker for substrate invariants and overlap-schedule hazards.

Three tiers behind one rule registry (``base.register_rule``, mirroring
the kernel registry's idiom):

  - **AST rules** (``ast_rules``): parse the source tree and enforce the
    syntactic invariants the substrate depends on — single pallas_call
    site, registry-only block geometry, append-only XLA_FLAGS, collective
    axis names from the partition vocabulary, the documented-surface
    contract, explicit warning categories.
  - **Plan rules** (``plan_rules``): check *resolved artifacts* with no
    devices — ring schedules for double-buffer aliasing and DMA-wait
    ordering, StreamPrograms against the VMEM budget, partition plans for
    ladder dead-ends and vocabulary drift on the production meshes.
  - **Model rules** (``model_rules`` over the ``explore`` engine):
    exhaustively explore bounded state spaces — every scheduler action
    interleaving, every legal DMA landing order of the ring schedules,
    and the dtype/scale dataflow of every suite StreamProgram — so the
    checked property holds in all reachable states, not one replayed
    trace. Explorations run under an explicit ``--budget``; exhaustion
    is its own exit code (3), never a silent pass.

Drive it as ``python -m repro.analysis`` (see ``cli``); CI gates on a
clean run, and tests/test_analysis.py + tests/test_explore.py prove every
rule fires on the seeded violations in tests/analysis_fixtures. Import
cost is deliberate: this ``__init__`` pulls only the stdlib-based
registry; the plan and model tiers import jax lazily inside each rule
(the scheduler model checker needs no jax at all).
"""
from repro.analysis.base import (  # noqa: F401
    Context,
    Finding,
    Rule,
    default_root,
    register_rule,
    registered_rules,
    run_rules,
)
