"""Fault tolerance & straggler mitigation (paper C7).

Occamy's D2D link calibrates once, disables faulty PHYs, and reshuffles
traffic over the survivors with linear degradation. The framework analogue:

- StragglerMonitor: per-step wall-clock EWMA; a step exceeding k x the EWMA
  flags a straggle event. At scale each host reports its own timing on the
  control plane (kept OUT of the hot loop, like the narrow 64-bit network).
- elastic_remesh: rebuild a smaller/larger mesh after failures (shrink the
  `data` axis — drop the bad "lanes") and re-shard the training state onto
  it from host memory or the last checkpoint. Batch is re-sharded too;
  throughput degrades linearly with lost data-parallel rank, exactly the
  channel-allocator contract.
- FailureInjector: deterministic fault schedule for tests/examples.
"""
from __future__ import annotations

import dataclasses

import jax
import numpy as np


@dataclasses.dataclass
class StragglerMonitor:
    threshold: float = 2.5  # x EWMA counts as a straggle
    alpha: float = 0.1
    ewma: float | None = None
    events: int = 0
    steps: int = 0

    def observe(self, step_seconds: float) -> bool:
        self.steps += 1
        if self.ewma is None:
            self.ewma = step_seconds
            return False
        straggled = step_seconds > self.threshold * self.ewma
        if straggled:
            self.events += 1
        else:  # do not pollute the EWMA with outliers
            self.ewma = (1 - self.alpha) * self.ewma + self.alpha * step_seconds
        return straggled

    @property
    def should_exclude(self) -> bool:
        """A host persistently straggling gets excluded at the next elastic
        boundary (3 events within any 100-step window)."""
        return self.events >= 3


class FailureInjector:
    """Deterministic failure schedule: {step: kind}; kinds: 'crash' (the loop
    must restart from checkpoint), 'straggle' (sleep multiplier)."""

    def __init__(self, schedule: dict[int, str] | None = None):
        self.schedule = schedule or {}
        self.triggered: list[tuple[int, str]] = []

    def check(self, step: int) -> str | None:
        kind = self.schedule.get(step)
        if kind:
            self.triggered.append((step, kind))
        return kind


def elastic_remesh(data_parallel: int, model_parallel: int, lost_ranks: int = 0):
    """Rebuild the mesh with `lost_ranks` fewer data-parallel rows using
    whatever devices remain. Returns (mesh, new_data_parallel)."""
    new_dp = data_parallel - lost_ranks
    assert new_dp >= 1, "cannot shrink below one data-parallel rank"
    devices = np.asarray(jax.devices()[: new_dp * model_parallel])
    mesh = jax.sharding.Mesh(
        devices.reshape(new_dp, model_parallel), ("data", "model")
    )
    return mesh, new_dp


def reshard_state(state, cfg, mesh, mode="train"):
    """Re-device_put a state pytree onto a (new) mesh (elastic restart)."""
    from repro.parallel import sharding as sh

    pspecs = sh.param_specs(cfg, state["params"], mesh, mode)
    specs = {
        "params": pspecs,
        "opt": {"m": pspecs, "v": pspecs,
                "step": jax.sharding.PartitionSpec()},
    }
    shardings = sh.named(mesh, specs)
    return jax.tree.map(
        lambda x, s: jax.device_put(np.asarray(jax.device_get(x)), s),
        state, shardings,
    )
