"""Train/serve step builders and the fault-tolerant host training loop."""
from __future__ import annotations

import time

import jax
import jax.numpy as jnp

from repro.models import registry
from repro.optim import adamw, compression


def init_train_state(cfg, rng):
    params = registry.init_params(cfg, rng)
    return {
        "params": params,
        "opt": adamw.init_state(params, jnp.dtype(cfg.optimizer_dtype)),
    }


def train_state_struct(cfg):
    """ShapeDtypeStructs for the train state (dry-run: no allocation)."""
    params = registry.param_shapes(cfg)
    opt_dt = jnp.dtype(cfg.optimizer_dtype)
    def like(p):
        return jax.ShapeDtypeStruct(p.shape, opt_dt)

    return {
        "params": params,
        "opt": {
            "m": jax.tree.map(like, params),
            "v": jax.tree.map(like, params),
            "step": jax.ShapeDtypeStruct((), jnp.int32),
        },
    }


def make_train_step(cfg, microbatches: int | None = None,
                    grad_compression: bool = False):
    microbatches = microbatches if microbatches is not None else cfg.microbatches
    """fwd+bwd+AdamW. microbatches>1 = gradient accumulation over batch tiles
    (C4 double-buffering at the batch edge; shrinks activation temps N-fold).
    grad_compression = bf16 gradient round-trip with fp32 error feedback
    before the data/pod-axis reduction (halves D2D bytes, C7)."""

    def loss_and_grads(params, batch):
        return jax.value_and_grad(
            lambda p: registry.loss_fn(p, cfg, batch)
        )(params)

    if microbatches > 1:
        from repro.core.pipeline import microbatched

        loss_and_grads = microbatched(loss_and_grads, microbatches)

    def train_step(state, batch):
        loss, grads = loss_and_grads(state["params"], batch)
        if grad_compression:
            grads, err = compression.compress_decompress(
                grads, state["grad_err"]
            )
        params, opt, metrics = adamw.apply_updates(
            cfg, state["params"], grads, state["opt"]
        )
        new_state = {"params": params, "opt": opt}
        if grad_compression:
            new_state["grad_err"] = err
        return new_state, {"loss": loss, **metrics}

    return train_step


def make_prefill_step(cfg):
    def prefill_step(params, batch):
        logits, _ = registry.forward(params, cfg, batch)
        return logits

    return prefill_step


def make_decode_step(cfg):
    def decode_step(params, cache, batch):
        return registry.decode_step(params, cfg, cache, batch)

    return decode_step


# ---------------------------------------------------------------------------
# fault-tolerant host loop
# ---------------------------------------------------------------------------


def run_training(
    cfg,
    shape,
    mesh=None,
    *,
    num_steps: int = 100,
    seed: int = 0,
    ckpt_dir: str | None = None,
    ckpt_every: int = 50,
    batch_override: int | None = None,
    seq_override: int | None = None,
    microbatches: int = 1,
    grad_compression: bool = False,
    failure_injector=None,
    log_every: int = 10,
    log_fn=print,
):
    """Full training driver: data prefetch, jitted step, straggler monitor,
    checkpoint/restart (resumes both the step count AND the data stream)."""
    from repro.data.synthetic import DataIterator
    from repro.parallel import sharding as sh
    from repro.runtime import checkpoint as ckpt
    from repro.runtime.fault_tolerance import StragglerMonitor

    state = init_train_state(cfg, jax.random.PRNGKey(seed))
    if grad_compression:
        state["grad_err"] = compression.init_error_state(state["params"])
    start_step = 0
    if ckpt_dir:
        last = ckpt.latest_step(ckpt_dir)
        if last is not None:
            state = ckpt.restore(ckpt_dir, last, state)
            start_step = last
            log_fn(f"[restore] resumed from step {last}")

    ctx = None
    step_fn = make_train_step(cfg, microbatches, grad_compression)
    if mesh is not None:
        pspecs = sh.param_specs(cfg, state["params"], mesh, "train")
        from jax.sharding import PartitionSpec as P

        state_specs = {"params": pspecs,
                       "opt": {"m": pspecs, "v": pspecs, "step": P()}}
        if grad_compression:
            state_specs["grad_err"] = pspecs
        sspec = sh.named(mesh, state_specs)
        state = jax.tree.map(
            lambda x, s: jax.device_put(x, s), state, sspec
        )
        act = sh.default_activation_specs(cfg, mesh, "train")
        ctx = sh.activation_sharding(act)
        jitted = jax.jit(step_fn, in_shardings=(sspec, None),
                         out_shardings=(sspec, None), donate_argnums=(0,))
    else:
        jitted = jax.jit(step_fn, donate_argnums=(0,))

    data = DataIterator(cfg, shape, seed=seed, start_step=start_step,
                        batch_override=batch_override,
                        seq_override=seq_override)
    monitor = StragglerMonitor()
    losses = []
    try:
        if ctx is not None:
            ctx.__enter__()
        for _ in range(num_steps - start_step):
            step, batch = next(data)
            if failure_injector is not None:
                kind = failure_injector.check(step)
                if kind == "crash":
                    raise RuntimeError(f"injected crash at step {step}")
                if kind == "straggle":
                    time.sleep(0.2)
            t0 = time.time()
            state, metrics = jitted(state, batch)
            loss = float(metrics["loss"])
            dt = time.time() - t0
            straggled = monitor.observe(dt)
            losses.append(loss)
            if step % log_every == 0:
                log_fn(
                    f"step {step:5d} loss {loss:8.4f} "
                    f"gnorm {float(metrics['grad_norm']):7.3f} "
                    f"{dt*1e3:7.1f} ms{' [straggle]' if straggled else ''}"
                )
            if ckpt_dir and (step + 1) % ckpt_every == 0:
                ckpt.save(ckpt_dir, step + 1, state)
                log_fn(f"[ckpt] step {step + 1}")
    finally:
        if ctx is not None:
            ctx.__exit__(None, None, None)
        data.close()
    return state, losses, monitor
