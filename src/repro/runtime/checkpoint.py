"""Sharded checkpoint save/restore (paper C7: fault tolerance substrate).

Layout: <dir>/step_<N>/
  manifest.json     — step, mesh shape/axes, flattened tree structure, specs
  arrays.npz        — one entry per leaf (host-gathered)

Design points for 1000+ nodes (single-host container runs the same code):
- save is ATOMIC: written to a temp dir, fsync'd, then renamed — a crash
  mid-save never corrupts the latest checkpoint.
- restore is MESH-AGNOSTIC: leaves are re-device_put with the *target* mesh's
  shardings, so a job can restart on a smaller/larger data axis (elastic
  re-mesh after node failure, the D2D channel-allocator analogue).
- on multi-host, each host would write only its addressable shards
  (`jax.experimental.multihost_utils`); the manifest format already carries
  the spec strings needed for that extension.
"""
from __future__ import annotations

import json
import os
import shutil
import threading

import jax
import numpy as np


def _flatten(tree):
    leaves, treedef = jax.tree_util.tree_flatten_with_path(tree)
    keyed = {}
    for path, leaf in leaves:
        key = "/".join(str(getattr(p, "key", getattr(p, "idx", p))) for p in path)
        keyed[key] = leaf
    return keyed, treedef


def save(ckpt_dir: str, step: int, state, extra: dict | None = None) -> str:
    keyed, _ = _flatten(state)
    tmp = os.path.join(ckpt_dir, f".tmp_step_{step}")
    final = os.path.join(ckpt_dir, f"step_{step}")
    os.makedirs(tmp, exist_ok=True)
    arrays = {k: np.asarray(jax.device_get(v)) for k, v in keyed.items()}
    np.savez(os.path.join(tmp, "arrays.npz"), **arrays)
    manifest = {
        "step": step,
        "keys": sorted(arrays.keys()),
        "dtypes": {k: str(v.dtype) for k, v in arrays.items()},
        "shapes": {k: list(v.shape) for k, v in arrays.items()},
        "extra": extra or {},
    }
    with open(os.path.join(tmp, "manifest.json"), "w") as f:
        json.dump(manifest, f)
        f.flush()
        os.fsync(f.fileno())
    if os.path.exists(final):
        shutil.rmtree(final)
    os.rename(tmp, final)  # atomic publish
    return final


def save_async(ckpt_dir: str, step: int, state, extra=None) -> threading.Thread:
    """Device->host copy happens on the caller; IO in a side thread so the
    step loop is not blocked (paper C4: overlap bulk movement with compute)."""
    keyed, _ = _flatten(state)
    host = {k: np.asarray(jax.device_get(v)) for k, v in keyed.items()}

    def _write():
        tmp = os.path.join(ckpt_dir, f".tmp_step_{step}")
        final = os.path.join(ckpt_dir, f"step_{step}")
        os.makedirs(tmp, exist_ok=True)
        np.savez(os.path.join(tmp, "arrays.npz"), **host)
        with open(os.path.join(tmp, "manifest.json"), "w") as f:
            json.dump({"step": step, "keys": sorted(host), "extra": extra or {}}, f)
        if os.path.exists(final):
            shutil.rmtree(final)
        os.rename(tmp, final)

    t = threading.Thread(target=_write, daemon=True)
    t.start()
    return t


def latest_step(ckpt_dir: str) -> int | None:
    if not os.path.isdir(ckpt_dir):
        return None
    steps = [
        int(d.split("_")[1])
        for d in os.listdir(ckpt_dir)
        if d.startswith("step_")
    ]
    return max(steps) if steps else None


def restore(ckpt_dir: str, step: int, state_like, shardings=None):
    """Restore into the structure of `state_like`; device_put with the given
    shardings (possibly for a DIFFERENT mesh than the checkpoint's)."""
    path = os.path.join(ckpt_dir, f"step_{step}")
    with np.load(os.path.join(path, "arrays.npz")) as data:
        keyed_like, treedef = _flatten(state_like)
        leaves = []
        shard_keyed, _ = _flatten(shardings) if shardings is not None else (None, None)
        for key, like in keyed_like.items():
            arr = data[key]
            if hasattr(like, "dtype") and str(arr.dtype) != str(like.dtype):
                arr = arr.astype(like.dtype)
            if shard_keyed is not None:
                leaves.append(jax.device_put(arr, shard_keyed[key]))
            else:
                leaves.append(jax.numpy.asarray(arr))
        # rebuild in the same keyed order as state_like's flatten
        return jax.tree_util.tree_unflatten(treedef, leaves)
