"""Deterministic synthetic data pipeline with sharded placement + prefetch.

Every batch is a pure function of (seed, step): after a crash/elastic restart
the stream resumes EXACTLY where the checkpoint left off, on any mesh shape —
data determinism is part of the fault-tolerance story, not a convenience.
Tokens follow a Zipf-like distribution so vocab-sharded embedding traffic is
realistic rather than uniform.
"""
from __future__ import annotations

import queue
import threading

import jax
import numpy as np

from repro.configs.base import ModelConfig, ShapeSpec


def batch_at_step(cfg: ModelConfig, shape: ShapeSpec, seed: int, step: int,
                  batch_override: int | None = None,
                  seq_override: int | None = None) -> dict:
    """Stateless batch generation — the (seed, step) contract."""
    rng = np.random.default_rng(np.random.SeedSequence([seed, step]))
    B = batch_override or shape.global_batch
    S = seq_override or shape.seq_len
    d = cfg.d_model
    # Zipf-ish token ids clipped to vocab
    raw = rng.zipf(1.3, size=(B, S + 1)) - 1
    toks = np.minimum(raw, cfg.vocab_size - 1).astype(np.int32)
    out = {}
    s_text = S
    if cfg.family == "vlm":
        s_text = S - cfg.num_patches
        out["patches"] = rng.standard_normal(
            (B, cfg.num_patches, d)).astype(np.float32)
    if cfg.family == "audio":
        out["frames"] = rng.standard_normal(
            (B, cfg.encoder_seq, d)).astype(np.float32)
    out["tokens"] = toks[:, :s_text]
    labels = toks[:, 1 : S + 1].copy()
    if cfg.family == "vlm":
        labels[:, : cfg.num_patches] = -1
    out["labels"] = labels
    return out


class DataIterator:
    """Host-side prefetching iterator: batch for step i+1 is generated and
    device_put while step i computes (the C4 double-buffer at the input edge).
    """

    def __init__(self, cfg, shape, seed=0, start_step=0, shardings=None,
                 prefetch=2, batch_override=None, seq_override=None,
                 cast=None):
        self.cfg, self.shape, self.seed = cfg, shape, seed
        self.shardings = shardings
        self.batch_override = batch_override
        self.seq_override = seq_override
        self.cast = cast
        self._step = start_step
        self._q: queue.Queue = queue.Queue(maxsize=prefetch)
        self._stop = threading.Event()
        self._thread = threading.Thread(target=self._producer, daemon=True)
        self._thread.start()

    def _make(self, step):
        b = batch_at_step(self.cfg, self.shape, self.seed, step,
                          self.batch_override, self.seq_override)
        if self.cast:
            b = {k: (v.astype(self.cast) if v.dtype == np.float32 else v)
                 for k, v in b.items()}
        if self.shardings is not None:
            return {
                k: jax.device_put(v, self.shardings[k]) for k, v in b.items()
            }
        return jax.tree.map(jax.numpy.asarray, b)

    def _producer(self):
        step = self._step
        while not self._stop.is_set():
            try:
                self._q.put((step, self._make(step)), timeout=0.5)
                step += 1
            except queue.Full:
                continue

    def __iter__(self):
        return self

    def __next__(self):
        step, batch = self._q.get()
        return step, batch

    def close(self):
        self._stop.set()
