"""Multi-precision policies with expanding accumulation (paper C6, Fig. 10).

Occamy's FPU scales 1x/2x/4x/8x from FP64 to FP8 with widening sum-dot-product
accumulation. TPU analogue: fp32 -> bf16 -> fp8 on the MXU, with
``preferred_element_type`` providing the expanding accumulate. FP64 has no MXU
support (DESIGN.md §6.3): fp32 is the top precision and the Fig. 10 sweep maps
to fp32/bf16/fp8.

A ``Precision`` is a *policy*: compute dtype (stream/operand width), accum
dtype (the expanding accumulator a kernel must carry at full width), flop
multiplier (MXU throughput relative to bf16), and ``scale_block`` — the
per-block scaling granularity for narrow formats. fp8's dynamic range is too
small to carry raw activations, so fp8 policies quantize per contiguous block
of ``scale_block`` elements along the contraction axis: operands travel as
(values, fp32 per-block scales) and kernels rescale inside the fp32
accumulator. bf16/fp32 set ``scale_block=0`` — unit scales, plain casts.

Policies ride ``ops.*`` signatures as ``precision=None`` keywords (next to
``impl=`` and block overrides); ``None`` is the exact legacy full-precision
path. ``resolve`` is the single name->policy seam every consumer shares.
"""
from __future__ import annotations

import dataclasses
import math

import jax
import jax.numpy as jnp

from repro.core.topology import PEAK_FLOPS_BF16


@dataclasses.dataclass(frozen=True)
class Precision:
    name: str
    compute_dtype: jnp.dtype
    accum_dtype: jnp.dtype  # the EXPanding accumulator
    flop_multiplier: float  # MXU throughput relative to bf16
    scale_block: int = 0  # per-block scale granularity; 0 = unit scales


POLICIES = {
    # paper analogue:            FP64            FP32/FP16 EXP    FP8 EXP
    "fp32": Precision("fp32", jnp.float32, jnp.float32, 0.5),
    "bf16": Precision("bf16", jnp.bfloat16, jnp.float32, 1.0),
    "fp8": Precision("fp8", jnp.float8_e4m3fn, jnp.float32, 2.0, 128),
    "fp8_e5m2": Precision(
        "fp8_e5m2", jnp.float8_e5m2, jnp.float32, 2.0, 128
    ),
}

# which policies each op's low-precision path supports — the docgen source
# for the op-reference "precisions" column. Ops absent here run fp32-only
# (their kernels never grew a scaled path).
SUPPORTED_OPS = {
    "gemm": ("fp32", "bf16", "fp8", "fp8_e5m2"),
    "flash_attention": ("fp32", "bf16", "fp8", "fp8_e5m2"),
    "decode_attention": ("fp32", "bf16", "fp8", "fp8_e5m2"),
}


def supported_policies(op: str) -> tuple[str, ...]:
    """Policy names ``op``'s kernels accept via ``precision=`` (fp32-only
    ops — no scaled path — report just ``("fp32",)``)."""
    return SUPPORTED_OPS.get(op, ("fp32",))


def resolve(policy) -> Precision | None:
    """Normalize a ``precision=`` argument: None passes through (the legacy
    full-precision path), a name looks up ``POLICIES``, a ``Precision``
    returns itself. Unknown names raise KeyError listing the known ones."""
    if policy is None or isinstance(policy, Precision):
        return policy
    try:
        return POLICIES[policy]
    except KeyError:
        raise KeyError(
            f"unknown precision policy {policy!r}; known: {sorted(POLICIES)}"
        ) from None


def peak_flops(policy: str | Precision) -> float:
    p = POLICIES[policy] if isinstance(policy, str) else policy
    return PEAK_FLOPS_BF16 * p.flop_multiplier


def quantize_blockwise(x, policy, *, axis: int = -1, block: int | None = None):
    """Quantize ``x`` to (values, scales) with one fp32 scale per contiguous
    ``block`` elements along ``axis`` (the contraction axis).

    ``block`` defaults to the policy's ``scale_block`` (whole-axis when 0).
    Policies with ``scale_block == 0`` (bf16/fp32) return unit scales — a
    plain cast — so every consumer handles narrow and wide formats through
    one code path. Scales are ``amax / finfo(compute).max`` per block
    (zero-amax blocks get scale 1.0 so dequantization is exact on zeros);
    values are ``x / scale`` cast to the compute dtype. ``scales`` has
    ``x``'s shape with ``axis`` shrunk to ``ceil(n / block)``.
    """
    p = resolve(policy)
    axis = axis % x.ndim
    n = x.shape[axis]
    if block is None:
        block = p.scale_block or n
    block = max(1, min(block, n))
    nb = math.ceil(n / block)
    xf = jnp.asarray(x, jnp.float32)
    pad = nb * block - n
    if pad:
        pad_widths = [(0, 0)] * x.ndim
        pad_widths[axis] = (0, pad)
        xpad = jnp.pad(xf, pad_widths)
    else:
        xpad = xf
    grouped = jnp.moveaxis(xpad, axis, -1).reshape(
        *[xpad.shape[d] for d in range(x.ndim) if d != axis], nb, block
    )
    if p.scale_block > 0:
        amax = jnp.max(jnp.abs(grouped), axis=-1)
        fmax = float(jnp.finfo(p.compute_dtype).max)
        scales = jnp.where(amax > 0, amax / fmax, 1.0).astype(jnp.float32)
    else:
        scales = jnp.ones(grouped.shape[:-1], jnp.float32)
    scaled = grouped / scales[..., None]
    values = jnp.moveaxis(
        scaled.reshape(*scales.shape[:-1], scales.shape[-1] * block),
        -1, axis,
    )
    if pad:
        values = jax.lax.slice_in_dim(values, 0, n, axis=axis)
    values = values.astype(p.compute_dtype)
    scales = jnp.moveaxis(scales, -1, axis)
    return values, scales


def dequantize_blockwise(values, scales, *, axis: int = -1,
                         block: int | None = None):
    """Inverse of ``quantize_blockwise``: fp32 reconstruction. Pass the
    same ``block`` quantization used; when omitted it is inferred as
    ``ceil(n / nb)`` — exact whenever the block count is 1 or divides the
    axis, ambiguous otherwise (a ragged final block), so callers that
    quantized with an explicit block must dequantize with it too."""
    axis = axis % values.ndim
    n = values.shape[axis]
    nb = scales.shape[axis]
    if block is None:
        block = math.ceil(n / nb)
    # element i reads scale block min(i // block, nb - 1)
    idx = jnp.minimum(jnp.arange(n) // block, nb - 1)
    expanded = jnp.take(scales, idx, axis=axis)
    return values.astype(jnp.float32) * expanded


def quantize_kv_cache(k, v, policy):
    """Quantize a (B, K, S, D) KV cache per row over the head dimension:
    fp8 values + fp32 (B, K, S, 1) scales — the serving-engine cache layout
    where each cached token's key/value carries one scale."""
    p = resolve(policy)
    kq, ks = quantize_blockwise(k, p, axis=-1, block=k.shape[-1])
    vq, vs = quantize_blockwise(v, p, axis=-1, block=v.shape[-1])
    return kq, ks, vq, vs


def cast_gemm_operands(a: jax.Array, b: jax.Array, policy: str | Precision):
    p = POLICIES[policy] if isinstance(policy, str) else policy
    return a.astype(p.compute_dtype), b.astype(p.compute_dtype), p


def expanding_gemm(a, b, policy: str | Precision = "bf16", impl=None):
    """GEMM at the given precision with expanding accumulation (Fig. 10)."""
    from repro.kernels import ops

    p = POLICIES[policy] if isinstance(policy, str) else policy
    return ops.gemm(
        a.astype(p.compute_dtype),
        b.astype(p.compute_dtype),
        out_dtype=p.accum_dtype,
        accum_dtype=p.accum_dtype,
        impl=impl,
    )
