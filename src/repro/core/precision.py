"""Multi-precision policies with expanding accumulation (paper C6, Fig. 10).

Occamy's FPU scales 1x/2x/4x/8x from FP64 to FP8 with widening sum-dot-product
accumulation. TPU analogue: fp32 -> bf16 -> fp8 on the MXU, with
``preferred_element_type`` providing the expanding accumulate. FP64 has no MXU
support (DESIGN.md §6.3): fp32 is the top precision and the Fig. 10 sweep maps
to fp32/bf16/fp8.
"""
from __future__ import annotations

import dataclasses

import jax
import jax.numpy as jnp

from repro.core.topology import PEAK_FLOPS_BF16


@dataclasses.dataclass(frozen=True)
class Precision:
    name: str
    compute_dtype: jnp.dtype
    accum_dtype: jnp.dtype  # the EXPanding accumulator
    flop_multiplier: float  # MXU throughput relative to bf16


POLICIES = {
    # paper analogue:            FP64            FP32/FP16 EXP    FP8 EXP
    "fp32": Precision("fp32", jnp.float32, jnp.float32, 0.5),
    "bf16": Precision("bf16", jnp.bfloat16, jnp.float32, 1.0),
    "fp8": Precision("fp8", jnp.float8_e4m3fn, jnp.float32, 2.0),
    "fp8_e5m2": Precision("fp8_e5m2", jnp.float8_e5m2, jnp.float32, 2.0),
}


def peak_flops(policy: str | Precision) -> float:
    p = POLICIES[policy] if isinstance(policy, str) else policy
    return PEAK_FLOPS_BF16 * p.flop_multiplier


def cast_gemm_operands(a: jax.Array, b: jax.Array, policy: str | Precision):
    p = POLICIES[policy] if isinstance(policy, str) else policy
    return a.astype(p.compute_dtype), b.astype(p.compute_dtype), p


def expanding_gemm(a, b, policy: str | Precision = "bf16", impl=None):
    """GEMM at the given precision with expanding accumulation (Fig. 10)."""
    from repro.kernels import ops

    p = POLICIES[policy] if isinstance(policy, str) else policy
    return ops.gemm(
        a.astype(p.compute_dtype),
        b.astype(p.compute_dtype),
        out_dtype=p.accum_dtype,
        accum_dtype=p.accum_dtype,
        impl=impl,
    )
