"""Double-buffered tiling (paper C4, Fig. 4d) at the framework level.

Inside a Pallas kernel, double buffering is automatic (two in-flight block
copies per operand — the DMA core's job). This module provides the same
discipline for *HBM-capacity-bound* computations above the kernel level:
process a large operand in tiles under a scan so peak memory stays at
O(tile), while XLA overlaps the gather of tile i+1 with compute on tile i
(latency-tolerant bulk transfer + fine-grain compute, Sec. III-B).
"""
from __future__ import annotations

from typing import Callable

import jax
import jax.numpy as jnp


def tiled_map(fn: Callable, x: jax.Array, tile: int, axis: int = 0):
    """Apply fn tile-by-tile along `axis` with O(tile) live memory."""
    n = x.shape[axis]
    assert n % tile == 0, (n, tile)
    xt = jnp.moveaxis(x, axis, 0).reshape(n // tile, tile, *(
        s for i, s in enumerate(x.shape) if i != axis
    ))
    ys = jax.lax.map(fn, xt)
    out = ys.reshape(n // tile * ys.shape[1], *ys.shape[2:])
    return jnp.moveaxis(
        out.reshape(n, *ys.shape[2:]), 0, axis
    ) if axis else out.reshape(n, *ys.shape[2:])


def tiled_gemm(a: jax.Array, b: jax.Array, tile_m: int = 1024,
               gemm_fn: Callable | None = None):
    """C = A @ B streaming A in row tiles (double-buffered against compute)."""
    from repro.kernels import ops

    gemm_fn = gemm_fn or ops.gemm
    return tiled_map(lambda at: gemm_fn(at, b), a, tile_m, axis=0)


def microbatched(step_fn: Callable, n_micro: int):
    """Gradient-accumulation wrapper: split the batch into n_micro tiles and
    scan, double-buffering batch tiles against fwd/bwd compute. Returns a
    step with identical signature operating on the full batch."""

    def wrapped(params, batch):
        def split(x):
            b = x.shape[0]
            assert b % n_micro == 0
            return x.reshape(n_micro, b // n_micro, *x.shape[1:])

        micro = jax.tree.map(split, batch)

        def body(acc, mb):
            loss, grads = step_fn(params, mb)
            return jax.tree.map(jnp.add, acc, (loss, grads)), None

        zero_loss = jnp.float32(0.0)
        zero_grads = jax.tree.map(
            lambda p: jnp.zeros(p.shape, jnp.float32), params
        )
        (loss, grads), _ = jax.lax.scan(
            body, (zero_loss, zero_grads), micro
        )
        scale = 1.0 / n_micro
        return loss * scale, jax.tree.map(lambda g: g * scale, grads)

    return wrapped
