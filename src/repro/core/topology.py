"""Hierarchy mapping: Occamy levels <-> TPU mesh axes (paper C5) + a
bandwidth model used for collective cost estimates in §Perf analysis.

Occamy:  core(3 SUs) -> cluster(8+1 cores, 128KiB SPM) -> group(4 clusters)
         -> chiplet(6 groups, HBM2E 381GiB/s) -> system(2 chiplets, D2D 8GiB/s)
TPU pod: MXU/VPU -> chip(VMEM ~128MiB, HBM 819GB/s) -> ICI axis `model`
         -> ICI axis `data` -> inter-pod `pod` (DCN/optical)

Both hierarchies share the property the paper calls *symmetry*: constant
architectural bandwidth per level, so code written level-agnostically (pjit
specs here, cluster-agnostic C there) performs predictably.
"""
from __future__ import annotations

import dataclasses

# task-spec hardware constants (TPU v5e class)
PEAK_FLOPS_BF16 = 197e12
HBM_BW = 819e9
ICI_LINK_BW = 50e9  # per link
POD_LINK_BW = 25e9  # inter-pod (D2D analogue): half ICI


@dataclasses.dataclass(frozen=True)
class Level:
    name: str
    occamy_analogue: str
    fanout: int
    bw: float  # bytes/s available to one participant at this level


def levels(multi_pod: bool = False):
    lv = [
        Level("chip", "cluster (SPM+DMA)", 1, HBM_BW),
        Level("model", "chiplet crossbar", 16, ICI_LINK_BW),
        Level("data", "group interconnect", 16, ICI_LINK_BW),
    ]
    if multi_pod:
        lv.append(Level("pod", "D2D link", 2, POD_LINK_BW))
    return lv


def axis_bw(axis: str) -> float:
    return POD_LINK_BW if axis == "pod" else ICI_LINK_BW


def collective_seconds(kind: str, nbytes: float, axis: str, n: int) -> float:
    """Ring-algorithm time for `nbytes` (per-device buffer) over axis size n."""
    bw = axis_bw(axis)
    if n <= 1:
        return 0.0
    frac = (n - 1) / n
    if kind == "all_reduce":
        return 2 * frac * nbytes / bw
    if kind in ("all_gather", "reduce_scatter", "all_to_all"):
        return frac * nbytes / bw
    if kind == "permute":
        return nbytes / bw
    raise ValueError(kind)


def dp_allreduce_seconds(param_bytes_per_device: float, mesh_axes: dict) -> float:
    """Gradient all-reduce cost across the data (and pod) axes — the step's
    D2D-link analogue term."""
    t = collective_seconds(
        "all_reduce", param_bytes_per_device, "data", mesh_axes.get("data", 1)
    )
    if mesh_axes.get("pod", 1) > 1:
        t += collective_seconds(
            "all_reduce", param_bytes_per_device, "pod", mesh_axes["pod"]
        )
    return t
