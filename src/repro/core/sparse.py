"""Sparse tensor formats (paper Sec. II-A: value/index-pair major axes).

The SUs accept "any sparse tensor format whose major axis is given by a
value-index array pair". We provide the two TPU-idiomatic members:

- **ELL** (padded value/index rows): the direct value-index pair, used by the
  spmm/spmspm XLA paths, GCN, and the intersection kernel. Padding entries
  carry value 0 (they contribute nothing) and index 0.
- **BSR** (block-sparse rows): the MXU adaptation — unstructured sparsity is
  exploited at (bm x bk)-tile granularity, with scalar-prefetched tile
  coordinates playing the role of the SU index stream (DESIGN.md §6.2).
"""
from __future__ import annotations

import dataclasses

import numpy as np


@dataclasses.dataclass
class EllMatrix:
    """Padded ELL rows: values/cols (R, L); logical shape (R, C)."""

    values: np.ndarray
    cols: np.ndarray
    shape: tuple[int, int]

    @property
    def nnz(self) -> int:
        return int((self.values != 0).sum())

    def todense(self) -> np.ndarray:
        R, C = self.shape
        out = np.zeros((R, C), self.values.dtype)
        np.add.at(out, (np.arange(R)[:, None], self.cols), self.values)
        return out


def dense_to_ell(dense: np.ndarray, max_nnz: int | None = None) -> EllMatrix:
    R, C = dense.shape
    L = max_nnz or max(int((dense != 0).sum(1).max()), 1)
    values = np.zeros((R, L), dense.dtype)
    cols = np.zeros((R, L), np.int32)
    for r in range(R):
        (nz,) = np.nonzero(dense[r])
        nz = nz[:L]
        values[r, : len(nz)] = dense[r, nz]
        cols[r, : len(nz)] = nz
    return EllMatrix(values, cols, (R, C))


def random_ell(
    rng: np.random.Generator, R: int, C: int, density: float, dtype=np.float32
) -> EllMatrix:
    """Unstructured random sparse matrix (paper Fig. 9c/d operands)."""
    L = max(int(round(C * density)), 1)
    cols = np.sort(
        np.argsort(rng.random((R, C)), axis=1)[:, :L].astype(np.int32), axis=1
    )
    values = rng.standard_normal((R, L)).astype(dtype)
    return EllMatrix(values, cols, (R, C))


@dataclasses.dataclass
class BsrMatrix:
    """Block-sparse rows: tiles sorted by (row, col) coordinate.

    Every row-block owns >= 1 tile (empty row-blocks get a zero tile) so the
    spmm kernel's output blocks are always initialized.
    """

    tile_values: np.ndarray  # (T, bm, bk)
    tile_rows: np.ndarray  # (T,) int32, block-row index, sorted
    tile_cols: np.ndarray  # (T,) int32, block-col index
    shape: tuple[int, int]

    @property
    def block_shape(self) -> tuple[int, int]:
        return self.tile_values.shape[1], self.tile_values.shape[2]

    @property
    def density(self) -> float:
        bm, bk = self.block_shape
        total = (self.shape[0] // bm) * (self.shape[1] // bk)
        return len(self.tile_rows) / max(total, 1)

    def todense(self) -> np.ndarray:
        bm, bk = self.block_shape
        out = np.zeros(self.shape, self.tile_values.dtype)
        for t in range(len(self.tile_rows)):
            r, c = self.tile_rows[t] * bm, self.tile_cols[t] * bk
            out[r : r + bm, c : c + bk] += self.tile_values[t]
        return out


def dense_to_bsr(dense: np.ndarray, bm: int = 8, bk: int = 128) -> BsrMatrix:
    R, C = dense.shape
    assert R % bm == 0 and C % bk == 0, (R, C, bm, bk)
    nr, nc = R // bm, C // bk
    tiles, rows, cols = [], [], []
    blocked = dense.reshape(nr, bm, nc, bk).transpose(0, 2, 1, 3)
    for i in range(nr):
        found = False
        for j in range(nc):
            tile = blocked[i, j]
            if np.any(tile != 0):
                tiles.append(tile)
                rows.append(i)
                cols.append(j)
                found = True
        if not found:  # keep output blocks initialized
            tiles.append(np.zeros((bm, bk), dense.dtype))
            rows.append(i)
            cols.append(0)
    return BsrMatrix(
        np.stack(tiles),
        np.asarray(rows, np.int32),
        np.asarray(cols, np.int32),
        (R, C),
    )
