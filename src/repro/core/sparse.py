"""Sparse tensor formats (paper Sec. II-A: value/index-pair major axes).

The SUs accept "any sparse tensor format whose major axis is given by a
value-index array pair". We provide three members, all registered as JAX
pytrees (array leaves + static shape aux data) so sparse operands pass whole
through ``jax.jit`` / ``jax.vmap`` boundaries without densifying:

- **ELL** (padded value/index rows): the direct value-index pair, used by the
  spmm/spmspm paths, GCN, and the intersection kernel. Padding entries carry
  value 0 (they contribute nothing) and index 0.
- **BSR** (block-sparse rows): the MXU adaptation — unstructured sparsity is
  exploited at (bm x bk)-tile granularity, with scalar-prefetched tile
  coordinates playing the role of the SU index stream (DESIGN.md §6.2).
- **CSR** (compressed rows): the interchange format; ``ell_to_csr`` /
  ``csr_to_ell`` / ``csr_to_bsr`` / ``bsr_to_csr`` form the conversion path
  between the compute formats.

Converters are vectorized (no Python per-row/per-tile loops). Construction is
host-side — the nnz structure decides output shapes — but ``todense`` and all
format members are jnp-native and trace cleanly.
"""
from __future__ import annotations

import dataclasses

import jax
import jax.numpy as jnp
import numpy as np


@jax.tree_util.register_pytree_node_class
@dataclasses.dataclass
class EllMatrix:
    """Padded ELL rows: values/cols (R, L); logical shape (R, C)."""

    values: jax.Array
    cols: jax.Array
    shape: tuple[int, int]

    def tree_flatten(self):
        return (self.values, self.cols), (self.shape,)

    @classmethod
    def tree_unflatten(cls, aux, children):
        return cls(children[0], children[1], aux[0])

    @property
    def nnz(self) -> int:
        return int((np.asarray(self.values) != 0).sum())

    def todense(self) -> jax.Array:
        R, C = self.shape
        rows = jnp.arange(R)[:, None]
        out = jnp.zeros((R, C), self.values.dtype)
        # padding slots carry value 0, so aliased (row, 0) scatters add nothing
        return out.at[rows, self.cols].add(self.values)


def dense_to_ell(dense, max_nnz: int | None = None) -> EllMatrix:
    dense = jnp.asarray(dense)
    R, C = dense.shape
    mask = dense != 0
    row_nnz = np.asarray(mask.sum(axis=1))
    if max_nnz is not None and row_nnz.max(initial=0) > max_nnz:
        offender = int(row_nnz.argmax())
        raise ValueError(
            f"dense_to_ell: row {offender} has {int(row_nnz[offender])} "
            f"nonzeros > max_nnz={max_nnz}; widen max_nnz or pre-prune"
        )
    L = max_nnz or max(int(row_nnz.max(initial=0)), 1)
    # stable sort moves nonzero slots to the front, preserving column order
    order = jnp.argsort(~mask, axis=1, stable=True)[:, : min(L, C)]
    order = order.astype(jnp.int32)
    keep = jnp.take_along_axis(mask, order, axis=1)
    values = jnp.where(keep, jnp.take_along_axis(dense, order, axis=1), 0)
    cols = jnp.where(keep, order, 0)
    if L > C:  # honor a requested slot width wider than the matrix
        values = jnp.pad(values, ((0, 0), (0, L - C)))
        cols = jnp.pad(cols, ((0, 0), (0, L - C)))
    return EllMatrix(values, cols, (R, C))


def random_ell(
    rng: np.random.Generator, R: int, C: int, density: float, dtype=np.float32
) -> EllMatrix:
    """Unstructured random sparse matrix (paper Fig. 9c/d operands)."""
    L = max(int(round(C * density)), 1)
    # row-wise sample-without-replacement: argpartition of uniform keys (O(RC),
    # vs the full-sort O(RC log C)) then sort only the kept L columns
    keys = rng.random((R, C))
    cols = np.sort(
        np.argpartition(keys, L - 1, axis=1)[:, :L].astype(np.int32), axis=1
    )
    values = rng.standard_normal((R, L)).astype(dtype)
    return EllMatrix(jnp.asarray(values), jnp.asarray(cols), (R, C))


@jax.tree_util.register_pytree_node_class
@dataclasses.dataclass
class BsrMatrix:
    """Block-sparse rows: tiles sorted by (row, col) coordinate.

    Every row-block owns >= 1 tile (empty row-blocks get a zero tile) so the
    spmm kernel's output blocks are always initialized.
    """

    tile_values: jax.Array  # (T, bm, bk)
    tile_rows: jax.Array  # (T,) int32, block-row index, sorted
    tile_cols: jax.Array  # (T,) int32, block-col index
    shape: tuple[int, int]

    def tree_flatten(self):
        return (self.tile_values, self.tile_rows, self.tile_cols), (self.shape,)

    @classmethod
    def tree_unflatten(cls, aux, children):
        return cls(*children, aux[0])

    @property
    def block_shape(self) -> tuple[int, int]:
        return self.tile_values.shape[1], self.tile_values.shape[2]

    @property
    def density(self) -> float:
        bm, bk = self.block_shape
        total = (self.shape[0] // bm) * (self.shape[1] // bk)
        return len(self.tile_rows) / max(total, 1)

    def todense(self) -> jax.Array:
        bm, bk = self.block_shape
        R, C = self.shape
        nr, nc = R // bm, C // bk
        blocked = jnp.zeros((nr, nc, bm, bk), self.tile_values.dtype)
        blocked = blocked.at[self.tile_rows, self.tile_cols].add(self.tile_values)
        return blocked.transpose(0, 2, 1, 3).reshape(R, C)


def dense_to_bsr(dense, bm: int = 8, bk: int = 128) -> BsrMatrix:
    dense = np.asarray(dense)
    R, C = dense.shape
    assert R % bm == 0 and C % bk == 0, (R, C, bm, bk)
    nr, nc = R // bm, C // bk
    blocked = dense.reshape(nr, bm, nc, bk).transpose(0, 2, 1, 3)
    nz = np.any(blocked != 0, axis=(2, 3))  # (nr, nc)
    nz[~nz.any(axis=1), 0] = True  # keep every output row-block initialized
    rows, cols = np.nonzero(nz)  # row-major => sorted by (row, col)
    return BsrMatrix(
        jnp.asarray(blocked[rows, cols]),
        jnp.asarray(rows.astype(np.int32)),
        jnp.asarray(cols.astype(np.int32)),
        (R, C),
    )


@jax.tree_util.register_pytree_node_class
@dataclasses.dataclass
class CsrMatrix:
    """Compressed sparse rows: data/indices (nnz,), indptr (R+1,)."""

    data: jax.Array
    indices: jax.Array  # int32 column ids
    indptr: jax.Array  # int32 row pointers
    shape: tuple[int, int]

    def tree_flatten(self):
        return (self.data, self.indices, self.indptr), (self.shape,)

    @classmethod
    def tree_unflatten(cls, aux, children):
        return cls(*children, aux[0])

    @property
    def nnz(self) -> int:
        return int(self.data.shape[0])

    def todense(self) -> jax.Array:
        R, C = self.shape
        nnz = self.data.shape[0]
        rows = (
            jnp.searchsorted(self.indptr, jnp.arange(nnz), side="right") - 1
        )
        out = jnp.zeros((R, C), self.data.dtype)
        return out.at[rows, self.indices].add(self.data)


def dense_to_csr(dense) -> CsrMatrix:
    dense = np.asarray(dense)
    R, C = dense.shape
    rows, cols = np.nonzero(dense)
    indptr = np.zeros(R + 1, np.int32)
    indptr[1:] = np.cumsum(np.bincount(rows, minlength=R))
    return CsrMatrix(
        jnp.asarray(dense[rows, cols]),
        jnp.asarray(cols.astype(np.int32)),
        jnp.asarray(indptr),
        (R, C),
    )


# ---------------------------------------------------------------------------
# Conversion path: CSR <-> ELL <-> BSR
# ---------------------------------------------------------------------------


def ell_to_csr(A: EllMatrix) -> CsrMatrix:
    vals = np.asarray(A.values)
    cols = np.asarray(A.cols)
    mask = vals != 0  # padding slots carry value 0
    rows, slots = np.nonzero(mask)  # row-major: real entries in column order
    R = A.shape[0]
    indptr = np.zeros(R + 1, np.int32)
    indptr[1:] = np.cumsum(mask.sum(axis=1))
    return CsrMatrix(
        jnp.asarray(vals[rows, slots]),
        jnp.asarray(cols[rows, slots].astype(np.int32)),
        jnp.asarray(indptr),
        A.shape,
    )


def csr_to_ell(A: CsrMatrix, max_nnz: int | None = None) -> EllMatrix:
    data = np.asarray(A.data)
    indices = np.asarray(A.indices)
    indptr = np.asarray(A.indptr)
    R = A.shape[0]
    counts = np.diff(indptr)
    if max_nnz is not None and counts.max(initial=0) > max_nnz:
        offender = int(counts.argmax())
        raise ValueError(
            f"csr_to_ell: row {offender} has {int(counts[offender])} "
            f"nonzeros > max_nnz={max_nnz}; widen max_nnz or pre-prune"
        )
    L = max_nnz or max(int(counts.max(initial=0)), 1)
    rows = np.repeat(np.arange(R), counts)
    slots = np.arange(len(data)) - indptr[rows]  # position within each row
    values = np.zeros((R, L), data.dtype)
    cols = np.zeros((R, L), np.int32)
    values[rows, slots] = data
    cols[rows, slots] = indices
    return EllMatrix(jnp.asarray(values), jnp.asarray(cols), A.shape)


def csr_to_bsr(A: CsrMatrix, bm: int = 8, bk: int = 128) -> BsrMatrix:
    """O(nnz) tile build: scatter entries into their (block-row, block-col)
    tiles without materializing the dense matrix."""
    data = np.asarray(A.data)
    indices = np.asarray(A.indices)
    indptr = np.asarray(A.indptr)
    R, C = A.shape
    assert R % bm == 0 and C % bk == 0, (R, C, bm, bk)
    nr, nc = R // bm, C // bk
    rows = np.repeat(np.arange(R), np.diff(indptr))
    keys = (rows // bm).astype(np.int64) * nc + indices // bk
    # every row-block owns >= 1 tile: add an empty (r, 0) tile where absent
    present = np.zeros(nr, bool)
    present[rows // bm] = True
    empty_keys = np.flatnonzero(~present).astype(np.int64) * nc
    uniq, inv = np.unique(np.concatenate([keys, empty_keys]), return_inverse=True)
    tiles = np.zeros((len(uniq), bm, bk), data.dtype)
    np.add.at(tiles, (inv[: len(keys)], rows % bm, indices % bk), data)
    return BsrMatrix(
        jnp.asarray(tiles),
        jnp.asarray((uniq // nc).astype(np.int32)),
        jnp.asarray((uniq % nc).astype(np.int32)),
        (R, C),
    )


def bsr_to_csr(A: BsrMatrix) -> CsrMatrix:
    """O(tile storage): enumerate nonzero tile entries, never densify."""
    tv = np.asarray(A.tile_values)
    tr = np.asarray(A.tile_rows)
    tc = np.asarray(A.tile_cols)
    T, bm, bk = tv.shape
    R, C = A.shape
    t_idx, r_off, c_off = np.nonzero(tv)
    rows = tr[t_idx] * bm + r_off
    cols = tc[t_idx] * bk + c_off
    order = np.lexsort((cols, rows))  # CSR wants row-major, cols ascending
    rows, cols = rows[order], cols[order]
    indptr = np.zeros(R + 1, np.int32)
    indptr[1:] = np.cumsum(np.bincount(rows, minlength=R))
    return CsrMatrix(
        jnp.asarray(tv[t_idx, r_off, c_off][order]),
        jnp.asarray(cols.astype(np.int32)),
        jnp.asarray(indptr),
        (R, C),
    )


def ell_to_bsr(A: EllMatrix, bm: int = 8, bk: int = 128) -> BsrMatrix:
    return csr_to_bsr(ell_to_csr(A), bm=bm, bk=bk)


def bsr_to_ell(A: BsrMatrix, max_nnz: int | None = None) -> EllMatrix:
    return csr_to_ell(bsr_to_csr(A), max_nnz=max_nnz)
