"""Streaming-unit programming model (paper C1/C2) as the kernel substrate.

Occamy's SUs map *streams* — ≤4D affine address sequences or index-driven
indirect sequences — onto FP register reads/writes, so the issue slots carry
only compute. The TPU translation: a stream is a (block_shape, index_map)
pair; the Pallas grid pipeline performs the address generation and the
double-buffered HBM->VMEM copies, and the kernel body carries only compute.

This module makes that correspondence explicit and first-class:

  AffineStream(block, loop)    ~ SU 4D affine stream descriptor (Fig. 4a)
  IndirectStream(block, idx)   ~ SU indirect stream (Fig. 4b): a scalar-
                                 prefetched index array drives the index_map
  StreamProgram(...)           ~ a full SU configuration: grid (the FREP loop
                                 nest), bound streams, and the compute body
  stream_compute(program, ...) ~ FREP + SU setup: executes the program with
                                 operands bound to its streams

Every production kernel (kernels/*.py) builds a StreamProgram and executes it
here — this is the only module that calls ``pl.pallas_call``, so backend
concerns (compiler params, scalar prefetch plumbing, interpret mode) live in
exactly one place.
"""
from __future__ import annotations

import dataclasses
import math
from typing import Any, Callable

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl
from jax.experimental.pallas import tpu as pltpu

# jax renamed TPUCompilerParams -> CompilerParams across releases; resolve the
# one this jax ships so kernels never touch the name directly.
_CompilerParams = getattr(pltpu, "CompilerParams", None) or getattr(
    pltpu, "TPUCompilerParams"
)


def _dtype_bytes(dtype) -> int:
    # dtype is cost metadata only; streams without it are counted at the
    # 4-byte float32 default (see StreamProgram.traffic_bytes)
    return jnp.dtype(dtype or jnp.float32).itemsize


@dataclasses.dataclass(frozen=True)
class AffineStream:
    """≤4D affine stream: block_shape + an index_map over the grid ids.

    ``dtype`` is cost metadata (the element width the stream carries); it lets
    a StreamProgram report per-step traffic without running the kernel.
    """

    block_shape: tuple
    index_map: Callable  # (*grid_ids) -> block coords
    dtype: Any = None

    @property
    def block_elems(self) -> int:
        return math.prod(self.block_shape)

    @property
    def bytes_per_step(self) -> int:
        """HBM<->VMEM bytes one grid step of this stream moves."""
        return self.block_elems * _dtype_bytes(self.dtype)

    def spec(self, n_prefetch: int = 0) -> pl.BlockSpec:
        if n_prefetch == 0:
            return pl.BlockSpec(self.block_shape, self.index_map)
        # scalar-prefetch grids pass the prefetch refs after the grid ids;
        # an affine map never reads them, so truncate.
        fn = self.index_map
        return pl.BlockSpec(
            self.block_shape, lambda *a: fn(*a[: len(a) - n_prefetch])
        )


@dataclasses.dataclass(frozen=True)
class IndirectStream:
    """Index-driven stream: ``index_map`` may read the scalar-prefetched index
    arrays (passed as trailing args), Occamy's 8/16/32-bit index streams."""

    block_shape: tuple
    index_map: Callable  # (*grid_ids, *prefetch_refs) -> block coords
    dtype: Any = None

    @property
    def block_elems(self) -> int:
        return math.prod(self.block_shape)

    @property
    def bytes_per_step(self) -> int:
        return self.block_elems * _dtype_bytes(self.dtype)

    def spec(self, n_prefetch: int = 0) -> pl.BlockSpec:
        return pl.BlockSpec(self.block_shape, self.index_map)


Stream = AffineStream | IndirectStream


@dataclasses.dataclass(frozen=True)
class StreamProgram:
    """A complete SU configuration: the FREP loop nest (grid), the streams
    feeding/draining the body, and the body itself.

    ``index_args`` are scalar-prefetched (SMEM-resident) index arrays,
    available to every IndirectStream's index_map and to the body as leading
    refs. ``dimension_semantics`` annotates each grid axis as "parallel" or
    "arbitrary" (sequential) for the TPU pipeliner.
    """

    name: str
    body: Callable
    grid: tuple
    in_streams: tuple[Stream, ...]
    out_streams: tuple[Stream, ...]
    out_shapes: tuple[jax.ShapeDtypeStruct, ...]
    index_args: tuple = ()
    scratch: tuple = ()
    dimension_semantics: tuple | None = None

    @property
    def steps(self) -> int:
        """Grid steps — the SU's total stream-advance count."""
        return math.prod(self.grid)

    def traffic_bytes(self) -> int:
        """Upper-bound HBM traffic: every stream refetches per grid step.

        The Pallas pipeliner elides refetches when an index_map repeats a
        block across consecutive steps, so this is the no-reuse bound — the
        numerator of the paper's per-kernel operational-intensity figures.
        Streams built without a dtype are counted at 4 bytes/element; pass
        dtypes on every stream for exact figures.
        """
        per_step = sum(
            s.bytes_per_step for s in (*self.in_streams, *self.out_streams)
        )
        return per_step * self.steps

    def validate(self, *, strict: bool = False) -> list[str]:
        """Structural invariants of the program, as a list of problem strings
        (empty when well-formed) — the resolve-time check ``repro.analysis``
        runs over every registered kernel's program builder.

        Always checked: the grid is a non-empty tuple of positive ints,
        every stream's block_shape is all-positive, and ``out_shapes``
        pairs one shape per out stream. With ``strict`` the index_map
        arity is also checked: an AffineStream's map must accept exactly
        one argument per grid axis (an IndirectStream's at least that many
        — it may also read the scalar-prefetch refs). Returns problems
        instead of raising so the analyzer can report every violation of a
        seeded-bad program at once.
        """
        problems = []
        if not self.grid or not all(
            isinstance(g, int) and g > 0 for g in self.grid
        ):
            problems.append(f"grid must be positive ints, got {self.grid!r}")
        if len(self.out_shapes) != len(self.out_streams):
            problems.append(
                f"{len(self.out_streams)} out_streams but "
                f"{len(self.out_shapes)} out_shapes"
            )
        for role, streams in (("in", self.in_streams),
                              ("out", self.out_streams)):
            for i, s in enumerate(streams):
                if not all(isinstance(b, int) and b > 0 for b in s.block_shape):
                    problems.append(
                        f"{role}_streams[{i}] block_shape {s.block_shape!r} "
                        f"has a non-positive extent"
                    )
                if strict:
                    code = getattr(s.index_map, "__code__", None)
                    if code is not None and not (code.co_flags & 0x04):
                        nargs = code.co_argcount
                        want = len(self.grid)
                        affine = isinstance(s, AffineStream)
                        if (affine and nargs != want) or nargs < want:
                            problems.append(
                                f"{role}_streams[{i}] index_map takes "
                                f"{nargs} args for a {want}-axis grid"
                            )
        return [f"{self.name}: {p}" for p in problems]

    def vmem_bytes(self) -> int:
        """Estimated VMEM residency of the pipelined program.

        Every in/out stream holds one block double-buffered (the C4 SPM
        discipline: compute on one buffer while DMA fills the other), scratch
        buffers are single, persistent allocations. This is the analytic
        feasibility bound the block-size autotuner checks against the VMEM
        budget before compiling a candidate geometry.
        """
        stream_bytes = 2 * sum(
            s.bytes_per_step for s in (*self.in_streams, *self.out_streams)
        )
        scratch_bytes = sum(
            math.prod(s.shape) * _dtype_bytes(getattr(s, "dtype", None))
            for s in self.scratch
        )
        return stream_bytes + scratch_bytes


def stream_compute(program: StreamProgram, *operands, interpret: bool = False):
    """Execute a StreamProgram (the FREP + SU launch).

    ``operands`` bind positionally to ``program.in_streams``; scalar-prefetch
    index args come from the program itself. This is the single pallas_call
    site in the codebase.
    """
    if len(operands) != len(program.in_streams):
        raise ValueError(
            f"{program.name}: got {len(operands)} operands for "
            f"{len(program.in_streams)} in_streams"
        )
    n_pre = len(program.index_args)
    in_specs = [s.spec(n_pre) for s in program.in_streams]
    out_specs = [s.spec(n_pre) for s in program.out_streams]
    single = len(program.out_streams) == 1
    if single:
        out_specs, out_shapes = out_specs[0], program.out_shapes[0]
    else:
        out_shapes = list(program.out_shapes)

    kwargs: dict = {"out_shape": out_shapes, "interpret": interpret}
    if program.dimension_semantics is not None and not interpret:
        kwargs["compiler_params"] = _CompilerParams(
            dimension_semantics=tuple(program.dimension_semantics)
        )

    if n_pre:
        grid_spec = pltpu.PrefetchScalarGridSpec(
            num_scalar_prefetch=n_pre,
            grid=program.grid,
            in_specs=in_specs,
            out_specs=out_specs,
            scratch_shapes=list(program.scratch),
        )
        return pl.pallas_call(program.body, grid_spec=grid_spec, **kwargs)(
            *program.index_args, *operands
        )
    return pl.pallas_call(
        program.body,
        grid=program.grid,
        in_specs=in_specs,
        out_specs=out_specs,
        scratch_shapes=list(program.scratch),
        **kwargs,
    )(*operands)


def remote_ring_hop(x: jax.Array, axis: str, n: int) -> jax.Array:
    """One forward ring hop as a pallas async remote copy (RDMA).

    The D2D analogue of the SU double-buffer: instead of routing the hop
    through XLA's ``collective-permute``, the kernel programs the
    inter-chip DMA engine directly — ``make_async_remote_copy`` pushes the
    local buffer to rank ``(me + 1) % n`` and blocks on the receive
    semaphore until the left neighbour's push lands. Semantically identical
    to ``ppermute(x, axis, ring_fwd)``; the win is scheduling: the copy is
    a plain DMA the pipeliner can overlap like any other stream.

    TPU-only (the DMA engine and semaphores are TPU hardware); callers gate
    on ``jax.default_backend() == "tpu"`` and fall back to ``ppermute``
    (``parallel.collectives._hop_send``). Assumes the ring spans the whole
    ``axis`` with logical device ids matching axis order — the layout
    ``shard_map`` meshes give a single ring axis. Must run inside a
    ``shard_map`` naming ``axis``.
    """

    def body(x_ref, y_ref, send_sem, recv_sem):
        me = jax.lax.axis_index(axis)
        copy = pltpu.make_async_remote_copy(
            src_ref=x_ref,
            dst_ref=y_ref,
            send_sem=send_sem,
            recv_sem=recv_sem,
            device_id=((me + 1) % n,),
            device_id_type=pltpu.DeviceIdType.LOGICAL,
        )
        copy.start()
        copy.wait()

    return pl.pallas_call(
        body,
        out_shape=jax.ShapeDtypeStruct(x.shape, x.dtype),
        in_specs=[pl.BlockSpec(memory_space=pltpu.ANY)],
        out_specs=pl.BlockSpec(memory_space=pltpu.ANY),
        scratch_shapes=[pltpu.SemaphoreType.DMA, pltpu.SemaphoreType.DMA],
        compiler_params=_CompilerParams(
            has_side_effects=True, collective_id=0
        ),
    )(x)


def gemm_streams(
    M: int, N: int, K: int, bm: int, bn: int, bk: int, dtype=None
):
    """The paper's Fig. 4a GEMM loop nest as three affine streams."""
    a = AffineStream((bm, bk), lambda i, j, k: (i, k), dtype=dtype)
    b = AffineStream((bk, bn), lambda i, j, k: (k, j), dtype=dtype)
    o = AffineStream((bm, bn), lambda i, j, k: (i, j), dtype=dtype)
    grid = (M // bm, N // bn, K // bk)
    return grid, [a, b], o
