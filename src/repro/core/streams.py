"""Streaming-unit programming model (paper C1/C2) as a Pallas front-end.

Occamy's SUs map *streams* — ≤4D affine address sequences or index-driven
indirect sequences — onto FP register reads/writes, so the issue slots carry
only compute. The TPU translation: a stream is a (block_shape, index_map)
pair; the Pallas grid pipeline performs the address generation and the
double-buffered HBM->VMEM copies, and the kernel body carries only compute.

This module makes that correspondence explicit and first-class:

  AffineStream(block, loop)    ~ SU 4D affine stream descriptor (Fig. 4a)
  IndirectStream(block, idx)   ~ SU indirect stream (Fig. 4b): a scalar-
                                 prefetched index array drives the index_map
  stream_compute(...)          ~ FREP + SU setup: launches the kernel with
                                 streams bound to its operands

The production kernels (kernels/*.py) are hand-scheduled instances of this
model; stream_compute is the generic entry point used by examples and tests.
"""
from __future__ import annotations

import dataclasses
from typing import Callable, Sequence

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl
from jax.experimental.pallas import tpu as pltpu


@dataclasses.dataclass(frozen=True)
class AffineStream:
    """≤4D affine stream: block_shape + an index_map over the grid ids."""

    block_shape: tuple
    index_map: Callable  # (*grid_ids) -> block coords

    def spec(self, n_prefetch: int = 0) -> pl.BlockSpec:
        if n_prefetch == 0:
            return pl.BlockSpec(self.block_shape, self.index_map)
        # scalar-prefetch grids pass the prefetch refs after the grid ids
        fn = self.index_map
        return pl.BlockSpec(
            self.block_shape, lambda *a: fn(*a[: len(a) - n_prefetch])
        )


@dataclasses.dataclass(frozen=True)
class IndirectStream:
    """Index-driven stream: `index_map` may read the scalar-prefetched index
    arrays (passed as trailing args), Occamy's 8/16/32-bit index streams."""

    block_shape: tuple
    index_map: Callable  # (*grid_ids, *prefetch_refs) -> block coords

    def spec(self, n_prefetch: int) -> pl.BlockSpec:
        return pl.BlockSpec(self.block_shape, self.index_map)


def stream_compute(
    body: Callable,
    *,
    grid: tuple,
    in_streams: Sequence[AffineStream | IndirectStream],
    out_stream: AffineStream,
    out_shape: jax.ShapeDtypeStruct,
    index_args: Sequence[jax.Array] = (),
    scratch: Sequence = (),
    interpret: bool = False,
):
    """Run `body` with operands bound to streams (the FREP+SU launch).

    index_args are scalar-prefetched (SMEM-resident) index arrays available
    to every IndirectStream's index_map and to the body as leading refs.
    """
    n_pre = len(index_args)
    in_specs = [s.spec(n_pre) for s in in_streams]
    out_specs = out_stream.spec(n_pre)
    if n_pre:
        grid_spec = pltpu.PrefetchScalarGridSpec(
            num_scalar_prefetch=n_pre,
            grid=grid,
            in_specs=in_specs,
            out_specs=out_specs,
            scratch_shapes=list(scratch),
        )
        return pl.pallas_call(
            body, grid_spec=grid_spec, out_shape=out_shape, interpret=interpret
        )(*index_args)
    return pl.pallas_call(
        body,
        grid=grid,
        in_specs=in_specs,
        out_specs=out_specs,
        out_shape=out_shape,
        scratch_shapes=list(scratch),
        interpret=interpret,
    )


def gemm_streams(M: int, N: int, K: int, bm: int, bn: int, bk: int):
    """The paper's Fig. 4a GEMM loop nest as three affine streams."""
    a = AffineStream((bm, bk), lambda i, j, k: (i, k))
    b = AffineStream((bk, bn), lambda i, j, k: (k, j))
    o = AffineStream((bm, bn), lambda i, j, k: (i, j))
    grid = (M // bm, N // bn, K // bk)
    return grid, [a, b], o
