"""Degrade-path diagnostics: one warning category, one emission channel.

Several layers of the stack degrade gracefully instead of failing — the
partition ladder replicates when every rung declines, ``host_device_mesh``
shrinks an indivisible factorisation, ``remote_copy=True`` falls back to
``ppermute`` off-TPU. Historically these spoke through inconsistent
channels (``print`` vs bare ``warnings.warn``), which made degraded modes
invisible to callers filtering warnings and unenforceable by tooling.

This module is the single vocabulary: every degrade path warns through
``warn_degrade`` with the ``ReproDegradeWarning`` category, so callers can
``warnings.filterwarnings`` on exactly the degraded-mode signal and the
``repro.analysis`` lint rule (``warn-category``) can statically verify no
bare ``warnings.warn`` sneaks back in. Stdlib-only on purpose: launchers
import it before jax.
"""
from __future__ import annotations

import warnings

_SEEN: set = set()


class ReproDegradeWarning(UserWarning):
    """A requested configuration degraded to a weaker-but-correct mode.

    Examples: the partition ladder exhausted every rung and replicated, a
    mesh factorisation shrank to the largest dividing shape, or a TPU-only
    fast path (``remote_copy``) fell back to its portable twin. Subclasses
    ``UserWarning`` so existing ``pytest.warns(UserWarning)`` expectations
    keep matching.
    """


def warn_degrade(message: str, *, key=None, stacklevel: int = 2) -> None:
    """Emit ``message`` as a ``ReproDegradeWarning``.

    Args: ``message`` — what degraded and to what; ``key`` — when set, the
    warning is ONE-SHOT per process for this key (hot paths like
    ``plan_for`` call this per op call; the first degrade is signal, the
    10^6th is noise); ``stacklevel`` — forwarded to ``warnings.warn`` so
    the report points at the degrading caller.
    """
    if key is not None:
        if key in _SEEN:
            return
        _SEEN.add(key)
    warnings.warn(message, ReproDegradeWarning, stacklevel=stacklevel + 1)


def reset_degrade_warnings() -> None:
    """Clear the one-shot ``key`` memory (tests re-arm suppressed warnings)."""
    _SEEN.clear()
