"""Gradient compression with error feedback (distributed-optimization trick).

The pod-axis gradient all-reduce is the direct analogue of Occamy's D2D bulk
traffic — the slowest link in the hierarchy. Casting gradients to bf16 for
the reduction halves D2D bytes; fp32 error feedback (residual carried to the
next step) keeps convergence unbiased. Enabled via cfg.grad_compression.
"""
from __future__ import annotations

import jax
import jax.numpy as jnp


def init_error_state(params):
    return jax.tree.map(lambda p: jnp.zeros(p.shape, jnp.float32), params)


def compress_decompress(grads, err):
    """Returns (grads_after_roundtrip_fp32, new_err). The bf16 cast happens
    BEFORE the (jit-visible) gradient reduction, so the all-reduce moves bf16
    bytes; error feedback accumulates what the cast lost."""

    def one(g, e):
        gf = g.astype(jnp.float32) + e
        gc = gf.astype(jnp.bfloat16)
        return gc.astype(jnp.float32), gf - gc.astype(jnp.float32)

    flat_g, treedef = jax.tree.flatten(grads)
    flat_e = treedef.flatten_up_to(err)
    out = [one(g, e) for g, e in zip(flat_g, flat_e)]
    return (
        treedef.unflatten([o[0] for o in out]),
        treedef.unflatten([o[1] for o in out]),
    )
