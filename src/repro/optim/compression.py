"""Gradient compression with error feedback (distributed-optimization trick).

The pod-axis gradient all-reduce is the direct analogue of Occamy's D2D bulk
traffic — the slowest link in the hierarchy. Compressing gradients for the
reduction shrinks D2D bytes; fp32 error feedback (residual carried to the
next step) keeps convergence unbiased: the round-trip values telescope, so
the sum of compressed gradients over any window equals the sum of true
gradients minus the final residual. Enabled via cfg.grad_compression.

The compression width is a ``core.precision`` policy, not a hard-coded
dtype: the default ``"bf16"`` reproduces the classic bf16 round-trip
(scale_block == 0 — a plain cast), while block-scaled policies (``"fp8"``)
quantize per ``scale_block`` elements of the trailing axis through the same
(values, scales) machinery the scaled kernels use — one ladder, every
consumer.
"""
from __future__ import annotations

import jax
import jax.numpy as jnp

from repro.core import precision as _prec


def init_error_state(params):
    return jax.tree.map(lambda p: jnp.zeros(p.shape, jnp.float32), params)


def compress_decompress(grads, err, policy="bf16"):
    """Returns (grads_after_roundtrip_fp32, new_err). The narrow cast
    happens BEFORE the (jit-visible) gradient reduction, so the all-reduce
    moves compressed bytes; error feedback accumulates what the cast lost.

    Args: ``grads`` — the gradient pytree; ``err`` — the fp32 residual
    pytree from the previous step (``init_error_state`` shape); ``policy``
    — a ``core.precision`` policy name or ``Precision`` selecting the
    round-trip width (default ``"bf16"``, the legacy behavior). Policies
    with ``scale_block > 0`` round-trip through per-block (values, scales)
    quantization over each leaf's trailing axis; scalar leaves and
    unit-scale policies take the plain-cast path.
    """
    p = _prec.resolve(policy)

    def one(g, e):
        gf = g.astype(jnp.float32) + e
        if p.scale_block and gf.ndim:
            blk = p.scale_block
            gc = _prec.dequantize_blockwise(
                *_prec.quantize_blockwise(gf, p, axis=-1, block=blk),
                axis=-1, block=blk,
            )
        else:
            gc = gf.astype(p.compute_dtype).astype(jnp.float32)
        return gc, gf - gc

    out = jax.tree.map(one, grads, err)
    return jax.tree.transpose(
        jax.tree.structure(grads), jax.tree.structure((0, 0)), out
    )
