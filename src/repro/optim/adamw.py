"""AdamW with parameter-co-sharded states (built from scratch, functional).

Optimizer moments inherit each parameter's PartitionSpec, so FSDP-sharded
params get FSDP-sharded states (ZeRO-style) for free. Updates are computed in
fp32 regardless of parameter dtype (the paper's expanding-accumulation
discipline applied to the optimizer).
"""
from __future__ import annotations

import jax
import jax.numpy as jnp


def init_state(params, dtype=jnp.float32):
    def zeros(p):
        return jnp.zeros(p.shape, dtype)

    return {
        "m": jax.tree.map(zeros, params),
        "v": jax.tree.map(zeros, params),
        "step": jnp.zeros((), jnp.int32),
    }


def global_norm(tree):
    return jnp.sqrt(
        sum(jnp.sum(jnp.square(x.astype(jnp.float32))) for x in jax.tree.leaves(tree))
    )


def clip_by_global_norm(grads, max_norm):
    norm = global_norm(grads)
    scale = jnp.minimum(1.0, max_norm / jnp.maximum(norm, 1e-9))
    return jax.tree.map(lambda g: (g.astype(jnp.float32) * scale), grads), norm


def lr_schedule(cfg, step):
    warm = jnp.minimum(step.astype(jnp.float32) / max(cfg.warmup_steps, 1), 1.0)
    return cfg.learning_rate * warm


def apply_updates(cfg, params, grads, opt_state):
    """Returns (new_params, new_opt_state, metrics)."""
    grads, gnorm = clip_by_global_norm(grads, cfg.grad_clip)
    step = opt_state["step"] + 1
    lr = lr_schedule(cfg, step)
    b1, b2, eps, wd = cfg.beta1, cfg.beta2, 1e-8, cfg.weight_decay
    bc1 = 1.0 - b1 ** step.astype(jnp.float32)
    bc2 = 1.0 - b2 ** step.astype(jnp.float32)

    def upd(p, g, m, v):
        gf = g.astype(jnp.float32)
        m_new = b1 * m + (1.0 - b1) * gf
        v_new = b2 * v + (1.0 - b2) * gf * gf
        mh = m_new / bc1
        vh = v_new / bc2
        delta = mh / (jnp.sqrt(vh) + eps) + wd * p.astype(jnp.float32)
        return (p.astype(jnp.float32) - lr * delta).astype(p.dtype), m_new, v_new

    flat_p, treedef = jax.tree.flatten(params)
    flat_g = treedef.flatten_up_to(grads)
    flat_m = treedef.flatten_up_to(opt_state["m"])
    flat_v = treedef.flatten_up_to(opt_state["v"])
    out = [upd(p, g, m, v) for p, g, m, v in zip(flat_p, flat_g, flat_m, flat_v)]
    new_params = treedef.unflatten([o[0] for o in out])
    new_m = treedef.unflatten([o[1] for o in out])
    new_v = treedef.unflatten([o[2] for o in out])
    return (
        new_params,
        {"m": new_m, "v": new_v, "step": step},
        {"grad_norm": gnorm, "lr": lr},
    )
