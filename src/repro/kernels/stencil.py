"""Stencil kernel with offset streams (paper Fig. 9b; SARIS [36] analogue).

SARIS stores per-point offset index arrays and streams them through the
indirect SUs in ideal processing order. TPU adaptation: offsets become static
block-relative addresses; the kernel receives THREE views of the grid (the
previous/current/next x-blocks, selected by index_map arithmetic — periodic
boundary) and applies each offset as a static slice + lane rotate, so the
inner loop issues only multiply-accumulates. Supports any star/box stencil
with |dx| <= block size.
"""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
import numpy as np
from jax.experimental import pallas as pl
from jax.experimental.pallas import tpu as pltpu


def _stencil_kernel(prev_ref, cur_ref, next_ref, o_ref, *, offsets, weights, bx):
    buf = jnp.concatenate(
        [prev_ref[...], cur_ref[...], next_ref[...]], axis=0
    ).astype(jnp.float32)  # (3*bx, Y, Z)
    acc = jnp.zeros_like(o_ref, dtype=jnp.float32)
    for p in range(offsets.shape[0]):
        dx, dy, dz = (int(d) for d in offsets[p])
        sl = buf[bx + dx : 2 * bx + dx]  # static x-offset slice
        if dy or dz:
            sl = jnp.roll(sl, (-dy, -dz), axis=(1, 2))  # periodic y/z rotate
        acc += float(weights[p]) * sl
    o_ref[...] = acc.astype(o_ref.dtype)


def stencil_pallas(
    grid: jax.Array,  # (X, Y, Z)
    offsets: np.ndarray,  # (P, 3) static int offsets
    weights,  # (P,) static
    *,
    bx: int = 8,
    interpret: bool = False,
):
    X, Y, Z = grid.shape
    bx = min(bx, X)
    assert X % bx == 0, (X, bx)
    assert int(np.abs(offsets[:, 0]).max(initial=0)) <= bx, "dx exceeds block"
    weights = np.asarray(weights)
    nb = X // bx

    out = pl.pallas_call(
        functools.partial(
            _stencil_kernel, offsets=np.asarray(offsets), weights=weights, bx=bx
        ),
        grid=(nb,),
        in_specs=[
            pl.BlockSpec((bx, Y, Z), lambda i: ((i - 1) % nb, 0, 0)),
            pl.BlockSpec((bx, Y, Z), lambda i: (i, 0, 0)),
            pl.BlockSpec((bx, Y, Z), lambda i: ((i + 1) % nb, 0, 0)),
        ],
        out_specs=pl.BlockSpec((bx, Y, Z), lambda i: (i, 0, 0)),
        out_shape=jax.ShapeDtypeStruct((X, Y, Z), grid.dtype),
        compiler_params=pltpu.CompilerParams(
            dimension_semantics=("arbitrary",)
        ),
        interpret=interpret,
    )(grid, grid, grid)
    return out
