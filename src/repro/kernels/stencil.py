"""Stencil kernel with offset streams (paper Fig. 9b; SARIS [36] analogue).

SARIS stores per-point offset index arrays and streams them through the
indirect SUs in ideal processing order. TPU adaptation: offsets become static
block-relative addresses; the stream program binds THREE affine views of the
grid (the previous/current/next x-blocks, selected by index_map arithmetic —
periodic boundary) and the body applies each offset as a static slice + lane
rotate, so the inner loop issues only multiply-accumulates. Supports any
star/box stencil with |dx| <= block size.
"""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
import numpy as np

from repro.core.streams import AffineStream, StreamProgram, stream_compute
from repro.kernels.registry import resolve_blocks


def _stencil_kernel(prev_ref, cur_ref, next_ref, o_ref, *, offsets, weights, bx):
    buf = jnp.concatenate(
        [prev_ref[...], cur_ref[...], next_ref[...]], axis=0
    ).astype(jnp.float32)  # (3*bx, Y, Z)
    acc = jnp.zeros_like(o_ref, dtype=jnp.float32)
    for p in range(offsets.shape[0]):
        dx, dy, dz = (int(d) for d in offsets[p])
        sl = buf[bx + dx : 2 * bx + dx]  # static x-offset slice
        if dy or dz:
            sl = jnp.roll(sl, (-dy, -dz), axis=(1, 2))  # periodic y/z rotate
        acc += float(weights[p]) * sl
    o_ref[...] = acc.astype(o_ref.dtype)


def stencil_program(X, Y, Z, bx, offsets, weights, dtype) -> StreamProgram:
    """Stencil as a stream program: three halo-shifted affine views of the
    same operand (the offset streams), one output stream."""
    nb = X // bx
    body = functools.partial(
        _stencil_kernel, offsets=np.asarray(offsets),
        weights=np.asarray(weights), bx=bx,
    )
    def view(shift):
        return AffineStream(
            (bx, Y, Z), lambda i: ((i + shift) % nb, 0, 0), dtype=dtype
        )
    return StreamProgram(
        name="stencil",
        body=body,
        grid=(nb,),
        in_streams=(view(-1), view(0), view(+1)),
        out_streams=(AffineStream((bx, Y, Z), lambda i: (i, 0, 0), dtype=dtype),),
        out_shapes=(jax.ShapeDtypeStruct((X, Y, Z), dtype),),
        dimension_semantics=("arbitrary",),
    )


def stencil_pallas(
    grid: jax.Array,  # (X, Y, Z)
    offsets: np.ndarray,  # (P, 3) static int offsets
    weights,  # (P,) static
    *,
    bx: int | None = None,
    interpret: bool = False,
):
    X, Y, Z = grid.shape
    bx = min(resolve_blocks("stencil", bx=bx)["bx"], X)
    assert X % bx == 0, (X, bx)
    assert int(np.abs(offsets[:, 0]).max(initial=0)) <= bx, "dx exceeds block"

    program = stencil_program(X, Y, Z, bx, offsets, weights, grid.dtype)
    return stream_compute(program, grid, grid, grid, interpret=interpret)
