"""FlashAttention-2 forward kernel (paper Sec. V-C uses FA-2 inside GPT-J).

Online-softmax over KV blocks with the running (m, l, acc) statistics held in
VMEM scratch across the innermost grid dimension. The KV block stream is the
paper's C4 double-buffered DMA tile stream; causal/window masking is applied
with iota position comparisons, and fully-masked blocks skip their compute
(pl.when) — the control-flow analogue of the SUs skipping dead iterations.
Supports GQA (H = K * G) via the k/v stream index maps.
"""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
import numpy as np
from jax.experimental import pallas as pl
from jax.experimental.pallas import tpu as pltpu

from repro.core.streams import AffineStream, StreamProgram, stream_compute
from repro.kernels.registry import resolve_blocks

NEG = -1e30


def zigzag_indices(S: int, d: int) -> np.ndarray:
    """The zigzag (head+tail) sequence permutation of a ``d``-rank causal
    KV ring: split ``S`` rows into ``2d`` half-chunks and give rank ``r``
    half-chunks ``r`` and ``2d-1-r`` — one from the causal head, one from
    the tail — so every rank does the same 2·(S/2d)² score work per hop
    instead of rank 0 idling on every wrapped hop.

    Returns the length-``S`` gather index array ``idx``: natural-order row
    ``idx[i]`` lands at zigzag position ``i``; sharding positions over the
    ``data`` axis then hands rank ``r`` exactly its two half-chunks, head
    half first. Within each half the natural order is preserved and every
    head position precedes every tail position, so the concatenated local
    block is order-isomorphic to its global rows — a plain causal mask on
    the local block IS the global causal mask restricted to them (the
    property the ring's hop-0 kernel call relies on). Requires
    ``S % (2 * d) == 0``.
    """
    c2 = S // (2 * d)
    parts = []
    for r in range(d):
        parts.append(np.arange(r * c2, (r + 1) * c2))
        parts.append(np.arange((2 * d - 1 - r) * c2, (2 * d - r) * c2))
    return np.concatenate(parts)


def zigzag_inverse(S: int, d: int) -> np.ndarray:
    """Inverse of ``zigzag_indices``: gathering with it restores natural
    sequence order (``zz[zigzag_inverse(S, d)] == natural``)."""
    return np.argsort(zigzag_indices(S, d), kind="stable")


def _fa_kernel(
    q_ref, k_ref, v_ref, *refs,
    scale, causal, window, q_offset, sk, bq, bk, nk, return_lse,
    scaled=False,
):
    # scaled programs bind three per-row fp32 scale streams after v; then
    # the o out-ref; refs ends with the (m, l, acc) scratch — preceded by
    # the lse out-ref when the program was built with return_lse (out refs
    # bind before scratch)
    if scaled:
        qs_ref, ks_ref, vs_ref, *refs = refs
    else:
        qs_ref = ks_ref = vs_ref = None
    o_ref, *refs = refs
    lse_ref, (m_ref, l_ref, acc_ref) = (
        (refs[0], refs[1:]) if return_lse else (None, refs)
    )
    ik = pl.program_id(3)
    iq = pl.program_id(2)

    @pl.when(ik == 0)
    def _init():
        m_ref[...] = jnp.full_like(m_ref, NEG)
        l_ref[...] = jnp.zeros_like(l_ref)
        acc_ref[...] = jnp.zeros_like(acc_ref)

    q_pos = q_offset + iq * bq + jax.lax.broadcasted_iota(jnp.int32, (bq, bk), 0)
    k_pos = ik * bk + jax.lax.broadcasted_iota(jnp.int32, (bq, bk), 1)

    # block-level early-out: skip fully-masked KV blocks. A lookback window
    # bounds positions like causal does (k_pos <= q_pos), so the
    # above-the-diagonal skip applies to windowed non-causal blocks too.
    run = None
    if causal or window:  # block strictly above the (implied) diagonal
        run = ik * bk <= q_offset + (iq + 1) * bq - 1
    if window:  # block entirely older than every q row's window
        in_window = (ik + 1) * bk - 1 > q_offset + iq * bq - window
        run = jnp.logical_and(run, in_window)

    def _compute():
        # dequantize at use: narrow values ride the streams, the rescale
        # happens inside the fp32 block compute (widening accumulation)
        q = q_ref[0, 0].astype(jnp.float32)
        if qs_ref is not None:
            q = q * qs_ref[0, 0]
        q = q * scale
        k = k_ref[0, 0].astype(jnp.float32)
        if ks_ref is not None:
            k = k * ks_ref[0, 0]
        s = jax.lax.dot_general(
            q, k, (((1,), (1,)), ((), ())), preferred_element_type=jnp.float32
        )  # (bq, bk)
        mask = k_pos < sk
        if causal or window:
            mask &= k_pos <= q_pos
        if window:
            mask &= k_pos > q_pos - window
        s = jnp.where(mask, s, NEG)
        m_prev = m_ref[...]
        m_new = jnp.maximum(m_prev, s.max(axis=-1, keepdims=True))
        # fully-masked rows: exp(NEG - NEG) == 1, zero them via the mask
        p = jnp.where(mask, jnp.exp(s - m_new), 0.0)
        corr = jnp.exp(m_prev - m_new)
        l_ref[...] = l_ref[...] * corr + p.sum(axis=-1, keepdims=True)
        vblk = v_ref[0, 0].astype(jnp.float32)
        if vs_ref is not None:
            vblk = vblk * vs_ref[0, 0]
        acc_ref[...] = acc_ref[...] * corr + jax.lax.dot(
            p, vblk, preferred_element_type=jnp.float32,
        )
        m_ref[...] = m_new

    if run is None:
        _compute()
    else:
        pl.when(run)(_compute)

    @pl.when(ik == nk - 1)
    def _flush():
        o_ref[0, 0] = (
            acc_ref[...] / jnp.maximum(l_ref[...], 1e-30)
        ).astype(o_ref.dtype)
        if lse_ref is not None:
            lse_ref[0, 0] = (
                m_ref[..., 0] + jnp.log(jnp.maximum(l_ref[..., 0], 1e-30))
            )


def flash_attention_program(
    B, H, G, Sqp, D, nq, nk, bq, bk, dtype, k_dtype, v_dtype,
    *, scale, causal, window, q_offset, sk, return_lse=False, scaled=False,
) -> StreamProgram:
    """FA-2 as a stream program: q/o stream over (b, h, iq); the k/v streams
    revisit the shared KV head h//G — the GQA index map. ``return_lse``
    adds a second (B, H, Sqp) fp32 output stream carrying the per-row
    log-sum-exp (the ring-attention merge statistic). ``scaled`` adds
    three per-row fp32 scale streams (q, k, v) riding the same index maps
    as their value streams — the quantized-operand path."""
    body = functools.partial(
        _fa_kernel, scale=scale, causal=causal, window=window,
        q_offset=q_offset, sk=sk, bq=bq, bk=bk, nk=nk, return_lse=return_lse,
        scaled=scaled,
    )
    def kv_stream(dt):
        return AffineStream(
            (1, 1, bk, D), lambda b, h, i, j: (b, h // G, j, 0), dtype=dt
        )
    in_streams = [
        AffineStream(
            (1, 1, bq, D), lambda b, h, i, j: (b, h, i, 0), dtype=dtype
        ),
        kv_stream(k_dtype),
        kv_stream(v_dtype),
    ]
    if scaled:
        in_streams.append(AffineStream(
            (1, 1, bq, 1), lambda b, h, i, j: (b, h, i, 0),
            dtype=jnp.float32,
        ))
        in_streams.extend(
            AffineStream(
                (1, 1, bk, 1), lambda b, h, i, j: (b, h // G, j, 0),
                dtype=jnp.float32,
            )
            for _ in range(2)
        )
    out_streams = [
        AffineStream(
            (1, 1, bq, D), lambda b, h, i, j: (b, h, i, 0),
            dtype=jnp.float32 if scaled else dtype
        ),
    ]
    out_shapes = [jax.ShapeDtypeStruct(
        (B, H, Sqp, D), jnp.float32 if scaled else dtype
    )]
    if return_lse:
        out_streams.append(AffineStream(
            (1, 1, bq), lambda b, h, i, j: (b, h, i), dtype=jnp.float32
        ))
        out_shapes.append(jax.ShapeDtypeStruct((B, H, Sqp), jnp.float32))
    return StreamProgram(
        name="flash_attention_scaled" if scaled else "flash_attention",
        body=body,
        grid=(B, H, nq, nk),
        in_streams=tuple(in_streams),
        out_streams=tuple(out_streams),
        out_shapes=tuple(out_shapes),
        scratch=(
            pltpu.VMEM((bq, 1), jnp.float32),
            pltpu.VMEM((bq, 1), jnp.float32),
            pltpu.VMEM((bq, D), jnp.float32),
        ),
        dimension_semantics=("parallel", "parallel", "parallel", "arbitrary"),
    )


def flash_attention_pallas(
    q: jax.Array,  # (B, H, Sq, D)
    k: jax.Array,  # (B, K, Sk, D)
    v: jax.Array,
    *,
    causal: bool = True,
    window: int = 0,
    q_offset: int = 0,
    scale: float | None = None,
    bq: int | None = None,
    bk: int | None = None,
    return_lse: bool = False,
    interpret: bool = False,
):
    B, H, Sq, D = q.shape
    K, Sk = k.shape[1], k.shape[2]
    G = H // K
    scale = scale if scale is not None else 1.0 / np.sqrt(D)
    blocks = resolve_blocks("flash_attention", bq=bq, bk=bk)
    bq = min(blocks["bq"], Sq)
    bk = min(blocks["bk"], Sk)
    pq, pk_ = (-Sq) % bq, (-Sk) % bk
    if pq:
        q = jnp.pad(q, ((0, 0), (0, 0), (0, pq), (0, 0)))
    if pk_:
        k = jnp.pad(k, ((0, 0), (0, 0), (0, pk_), (0, 0)))
        v = jnp.pad(v, ((0, 0), (0, 0), (0, pk_), (0, 0)))
    nq, nk = (Sq + pq) // bq, (Sk + pk_) // bk

    program = flash_attention_program(
        B, H, G, Sq + pq, D, nq, nk, bq, bk, q.dtype, k.dtype, v.dtype,
        scale=scale, causal=causal, window=window, q_offset=q_offset, sk=Sk,
        return_lse=return_lse,
    )
    out = stream_compute(program, q, k, v, interpret=interpret)
    if return_lse:
        o, lse = out
        return o[:, :, :Sq], lse[:, :, :Sq]
    return out[:, :, :Sq]


def flash_attention_scaled_pallas(
    q: jax.Array,  # (B, H, Sq, D)
    k: jax.Array,  # (B, K, Sk, D)
    v: jax.Array,
    precision,
    *,
    causal: bool = True,
    window: int = 0,
    q_offset: int = 0,
    scale: float | None = None,
    bq: int | None = None,
    bk: int | None = None,
    return_lse: bool = False,
    interpret: bool = False,
):
    """Low-precision FA-2: operands quantized per row over D (one fp32
    scale per (b, h, s) position — the KV-cache layout), values streamed
    narrow, dequantized inside the fp32 block compute."""
    from repro.core import precision as prec

    p = prec.resolve(precision)
    B, H, Sq, D = q.shape
    K, Sk = k.shape[1], k.shape[2]
    G = H // K
    scale = scale if scale is not None else 1.0 / np.sqrt(D)
    blocks = resolve_blocks("flash_attention", bq=bq, bk=bk)
    bq = min(blocks["bq"], Sq)
    bk = min(blocks["bk"], Sk)
    pq, pk_ = (-Sq) % bq, (-Sk) % bk
    if pq:
        q = jnp.pad(q, ((0, 0), (0, 0), (0, pq), (0, 0)))
    if pk_:
        k = jnp.pad(k, ((0, 0), (0, 0), (0, pk_), (0, 0)))
        v = jnp.pad(v, ((0, 0), (0, 0), (0, pk_), (0, 0)))
    nq, nk = (Sq + pq) // bq, (Sk + pk_) // bk

    qq, q_scale = prec.quantize_blockwise(q, p, axis=-1, block=D)
    kq, k_scale = prec.quantize_blockwise(k, p, axis=-1, block=D)
    vq, v_scale = prec.quantize_blockwise(v, p, axis=-1, block=D)

    program = flash_attention_program(
        B, H, G, Sq + pq, D, nq, nk, bq, bk,
        p.compute_dtype, p.compute_dtype, p.compute_dtype,
        scale=scale, causal=causal, window=window, q_offset=q_offset, sk=Sk,
        return_lse=return_lse, scaled=True,
    )
    out = stream_compute(
        program, qq, kq, vq, q_scale, k_scale, v_scale, interpret=interpret
    )
    if return_lse:
        o, lse = out
        return o[:, :, :Sq], lse[:, :, :Sq]
    return out[:, :, :Sq]
