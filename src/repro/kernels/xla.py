"""Blocked jnp implementations of the stream kernels ("xla" impls).

Each function implements the *same algorithm* as its Pallas StreamProgram
sibling — same FLOPs, same memory behaviour — expressed in jnp so it lowers
on any backend. The multi-pod dry-run compiles these where Pallas cannot
lower on CPU; ``registry.unroll_inner()`` swaps their inner lax.scan for a
python loop so XLA's HloCostAnalysis (which counts while-loop bodies once)
sees true FLOP/byte/collective counts.
"""
from __future__ import annotations

import jax
import jax.numpy as jnp
import numpy as np

from repro.kernels import registry


# ---------------------------------------------------------------------------
# Per-block scaled GEMM (the narrow-precision path)
# ---------------------------------------------------------------------------


def gemm_scaled_xla(a, b, precision, *, out_dtype=None,
                    accum_dtype=jnp.float32, bm=None, bk=None, bn=None):
    """Blocked per-block scaled GEMM in jnp: the same (values, scales)
    dataflow as ``gemm.gemm_scaled_pallas`` — quantize per K-block of size
    ``bk``, run the narrow dot per block, rescale inside the fp32
    accumulator — expressed as a scan over K blocks so it lowers anywhere.
    """
    from repro.core import precision as prec

    p = prec.resolve(precision)
    M, K = a.shape
    K2, N = b.shape
    assert K == K2
    out_dtype = out_dtype or jnp.float32
    bk = min(registry.resolve_blocks("gemm", bm=bm, bk=bk, bn=bn)["bk"], K)
    pad = (-K) % bk
    if pad:
        a = jnp.pad(a, ((0, 0), (0, pad)))
        b = jnp.pad(b, ((0, pad), (0, 0)))
    Kp = K + pad
    nk = Kp // bk

    aq, a_scale = prec.quantize_blockwise(a, p, axis=1, block=bk)
    bq, b_scale = prec.quantize_blockwise(b, p, axis=0, block=bk)
    ab = jnp.moveaxis(aq.reshape(M, nk, bk), 1, 0)  # (nk, M, bk)
    bb = bq.reshape(nk, bk, N)

    def body(acc, xs):
        ablk, bblk, asc, bsc = xs
        part = jnp.dot(ablk, bblk, preferred_element_type=accum_dtype)
        return acc + part * (asc[:, None] * bsc[None, :]), None

    acc0 = jnp.zeros((M, N), accum_dtype)
    xs = (ab, bb, jnp.moveaxis(a_scale, 1, 0), b_scale)
    if registry.unroll_inner_enabled():
        acc = acc0
        for i in range(nk):
            acc, _ = body(acc, jax.tree.map(lambda x: x[i], xs))
    else:
        acc, _ = jax.lax.scan(body, acc0, xs)
    return acc.astype(out_dtype)


# ---------------------------------------------------------------------------
# FlashAttention-2 (forward)
# ---------------------------------------------------------------------------


def flash_attention_xla(q, k, v, *, causal=True, window=0, q_offset=0,
                        scale=None, bq=None, bk=None, return_lse=False):
    """Online-softmax over KV blocks (FlashAttention-2 dataflow in jnp).

    Memory is O(Sq * bk) per head instead of O(Sq * Sk): this is the
    C4 double-buffered-tile structure the paper uses, expressed as a scan.
    ``bq``/``bk`` resolve through the registry (explicit > override >
    default), the same block geometry the Pallas kernel reads. A lookback
    ``window`` bounds attention to ``(q_pos - window, q_pos]`` regardless
    of ``causal`` (the shared window semantics — see ``ref.mha_ref``).
    ``return_lse=True`` also returns the (B, H, Sq) fp32 log-sum-exp the
    ring-attention merge consumes.
    """
    B, H, Sq, D = q.shape
    K, Sk = k.shape[1], k.shape[2]
    G = H // K
    scale = scale if scale is not None else 1.0 / np.sqrt(D)
    if registry.unroll_inner_enabled():
        # q-blocked form with STATIC skipping of fully-masked (q, kv) block
        # pairs — cost-representative of the Pallas kernel's pl.when skips
        # (causal halves attention FLOPs; sliding windows keep only a band)
        return _flash_attention_xla_unrolled(
            q, k, v, causal=causal, window=window, q_offset=q_offset,
            scale=scale, bq=bq, bk=bk, return_lse=return_lse,
        )
    block_k = min(registry.resolve_blocks("flash_attention", bk=bk)["bk"], Sk)
    pad = (-Sk) % block_k
    if pad:
        k = jnp.pad(k, ((0, 0), (0, 0), (0, pad), (0, 0)))
        v = jnp.pad(v, ((0, 0), (0, 0), (0, pad), (0, 0)))
    nb = (Sk + pad) // block_k

    qf = (q.astype(jnp.float32) * scale).reshape(B, K, G, Sq, D)
    kb = jnp.moveaxis(k.reshape(B, K, nb, block_k, D), 2, 0)
    vb = jnp.moveaxis(v.reshape(B, K, nb, block_k, D), 2, 0)
    q_pos = jnp.arange(Sq) + q_offset  # absolute positions

    NEG = jnp.float32(-1e30)

    def body(carry, xs):
        m, denom, acc = carry
        kblk, vblk, bidx = xs
        s = jnp.einsum("bkgqd,bksd->bkgqs", qf, kblk.astype(jnp.float32))
        k_pos = bidx * block_k + jnp.arange(block_k)
        mask = k_pos[None, :] < Sk
        if causal or window:
            mask &= k_pos[None, :] <= q_pos[:, None]
        if window:
            mask &= k_pos[None, :] > q_pos[:, None] - window
        s = jnp.where(mask, s, NEG)
        m_new = jnp.maximum(m, s.max(axis=-1))
        # fully-masked rows: exp(NEG - NEG) == 1, so zero by mask explicitly
        p = jnp.where(mask, jnp.exp(s - m_new[..., None]), 0.0)
        corr = jnp.exp(m - m_new)
        denom = denom * corr + p.sum(axis=-1)
        acc = acc * corr[..., None] + jnp.einsum(
            "bkgqs,bksd->bkgqd", p, vblk.astype(jnp.float32)
        )
        return (m_new, denom, acc), None

    m0 = jnp.full((B, K, G, Sq), NEG)
    l0 = jnp.zeros((B, K, G, Sq))
    acc0 = jnp.zeros((B, K, G, Sq, D))
    (m, denom, acc), _ = jax.lax.scan(
        body, (m0, l0, acc0), (kb, vb, jnp.arange(nb))
    )
    o = acc / jnp.maximum(denom, 1e-30)[..., None]
    o = o.reshape(B, H, Sq, D).astype(q.dtype)
    if not return_lse:
        return o
    lse = (m + jnp.log(jnp.maximum(denom, 1e-30))).reshape(B, H, Sq)
    return o, lse


def _flash_attention_xla_unrolled(q, k, v, *, causal, window, q_offset, scale,
                                  bq=None, bk=None, return_lse=False):
    B, H, Sq, D = q.shape
    K, Sk = k.shape[1], k.shape[2]
    G = H // K
    NEG = jnp.float32(-1e30)
    # the same single block-geometry path every impl uses (explicit >
    # set_block_override > default) — no private env-var escape hatch
    blocks = registry.resolve_blocks("flash_attention", bq=bq, bk=bk)
    bq, bk = min(blocks["bq"], Sq), min(blocks["bk"], Sk)
    pq, pk = (-Sq) % bq, (-Sk) % bk
    if pq:
        q = jnp.pad(q, ((0, 0), (0, 0), (0, pq), (0, 0)))
    if pk:
        k = jnp.pad(k, ((0, 0), (0, 0), (0, pk), (0, 0)))
        v = jnp.pad(v, ((0, 0), (0, 0), (0, pk), (0, 0)))
    nq, nk = (Sq + pq) // bq, (Sk + pk) // bk
    qf = (q.astype(jnp.float32) * scale).reshape(B, K, G, nq, bq, D)

    outs, lses = [], []
    for i in range(nq):
        qi = qf[:, :, :, i]  # (B,K,G,bq,D)
        q_lo, q_hi = q_offset + i * bq, q_offset + (i + 1) * bq - 1
        m = jnp.full((B, K, G, bq), NEG)
        denom = jnp.zeros((B, K, G, bq))
        acc = jnp.zeros((B, K, G, bq, D))
        for j in range(nk):
            k_lo, k_hi = j * bk, (j + 1) * bk - 1
            if (causal or window) and k_lo > q_hi:
                continue  # static skip: above the diagonal (window implies it)
            if window and k_hi <= q_lo - window:
                continue  # static skip: older than every row's window
            kj = k[:, :, j * bk : (j + 1) * bk].astype(jnp.float32)
            vj = v[:, :, j * bk : (j + 1) * bk].astype(jnp.float32)
            s = jnp.einsum("bkgqd,bksd->bkgqs", qi, kj)
            q_pos = q_lo + jnp.arange(bq)[:, None]
            k_pos = k_lo + jnp.arange(bk)[None, :]
            mask = k_pos < Sk
            if causal or window:
                mask &= k_pos <= q_pos
            if window:
                mask &= k_pos > q_pos - window
            s = jnp.where(mask, s, NEG)
            m_new = jnp.maximum(m, s.max(axis=-1))
            p = jnp.where(mask, jnp.exp(s - m_new[..., None]), 0.0)
            corr = jnp.exp(m - m_new)
            denom = denom * corr + p.sum(axis=-1)
            acc = acc * corr[..., None] + jnp.einsum("bkgqs,bksd->bkgqd", p, vj)
            m = m_new
        outs.append(acc / jnp.maximum(denom, 1e-30)[..., None])
        lses.append(m + jnp.log(jnp.maximum(denom, 1e-30)))
    o = jnp.concatenate(outs, axis=3).reshape(B, H, Sq + pq, D)[:, :, :Sq]
    o = o.astype(q.dtype)
    if not return_lse:
        return o
    lse = jnp.concatenate(lses, axis=3).reshape(B, H, Sq + pq)[:, :, :Sq]
    return o, lse


def flash_attention_scaled_xla(q, k, v, precision, *, causal=True, window=0,
                               q_offset=0, scale=None, bq=None, bk=None,
                               return_lse=False):
    """Low-precision FA-2 in jnp: per-row quantize/dequantize of q/k/v (one
    fp32 scale per (b, h, s) row over D), then the unchanged blocked
    online-softmax scan. The quantization error is in operand storage only
    — the algorithm and its fp32 accumulation are identical to
    ``flash_attention_xla``, matching the Pallas kernel's dequantize-at-use
    dataflow."""
    from repro.core import precision as prec

    p = prec.resolve(precision)
    deq = []
    for x in (q, k, v):
        vals, scales = prec.quantize_blockwise(x, p, axis=-1,
                                               block=x.shape[-1])
        deq.append(prec.dequantize_blockwise(vals, scales, axis=-1))
    return flash_attention_xla(
        deq[0], deq[1], deq[2], causal=causal, window=window,
        q_offset=q_offset, scale=scale, bq=bq, bk=bk, return_lse=return_lse,
    )


def decode_attention_xla(q, k, v, position, *, window=0, scale=None, bs=None,
                         precision=None, block_table=None, k_scale=None,
                         v_scale=None, pos_offset=0, return_lse=False):
    """Blocked single-token attention against a cache (online softmax over
    cache blocks, the memory-bound decode form GPT-J hits every step).

    The cache streams through in ``bs``-sized blocks — O(B*H*bs) live state
    instead of the ref form's O(B*H*S) score matrix — mirroring the C4
    double-buffered cache-tile traffic. ``bs`` resolves through the registry
    (explicit > override > default) like every other block parameter.

    ``precision`` enables the quantized-cache serving path: the KV cache is
    held as narrow values plus one fp32 scale per cached (b, k, s) row
    (``precision.quantize_kv_cache``), each streamed block is dequantized
    at use inside the fp32 online softmax — the cache's HBM footprint and
    stream traffic shrink by the compute dtype's width ratio.

    ``block_table`` switches the cache operands to the *paged* layout: k/v
    are physical block pools ``(P, K, bs, D)`` and ``block_table`` is a
    ``(B, NB)`` int32 map from each sequence's logical cache block to its
    pool slot. The pool's own block extent pins ``bs`` (the page size is
    the stream tile), the gathered blocks stream through the *same* online
    softmax body as the contiguous path — so the two layouts are bitwise
    equal whenever the contiguous length is ``NB * bs``. Table entries past
    a sequence's ``position`` may point anywhere valid (the mask makes
    those blocks exact no-ops). ``k_scale``/``v_scale`` pass pre-quantized
    pool scales (``(P, K, bs, 1)``) so a cache held narrow by the serving
    engine skips the quantize-at-use step. ``pos_offset`` shifts the
    absolute position of logical block 0 (the cache-shard offset ring
    decode folds over); ``return_lse`` additionally returns the (B, H)
    fp32 log-sum-exp the per-shard online-softmax merge consumes.
    """
    B, H, D = q.shape
    K = k.shape[1]
    G = H // K
    scale = scale if scale is not None else 1.0 / np.sqrt(D)
    paged = block_table is not None
    if precision is not None and k_scale is None:
        from repro.core import precision as prec

        k, k_scale, v, v_scale = prec.quantize_kv_cache(k, v, precision)
    if paged:
        bs = k.shape[2]  # the pool's page size IS the stream tile
        nb = block_table.shape[1]
        S = nb * bs
        # gather pool pages into the (nb, B, K, bs, d) stream the scan eats
        def blk(x):
            return jnp.moveaxis(x[block_table], 1, 0)

        kb, vb = blk(k), blk(v)
        ksb = blk(k_scale) if k_scale is not None else jnp.zeros((nb,))
        vsb = blk(v_scale) if v_scale is not None else jnp.zeros((nb,))
    else:
        S = k.shape[2]
        bs = min(registry.resolve_blocks("decode_attention", bs=bs)["bs"], S)
        pad = (-S) % bs
        if pad:
            k = jnp.pad(k, ((0, 0), (0, 0), (0, pad), (0, 0)))
            v = jnp.pad(v, ((0, 0), (0, 0), (0, pad), (0, 0)))
            if k_scale is not None:
                k_scale = jnp.pad(k_scale, ((0, 0), (0, 0), (0, pad), (0, 0)))
                v_scale = jnp.pad(v_scale, ((0, 0), (0, 0), (0, pad), (0, 0)))
        nb = (S + pad) // bs
        def blk(x, d):
            return jnp.moveaxis(x.reshape(B, K, nb, bs, d), 2, 0)

        kb, vb = blk(k, D), blk(v, D)
        ksb = blk(k_scale, 1) if k_scale is not None else jnp.zeros((nb,))
        vsb = blk(v_scale, 1) if v_scale is not None else jnp.zeros((nb,))
    qf = (q.astype(jnp.float32) * scale).reshape(B, K, G, D)
    NEG = jnp.float32(-1e30)

    def body(carry, xs):
        m, denom, acc = carry
        kblk, vblk, ksblk, vsblk, bidx = xs
        kf = kblk.astype(jnp.float32)
        vf = vblk.astype(jnp.float32)
        if k_scale is not None:  # dequantize the cache block at use
            kf = kf * ksblk
            vf = vf * vsblk
        s = jnp.einsum("bkgd,bksd->bkgs", qf, kf)
        # absolute positions of this block's rows (paged pools shift by the
        # shard offset; the gathered page's rows stay block-contiguous)
        idx = pos_offset + bidx * bs + jnp.arange(bs)[None, :]
        mask = (idx < pos_offset + S) & (idx <= position[:, None])
        if window:
            mask &= idx > position[:, None] - window
        mask = mask[:, None, None, :]  # (B, 1, 1, bs)
        s = jnp.where(mask, s, NEG)
        m_new = jnp.maximum(m, s.max(axis=-1))
        p = jnp.where(mask, jnp.exp(s - m_new[..., None]), 0.0)
        corr = jnp.exp(m - m_new)
        denom = denom * corr + p.sum(axis=-1)
        acc = acc * corr[..., None] + jnp.einsum("bkgs,bksd->bkgd", p, vf)
        return (m_new, denom, acc), None

    m0 = jnp.full((B, K, G), NEG)
    l0 = jnp.zeros((B, K, G))
    acc0 = jnp.zeros((B, K, G, D))
    if registry.unroll_inner_enabled() and not paged:
        carry = (m0, l0, acc0)
        for i in range(nb):
            carry, _ = body(
                carry, (kb[i], vb[i], ksb[i], vsb[i], jnp.int32(i))
            )
        m, denom, acc = carry
    else:
        (m, denom, acc), _ = jax.lax.scan(
            body, (m0, l0, acc0), (kb, vb, ksb, vsb, jnp.arange(nb))
        )
    o = acc / jnp.maximum(denom, 1e-30)[..., None]
    o = o.reshape(B, H, D).astype(q.dtype)
    if not return_lse:
        return o
    lse = (m + jnp.log(jnp.maximum(denom, 1e-30))).reshape(B, H)
    return o, lse


# ---------------------------------------------------------------------------
# Chunked linear attention with data-dependent decay (RWKV6 / SSD)
# ---------------------------------------------------------------------------


def linear_attention_xla(r, k, v, w_log, u=None, s0=None, *, chunk=None):
    chunk = registry.resolve_blocks("linear_attention", chunk=chunk)["chunk"]
    B, H, T, N = r.shape
    M = v.shape[-1]
    pad = (-T) % chunk
    if pad:
        def zr(x):
            return jnp.pad(x, ((0, 0), (0, 0), (0, pad), (0, 0)))

        r, k, v, w_log = zr(r), zr(k), zr(v), zr(w_log)
    Tp = T + pad
    nc = Tp // chunk
    ssd = u is None

    # (nc, B, H, C, ...) for scan over chunks
    def cs(x):
        return jnp.moveaxis(
            x.astype(jnp.float32).reshape(B, H, nc, chunk, -1), 2, 0
        )
    rc, kc, vc, wc = cs(r), cs(k), cs(v), cs(w_log)

    def body(S, xs):
        rch, kch, vch, wch = xs  # (B,H,C,N|M)
        inc = jnp.cumsum(wch, axis=2)  # inclusive log-decay (B,H,C,N)
        exc = inc - wch  # exclusive
        e = inc if ssd else exc
        total = inc[:, :, -1:, :]  # (B,H,1,N)
        # inter-chunk: o_t += (r_t * exp(e_t)) @ S_in
        r_dec = rch * jnp.exp(e)
        o = jnp.einsum("bhcn,bhnm->bhcm", r_dec, S)
        # intra-chunk: coeff[t,s] = exp(e_t)*exp(-inc_s) for s<t (ssd: s<=t;
        # coeff<=1 overall; factors bounded: chunk*|W_LOG_FLOOR| < log(f32max))
        k_dec = kch * jnp.exp(-inc)
        scores = jnp.einsum("bhtn,bhsn->bhts", r_dec, k_dec)
        t_idx = jnp.arange(chunk)
        mask = (
            t_idx[:, None] >= t_idx[None, :]
            if ssd
            else t_idx[:, None] > t_idx[None, :]
        )
        scores = jnp.where(mask, scores, 0.0)
        o = o + jnp.einsum("bhts,bhsm->bhtm", scores, vch)
        if not ssd:  # rwkv diagonal bonus
            o = o + jnp.einsum("bhcn,bhcn,bhcm->bhcm", rch, u[None, :, None] * kch, vch)
        # state update: S_out = exp(total) * S_in + sum_s exp(total-inc_s) k_s v_s
        k_tail = kch * jnp.exp(total - inc)
        S = jnp.exp(total)[..., 0, :, None] * S + jnp.einsum(
            "bhsn,bhsm->bhnm", k_tail, vch
        )
        return S, o

    S0 = (
        s0.astype(jnp.float32)
        if s0 is not None
        else jnp.zeros((B, H, N, M), jnp.float32)
    )
    if registry.unroll_inner_enabled():
        S, os_ = S0, []
        for i in range(nc):
            S, oi = body(S, (rc[i], kc[i], vc[i], wc[i]))
            os_.append(oi)
        o = jnp.stack(os_, 0)
    else:
        S, o = jax.lax.scan(body, S0, (rc, kc, vc, wc))
    o = jnp.moveaxis(o, 0, 2).reshape(B, H, Tp, M)[:, :, :T]
    return o.astype(v.dtype), S


# ---------------------------------------------------------------------------
# BSR SpMM / SpMSpM blocked forms
# ---------------------------------------------------------------------------


def bsr_spmm_xla(tile_values, tile_rows, tile_cols, dense, num_rows):
    """Scatter-accumulate the per-tile matmuls (same tile economy as the
    StreamProgram: compute scales with nnz blocks only)."""
    T, bm, bk = tile_values.shape
    gathered = jax.vmap(
        lambda c: jax.lax.dynamic_slice_in_dim(dense, c * bk, bk, axis=0)
    )(tile_cols)
    prods = jnp.einsum(
        "tmk,tkf->tmf",
        tile_values.astype(jnp.float32),
        gathered.astype(jnp.float32),
    )
    out = jnp.zeros((num_rows // bm, bm, dense.shape[1]), jnp.float32)
    out = out.at[tile_rows].add(prods)
    return out.reshape(num_rows, dense.shape[1])


def spmspm_xla(a_values, a_cols, b_values, b_rows, contraction_dim):
    """One-side-densified intersection (blocked gather; representative of
    the kernel's VMEM bitmap intersect)."""
    R = a_values.shape[0]
    a_dense = jnp.zeros((R, contraction_dim), jnp.float32)
    a_dense = a_dense.at[jnp.arange(R)[:, None], a_cols].add(
        a_values.astype(jnp.float32)
    )
    gathered = a_dense[:, b_rows]  # (R, C, Lb)
    return jnp.einsum("cj,rcj->rc", b_values.astype(jnp.float32), gathered)
