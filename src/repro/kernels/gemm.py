"""Tiled multi-precision GEMM kernel (paper Fig. 9a / Fig. 10).

The (grid, BlockSpec) pair is the TPU analogue of the paper's 4D affine SU
streams: three grid loops (M, N, K tiles) + the MXU's internal unroll mirror
the GEMM mapping described in Sec. II-A. Accumulation is *expanding* (fp8/bf16
inputs, fp32 accumulator) like the paper's EXP sum-dot-product kernels; the
Pallas pipeline double-buffers HBM->VMEM tile copies exactly as the cluster
DMA double-buffers SPM tiles (C4).
"""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl
from jax.experimental.pallas import tpu as pltpu


def _gemm_kernel(a_ref, b_ref, o_ref, acc_ref, *, nk: int, out_dtype):
    @pl.when(pl.program_id(2) == 0)
    def _init():
        acc_ref[...] = jnp.zeros_like(acc_ref)

    acc_ref[...] += jnp.dot(
        a_ref[...], b_ref[...], preferred_element_type=acc_ref.dtype
    )

    @pl.when(pl.program_id(2) == nk - 1)
    def _flush():
        o_ref[...] = acc_ref[...].astype(o_ref.dtype)


def gemm_pallas(
    a: jax.Array,  # (M, K)
    b: jax.Array,  # (K, N)
    *,
    out_dtype=None,
    accum_dtype=jnp.float32,
    bm: int = 256,
    bk: int = 256,
    bn: int = 256,
    interpret: bool = False,
) -> jax.Array:
    M, K = a.shape
    K2, N = b.shape
    assert K == K2
    out_dtype = out_dtype or a.dtype
    bm, bk, bn = min(bm, M), min(bk, K), min(bn, N)

    pm, pk, pn = (-M) % bm, (-K) % bk, (-N) % bn
    if pm or pk:
        a = jnp.pad(a, ((0, pm), (0, pk)))
    if pk or pn:
        b = jnp.pad(b, ((0, pk), (0, pn)))
    Mp, Kp, Np = M + pm, K + pk, N + pn
    nk = Kp // bk

    out = pl.pallas_call(
        functools.partial(_gemm_kernel, nk=nk, out_dtype=out_dtype),
        grid=(Mp // bm, Np // bn, nk),
        in_specs=[
            pl.BlockSpec((bm, bk), lambda i, j, k: (i, k)),
            pl.BlockSpec((bk, bn), lambda i, j, k: (k, j)),
        ],
        out_specs=pl.BlockSpec((bm, bn), lambda i, j, k: (i, j)),
        out_shape=jax.ShapeDtypeStruct((Mp, Np), out_dtype),
        scratch_shapes=[pltpu.VMEM((bm, bn), accum_dtype)],
        compiler_params=pltpu.CompilerParams(
            dimension_semantics=("parallel", "parallel", "arbitrary")
        ),
        interpret=interpret,
    )(a, b)
    return out[:M, :N]
