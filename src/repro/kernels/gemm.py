"""Tiled multi-precision GEMM kernel (paper Fig. 9a / Fig. 10).

The StreamProgram's three affine streams are the TPU analogue of the paper's
4D affine SU streams: three grid loops (M, N, K tiles) + the MXU's internal
unroll mirror the GEMM mapping described in Sec. II-A. Accumulation is
*expanding* (fp8/bf16 inputs, fp32 accumulator) like the paper's EXP
sum-dot-product kernels; the Pallas pipeline double-buffers HBM->VMEM tile
copies exactly as the cluster DMA double-buffers SPM tiles (C4).
"""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl
from jax.experimental.pallas import tpu as pltpu

from repro.core.streams import AffineStream, StreamProgram, stream_compute
from repro.kernels.registry import resolve_blocks


def _gemm_kernel(a_ref, b_ref, o_ref, acc_ref, *, nk: int):
    @pl.when(pl.program_id(2) == 0)
    def _init():
        acc_ref[...] = jnp.zeros_like(acc_ref)

    acc_ref[...] += jnp.dot(
        a_ref[...], b_ref[...], preferred_element_type=acc_ref.dtype
    )

    @pl.when(pl.program_id(2) == nk - 1)
    def _flush():
        o_ref[...] = acc_ref[...].astype(o_ref.dtype)


def gemm_program(
    Mp: int, Np: int, Kp: int, bm: int, bn: int, bk: int,
    *, a_dtype, b_dtype, out_dtype, accum_dtype,
) -> StreamProgram:
    """GEMM as a stream program: the Fig. 4a loop nest, streams + body."""
    nk = Kp // bk
    return StreamProgram(
        name="gemm",
        body=functools.partial(_gemm_kernel, nk=nk),
        grid=(Mp // bm, Np // bn, nk),
        in_streams=(
            AffineStream((bm, bk), lambda i, j, k: (i, k), dtype=a_dtype),
            AffineStream((bk, bn), lambda i, j, k: (k, j), dtype=b_dtype),
        ),
        out_streams=(
            AffineStream((bm, bn), lambda i, j, k: (i, j), dtype=out_dtype),
        ),
        out_shapes=(jax.ShapeDtypeStruct((Mp, Np), out_dtype),),
        scratch=(pltpu.VMEM((bm, bn), accum_dtype),),
        dimension_semantics=("parallel", "parallel", "arbitrary"),
    )


def gemm_pallas(
    a: jax.Array,  # (M, K)
    b: jax.Array,  # (K, N)
    *,
    out_dtype=None,
    accum_dtype=jnp.float32,
    bm: int | None = None,
    bk: int | None = None,
    bn: int | None = None,
    interpret: bool = False,
) -> jax.Array:
    M, K = a.shape
    K2, N = b.shape
    assert K == K2
    out_dtype = out_dtype or a.dtype
    blocks = resolve_blocks("gemm", bm=bm, bk=bk, bn=bn)
    bm = min(blocks["bm"], M)
    bk = min(blocks["bk"], K)
    bn = min(blocks["bn"], N)

    pm, pk, pn = (-M) % bm, (-K) % bk, (-N) % bn
    if pm or pk:
        a = jnp.pad(a, ((0, pm), (0, pk)))
    if pk or pn:
        b = jnp.pad(b, ((0, pk), (0, pn)))
    Mp, Kp, Np = M + pm, K + pk, N + pn

    program = gemm_program(
        Mp, Np, Kp, bm, bn, bk,
        a_dtype=a.dtype, b_dtype=b.dtype, out_dtype=out_dtype,
        accum_dtype=accum_dtype,
    )
    out = stream_compute(program, a, b, interpret=interpret)
    return out[:M, :N]


def _gemm_scaled_kernel(
    a_ref, b_ref, as_ref, bs_ref, o_ref, acc_ref, *, nk: int
):
    @pl.when(pl.program_id(2) == 0)
    def _init():
        acc_ref[...] = jnp.zeros_like(acc_ref)

    # the narrow dot runs at compute-dtype MXU rate; per-block scales enter
    # the fp32 accumulator as a rank-1 outer product (bm,1) x (1,bn)
    part = jnp.dot(
        a_ref[...], b_ref[...], preferred_element_type=acc_ref.dtype
    )
    acc_ref[...] += part * (as_ref[...] * bs_ref[...])

    @pl.when(pl.program_id(2) == nk - 1)
    def _flush():
        o_ref[...] = acc_ref[...].astype(o_ref.dtype)


def gemm_scaled_program(
    Mp: int, Np: int, Kp: int, bm: int, bn: int, bk: int,
    *, compute_dtype, out_dtype, accum_dtype,
) -> StreamProgram:
    """Per-block scaled GEMM: the value streams carry the compute dtype and
    two extra fp32 streams carry one scale per (row, K-block) of A and per
    (K-block, col) of B — Occamy's narrow-operand path with the widening
    accumulator holding the rescale."""
    nk = Kp // bk
    return StreamProgram(
        name="gemm_scaled",
        body=functools.partial(_gemm_scaled_kernel, nk=nk),
        grid=(Mp // bm, Np // bn, nk),
        in_streams=(
            AffineStream((bm, bk), lambda i, j, k: (i, k),
                         dtype=compute_dtype),
            AffineStream((bk, bn), lambda i, j, k: (k, j),
                         dtype=compute_dtype),
            AffineStream((bm, 1), lambda i, j, k: (i, k),
                         dtype=jnp.float32),
            AffineStream((1, bn), lambda i, j, k: (k, j),
                         dtype=jnp.float32),
        ),
        out_streams=(
            AffineStream((bm, bn), lambda i, j, k: (i, j), dtype=out_dtype),
        ),
        out_shapes=(jax.ShapeDtypeStruct((Mp, Np), out_dtype),),
        scratch=(pltpu.VMEM((bm, bn), accum_dtype),),
        dimension_semantics=("parallel", "parallel", "arbitrary"),
    )


def gemm_scaled_pallas(
    a: jax.Array,  # (M, K)
    b: jax.Array,  # (K, N)
    precision,
    *,
    out_dtype=None,
    accum_dtype=jnp.float32,
    bm: int | None = None,
    bk: int | None = None,
    bn: int | None = None,
    interpret: bool = False,
) -> jax.Array:
    """Low-precision GEMM: quantize per K-block of size ``bk`` (so one
    scale covers exactly one streamed tile), run the scaled StreamProgram,
    accumulate fp32."""
    from repro.core import precision as prec

    p = prec.resolve(precision)
    M, K = a.shape
    K2, N = b.shape
    assert K == K2
    out_dtype = out_dtype or jnp.float32
    blocks = resolve_blocks("gemm", bm=bm, bk=bk, bn=bn)
    bm = min(blocks["bm"], M)
    bk = min(blocks["bk"], K)
    bn = min(blocks["bn"], N)

    pm, pk, pn = (-M) % bm, (-K) % bk, (-N) % bn
    if pm or pk:
        a = jnp.pad(a, ((0, pm), (0, pk)))
    if pk or pn:
        b = jnp.pad(b, ((0, pk), (0, pn)))
    Mp, Kp, Np = M + pm, K + pk, N + pn

    # quantize after padding: Kp % bk == 0 so scale blocks align with tiles
    aq, a_scale = prec.quantize_blockwise(a, p, axis=1, block=bk)
    bq, b_scale = prec.quantize_blockwise(b, p, axis=0, block=bk)

    program = gemm_scaled_program(
        Mp, Np, Kp, bm, bn, bk,
        compute_dtype=p.compute_dtype, out_dtype=out_dtype,
        accum_dtype=accum_dtype,
    )
    out = stream_compute(
        program, aq, bq, a_scale, b_scale, interpret=interpret
    )
    return out[:M, :N]
