"""Tiled multi-precision GEMM kernel (paper Fig. 9a / Fig. 10).

The StreamProgram's three affine streams are the TPU analogue of the paper's
4D affine SU streams: three grid loops (M, N, K tiles) + the MXU's internal
unroll mirror the GEMM mapping described in Sec. II-A. Accumulation is
*expanding* (fp8/bf16 inputs, fp32 accumulator) like the paper's EXP
sum-dot-product kernels; the Pallas pipeline double-buffers HBM->VMEM tile
copies exactly as the cluster DMA double-buffers SPM tiles (C4).
"""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl
from jax.experimental.pallas import tpu as pltpu

from repro.core.streams import AffineStream, StreamProgram, stream_compute
from repro.kernels.registry import resolve_blocks


def _gemm_kernel(a_ref, b_ref, o_ref, acc_ref, *, nk: int):
    @pl.when(pl.program_id(2) == 0)
    def _init():
        acc_ref[...] = jnp.zeros_like(acc_ref)

    acc_ref[...] += jnp.dot(
        a_ref[...], b_ref[...], preferred_element_type=acc_ref.dtype
    )

    @pl.when(pl.program_id(2) == nk - 1)
    def _flush():
        o_ref[...] = acc_ref[...].astype(o_ref.dtype)


def gemm_program(
    Mp: int, Np: int, Kp: int, bm: int, bn: int, bk: int,
    *, a_dtype, b_dtype, out_dtype, accum_dtype,
) -> StreamProgram:
    """GEMM as a stream program: the Fig. 4a loop nest, streams + body."""
    nk = Kp // bk
    return StreamProgram(
        name="gemm",
        body=functools.partial(_gemm_kernel, nk=nk),
        grid=(Mp // bm, Np // bn, nk),
        in_streams=(
            AffineStream((bm, bk), lambda i, j, k: (i, k), dtype=a_dtype),
            AffineStream((bk, bn), lambda i, j, k: (k, j), dtype=b_dtype),
        ),
        out_streams=(
            AffineStream((bm, bn), lambda i, j, k: (i, j), dtype=out_dtype),
        ),
        out_shapes=(jax.ShapeDtypeStruct((Mp, Np), out_dtype),),
        scratch=(pltpu.VMEM((bm, bn), accum_dtype),),
        dimension_semantics=("parallel", "parallel", "arbitrary"),
    )


def gemm_pallas(
    a: jax.Array,  # (M, K)
    b: jax.Array,  # (K, N)
    *,
    out_dtype=None,
    accum_dtype=jnp.float32,
    bm: int | None = None,
    bk: int | None = None,
    bn: int | None = None,
    interpret: bool = False,
) -> jax.Array:
    M, K = a.shape
    K2, N = b.shape
    assert K == K2
    out_dtype = out_dtype or a.dtype
    blocks = resolve_blocks("gemm", bm=bm, bk=bk, bn=bn)
    bm = min(blocks["bm"], M)
    bk = min(blocks["bk"], K)
    bn = min(blocks["bn"], N)

    pm, pk, pn = (-M) % bm, (-K) % bk, (-N) % bn
    if pm or pk:
        a = jnp.pad(a, ((0, pm), (0, pk)))
    if pk or pn:
        b = jnp.pad(b, ((0, pk), (0, pn)))
    Mp, Kp, Np = M + pm, K + pk, N + pn

    program = gemm_program(
        Mp, Np, Kp, bm, bn, bk,
        a_dtype=a.dtype, b_dtype=b.dtype, out_dtype=out_dtype,
        accum_dtype=accum_dtype,
    )
    out = stream_compute(program, a, b, interpret=interpret)
    return out[:M, :N]
