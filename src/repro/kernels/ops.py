"""Jit-ready kernel entry points with implementation dispatch.

Each op has up to four implementations:
  - ``pallas``:    the TPU kernel (pl.pallas_call, explicit BlockSpec tiling)
  - ``interpret``: the same kernel body interpreted on CPU (tests)
  - ``xla``:       a blocked jnp implementation of the *same algorithm* —
                   lowering-representative (same FLOPs / memory behaviour), used
                   by the multi-pod dry-run where Pallas cannot lower on CPU
  - ``ref``:       the naive oracle from ref.py

Selection: ``impl=`` argument > ``REPRO_KERNEL_IMPL`` env var > auto
(pallas on TPU backends, xla elsewhere).
"""
from __future__ import annotations

import functools
import os

import jax
import jax.numpy as jnp
import numpy as np

from repro.kernels import ref as _ref

_VALID = ("auto", "pallas", "interpret", "xla", "ref")
_default_impl = None  # process-wide override set by set_default_impl()

# When True, the xla paths replace their inner lax.scan (KV blocks / decay
# chunks) with python loops. XLA's HloCostAnalysis counts while-loop bodies
# ONCE regardless of trip count, so roofline-term extraction (launch/dryrun)
# traces small unrolled variants to get true FLOP/byte/collective counts.
_UNROLL_INNER = False


class unrolled_inner:
    def __enter__(self):
        global _UNROLL_INNER
        self._old, _UNROLL_INNER = _UNROLL_INNER, True
        return self

    def __exit__(self, *a):
        global _UNROLL_INNER
        _UNROLL_INNER = self._old


def set_default_impl(impl: str | None) -> None:
    global _default_impl
    assert impl is None or impl in _VALID, impl
    _default_impl = impl


def resolve_impl(impl: str | None = None) -> str:
    impl = impl or _default_impl or os.environ.get("REPRO_KERNEL_IMPL", "auto")
    assert impl in _VALID, impl
    if impl == "auto":
        return "pallas" if jax.default_backend() == "tpu" else "xla"
    return impl


# ---------------------------------------------------------------------------
# GEMM
# ---------------------------------------------------------------------------


def gemm(a, b, *, out_dtype=None, accum_dtype=jnp.float32, impl=None):
    impl = resolve_impl(impl)
    if impl in ("pallas", "interpret"):
        from repro.kernels import gemm as _gemm

        return _gemm.gemm_pallas(
            a, b, out_dtype=out_dtype, accum_dtype=accum_dtype,
            interpret=impl == "interpret",
        )
    return _ref.gemm_ref(a, b, out_dtype=out_dtype, accum_dtype=accum_dtype)


# ---------------------------------------------------------------------------
# FlashAttention-2 (forward) — paper Sec. V-C
# ---------------------------------------------------------------------------


def flash_attention(
    q, k, v, *, causal=True, window=0, q_offset=0, scale=None, impl=None,
    block_k=512,
):
    """q: (B,H,Sq,D); k,v: (B,K,Sk,D). Returns (B,H,Sq,D)."""
    impl = resolve_impl(impl)
    if impl in ("pallas", "interpret"):
        from repro.kernels import flash_attention as _fa

        return _fa.flash_attention_pallas(
            q, k, v, causal=causal, window=window, q_offset=q_offset,
            scale=scale, interpret=impl == "interpret",
        )
    if impl == "ref":
        return _ref.mha_ref(
            q, k, v, causal=causal, window=window, q_offset=q_offset, scale=scale
        )
    return _flash_attention_xla(
        q, k, v, causal=causal, window=window, q_offset=q_offset, scale=scale,
        block_k=block_k,
    )


def _flash_attention_xla(q, k, v, *, causal, window, q_offset, scale, block_k):
    """Online-softmax over KV blocks (FlashAttention-2 dataflow in jnp).

    Memory is O(Sq * block_k) per head instead of O(Sq * Sk): this is the
    C4 double-buffered-tile structure the paper uses, expressed as a scan.
    """
    B, H, Sq, D = q.shape
    K, Sk = k.shape[1], k.shape[2]
    G = H // K
    scale = scale if scale is not None else 1.0 / np.sqrt(D)
    if _UNROLL_INNER:
        # q-blocked form with STATIC skipping of fully-masked (q, kv) block
        # pairs — cost-representative of the Pallas kernel's pl.when skips
        # (causal halves attention FLOPs; sliding windows keep only a band)
        return _flash_attention_xla_unrolled(
            q, k, v, causal=causal, window=window, q_offset=q_offset,
            scale=scale,
        )
    block_k = min(block_k, Sk)
    pad = (-Sk) % block_k
    if pad:
        k = jnp.pad(k, ((0, 0), (0, 0), (0, pad), (0, 0)))
        v = jnp.pad(v, ((0, 0), (0, 0), (0, pad), (0, 0)))
    nb = (Sk + pad) // block_k

    qf = (q.astype(jnp.float32) * scale).reshape(B, K, G, Sq, D)
    kb = jnp.moveaxis(k.reshape(B, K, nb, block_k, D), 2, 0)
    vb = jnp.moveaxis(v.reshape(B, K, nb, block_k, D), 2, 0)
    q_pos = jnp.arange(Sq) + q_offset  # absolute positions

    NEG = jnp.float32(-1e30)

    def body(carry, xs):
        m, l, acc = carry
        kblk, vblk, bidx = xs
        s = jnp.einsum("bkgqd,bksd->bkgqs", qf, kblk.astype(jnp.float32))
        k_pos = bidx * block_k + jnp.arange(block_k)
        mask = k_pos[None, :] < Sk
        if causal:
            mask &= k_pos[None, :] <= q_pos[:, None]
        if window:
            mask &= k_pos[None, :] > q_pos[:, None] - window
        s = jnp.where(mask, s, NEG)
        m_new = jnp.maximum(m, s.max(axis=-1))
        # fully-masked rows: exp(NEG - NEG) == 1, so zero by mask explicitly
        p = jnp.where(mask, jnp.exp(s - m_new[..., None]), 0.0)
        corr = jnp.exp(m - m_new)
        l = l * corr + p.sum(axis=-1)
        acc = acc * corr[..., None] + jnp.einsum(
            "bkgqs,bksd->bkgqd", p, vblk.astype(jnp.float32)
        )
        return (m_new, l, acc), None

    m0 = jnp.full((B, K, G, Sq), NEG)
    l0 = jnp.zeros((B, K, G, Sq))
    acc0 = jnp.zeros((B, K, G, Sq, D))
    (m, l, acc), _ = jax.lax.scan(
        body, (m0, l0, acc0), (kb, vb, jnp.arange(nb))
    )
    o = acc / jnp.maximum(l, 1e-30)[..., None]
    return o.reshape(B, H, Sq, D).astype(q.dtype)


def _flash_attention_xla_unrolled(q, k, v, *, causal, window, q_offset, scale):
    B, H, Sq, D = q.shape
    K, Sk = k.shape[1], k.shape[2]
    G = H // K
    NEG = jnp.float32(-1e30)
    grid = int(os.environ.get("REPRO_UNROLL_GRID", "8"))
    bq = min(Sq, max(-(-Sq // grid), 128))
    bk = min(Sk, max(-(-Sk // grid), 128))
    pq, pk = (-Sq) % bq, (-Sk) % bk
    if pq:
        q = jnp.pad(q, ((0, 0), (0, 0), (0, pq), (0, 0)))
    if pk:
        k = jnp.pad(k, ((0, 0), (0, 0), (0, pk), (0, 0)))
        v = jnp.pad(v, ((0, 0), (0, 0), (0, pk), (0, 0)))
    nq, nk = (Sq + pq) // bq, (Sk + pk) // bk
    qf = (q.astype(jnp.float32) * scale).reshape(B, K, G, nq, bq, D)

    outs = []
    for i in range(nq):
        qi = qf[:, :, :, i]  # (B,K,G,bq,D)
        q_lo, q_hi = q_offset + i * bq, q_offset + (i + 1) * bq - 1
        m = jnp.full((B, K, G, bq), NEG)
        l = jnp.zeros((B, K, G, bq))
        acc = jnp.zeros((B, K, G, bq, D))
        for j in range(nk):
            k_lo, k_hi = j * bk, (j + 1) * bk - 1
            if causal and k_lo > q_hi:
                continue  # static skip: above the diagonal
            if window and k_hi <= q_lo - window:
                continue  # static skip: older than every row's window
            kj = k[:, :, j * bk : (j + 1) * bk].astype(jnp.float32)
            vj = v[:, :, j * bk : (j + 1) * bk].astype(jnp.float32)
            s = jnp.einsum("bkgqd,bksd->bkgqs", qi, kj)
            q_pos = q_lo + jnp.arange(bq)[:, None]
            k_pos = k_lo + jnp.arange(bk)[None, :]
            mask = k_pos < Sk
            if causal:
                mask &= k_pos <= q_pos
            if window:
                mask &= k_pos > q_pos - window
            s = jnp.where(mask, s, NEG)
            m_new = jnp.maximum(m, s.max(axis=-1))
            p = jnp.where(mask, jnp.exp(s - m_new[..., None]), 0.0)
            corr = jnp.exp(m - m_new)
            l = l * corr + p.sum(axis=-1)
            acc = acc * corr[..., None] + jnp.einsum("bkgqs,bksd->bkgqd", p, vj)
            m = m_new
        outs.append(acc / jnp.maximum(l, 1e-30)[..., None])
    o = jnp.concatenate(outs, axis=3).reshape(B, H, Sq + pq, D)[:, :, :Sq]
    return o.astype(q.dtype)


def decode_attention(q, k, v, position, *, window=0, scale=None, impl=None):
    """Single-token attention against a cache. Linear in cache length."""
    impl = resolve_impl(impl)
    # Decode is memory-bound and already linear; the xla form IS the ref form.
    return _ref.decode_attention_ref(
        q, k, v, position, window=window, scale=scale
    )


# ---------------------------------------------------------------------------
# Chunked linear attention with data-dependent decay (RWKV6 / SSD)
# ---------------------------------------------------------------------------

W_LOG_FLOOR = -2.5  # per-token decay floor: exp over a 32-chunk stays in fp32
LIN_CHUNK = 32


def linear_attention(r, k, v, w_log, u=None, s0=None, *, impl=None, chunk=LIN_CHUNK):
    """Chunked scan: S_t = diag(exp(w_t)) S_{t-1} + k_t v_t^T.

    u given  => RWKV6 read-out (o_t from S_{t-1} plus u-bonus for token t)
    u None   => SSD/Mamba read-out (o_t from S_t)
    Returns (o (B,H,T,M), S_final (B,H,N,M)).
    """
    impl = resolve_impl(impl)
    w_log = jnp.maximum(w_log, W_LOG_FLOOR)
    if impl == "ref":
        return _ref.linear_attention_scan_ref(r, k, v, w_log, u, s0)
    if impl in ("pallas", "interpret"):
        from repro.kernels import rwkv6 as _rwkv

        return _rwkv.linear_attention_pallas(
            r, k, v, w_log, u, s0, chunk=chunk, interpret=impl == "interpret"
        )
    return _linear_attention_xla(r, k, v, w_log, u, s0, chunk)


def _linear_attention_xla(r, k, v, w_log, u, s0, chunk):
    B, H, T, N = r.shape
    M = v.shape[-1]
    pad = (-T) % chunk
    if pad:
        zr = lambda x: jnp.pad(x, ((0, 0), (0, 0), (0, pad), (0, 0)))
        r, k, v, w_log = zr(r), zr(k), zr(v), zr(w_log)
    Tp = T + pad
    nc = Tp // chunk
    ssd = u is None

    # (nc, B, H, C, ...) for scan over chunks
    cs = lambda x: jnp.moveaxis(
        x.astype(jnp.float32).reshape(B, H, nc, chunk, -1), 2, 0
    )
    rc, kc, vc, wc = cs(r), cs(k), cs(v), cs(w_log)

    def body(S, xs):
        rch, kch, vch, wch = xs  # (B,H,C,N|M)
        inc = jnp.cumsum(wch, axis=2)  # inclusive log-decay (B,H,C,N)
        exc = inc - wch  # exclusive
        e = inc if ssd else exc
        total = inc[:, :, -1:, :]  # (B,H,1,N)
        # inter-chunk: o_t += (r_t * exp(e_t)) @ S_in
        r_dec = rch * jnp.exp(e)
        o = jnp.einsum("bhcn,bhnm->bhcm", r_dec, S)
        # intra-chunk: coeff[t,s] = exp(e_t)*exp(-inc_s) for s<t (ssd: s<=t;
        # coeff<=1 overall; factors bounded: chunk*|W_LOG_FLOOR| < log(f32max))
        k_dec = kch * jnp.exp(-inc)
        scores = jnp.einsum("bhtn,bhsn->bhts", r_dec, k_dec)
        t_idx = jnp.arange(chunk)
        mask = (
            t_idx[:, None] >= t_idx[None, :]
            if ssd
            else t_idx[:, None] > t_idx[None, :]
        )
        scores = jnp.where(mask, scores, 0.0)
        o = o + jnp.einsum("bhts,bhsm->bhtm", scores, vch)
        if not ssd:  # rwkv diagonal bonus
            o = o + jnp.einsum("bhcn,bhcn,bhcm->bhcm", rch, u[None, :, None] * kch, vch)
        # state update: S_out = exp(total) * S_in + sum_s exp(total-inc_s) k_s v_s
        k_tail = kch * jnp.exp(total - inc)
        S = jnp.exp(total)[..., 0, :, None] * S + jnp.einsum(
            "bhsn,bhsm->bhnm", k_tail, vch
        )
        return S, o

    S0 = (
        s0.astype(jnp.float32)
        if s0 is not None
        else jnp.zeros((B, H, N, M), jnp.float32)
    )
    if _UNROLL_INNER:
        S, os_ = S0, []
        for i in range(nc):
            S, oi = body(S, (rc[i], kc[i], vc[i], wc[i]))
            os_.append(oi)
        o = jnp.stack(os_, 0)
    else:
        S, o = jax.lax.scan(body, S0, (rc, kc, vc, wc))
    o = jnp.moveaxis(o, 0, 2).reshape(B, H, Tp, M)[:, :, :T]
    return o.astype(v.dtype), S


def linear_attention_step(r, k, v, w_log, u, S):
    """Single-token decode step. r,k: (B,H,N); v: (B,H,M); S: (B,H,N,M)."""
    w_log = jnp.maximum(w_log, W_LOG_FLOOR)
    rf, kf, vf, wf = (x.astype(jnp.float32) for x in (r, k, v, w_log))
    kv = jnp.einsum("bhn,bhm->bhnm", kf, vf)
    S_new = jnp.exp(wf)[..., None] * S + kv
    if u is None:
        o = jnp.einsum("bhn,bhnm->bhm", rf, S_new)
    else:
        o = jnp.einsum("bhn,bhnm->bhm", rf, S) + jnp.einsum(
            "bhn,bhn,bhm->bhm", rf, u[None] * kf, vf
        )
    return o.astype(v.dtype), S_new


# ---------------------------------------------------------------------------
# SpMM (sparse-dense, ELL value/index rows)
# ---------------------------------------------------------------------------


def spmm(values, cols, dense, *, impl=None):
    impl = resolve_impl(impl)
    if impl in ("pallas", "interpret"):
        from repro.kernels import spmm as _spmm

        return _spmm.spmm_pallas(
            values, cols, dense, interpret=impl == "interpret"
        )
    return _ref.spmm_ref(values, cols, dense)


def bsr_spmm(tile_values, tile_rows, tile_cols, dense, num_rows, *, impl=None):
    """Block-sparse rows x dense (the MXU-native sparse-dense form)."""
    impl = resolve_impl(impl)
    if impl in ("pallas", "interpret"):
        from repro.kernels import spmm as _spmm

        return _spmm.bsr_spmm_pallas(
            tile_values, tile_rows, tile_cols, dense, num_rows,
            interpret=impl == "interpret",
        )
    # xla / ref: scatter-accumulate the per-tile matmuls
    T, bm, bk = tile_values.shape
    gathered = jax.vmap(
        lambda c: jax.lax.dynamic_slice_in_dim(dense, c * bk, bk, axis=0)
    )(tile_cols)
    prods = jnp.einsum(
        "tmk,tkf->tmf",
        tile_values.astype(jnp.float32),
        gathered.astype(jnp.float32),
    )
    out = jnp.zeros((num_rows // bm, bm, dense.shape[1]), jnp.float32)
    out = out.at[tile_rows].add(prods)
    return out.reshape(num_rows, dense.shape[1])


# ---------------------------------------------------------------------------
# SpMSpM (sparse-sparse, index intersection)
# ---------------------------------------------------------------------------


def spmspm(a_values, a_cols, b_values, b_rows, contraction_dim, *, impl=None):
    impl = resolve_impl(impl)
    if impl in ("pallas", "interpret"):
        from repro.kernels import spmspm as _spmspm

        return _spmspm.spmspm_pallas(
            a_values, a_cols, b_values, b_rows, contraction_dim,
            interpret=impl == "interpret",
        )
    if impl == "ref":
        return _ref.spmspm_ref(a_values, a_cols, b_values, b_rows, contraction_dim)
    # xla: one-side-densified intersection (blocked gather; representative of
    # the kernel's VMEM bitmap intersect)
    R = a_values.shape[0]
    a_dense = jnp.zeros((R, contraction_dim), jnp.float32)
    a_dense = a_dense.at[jnp.arange(R)[:, None], a_cols].add(
        a_values.astype(jnp.float32)
    )
    gathered = jnp.moveaxis(a_dense[:, b_rows], 0, 0)  # (R, C, Lb)
    return jnp.einsum("cj,rcj->rc", b_values.astype(jnp.float32), gathered)


# ---------------------------------------------------------------------------
# Stencil (indirect offset streams, periodic boundary)
# ---------------------------------------------------------------------------


def stencil(grid, offsets: np.ndarray, weights, *, impl=None):
    impl = resolve_impl(impl)
    if impl in ("pallas", "interpret"):
        from repro.kernels import stencil as _stencil

        return _stencil.stencil_pallas(
            grid, offsets, weights, interpret=impl == "interpret"
        )
    return _ref.stencil_ref(grid, offsets, weights)
