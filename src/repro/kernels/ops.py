"""Jit-ready kernel entry points, dispatched through the kernel registry.

Public signatures are stable; every op resolves its implementation through
``repro.kernels.registry`` (explicit ``impl=`` arg > ``set_default_impl()`` >
``REPRO_KERNEL_IMPL`` env var > auto). The implementations themselves live in:

  - StreamProgram kernels (``pallas``/``interpret``): sibling kernel modules,
    executed through ``core.streams.stream_compute``
  - blocked jnp forms (``xla``): kernels/xla.py
  - naive oracles (``ref``): kernels/ref.py

Sparse ops additionally accept the pytree formats from ``core.sparse``
(EllMatrix / BsrMatrix) in place of their unpacked value/index arrays, so
sparse operands pass whole through ``jax.jit`` boundaries.

Block geometry resolves the same way for every op, in exactly one place:
``registry.resolve_blocks(op, **explicit)`` (explicit kwarg > autotuner/user
``set_block_override`` > static default). The dispatcher resolves once and
passes identical resolved sizes to whichever impl runs, so an explicit
``bk=`` and a ``set_block_override`` behave the same under pallas,
interpret, and xla alike — no impl carries its own block literal.

Partitioning is the third dispatch axis (kernels/partition.py): every op
accepts ``mesh=`` (or picks the mesh up from ``sharding.use_mesh``) and the
dispatcher resolves the op's PartitionRule once per call, wrapping whichever
registered impl runs in ``shard_map`` — same public signature, sharded
execution. On a multi-pod mesh plans resolve TWO-LEVEL, jointly over
``("pod", "model")`` with per-level collective epilogues (intra-pod psum
before the cross-pod D2D hop); indivisible shapes walk the replication
fallback ladder (drop the pod level, then replicate) instead of failing.
"""
from __future__ import annotations

import jax.numpy as jnp
import numpy as np

from repro.core.sparse import BsrMatrix, EllMatrix
from repro.kernels import ref as _ref
from repro.kernels import registry
from repro.kernels import xla as _xla
from repro.kernels.registry import (  # re-exported: the public dispatch API
    kernel_call,
    resolve_blocks,
    resolve_impl,
)
from repro.kernels.registry import set_default_impl  # noqa: F401  (re-export)


def _dispatch(op, *args, mesh=None, impl=None, **kwargs):
    """The one mesh-aware dispatch seam: explicit ``mesh=`` kwarg, else the
    ``sharding.use_mesh`` context, else plain single-device kernel_call.

    Plan-only schedule kwargs (``partition.PLAN_KWARGS``: overlap/zigzag/
    remote_copy) ride through to the partition layer and are stripped
    before any direct kernel_call — a single device has no ring to
    schedule."""
    from repro.kernels import partition

    if mesh is None:
        from repro.parallel import sharding as _sh

        mesh = _sh.kernel_mesh()
    if mesh is not None:
        return partition.sharded_call(op, mesh, *args, impl=impl, **kwargs)
    return kernel_call(op, *args, impl=impl,
                       **partition.strip_plan_kwargs(kwargs))

# roofline dry-run context (see registry.unroll_inner): kept under its
# historical name for callers that patched the old ops-level flag
unrolled_inner = registry.unroll_inner


# ---------------------------------------------------------------------------
# Dense GEMM (paper Fig. 9a / Fig. 10)
# ---------------------------------------------------------------------------


def gemm(a, b, *, out_dtype=None, accum_dtype=jnp.float32, precision=None,
         impl=None, mesh=None, bm=None, bk=None, bn=None):
    """C = A @ B with widening accumulation.

    ``precision`` selects a low-precision policy (``core.precision``): the
    operands are quantized per K-block to the policy's compute dtype, the
    narrow dot runs at the scaled MXU rate, and the per-block fp32 scales
    rescale inside the fp32 accumulator. ``None`` is the exact legacy
    full-precision path — byte-identical dispatch, no quantization."""
    precision = _resolve_precision(precision)
    blocks = resolve_blocks("gemm", bm=bm, bk=bk, bn=bn)
    return _dispatch(
        "gemm", a, b, out_dtype=out_dtype, accum_dtype=accum_dtype,
        mesh=mesh, impl=impl, **_precision_kwargs(precision), **blocks,
    )


def _resolve_precision(precision):
    if precision is None:
        return None
    from repro.core import precision as _prec

    return _prec.resolve(precision)


def _precision_kwargs(precision):
    # precision rides dispatch only when set, so impls and rules without a
    # scaled path never see the kwarg (the PLAN_KWARGS signature-filter
    # discipline) and the None path stays byte-identical to the legacy one
    return {} if precision is None else {"precision": precision}


@registry.register_stream_kernel("gemm")
def _gemm_stream(a, b, *, out_dtype=None, accum_dtype=jnp.float32,
                 precision=None, bm=None, bk=None, bn=None, interpret=False):
    from repro.kernels import gemm as _gemm

    if precision is not None:
        return _gemm.gemm_scaled_pallas(
            a, b, precision, out_dtype=out_dtype, accum_dtype=accum_dtype,
            bm=bm, bk=bk, bn=bn, interpret=interpret,
        )
    return _gemm.gemm_pallas(
        a, b, out_dtype=out_dtype, accum_dtype=accum_dtype,
        bm=bm, bk=bk, bn=bn, interpret=interpret,
    )


@registry.register_kernel("gemm", impl="xla")
def _gemm_xla(a, b, *, out_dtype=None, accum_dtype=jnp.float32,
              precision=None, bm=None, bk=None, bn=None):
    if precision is not None:
        return _xla.gemm_scaled_xla(
            a, b, precision, out_dtype=out_dtype, accum_dtype=accum_dtype,
            bm=bm, bk=bk, bn=bn,
        )
    return _ref.gemm_ref(a, b, out_dtype=out_dtype, accum_dtype=accum_dtype)


@registry.register_kernel("gemm", impl="ref")
def _gemm_ref(a, b, *, out_dtype=None, accum_dtype=jnp.float32,
              precision=None, bm=None, bk=None, bn=None):
    if precision is not None:
        return _ref.gemm_scaled_ref(
            a, b, precision, out_dtype=out_dtype, accum_dtype=accum_dtype,
            bk=bk,
        )
    return _ref.gemm_ref(a, b, out_dtype=out_dtype, accum_dtype=accum_dtype)


# ---------------------------------------------------------------------------
# FlashAttention-2 (forward) — paper Sec. V-C
# ---------------------------------------------------------------------------


def flash_attention(
    q, k, v, *, causal=True, window=0, q_offset=0, scale=None,
    precision=None, impl=None, mesh=None, bq=None, bk=None, block_k=None,
    return_lse=False, overlap=True, zigzag=True, remote_copy=False,
):
    """q: (B,H,Sq,D); k,v: (B,K,Sk,D). Returns (B,H,Sq,D).

    ``window > 0`` is a *lookback* window: each query attends to keys in
    ``(q_pos - window, q_pos]``, so a window bounds future positions even
    with ``causal=False`` (identical semantics across every impl).
    ``return_lse=True`` additionally returns the per-row log-sum-exp,
    (B,H,Sq) fp32 — the statistic the sequence-parallel ring merge
    (``parallel.collectives.online_softmax_merge``) consumes.

    ``overlap``/``zigzag``/``remote_copy`` are mesh-schedule knobs for the
    sequence-parallel KV ring (no-ops on a single device): ``overlap``
    double-buffers the hop transfers behind the hop kernels,
    ``zigzag`` load-balances causal Q ownership across head/tail chunks,
    ``remote_copy`` opts the hop into the pallas async-remote-copy path on
    TPU backends. ``overlap=False`` + ``zigzag=False`` is the synchronous
    contiguous-chunk oracle. Numerics are unchanged either way.

    ``precision`` quantizes q/k/v per row over D (fp8/bf16 values + fp32
    per-row scales); the scaled kernels dequantize inside the fp32 block
    compute, so only the operand streams narrow. Scaled attention always
    returns fp32.

    ``block_k`` is the historical spelling of ``bk``; both resolve through
    the registry, so an explicit argument and ``set_block_override`` reach
    the pallas and xla impls identically.
    """
    if block_k is not None:
        if bk is not None and bk != block_k:
            raise TypeError(
                f"flash_attention: bk={bk} and its alias block_k={block_k} disagree"
            )
        bk = block_k
    precision = _resolve_precision(precision)
    blocks = resolve_blocks("flash_attention", bq=bq, bk=bk)
    return _dispatch(
        "flash_attention", q, k, v, causal=causal, window=window,
        q_offset=q_offset, scale=scale, return_lse=return_lse, mesh=mesh,
        impl=impl, overlap=overlap, zigzag=zigzag, remote_copy=remote_copy,
        **_precision_kwargs(precision), **blocks,
    )


@registry.register_stream_kernel("flash_attention")
def _fa_stream(q, k, v, *, causal, window, q_offset, scale, precision=None,
               bq=None, bk=None, return_lse=False, interpret=False):
    from repro.kernels import flash_attention as _fa

    if precision is not None:
        return _fa.flash_attention_scaled_pallas(
            q, k, v, precision, causal=causal, window=window,
            q_offset=q_offset, scale=scale, bq=bq, bk=bk,
            return_lse=return_lse, interpret=interpret,
        )
    return _fa.flash_attention_pallas(
        q, k, v, causal=causal, window=window, q_offset=q_offset,
        scale=scale, bq=bq, bk=bk, return_lse=return_lse, interpret=interpret,
    )


@registry.register_kernel("flash_attention", impl="xla")
def _fa_xla(q, k, v, *, causal, window, q_offset, scale, precision=None,
            bq=None, bk=None, return_lse=False):
    if precision is not None:
        return _xla.flash_attention_scaled_xla(
            q, k, v, precision, causal=causal, window=window,
            q_offset=q_offset, scale=scale, bq=bq, bk=bk,
            return_lse=return_lse,
        )
    return _xla.flash_attention_xla(
        q, k, v, causal=causal, window=window, q_offset=q_offset,
        scale=scale, bq=bq, bk=bk, return_lse=return_lse,
    )


@registry.register_kernel("flash_attention", impl="ref")
def _fa_ref(q, k, v, *, causal, window, q_offset, scale, precision=None,
            bq=None, bk=None, return_lse=False):
    if precision is not None:
        return _ref.mha_scaled_ref(
            q, k, v, precision, causal=causal, window=window,
            q_offset=q_offset, scale=scale, return_lse=return_lse,
        )
    return _ref.mha_ref(
        q, k, v, causal=causal, window=window, q_offset=q_offset, scale=scale,
        return_lse=return_lse,
    )


def decode_attention(q, k, v, position, *, window=0, scale=None,
                     precision=None, impl=None, mesh=None, bs=None,
                     paged=False, block_table=None, k_scale=None,
                     v_scale=None, pos_offset=0, return_lse=False):
    """Single-token attention against a cache. Linear in cache length.

    ``precision`` holds the KV cache quantized — narrow values plus one
    fp32 scale per cached row (``core.precision.quantize_kv_cache``) —
    and dequantizes each streamed block at use: the serving path where the
    cache dominates HBM footprint and decode is purely memory-bound.

    ``paged=True`` switches k/v to the serving engine's block-pool layout:
    ``(P, K, bs, D)`` physical pages plus a ``(B, NB)`` int32
    ``block_table`` mapping each sequence's logical cache block to its
    pool slot (``serving.paged_cache``). The gathered pages stream through
    the same online-softmax body as the contiguous cache, so the two
    layouts are bitwise-equal at matching geometry; ``k_scale``/``v_scale``
    pass a pre-quantized pool's per-row fp32 scales. ``pos_offset`` is the
    absolute position of logical block 0 (nonzero for ring-decode cache
    shards) and ``return_lse=True`` adds the (B, H) fp32 log-sum-exp the
    per-shard ``online_softmax_merge`` fold consumes. All paged kwargs
    ride dispatch only when set, so the legacy contiguous path stays
    byte-identical."""
    precision = _resolve_precision(precision)
    if paged and block_table is None:
        raise TypeError("decode_attention: paged=True requires block_table")
    if block_table is not None and not paged:
        raise TypeError("decode_attention: block_table requires paged=True")
    if paged:
        if k.ndim != 4 or k.shape[:3] != v.shape[:3]:
            raise ValueError(
                f"decode_attention(paged): pools must be (P, K, bs, D), got "
                f"k={k.shape} v={v.shape}"
            )
        paged_kwargs = {"block_table": block_table}
        if k_scale is not None:
            paged_kwargs.update(k_scale=k_scale, v_scale=v_scale)
        blocks = {}  # the pool's page extent pins bs; no registry tile
    else:
        if k_scale is not None or v_scale is not None:
            raise TypeError(
                "decode_attention: k_scale/v_scale are pool scales for the "
                "paged path; the contiguous path quantizes via precision="
            )
        paged_kwargs = {}
        blocks = resolve_blocks("decode_attention", bs=bs)
    extra = {}
    # pos_offset may be a traced per-shard scalar; ride only when set so the
    # legacy kwarg surface (and its dispatch bytes) stay unchanged
    if not (isinstance(pos_offset, int) and pos_offset == 0):
        extra["pos_offset"] = pos_offset
    if return_lse:
        extra["return_lse"] = True
    return _dispatch(
        "decode_attention", q, k, v, position, window=window, scale=scale,
        mesh=mesh, impl=impl, **_precision_kwargs(precision),
        **paged_kwargs, **extra, **blocks,
    )


@registry.register_kernel("decode_attention", impl="xla")
def _decode_xla(q, k, v, position, *, window, scale, precision=None, bs=None,
                block_table=None, k_scale=None, v_scale=None, pos_offset=0,
                return_lse=False):
    return _xla.decode_attention_xla(
        q, k, v, position, window=window, scale=scale, bs=bs,
        precision=precision, block_table=block_table, k_scale=k_scale,
        v_scale=v_scale, pos_offset=pos_offset, return_lse=return_lse,
    )


# decode is memory-bound and already linear; the ref form stands in for the
# stream impls (the blocked xla form above carries the cache-tile geometry).
@registry.register_kernel("decode_attention", impl="pallas")
@registry.register_kernel("decode_attention", impl="interpret")
@registry.register_kernel("decode_attention", impl="ref")
def _decode_ref(q, k, v, position, *, window, scale, precision=None, bs=None,
                block_table=None, k_scale=None, v_scale=None, pos_offset=0,
                return_lse=False):
    if block_table is not None:
        return _ref.decode_attention_paged_ref(
            q, k, v, block_table, position, window=window, scale=scale,
            precision=precision, k_scale=k_scale, v_scale=v_scale,
            pos_offset=pos_offset, return_lse=return_lse,
        )
    if precision is not None:
        return _ref.decode_attention_scaled_ref(
            q, k, v, position, precision=precision, window=window,
            scale=scale, pos_offset=pos_offset, return_lse=return_lse,
        )
    return _ref.decode_attention_ref(q, k, v, position, window=window,
                                     scale=scale, pos_offset=pos_offset,
                                     return_lse=return_lse)


# ---------------------------------------------------------------------------
# Chunked linear attention with data-dependent decay (RWKV6 / SSD)
# ---------------------------------------------------------------------------

# per-token decay floor; the chunked kernels exponentiate at most
# chunk * |W_LOG_FLOOR| in one fp32 exp, so chunk is bounded by _MAX_CHUNK_EXP
# (log(f32max) ~= 88.7, kept with margin). The chunk default lives in
# registry.block_defaults("linear_attention").
W_LOG_FLOOR = -2.5
_MAX_CHUNK_EXP = 85.0


def linear_attention(r, k, v, w_log, u=None, s0=None, *, impl=None, mesh=None,
                     chunk=None):
    """Chunked scan: S_t = diag(exp(w_t)) S_{t-1} + k_t v_t^T.

    u given  => RWKV6 read-out (o_t from S_{t-1} plus u-bonus for token t)
    u None   => SSD/Mamba read-out (o_t from S_t)
    Returns (o (B,H,T,M), S_final (B,H,N,M)).
    """
    chunk = resolve_blocks("linear_attention", chunk=chunk)["chunk"]
    # ref runs the exact per-token scan and never exponentiates a chunk span
    if resolve_impl(impl) != "ref" and chunk * -W_LOG_FLOOR > _MAX_CHUNK_EXP:
        raise ValueError(
            f"chunk={chunk} overflows fp32: chunk * |W_LOG_FLOOR| = "
            f"{chunk * -W_LOG_FLOOR} must stay <= {_MAX_CHUNK_EXP} "
            f"(max chunk {int(_MAX_CHUNK_EXP / -W_LOG_FLOOR)})"
        )
    w_log = jnp.maximum(w_log, W_LOG_FLOOR)
    return _dispatch(
        "linear_attention", r, k, v, w_log, u, s0, chunk=chunk, mesh=mesh,
        impl=impl,
    )


@registry.register_stream_kernel("linear_attention")
def _la_stream(r, k, v, w_log, u, s0, *, chunk, interpret=False):
    from repro.kernels import rwkv6 as _rwkv

    return _rwkv.linear_attention_pallas(
        r, k, v, w_log, u, s0, chunk=chunk, interpret=interpret
    )


@registry.register_kernel("linear_attention", impl="xla")
def _la_xla(r, k, v, w_log, u, s0, *, chunk):
    return _xla.linear_attention_xla(r, k, v, w_log, u, s0, chunk=chunk)


@registry.register_kernel("linear_attention", impl="ref")
def _la_ref(r, k, v, w_log, u, s0, *, chunk=None):
    return _ref.linear_attention_scan_ref(r, k, v, w_log, u, s0)


def linear_attention_step(r, k, v, w_log, u, S):
    """Single-token decode step. r,k: (B,H,N); v: (B,H,M); S: (B,H,N,M)."""
    w_log = jnp.maximum(w_log, W_LOG_FLOOR)
    rf, kf, vf, wf = (x.astype(jnp.float32) for x in (r, k, v, w_log))
    kv = jnp.einsum("bhn,bhm->bhnm", kf, vf)
    S_new = jnp.exp(wf)[..., None] * S + kv
    if u is None:
        o = jnp.einsum("bhn,bhnm->bhm", rf, S_new)
    else:
        o = jnp.einsum("bhn,bhnm->bhm", rf, S) + jnp.einsum(
            "bhn,bhn,bhm->bhm", rf, u[None] * kf, vf
        )
    return o.astype(v.dtype), S_new


# ---------------------------------------------------------------------------
# SpMM (sparse-dense, ELL value/index rows)
# ---------------------------------------------------------------------------


def spmm(values, cols=None, dense=None, *, impl=None, mesh=None, bm=None):
    """ELL sparse-dense matmul. Either ``spmm(A, dense)`` with A an
    EllMatrix, or the unpacked ``spmm(values, cols, dense)``."""
    if isinstance(values, EllMatrix):
        if cols is not None and dense is not None:
            raise TypeError(
                "spmm(A, dense): extra operand alongside the EllMatrix form"
            )
        if dense is None:  # positional form: spmm(A, dense)
            dense = cols
        values, cols = values.values, values.cols
    if cols is None or dense is None:
        raise TypeError("spmm: cols and dense operands are required")
    blocks = resolve_blocks("spmm", bm=bm)
    return _dispatch("spmm", values, cols, dense, mesh=mesh, impl=impl,
                     **blocks)


@registry.register_stream_kernel("spmm")
def _spmm_stream(values, cols, dense, *, bm=None, interpret=False):
    from repro.kernels import spmm as _spmm

    return _spmm.spmm_pallas(values, cols, dense, bm=bm, interpret=interpret)


@registry.register_kernel("spmm", impl="xla")
@registry.register_kernel("spmm", impl="ref")
def _spmm_ref(values, cols, dense, *, bm=None):
    return _ref.spmm_ref(values, cols, dense)


def bsr_spmm(tile_values, tile_rows=None, tile_cols=None, dense=None,
             num_rows=None, *, impl=None, mesh=None, bf=None):
    """Block-sparse rows x dense (the MXU-native sparse-dense form).

    Either ``bsr_spmm(A, dense)`` with A a BsrMatrix, or the unpacked
    ``bsr_spmm(tile_values, tile_rows, tile_cols, dense, num_rows)``.
    """
    if isinstance(tile_values, BsrMatrix):
        A = tile_values
        if (tile_cols is not None or num_rows is not None
                or (tile_rows is not None and dense is not None)):
            raise TypeError(
                "bsr_spmm(A, dense): extra operands alongside the BsrMatrix form"
            )
        if dense is None:  # positional form: bsr_spmm(A, dense)
            dense = tile_rows
        tile_values, tile_rows, tile_cols = A.tile_values, A.tile_rows, A.tile_cols
        num_rows = A.shape[0]
    if tile_rows is None or tile_cols is None or dense is None or num_rows is None:
        raise TypeError(
            "bsr_spmm: tile coordinates, dense operand and num_rows are required"
        )
    blocks = resolve_blocks("bsr_spmm", bf=bf)
    return _dispatch(
        "bsr_spmm", tile_values, tile_rows, tile_cols, dense,
        num_rows=num_rows, mesh=mesh, impl=impl, **blocks,
    )


@registry.register_stream_kernel("bsr_spmm")
def _bsr_stream(tile_values, tile_rows, tile_cols, dense, num_rows,
                *, bf=None, interpret=False):
    from repro.kernels import spmm as _spmm

    return _spmm.bsr_spmm_pallas(
        tile_values, tile_rows, tile_cols, dense, num_rows, bf=bf,
        interpret=interpret,
    )


@registry.register_kernel("bsr_spmm", impl="xla")
@registry.register_kernel("bsr_spmm", impl="ref")
def _bsr_xla(tile_values, tile_rows, tile_cols, dense, num_rows, *, bf=None):
    return _xla.bsr_spmm_xla(tile_values, tile_rows, tile_cols, dense, num_rows)


# ---------------------------------------------------------------------------
# SpMSpM (sparse-sparse, index intersection)
# ---------------------------------------------------------------------------


def spmspm(a_values, a_cols, b_values=None, b_rows=None, contraction_dim=None,
           *, impl=None, mesh=None, bm=None, bn=None):
    """Sparse x sparse by index intersection. Either ``spmspm(A, B, k)`` with
    ELL operands (B holding the right matrix's columns), or unpacked arrays.
    """
    if isinstance(a_values, EllMatrix):
        A, B = a_values, a_cols
        if not isinstance(B, EllMatrix):
            raise TypeError("spmspm(A, B, k): B must also be an EllMatrix")
        if b_rows is not None or (b_values is not None
                                  and contraction_dim is not None):
            raise TypeError(
                "spmspm(A, B, k): extra operands alongside the EllMatrix form"
            )
        if b_values is not None:  # positional form: spmspm(A, B, k)
            contraction_dim = b_values
        a_values, a_cols = A.values, A.cols
        b_values, b_rows = B.values, B.cols
    if b_values is None or b_rows is None or contraction_dim is None:
        raise TypeError(
            "spmspm: b_values, b_rows and contraction_dim are required"
        )
    blocks = resolve_blocks("spmspm", bm=bm, bn=bn)
    return _dispatch(
        "spmspm", a_values, a_cols, b_values, b_rows,
        contraction_dim=contraction_dim, mesh=mesh, impl=impl, **blocks,
    )


@registry.register_stream_kernel("spmspm")
def _spmspm_stream(a_values, a_cols, b_values, b_rows, contraction_dim,
                   *, bm=None, bn=None, interpret=False):
    from repro.kernels import spmspm as _spmspm

    return _spmspm.spmspm_pallas(
        a_values, a_cols, b_values, b_rows, contraction_dim,
        bm=bm, bn=bn, interpret=interpret,
    )


@registry.register_kernel("spmspm", impl="xla")
def _spmspm_xla(a_values, a_cols, b_values, b_rows, contraction_dim,
                *, bm=None, bn=None):
    return _xla.spmspm_xla(a_values, a_cols, b_values, b_rows, contraction_dim)


@registry.register_kernel("spmspm", impl="ref")
def _spmspm_ref(a_values, a_cols, b_values, b_rows, contraction_dim,
                *, bm=None, bn=None):
    return _ref.spmspm_ref(a_values, a_cols, b_values, b_rows, contraction_dim)


# ---------------------------------------------------------------------------
# Stencil (indirect offset streams, periodic boundary)
# ---------------------------------------------------------------------------


def stencil(grid, offsets: np.ndarray, weights, *, impl=None, mesh=None,
            bx=None, overlap=True):
    """``overlap`` double-buffers the sharded halo exchange (interior rows
    compute while the boundary planes fly); ``overlap=False`` is the
    synchronous pad-then-kernel oracle. No-op on a single device."""
    blocks = resolve_blocks("stencil", bx=bx)
    return _dispatch("stencil", grid, offsets=offsets, weights=weights,
                     mesh=mesh, impl=impl, overlap=overlap, **blocks)


@registry.register_stream_kernel("stencil")
def _stencil_stream(grid, offsets, weights, *, bx=None, interpret=False):
    from repro.kernels import stencil as _stencil

    return _stencil.stencil_pallas(grid, offsets, weights, bx=bx,
                                   interpret=interpret)


@registry.register_kernel("stencil", impl="xla")
@registry.register_kernel("stencil", impl="ref")
def _stencil_ref(grid, offsets, weights, *, bx=None):
    return _ref.stencil_ref(grid, offsets, weights)
