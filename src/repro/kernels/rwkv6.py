"""Chunked linear-attention kernel with data-dependent decay (RWKV6 / SSD).

Implements S_t = diag(exp(w_t)) S_{t-1} + k_t v_t^T in chunks: the recurrent
state lives in VMEM scratch across the sequential chunk grid dimension (the
TPU analogue of Occamy keeping the accumulator resident in the FPU register
file while SUs stream operands). Intra-chunk work is two MXU matmuls; the
cumulative-decay cumsum is computed as a lower-triangular matmul so the whole
kernel is MXU-resident. Handles both the RWKV read-out (u-bonus, o_t from
S_{t-1}) and the SSD read-out (o_t from S_t).
"""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl
from jax.experimental.pallas import tpu as pltpu

from repro.core.streams import AffineStream, StreamProgram, stream_compute
from repro.kernels.registry import resolve_blocks


def _la_kernel(
    r_ref, k_ref, v_ref, w_ref, u_ref, s0_ref, o_ref, sout_ref, s_ref,
    *, ssd, nc, chunk,
):
    c = pl.program_id(1)

    @pl.when(c == 0)
    def _init():
        s_ref[...] = s0_ref[0].astype(jnp.float32)

    C = chunk
    r = r_ref[0].astype(jnp.float32)  # (C, N)
    k = k_ref[0].astype(jnp.float32)
    v = v_ref[0].astype(jnp.float32)  # (C, M)
    wl = w_ref[0].astype(jnp.float32)  # (C, N)

    # inclusive cumsum as lower-triangular matmul (MXU-resident)
    tri = (
        jax.lax.broadcasted_iota(jnp.int32, (C, C), 0)
        >= jax.lax.broadcasted_iota(jnp.int32, (C, C), 1)
    ).astype(jnp.float32)
    inc = jax.lax.dot(tri, wl, preferred_element_type=jnp.float32)
    exc = inc - wl
    e = inc if ssd else exc
    total = inc[-1:, :]  # (1, N)

    S = s_ref[...]
    r_dec = r * jnp.exp(e)
    o = jax.lax.dot(r_dec, S, preferred_element_type=jnp.float32)  # (C, M)

    k_dec = k * jnp.exp(-inc)
    scores = jax.lax.dot_general(
        r_dec, k_dec, (((1,), (1,)), ((), ())),
        preferred_element_type=jnp.float32,
    )  # (C, C)
    t_i = jax.lax.broadcasted_iota(jnp.int32, (C, C), 0)
    s_i = jax.lax.broadcasted_iota(jnp.int32, (C, C), 1)
    mask = (t_i >= s_i) if ssd else (t_i > s_i)
    scores = jnp.where(mask, scores, 0.0)
    o = o + jax.lax.dot(scores, v, preferred_element_type=jnp.float32)
    if not ssd:  # rwkv diagonal bonus
        u = u_ref[0].astype(jnp.float32)  # (1, N) broadcast row
        o = o + jnp.sum(r * u * k, axis=-1, keepdims=True) * v

    k_tail = k * jnp.exp(total - inc)
    s_new = jnp.exp(total).T * S + jax.lax.dot_general(
        k_tail, v, (((0,), (0,)), ((), ())), preferred_element_type=jnp.float32
    )
    s_ref[...] = s_new
    o_ref[0] = o.astype(o_ref.dtype)

    @pl.when(c == nc - 1)
    def _flush():
        sout_ref[0] = s_new


def linear_attention_program(
    BH, Tp, N, M, chunk, *, ssd, r_dtype, k_dtype, v_dtype, w_dtype, o_dtype
) -> StreamProgram:
    """Chunked decay scan as a stream program: r/k/v/w chunk streams advance
    with the sequential chunk grid; u and the initial state are resident."""
    nc = Tp // chunk
    def chunk_stream(w, dt):
        return AffineStream((1, chunk, w), lambda b, c: (b, c, 0), dtype=dt)

    def resident(shape, dt):
        return AffineStream(shape, lambda b, c: (b, 0, 0), dtype=dt)
    return StreamProgram(
        name="linear_attention",
        body=functools.partial(_la_kernel, ssd=ssd, nc=nc, chunk=chunk),
        grid=(BH, nc),
        in_streams=(
            chunk_stream(N, r_dtype),
            chunk_stream(N, k_dtype),
            chunk_stream(M, v_dtype),
            chunk_stream(N, w_dtype),
            resident((1, 1, N), jnp.float32),
            resident((1, N, M), jnp.float32),
        ),
        out_streams=(
            chunk_stream(M, o_dtype),
            resident((1, N, M), jnp.float32),
        ),
        out_shapes=(
            jax.ShapeDtypeStruct((BH, Tp, M), o_dtype),
            jax.ShapeDtypeStruct((BH, N, M), jnp.float32),
        ),
        scratch=(pltpu.VMEM((N, M), jnp.float32),),
        dimension_semantics=("arbitrary", "arbitrary"),
    )


def linear_attention_pallas(
    r, k, v, w_log, u=None, s0=None, *, chunk: int | None = None,
    interpret: bool = False
):
    """r,k,w_log: (B,H,T,N); v: (B,H,T,M); u: (H,N) or None; s0: (B,H,N,M)."""
    B, H, T, N = r.shape
    M = v.shape[-1]
    ssd = u is None
    chunk = resolve_blocks("linear_attention", chunk=chunk)["chunk"]
    pad = (-T) % chunk
    if pad:
        def zp(x):
            return jnp.pad(x, ((0, 0), (0, 0), (0, pad), (0, 0)))

        r, k, v, w_log = zp(r), zp(k), zp(v), zp(w_log)
    Tp = T + pad
    BH = B * H

    def flat(x):
        return x.reshape(BH, Tp, x.shape[-1])

    rf, kf, vf, wf = flat(r), flat(k), flat(v), flat(w_log)
    uf = (
        jnp.zeros((BH, 1, N), jnp.float32)
        if ssd
        else jnp.tile(u[None, :, None, :], (B, 1, 1, 1)).reshape(BH, 1, N)
    )
    s0f = (
        jnp.zeros((BH, N, M), jnp.float32)
        if s0 is None
        else s0.reshape(BH, N, M).astype(jnp.float32)
    )

    program = linear_attention_program(
        BH, Tp, N, M, chunk, ssd=ssd,
        r_dtype=rf.dtype, k_dtype=kf.dtype, v_dtype=vf.dtype, w_dtype=wf.dtype,
        o_dtype=v.dtype,
    )
    o, s_out = stream_compute(program, rf, kf, vf, wf, uf, s0f,
                              interpret=interpret)
    return (
        o.reshape(B, H, Tp, M)[:, :, :T],
        s_out.reshape(B, H, N, M),
    )
