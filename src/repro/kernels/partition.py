"""Mesh-aware kernel partitioning: the third axis of dispatch (paper Fig. 13).

Occamy's hierarchical, symmetric interconnect lets cluster-agnostic kernels
scale across groups, chiplets, and the D2D link with predictable bandwidth
per level. The software analogue: every op in the kernel registry carries a
``PartitionRule`` describing how its operands split over the mesh's
*partition levels* — the chiplet axis (``model``) and, on a multi-pod mesh,
the pod axis (``pod``, the D2D link) jointly above it — which collective
stitches the partials back together at each level, and when the op must
degrade to fewer levels or to replication instead (the same divisibility
contract as ``parallel/sharding.py``).

Layering (parallel to impl selection and block resolution):

  ops.py            resolves the rule once per call — explicit ``mesh=`` kwarg
                    or the mesh from ``sharding.use_mesh`` — and routes here
  partition.py      plan_for(): PartitionRule -> PartitionPlan (specs +
                    local function + per-level collective-cost metadata)
  sharded_call()    wraps WHICHEVER registered impl runs in ``shard_map``
                    (via parallel/compat), so pallas, interpret, xla and ref
                    all execute the identical sharded program; the single
                    pallas-call-site invariant (core/streams.py) is untouched
  consumers         launch/roofline prices plan.collectives per level with
                    ``topology.collective_seconds`` (on-chiplet vs D2D
                    bandwidth); benchmarks/bench_mesh.py times sharded vs
                    single device

Rule table (the op's logical-axis split over the partition levels):

  gemm              K-sharded (A cols x B rows) over pod×model jointly; the
                    epilogue is a *hierarchical* all-reduce — intra-pod psum
                    then cross-pod psum — so the D2D link carries one
                    already-reduced buffer per pod. Falls back to M-row
                    sharding, then (via the level ladder) to model-only,
                    then replication
  flash_attention   GQA head-sharded (q heads AND kv heads) over pod×model,
                    COMPOSED with the ``data`` level (attention_levels):
                    B over ``data`` when the batch divides it, else the
                    sequence-parallel KV ring — Sq/Sk sharded over ``data``
                    with the K/V chunks rotating through (n-1) ppermute
                    hops, each hop re-entering the registered kernel at its
                    static q_offset and folding through the online-softmax
                    merge (collectives.ring_scan / online_softmax_merge) —
                    the latency-tolerant C4/C5 tile-rotation pattern at
                    mesh scale. TP-hostile head counts keep the data-level
                    composition and drop only the head split
  decode_attention  same composed GQA head × batch rule (cache and
                    position rows ride the batch split); no ring
  linear_attention  head-sharded state/decay streams (u, s0 included),
                    composed with B over ``data``
  spmm              row-sharded ELL value/index streams — rows split across
                    pods, then within each pod — dense replicated
  bsr_spmm          tile-sharded (nnz-parallel), hierarchical ``psum``
                    epilogue over rows
  spmspm            row-sharded A, B replicated
  stencil           x-sharded grid with ``ppermute`` halo exchange; on a
                    multi-pod mesh the intra-pod hops ride the chiplet
                    crossbar and the single pod-boundary hop per direction
                    rides the D2D link (SARIS boundary planes)

**The replication fallback ladder.** ``plan_for`` resolves the mesh's
partition levels outermost-first (``pod`` above ``model``) and offers the
full stack to the op's rule; if the rule's divisibility checks fail, the
outermost level is dropped and the rule is retried, down to a single level
and finally to ``None`` (replication). An op whose heads divide the chiplet
axis but not pod×model therefore still shards intra-pod instead of
replicating outright.

``plan_for`` also accepts a device-free ``MeshSpec`` so the dry-run/roofline
path can cost the per-level collectives without constructing devices;
executing a plan (``sharded_call``) requires a real ``jax.sharding.Mesh``.
"""
from __future__ import annotations

import dataclasses
import functools
import inspect
import math
from typing import Any, Callable

import jax
import jax.numpy as jnp
from jax.sharding import Mesh, PartitionSpec as P

from repro.diagnostics import warn_degrade
from repro.kernels import registry
from repro.parallel.collectives import hierarchical_psum
from repro.parallel.compat import shard_map

# The complete mesh-axis vocabulary the partition layer ever shards over or
# names in a collective: the D2D pod link, the group interconnect (data),
# and the chiplet crossbar (model). partition_levels / attention_levels
# only ever emit these names, and the repro.analysis axis-name lint rule
# holds every string-literal collective axis in the tree to this list — a
# stray "modle" in a psum is a silent replication bug otherwise.
AXIS_VOCAB = ("pod", "data", "model")


# ---------------------------------------------------------------------------
# Plan objects
# ---------------------------------------------------------------------------


@dataclasses.dataclass(frozen=True)
class CollectiveCost:
    """One collective a plan fires at one partition level.

    Fields: ``kind`` — the collective, in the vocabulary of
    ``topology.collective_seconds`` ("all_reduce" | "all_gather" |
    "reduce_scatter" | "permute"); ``axis`` — the mesh axis it crosses
    (``"pod"`` prices at the D2D link bandwidth, anything else at the
    on-chiplet ICI bandwidth); ``nbytes`` — the per-device payload;
    ``n`` — the participant count at that level (the ring size the
    bandwidth model uses). ``n=0`` means "the plan's total shard count",
    kept for constructors predating per-level costing.
    """

    kind: str
    axis: str
    nbytes: int
    n: int = 0


@dataclasses.dataclass(frozen=True)
class PartitionPlan:
    """A resolved partitioning of one op call over one or more mesh levels.

    Fields: ``op`` — the registry op name; ``levels`` — outer→inner
    ``(axis, size)`` pairs the plan shards over (``(("pod", 2), ("model",
    16))`` for a two-level plan, a single pair otherwise); ``in_specs`` —
    one PartitionSpec per positional operand (entries for operands that are
    ``None`` are ignored); ``out_specs`` — the output spec (or tuple
    thereof); ``local_fn`` — takes the full operand tuple (Nones included)
    and runs the registered impl on the local shard, firing any collective
    epilogue inside ``shard_map``; ``collectives`` — per-level
    ``CollectiveCost`` metadata in firing order (innermost level first);
    ``note`` — a human-readable one-liner for benchmark/roofline rows.

    Latency-tolerance metadata (the overlap cost model reads these):
    ``overlappable`` — the local_fn issues its collectives double-buffered,
    so per-hop D2D time hides behind per-hop compute instead of adding to
    it; ``hops`` — the pipeline depth the overlap model amortises over
    (ring length for the KV ring, 2 for the halo exchange's two
    directions); ``pre`` / ``post`` — optional GLOBAL-array rewrites
    applied by ``sharded_call`` outside shard_map: ``pre(*args) -> args``
    before sharding (the zigzag sequence gather), ``post(out) -> out``
    after (its inverse).

    Invariants: ``n`` (total shard count) is the product of the level
    sizes; ``axis`` is the spec-entry form of the levels — the bare axis
    name for a single level, the axis tuple for a joint split.
    """

    op: str
    levels: tuple
    in_specs: tuple
    out_specs: Any
    local_fn: Callable
    collectives: tuple[CollectiveCost, ...] = ()
    note: str = ""
    overlappable: bool = False
    hops: int = 0
    pre: Callable | None = None
    post: Callable | None = None

    @property
    def axis(self):
        """Spec-entry form of the partition axes: ``"model"`` for a
        single-level plan, ``("pod", "model")`` for a joint two-level one."""
        axes = tuple(a for a, _ in self.levels)
        return axes[0] if len(axes) == 1 else axes

    @property
    def n(self) -> int:
        """Total shard count: the product of every level's size."""
        return math.prod(n for _, n in self.levels)


@dataclasses.dataclass(frozen=True)
class MeshSpec:
    """Device-free mesh descriptor: lets the dry-run/roofline layer resolve
    partition plans (and their per-level D2D costs) without any devices
    existing.

    Fields: ``shape`` — ``{axis_name: size}`` in axis order (a 2-pod
    production mesh is ``{"pod": 2, "data": 16, "model": 16}``).
    """

    shape: dict

    @property
    def axis_names(self) -> tuple:
        """The mesh axis names, in declaration order."""
        return tuple(self.shape)


def partition_axis(mesh) -> str:
    """The innermost axis ops shard over: ``model`` (the chiplet crossbar in
    the C5 mapping) when present, else the last axis of ``mesh`` (a Mesh or
    MeshSpec). Two-level plans stack the ``pod`` axis above this one — see
    ``partition_levels``."""
    names = tuple(mesh.axis_names)
    return "model" if "model" in names else names[-1]


def partition_levels(mesh) -> tuple:
    """The partition-level stack of ``mesh``, outermost first.

    Returns ``(axis, size)`` pairs: ``("pod", P)`` when the mesh has a
    non-trivial ``pod`` axis (the D2D link), then the ``partition_axis``
    (the chiplet crossbar). Size-1 axes are dropped, so a flat mesh yields
    one level and a trivial mesh yields ``()`` (replication). ``mesh`` may
    be a Mesh or a device-free MeshSpec.
    """
    names = tuple(mesh.axis_names)
    inner = partition_axis(mesh)
    levels = []
    if "pod" in names and inner != "pod" and int(mesh.shape["pod"]) > 1:
        levels.append(("pod", int(mesh.shape["pod"])))
    if int(mesh.shape[inner]) > 1:
        levels.append((inner, int(mesh.shape[inner])))
    return tuple(levels)


def attention_levels(mesh) -> tuple:
    """The attention family's level stack: ``partition_levels`` with the
    ``data`` axis (the group-interconnect level) slotted between ``pod``
    and the chiplet axis.

    Attention rules use the extra level for the *batch or sequence*
    dimension — B-sharding when the batch divides it, else the
    sequence-parallel KV ring for ``flash_attention`` — composed with the
    GQA head sharding the remaining levels carry. Size-1 axes are dropped;
    a mesh without a ``data`` axis degenerates to ``partition_levels``.
    """
    names = tuple(mesh.axis_names)
    inner = partition_axis(mesh)
    levels = []
    if "pod" in names and inner != "pod" and int(mesh.shape["pod"]) > 1:
        levels.append(("pod", int(mesh.shape["pod"])))
    if "data" in names and inner != "data" and int(mesh.shape["data"]) > 1:
        levels.append(("data", int(mesh.shape["data"])))
    if int(mesh.shape[inner]) > 1:
        levels.append((inner, int(mesh.shape[inner])))
    return tuple(levels)


def _joint(levels) -> str | tuple:
    """PartitionSpec entry for a joint split over ``levels``: the bare axis
    name for one level, the axis-name tuple for several."""
    axes = tuple(a for a, _ in levels)
    return axes[0] if len(axes) == 1 else axes


def _ntot(levels) -> int:
    return math.prod(n for _, n in levels)


def _levels_note(levels) -> str:
    return "+".join(f"{a}={n}" for a, n in levels)


# ---------------------------------------------------------------------------
# Rule registry
# ---------------------------------------------------------------------------

_RULES: dict[str, Callable] = {}
_LEVEL_FNS: dict[str, Callable] = {}


def register_partition_rule(op: str, *, levels: Callable | None = None) -> Callable:
    """Decorator: ``@register_partition_rule("spmm")`` registers the
    PartitionRule for the registry op named ``op``.

    The rule receives ``(levels, *operands, impl=..., **op_kwargs)`` —
    ``levels`` being the outer→inner ``(axis, size)`` stack ``plan_for``
    offers it — and returns a PartitionPlan, or None when its divisibility
    checks fail at that level count (``plan_for`` then retries with the
    outermost level dropped: the replication fallback ladder).

    ``levels`` selects the op's level vocabulary — the function mapping a
    mesh to the stack ``plan_for`` offers (default ``partition_levels``;
    the attention family uses ``attention_levels``, which adds the ``data``
    axis for batch/sequence parallelism).
    """

    def deco(fn: Callable) -> Callable:
        _RULES[op] = fn
        if levels is not None:
            _LEVEL_FNS[op] = levels
        return fn

    return deco


def partitioned_ops() -> list[str]:
    """Sorted names of every op that registered a PartitionRule."""
    return sorted(_RULES)


# Plan-only keywords: schedule knobs the partition layer consumes, never the
# kernels. ``plan_for`` forwards each one only to rules whose signature
# declares it (rules like gemm's pass **blocks straight to kernel_call, so a
# stray ``overlap=`` would land in an impl); the dispatch seams strip them
# before any direct kernel_call.
PLAN_KWARGS = ("overlap", "zigzag", "remote_copy")


@functools.lru_cache(maxsize=None)
def _rule_plan_params(rule: Callable) -> frozenset:
    """The subset of PLAN_KWARGS a rule's signature declares."""
    try:
        params = inspect.signature(rule).parameters
    except (TypeError, ValueError):  # builtins/C callables: assume none
        return frozenset()
    return frozenset(k for k in PLAN_KWARGS if k in params)


def strip_plan_kwargs(kwargs: dict) -> dict:
    """``kwargs`` without the plan-only schedule keywords — what a plain
    (replicated) ``kernel_call`` may receive."""
    return {k: v for k, v in kwargs.items() if k not in PLAN_KWARGS}


def plan_for(op: str, mesh, *args, impl: str | None = None, **kwargs):
    """Resolve the op's PartitionRule against ``mesh`` (a Mesh or MeshSpec).

    Args: ``op`` — registry op name; ``mesh`` — the mesh (or device-free
    MeshSpec) whose partition levels the rule sees; ``*args`` / ``**kwargs``
    — the op call's operands (arrays or ShapeDtypeStructs; plans resolve
    from shapes alone) and keyword parameters; ``impl`` — the registry impl
    the plan's local function will dispatch to.

    Walks the replication fallback ladder: the full level stack (pod×model
    on a multi-pod mesh) is offered first; each time the rule declines, the
    outermost level is dropped. Returns None — replication — when the op
    has no rule, no non-trivial level exists, or every rung fails (the
    graceful-degradation contract shared with parallel/sharding.py). A
    fully exhausted ladder — a rule that declined every rung of a
    non-trivial stack — emits a one-shot ``ReproDegradeWarning`` naming the
    op and mesh, so silent replication is visible to callers and to the
    ``repro.analysis`` ladder-dead-end check.
    """
    rule = _RULES.get(op)
    if rule is None:
        return None
    accepted = _rule_plan_params(rule)
    kwargs = {
        k: v for k, v in kwargs.items()
        if k not in PLAN_KWARGS or k in accepted
    }
    levels = _LEVEL_FNS.get(op, partition_levels)(mesh)
    offered = levels
    while levels:
        plan = rule(levels, *args, impl=impl, **kwargs)
        if plan is not None:
            return plan
        levels = levels[1:]
    if offered:
        shape = "x".join(f"{a}={s}" for a, s in offered)
        warn_degrade(
            f"partition ladder exhausted for {op!r}: every rung of "
            f"({shape}) declined; replicating the call on all devices",
            key=("ladder_exhausted", op, shape),
        )
    return None


def plan_collective_bytes(plan: PartitionPlan | None) -> int:
    """Total per-device collective payload of ``plan``, summed across every
    level (0 for replication)."""
    if plan is None:
        return 0
    return sum(c.nbytes for c in plan.collectives)


def local_operand_structs(plan: PartitionPlan | None, mesh, args) -> tuple:
    """Per-device shard geometry of each live operand under ``plan``.

    Args: ``plan`` — a plan from ``plan_for`` (None means replication:
    operands pass through whole); ``mesh`` — the Mesh or MeshSpec the plan
    was resolved against; ``args`` — the positional operands (arrays or
    ShapeDtypeStructs; ``None`` entries are skipped, mirroring
    ``sharded_call``).

    Returns one ``jax.ShapeDtypeStruct`` per live operand with every
    sharded dimension divided by the product of its spec axes' sizes — the
    shapes the registered impl actually sees inside ``shard_map``. This is
    what keys autotune records under a mesh: tuned block geometry is only
    valid for the *local* shapes the kernel ran on.
    """
    live = [a for a in args if a is not None]
    if plan is None:
        return tuple(jax.ShapeDtypeStruct(a.shape, a.dtype) for a in live)
    out = []
    for a, spec in zip(args, plan.in_specs):
        if a is None:
            continue
        shape = list(a.shape)
        for d, entry in enumerate(tuple(spec)):
            if entry is None:
                continue
            names = (entry,) if isinstance(entry, str) else tuple(entry)
            for name in names:
                shape[d] //= int(mesh.shape[name])
        out.append(jax.ShapeDtypeStruct(tuple(shape), a.dtype))
    return tuple(out)


def sharded_call(op: str, mesh, *args, impl: str | None = None, **kwargs):
    """Run ``op`` sharded over ``mesh`` through whichever registered impl is
    selected, falling back to a plain (replicated) ``kernel_call`` when no
    plan applies.

    Args: ``op`` — registry op name; ``mesh`` — a real ``jax.sharding.Mesh``
    (a MeshSpec resolves plans but cannot execute them); ``*args`` — the
    positional operands (``None`` holes allowed, e.g. linear_attention's
    optional u/s0); ``impl``/``**kwargs`` — forwarded to the registry
    dispatch. Returns exactly what the unsharded op returns.

    This is the single seam ops.py routes mesh-aware calls through — no
    per-call spec plumbing anywhere else.
    """
    impl = registry.resolve_impl(impl)
    plan = plan_for(op, mesh, *args, impl=impl, **kwargs)
    if plan is None:
        return registry.kernel_call(
            op, *args, impl=impl, **strip_plan_kwargs(kwargs)
        )
    if not isinstance(mesh, Mesh):
        raise TypeError(
            f"executing a partition plan for {op!r} needs a device mesh; "
            f"got {type(mesh).__name__} (MeshSpec is for plan_for/costing only)"
        )
    if plan.pre is not None:  # global rewrite (zigzag gather) before sharding
        args = plan.pre(*args)
    live = [i for i, a in enumerate(args) if a is not None]
    in_specs = tuple(plan.in_specs[i] for i in live)

    def wrapped(*live_args):
        full = list(args)
        for i, v in zip(live, live_args):
            full[i] = v
        return plan.local_fn(*full)

    fn = shard_map(
        wrapped, mesh=mesh, in_specs=in_specs, out_specs=plan.out_specs,
        check_vma=False,
    )
    out = fn(*(args[i] for i in live))
    return plan.post(out) if plan.post is not None else out


def _nbytes(shape, dtype) -> int:
    return math.prod(shape) * jnp.dtype(dtype).itemsize


def _per_level_psum_costs(levels, shape, dtype) -> tuple:
    """One all_reduce CollectiveCost per level, innermost (intra-pod) first —
    the firing order of ``hierarchical_psum``."""
    return tuple(
        CollectiveCost("all_reduce", axis, _nbytes(shape, dtype), n)
        for axis, n in reversed(tuple(levels))
    )


# ---------------------------------------------------------------------------
# Rules
# ---------------------------------------------------------------------------


@register_partition_rule("gemm")
def _gemm_rule(levels, a, b, *, impl=None, out_dtype=None,
               accum_dtype=jnp.float32, precision=None, **blocks):
    """K-sharded GEMM with a hierarchical psum epilogue (the paper's split-K
    over the chiplet axis; on a multi-pod mesh the intra-pod psum runs
    before the cross-pod psum so the D2D link moves one buffer per pod);
    M-row sharding when K resists; the level ladder handles the rest.

    Each shard quantizes its own K-slab under a ``precision`` policy, so
    the per-block scales compose with the sharding by construction (no
    scale arrays cross the shard_map boundary). Sub-fp32 policies also
    narrow the psum payload to bf16 — the ``optim/compression.py``
    error-feedback reduction dtype — halving the D2D bytes the collective
    moves (the intra-shard accumulate stays fp32; only the cross-device
    partial rides narrow)."""
    from repro.core import precision as _prec

    precision = _prec.resolve(precision)
    M, K = a.shape
    N = b.shape[1]
    out_dtype = out_dtype or (
        jnp.float32 if precision is not None else a.dtype
    )
    n = _ntot(levels)
    ax = _joint(levels)
    pk = {} if precision is None else {"precision": precision}
    reduce_dtype = accum_dtype
    if (precision is not None
            and jnp.dtype(precision.compute_dtype).itemsize < 4):
        reduce_dtype = jnp.bfloat16

    if K % n == 0:
        def local(a_l, b_l):
            part = registry.kernel_call(
                "gemm", a_l, b_l, out_dtype=reduce_dtype,
                accum_dtype=accum_dtype, impl=impl, **pk, **blocks,
            )
            return hierarchical_psum(part, levels).astype(out_dtype)

        return PartitionPlan(
            op="gemm", levels=tuple(levels),
            in_specs=(P(None, ax), P(ax, None)),
            out_specs=P(None, None),
            local_fn=local,
            collectives=_per_level_psum_costs(levels, (M, N), reduce_dtype),
            note=f"k-sharded ({K}/{n} per device over {_levels_note(levels)})"
                 ", psum epilogue"
                 + (f", {jnp.dtype(reduce_dtype).name} reduce"
                    if reduce_dtype != accum_dtype else ""),
        )

    if M % n == 0:
        def local(a_l, b_l):
            return registry.kernel_call(
                "gemm", a_l, b_l, out_dtype=out_dtype,
                accum_dtype=accum_dtype, impl=impl, **pk, **blocks,
            )

        return PartitionPlan(
            op="gemm", levels=tuple(levels),
            in_specs=(P(ax, None), P(None, None)),
            out_specs=P(ax, None),
            local_fn=local,
            note=f"m-row-sharded ({M}/{n} per device over "
                 f"{_levels_note(levels)})",
        )
    return None


def _attn_levels_split(levels, batch: int):
    """Split the attention level stack into its parts.

    Returns ``(head_levels, data_level, batch_ok)``: the non-``data``
    levels (the GQA head-sharding stack), the ``("data", n)`` level if
    offered (else None), and whether ``batch`` divides it (B-over-``data``
    composition is legal).
    """
    heads = tuple(lv for lv in levels if lv[0] != "data")
    data = next((lv for lv in levels if lv[0] == "data"), None)
    batch_ok = data is not None and batch % data[1] == 0
    return heads, data, batch_ok


def _attn_used(levels, head_ok: bool, data_used: bool):
    """The subset of ``levels`` a composed attention plan actually shards
    over, preserving mesh (outer→inner) order."""
    return tuple(
        lv for lv in levels
        if (lv[0] == "data" and data_used) or (lv[0] != "data" and head_ok)
    )


def _attn_head_ok(heads, count: int):
    """GQA head divisibility at this rung, or ``None`` to decline it.

    ``count`` heads must divide the whole head stack for the split to
    engage. When they don't but the stack can still shrink (two head
    levels offered), the rule DECLINES the rung instead of settling for a
    data-only plan — the ladder then drops the outermost level and the
    retry may recover an intra-pod head split (e.g. 4 kv heads on
    pod=2 × model=4 head-shard 4-way after the pod level drops). Only a
    minimal (single-level) head stack that still fails degrades to the
    data-only composition.
    """
    ok = bool(heads) and count % _ntot(heads) == 0
    if not ok and len(heads) > 1:
        return None
    return ok


@register_partition_rule("flash_attention", levels=attention_levels)
def _flash_rule(levels, q, k, v, *, impl=None, causal=True, window=0,
                q_offset=0, scale=None, precision=None, return_lse=False,
                overlap=True, zigzag=True, remote_copy=False, **blocks):
    """The attention family's composed rule: GQA head sharding × a ``data``
    level carrying either the batch or the sequence.

    Heads: q heads AND kv heads split together over the non-``data``
    levels (pods first, then the chiplet axis) so every device keeps whole
    (kv-head × group) blocks; TP-hostile counts drop the head split.

    Data level, in preference order:

    - **batch**: ``B % data == 0`` → B-sharding, collective-free;
    - **sequence-parallel KV ring**: the long-context form (B too small to
      split, ``Sq == Sk`` divisible by ``data``). Each device keeps its Q
      chunk resident and the K/V chunks rotate through an (n−1)-hop
      ``ppermute`` ring (``collectives.ring_scan``, double-buffered when
      ``overlap`` so each hop's D2D flight hides behind the hop kernel);
      every hop re-enters the registered kernel and the per-hop partials
      fold through the (m, l, acc)-equivalent ``online_softmax_merge``.

      The unbounded-causal ring additionally stripes Q ownership
      **zigzag** (``zigzag``, default on; see
      ``flash_attention.zigzag_indices``): rank ``r`` owns half-chunks
      ``r`` and ``2d-1-r``, gathered/ungathered globally by the plan's
      ``pre``/``post``. Hop 0 is ONE plain causal kernel call on the
      concatenated local block (order-isomorphic to its global rows); hop
      ``t>0`` is exactly two fully-unmasked ``causal=False`` sub-calls —
      every omitted (q-half × kv-half) pair is provably fully masked — so
      every rank does identical 2·(Sq/2d)² score work per hop and the
      wrapped-hop no-ops of the naive causal ring disappear.

      The legacy (contiguous-chunk) ring remains for windowed/non-causal/
      zigzag-indivisible cases: each hop runs at its static ``q_offset``
      so the mask lands on absolute positions, wrapped hops merge as
      no-ops, and a lookback window prunes whole tail hops statically.
      The ring declines bounded masks at nonzero ``q_offset`` (the wrap
      would alias past positions).

    If neither composition applies at this rung the ladder drops the
    outermost level and retries; ``None`` only once every level is gone.
    """
    from repro.kernels.flash_attention import zigzag_indices, zigzag_inverse
    from repro.parallel.collectives import (
        NEG_LSE, online_softmax_merge, ring_scan,
    )

    B, H, Sq, _ = q.shape
    K, Sk = k.shape[1], k.shape[2]
    # precision quantizes per shard (and per ring hop) inside the impls:
    # each device scales its own rows over D, so no scale arrays ever
    # cross the shard_map boundary and the composition is automatic
    pk = {} if precision is None else {"precision": precision}
    heads, data, batch_ok = _attn_levels_split(levels, B)
    head_ok = _attn_head_ok(heads, K)
    if head_ok is None:
        return None  # decline: a shorter head stack may still divide
    bounded = bool(causal or window)
    ring_ok = (
        data is not None and not batch_ok
        and Sq == Sk and Sq % data[1] == 0
        and not (bounded and q_offset != 0)
    )
    if not head_ok and not batch_ok and not ring_ok:
        return None
    ax = _joint(heads) if head_ok else None
    used = _attn_used(levels, head_ok, batch_ok or ring_ok)
    notes = []
    if head_ok:
        notes.append(
            f"head-sharded ({K}/{_ntot(heads)} kv heads over "
            f"{_levels_note(heads)})"
        )

    if batch_ok or not ring_ok:
        dt = "data" if batch_ok else None
        h4 = P(dt, ax, None, None)

        def local(q_l, k_l, v_l):
            return registry.kernel_call(
                "flash_attention", q_l, k_l, v_l, causal=causal,
                window=window, q_offset=q_offset, scale=scale,
                return_lse=return_lse, impl=impl, **pk, **blocks,
            )

        if batch_ok:
            notes.append(f"batch-sharded (B={B}/{data[1]} over data)")
        return PartitionPlan(
            op="flash_attention", levels=used,
            in_specs=(h4, h4, h4),
            out_specs=(h4, P(dt, ax, None)) if return_lse else h4,
            local_fn=local,
            note=" + ".join(notes),
        )

    # sequence-parallel ring: Sq/Sk over `data`, KV rotating
    d = data[1]
    c = Sq // d  # per-device chunk length (static)
    hops = d
    if window:
        # hop t's nearest k sits c*t - (c-1) behind the earliest q; hops
        # entirely beyond every row's lookback are pruned statically
        hops = min(d, max(1, -(-(window + c - 1) // c)))
    zig = bool(
        zigzag and causal and not window and q_offset == 0
        and Sq % (2 * d) == 0
    )

    if zig:
        c2 = Sq // (2 * d)  # half-chunk length: rank r owns chunks r, 2d-1-r

        def local(q_l, k_l, v_l):
            me = jax.lax.axis_index("data")
            o0 = jnp.zeros(q_l.shape, jnp.float32)
            lse0 = jnp.full(q_l.shape[:-1], NEG_LSE, jnp.float32)

            def step(carry, kv, t):
                o, lse = carry
                k_b, v_b = kv
                if t == 0:
                    # resident hop: the local block is order-isomorphic to
                    # its global rows, so a plain causal call IS the global
                    # causal mask restricted to them
                    o_t, lse_t = registry.kernel_call(
                        "flash_attention", q_l, k_b, v_b, causal=True,
                        window=0, q_offset=0, scale=scale,
                        return_lse=True, impl=impl, **pk, **blocks,
                    )
                    return online_softmax_merge(o, lse, o_t, lse_t)
                # hop t>0: the resident KV left rank s = me - t (mod d).
                # Of the four (q-half × kv-half) pairs, q_tail × k_head is
                # always fully valid; up-ranks (me >= t, s < me) also get
                # q_head × k_head, down-ranks (wrapped, s > me) also get
                # q_tail × k_tail — every pair fully valid, every omitted
                # pair fully masked, so both sub-calls run unmasked
                # (causal=False) and each rank does the same 2·c2² work.
                up = me >= t
                q_head, q_tail = q_l[:, :, :c2], q_l[:, :, c2:]
                k_head, v_head = k_b[:, :, :c2], v_b[:, :, :c2]
                k_tail, v_tail = k_b[:, :, c2:], v_b[:, :, c2:]
                o_full, lse_full = registry.kernel_call(
                    "flash_attention", q_tail, k_head, v_head,
                    causal=False, window=0, q_offset=0, scale=scale,
                    return_lse=True, impl=impl, **pk, **blocks,
                )
                o_sel, lse_sel = registry.kernel_call(
                    "flash_attention",
                    jnp.where(up, q_head, q_tail),
                    jnp.where(up, k_head, k_tail),
                    jnp.where(up, v_head, v_tail),
                    causal=False, window=0, q_offset=0, scale=scale,
                    return_lse=True, impl=impl, **pk, **blocks,
                )
                # head rows: up-ranks take the sel partial, down-ranks none
                o_h = jnp.where(up, o_sel.astype(jnp.float32), 0.0)
                lse_h = jnp.where(up, lse_sel, NEG_LSE)
                # tail rows: the always-valid full partial, plus (down
                # ranks only) the sel partial over k_tail
                o_m, lse_m = online_softmax_merge(
                    o_full.astype(jnp.float32), lse_full,
                    jnp.where(up, 0.0, o_sel.astype(jnp.float32)),
                    jnp.where(up, NEG_LSE, lse_sel),
                )
                o_t = jnp.concatenate([o_h, o_m], axis=2)
                lse_t = jnp.concatenate([lse_h, lse_m], axis=2)
                return online_softmax_merge(o, lse, o_t, lse_t)

            o, lse = ring_scan(
                step, (o0, lse0), (k_l, v_l), "data", d,
                hops=d, overlap=overlap, remote_copy=remote_copy,
            )
            o = o.astype(q_l.dtype)
            return (o, lse) if return_lse else o

        idx, inv = zigzag_indices(Sq, d), zigzag_inverse(Sq, d)

        def pre(q_g, k_g, v_g):
            return tuple(jnp.take(x, idx, axis=2) for x in (q_g, k_g, v_g))

        def post(out):
            if return_lse:
                o_g, lse_g = out
                return jnp.take(o_g, inv, axis=2), jnp.take(lse_g, inv, axis=2)
            return jnp.take(out, inv, axis=2)

    else:
        pre = post = None

        def local(q_l, k_l, v_l):
            me = jax.lax.axis_index("data")
            o0 = jnp.zeros(q_l.shape, jnp.float32)
            lse0 = jnp.full(q_l.shape[:-1], NEG_LSE, jnp.float32)

            def step(carry, kv, t):
                o, lse = carry
                k_b, v_b = kv
                o_t, lse_t = registry.kernel_call(
                    "flash_attention", q_l, k_b, v_b, causal=causal,
                    window=window, q_offset=q_offset + t * c, scale=scale,
                    return_lse=True, impl=impl, **pk, **blocks,
                )
                if bounded and t:
                    # ranks me < t hold a wrapped (future) KV chunk this
                    # hop: causal/window semantics mask it entirely, so
                    # the partial merges as a no-op
                    valid = me >= t
                    lse_t = jnp.where(valid, lse_t, NEG_LSE)
                    o_t = jnp.where(valid, o_t.astype(jnp.float32), 0.0)
                return online_softmax_merge(o, lse, o_t, lse_t)

            o, lse = ring_scan(
                step, (o0, lse0), (k_l, v_l), "data", d,
                hops=hops, overlap=overlap, remote_copy=remote_copy,
            )
            o = o.astype(q_l.dtype)
            return (o, lse) if return_lse else o

    h4 = P(None, ax, "data", None)
    kv_local_bytes = _nbytes(
        (B, (K // _ntot(heads)) if head_ok else K, Sk // d, k.shape[-1]),
        k.dtype,
    )
    notes.append(
        f"ring seq-parallel{' zigzag' if zig else ''} "
        f"(Sq={Sq}/{d} per device over data={d}, {hops - 1} kv hops)"
    )
    return PartitionPlan(
        op="flash_attention", levels=used,
        in_specs=(h4, h4, h4),
        out_specs=(h4, P(None, ax, "data")) if return_lse else h4,
        local_fn=local,
        collectives=tuple(
            CollectiveCost("permute", "data", kv_local_bytes, d)
            for _ in range(2 * (hops - 1))  # k and v, per hop
        ),
        note=" + ".join(notes),
        overlappable=bool(overlap and hops > 1),
        hops=hops,
        pre=pre,
        post=post,
    )


@register_partition_rule("decode_attention", levels=attention_levels)
def _decode_rule(levels, q, k, v, position, *, impl=None, **kwargs):
    """Same composed GQA head × batch rule as flash_attention: heads over
    the non-``data`` levels, B (queries AND their cache/position rows) over
    ``data`` when it divides. No sequence ring — decode is one query token
    against a resident cache."""
    if kwargs.get("block_table") is not None:
        # paged pools carry no batch dim and shard by cache pages, not by
        # B/heads — the serving layer's ring_decode owns that distribution
        return None
    B, K = q.shape[0], k.shape[1]
    heads, data, batch_ok = _attn_levels_split(levels, B)
    head_ok = _attn_head_ok(heads, K)
    if head_ok is None:
        return None  # decline: a shorter head stack may still divide
    if not head_ok and not batch_ok:
        return None
    ax = _joint(heads) if head_ok else None
    dt = "data" if batch_ok else None

    def local(q_l, k_l, v_l, pos_l):
        return registry.kernel_call(
            "decode_attention", q_l, k_l, v_l, pos_l, impl=impl, **kwargs
        )

    notes = []
    if head_ok:
        notes.append(f"head-sharded ({K}/{_ntot(heads)} kv heads over "
                     f"{_levels_note(heads)})")
    if batch_ok:
        notes.append(f"batch-sharded (B={B}/{data[1]} over data)")
    return PartitionPlan(
        op="decode_attention", levels=_attn_used(levels, head_ok, batch_ok),
        in_specs=(P(dt, ax, None), P(dt, ax, None, None),
                  P(dt, ax, None, None), P(dt)),
        out_specs=P(dt, ax, None),
        local_fn=local,
        note=" + ".join(notes),
    )


@register_partition_rule("linear_attention", levels=attention_levels)
def _linear_attention_rule(levels, r, k, v, w_log, u=None, s0=None, *,
                           impl=None, **kwargs):
    """Head-sharded chunked state scan composed with B over ``data``: every
    stream (r/k/v/decay, the carried state) splits on H across the
    non-``data`` levels and on B across ``data``; the u bonus is per-head
    only. The recurrence stays embarrassingly parallel across devices: no
    collective epilogue at all."""
    B, H = r.shape[0], r.shape[1]
    heads, data, batch_ok = _attn_levels_split(levels, B)
    head_ok = _attn_head_ok(heads, H)
    if head_ok is None:
        return None  # decline: a shorter head stack may still divide
    if not head_ok and not batch_ok:
        return None
    ax = _joint(heads) if head_ok else None
    dt = "data" if batch_ok else None

    def local(r_l, k_l, v_l, w_l, u_l, s0_l):
        return registry.kernel_call(
            "linear_attention", r_l, k_l, v_l, w_l, u_l, s0_l,
            impl=impl, **kwargs,
        )

    h4 = P(dt, ax, None, None)
    notes = []
    if head_ok:
        notes.append(f"head-sharded ({H}/{_ntot(heads)} heads over "
                     f"{_levels_note(heads)})")
    if batch_ok:
        notes.append(f"batch-sharded (B={B}/{data[1]} over data)")
    return PartitionPlan(
        op="linear_attention", levels=_attn_used(levels, head_ok, batch_ok),
        in_specs=(h4, h4, h4, h4, P(ax, None), h4),
        out_specs=(h4, h4),
        local_fn=local,
        note=" + ".join(notes),
    )


@register_partition_rule("spmm")
def _spmm_rule(levels, values, cols, dense, *, impl=None, **kwargs):
    """Row-sharded ELL: rows split across pods, then across the chiplet axis
    within each pod; each device streams its own value/index rows against
    the replicated dense operand — the chiplet-local SU indirection."""
    R = values.shape[0]
    n = _ntot(levels)
    if R % n != 0:
        return None
    ax = _joint(levels)

    def local(v_l, c_l, d_l):
        return registry.kernel_call("spmm", v_l, c_l, d_l, impl=impl, **kwargs)

    return PartitionPlan(
        op="spmm", levels=tuple(levels),
        in_specs=(P(ax, None), P(ax, None), P(None, None)),
        out_specs=P(ax, None),
        local_fn=local,
        note=f"row-sharded ({R}/{n} ELL rows per device over "
             f"{_levels_note(levels)})",
    )


@register_partition_rule("bsr_spmm")
def _bsr_rule(levels, tile_values, tile_rows, tile_cols, dense, *,
              num_rows, impl=None, **kwargs):
    """Tile-sharded BSR (nnz-parallel): devices own disjoint tile subsets,
    each scatter-accumulates a full-height partial, and a hierarchical psum
    stitches the rows back — intra-pod first, so the D2D crossing moves one
    reduced partial per pod."""
    T = tile_values.shape[0]
    n = _ntot(levels)
    if T % n != 0 or T == 0:
        return None
    F = dense.shape[1]
    bm_tile = tile_values.shape[1]
    ax = _joint(levels)

    def local(tv_l, tr_l, tc_l, d_l):
        part = registry.kernel_call(
            "bsr_spmm", tv_l, tr_l, tc_l, d_l, num_rows=num_rows,
            impl=impl, **kwargs,
        )
        # the stream kernel only initialises output blocks whose row id
        # appears in ITS tile subset; rows all of whose tiles live on other
        # devices stay uninitialised locally, so mask them before the psum
        present = jnp.zeros((num_rows // bm_tile,), bool).at[tr_l].set(True)
        row_mask = jnp.repeat(present, bm_tile)[:, None]
        return hierarchical_psum(jnp.where(row_mask, part, 0.0), levels)

    return PartitionPlan(
        op="bsr_spmm", levels=tuple(levels),
        in_specs=(P(ax, None, None), P(ax), P(ax), P(None, None)),
        out_specs=P(None, None),
        local_fn=local,
        collectives=_per_level_psum_costs(levels, (num_rows, F), jnp.float32),
        note=f"tile-sharded ({T}/{n} nnz tiles per device over "
             f"{_levels_note(levels)}), psum epilogue",
    )


@register_partition_rule("spmspm")
def _spmspm_rule(levels, a_values, a_cols, b_values, b_rows, *,
                 contraction_dim, impl=None, **kwargs):
    """A-row-sharded sparse×sparse: A's rows split across pods then within,
    B replicated; each device intersects its own rows independently."""
    R = a_values.shape[0]
    n = _ntot(levels)
    if R % n != 0:
        return None
    ax = _joint(levels)

    def local(av_l, ac_l, bv_l, br_l):
        return registry.kernel_call(
            "spmspm", av_l, ac_l, bv_l, br_l,
            contraction_dim=contraction_dim, impl=impl, **kwargs,
        )

    return PartitionPlan(
        op="spmspm", levels=tuple(levels),
        in_specs=(P(ax, None), P(ax, None), P(None, None), P(None, None)),
        out_specs=P(ax, None),
        local_fn=local,
        note=f"a-row-sharded ({R}/{n} rows per device over "
             f"{_levels_note(levels)})",
    )


def _halo_block(width: int, cap: int, halo: int) -> int:
    """Largest block <= cap that divides the padded local extent and still
    covers the halo reach (the pallas kernel requires max|dx| <= bx)."""
    for d in range(min(cap, width), 0, -1):
        if width % d == 0 and d >= halo:
            return d
    return width


@register_partition_rule("stencil")
def _stencil_rule(levels, grid, *, offsets, weights, impl=None, bx=None,
                  overlap=True, **kwargs):
    """X-sharded grid with ppermute halo exchange (the SARIS boundary planes).

    Each device pads its slab with ``h`` neighbour planes per side — the
    ring wrap IS the periodic boundary — then runs the registered impl on
    the padded slab; offsets never reach past the halo, so the impl's own
    periodic wrap never engages inside the slab.

    With ``overlap`` (default, when the slab is deep enough: ``lx >= 2h``)
    the exchange is double-buffered: both halo ppermutes are issued first,
    the interior rows — which never reach the halo — are computed directly
    on the unpadded slab while the planes fly, and only the two ``h``-row
    boundary strips wait on the transfers. Row-for-row the same values in
    the same accumulation order as the synchronous path (bit-identical);
    only the issue order differs. ``overlap=False`` keeps the synchronous
    pad-then-kernel schedule as the correctness oracle.

    On a two-level mesh the slab order is pod-major: most neighbours sit on
    the same pod, so the exchange is an intra-pod ``ppermute`` ring over the
    chiplet axis, plus ONE cross-pod boundary hop per direction — an extra
    ``ppermute`` over the pod axis whose payload replaces the intra-pod
    wrap value exactly at the pod-edge devices (its own ring wrap carries
    the global periodic boundary across the D2D link).
    """
    import numpy as np

    X, Y, Z = grid.shape
    offs = np.asarray(offsets)
    h = int(np.abs(offs[:, 0]).max(initial=0))
    n = _ntot(levels)
    if X % n != 0:
        return None
    lx = X // n
    if h > lx:
        return None  # halo wider than a slab: drop a level rather than multi-hop
    padded_x = lx + 2 * h
    bx_cap = registry.resolve_blocks("stencil", bx=bx)["bx"]
    bx_local = _halo_block(padded_x, bx_cap, max(h, 1))
    ax = _joint(levels)
    inner_axis, tp = levels[-1]
    outer = levels[:-1]  # () or the single ("pod", P) level above
    fwd = [(i, (i + 1) % tp) for i in range(tp)]
    bwd = [(i, (i - 1) % tp) for i in range(tp)]
    if outer:
        (pod_axis, pods), = outer
        pod_fwd = [(i, (i + 1) % pods) for i in range(pods)]
        pod_bwd = [(i, (i - 1) % pods) for i in range(pods)]
    overlapped = bool(overlap and h and lx >= 2 * h)

    def exchange(g_l):
        lo = jax.lax.ppermute(g_l[-h:], inner_axis, fwd)  # left tail
        hi = jax.lax.ppermute(g_l[:h], inner_axis, bwd)  # right head
        if outer:
            # pod-edge devices got the intra-pod wrap; what they need is
            # the neighbouring pod's boundary slab, one D2D hop away
            m = jax.lax.axis_index(inner_axis)
            lo = jnp.where(m == 0,
                           jax.lax.ppermute(lo, pod_axis, pod_fwd), lo)
            hi = jnp.where(m == tp - 1,
                           jax.lax.ppermute(hi, pod_axis, pod_bwd), hi)
        return lo, hi

    if overlapped:
        bx_int = _halo_block(lx, bx_cap, max(h, 1))
        bx_strip = _halo_block(3 * h, bx_cap, max(h, 1))

        def local(g_l):
            # issue both halo transfers, then compute the interior while
            # they fly: rows [h, lx-h) reach at most the slab edges, so
            # the unpadded kernel's periodic wrap never touches them (the
            # wrap-polluted edge rows are discarded and recomputed below)
            lo, hi = exchange(g_l)
            interior = registry.kernel_call(
                "stencil", g_l, offsets, weights, impl=impl, bx=bx_int,
                **kwargs,
            )[h:lx - h]
            # boundary strips: h output rows each, padded to 3h input rows
            # so every stencil reach stays inside the strip
            top = registry.kernel_call(
                "stencil", jnp.concatenate([lo, g_l[:2 * h]], axis=0),
                offsets, weights, impl=impl, bx=bx_strip, **kwargs,
            )[h:2 * h]
            bottom = registry.kernel_call(
                "stencil", jnp.concatenate([g_l[-2 * h:], hi], axis=0),
                offsets, weights, impl=impl, bx=bx_strip, **kwargs,
            )[h:2 * h]
            return jnp.concatenate([top, interior, bottom], axis=0)

    else:
        def local(g_l):
            if h:
                lo, hi = exchange(g_l)
                padded = jnp.concatenate([lo, g_l, hi], axis=0)
            else:
                padded = g_l
            out = registry.kernel_call(
                "stencil", padded, offsets, weights, impl=impl, bx=bx_local,
                **kwargs,
            )
            return out[h:h + lx] if h else out

    halo_bytes = _nbytes((h, Y, Z), grid.dtype)
    colls = []
    if h:
        colls += [CollectiveCost("permute", inner_axis, halo_bytes, tp)] * 2
        if outer:
            colls += [CollectiveCost("permute", pod_axis, halo_bytes, pods)] * 2
    return PartitionPlan(
        op="stencil", levels=tuple(levels),
        in_specs=(P(ax, None, None),),
        out_specs=P(ax, None, None),
        local_fn=local,
        collectives=tuple(colls),
        note=f"x-sharded ({lx} planes per device over {_levels_note(levels)})"
             f", halo h={h} via ppermute"
             + (" + pod boundary hop" if h and outer else "")
             + (" (overlapped)" if overlapped else ""),
        overlappable=overlapped,
        hops=2 if overlapped else 0,
    )
