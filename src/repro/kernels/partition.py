"""Mesh-aware kernel partitioning: the third axis of dispatch (paper Fig. 13).

Occamy's hierarchical, symmetric interconnect lets cluster-agnostic kernels
scale across groups, chiplets, and the D2D link with predictable bandwidth
per level. The software analogue: every op in the kernel registry carries a
``PartitionRule`` describing how its operands split over a mesh axis (the
chiplet axis), which collective stitches the partials back together (the D2D
traffic), and when the op must degrade to replication instead (the same
divisibility contract as ``parallel/sharding.py``).

Layering (parallel to impl selection and block resolution):

  ops.py            resolves the rule once per call — explicit ``mesh=`` kwarg
                    or the mesh from ``sharding.use_mesh`` — and routes here
  partition.py      plan_for(): PartitionRule -> PartitionPlan (specs +
                    local function + collective-cost metadata)
  sharded_call()    wraps WHICHEVER registered impl runs in ``shard_map``
                    (via parallel/compat), so pallas, interpret, xla and ref
                    all execute the identical sharded program; the single
                    pallas-call-site invariant (core/streams.py) is untouched
  consumers         launch/roofline prices plan.collectives with
                    ``topology.collective_seconds`` (the D2D roofline term);
                    benchmarks/bench_mesh.py times sharded vs single device

Rule table (the op's logical-axis split over the partition axis):

  gemm              K-sharded (A cols x B rows), ``psum`` epilogue; falls
                    back to M-row sharding, then replication
  flash_attention   GQA head-sharded (q heads AND kv heads); replicates on
                    TP-hostile head counts
  decode_attention  same GQA head rule (position stays replicated)
  linear_attention  head-sharded state/decay streams (u, s0 included)
  spmm              row-sharded ELL value/index streams, dense replicated
  bsr_spmm          tile-sharded (nnz-parallel), ``psum`` epilogue over rows
  spmspm            row-sharded A, B replicated
  stencil           x-sharded grid with ``ppermute`` halo exchange (SARIS
                    boundary planes ride the D2D link)

``plan_for`` also accepts a device-free ``MeshSpec`` so the dry-run/roofline
path can cost the D2D collectives without constructing devices; executing a
plan (``sharded_call``) requires a real ``jax.sharding.Mesh``.
"""
from __future__ import annotations

import dataclasses
import math
from typing import Any, Callable

import jax
import jax.numpy as jnp
from jax.sharding import Mesh, PartitionSpec as P

from repro.kernels import registry
from repro.parallel.compat import shard_map


# ---------------------------------------------------------------------------
# Plan objects
# ---------------------------------------------------------------------------


@dataclasses.dataclass(frozen=True)
class CollectiveCost:
    """One collective the plan's epilogue fires, in the vocabulary of
    ``topology.collective_seconds``: kind, mesh axis, per-device payload."""

    kind: str  # "all_reduce" | "all_gather" | "reduce_scatter" | "permute"
    axis: str
    nbytes: int


@dataclasses.dataclass(frozen=True)
class PartitionPlan:
    """A resolved partitioning of one op call on one mesh axis.

    ``in_specs`` carries one PartitionSpec per positional operand (entries
    for operands that are ``None`` are ignored); ``local_fn`` takes the full
    operand tuple (Nones included) and runs the registered impl on the local
    shard, firing any collective epilogue inside ``shard_map``.
    """

    op: str
    axis: str
    n: int
    in_specs: tuple
    out_specs: Any
    local_fn: Callable
    collectives: tuple[CollectiveCost, ...] = ()
    note: str = ""


@dataclasses.dataclass(frozen=True)
class MeshSpec:
    """Device-free mesh descriptor: lets the dry-run/roofline layer resolve
    partition plans (and their D2D costs) without any devices existing."""

    shape: dict  # axis name -> size, in axis order

    @property
    def axis_names(self) -> tuple:
        return tuple(self.shape)


def partition_axis(mesh) -> str:
    """The axis ops shard over: ``model`` (the chiplet crossbar in the C5
    mapping) when present, else the innermost mesh axis."""
    names = tuple(mesh.axis_names)
    return "model" if "model" in names else names[-1]


# ---------------------------------------------------------------------------
# Rule registry
# ---------------------------------------------------------------------------

_RULES: dict[str, Callable] = {}


def register_partition_rule(op: str) -> Callable:
    """Decorator: ``@register_partition_rule("spmm")``. The rule receives
    ``(axis, n, *operands, impl=..., **op_kwargs)`` and returns a
    PartitionPlan, or None to degrade to replication."""

    def deco(fn: Callable) -> Callable:
        _RULES[op] = fn
        return fn

    return deco


def partitioned_ops() -> list[str]:
    return sorted(_RULES)


def plan_for(op: str, mesh, *args, impl: str | None = None, **kwargs):
    """Resolve the op's PartitionRule against ``mesh`` (a Mesh or MeshSpec).

    Returns None — replication — when the op has no rule, the partition axis
    is trivial, or the rule's divisibility checks fail (the graceful-
    degradation contract shared with parallel/sharding.py).
    """
    rule = _RULES.get(op)
    if rule is None:
        return None
    axis = partition_axis(mesh)
    n = int(mesh.shape[axis])
    if n <= 1:
        return None
    return rule(axis, n, *args, impl=impl, **kwargs)


def plan_collective_bytes(plan: PartitionPlan | None) -> int:
    """Total per-device collective payload of a plan (0 for replication)."""
    if plan is None:
        return 0
    return sum(c.nbytes for c in plan.collectives)


def sharded_call(op: str, mesh, *args, impl: str | None = None, **kwargs):
    """Run ``op`` sharded over ``mesh`` through whichever registered impl is
    selected, falling back to a plain (replicated) ``kernel_call`` when no
    plan applies. This is the single seam ops.py routes mesh-aware calls
    through — no per-call spec plumbing anywhere else.
    """
    impl = registry.resolve_impl(impl)
    plan = plan_for(op, mesh, *args, impl=impl, **kwargs)
    if plan is None:
        return registry.kernel_call(op, *args, impl=impl, **kwargs)
    if not isinstance(mesh, Mesh):
        raise TypeError(
            f"executing a partition plan for {op!r} needs a device mesh; "
            f"got {type(mesh).__name__} (MeshSpec is for plan_for/costing only)"
        )
    live = [i for i, a in enumerate(args) if a is not None]
    in_specs = tuple(plan.in_specs[i] for i in live)

    def wrapped(*live_args):
        full = list(args)
        for i, v in zip(live, live_args):
            full[i] = v
        return plan.local_fn(*full)

    fn = shard_map(
        wrapped, mesh=mesh, in_specs=in_specs, out_specs=plan.out_specs,
        check_vma=False,
    )
    return fn(*(args[i] for i in live))


def _nbytes(shape, dtype) -> int:
    return math.prod(shape) * jnp.dtype(dtype).itemsize


# ---------------------------------------------------------------------------
# Rules
# ---------------------------------------------------------------------------


@register_partition_rule("gemm")
def _gemm_rule(axis, n, a, b, *, impl=None, out_dtype=None,
               accum_dtype=jnp.float32, **blocks):
    """K-sharded GEMM with a psum epilogue (the paper's split-K over the
    chiplet axis); M-row sharding when K resists; replication when both do."""
    M, K = a.shape
    N = b.shape[1]
    out_dtype = out_dtype or a.dtype

    if K % n == 0:
        def local(a_l, b_l):
            part = registry.kernel_call(
                "gemm", a_l, b_l, out_dtype=accum_dtype,
                accum_dtype=accum_dtype, impl=impl, **blocks,
            )
            return jax.lax.psum(part, axis).astype(out_dtype)

        return PartitionPlan(
            op="gemm", axis=axis, n=n,
            in_specs=(P(None, axis), P(axis, None)),
            out_specs=P(None, None),
            local_fn=local,
            collectives=(
                CollectiveCost("all_reduce", axis, _nbytes((M, N), accum_dtype)),
            ),
            note=f"k-sharded ({K}/{n} per device), psum epilogue",
        )

    if M % n == 0:
        def local(a_l, b_l):
            return registry.kernel_call(
                "gemm", a_l, b_l, out_dtype=out_dtype,
                accum_dtype=accum_dtype, impl=impl, **blocks,
            )

        return PartitionPlan(
            op="gemm", axis=axis, n=n,
            in_specs=(P(axis, None), P(None, None)),
            out_specs=P(axis, None),
            local_fn=local,
            note=f"m-row-sharded ({M}/{n} per device)",
        )
    return None


def _head_sharded_attn(op, axis, n, q, k, kv_heads: int, in_specs, out_specs,
                       local_fn, note):
    if kv_heads % n != 0:
        return None  # TP-hostile head count: replicate (GQA groups stay whole)
    return PartitionPlan(
        op=op, axis=axis, n=n, in_specs=in_specs, out_specs=out_specs,
        local_fn=local_fn, note=note,
    )


@register_partition_rule("flash_attention")
def _flash_rule(axis, n, q, k, v, *, impl=None, **kwargs):
    """GQA-aware head sharding: q heads AND kv heads split together so every
    device keeps whole (kv-head x group) blocks; TP-hostile counts (e.g. 20
    or 25 heads) replicate instead, via the same divisibility contract as
    parallel/sharding.py."""
    K = k.shape[1]

    def local(q_l, k_l, v_l):
        return registry.kernel_call(
            "flash_attention", q_l, k_l, v_l, impl=impl, **kwargs
        )

    h4 = P(None, axis, None, None)
    return _head_sharded_attn(
        "flash_attention", axis, n, q, k, K,
        in_specs=(h4, h4, h4), out_specs=h4, local_fn=local,
        note=f"head-sharded ({K}/{n} kv heads per device)",
    )


@register_partition_rule("decode_attention")
def _decode_rule(axis, n, q, k, v, position, *, impl=None, **kwargs):
    K = k.shape[1]

    def local(q_l, k_l, v_l, pos_l):
        return registry.kernel_call(
            "decode_attention", q_l, k_l, v_l, pos_l, impl=impl, **kwargs
        )

    return _head_sharded_attn(
        "decode_attention", axis, n, q, k, K,
        in_specs=(P(None, axis, None), P(None, axis, None, None),
                  P(None, axis, None, None), P(None)),
        out_specs=P(None, axis, None),
        local_fn=local,
        note=f"head-sharded ({K}/{n} kv heads per device)",
    )


@register_partition_rule("linear_attention")
def _linear_attention_rule(axis, n, r, k, v, w_log, u=None, s0=None, *,
                           impl=None, **kwargs):
    """Head-sharded chunked state scan: every stream (r/k/v/decay, the u
    bonus, the carried state) splits on H, so the recurrence is embarrassingly
    parallel across devices — no collective epilogue at all."""
    H = r.shape[1]
    if H % n != 0:
        return None

    def local(r_l, k_l, v_l, w_l, u_l, s0_l):
        return registry.kernel_call(
            "linear_attention", r_l, k_l, v_l, w_l, u_l, s0_l,
            impl=impl, **kwargs,
        )

    h4 = P(None, axis, None, None)
    return PartitionPlan(
        op="linear_attention", axis=axis, n=n,
        in_specs=(h4, h4, h4, h4, P(axis, None), h4),
        out_specs=(h4, h4),
        local_fn=local,
        note=f"head-sharded ({H}/{n} heads per device)",
    )


@register_partition_rule("spmm")
def _spmm_rule(axis, n, values, cols, dense, *, impl=None, **kwargs):
    """Row-sharded ELL: each device streams its own value/index rows against
    the replicated dense operand — the chiplet-local SU indirection."""
    R = values.shape[0]
    if R % n != 0:
        return None

    def local(v_l, c_l, d_l):
        return registry.kernel_call("spmm", v_l, c_l, d_l, impl=impl, **kwargs)

    return PartitionPlan(
        op="spmm", axis=axis, n=n,
        in_specs=(P(axis, None), P(axis, None), P(None, None)),
        out_specs=P(axis, None),
        local_fn=local,
        note=f"row-sharded ({R}/{n} ELL rows per device)",
    )


@register_partition_rule("bsr_spmm")
def _bsr_rule(axis, n, tile_values, tile_rows, tile_cols, dense, *,
              num_rows, impl=None, **kwargs):
    """Tile-sharded BSR (nnz-parallel): devices own disjoint tile subsets,
    each scatter-accumulates a full-height partial, and a psum stitches the
    rows back — the D2D-crossing sparse reduction."""
    T = tile_values.shape[0]
    if T % n != 0 or T == 0:
        return None
    F = dense.shape[1]
    bm_tile = tile_values.shape[1]

    def local(tv_l, tr_l, tc_l, d_l):
        part = registry.kernel_call(
            "bsr_spmm", tv_l, tr_l, tc_l, d_l, num_rows=num_rows,
            impl=impl, **kwargs,
        )
        # the stream kernel only initialises output blocks whose row id
        # appears in ITS tile subset; rows all of whose tiles live on other
        # devices stay uninitialised locally, so mask them before the psum
        present = jnp.zeros((num_rows // bm_tile,), bool).at[tr_l].set(True)
        row_mask = jnp.repeat(present, bm_tile)[:, None]
        return jax.lax.psum(jnp.where(row_mask, part, 0.0), axis)

    return PartitionPlan(
        op="bsr_spmm", axis=axis, n=n,
        in_specs=(P(axis, None, None), P(axis), P(axis), P(None, None)),
        out_specs=P(None, None),
        local_fn=local,
        collectives=(
            CollectiveCost(
                "all_reduce", axis, _nbytes((num_rows, F), jnp.float32)
            ),
        ),
        note=f"tile-sharded ({T}/{n} nnz tiles per device), psum epilogue",
    )


@register_partition_rule("spmspm")
def _spmspm_rule(axis, n, a_values, a_cols, b_values, b_rows, *,
                 contraction_dim, impl=None, **kwargs):
    R = a_values.shape[0]
    if R % n != 0:
        return None

    def local(av_l, ac_l, bv_l, br_l):
        return registry.kernel_call(
            "spmspm", av_l, ac_l, bv_l, br_l,
            contraction_dim=contraction_dim, impl=impl, **kwargs,
        )

    return PartitionPlan(
        op="spmspm", axis=axis, n=n,
        in_specs=(P(axis, None), P(axis, None), P(None, None), P(None, None)),
        out_specs=P(axis, None),
        local_fn=local,
        note=f"a-row-sharded ({R}/{n} rows per device)",
    )


def _halo_block(width: int, cap: int, halo: int) -> int:
    """Largest block <= cap that divides the padded local extent and still
    covers the halo reach (the pallas kernel requires max|dx| <= bx)."""
    for d in range(min(cap, width), 0, -1):
        if width % d == 0 and d >= halo:
            return d
    return width


@register_partition_rule("stencil")
def _stencil_rule(axis, n, grid, *, offsets, weights, impl=None, bx=None,
                  **kwargs):
    """X-sharded grid with ppermute halo exchange (the SARIS boundary planes
    crossing the D2D link). Each device pads its slab with ``h`` neighbour
    planes per side — the ring wrap IS the periodic boundary — then runs the
    registered impl on the padded slab; offsets never reach past the halo, so
    the impl's own periodic wrap never engages inside the slab.
    """
    import numpy as np

    X, Y, Z = grid.shape
    offs = np.asarray(offsets)
    h = int(np.abs(offs[:, 0]).max(initial=0))
    if X % n != 0:
        return None
    lx = X // n
    if h > lx:
        return None  # halo wider than a slab: replicate rather than multi-hop
    padded_x = lx + 2 * h
    bx_cap = registry.resolve_blocks("stencil", bx=bx)["bx"]
    bx_local = _halo_block(padded_x, bx_cap, max(h, 1))
    fwd = [(i, (i + 1) % n) for i in range(n)]
    bwd = [(i, (i - 1) % n) for i in range(n)]

    def local(g_l):
        if h:
            lo = jax.lax.ppermute(g_l[-h:], axis, fwd)  # left neighbour tail
            hi = jax.lax.ppermute(g_l[:h], axis, bwd)  # right neighbour head
            padded = jnp.concatenate([lo, g_l, hi], axis=0)
        else:
            padded = g_l
        out = registry.kernel_call(
            "stencil", padded, offsets, weights, impl=impl, bx=bx_local,
            **kwargs,
        )
        return out[h:h + lx] if h else out

    halo_bytes = _nbytes((h, Y, Z), grid.dtype)
    return PartitionPlan(
        op="stencil", axis=axis, n=n,
        in_specs=(P(axis, None, None),),
        out_specs=P(axis, None, None),
        local_fn=local,
        collectives=(
            CollectiveCost("permute", axis, halo_bytes),
            CollectiveCost("permute", axis, halo_bytes),
        ) if h else (),
        note=f"x-sharded ({lx} planes per device), halo h={h} via ppermute",
    )
