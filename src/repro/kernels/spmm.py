"""Sparse-dense matmul kernels (paper Fig. 9c).

Two forms:

1. ``spmm_pallas`` — ELL value/index rows. The column-index stream is kept in
   VMEM and drives an in-kernel gather — the VPU form of the paper's indirect
   SU stream, used for narrow dense operands.

2. ``bsr_spmm_pallas`` — block-sparse rows. Unstructured sparsity exploited at
   (bm x bk) tile granularity: scalar-prefetched tile coordinates become the
   IndirectStream index maps selecting which dense K-blocks to stream (index
   stream -> address generation), and each step is a dense MXU matmul. Empty
   tiles are never visited: compute scales with nnz blocks, exactly the
   paper's "compute only on nonzeros" economy.
"""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl

from repro.core.streams import (
    AffineStream,
    IndirectStream,
    StreamProgram,
    stream_compute,
)
from repro.kernels.registry import resolve_blocks


# ---------------------------------------------------------------------------
# ELL spmm: in-kernel gather (VPU form, used for narrow dense operands)
# ---------------------------------------------------------------------------


def _ell_kernel(values_ref, cols_ref, dense_ref, o_ref, *, L):
    vals = values_ref[...]  # (bm, L)
    cols = cols_ref[...]  # (bm, L)
    acc = jnp.zeros_like(o_ref, dtype=jnp.float32)
    for j in range(L):  # static unroll: L is the padded nnz/row
        rows = dense_ref[cols[:, j]]  # (bm, F) gather from VMEM
        acc += vals[:, j : j + 1].astype(jnp.float32) * rows.astype(jnp.float32)
    o_ref[...] = acc.astype(o_ref.dtype)


def ell_spmm_program(Rp, L, C, F, bm, val_dtype, dense_dtype) -> StreamProgram:
    """ELL SpMM as a stream program: value/index row streams advance with the
    row-block grid; the dense operand is a resident (non-advancing) stream."""
    return StreamProgram(
        name="spmm",
        body=functools.partial(_ell_kernel, L=L),
        grid=(Rp // bm,),
        in_streams=(
            AffineStream((bm, L), lambda i: (i, 0), dtype=val_dtype),
            AffineStream((bm, L), lambda i: (i, 0), dtype=jnp.int32),
            AffineStream((C, F), lambda i: (0, 0), dtype=dense_dtype),
        ),
        out_streams=(
            AffineStream((bm, F), lambda i: (i, 0), dtype=dense_dtype),
        ),
        out_shapes=(jax.ShapeDtypeStruct((Rp, F), dense_dtype),),
    )


def spmm_pallas(values, cols, dense, *, bm: int | None = None,
                interpret: bool = False):
    """values/cols: (R, L); dense: (C, F) — dense must fit VMEM per block."""
    R, L = values.shape
    C, F = dense.shape
    bm = min(resolve_blocks("spmm", bm=bm)["bm"], R)
    pad = (-R) % bm
    if pad:
        values = jnp.pad(values, ((0, pad), (0, 0)))
        cols = jnp.pad(cols, ((0, pad), (0, 0)))
    program = ell_spmm_program(R + pad, L, C, F, bm, values.dtype, dense.dtype)
    out = stream_compute(program, values, cols, dense, interpret=interpret)
    return out[:R]


# ---------------------------------------------------------------------------
# BSR spmm: scalar-prefetched tile coordinates drive the dense index stream
# ---------------------------------------------------------------------------


def _bsr_kernel(rows_ref, cols_ref, vals_ref, dense_ref, o_ref, *, nt):
    t = pl.program_id(1)
    row = rows_ref[t]
    prev_row = rows_ref[jnp.maximum(t - 1, 0)]
    is_first = jnp.logical_or(t == 0, row != prev_row)

    @pl.when(is_first)
    def _init():
        o_ref[...] = jnp.zeros_like(o_ref)

    o_ref[...] += jax.lax.dot(
        vals_ref[0], dense_ref[...], preferred_element_type=jnp.float32
    ).astype(o_ref.dtype)


def bsr_spmm_program(
    tile_rows, tile_cols, T, bm, bk, bf, Fp, num_rows, val_dtype, dense_dtype
) -> StreamProgram:
    """BSR SpMM as a stream program: the (row, col) coordinate arrays are
    scalar-prefetched index streams; the dense and output streams are
    IndirectStreams whose index maps read them — address generation happens
    in "hardware" (the grid pipeline), the body issues only MXU matmuls."""
    return StreamProgram(
        name="bsr_spmm",
        body=functools.partial(_bsr_kernel, nt=T),
        grid=(Fp // bf, T),
        in_streams=(
            AffineStream((1, bm, bk), lambda f, t: (t, 0, 0), dtype=val_dtype),
            IndirectStream(
                (bk, bf), lambda f, t, rows, cols: (cols[t], f),
                dtype=dense_dtype,
            ),
        ),
        out_streams=(
            IndirectStream(
                (bm, bf), lambda f, t, rows, cols: (rows[t], f),
                dtype=jnp.float32,
            ),
        ),
        out_shapes=(jax.ShapeDtypeStruct((num_rows, Fp), jnp.float32),),
        index_args=(tile_rows, tile_cols),
        dimension_semantics=("parallel", "arbitrary"),
    )


def bsr_spmm_pallas(
    tile_values,  # (T, bm, bk) nonzero tiles, sorted by (row, col)
    tile_rows,  # (T,) int32 block-row ids (every row id present)
    tile_cols,  # (T,) int32 block-col ids
    dense,  # (K, F)
    num_rows: int,
    *,
    bf: int | None = None,
    interpret: bool = False,
):
    T, bm, bk = tile_values.shape
    K, F = dense.shape
    bf = min(resolve_blocks("bsr_spmm", bf=bf)["bf"], F)
    pad = (-F) % bf
    if pad:
        dense = jnp.pad(dense, ((0, 0), (0, pad)))
    Fp = F + pad

    program = bsr_spmm_program(
        tile_rows, tile_cols, T, bm, bk, bf, Fp, num_rows,
        tile_values.dtype, dense.dtype,
    )
    out = stream_compute(program, tile_values, dense, interpret=interpret)
    return out[:, :F]
