"""Sparse-dense matmul kernels (paper Fig. 9c).

Two forms:

1. ``spmm_pallas`` — ELL value/index rows. The column-index stream is scalar-
   prefetched into SMEM and drives the dense operand's BlockSpec index_map —
   the literal TPU translation of the paper's indirect SU stream (indices
   generate addresses in "hardware", the compute loop issues only FMAs).
   Grid: (row blocks, nnz position); each step gathers one dense *row block*
   per ELL slot via the index stream and accumulates a rank-1 update... on the
   MXU this degenerates, so the production path is:

2. ``bsr_spmm_pallas`` — block-sparse rows. Unstructured sparsity exploited at
   (bm x bk) tile granularity: scalar-prefetched tile coordinates select which
   dense K-blocks to stream (index stream -> address generation), and each
   step is a dense MXU matmul. Empty tiles are never visited: compute scales
   with nnz blocks, exactly the paper's "compute only on nonzeros" economy.
"""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl
from jax.experimental.pallas import tpu as pltpu


# ---------------------------------------------------------------------------
# ELL spmm: in-kernel gather (VPU form, used for narrow dense operands)
# ---------------------------------------------------------------------------


def _ell_kernel(values_ref, cols_ref, dense_ref, o_ref, *, L):
    vals = values_ref[...]  # (bm, L)
    cols = cols_ref[...]  # (bm, L)
    acc = jnp.zeros_like(o_ref, dtype=jnp.float32)
    for l in range(L):  # static unroll: L is the padded nnz/row
        rows = dense_ref[cols[:, l]]  # (bm, F) gather from VMEM
        acc += vals[:, l : l + 1].astype(jnp.float32) * rows.astype(jnp.float32)
    o_ref[...] = acc.astype(o_ref.dtype)


def spmm_pallas(values, cols, dense, *, bm: int = 128, interpret: bool = False):
    """values/cols: (R, L); dense: (C, F) — dense must fit VMEM per block."""
    R, L = values.shape
    C, F = dense.shape
    bm = min(bm, R)
    pad = (-R) % bm
    if pad:
        values = jnp.pad(values, ((0, pad), (0, 0)))
        cols = jnp.pad(cols, ((0, pad), (0, 0)))
    Rp = R + pad
    out = pl.pallas_call(
        functools.partial(_ell_kernel, L=L),
        grid=(Rp // bm,),
        in_specs=[
            pl.BlockSpec((bm, L), lambda i: (i, 0)),
            pl.BlockSpec((bm, L), lambda i: (i, 0)),
            pl.BlockSpec((C, F), lambda i: (0, 0)),
        ],
        out_specs=pl.BlockSpec((bm, F), lambda i: (i, 0)),
        out_shape=jax.ShapeDtypeStruct((Rp, F), dense.dtype),
        interpret=interpret,
    )(values, cols, dense)
    return out[:R]


# ---------------------------------------------------------------------------
# BSR spmm: scalar-prefetched tile coordinates drive the dense index_map
# ---------------------------------------------------------------------------


def _bsr_kernel(rows_ref, cols_ref, vals_ref, dense_ref, o_ref, *, nt):
    t = pl.program_id(1)
    row = rows_ref[t]
    prev_row = rows_ref[jnp.maximum(t - 1, 0)]
    is_first = jnp.logical_or(t == 0, row != prev_row)

    @pl.when(is_first)
    def _init():
        o_ref[...] = jnp.zeros_like(o_ref)

    o_ref[...] += jax.lax.dot(
        vals_ref[0], dense_ref[...], preferred_element_type=jnp.float32
    ).astype(o_ref.dtype)


def bsr_spmm_pallas(
    tile_values,  # (T, bm, bk) nonzero tiles, sorted by (row, col)
    tile_rows,  # (T,) int32 block-row ids (every row id present)
    tile_cols,  # (T,) int32 block-col ids
    dense,  # (K, F)
    num_rows: int,
    *,
    bf: int = 512,
    interpret: bool = False,
):
    T, bm, bk = tile_values.shape
    K, F = dense.shape
    bf = min(bf, F)
    pad = (-F) % bf
    if pad:
        dense = jnp.pad(dense, ((0, 0), (0, pad)))
    Fp = F + pad

    grid_spec = pltpu.PrefetchScalarGridSpec(
        num_scalar_prefetch=2,
        grid=(Fp // bf, T),
        in_specs=[
            pl.BlockSpec((1, bm, bk), lambda f, t, rows, cols: (t, 0, 0)),
            pl.BlockSpec((bk, bf), lambda f, t, rows, cols: (cols[t], f)),
        ],
        out_specs=pl.BlockSpec(
            (bm, bf), lambda f, t, rows, cols: (rows[t], f)
        ),
    )
    out = pl.pallas_call(
        functools.partial(_bsr_kernel, nt=T),
        grid_spec=grid_spec,
        out_shape=jax.ShapeDtypeStruct((num_rows, Fp), jnp.float32),
        compiler_params=pltpu.CompilerParams(
            dimension_semantics=("parallel", "arbitrary")
        ),
        interpret=interpret,
    )(tile_rows, tile_cols, tile_values, dense)
    return out[:, :F]
