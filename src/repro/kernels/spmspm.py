"""Sparse-sparse matmul by index intersection (paper Fig. 9d, SU C3).

Occamy's SUs advance two sorted index streams with per-element comparators.
The TPU has no data-dependent stream advance, so the intersection is
*blocked* (DESIGN.md §6.2): a (bm x La) tile of A-row indices is compared
all-pairs against a (bn x Lb) tile of B-column indices on the VPU; matching
pairs contribute val_a * val_b to out[m, n]. Comparisons per tile =
bm*bn*La*Lb — the paper's GCOMP figure of merit.
"""
from __future__ import annotations

import jax
import jax.numpy as jnp

from repro.core.streams import AffineStream, StreamProgram, stream_compute
from repro.kernels.registry import resolve_blocks


def _spmspm_kernel(av_ref, ac_ref, bv_ref, br_ref, o_ref):
    a_vals = av_ref[...].astype(jnp.float32)  # (bm, La)
    a_cols = ac_ref[...]
    b_vals = bv_ref[...].astype(jnp.float32)  # (bn, Lb)
    b_rows = br_ref[...]
    # all-pairs comparator: (bm, La, bn, Lb)
    eq = a_cols[:, :, None, None] == b_rows[None, None, :, :]
    contrib = jnp.where(
        eq, a_vals[:, :, None, None] * b_vals[None, None, :, :], 0.0
    )
    o_ref[...] = contrib.sum(axis=(1, 3)).astype(o_ref.dtype)


def spmspm_program(Rp, Cp, La, Lb, bm, bn, a_dtype, b_dtype,
                   idx_dtype=jnp.int32) -> StreamProgram:
    """Blocked intersection as a stream program: the A value/index streams
    advance with the row grid, the B streams with the column grid."""
    def a_row(i, j):
        return (i, 0)

    def b_col(i, j):
        return (j, 0)
    return StreamProgram(
        name="spmspm",
        body=_spmspm_kernel,
        grid=(Rp // bm, Cp // bn),
        in_streams=(
            AffineStream((bm, La), a_row, dtype=a_dtype),
            AffineStream((bm, La), a_row, dtype=idx_dtype),
            AffineStream((bn, Lb), b_col, dtype=b_dtype),
            AffineStream((bn, Lb), b_col, dtype=idx_dtype),
        ),
        out_streams=(
            AffineStream((bm, bn), lambda i, j: (i, j), dtype=jnp.float32),
        ),
        out_shapes=(jax.ShapeDtypeStruct((Rp, Cp), jnp.float32),),
        dimension_semantics=("parallel", "parallel"),
    )


def spmspm_pallas(
    a_values,  # (R, La) ELL rows
    a_cols,
    b_values,  # (C, Lb) ELL columns (CSC-like)
    b_rows,
    contraction_dim: int,
    *,
    bm: int | None = None,
    bn: int | None = None,
    interpret: bool = False,
):
    R, La = a_values.shape
    C, Lb = b_values.shape
    blocks = resolve_blocks("spmspm", bm=bm, bn=bn)
    bm = min(blocks["bm"], R)
    bn = min(blocks["bn"], C)
    pr, pc = (-R) % bm, (-C) % bn
    if pr:
        a_values = jnp.pad(a_values, ((0, pr), (0, 0)))
        a_cols = jnp.pad(a_cols, ((0, pr), (0, 0)))
    if pc:
        b_values = jnp.pad(b_values, ((0, pc), (0, 0)))
        b_rows = jnp.pad(b_rows, ((0, pc), (0, 0)))

    program = spmspm_program(
        R + pr, C + pc, La, Lb, bm, bn,
        a_values.dtype, b_values.dtype, a_cols.dtype,
    )
    out = stream_compute(
        program, a_values, a_cols, b_values, b_rows, interpret=interpret
    )
    return out[:R, :C]
