"""Sparse-sparse matmul by index intersection (paper Fig. 9d, SU C3).

Occamy's SUs advance two sorted index streams with per-element comparators.
The TPU has no data-dependent stream advance, so the intersection is
*blocked* (DESIGN.md §6.2): a (bm x La) tile of A-row indices is compared
all-pairs against a (bn x Lb) tile of B-column indices on the VPU; matching
pairs contribute val_a * val_b to out[m, n]. Comparisons per tile =
bm*bn*La*Lb — the paper's GCOMP figure of merit.
"""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl
from jax.experimental.pallas import tpu as pltpu


def _spmspm_kernel(av_ref, ac_ref, bv_ref, br_ref, o_ref):
    a_vals = av_ref[...].astype(jnp.float32)  # (bm, La)
    a_cols = ac_ref[...]
    b_vals = bv_ref[...].astype(jnp.float32)  # (bn, Lb)
    b_rows = br_ref[...]
    # all-pairs comparator: (bm, La, bn, Lb)
    eq = a_cols[:, :, None, None] == b_rows[None, None, :, :]
    contrib = jnp.where(
        eq, a_vals[:, :, None, None] * b_vals[None, None, :, :], 0.0
    )
    o_ref[...] = contrib.sum(axis=(1, 3)).astype(o_ref.dtype)


def spmspm_pallas(
    a_values,  # (R, La) ELL rows
    a_cols,
    b_values,  # (C, Lb) ELL columns (CSC-like)
    b_rows,
    contraction_dim: int,
    *,
    bm: int = 8,
    bn: int = 128,
    interpret: bool = False,
):
    R, La = a_values.shape
    C, Lb = b_values.shape
    bm, bn = min(bm, R), min(bn, C)
    pr, pc = (-R) % bm, (-C) % bn
    if pr:
        a_values = jnp.pad(a_values, ((0, pr), (0, 0)))
        a_cols = jnp.pad(a_cols, ((0, pr), (0, 0)))
    if pc:
        b_values = jnp.pad(b_values, ((0, pc), (0, 0)))
        b_rows = jnp.pad(b_rows, ((0, pc), (0, 0)))

    out = pl.pallas_call(
        _spmspm_kernel,
        grid=((R + pr) // bm, (C + pc) // bn),
        in_specs=[
            pl.BlockSpec((bm, La), lambda i, j: (i, 0)),
            pl.BlockSpec((bm, La), lambda i, j: (i, 0)),
            pl.BlockSpec((bn, Lb), lambda i, j: (j, 0)),
            pl.BlockSpec((bn, Lb), lambda i, j: (j, 0)),
        ],
        out_specs=pl.BlockSpec((bm, bn), lambda i, j: (i, j)),
        out_shape=jax.ShapeDtypeStruct((R + pr, C + pc), jnp.float32),
        compiler_params=pltpu.CompilerParams(
            dimension_semantics=("parallel", "parallel")
        ),
        interpret=interpret,
    )(a_values, a_cols, b_values, b_rows)
    return out[:R, :C]
