"""Kernel registry: one dispatch layer for every op in the stack.

Each op registers up to four implementations:

  - ``pallas``:    the TPU StreamProgram kernel (built on core.streams)
  - ``interpret``: the same kernel body interpreted on CPU (tests)
  - ``xla``:       a blocked jnp implementation of the *same algorithm* —
                   lowering-representative (same FLOPs / memory behaviour),
                   used by the multi-pod dry-run where Pallas cannot lower
  - ``ref``:       the naive oracle from ref.py

Selection precedence: explicit ``impl=`` argument > ``set_default_impl()`` >
``REPRO_KERNEL_IMPL`` env var > auto (pallas on TPU backends, xla elsewhere).

The registry also owns the per-op default block-size table with an override
layer (``set_block_override``) — the seam a future autotuner writes through —
and the ``unroll_inner`` flag the roofline dry-run uses to trade lax.scan
inner loops for cost-countable python unrolls.
"""
from __future__ import annotations

import contextlib
import functools
import os
from typing import Callable

import jax

VALID_IMPLS = ("auto", "pallas", "interpret", "xla", "ref")

_REGISTRY: dict[str, dict[str, Callable]] = {}
_default_impl: str | None = None  # process-wide override set by set_default_impl()


# ---------------------------------------------------------------------------
# Registration
# ---------------------------------------------------------------------------


def register_kernel(op: str, *, impl: str) -> Callable:
    """Decorator: ``@register_kernel("spmm", impl="pallas")``."""
    if impl not in VALID_IMPLS or impl == "auto":
        raise ValueError(f"cannot register impl {impl!r}; one of {VALID_IMPLS[1:]}")

    def deco(fn: Callable) -> Callable:
        _REGISTRY.setdefault(op, {})[impl] = fn
        return fn

    return deco


def register_stream_kernel(op: str) -> Callable:
    """Register a StreamProgram-backed kernel under both ``pallas`` and
    ``interpret`` (the interpret entry is the same program, interpreted)."""

    def deco(fn: Callable) -> Callable:
        _REGISTRY.setdefault(op, {})["pallas"] = fn
        _REGISTRY[op]["interpret"] = functools.partial(fn, interpret=True)
        return fn

    return deco


def registered_ops() -> list[str]:
    return sorted(_REGISTRY)


def implementations(op: str) -> list[str]:
    if op not in _REGISTRY:
        raise KeyError(
            f"unknown kernel op {op!r}; registered ops: {registered_ops()}"
        )
    return sorted(_REGISTRY[op])


# ---------------------------------------------------------------------------
# Implementation selection
# ---------------------------------------------------------------------------


def set_default_impl(impl: str | None) -> None:
    global _default_impl
    if impl is not None and impl not in VALID_IMPLS:
        raise ValueError(f"unknown impl {impl!r}; one of {VALID_IMPLS}")
    _default_impl = impl


@contextlib.contextmanager
def default_impl(impl: str | None):
    """Scoped form of ``set_default_impl``: restores the previous default on
    exit, so harnesses and tests don't leak process-global impl state."""
    global _default_impl
    if impl is not None and impl not in VALID_IMPLS:
        raise ValueError(f"unknown impl {impl!r}; one of {VALID_IMPLS}")
    old = _default_impl
    _default_impl = impl
    try:
        yield
    finally:
        _default_impl = old


def resolve_impl(impl: str | None = None) -> str:
    impl = impl or _default_impl or os.environ.get("REPRO_KERNEL_IMPL", "auto")
    if impl not in VALID_IMPLS:
        raise ValueError(f"unknown impl {impl!r}; one of {VALID_IMPLS}")
    if impl == "auto":
        return "pallas" if jax.default_backend() == "tpu" else "xla"
    return impl


def kernel_call(op: str, *args, impl: str | None = None, **kwargs):
    """Dispatch ``op`` to its registered implementation."""
    if op not in _REGISTRY:
        raise KeyError(
            f"unknown kernel op {op!r}; registered ops: {registered_ops()}"
        )
    impl = resolve_impl(impl)
    fn = _REGISTRY[op].get(impl)
    if fn is None:
        raise NotImplementedError(
            f"kernel {op!r} has no {impl!r} implementation; "
            f"available: {implementations(op)}"
        )
    return fn(*args, **kwargs)


# ---------------------------------------------------------------------------
# Block-size defaults + override table (autotuning groundwork)
# ---------------------------------------------------------------------------

_BLOCK_DEFAULTS: dict[str, dict[str, int]] = {
    "gemm": {"bm": 256, "bk": 256, "bn": 256},
    "flash_attention": {"bq": 128, "bk": 128},
    "linear_attention": {"chunk": 32},
    "spmm": {"bm": 128},
    "bsr_spmm": {"bf": 512},
    "spmspm": {"bm": 8, "bn": 128},
    "stencil": {"bx": 8},
    "decode_attention": {"bs": 512},
}
_block_overrides: dict[str, dict[str, int]] = {}


def block_defaults(op: str, *, overrides: bool = True) -> dict[str, int]:
    """Per-op block sizes: the static defaults merged with any override.

    ``overrides=False`` returns the pristine table defaults — the autotuner's
    baseline, measured regardless of what overrides are currently active.
    """
    if not overrides:
        return dict(_BLOCK_DEFAULTS.get(op, {}))
    return {**_BLOCK_DEFAULTS.get(op, {}), **_block_overrides.get(op, {})}


def set_block_override(op: str, **sizes: int) -> None:
    """Override default block sizes for ``op`` (e.g. from an autotuner)."""
    known = _BLOCK_DEFAULTS.get(op)
    if known is None:
        raise KeyError(
            f"op {op!r} has no block-size table; known: {sorted(_BLOCK_DEFAULTS)}"
        )
    bad = set(sizes) - set(known)
    if bad:
        raise ValueError(f"{op!r} has no block parameters {sorted(bad)}")
    _block_overrides.setdefault(op, {}).update(sizes)


def clear_block_overrides(op: str | None = None) -> None:
    if op is None:
        _block_overrides.clear()
    else:
        _block_overrides.pop(op, None)


def resolve_blocks(op: str, **explicit: int | None) -> dict[str, int]:
    """The single block-geometry resolution path, every impl's source of
    truth: explicit kwarg > ``set_block_override`` > static default.

    ``explicit`` entries that are None fall through to the override/default
    layers; unknown parameter names raise (same contract as
    ``set_block_override``). Returns the complete block dict for ``op``, so
    pallas, interpret, and xla implementations of one call all receive
    identical geometry.
    """
    known = _BLOCK_DEFAULTS.get(op)
    if known is None:
        raise KeyError(
            f"op {op!r} has no block-size table; known: {sorted(_BLOCK_DEFAULTS)}"
        )
    bad = set(explicit) - set(known)
    if bad:
        raise ValueError(f"{op!r} has no block parameters {sorted(bad)}")
    resolved = {**known, **_block_overrides.get(op, {})}
    resolved.update({k: v for k, v in explicit.items() if v is not None})
    return resolved


@contextlib.contextmanager
def block_override(op: str, **sizes: int):
    """Scoped ``set_block_override``: the autotuner times each candidate
    under this so a failed or aborted search never leaks geometry."""
    old = dict(_block_overrides.get(op, {}))
    had = op in _block_overrides
    set_block_override(op, **sizes)
    try:
        yield
    finally:
        if had:
            _block_overrides[op] = old
        else:
            _block_overrides.pop(op, None)


# ---------------------------------------------------------------------------
# Roofline unroll flag (consumed by the xla implementations)
# ---------------------------------------------------------------------------

# When True, the xla paths replace their inner lax.scan (KV blocks / decay
# chunks) with python loops. XLA's HloCostAnalysis counts while-loop bodies
# ONCE regardless of trip count, so roofline-term extraction (launch/dryrun)
# traces small unrolled variants to get true FLOP/byte/collective counts.
_unroll_inner = False


def unroll_inner_enabled() -> bool:
    return _unroll_inner


@contextlib.contextmanager
def unroll_inner():
    global _unroll_inner
    old, _unroll_inner = _unroll_inner, True
    try:
        yield
    finally:
        _unroll_inner = old
