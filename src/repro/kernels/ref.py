"""Pure-jnp oracles for every kernel in this package.

These are the *naive, obviously-correct* implementations used as ground truth
by the test suite. Lowering-representative blocked implementations (same
algorithm the Pallas kernels use, expressed in jnp so they lower on any
backend) live in ops.py; the TPU kernels live in the sibling modules.
"""
from __future__ import annotations

import jax
import jax.numpy as jnp
import numpy as np

# ---------------------------------------------------------------------------
# Dense GEMM (paper Fig. 9a / Fig. 10): multi-precision, expanding accumulation
# ---------------------------------------------------------------------------


def gemm_ref(a: jax.Array, b: jax.Array, out_dtype=None, accum_dtype=jnp.float32):
    """C = A @ B with widening accumulation (paper's EXP sum-dot-product)."""
    out_dtype = out_dtype or a.dtype
    acc = jnp.matmul(a, b, preferred_element_type=accum_dtype)
    return acc.astype(out_dtype)


def gemm_scaled_ref(a, b, precision, *, out_dtype=None,
                    accum_dtype=jnp.float32, bk=None):
    """Scaled-GEMM oracle: quantize both operands per K-block exactly as
    the production kernels do, dequantize to fp32, and matmul — the ground
    truth the blocked scaled impls (which never materialize the fp32
    dequantized operands) must match bit-for-bit up to reassociation."""
    from repro.core import precision as prec
    from repro.kernels import registry

    p = prec.resolve(precision)
    K = a.shape[1]
    bk = min(registry.resolve_blocks("gemm", bk=bk)["bk"], K)
    af = prec.dequantize_blockwise(
        *prec.quantize_blockwise(a, p, axis=1, block=bk), axis=1, block=bk
    )
    bf = prec.dequantize_blockwise(
        *prec.quantize_blockwise(b, p, axis=0, block=bk), axis=0, block=bk
    )
    return gemm_ref(af, bf, out_dtype or jnp.float32, accum_dtype)


def mha_scaled_ref(q, k, v, precision, **kwargs):
    """Scaled-attention oracle: per-row quantize/dequantize of q/k/v over
    the head dimension, then the exact softmax oracle ``mha_ref``."""
    from repro.core import precision as prec

    p = prec.resolve(precision)
    deq = []
    for x in (q, k, v):
        vals, scales = prec.quantize_blockwise(
            x, p, axis=-1, block=x.shape[-1]
        )
        deq.append(prec.dequantize_blockwise(vals, scales, axis=-1))
    return mha_ref(*deq, **kwargs)


# ---------------------------------------------------------------------------
# Attention (paper Sec. V-C: FlashAttention-2 inside GPT-J)
# ---------------------------------------------------------------------------


def mha_ref(
    q: jax.Array,  # (B, H, Sq, D)
    k: jax.Array,  # (B, K, Sk, D)  -- GQA: H = K * G
    v: jax.Array,  # (B, K, Sk, D)
    *,
    causal: bool = True,
    window: int = 0,  # 0 = unbounded; else LOOKBACK window (implies k <= q)
    q_offset: int = 0,  # absolute position of q[0] (for prefill continuation)
    scale: float | None = None,
    return_lse: bool = False,
):
    """Attention oracle. ``window > 0`` is a *lookback* window: each query
    attends to keys in ``(q_pos - window, q_pos]``, so the window itself
    imposes the ``k_pos <= q_pos`` upper bound even with ``causal=False``
    (the semantics every impl shares — see the cross-impl window test).
    ``return_lse=True`` additionally returns the per-row log-sum-exp of the
    masked scores, (B, H, Sq) fp32 — the ring-attention merge statistic.
    """
    B, H, Sq, D = q.shape
    K, Sk = k.shape[1], k.shape[2]
    G = H // K
    scale = scale if scale is not None else 1.0 / np.sqrt(D)
    qf = q.reshape(B, K, G, Sq, D).astype(jnp.float32)
    s = jnp.einsum("bkgqd,bksd->bkgqs", qf, k.astype(jnp.float32)) * scale
    q_pos = jnp.arange(Sq)[:, None] + q_offset
    k_pos = jnp.arange(Sk)[None, :]
    mask = jnp.ones((Sq, Sk), dtype=bool)
    if causal or window:
        mask &= k_pos <= q_pos
    if window:
        mask &= k_pos > q_pos - window
    s = jnp.where(mask, s, -jnp.inf)
    p = jax.nn.softmax(s, axis=-1)
    p = jnp.where(jnp.isnan(p), 0.0, p)  # fully-masked rows
    o = jnp.einsum("bkgqs,bksd->bkgqd", p, v.astype(jnp.float32))
    o = o.reshape(B, H, Sq, D).astype(q.dtype)
    if not return_lse:
        return o
    lse = jax.scipy.special.logsumexp(s, axis=-1)  # -inf on fully-masked rows
    lse = jnp.maximum(lse, -1e30).reshape(B, H, Sq)  # keep merges finite
    return o, lse


def decode_attention_ref(
    q: jax.Array,  # (B, H, D) one new token per sequence
    k: jax.Array,  # (B, K, S, D) cache
    v: jax.Array,  # (B, K, S, D)
    position: jax.Array,  # (B,) int32 absolute position of the new token
    *,
    window: int = 0,
    scale: float | None = None,
    pos_offset=0,
    return_lse: bool = False,
) -> jax.Array:
    """Decode oracle. ``pos_offset`` is the absolute position of cache row
    0 (a cache *shard*'s base in ring decode); ``return_lse=True`` adds the
    (B, H) fp32 log-sum-exp the per-shard online-softmax merge consumes
    (floored at -1e30 so fully-masked shards merge as exact no-ops)."""
    B, H, D = q.shape
    K, S = k.shape[1], k.shape[2]
    G = H // K
    scale = scale if scale is not None else 1.0 / np.sqrt(D)
    qf = q.reshape(B, K, G, D).astype(jnp.float32)
    s = jnp.einsum("bkgd,bksd->bkgs", qf, k.astype(jnp.float32)) * scale
    idx = jnp.arange(S)[None, :] + pos_offset
    mask = idx <= position[:, None]
    if window:
        mask &= idx > (position[:, None] - window)
    s = jnp.where(mask[:, None, None, :], s, -jnp.inf)
    p = jax.nn.softmax(s, axis=-1)
    p = jnp.where(jnp.isnan(p), 0.0, p)  # fully-masked rows (empty shards)
    o = jnp.einsum("bkgs,bksd->bkgd", p, v.astype(jnp.float32))
    o = o.reshape(B, H, D).astype(q.dtype)
    if not return_lse:
        return o
    lse = jax.scipy.special.logsumexp(s, axis=-1)
    lse = jnp.maximum(lse, -1e30).reshape(B, H)
    return o, lse


def decode_attention_scaled_ref(q, k, v, position, *, precision, **kwargs):
    """Quantized-KV-cache decode oracle: quantize the cache per row exactly
    as the serving path does, dequantize, and run the exact oracle."""
    from repro.core import precision as prec

    kq, ks, vq, vs = prec.quantize_kv_cache(k, v, precision)
    kf = prec.dequantize_blockwise(kq, ks, axis=-1)
    vf = prec.dequantize_blockwise(vq, vs, axis=-1)
    return decode_attention_ref(q, kf, vf, position, **kwargs)


def decode_attention_paged_ref(
    q,  # (B, H, D)
    k,  # (P, K, bs, D) physical block pool
    v,  # (P, K, bs, D)
    block_table,  # (B, NB) int32 pool slots per logical cache block
    position,  # (B,)
    *,
    window: int = 0,
    scale: float | None = None,
    precision=None,
    k_scale=None,  # (P, K, bs, 1) fp32 pool scales (pre-quantized cache)
    v_scale=None,
    pos_offset=0,
    return_lse: bool = False,
):
    """Paged-cache oracle: gather each sequence's pages back into the
    contiguous (B, K, NB*bs, D) layout and run the exact contiguous oracle
    — the ground truth the blocked gather path must match bitwise."""
    from repro.core import precision as prec

    if precision is not None and k_scale is None:
        k, k_scale, v, v_scale = prec.quantize_kv_cache(k, v, precision)
    if k_scale is not None:
        k = prec.dequantize_blockwise(k, k_scale, axis=-1)
        v = prec.dequantize_blockwise(v, v_scale, axis=-1)
    B, nb = block_table.shape
    K, bs, D = k.shape[1], k.shape[2], k.shape[3]
    def gather(pool):
        return jnp.moveaxis(pool[block_table], 1, 2).reshape(B, K, nb * bs, D)
    return decode_attention_ref(
        q, gather(k), gather(v), position, window=window, scale=scale,
        pos_offset=pos_offset, return_lse=return_lse,
    )


# ---------------------------------------------------------------------------
# Chunked linear attention with data-dependent decay (RWKV6 "Finch" + SSD)
# ---------------------------------------------------------------------------


def linear_attention_scan_ref(
    r: jax.Array,  # (B, H, T, N) receptance / C
    k: jax.Array,  # (B, H, T, N) key / B
    v: jax.Array,  # (B, H, T, M) value / x
    w_log: jax.Array,  # (B, H, T, N) log-decay, <= 0
    u: jax.Array | None,  # (H, N) rwkv bonus; None => SSD mode
    s0: jax.Array | None = None,  # (B, H, N, M) incoming state
) -> tuple[jax.Array, jax.Array]:
    """Exact per-token recurrence (the oracle).

    rwkv mode (u given):  o_t = r_t . S_{t-1} + (r_t * u * k_t) v_t;
                          S_t = diag(exp(w_t)) S_{t-1} + k_t v_t^T
    ssd  mode (u None):   S_t as above; o_t = r_t . S_t
    """
    B, H, T, N = r.shape
    M = v.shape[-1]
    ssd = u is None
    S = s0 if s0 is not None else jnp.zeros((B, H, N, M), jnp.float32)

    def step(S, xs):
        rt, kt, vt, wt = xs  # (B,H,N), (B,H,N), (B,H,M), (B,H,N)
        kv = jnp.einsum("bhn,bhm->bhnm", kt, vt)
        S_new = jnp.exp(wt)[..., None] * S + kv
        if ssd:
            o = jnp.einsum("bhn,bhnm->bhm", rt, S_new)
        else:
            o = jnp.einsum("bhn,bhnm->bhm", rt, S) + jnp.einsum(
                "bhn,bhn,bhm->bhm", rt, u[None] * kt, vt
            )
        return S_new, o

    xs = tuple(
        jnp.moveaxis(x.astype(jnp.float32), 2, 0) for x in (r, k, v, w_log)
    )
    S, o = jax.lax.scan(step, S, xs)
    return jnp.moveaxis(o, 0, 2).astype(v.dtype), S


# ---------------------------------------------------------------------------
# Sparse-dense matmul (paper Fig. 9c) on the blocked-ELL value/index format
# ---------------------------------------------------------------------------


def spmm_ref(values: jax.Array, cols: jax.Array, dense: jax.Array) -> jax.Array:
    """values/cols: (R, L) ELL rows (padding: value 0, col 0); dense: (C, F)."""
    gathered = dense[cols]  # (R, L, F)
    return jnp.einsum(
        "rl,rlf->rf", values.astype(jnp.float32), gathered.astype(jnp.float32)
    ).astype(dense.dtype)


# ---------------------------------------------------------------------------
# Sparse-sparse matmul (paper Fig. 9d): index intersection
# ---------------------------------------------------------------------------


def spmspm_ref(
    a_values: jax.Array,  # (R, La) ELL rows of A
    a_cols: jax.Array,  # (R, La) sorted indices into the contraction dim
    b_values: jax.Array,  # (C, Lb) ELL *columns* of B (CSC-like)
    b_rows: jax.Array,  # (C, Lb) sorted indices into the contraction dim
    contraction_dim: int,
) -> jax.Array:
    """out[r, c] = sum over the index intersection of A.row(r) and B.col(c).

    Oracle: densify both operands and matmul. Padding entries carry value 0.
    """
    R, La = a_values.shape
    C, Lb = b_values.shape
    a_dense = jnp.zeros((R, contraction_dim), jnp.float32)
    a_dense = a_dense.at[jnp.arange(R)[:, None], a_cols].add(
        a_values.astype(jnp.float32)
    )
    b_dense = jnp.zeros((C, contraction_dim), jnp.float32)
    b_dense = b_dense.at[jnp.arange(C)[:, None], b_rows].add(
        b_values.astype(jnp.float32)
    )
    return a_dense @ b_dense.T


def spmspm_comparisons(a_cols: jax.Array, b_rows: jax.Array) -> int:
    """Paper figure of merit: index comparisons performed (GCOMP)."""
    R, La = a_cols.shape
    C, Lb = b_rows.shape
    return int(R) * int(C) * int(La) * int(Lb)


# ---------------------------------------------------------------------------
# Stencil (paper Fig. 9b): offset streams over a 3D grid, periodic boundary
# ---------------------------------------------------------------------------


def stencil_ref(
    grid: jax.Array,  # (X, Y, Z)
    offsets: np.ndarray,  # (P, 3) int offsets
    weights: jax.Array,  # (P,)
) -> jax.Array:
    out = jnp.zeros_like(grid, dtype=jnp.float32)
    for p in range(offsets.shape[0]):
        dx, dy, dz = (int(o) for o in offsets[p])
        out = out + weights[p].astype(jnp.float32) * jnp.roll(
            grid, (-dx, -dy, -dz), axis=(0, 1, 2)
        ).astype(jnp.float32)
    return out.astype(grid.dtype)
