"""Explicit collective patterns: expert-parallel all-to-all MoE, the
hierarchical psum, and the ppermute ring primitives behind sequence
parallelism (paper C5's D2D traffic patterns as jax.lax collectives under
shard_map).

The default MoE keeps all experts TP-sharded on d_ff (weights resident
everywhere); this module provides the EP alternative — experts partitioned
across the `model` axis with token all-to-alls — used in the §Perf hillclimb
where it trades weight all-gathers for activation exchange.

The ring family (``ring_scan``, ``ring_scan_carry``,
``online_softmax_merge``) is the latency-tolerant tile-rotation pattern the
paper's C4/C5 interconnect overlaps with compute: a resident operand stays
put while its partner shard hops rank→rank over ``ppermute``, (n−1) hops
total — ring flash attention (``kernels/partition.py``) and the
sequence-parallel linear-recurrence carry are both built on it.
"""
from __future__ import annotations

import dataclasses

import jax
import jax.numpy as jnp
from jax.sharding import PartitionSpec as P

from repro.diagnostics import warn_degrade
from repro.parallel.compat import shard_map

# matches the flash kernels' masked-score floor: fully-masked softmax rows
# carry lse ~= NEG, which the online merge weights to exp(NEG - NEG) ~ 1
# against a zero accumulator instead of producing -inf - -inf NaNs
NEG_LSE = -1e30


def hierarchical_psum(x, levels):
    """Reduce ``x`` across a hierarchy of mesh axes, innermost level first.

    ``levels`` is an outer→inner tuple of ``(axis_name, size)`` pairs (the
    ``PartitionPlan.levels`` vocabulary from ``kernels/partition.py``): for
    Occamy's two-level pod×model plans this fires the intra-pod (chiplet
    crossbar) psum before the cross-pod (D2D link) psum, so the narrow D2D
    hop carries one already-reduced buffer per pod instead of one per device
    — the hierarchical all-reduce the paper's Fig. 13 scaling relies on.

    Args: ``x`` — the per-device partial (any array); ``levels`` — the
    ``((axis, n), ...)`` hierarchy, outermost first. Size-1 levels are
    skipped. Returns the fully reduced array, replicated across every level's
    axis. Must run inside a ``shard_map`` whose mesh names all the axes.
    """
    for axis, n in reversed(tuple(levels)):
        if n > 1:
            x = jax.lax.psum(x, axis)
    return x


def ep_expert_ffn(disp, wi, wg, wo, act, mesh, dp, *, ep_axis="model"):
    """Expert-parallel FFN on capacity-dispatched tokens.

    disp: (B, E, C, d) batch-sharded over dp, replicated over ep_axis.
    weights: (E, d, f) etc. with E sharded over ep_axis (requires E %
    mesh[ep_axis] == 0, checked by the caller).
    Inside shard_map: all_to_all swaps the (E, local-batch) layout so each
    ep-rank holds ALL batch rows for ITS experts, runs the dense FFN, and
    all_to_alls back — two activation exchanges instead of streaming every
    expert's weights through every rank.
    """
    ep = mesh.shape[ep_axis]

    def local(disp_l, wi_l, wg_l, wo_l):
        # disp_l: (b, E, C, d) with b = B/|dp|; E global here, experts local
        b, E, C, d = disp_l.shape
        e_loc = wi_l.shape[0]  # E / ep
        # regroup (b, E, C, d) -> (ep, b, e_loc, C, d) and exchange over ep
        x = disp_l.reshape(b, ep, e_loc, C, d).transpose(1, 0, 2, 3, 4)
        x = jax.lax.all_to_all(x, ep_axis, split_axis=0, concat_axis=1,
                               tiled=False)
        # x: (ep*b, e_loc, C, d) — every rank now owns its experts' tokens
        h = jnp.einsum("becd,edf->becf",
                       x.reshape(ep * b, e_loc, C, d), wi_l,
                       preferred_element_type=jnp.float32)
        if wg_l is not None:
            g = jnp.einsum("becd,edf->becf", x.reshape(ep * b, e_loc, C, d),
                           wg_l, preferred_element_type=jnp.float32)
            h = act(g) * h
        h = h.astype(disp_l.dtype)
        y = jnp.einsum("becf,efd->becd", h, wo_l,
                       preferred_element_type=jnp.float32).astype(disp_l.dtype)
        # exchange back: (ep*b, e_loc, C, d) -> (b, E, C, d)
        y = y.reshape(ep, b, e_loc, C, d)
        y = jax.lax.all_to_all(y, ep_axis, split_axis=0, concat_axis=0,
                               tiled=False)
        return y.reshape(ep, b, e_loc, C, d).transpose(1, 0, 2, 3, 4).reshape(
            b, E, C, d
        )

    has_gate = wg is not None
    if has_gate:
        return shard_map(
            lambda d_, wi_, wg_, wo_: local(d_, wi_, wg_, wo_),
            mesh=mesh,
            in_specs=(P(dp, None, None, None), P(ep_axis, None, None),
                      P(ep_axis, None, None), P(ep_axis, None, None)),
            out_specs=P(dp, None, None, None),
            check_vma=False,
        )(disp, wi, wg, wo)
    return shard_map(
        lambda d_, wi_, wo_: local(d_, wi_, None, wo_),
        mesh=mesh,
        in_specs=(P(dp, None, None, None), P(ep_axis, None, None),
                  P(ep_axis, None, None)),
        out_specs=P(dp, None, None, None),
        check_vma=False,
    )(disp, wi, wo)


def _ring_fwd(n: int):
    return [(i, (i + 1) % n) for i in range(n)]


def _hop_send(axis: str, n: int, remote_copy: bool):
    """One ring hop as a leaf function: ``ppermute`` by default; the pallas
    async-remote-copy fast path (``core.streams.remote_ring_hop``, the RDMA
    engine the SU double-buffer hands its D2D hops to) when ``remote_copy``
    is set AND the backend is a real TPU. Anywhere else the request falls
    back to ``ppermute`` — the inter-chip DMA engine simply does not exist
    on host/GPU backends, and the two paths move identical bytes — with a
    one-shot ``ReproDegradeWarning`` so the degraded overlap is visible.
    """
    if remote_copy:
        if jax.default_backend() == "tpu":
            from repro.core.streams import remote_ring_hop

            return lambda x: remote_ring_hop(x, axis, n)
        warn_degrade(
            f"remote_copy=True requested on backend "
            f"{jax.default_backend()!r}: no inter-chip DMA engine here, "
            f"falling back to ppermute (identical bytes; the hop overlaps "
            f"via XLA collective-permute scheduling instead of the SU "
            f"double-buffer DMA)",
            key=("remote_copy_fallback", jax.default_backend()),
        )
    perm = _ring_fwd(n)
    return lambda x: jax.lax.ppermute(x, axis, perm)


@dataclasses.dataclass(frozen=True)
class HopEvent:
    """One event of a ring hop schedule, in issue order.

    Fields: ``kind`` — ``"send"`` (issue hop ``hop``'s transfer),
    ``"dma_start"`` / ``"dma_wait"`` (the remote-copy form of a send: the
    async DMA issue and its receive-semaphore wait), or ``"fold"`` (consume
    hop ``hop``'s resident block into the carry); ``hop`` — the hop index
    the event serves (``fold`` at hop t reads the block that has travelled
    t ranks); ``src`` — the buffer id the event reads (the resident block
    for sends and folds); ``dst`` — the buffer id a transfer lands in
    (None for folds; ``dma_wait`` records the landing buffer it fences).
    """

    kind: str
    hop: int
    src: int | None = None
    dst: int | None = None


def ring_schedule(hops: int, *, overlap: bool = True,
                  remote_copy: bool = False) -> tuple:
    """The ring hop schedule as data: the exact event order ``ring_scan``
    executes, checkable without devices.

    Args: ``hops`` — fold count (``ring_scan``'s ``hops``); ``overlap`` —
    double-buffered order (hop t+1's transfer issued BEFORE hop t's fold)
    vs the synchronous oracle (transfer only after the fold); ``remote_copy``
    — expand each send into its DMA pair (``dma_start`` + ``dma_wait``, the
    ``remote_ring_hop`` semantics) so the analyzer can verify the semaphore
    wait is ordered before the consuming fold.

    Returns a tuple of ``HopEvent``. Blocks live in two alternating buffers
    (hop t resides in buffer ``t % 2``) — the double-buffer discipline that
    keeps hop t+1's landing buffer disjoint from the one hop t's fold still
    reads. ``repro.analysis``'s ``overlap-schedule`` rule replays this very
    schedule through its hazard checker; ``ring_scan`` drives its jax calls
    off it, so the checked artifact is the executed artifact.
    """
    events = []

    def send(t):
        src, dst = (t - 1) % 2, t % 2
        if remote_copy:
            events.append(HopEvent("dma_start", t, src, dst))
            events.append(HopEvent("dma_wait", t, None, dst))
        else:
            events.append(HopEvent("send", t, src, dst))

    for t in range(hops):
        if overlap and t + 1 < hops:
            send(t + 1)
        events.append(HopEvent("fold", t, t % 2))
        if not overlap and t + 1 < hops:
            send(t + 1)
    return tuple(events)


def ring_scan(step_fn, carry, block, axis: str, n: int, *,
              hops: int | None = None, overlap: bool = True,
              remote_copy: bool = False):
    """Rotate ``block`` through an n-rank ``ppermute`` ring, folding it into
    ``carry`` at every hop — the primitive under ring flash attention.

    Args: ``step_fn(carry, block, t) -> carry`` — called once per hop; at
    hop ``t`` the resident ``block`` is the one originally owned by rank
    ``(axis_index - t) % n``; ``carry`` — the running accumulator; ``block``
    — the rotating operand (any pytree; every leaf hops together); ``axis``
    — the mesh axis the ring lives on; ``n`` — the ring size (static);
    ``hops`` — stop after this many steps (default ``n``: every shard
    visits every rank; a lookback window lets ring attention prune the
    tail). The permutation always spans the full ``n``-rank ring
    regardless of ``hops``.

    ``overlap`` (default) double-buffers the ring: hop ``t+1``'s transfer
    is issued BEFORE hop ``t``'s fold, so the scheduler can fly the D2D
    hop behind ``step_fn``'s compute — the software form of the SU
    double-buffer the paper's C4/C5 interconnect overlaps with FPU work.
    ``overlap=False`` keeps the synchronous schedule (permute only after
    the fold) as the correctness oracle; both orders fold bit-identical
    values, only issue order differs. ``remote_copy`` opts the hop into
    the pallas async-remote-copy path on TPU backends (see ``_hop_send``).

    Fires exactly ``hops - 1`` ppermutes — the block is consumed in place
    on the final hop, never sent home. Must run inside a ``shard_map``
    naming ``axis``. Returns the folded carry.

    The issue order is not re-derived here: the jax calls replay
    ``ring_schedule(hops, overlap=...)`` event by event (sends depend only
    on the resident block, never on ``step_fn``'s result, so an
    overlap-ordered send lets the hop fly while the kernel/merge runs).
    ``remote_copy`` swaps the transport of each send (``_hop_send``), not
    the event order — ``remote_ring_hop`` fuses its DMA start/wait pair
    inside one kernel.
    """
    hops = n if hops is None else hops
    send = _hop_send(axis, n, remote_copy)
    buffers = {0: block}
    for ev in ring_schedule(hops, overlap=overlap):
        if ev.kind == "send":
            buffers[ev.dst] = jax.tree_util.tree_map(send, buffers[ev.src])
        else:  # fold
            carry = step_fn(carry, buffers[ev.src], ev.hop)
    return carry


def online_softmax_merge(o_acc, lse_acc, o, lse):
    """Merge one attention partial into a running online-softmax accumulator.

    Args: ``o_acc`` / ``lse_acc`` — the running (unnormalised-by-partner)
    output and log-sum-exp (init ``o_acc = 0``, ``lse_acc = NEG_LSE``);
    ``o`` / ``lse`` — a new partial: softmax-normalised output and its lse
    over the same query rows, as the kernels' ``return_lse=True`` path
    emits them (``lse`` has one fewer trailing dim than ``o``).

    Returns the merged ``(o, lse)``: each side is reweighted by
    ``exp(lse_side - lse_merged)``, the exact rescaling the flash kernels
    apply per KV block — so folding ring partials in any order reproduces
    the single-device softmax. Rows fully masked in BOTH sides stay 0 (the
    NEG_LSE floor keeps every weight finite).
    """
    lse_new = jnp.logaddexp(lse_acc, lse)
    w_acc = jnp.exp(lse_acc - lse_new)[..., None]
    w = jnp.exp(lse - lse_new)[..., None]
    return (
        o_acc.astype(jnp.float32) * w_acc + o.astype(jnp.float32) * w,
        lse_new,
    )


def ring_scan_carry(chunk_fn, xs_l, s0, axis: str, n: int, *,
                    overlap: bool = True):
    """Sequence-parallel linear-recurrence carry over a ppermute ring: rank
    ``r`` scans its local chunk with the TRUE carry produced by rank
    ``r - 1`` (the D2D-pipelined version of the SSM chunk scan).

    Args: ``chunk_fn(state, xs_local) -> (state_out, ys_local)`` — the
    per-chunk scan; ``xs_l`` — this rank's chunk; ``s0`` — the global
    initial state (only rank 0's is consumed); ``axis`` / ``n`` — the ring
    axis and its (static) size; ``overlap`` — issue hop ``t+1``'s permute
    the moment ``chunk_fn`` produces its state, BEFORE the keep-merges, so
    the hop flies while the where-folds run (the carry chain itself is
    inherently serial — permute -> chunk_fn -> permute — so unlike
    ``ring_scan`` only the merge arithmetic can hide the hop here);
    ``overlap=False`` keeps the synchronous oracle order.

    Runs inside ``shard_map``. The carry threads hop by hop: after hop
    ``t`` the state that left rank ``t`` arrives at rank ``t + 1``, which
    re-scans its chunk with it — so every rank's kept result is computed
    from the exact sequential prefix state, unlike the pre-fix version
    whose single ppermute delivered each rank only its LEFT neighbour's
    locally-seeded scan. SPMD cost is ``n`` chunk evaluations per rank
    (the recurrence is inherently a depth-``n`` pipeline; the extra
    evaluations are the dead pipeline slots).

    Returns ``(ys, s_out)``: this rank's output chunk and end state (rank
    ``n - 1``'s ``s_out`` is the global final state).
    """
    me = jax.lax.axis_index(axis)
    perm = _ring_fwd(n)
    s_new, ys = chunk_fn(s0, xs_l)
    s_keep = s_new  # correct on rank 0 after hop 0; later ranks fixed below
    s_in = jax.lax.ppermute(s_new, axis, perm) if n > 1 else None
    for t in range(1, n):
        s_new, ys_t = chunk_fn(s_in, xs_l)
        if overlap and t != n - 1:
            s_in = jax.lax.ppermute(s_new, axis, perm)
        keep = me == t
        ys = jax.tree_util.tree_map(
            lambda a, b: jnp.where(keep, b, a), ys, ys_t
        )
        s_keep = jax.tree_util.tree_map(
            lambda a, b: jnp.where(keep, b, a), s_keep, s_new
        )
        if not overlap and t != n - 1:
            s_in = jax.lax.ppermute(s_new, axis, perm)
    return ys, s_keep
