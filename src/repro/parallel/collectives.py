"""Explicit collective patterns: expert-parallel all-to-all MoE and the
ring-carry sequence-parallel scan (paper C5's D2D traffic patterns as
jax.lax collectives under shard_map).

The default MoE keeps all experts TP-sharded on d_ff (weights resident
everywhere); this module provides the EP alternative — experts partitioned
across the `model` axis with token all-to-alls — used in the §Perf hillclimb
where it trades weight all-gathers for activation exchange.
"""
from __future__ import annotations

import jax
import jax.numpy as jnp
from jax.sharding import PartitionSpec as P

from repro.parallel.compat import shard_map


def hierarchical_psum(x, levels):
    """Reduce ``x`` across a hierarchy of mesh axes, innermost level first.

    ``levels`` is an outer→inner tuple of ``(axis_name, size)`` pairs (the
    ``PartitionPlan.levels`` vocabulary from ``kernels/partition.py``): for
    Occamy's two-level pod×model plans this fires the intra-pod (chiplet
    crossbar) psum before the cross-pod (D2D link) psum, so the narrow D2D
    hop carries one already-reduced buffer per pod instead of one per device
    — the hierarchical all-reduce the paper's Fig. 13 scaling relies on.

    Args: ``x`` — the per-device partial (any array); ``levels`` — the
    ``((axis, n), ...)`` hierarchy, outermost first. Size-1 levels are
    skipped. Returns the fully reduced array, replicated across every level's
    axis. Must run inside a ``shard_map`` whose mesh names all the axes.
    """
    for axis, n in reversed(tuple(levels)):
        if n > 1:
            x = jax.lax.psum(x, axis)
    return x


def ep_expert_ffn(disp, wi, wg, wo, act, mesh, dp, *, ep_axis="model"):
    """Expert-parallel FFN on capacity-dispatched tokens.

    disp: (B, E, C, d) batch-sharded over dp, replicated over ep_axis.
    weights: (E, d, f) etc. with E sharded over ep_axis (requires E %
    mesh[ep_axis] == 0, checked by the caller).
    Inside shard_map: all_to_all swaps the (E, local-batch) layout so each
    ep-rank holds ALL batch rows for ITS experts, runs the dense FFN, and
    all_to_alls back — two activation exchanges instead of streaming every
    expert's weights through every rank.
    """
    ep = mesh.shape[ep_axis]

    def local(disp_l, wi_l, wg_l, wo_l):
        # disp_l: (b, E, C, d) with b = B/|dp|; E global here, experts local
        b, E, C, d = disp_l.shape
        e_loc = wi_l.shape[0]  # E / ep
        # regroup (b, E, C, d) -> (ep, b, e_loc, C, d) and exchange over ep
        x = disp_l.reshape(b, ep, e_loc, C, d).transpose(1, 0, 2, 3, 4)
        x = jax.lax.all_to_all(x, ep_axis, split_axis=0, concat_axis=1,
                               tiled=False)
        # x: (ep*b, e_loc, C, d) — every rank now owns its experts' tokens
        h = jnp.einsum("becd,edf->becf",
                       x.reshape(ep * b, e_loc, C, d), wi_l,
                       preferred_element_type=jnp.float32)
        if wg_l is not None:
            g = jnp.einsum("becd,edf->becf", x.reshape(ep * b, e_loc, C, d),
                           wg_l, preferred_element_type=jnp.float32)
            h = act(g) * h
        h = h.astype(disp_l.dtype)
        y = jnp.einsum("becf,efd->becd", h, wo_l,
                       preferred_element_type=jnp.float32).astype(disp_l.dtype)
        # exchange back: (ep*b, e_loc, C, d) -> (b, E, C, d)
        y = y.reshape(ep, b, e_loc, C, d)
        y = jax.lax.all_to_all(y, ep_axis, split_axis=0, concat_axis=0,
                               tiled=False)
        return y.reshape(ep, b, e_loc, C, d).transpose(1, 0, 2, 3, 4).reshape(
            b, E, C, d
        )

    has_gate = wg is not None
    if has_gate:
        return shard_map(
            lambda d_, wi_, wg_, wo_: local(d_, wi_, wg_, wo_),
            mesh=mesh,
            in_specs=(P(dp, None, None, None), P(ep_axis, None, None),
                      P(ep_axis, None, None), P(ep_axis, None, None)),
            out_specs=P(dp, None, None, None),
            check_vma=False,
        )(disp, wi, wg, wo)
    return shard_map(
        lambda d_, wi_, wo_: local(d_, wi_, None, wo_),
        mesh=mesh,
        in_specs=(P(dp, None, None, None), P(ep_axis, None, None),
                  P(ep_axis, None, None)),
        out_specs=P(dp, None, None, None),
        check_vma=False,
    )(disp, wi, wo)


def ring_scan_carry(chunk_fn, xs, state, mesh, seq_axis="data"):
    """Sequence-parallel linear-recurrence carry: each rank scans its local
    chunk, then the final state rides a collective_permute ring to the next
    rank (the D2D-pipelined version of the SSM chunk scan).

    chunk_fn(state, xs_local) -> (state_out, ys_local)
    """
    n = mesh.shape[seq_axis]

    def local(xs_l, s0_l):
        # stage i receives the carry from stage i-1; ranks pipeline naturally
        s, ys = chunk_fn(s0_l, xs_l)
        s_next = jax.lax.ppermute(
            s, seq_axis, [(i, (i + 1) % n) for i in range(n)]
        )
        return ys, s_next

    return local  # composed by the caller inside its own shard_map
