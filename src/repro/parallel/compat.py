"""jax version-compatibility shims for the parallel layer.

``shard_map`` moved from jax.experimental to the jax namespace (and renamed
``check_rep`` -> ``check_vma``) across jax releases; callers import the
resolved symbol from here and always use the new-style signature.
"""
from __future__ import annotations

import jax

if hasattr(jax, "shard_map"):
    shard_map = jax.shard_map
else:
    from jax.experimental.shard_map import shard_map as _shard_map

    def shard_map(f, *, mesh=None, in_specs=None, out_specs=None,
                  check_vma=None, **kw):
        if check_vma is not None:
            kw.setdefault("check_rep", check_vma)
        return _shard_map(
            f, mesh=mesh, in_specs=in_specs, out_specs=out_specs, **kw
        )
