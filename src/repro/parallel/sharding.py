"""Divisibility-aware sharding rules (the Occamy hierarchy as GSPMD specs).

The paper's interconnect is *symmetric*: code is written cluster-agnostically
and the network guarantees constant bandwidth per hierarchy level. The GSPMD
analogue: models only declare *logical* intent (`constrain(x, "residual")`)
and this module maps intent -> PartitionSpec for whatever mesh is active.

Several assigned archs have TP-hostile dimensions (20/25 heads, vocab 51866):
every rule checks divisibility and degrades to replication instead of failing,
the software analogue of the D2D channel allocator's graceful degradation.
"""
from __future__ import annotations

from contextlib import contextmanager

import jax
import numpy as np
from jax.sharding import Mesh, NamedSharding, PartitionSpec as P

# ---------------------------------------------------------------------------
# activation-sharding intent hooks (used inside model code)
# ---------------------------------------------------------------------------

_ACTIVE: dict | None = None


def constrain(x, kind: str):
    if _ACTIVE is None:
        return x
    sharding = _ACTIVE.get(kind)
    if sharding is None:
        return x
    spec = sharding.spec if isinstance(sharding, NamedSharding) else sharding
    if len(spec) > x.ndim:
        return x
    return jax.lax.with_sharding_constraint(x, sharding)


def current_mesh() -> Mesh | None:
    """Mesh the model is being lowered for (None outside a mesh context)."""
    if _ACTIVE is None:
        return None
    return _ACTIVE.get("__mesh__")


@contextmanager
def activation_sharding(specs: dict):
    global _ACTIVE
    old, _ACTIVE = _ACTIVE, specs
    try:
        yield
    finally:
        _ACTIVE = old


@contextmanager
def use_mesh(mesh: Mesh):
    """Kernel-partitioning mesh context: every ``ops.*`` call inside picks
    the mesh up via ``kernel_mesh()`` and runs its PartitionRule under
    shard_map (kernels/partition.py). Deliberately a SEPARATE key from the
    ``__mesh__`` that ``current_mesh()`` reads: the model-level GSPMD
    machinery (moe dispatch, ssm halo shift) keys off ``current_mesh()``,
    and neither context may silently activate the other's re-routing."""
    specs = dict(_ACTIVE or {})
    specs["__kernel_mesh__"] = mesh
    with activation_sharding(specs):
        yield mesh


def kernel_mesh() -> Mesh | None:
    """The mesh ops.* should partition kernels over (None unless a
    ``use_mesh`` context is active)."""
    if _ACTIVE is None:
        return None
    return _ACTIVE.get("__kernel_mesh__")


def default_activation_specs(cfg, mesh: Mesh, kind: str) -> dict:
    """Residual stream sequence-sharded over `model` (Megatron-SP style);
    logits vocab-sharded over `model`."""
    dp = dp_axes(mesh)
    specs = {}
    if kind == "train" and cfg.seq_shard_activations:
        specs["residual"] = NamedSharding(mesh, P(dp, "model", None))
    else:
        specs["residual"] = NamedSharding(mesh, P(dp, None, None))
    specs["logits"] = NamedSharding(mesh, P(dp, None, "model"))
    # MoE dispatch/hidden buffers: batch over dp, expert hidden over model
    specs["moe_dispatch"] = NamedSharding(mesh, P(dp, None, None, None))
    specs["moe_tokens"] = NamedSharding(mesh, P(dp, None, None))
    specs["moe_hidden"] = NamedSharding(mesh, P(dp, None, None, "model"))
    if getattr(cfg, "explicit_attn_sharding", False):
        # TP-indivisible heads: q stays sequence-sharded (attention work is
        # distributed over `model` by q rows, Megatron-CP style) while K/V
        # are gathered ONCE per layer — GSPMD otherwise re-gathers a K/V
        # slice per flash block (gemma-2b: 144 gathers/2 layers).
        tp_n = axis_size(mesh, "model")
        q_ok = cfg.num_heads % tp_n == 0
        kv_ok = cfg.num_kv_heads % tp_n == 0
        specs["attn_q"] = NamedSharding(
            mesh, P(dp, None, "model", None) if q_ok else P(dp, "model", None, None)
        )
        specs["attn_kv"] = NamedSharding(
            mesh, P(dp, None, "model", None) if kv_ok else P(dp, None, None, None)
        )
    specs["__mesh__"] = mesh
    return specs


# ---------------------------------------------------------------------------
# mesh helpers
# ---------------------------------------------------------------------------


def dp_axes(mesh: Mesh) -> tuple:
    return ("pod", "data") if "pod" in mesh.axis_names else ("data",)


def axis_size(mesh: Mesh, axes) -> int:
    if axes is None:
        return 1
    if isinstance(axes, str):
        axes = (axes,)
    return int(np.prod([mesh.shape[a] for a in axes]))


def _fits(dim: int, axes, mesh: Mesh) -> bool:
    return dim % axis_size(mesh, axes) == 0


def pick(mesh: Mesh, dim: int, *candidates):
    """First candidate axis (or axis tuple) that divides `dim`, else None."""
    for c in candidates:
        if c is not None and _fits(dim, c, mesh):
            return c
    return None


# ---------------------------------------------------------------------------
# parameter sharding rules
# ---------------------------------------------------------------------------

# leaf-name -> (logical role per trailing dim). Leading stacked-layer dims are
# auto-detected by rank and always unsharded (scan axis).
# roles: "d_in"/"d_out" (embedding dim), "heads" (H*hd or K*hd flat),
#        "ff" (d_ff or 2*d_ff), "vocab", "expert", "none"
_PARAM_ROLES = {
    "embed": ("vocab", "d_out"),
    "lm_head": ("d_in", "vocab"),
    "wq": ("d_in", "heads_q"),
    "wk": ("d_in", "heads_kv"),
    "wv": ("d_in", "heads_kv"),
    "wo": ("heads_q", "d_out"),
    "bq": ("heads_q",),
    "bk": ("heads_kv",),
    "bv": ("heads_kv",),
    "wi": ("d_in", "ff"),
    "wg": ("d_in", "ff"),
    "wo_mlp": ("ff", "d_out"),
    # whisper cross-attention
    "cwq": ("d_in", "heads_q"),
    "cwk": ("d_in", "heads_kv"),
    "cwv": ("d_in", "heads_kv"),
    "cwo": ("heads_q", "d_out"),
    "cbq": ("heads_q",),
    "cbk": ("heads_kv",),
    "cbv": ("heads_kv",),
    "frontend_proj": ("d_in", "d_out"),
    "router": ("d_in", "none"),
    "moe_wi": ("expert", "d_in", "ff"),
    "moe_wg": ("expert", "d_in", "ff"),
    "moe_wo": ("expert", "ff", "d_out"),
    # rwkv6 time-mix / channel-mix
    "wr_t": ("d_in", "rwkv_heads"),
    "wk_t": ("d_in", "rwkv_heads"),
    "wv_t": ("d_in", "rwkv_heads"),
    "wg_t": ("d_in", "rwkv_heads"),
    "wo_t": ("rwkv_heads", "d_out"),
    "w_lora_a": ("d_in", "none"),
    "w_lora_b": ("none", "rwkv_heads"),
    "wk_c": ("d_in", "ff"),
    "wv_c": ("ff", "d_out"),
    "wr_c": ("d_in", "d_out"),
    # hybrid (mamba/SSD path)
    "ssm_in": ("d_in", "ssm_inner"),
    "ssm_out": ("ssm_inner", "d_out"),
    "ssm_bc": ("d_in", "none"),
    "ssm_dt": ("d_in", "ssm_heads"),
}


def _role_spec(role: str, dim: int, cfg, mesh: Mesh, mode: str):
    """Map one logical role to a mesh axis (or None)."""
    tp = "model"
    dp = dp_axes(mesh)
    hd = cfg.resolved_head_dim()
    fsdp_ok = (mode == "train" and cfg.fsdp) or (
        mode == "serve" and cfg.weights_2d_tp
    )
    fsdp = dp if fsdp_ok else None

    if role == "none":
        return None
    if role == "vocab":
        return pick(mesh, dim, tp)
    if role in ("d_in", "d_out"):
        return pick(mesh, dim, fsdp)
    if role == "ff":
        return pick(mesh, dim, tp)
    if role == "expert":
        return None  # experts TP'd on ff; EP variant handled in collectives
    if role == "heads_q":
        nh = dim // hd
        return tp if nh % axis_size(mesh, tp) == 0 else pick(mesh, dim, fsdp)
    if role == "heads_kv":
        nh = dim // hd
        return tp if nh % axis_size(mesh, tp) == 0 else pick(mesh, dim, fsdp)
    if role == "rwkv_heads":
        nh = dim // max(cfg.resolved_head_dim(), 1)
        return tp if nh % axis_size(mesh, tp) == 0 else pick(mesh, dim, fsdp)
    if role == "ssm_inner":
        nh = dim // max(cfg.ssm_head_dim, 1)
        return tp if nh % axis_size(mesh, tp) == 0 else pick(mesh, dim, fsdp)
    if role == "ssm_heads":
        return pick(mesh, dim, tp)
    raise ValueError(role)


def _leaf_name(path) -> str:
    for entry in reversed(path):
        if hasattr(entry, "key"):
            return str(entry.key)
    return ""


def param_specs(cfg, params_tree, mesh: Mesh, mode: str = "train"):
    """Tree of PartitionSpec matching params_tree (shapes or arrays)."""

    def one(path, leaf):
        name = _leaf_name(path)
        shape = leaf.shape
        roles = _PARAM_ROLES.get(name)
        if roles is None:
            return P()  # norms, scalars, unknown leaves: replicate
        lead = len(shape) - len(roles)
        axes = [None] * lead + [
            _role_spec(r, shape[lead + i], cfg, mesh, mode)
            for i, r in enumerate(roles)
        ]
        # a mesh axis may appear only once per spec: drop duplicates
        seen: set = set()
        final = []
        for a in axes:
            names = (a,) if isinstance(a, str) else tuple(a or ())
            if any(n in seen for n in names):
                final.append(None)
            else:
                seen.update(names)
                final.append(a)
        return P(*final)

    return jax.tree_util.tree_map_with_path(one, params_tree)


def param_shardings(cfg, params_tree, mesh: Mesh, mode: str = "train"):
    return jax.tree.map(
        lambda s: NamedSharding(mesh, s),
        param_specs(cfg, params_tree, mesh, mode),
        is_leaf=lambda x: isinstance(x, P),
    )


# ---------------------------------------------------------------------------
# batch / cache sharding
# ---------------------------------------------------------------------------


def batch_specs(cfg, batch_tree, mesh: Mesh):
    """Shard the leading batch dim over dp where divisible."""
    dp = dp_axes(mesh)

    def one(leaf):
        if not leaf.shape:
            return P()
        b = leaf.shape[0]
        axes = pick(mesh, b, dp, dp[-1:])
        return P(*([axes] + [None] * (len(leaf.shape) - 1)))

    return jax.tree.map(one, batch_tree)


def cache_specs(cfg, cache_tree, mesh: Mesh):
    """KV caches: (L, B, K, S, hd) — B over dp if divisible, S over model
    (flash-decode style partial-softmax sharding); SSM states (L, B, H, N, M):
    B over dp, H over model if divisible."""
    dp = dp_axes(mesh)
    tp = "model"

    def one(path, leaf):
        name = _leaf_name(path)
        shape = leaf.shape
        if name in ("k", "v", "cross_k", "cross_v") and len(shape) == 5:
            L_, B, K, S, hd = shape
            b_ax = pick(mesh, B, dp, dp[-1:])
            if b_ax is None:
                s_ax = pick(mesh, S, (dp[-1], tp), tp, dp[-1:])
            else:
                s_ax = pick(mesh, S, tp)
            return P(None, b_ax, None, s_ax, None)
        if name in ("ssm_state",) and len(shape) == 5:
            L_, B, H, N, M = shape
            b_ax = pick(mesh, B, dp, dp[-1:])
            h_ax = pick(mesh, H, tp)
            return P(None, b_ax, h_ax, None, None)
        if len(shape) >= 2:  # token-shift states etc: (L, B, ...)
            b_ax = pick(mesh, shape[1], dp, dp[-1:])
            return P(*([None, b_ax] + [None] * (len(shape) - 2)))
        return P()

    return jax.tree_util.tree_map_with_path(one, cache_tree)


def named(mesh: Mesh, spec_tree):
    return jax.tree.map(
        lambda s: NamedSharding(mesh, s),
        spec_tree,
        is_leaf=lambda x: isinstance(x, P),
    )
