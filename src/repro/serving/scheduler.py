"""Continuous-batching request scheduler (host side, deterministic).

Pure Python on purpose: no jax import, no device state. The scheduler owns
*bookkeeping only* — request queues, decode slots, and the physical cache
block ledger — and emits an ordered event trace; the engine owns the
tensors. That split is what makes the continuous-batching invariants
checkable device-free: the test battery and the ``paged-gather-coverage``
analysis rule replay synthetic workloads through this exact class and
audit the trace (ownership disjointness, FCFS admission, zero leaks)
without compiling anything.

Lifecycle of a request::

    WAITING --admit--> RUNNING --retire--> FINISHED
       ^                  |
       +----preempt-------+   (block exhaustion: blocks freed, request
                               re-queued at the FRONT of its priority
                               class with its generated prefix kept)

Scheduling policy, all deterministic:

  - admission is FCFS *within* a priority class; classes are served
    highest priority first (ties broken by arrival step, then request id)
  - a request is admitted only when a decode slot is free AND the
    allocator can cover its prompt plus one decode block
  - on block exhaustion the victim is the lowest-priority
    most-recently-admitted running sequence (LIFO within class), so the
    oldest work is never starved by the newest
  - preempted requests re-enter at the front of their class queue:
    combined with FCFS admission this bounds bypasses, so every admitted
    request eventually finishes (the no-starvation test's invariant)
"""
from __future__ import annotations

import dataclasses
import math
from collections import deque

# physical block 0 is the shared scratch page: inactive decode-slot rows and
# unwritten block-table tail entries point at it, live prefixes never do
NULL_BLOCK = 0

WAITING, RUNNING, FINISHED = "WAITING", "RUNNING", "FINISHED"


@dataclasses.dataclass
class Request:
    """One generation request. ``arrival`` is the open-loop arrival time in
    engine *steps* (virtual time, so admission traces are seed-reproducible
    across machines); ``priority`` is higher-wins."""

    rid: int
    prompt: tuple
    max_new_tokens: int
    priority: int = 0
    arrival: int = 0

    def __post_init__(self):
        self.prompt = tuple(int(t) for t in self.prompt)
        if not self.prompt:
            raise ValueError(f"request {self.rid}: empty prompt")
        if self.max_new_tokens < 1:
            raise ValueError(f"request {self.rid}: max_new_tokens < 1")


@dataclasses.dataclass
class Sequence:
    """Scheduler-side state of one admitted (or re-queued) request."""

    req: Request
    slot: int | None = None
    blocks: list = dataclasses.field(default_factory=list)
    generated: list = dataclasses.field(default_factory=list)
    admitted_at: int = -1  # step of the most recent admission (LIFO victim key)
    preemptions: int = 0
    saved_payload: object = None  # engine's host copy of the KV blocks

    @property
    def rid(self):
        return self.req.rid

    def tokens_cached(self) -> int:
        """Tokens whose KV lives in cache blocks: the prompt plus every
        generated token except the newest (written by the NEXT decode)."""
        return len(self.req.prompt) + max(0, len(self.generated) - 1)

    def next_position(self) -> int:
        """Absolute position of the token the next decode step processes."""
        return len(self.req.prompt) + len(self.generated) - 1

    def blocks_needed_now(self, block_size: int):
        """Logical block indices covering the cached prefix plus the token
        the next decode writes."""
        return list(range(self.next_position() // block_size + 1))


class BlockAllocator:
    """Fixed-pool physical block ledger. FIFO free list (deterministic),
    with ``NULL_BLOCK`` permanently reserved as the scratch page."""

    def __init__(self, num_blocks: int, *, reserved=(NULL_BLOCK,)):
        if num_blocks < 2:
            raise ValueError("need >= 2 blocks (one is the null page)")
        self.num_blocks = num_blocks
        self.reserved = tuple(sorted(set(reserved)))
        self.free = deque(
            b for b in range(num_blocks) if b not in self.reserved
        )
        self.owner: dict[int, int] = {}  # block -> rid

    def available(self) -> int:
        return len(self.free)

    def alloc(self, rid: int, n: int):
        """Pop ``n`` blocks for ``rid``; None (nothing popped) if short."""
        if n < 0:
            raise ValueError(f"alloc({n})")
        if len(self.free) < n:
            return None
        got = [self.free.popleft() for _ in range(n)]
        for b in got:
            self.owner[b] = rid
        return got

    def release(self, rid: int, blocks) -> None:
        for b in blocks:
            if self.owner.get(b) != rid:
                raise RuntimeError(
                    f"release: block {b} not owned by rid {rid} "
                    f"(owner={self.owner.get(b)})"
                )
            del self.owner[b]
            self.free.append(b)

    def owned_by(self, rid: int):
        return sorted(b for b, r in self.owner.items() if r == rid)

    def check(self):
        """Ledger self-audit: free + owned partitions the non-reserved pool."""
        problems = []
        free = list(self.free)
        owned = set(self.owner)
        if len(set(free)) != len(free):
            problems.append("duplicate blocks on the free list")
        if owned & set(free):
            problems.append(f"blocks both free and owned: {owned & set(free)}")
        if set(self.reserved) & (owned | set(free)):
            problems.append("reserved block leaked into the pool")
        pool = set(range(self.num_blocks)) - set(self.reserved)
        if (set(free) | owned) != pool:
            problems.append(
                f"pool not partitioned: missing {pool - set(free) - owned}"
            )
        return problems


class ContinuousBatchingScheduler:
    """Queues + slots + block ledger for the continuous-batching engine.

    The engine drives it step by step: ``submit`` requests (any time),
    ``admit(step)`` to fill free slots from the queues, ``ensure_block``
    before each sequence's decode (triggering preemption on exhaustion),
    ``record_token`` after, ``retire`` on EOS/max-len. Every transition
    appends to ``events`` — the reproducible admission trace the bench
    hashes and the analysis rule audits.
    """

    def __init__(self, *, num_blocks: int, block_size: int, max_slots: int,
                 max_blocks_per_seq: int | None = None):
        if block_size < 1 or max_slots < 1:
            raise ValueError("block_size and max_slots must be >= 1")
        self.block_size = block_size
        self.max_slots = max_slots
        self.max_blocks_per_seq = max_blocks_per_seq
        self.allocator = BlockAllocator(num_blocks)
        self.pending: list[Request] = []  # submitted, arrival in the future
        self.queues: dict[int, deque] = {}  # priority -> deque[Sequence]
        self.running: dict[int, Sequence] = {}  # slot -> Sequence
        self.finished: dict[int, Sequence] = {}
        self.events: list[tuple] = []
        self._seen_rids: set[int] = set()

    # -- submission ---------------------------------------------------------

    def submit(self, req: Request) -> None:
        if req.rid in self._seen_rids:
            raise ValueError(f"duplicate rid {req.rid}")
        self._seen_rids.add(req.rid)
        total = math.ceil(
            (len(req.prompt) + req.max_new_tokens) / self.block_size
        )
        cap = self.max_blocks_per_seq or (self.allocator.num_blocks - 1)
        limit = min(cap, self.allocator.num_blocks - 1)
        if total > limit:
            raise ValueError(
                f"request {req.rid} can never fit: needs {total} blocks, "
                f"per-sequence limit is {limit}"
            )
        self.pending.append(req)
        self.events.append(("submit", req.arrival, req.rid))

    def blocks_for_prompt(self, prompt_len: int) -> int:
        return math.ceil(prompt_len / self.block_size)

    # -- admission ----------------------------------------------------------

    def _queue_for(self, priority: int) -> deque:
        return self.queues.setdefault(priority, deque())

    def _free_slot(self):
        for s in range(self.max_slots):
            if s not in self.running:
                return s
        return None

    def admit(self, step: int):
        """Move arrived requests into the queues, then admit queue heads
        while a slot and enough blocks exist. Returns the admitted
        ``Sequence`` list in admission order (FCFS within class, highest
        class first); resumed sequences carry their generated prefix and
        ``saved_payload`` for the engine to restore."""
        still_pending = []
        arrivals = []
        for req in self.pending:
            (arrivals if req.arrival <= step else still_pending).append(req)
        self.pending = still_pending
        arrivals.sort(key=lambda r: (r.arrival, r.rid))
        for req in arrivals:
            self._queue_for(req.priority).append(Sequence(req))

        admitted = []
        while True:
            seq = self._next_admittable()
            if seq is None:
                break
            slot = self._free_slot()
            n = max(1, len(seq.blocks_needed_now(self.block_size)))
            got = self.allocator.alloc(seq.rid, n)
            if got is None:  # head-of-line blocks short: stop (FCFS, no skip)
                self._queue_for(seq.req.priority).appendleft(seq)
                break
            seq.slot = slot
            seq.blocks = got
            seq.admitted_at = step
            self.running[slot] = seq
            admitted.append(seq)
            self.events.append(
                ("admit", step, seq.rid, slot, tuple(got), seq.preemptions)
            )
        return admitted

    def _next_admittable(self):
        if self._free_slot() is None:
            return None
        for prio in sorted(self.queues, reverse=True):
            q = self.queues[prio]
            if q:
                return q.popleft()
        return None

    # -- block growth + preemption ------------------------------------------

    def ensure_block(self, seq: Sequence, step: int) -> bool:
        """Guarantee a cache block exists for the position ``seq``'s next
        decode writes. On exhaustion, preempt victims (lowest priority,
        most recently admitted) until space frees — possibly ``seq``
        itself, in which case False is returned and the engine must skip
        its decode this step."""
        pos = seq.next_position()
        if self.max_blocks_per_seq and (
            pos // self.block_size >= self.max_blocks_per_seq
        ):
            raise RuntimeError(
                f"rid {seq.rid}: position {pos} exceeds max_blocks_per_seq"
            )
        while pos // self.block_size >= len(seq.blocks):
            got = self.allocator.alloc(seq.rid, 1)
            if got is not None:
                seq.blocks.extend(got)
                self.events.append(("grow", step, seq.rid, got[0]))
                continue
            victim = self._pick_victim()
            self.preempt(victim, step)
            if victim is seq:
                return False
        return True

    def _pick_victim(self) -> Sequence:
        # lowest priority first, then most recently admitted, then rid
        return max(
            self.running.values(),
            key=lambda s: (-s.req.priority, s.admitted_at, s.rid),
        )

    def preempt(self, seq: Sequence, step: int) -> None:
        """Release ``seq``'s slot and blocks and re-queue it at the FRONT
        of its class. The engine saves/restores the KV payload around this
        (``Sequence.saved_payload``)."""
        del self.running[seq.slot]
        freed = tuple(seq.blocks)
        self.allocator.release(seq.rid, seq.blocks)
        self.events.append(("preempt", step, seq.rid, seq.slot, freed))
        seq.blocks = []
        seq.slot = None
        seq.preemptions += 1
        self._queue_for(seq.req.priority).appendleft(seq)

    # -- completion ---------------------------------------------------------

    def record_token(self, seq: Sequence, token: int) -> None:
        seq.generated.append(int(token))

    def should_retire(self, seq: Sequence, eos_id: int | None) -> bool:
        if len(seq.generated) >= seq.req.max_new_tokens:
            return True
        return eos_id is not None and bool(seq.generated) and (
            seq.generated[-1] == eos_id
        )

    def retire(self, seq: Sequence, step: int) -> None:
        del self.running[seq.slot]
        freed = tuple(seq.blocks)
        self.allocator.release(seq.rid, seq.blocks)
        self.events.append(("retire", step, seq.rid, seq.slot, freed))
        seq.blocks = []
        self.finished[seq.rid] = seq

    # -- introspection ------------------------------------------------------

    def idle(self) -> bool:
        return not (self.pending or self.running
                    or any(self.queues.values()))

    def leaked_blocks(self) -> int:
        """Blocks neither free nor owned by a live sequence (must be 0)."""
        live = {b for s in self.running.values() for b in s.blocks}
        return (self.allocator.num_blocks - len(self.allocator.reserved)
                - self.allocator.available() - len(live))

    def admission_trace(self):
        """The (step, rid, slot) admission order — the seed-reproducible
        artifact the bench hashes and CI pins."""
        return tuple(
            (e[1], e[2], e[3]) for e in self.events if e[0] == "admit"
        )


# -- first-class transitions (tier-C model-checking seam) ---------------------
#
# The engine drives the scheduler through fine-grained method calls
# (submit / admit / ensure_block / record_token / retire). For exhaustive
# exploration those calls are regrouped into three *atomic actions* — the
# smallest steps whose interleavings are externally schedulable:
#
#   ("submit", rid)   submit request ``rid`` with arrival = current step
#   ("admit",)        one admission pass (arrivals -> queues -> slots)
#   ("decode", slot)  one decode step for the sequence in ``slot``:
#                     ensure_block (may preempt, possibly itself) then
#                     record_token and retire when max_new_tokens is hit
#
# ``apply_action`` applies one action to a live scheduler; ``canonical_state``
# hashes the resulting ledger into the same tuple shape the abstract model in
# ``analysis.explore`` uses, so the bisimulation test can assert, transition
# by transition, that the checked model never drifts from this class.

ACTIONS = ("submit", "admit", "decode")


def default_token(seq: Sequence) -> int:
    """Deterministic token stream for model checking: 1, 2, 3, … per
    sequence. Token *values* never influence scheduling (eos is disabled),
    so any fixed stream explores the full reachable ledger space."""
    return len(seq.generated) + 1


def apply_action(sched: ContinuousBatchingScheduler, action: tuple,
                 step: int, *, requests, token_for=default_token):
    """Apply one atomic ``(state, action) -> state`` transition.

    ``requests`` maps rid -> :class:`Request` template; submits stamp the
    template's arrival to ``step`` so the request is immediately
    admissible. Returns the admitted ``(rid, slot)`` pairs for an admit
    action (the bisimulation test compares these against the abstract
    model's), else an empty list.
    """
    kind = action[0]
    if kind == "submit":
        req = requests[action[1]]
        sched.submit(dataclasses.replace(req, arrival=step))
        return []
    if kind == "admit":
        return [(seq.rid, seq.slot) for seq in sched.admit(step)]
    if kind == "decode":
        seq = sched.running[action[1]]
        if not sched.ensure_block(seq, step):
            return []  # preempted itself: the engine skips its decode
        sched.record_token(seq, token_for(seq))
        if sched.should_retire(seq, None):
            sched.retire(seq, step)
        return []
    raise ValueError(f"unknown action {action!r}")


def canonical_state(sched: ContinuousBatchingScheduler):
    """Hashable canonical ledger state, absolute time abstracted away.

    ``admitted_at`` steps are compressed to dense ranks over the running
    set (ties — same admit call — share a rank), which preserves the
    ``_pick_victim`` ordering while letting states reached at different
    wall-steps merge. Shape matches ``analysis.explore.SchedulerModel``'s
    ``ledger_view`` exactly::

        (queues, running, pending, free, finished)
        queues  = ((priority, (seq, …)), …)    nonempty, ascending priority
        running = ((slot, seq), …)             ascending slot
        seq     = (rid, n_generated, preemptions, adm_rank, blocks)
    """
    ranks = {at: i for i, at in enumerate(
        sorted({s.admitted_at for s in sched.running.values()}))}

    def seq_t(s: Sequence, rank: int):
        return (s.rid, len(s.generated), s.preemptions, rank,
                tuple(s.blocks))

    queues = tuple(
        (prio, tuple(seq_t(s, -1) for s in sched.queues[prio]))
        for prio in sorted(sched.queues) if sched.queues[prio]
    )
    running = tuple(
        (slot, seq_t(s, ranks[s.admitted_at]))
        for slot, s in sorted(sched.running.items())
    )
    pending = tuple(r.rid for r in
                    sorted(sched.pending, key=lambda r: (r.arrival, r.rid)))
    return (queues, running, pending, tuple(sched.allocator.free),
            tuple(sorted(sched.finished)))
