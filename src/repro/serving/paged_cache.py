"""PagedKVCache: block-table KV storage for the serving engine.

Physical layout (``core/sparse.py``-style registered pytree): per layer a
pool of fixed-size KV *pages* — ``k_pool``/``v_pool`` shaped
``(nl, P, K, bs, hd)`` — addressed through per-sequence block tables the
scheduler maintains (``serving/scheduler.py`` owns which physical page
belongs to whom; this module owns the tensors). Page ``NULL_BLOCK`` (0) is
the shared scratch page: inactive decode slots and unwritten table tails
point at it, and the decode mask makes every read of it an exact no-op.

With a ``policy`` (``core.precision``) the pools hold the cache *narrow*:
values in the policy's compute dtype plus per-row fp32 scales
``(nl, P, K, bs, 1)`` from the same per-row quantization
``precision.quantize_kv_cache`` applies — each page is dequantized at use
inside ``decode_attention``'s fp32 online softmax, so the resident cache
(the HBM footprint that dominates serving) shrinks by the width ratio.

Everything here is pure: writes return a new ``PagedKVCache`` (jit/donate
friendly); allocation lives in the scheduler's ``BlockAllocator``.
"""
from __future__ import annotations

import dataclasses

import jax
import jax.numpy as jnp

from repro.serving.scheduler import NULL_BLOCK  # re-export: table sentinel

__all__ = ["PagedKVCache", "NULL_BLOCK", "init_paged_cache"]


@jax.tree_util.register_pytree_node_class
@dataclasses.dataclass
class PagedKVCache:
    """KV page pools (+ optional quantization scales) for every layer.

    ``k_pool``/``v_pool``: (nl, P, K, bs, hd); ``k_scale``/``v_scale``:
    (nl, P, K, bs, 1) fp32 when ``policy`` is set, else None. ``block_size``
    and ``policy`` are static aux data (they select traced code paths).
    """

    k_pool: jax.Array
    v_pool: jax.Array
    k_scale: jax.Array | None
    v_scale: jax.Array | None
    block_size: int
    policy: str | None = None

    def tree_flatten(self):
        return (
            (self.k_pool, self.v_pool, self.k_scale, self.v_scale),
            (self.block_size, self.policy),
        )

    @classmethod
    def tree_unflatten(cls, aux, children):
        return cls(*children, *aux)

    @property
    def num_blocks(self) -> int:
        return self.k_pool.shape[1]

    @property
    def quantized(self) -> bool:
        return self.k_scale is not None

    # -- pure writes --------------------------------------------------------

    def write_prompt(self, block_ids, k_rows, v_rows) -> "PagedKVCache":
        """Scatter a prefilled prompt's KV into this cache's pages.

        ``block_ids``: (nbp,) int32 physical pages (the allocator's grant,
        in logical order); ``k_rows``/``v_rows``: (nl, nbp, K, bs, hd) — the
        prompt cache reshaped to page granularity (tail page zero-padded;
        the padding is never unmasked). Quantizes per row first when this
        cache holds a narrow policy."""
        k_rows, ks, v_rows, vs = _maybe_quantize(k_rows, v_rows, self.policy)
        new = dataclasses.replace(
            self,
            k_pool=self.k_pool.at[:, block_ids].set(
                k_rows.astype(self.k_pool.dtype)
            ),
            v_pool=self.v_pool.at[:, block_ids].set(
                v_rows.astype(self.v_pool.dtype)
            ),
        )
        if ks is not None:
            new = dataclasses.replace(
                new,
                k_scale=self.k_scale.at[:, block_ids].set(ks),
                v_scale=self.v_scale.at[:, block_ids].set(vs),
            )
        return new

    def gather_blocks(self, block_ids):
        """Host-transferable copy of the listed pages (the preemption
        payload): dict of (nl, n, K, bs, hd) values (+ scales when
        quantized). Bitwise round-trips through ``restore_blocks``."""
        out = {
            "k": self.k_pool[:, block_ids],
            "v": self.v_pool[:, block_ids],
        }
        if self.quantized:
            out["k_scale"] = self.k_scale[:, block_ids]
            out["v_scale"] = self.v_scale[:, block_ids]
        return out

    def restore_blocks(self, block_ids, payload) -> "PagedKVCache":
        """Write a ``gather_blocks`` payload into (possibly different)
        physical pages — the resume half of the preemption round-trip."""
        new = dataclasses.replace(
            self,
            k_pool=self.k_pool.at[:, block_ids].set(payload["k"]),
            v_pool=self.v_pool.at[:, block_ids].set(payload["v"]),
        )
        if self.quantized:
            new = dataclasses.replace(
                new,
                k_scale=self.k_scale.at[:, block_ids].set(payload["k_scale"]),
                v_scale=self.v_scale.at[:, block_ids].set(payload["v_scale"]),
            )
        return new


def _maybe_quantize(k_rows, v_rows, policy):
    if policy is None:
        return k_rows, None, v_rows, None
    from repro.core import precision as prec

    kq, ks, vq, vs = prec.quantize_kv_cache(k_rows, v_rows, policy)
    return kq, ks, vq, vs


def init_paged_cache(cfg, *, num_blocks: int, block_size: int,
                     policy: str | None = None) -> PagedKVCache:
    """Zero-initialized pools sized from the model config. With a policy,
    values live in the policy's compute dtype with unit fp32 scales."""
    hd = cfg.resolved_head_dim()
    K, nl = cfg.num_kv_heads, cfg.num_layers
    if policy is None:
        dt = jnp.dtype(cfg.dtype)
        k_scale = v_scale = None
    else:
        from repro.core import precision as prec

        dt = prec.resolve(policy).compute_dtype
        k_scale = jnp.ones((nl, num_blocks, K, block_size, 1), jnp.float32)
        v_scale = jnp.ones((nl, num_blocks, K, block_size, 1), jnp.float32)
    shape = (nl, num_blocks, K, block_size, hd)
    return PagedKVCache(
        k_pool=jnp.zeros(shape, dt),
        v_pool=jnp.zeros(shape, dt),
        k_scale=k_scale,
        v_scale=v_scale,
        block_size=block_size,
        policy=policy,
    )
