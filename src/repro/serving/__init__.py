"""Serving layer: continuous batching over a paged KV cache.

The package splits along the host/device boundary:

  - ``scheduler``   — pure-Python request scheduler + block allocator (no
                      jax import: the analysis plan rule replays it
                      device-free)
  - ``paged_cache`` — the ``PagedKVCache`` pytree (physical KV block pools,
                      optionally fp8-quantized) and its pure write helpers
  - ``ring_decode`` — cache-sharded decode over the ``data`` axis
                      (per-shard partials folded through
                      ``collectives.ring_scan`` + ``online_softmax_merge``)
  - ``engine``      — the continuous-batching loop wiring the scheduler to
                      jitted paged prefill/decode steps (imports the model
                      stack; import it explicitly)
"""
from repro.serving.paged_cache import PagedKVCache, NULL_BLOCK
from repro.serving.scheduler import (
    BlockAllocator,
    ContinuousBatchingScheduler,
    Request,
)

__all__ = [
    "BlockAllocator",
    "ContinuousBatchingScheduler",
    "NULL_BLOCK",
    "PagedKVCache",
    "Request",
]
