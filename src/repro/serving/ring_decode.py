"""Cache-sharded ring decode over the ``data`` axis.

The serving case: a paged KV cache bigger than one device's HBM. The page
pools shard across ``data`` — rank r owns the pages holding every
sequence's logical cache blocks ``[r*NB_l, (r+1)*NB_l)`` — and each decode
step folds per-shard attention partials into the exact softmax:

  1. every rank runs the registered paged ``decode_attention`` over its
     local table slab with ``pos_offset = r * NB_l * bs`` and
     ``return_lse=True`` → a partial ``(o_r, lse_r)``;
  2. the partials rotate through ``collectives.ring_scan`` (the same
     double-buffered ppermute ring flash attention hops KV through —
     ``overlap=True`` flies hop t+1 behind hop t's fold);
  3. each rank stashes every arriving partial at its *global* shard index
     and folds the full set in rank order 0..n-1 through
     ``collectives.online_softmax_merge``.

Folding in global order — not arrival order, which differs per rank — is
what makes the result *replicated bitwise*: every rank performs the
identical merge chain, so the output legally carries a replicated
out_spec and is bit-equal to ``ring_decode_reference`` (the same chain on
one device). Fully-masked shards (a sequence shorter than a shard's base
offset) carry ``lse ≈ NEG_LSE`` and merge as exact no-ops.
"""
from __future__ import annotations

import jax
import jax.numpy as jnp
from jax.sharding import PartitionSpec as P

from repro.kernels import registry
from repro.parallel import collectives
from repro.parallel.compat import shard_map

__all__ = ["ring_decode", "ring_decode_reference"]


def _shard_partial(q, k_pool, v_pool, block_table, position, *, base,
                   window, scale, k_scale, v_scale, impl):
    """One shard's paged decode partial: (o, lse), lse fp32 (B, H).

    Calls the registered impl directly (the partition-rule idiom) — this
    runs inside ``shard_map``, below the mesh-aware dispatch seam."""
    return registry.kernel_call(
        "decode_attention", q, k_pool, v_pool, position, impl=impl,
        window=window, scale=scale, block_table=block_table,
        k_scale=k_scale, v_scale=v_scale, pos_offset=base, return_lse=True,
    )


def ring_decode(q, k_pool, v_pool, block_table, position, mesh, *,
                axis: str = "data", window: int = 0, scale=None,
                k_scale=None, v_scale=None, impl=None, overlap: bool = True):
    """Decode against a cache sharded over ``mesh[axis]``.

    Args: ``q`` (B, H, D) and ``position`` (B,) — replicated; ``k_pool``/
    ``v_pool`` (P, K, bs, D) — sharded on P (rank r holds pages
    ``[r*P/n, (r+1)*P/n)``); ``block_table`` (B, NB) — sharded on columns,
    with the convention that each entry indexes the *owning rank's local*
    pool (the engine's per-shard allocators hand out local page ids);
    ``k_scale``/``v_scale`` — optional (P, K, bs, 1) pool scales, sharded
    like the pools. ``overlap=False`` is the synchronous-ring oracle —
    bit-identical fold values, only transfer issue order differs.

    Returns (B, H, D) in ``q.dtype``, replicated across ``axis`` and
    bitwise-equal to ``ring_decode_reference`` on the unsharded operands.
    """
    n = mesh.shape[axis]
    B, NB = block_table.shape
    bs = k_pool.shape[2]
    if NB % n or k_pool.shape[0] % n:
        raise ValueError(
            f"ring_decode: table columns ({NB}) and pool pages "
            f"({k_pool.shape[0]}) must divide the {axis} axis ({n})"
        )
    nb_l = NB // n

    def local(q_l, k_l, v_l, tbl_l, pos_l, ks_l, vs_l):
        me = jax.lax.axis_index(axis)
        o_l, lse_l = _shard_partial(
            q_l, k_l, v_l, tbl_l, pos_l, base=me * nb_l * bs, window=window,
            scale=scale, k_scale=ks_l, v_scale=vs_l, impl=impl,
        )
        # rotate the partials; stash each at its GLOBAL shard index so the
        # final merge chain is identical (and the output replicated) on
        # every rank
        buf_o = jnp.zeros((n,) + o_l.shape, jnp.float32)
        buf_lse = jnp.full((n,) + lse_l.shape, collectives.NEG_LSE,
                           jnp.float32)

        def stash(carry, blk, t):
            bo, bl = carry
            o_t, lse_t = blk
            src = (me - t) % n
            return bo.at[src].set(o_t), bl.at[src].set(lse_t)

        bo, bl = collectives.ring_scan(
            stash, (buf_o, buf_lse), (o_l.astype(jnp.float32), lse_l),
            axis, n, overlap=overlap,
        )
        o_acc = jnp.zeros(o_l.shape, jnp.float32)
        lse_acc = jnp.full(lse_l.shape, collectives.NEG_LSE, jnp.float32)
        for r in range(n):
            o_acc, lse_acc = collectives.online_softmax_merge(
                o_acc, lse_acc, bo[r], bl[r]
            )
        return o_acc.astype(q_l.dtype)

    pool_spec = P(axis, None, None, None)
    scale_spec = pool_spec if k_scale is not None else P()
    args = (q, k_pool, v_pool, block_table, position,
            k_scale if k_scale is not None else jnp.zeros(()),
            v_scale if v_scale is not None else jnp.zeros(()))

    def wrapped(q_l, k_l, v_l, tbl_l, pos_l, ks_l, vs_l):
        if k_scale is None:
            ks_l = vs_l = None
        return local(q_l, k_l, v_l, tbl_l, pos_l, ks_l, vs_l)

    return shard_map(
        wrapped,
        mesh=mesh,
        in_specs=(P(None, None, None), pool_spec, pool_spec, P(None, axis),
                  P(None), scale_spec, scale_spec),
        out_specs=P(None, None, None),
        check_vma=False,
    )(*args)


def ring_decode_reference(q, k_pool, v_pool, block_table, position, n, *,
                          window: int = 0, scale=None, k_scale=None,
                          v_scale=None, impl=None):
    """Single-device simulation of the n-shard merge chain: the same
    per-shard paged partials, folded in the same global order — the
    bitwise oracle for ``ring_decode`` (and itself allclose to plain
    contiguous ``decode_attention``, which sums the cache in one scan
    rather than via the merge chain)."""
    B, NB = block_table.shape
    bs = k_pool.shape[2]
    nb_l = NB // n
    p_l = k_pool.shape[0] // n
    o_acc = jnp.zeros(q.shape, jnp.float32)
    lse_acc = jnp.full(q.shape[:2], collectives.NEG_LSE, jnp.float32)
    for r in range(n):
        sl = slice(r * p_l, (r + 1) * p_l)
        o_r, lse_r = _shard_partial(
            q, k_pool[sl], v_pool[sl],
            block_table[:, r * nb_l:(r + 1) * nb_l], position,
            base=r * nb_l * bs, window=window, scale=scale,
            k_scale=None if k_scale is None else k_scale[sl],
            v_scale=None if v_scale is None else v_scale[sl],
            impl=impl,
        )
        o_acc, lse_acc = collectives.online_softmax_merge(
            o_acc, lse_acc, o_r.astype(jnp.float32), lse_r
        )
    return o_acc.astype(q.dtype)
