"""Continuous-batching serving engine.

Wires the host-side scheduler (``serving/scheduler.py``) to the device-side
paged model step (``models/transformer.decode_step_paged`` over a
``PagedKVCache``). One ``step()`` is one unit of virtual time:

  1. admit arrived requests (FCFS within priority class) while a decode
     slot and enough cache blocks exist; each admission runs a jitted
     prefill (per length bucket) and scatters the prompt KV into its pages
     — resumed requests restore their saved pages instead (the preemption
     round-trip is bitwise);
  2. grow each running sequence's block list for the token this step
     writes, preempting victims on exhaustion (their pages are copied to
     host before the blocks free);
  3. one jitted decode over ALL slots — inactive rows point at the shared
     scratch page and their outputs are dropped, so the decode shape is
     static and every live row's numbers are independent of batch
     composition (the interleaving-equivalence property the test battery
     checks bitwise);
  4. record tokens, retire on EOS / max-new-tokens, free blocks.

The model half sits behind a tiny protocol (``prefill``/``decode``/
``save_blocks``/``restore_blocks``) so the scheduler battery runs against
a deterministic host-only stub (``StubModel``) with no compilation, while
``PagedModel`` is the real thing — optionally holding the cache fp8 via
``precision=`` and distributing decode attention with ``ring_decode`` over
a mesh's ``data`` axis.
"""
from __future__ import annotations

import math

import numpy as np

from repro.serving import scheduler as sched
from repro.serving.scheduler import NULL_BLOCK, Request

__all__ = ["ServingEngine", "PagedModel", "StubModel", "Request"]


class StubModel:
    """Deterministic host-only model stub for scheduler tests.

    Token streams follow a per-sequence integer recurrence seeded by the
    last prompt token, so any slot/cache mix-up between sequences derails
    the stream — exactly what the battery's isolation properties detect.
    ``save/restore`` round-trip per-logical-block token counters so
    preemption bookkeeping is exercised too.
    """

    def __init__(self, vocab: int = 251):
        self.vocab = vocab
        self.block_writes: dict[int, list] = {}  # rid -> per-step log

    def _next(self, token: int, position: int) -> int:
        return (token * 31 + position * 7 + 13) % self.vocab

    def prefill(self, seq, block_ids):
        prompt = seq.req.prompt
        self.block_writes.setdefault(seq.rid, []).append(
            ("prefill", tuple(block_ids))
        )
        return self._next(prompt[-1], len(prompt) - 1)

    def decode(self, slot_tokens, slot_positions, slot_tables, active):
        out = np.zeros(len(slot_tokens), np.int64)
        for i in range(len(slot_tokens)):
            out[i] = self._next(int(slot_tokens[i]), int(slot_positions[i]))
        return out

    def save_blocks(self, seq, block_ids):
        return ("payload", seq.rid, len(block_ids))

    def restore_blocks(self, seq, block_ids, payload):
        tag, rid, n = payload
        assert tag == "payload" and rid == seq.rid and n <= len(block_ids)


class PagedModel:
    """The real model half: jitted paged prefill + decode over a
    ``PagedKVCache`` (dense/moe transformer families)."""

    def __init__(self, cfg, params, *, num_blocks, block_size, max_slots,
                 max_blocks_per_seq, precision=None, impl=None, mesh=None,
                 ring_axis: str = "data"):
        import jax
        import jax.numpy as jnp

        from repro.models import transformer
        from repro.serving import paged_cache, ring_decode

        if cfg.family not in ("dense", "moe"):
            raise NotImplementedError(
                f"PagedModel serves the transformer families (dense/moe), "
                f"got {cfg.family!r}"
            )
        self._jax, self._jnp = jax, jnp
        self._transformer = transformer
        self.cfg, self.params = cfg, params
        self.block_size = block_size
        self.max_blocks_per_seq = max_blocks_per_seq
        self.vocab = cfg.vocab_size
        self.impl = impl
        self.mesh = mesh
        self.ring_axis = ring_axis
        self.cache = paged_cache.init_paged_cache(
            cfg, num_blocks=num_blocks, block_size=block_size,
            policy=None if precision is None else getattr(
                precision, "name", precision
            ),
        )
        self.tables = np.full(
            (max_slots, max_blocks_per_seq), NULL_BLOCK, np.int32
        )
        attn_fn = None
        if mesh is not None:
            n = mesh.shape[ring_axis]
            if num_blocks % n or max_blocks_per_seq % n:
                raise ValueError(
                    "ring decode needs num_blocks and max_blocks_per_seq "
                    f"divisible by the {ring_axis} axis ({n})"
                )

            def attn_fn(q, kp, vp, ks, vs, tbl, pos, window):
                return ring_decode.ring_decode(
                    q, kp, vp, tbl, pos, mesh, axis=ring_axis,
                    window=window, k_scale=ks, v_scale=vs, impl=impl,
                )

        self._attn_fn = attn_fn
        self._decode_jit = jax.jit(
            lambda p, c, b: transformer.decode_step_paged(
                p, cfg, c, b, attn_fn=attn_fn
            ),
            donate_argnums=(1,),
        )
        self._prefill_jit: dict[int, object] = {}  # per length bucket
        self._impl_ctx = impl

    # -- prefill ------------------------------------------------------------

    def _bucket(self, s0: int) -> int:
        return self.block_size * math.ceil(s0 / self.block_size)

    def _prefill_fn(self, sb: int):
        jax, jnp = self._jax, self._jnp
        cfg, tr = self.cfg, self._transformer
        if sb not in self._prefill_jit:
            nbp = sb // self.block_size

            def run(params, cache, tokens, block_ids, last_idx):
                # tokens (1, sb) padded prompt; causal attention keeps every
                # real row independent of the padded tail
                logits, kv = tr.prefill_step(params, cfg, {"tokens": tokens},
                                             max_len=sb)
                nl, _, K, _, hd = kv["k"].shape
                def rows(x):  # (nl, nbp, K, bs, hd)
                    return jnp.moveaxis(
                        x[:, 0].reshape(nl, K, nbp, self.block_size, hd), 2, 1
                    )
                cache = cache.write_prompt(block_ids, rows(kv["k"]),
                                           rows(kv["v"]))
                first = jnp.argmax(
                    logits[0, last_idx, : cfg.vocab_size]
                ).astype(jnp.int32)
                return cache, first

            self._prefill_jit[sb] = jax.jit(run, donate_argnums=(1,))
        return self._prefill_jit[sb]

    def prefill(self, seq, block_ids):
        jnp = self._jnp
        prompt = seq.req.prompt
        sb = self._bucket(len(prompt))
        tokens = np.zeros((1, sb), np.int32)
        tokens[0, : len(prompt)] = prompt
        ids = np.full(sb // self.block_size, NULL_BLOCK, np.int32)
        ids[: len(block_ids)] = block_ids  # prompt pages (grant covers them)
        self.cache, first = self._prefill_fn(sb)(
            self.params, self.cache, jnp.asarray(tokens), jnp.asarray(ids),
            jnp.int32(len(prompt) - 1),
        )
        self.tables[seq.slot, :] = NULL_BLOCK
        self.tables[seq.slot, : len(block_ids)] = block_ids
        return int(first)

    # -- decode -------------------------------------------------------------

    def sync_table(self, seq) -> None:
        """Mirror the scheduler's block list into the device table row."""
        self.tables[seq.slot, :] = NULL_BLOCK
        self.tables[seq.slot, : len(seq.blocks)] = seq.blocks

    def decode(self, slot_tokens, slot_positions, slot_tables, active):
        jnp = self._jnp
        batch = {
            "token": jnp.asarray(slot_tokens, jnp.int32),
            "position": jnp.asarray(slot_positions, jnp.int32),
            "block_table": jnp.asarray(slot_tables, jnp.int32),
        }
        logits, self.cache = self._decode_jit(self.params, self.cache, batch)
        return np.asarray(
            jnp.argmax(logits[:, : self.vocab], axis=-1)
        ).astype(np.int64)

    # -- preemption payloads -------------------------------------------------

    def save_blocks(self, seq, block_ids):
        jax = self._jax
        ids = np.asarray(block_ids, np.int32)
        return jax.device_get(self.cache.gather_blocks(ids))

    def restore_blocks(self, seq, block_ids, payload):
        jnp = self._jnp
        n = payload["k"].shape[1]
        ids = jnp.asarray(np.asarray(block_ids[:n], np.int32))
        self.cache = self.cache.restore_blocks(ids, payload)


class ServingEngine:
    """Open-loop continuous-batching engine over a paged KV cache."""

    def __init__(self, model, *, num_blocks, block_size, max_slots,
                 max_blocks_per_seq, eos_id: int | None = None):
        self.model = model
        self.scheduler = sched.ContinuousBatchingScheduler(
            num_blocks=num_blocks, block_size=block_size,
            max_slots=max_slots, max_blocks_per_seq=max_blocks_per_seq,
        )
        self.max_slots = max_slots
        # decode-table width: with no per-sequence cap, a sequence can at
        # most hold the whole non-null pool
        self.table_width = max_blocks_per_seq or (num_blocks - 1)
        self.eos_id = eos_id
        self.step_count = 0
        self.completed: dict[int, tuple] = {}  # rid -> generated tokens
        self.latency_steps: dict[int, int] = {}  # rid -> retire - arrival
        # snapshot a victim's pages to host BEFORE the scheduler frees the
        # ledger entries (the resume half restores them bitwise)
        orig_preempt = self.scheduler.preempt

        def _preempt(seq, step):
            seq.saved_payload = self.model.save_blocks(seq, list(seq.blocks))
            orig_preempt(seq, step)

        self.scheduler.preempt = _preempt

    @classmethod
    def with_model(cls, cfg, params, *, num_blocks=64, block_size=16,
                   max_slots=8, max_blocks_per_seq=16, precision=None,
                   impl=None, mesh=None, eos_id=None):
        model = PagedModel(
            cfg, params, num_blocks=num_blocks, block_size=block_size,
            max_slots=max_slots, max_blocks_per_seq=max_blocks_per_seq,
            precision=precision, impl=impl, mesh=mesh,
        )
        return cls(model, num_blocks=num_blocks, block_size=block_size,
                   max_slots=max_slots, max_blocks_per_seq=max_blocks_per_seq,
                   eos_id=eos_id)

    def submit(self, req: Request) -> None:
        self.scheduler.submit(req)

    # -- one step of virtual time -------------------------------------------

    def step(self) -> int:
        """Admissions + one decode over all slots. Returns the number of
        live tokens produced this step."""
        s = self.step_count
        sc = self.scheduler

        for seq in sc.admit(s):
            if seq.saved_payload is not None:  # resume: restore pages
                self.model.restore_blocks(seq, seq.blocks, seq.saved_payload)
                seq.saved_payload = None
                if hasattr(self.model, "sync_table"):
                    self.model.sync_table(seq)
            else:
                first = self.model.prefill(seq, seq.blocks)
                sc.record_token(seq, first)
                if sc.should_retire(seq, self.eos_id):
                    self._retire(seq, s)

        # grow blocks (preempting on exhaustion) for this step's writes
        skipped: set[int] = set()
        for slot in sorted(self.scheduler.running):
            seq = self.scheduler.running.get(slot)
            if seq is None:  # already preempted as someone's victim
                continue
            before = len(seq.blocks)
            if not sc.ensure_block(seq, s):
                skipped.add(seq.rid)  # preempted itself; decode next round
                continue
            if len(seq.blocks) != before and hasattr(self.model,
                                                     "sync_table"):
                self.model.sync_table(seq)

        produced = 0
        if self.scheduler.running:
            tokens = np.zeros(self.max_slots, np.int64)
            positions = np.zeros(self.max_slots, np.int64)
            tables = np.full(
                (self.max_slots, self.table_width), NULL_BLOCK, np.int32,
            )
            if hasattr(self.model, "tables"):
                tables = self.model.tables
                tables[:] = NULL_BLOCK
            active = np.zeros(self.max_slots, bool)
            live = dict(self.scheduler.running)
            for slot, seq in live.items():
                active[slot] = True
                tokens[slot] = seq.generated[-1]
                positions[slot] = seq.next_position()
                tables[slot, : len(seq.blocks)] = seq.blocks
            next_tokens = self.model.decode(tokens, positions, tables, active)
            for slot, seq in live.items():
                sc.record_token(seq, int(next_tokens[slot]))
                produced += 1
                if sc.should_retire(seq, self.eos_id):
                    self._retire(seq, s)

        self.step_count += 1
        return produced

    def _retire(self, seq, step: int) -> None:
        self.scheduler.retire(seq, step)
        self.completed[seq.rid] = tuple(seq.generated)
        self.latency_steps[seq.rid] = step - seq.req.arrival + 1

    def run(self, max_steps: int = 10_000) -> dict:
        while not self.scheduler.idle():
            if self.step_count >= max_steps:
                raise RuntimeError(
                    f"engine did not drain in {max_steps} steps "
                    f"(running={sorted(s.rid for s in self.scheduler.running.values())})"
                )
            self.step()
        return dict(self.completed)

    def leaked_blocks(self) -> int:
        return self.scheduler.leaked_blocks()
