"""Production mesh construction (Occamy hierarchy -> TPU mesh axes).

Axis mapping (DESIGN.md C5): `model` = intra-chiplet crossbar (TP),
`data` = group level (DP/FSDP/SP), `pod` = D2D link (second DP axis).
A FUNCTION, not a module constant: importing this module never touches jax
device state.
"""
from __future__ import annotations

import warnings

import jax


def make_production_mesh(*, multi_pod: bool = False):
    shape = (2, 16, 16) if multi_pod else (16, 16)
    axes = ("pod", "data", "model") if multi_pod else ("data", "model")
    return jax.make_mesh(shape, axes)


def make_mesh(shape: tuple, axes: tuple):
    """Arbitrary meshes (tests, elastic re-meshing, hillclimb variants)."""
    return jax.make_mesh(shape, axes)


def host_device_mesh(tp: int = 1):
    """Whatever devices exist locally, as (data, model).

    When ``tp`` does not divide the device count, degrades to the largest
    dividing tp with a warning — the same graceful-degradation contract as
    ``parallel/sharding.py`` — and raises ``ValueError`` when no valid
    factorisation exists at all (tp < 1).
    """
    n = len(jax.devices())
    if tp < 1:
        raise ValueError(
            f"host_device_mesh: tp={tp} is not a valid model-axis size "
            f"(need 1 <= tp, have {n} devices)"
        )
    if n % tp != 0:
        fit = max(t for t in range(1, min(tp, n) + 1) if n % t == 0)
        warnings.warn(
            f"host_device_mesh: tp={tp} does not divide {n} devices; "
            f"degrading to tp={fit}",
            stacklevel=2,
        )
        tp = fit
    return jax.make_mesh((n // tp, tp), ("data", "model"))
