"""Production mesh construction (Occamy hierarchy -> TPU mesh axes).

Axis mapping (DESIGN.md C5): `model` = intra-chiplet crossbar (TP),
`data` = group level (DP/FSDP/SP), `pod` = D2D link (second DP axis).
A FUNCTION, not a module constant: importing this module never touches jax
device state.
"""
from __future__ import annotations

import jax

from repro.diagnostics import warn_degrade


def make_production_mesh(*, multi_pod: bool = False):
    shape = (2, 16, 16) if multi_pod else (16, 16)
    axes = ("pod", "data", "model") if multi_pod else ("data", "model")
    return jax.make_mesh(shape, axes)


def make_mesh(shape: tuple, axes: tuple):
    """Arbitrary meshes (tests, elastic re-meshing, hillclimb variants)."""
    return jax.make_mesh(shape, axes)


def host_device_mesh(tp: int = 1, pods: int = 1):
    """Whatever devices exist locally, as (data, model) — or, when ``pods``
    is requested, as the three-axis (pod, data, model) hierarchy.

    Args: ``tp`` — the model-axis (chiplet-crossbar) size; ``pods`` — the
    pod-axis (D2D-link) size. ``pods=1`` keeps the historical two-axis
    shape; any other value yields a three-axis mesh (the pod axis is kept
    even if it degrades to size 1, so callers written for the pod axis see
    a stable set of axis names).

    When ``pods * tp`` does not divide the device count, degrades with a
    warning — the largest dividing ``pods`` first, then the largest ``tp``
    that divides the per-pod remainder — the same graceful-degradation
    contract as ``parallel/sharding.py``. Raises ``ValueError`` when no
    valid factorisation exists at all (``tp < 1`` or ``pods < 1``).
    """
    n = len(jax.devices())
    if tp < 1 or pods < 1:
        raise ValueError(
            f"host_device_mesh: tp={tp}, pods={pods} is not a valid mesh "
            f"factorisation (need 1 <= pods and 1 <= tp, have {n} devices)"
        )
    want_tp, want_pods = tp, pods
    if n % pods != 0:
        pods = max(p for p in range(1, min(pods, n) + 1) if n % p == 0)
    per_pod = n // pods
    if per_pod % tp != 0:
        tp = max(t for t in range(1, min(tp, per_pod) + 1) if per_pod % t == 0)
    if (tp, pods) != (want_tp, want_pods):
        warn_degrade(
            f"host_device_mesh: pods={want_pods} x tp={want_tp} does not "
            f"divide {n} devices; degrading to tp={tp}, pods={pods}",
        )
    if want_pods == 1:
        return jax.make_mesh((n // tp, tp), ("data", "model"))
    return jax.make_mesh(
        (pods, per_pod // tp, tp), ("pod", "data", "model")
    )
