"""Production mesh construction (Occamy hierarchy -> TPU mesh axes).

Axis mapping (DESIGN.md C5): `model` = intra-chiplet crossbar (TP),
`data` = group level (DP/FSDP/SP), `pod` = D2D link (second DP axis).
A FUNCTION, not a module constant: importing this module never touches jax
device state.
"""
from __future__ import annotations

import jax


def make_production_mesh(*, multi_pod: bool = False):
    shape = (2, 16, 16) if multi_pod else (16, 16)
    axes = ("pod", "data", "model") if multi_pod else ("data", "model")
    return jax.make_mesh(shape, axes)


def make_mesh(shape: tuple, axes: tuple):
    """Arbitrary meshes (tests, elastic re-meshing, hillclimb variants)."""
    return jax.make_mesh(shape, axes)


def host_device_mesh(tp: int = 1):
    """Whatever devices exist locally, as (data, model)."""
    n = len(jax.devices())
    assert n % tp == 0
    return jax.make_mesh((n // tp, tp), ("data", "model"))
