from repro.launch.xla_flags import ensure_host_device_count

# append (never clobber) before anything imports jax: caller flags survive,
# including a caller-chosen device count
ensure_host_device_count(512)

# isort: split  -- the lines above MUST precede any jax-importing module
import argparse
import json
import sys
import time

import jax
import jax.numpy as jnp
from jax.sharding import NamedSharding, PartitionSpec as P

from repro.configs.base import SHAPES, all_arch_ids, get_config, shape_applicable
from repro.launch import roofline
from repro.launch.mesh import make_production_mesh
from repro.launch.op_cases import op_roofline_cases
from repro.models import registry
from repro.parallel import sharding as sh
from repro.runtime import train_loop

"""Multi-pod dry-run: .lower().compile() every (arch x shape x mesh) cell.

Proves the distribution config is coherent without hardware: 512 host
devices stand in for 2 pods x 256 chips. Emits memory_analysis(),
cost_analysis() and the parsed collective schedule per cell (EXPERIMENTS.md
§Dry-run reads these)."""


def _mem_stats(compiled):
    ma = compiled.memory_analysis()
    out = {}
    for k in (
        "argument_size_in_bytes",
        "output_size_in_bytes",
        "temp_size_in_bytes",
        "generated_code_size_in_bytes",
        "alias_size_in_bytes",
    ):
        v = getattr(ma, k, None)
        if v is not None:
            out[k] = int(v)
    if out:
        out["total_per_device"] = (
            out.get("argument_size_in_bytes", 0)
            + out.get("output_size_in_bytes", 0)
            + out.get("temp_size_in_bytes", 0)
            - out.get("alias_size_in_bytes", 0)
        )
    return out


def _cost_stats(compiled):
    ca = compiled.cost_analysis()
    if isinstance(ca, (list, tuple)):
        ca = ca[0]
    return {k: float(v) for k, v in ca.items()} if ca else {}


def _build_and_lower(cfg, shape, mesh, donate: bool = True):
    mode = "train" if shape.kind == "train" else "serve"
    param_tree = registry.param_shapes(cfg)
    pspecs = sh.param_specs(cfg, param_tree, mesh, mode)
    batch_tree = registry.input_specs(cfg, shape)
    bspecs = sh.batch_specs(cfg, batch_tree, mesh)
    act_specs = sh.default_activation_specs(cfg, mesh, shape.kind)

    with sh.activation_sharding(act_specs):
        if shape.kind == "train":
            state_tree = train_loop.train_state_struct(cfg)
            state_specs = {
                "params": pspecs,
                "opt": {"m": pspecs, "v": pspecs, "step": P()},
            }
            fn = train_loop.make_train_step(cfg)
            jitted = jax.jit(
                fn,
                in_shardings=(sh.named(mesh, state_specs), sh.named(mesh, bspecs)),
                out_shardings=(
                    sh.named(mesh, state_specs),
                    None,
                ),
                donate_argnums=(0,) if donate else (),
            )
            lowered = jitted.lower(state_tree, batch_tree)
        elif shape.kind == "prefill":
            fn = train_loop.make_prefill_step(cfg)
            dp = sh.dp_axes(mesh)
            jitted = jax.jit(
                fn,
                in_shardings=(sh.named(mesh, pspecs), sh.named(mesh, bspecs)),
                out_shardings=NamedSharding(mesh, P(dp, None, "model")),
            )
            lowered = jitted.lower(param_tree, batch_tree)
        else:  # decode
            cache_tree = registry.cache_spec(cfg, shape.global_batch, shape.seq_len)
            cspecs = sh.cache_specs(cfg, cache_tree, mesh)
            fn = train_loop.make_decode_step(cfg)
            jitted = jax.jit(
                fn,
                in_shardings=(
                    sh.named(mesh, pspecs),
                    sh.named(mesh, cspecs),
                    sh.named(mesh, bspecs),
                ),
                out_shardings=(None, sh.named(mesh, cspecs)),
                donate_argnums=(1,) if donate else (),
            )
            lowered = jitted.lower(param_tree, cache_tree, batch_tree)

    return lowered


_COST_KEYS = ("flops", "hbm_bytes", "coll_bytes")


def _cost_point(cfg, shape, mesh, n_layers: int, seq: int | None = None,
                num_global: int | None = None) -> dict:
    """FLOP/byte/collective counts from a small UNROLLED variant.

    XLA's cost analysis counts while-loop bodies once regardless of trip
    count; small unrolled lowers give exact probe points for the polynomial
    cost model below."""
    import dataclasses as _dc

    from repro.kernels import registry as kreg

    cfg2 = cfg.replace(
        num_layers=n_layers,
        scan_unroll=n_layers,
        encoder_layers=n_layers if cfg.encoder_layers else 0,
        **({"num_global_layers": num_global} if num_global is not None else {}),
    )
    shape2 = _dc.replace(shape, seq_len=seq) if seq else shape
    with kreg.unroll_inner():
        lowered = _build_and_lower(cfg2, shape2, mesh, donate=False)
        compiled = lowered.compile()
    cost = _cost_stats(compiled)
    coll = roofline.collective_bytes(compiled.as_text())
    return {
        "flops": cost.get("flops", 0.0),
        "hbm_bytes": cost.get("bytes accessed", 0.0),
        "coll_bytes": coll["total"],
        "coll_by_kind": coll["by_kind"],
        "coll_counts": coll["counts"],
    }


def _positivity_fallback(out, c_hi, hi, L):
    """XLA occasionally changes fusion strategy between probe sizes; if the
    affine fit goes non-physical (<= 0), fall back to proportional scaling."""
    for key in _COST_KEYS:
        if out[key] <= 0:
            out[key] = c_hi[key] * (L / hi)
            out[key + "_per_layer"] = c_hi[key] / hi
            out.setdefault("fallback", []).append(key)


def _layer_extrapolate(c_lo, c_hi, lo, hi, L):
    out = {}
    for key in _COST_KEYS:
        per_layer = (c_hi[key] - c_lo[key]) / (hi - lo)
        out[key] = c_lo[key] + (L - lo) * per_layer
        out[key + "_per_layer"] = per_layer
    out["coll_by_kind"] = {
        k: c_lo["coll_by_kind"][k]
        + (L - lo) * (c_hi["coll_by_kind"][k] - c_lo["coll_by_kind"][k]) / (hi - lo)
        for k in c_lo["coll_by_kind"]
    }
    out["coll_counts_per_layer"] = {
        k: (c_hi["coll_counts"][k] - c_lo["coll_counts"][k]) / (hi - lo)
        for k in c_lo["coll_counts"]
    }
    _positivity_fallback(out, c_hi, hi, L)
    return out


def _costs_chunked_seq(cfg, shape, mesh) -> dict:
    """ssm/hybrid train+prefill: the chunked linear-attention scan makes
    full-seq unrolled lowers explode (T/32 bodies), so probe small (L, T) and
    fit. Every term is bilinear in (L, T) for SWA/SSM layers; hybrid global-
    attention layers add a per-layer quadratic in T, fitted from ng-deltas.
    Exact because all costs are polynomial (deg<=2 in T, deg<=1 in L)."""
    T = shape.seq_len
    T1 = min(1024, T)
    T2 = min(2048, T)
    if T2 == T1:  # tiny shapes: plain L-extrapolation
        return _layer_extrapolate(
            _cost_point(cfg, shape, mesh, 2), _cost_point(cfg, shape, mesh, 4),
            2, 4, cfg.num_layers,
        )
    ng_true = cfg.num_global_layers if cfg.family == "hybrid" else 0
    a = _cost_point(cfg, shape, mesh, 2, T1, num_global=0)
    b = _cost_point(cfg, shape, mesh, 3, T1, num_global=0)
    c = _cost_point(cfg, shape, mesh, 2, T2, num_global=0)
    d = _cost_point(cfg, shape, mesh, 3, T2, num_global=0)

    def bilinear(key_get):
        pl1 = key_get(b) - key_get(a)  # per-layer at T1
        pl2 = key_get(d) - key_get(c)  # per-layer at T2
        pl_slope = (pl2 - pl1) / (T2 - T1)
        per_layer_T = pl1 + pl_slope * (T - T1)
        base1 = key_get(a) - 2 * pl1
        base2 = key_get(c) - 2 * pl2
        base_T = base1 + (base2 - base1) / (T2 - T1) * (T - T1)
        return base_T, per_layer_T

    glob_delta = {k: 0.0 for k in _COST_KEYS}
    if ng_true:
        # quadratic fit of the (global - swa) per-layer delta over T
        Ts = sorted({min(t, T) for t in (1024, 2048, 4096)})
        deltas = {k: [] for k in _COST_KEYS}
        for t in Ts:
            g = _cost_point(cfg, shape, mesh, 2, t, num_global=1)
            s = (
                a if t == T1 else c if t == T2 else
                _cost_point(cfg, shape, mesh, 2, t, num_global=0)
            )
            for k in _COST_KEYS:
                deltas[k].append(g[k] - s[k])
        import numpy as _np

        for k in _COST_KEYS:
            deg = min(2, len(Ts) - 1)
            coef = _np.polyfit(_np.asarray(Ts, float), deltas[k], deg)
            glob_delta[k] = float(_np.polyval(coef, T))

    L = cfg.num_layers
    out = {}
    for k in _COST_KEYS:
        base, per = bilinear(lambda p, kk=k: p[kk])
        out[k] = base + L * per + ng_true * glob_delta[k]
        out[k + "_per_layer"] = per
    out["coll_by_kind"] = {
        kind: bilinear(lambda p, kk=kind: p["coll_by_kind"][kk])[0]
        + L * bilinear(lambda p, kk=kind: p["coll_by_kind"][kk])[1]
        for kind in a["coll_by_kind"]
    }
    out["coll_counts_per_layer"] = {
        kind: float(b["coll_counts"][kind] - a["coll_counts"][kind])
        for kind in a["coll_counts"]
    }
    _positivity_fallback(out, d, 3, cfg.num_layers)
    return out


def extrapolated_costs(cfg, shape, mesh, points=(2, 4)) -> dict:
    if cfg.family in ("ssm", "hybrid") and shape.kind in ("train", "prefill"):
        return _costs_chunked_seq(cfg, shape, mesh)
    if cfg.family == "audio":
        # enc-dec probes carry 2x the unrolled attention bodies: use the
        # cheapest probe pair (positivity fallback guards instability)
        points = (1, 2)
    lo, hi = points
    c_lo = _cost_point(cfg, shape, mesh, lo)
    c_hi = _cost_point(cfg, shape, mesh, hi)
    return _layer_extrapolate(c_lo, c_hi, lo, hi, cfg.num_layers)


def lower_cell(arch: str, shape_name: str, multi_pod: bool,
               save_hlo: str | None = None, donate: bool = True,
               cfg_override=None, skip_full: bool = False,
               with_cost: bool = True) -> dict:
    cfg = cfg_override or get_config(arch)
    shape = SHAPES[shape_name]
    ok, reason = shape_applicable(cfg, shape)
    if not ok:
        return {"arch": arch, "shape": shape_name, "skipped": reason,
                "mesh": "2x16x16" if multi_pod else "16x16"}

    mesh = make_production_mesh(multi_pod=multi_pod)
    n_dev = mesh.devices.size
    result = {
        "arch": arch,
        "shape": shape_name,
        "mesh": "2x16x16" if multi_pod else "16x16",
        "devices": n_dev,
    }

    # 1) the deliverable: full-depth scan compile (sharding + memory proof)
    if not skip_full:
        t0 = time.time()
        lowered = _build_and_lower(cfg, shape, mesh, donate=donate)
        compiled = lowered.compile()
        result["compile_s"] = round(time.time() - t0, 1)
        result["memory"] = _mem_stats(compiled)
        hlo = compiled.as_text()
        result["hlo_bytes"] = len(hlo)
        result["collective_full_hlo"] = roofline.collective_bytes(hlo)["counts"]
        if save_hlo:
            with open(save_hlo, "w") as f:
                f.write(hlo)
        del compiled, lowered

    # 2) roofline terms from unrolled small-depth extrapolation
    # (§Roofline is single-pod only; multi-pod passes prove the pod axis)
    if not with_cost:
        return result
    t0 = time.time()
    costs = extrapolated_costs(cfg, shape, mesh)
    result["cost_compile_s"] = round(time.time() - t0, 1)
    terms = roofline.roofline_terms(
        costs["flops"], costs["hbm_bytes"], costs["coll_bytes"]
    )
    floor = roofline.min_bytes_per_device(cfg, shape, n_dev)
    terms["memory_floor_s"] = floor / roofline.HBM_BW
    terms["memory_efficiency"] = (
        floor / costs["hbm_bytes"] if costs["hbm_bytes"] else 0.0
    )
    mf = roofline.model_flops(cfg, shape)
    result.update(
        {
            "flops_per_device": costs["flops"],
            "hbm_bytes_per_device": costs["hbm_bytes"],
            "coll_bytes_per_device": costs["coll_bytes"],
            "coll_by_kind": costs["coll_by_kind"],
            "coll_counts_per_layer": costs["coll_counts_per_layer"],
            "roofline": terms,
            "model_flops_global": mf,
            "model_flops_per_device": mf / n_dev,
            "useful_flops_ratio": (mf / n_dev) / costs["flops"]
            if costs["flops"]
            else 0.0,
        }
    )
    return result


def op_roofline_cells(multi_pod: bool = False, precision=None) -> list[dict]:
    """Per-op D2D-costed rooflines on the production mesh — the Fig. 13
    scaling story as numbers: each partitioned op's operational-intensity
    figures gain a ``topology.collective_seconds`` term for the collectives
    its PartitionRule fires (psum / halo ppermute) at each level it crosses.
    With ``multi_pod`` the plans resolve two-level (pod×model) and every
    cell carries ``collective_s_per_level`` — intra-pod (``model``, ICI
    bandwidth) vs cross-pod (``pod``, D2D bandwidth) seconds side by side —
    so the cells show where the narrow D2D link, not HBM, is binding. The
    B=1 long-context flash_attention cell rides the sequence-parallel KV
    ring: its (n-1) per-hop ppermutes price into the ``data`` level, and at
    GPT-J geometry the cell reports d2d_s-dominant — the ring hop, not HBM,
    binds long-context scale-out.

    ``precision`` names a ``core.precision`` policy and sweeps the same
    cells down the width ladder (the Fig. 10 utilization-vs-width story):
    for each op whose kernels grew a scaled path the case operands recast
    to the policy's compute dtype (so ring-permute KV bytes shrink with
    the storage width), the analytic HBM bytes reprice at the narrow width
    plus one fp32 scale per ``scale_block`` elements, the compute ceiling
    becomes ``precision.peak_flops`` (2x bf16 for fp8, 0.5x for fp32), and
    the plan itself resolves under the policy — so the gemm cell's psum
    epilogue prices at the bf16 reduce width. Ops without a scaled path
    keep their full-precision cell and report ``precision: "fp32"``.

    Uses a device-free partition.MeshSpec: no devices are constructed, so
    this runs anywhere the dry-run runs.
    """
    from repro.core import precision as prec
    from repro.kernels import partition

    pol = prec.resolve(precision)
    shape = {"pod": 2, "data": 16, "model": 16} if multi_pod else \
        {"data": 16, "model": 16}
    mesh = partition.MeshSpec(shape)
    out = []
    for op, args, kwargs, flops, nbytes in op_roofline_cases():
        peak = None
        applied = pol is not None and pol.name in prec.supported_policies(op)
        if applied:
            orig_isz = jnp.dtype(args[0].dtype).itemsize
            new_isz = jnp.dtype(pol.compute_dtype).itemsize
            args = tuple(
                jax.ShapeDtypeStruct(a.shape, pol.compute_dtype)
                if jnp.issubdtype(a.dtype, jnp.floating) else a
                for a in args
            )
            kwargs = dict(kwargs, precision=pol)
            elems = nbytes / orig_isz
            nbytes = elems * new_isz + (
                (elems / pol.scale_block) * 4 if pol.scale_block else 0.0
            )
            peak = prec.peak_flops(pol)
        plan = partition.plan_for(op, mesh, *args, **kwargs)
        n = plan.n if plan else 1
        by_level = roofline.plan_collective_seconds_by_level(plan)
        d2d = sum(by_level.values())
        terms = roofline.roofline_terms(flops / n, nbytes / n, 0.0, d2d_s=d2d,
                                        peak_flops=peak)
        cell = {
            "op": op,
            "mesh": "x".join(str(s) for s in shape.values()),
            "partition": plan.note if plan else "replicated",
            "partition_levels": [f"{a}={ln}" for a, ln in plan.levels]
            if plan else [],
            "devices_used": n,
            "flops_per_device": flops / n,
            "bytes_per_device": nbytes / n,
            "d2d_bytes": partition.plan_collective_bytes(plan),
            "collective_s_per_level": by_level,
            "oi_flops_per_byte": flops / nbytes if nbytes else 0.0,
            "roofline": terms,  # serial model: every transfer waits
            "overlappable": bool(plan and plan.overlappable),
        }
        if pol is not None:
            cell["precision"] = pol.name if applied else "fp32"
        if plan is not None and plan.overlappable and plan.hops > 1:
            # the overlapped cell beside the serial one: per-hop D2D hides
            # behind per-hop compute, only the exposed remainder binds
            ov = roofline.overlapped_terms(
                flops / n, nbytes / n, 0.0, d2d, plan.hops,
                peak_flops=peak,
            )
            cell["roofline_overlapped"] = ov
            cell["overlap"] = {
                "hops": plan.hops,
                "serial_s": ov["serial_s"],
                "overlapped_s": ov["overlapped_s"],
                "d2d_exposed_s": ov["d2d_exposed_s"],
            }
        out.append(cell)
    return out


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", default=None, help="arch id (default: all)")
    ap.add_argument("--shape", default=None, help="shape name (default: all)")
    ap.add_argument("--multi-pod", action="store_true")
    ap.add_argument("--both-meshes", action="store_true")
    ap.add_argument("--out", default=None, help="append JSON lines here")
    ap.add_argument("--save-hlo", default=None)
    ap.add_argument("--no-cost", action="store_true",
                    help="skip roofline-cost extraction (compile proof only)")
    ap.add_argument("--op-roofline", action="store_true",
                    help="emit per-op D2D-costed roofline cells and exit")
    ap.add_argument("--precision", default=None,
                    choices=("fp32", "bf16", "fp8", "fp8_e5m2"),
                    help="price --op-roofline cells under this "
                         "core.precision policy (Fig. 10 width sweep)")
    args = ap.parse_args()

    if args.op_roofline:
        for res in op_roofline_cells(multi_pod=args.multi_pod,
                                     precision=args.precision):
            line = json.dumps(res)
            print(line, flush=True)
            if args.out:
                with open(args.out, "a") as f:
                    f.write(line + "\n")
        return

    archs = [args.arch] if args.arch else all_arch_ids()
    shapes = [args.shape] if args.shape else list(SHAPES)
    meshes = [False, True] if args.both_meshes else [args.multi_pod]

    failures = 0
    for arch in archs:
        for shape in shapes:
            for mp in meshes:
                try:
                    res = lower_cell(arch, shape, mp, save_hlo=args.save_hlo,
                                     with_cost=not (args.no_cost or mp))
                except Exception as e:  # a failure here is a bug in the system
                    res = {
                        "arch": arch, "shape": shape,
                        "mesh": "2x16x16" if mp else "16x16",
                        "error": f"{type(e).__name__}: {e}",
                    }
                    failures += 1
                line = json.dumps(res)
                print(line, flush=True)
                if args.out:
                    with open(args.out, "a") as f:
                        f.write(line + "\n")
    sys.exit(1 if failures else 0)


if __name__ == "__main__":
    main()
