"""Roofline-term extraction from compiled dry-run artifacts.

  compute    = HLO_FLOPs_per_device / PEAK_FLOPS
  memory     = HLO_bytes_per_device / HBM_BW
  collective = collective_bytes_per_device / LINK_BW
  d2d        = partition-rule collective epilogues priced per mesh level
               via topology.collective_seconds (the Fig. 13 D2D term)

collective_bytes is NOT in cost_analysis(): we parse the post-SPMD HLO text
and sum operand/result sizes of every collective op (with ring-algorithm byte
multipliers). The d2d term is the opposite direction: analytic, from the
kernel partition plans (kernels/partition.py), so the per-op operational-
intensity figures carry the chiplet/D2D crossing cost even where no HLO
exists. Hardware constants: TPU v5e-class, from the task spec.
"""
from __future__ import annotations

import re

from repro.core import topology

PEAK_FLOPS = 197e12  # bf16 FLOP/s per chip
HBM_BW = 819e9  # bytes/s per chip
LINK_BW = 50e9  # bytes/s per ICI link

_DTYPE_BYTES = {
    "pred": 1, "s8": 1, "u8": 1, "f8e4m3fn": 1, "f8e5m2": 1,
    # every fp8 spelling XLA emits (fn/fnuz/b11 variants and the bare
    # f8e4m3/f8e3m4 aliases) is one byte; missing entries silently fell
    # back to 4B and quadrupled low-precision collective bytes.
    "f8e4m3": 1, "f8e3m4": 1, "f8e4m3fnuz": 1, "f8e4m3b11fnuz": 1,
    "f8e5m2fnuz": 1, "s4": 1, "u4": 1,
    "s16": 2, "u16": 2, "bf16": 2, "f16": 2,
    "s32": 4, "u32": 4, "f32": 4,
    "s64": 8, "u64": 8, "f64": 8, "c64": 8,
}

_DEF_RE = re.compile(
    r"%?([\w.-]+)\s*=\s*(?:\()?(\w+)\[([\d,]*)\]"
)
_COLL_KINDS = (
    "all-gather",
    "all-reduce",
    "reduce-scatter",
    "all-to-all",
    "collective-permute",
)
# multiplier on result bytes: ring all-reduce moves ~2x the buffer;
# gather/scatter/a2a/permute move ~1x
_FACTOR = {
    "all-gather": 1.0,
    "all-reduce": 2.0,
    "reduce-scatter": 1.0,  # applied to the *operand* (the big side)
    "all-to-all": 1.0,
    "collective-permute": 1.0,
}


def _shape_bytes(dtype: str, dims: str) -> int:
    n = 1
    for d in dims.split(","):
        if d:
            n *= int(d)
    return n * _DTYPE_BYTES.get(dtype, 4)


def collective_bytes(hlo_text: str) -> dict:
    """Per-device bytes moved by collectives, by op kind."""
    sizes: dict[str, int] = {}
    totals = {k: 0.0 for k in _COLL_KINDS}
    counts = {k: 0 for k in _COLL_KINDS}
    for line in hlo_text.splitlines():
        m = _DEF_RE.search(line)
        if not m:
            continue
        name, dtype, dims = m.groups()
        nbytes = _shape_bytes(dtype, dims)
        sizes[name] = nbytes
        for kind in _COLL_KINDS:
            # match op kind as a word: "all-gather(", "all-gather-start("
            if f" {kind}(" in line or f" {kind}-start(" in line:
                if kind == "reduce-scatter":
                    # operand is result * shard count; find first operand name
                    ops = re.findall(r"\(([^)]*)\)", line)
                    opbytes = nbytes
                    if ops:
                        first = ops[-1].split(",")[0].strip().lstrip("%")
                        opbytes = sizes.get(first, nbytes)
                    totals[kind] += _FACTOR[kind] * max(opbytes, nbytes)
                else:
                    totals[kind] += _FACTOR[kind] * nbytes
                counts[kind] += 1
                break
    totals_all = sum(totals.values())
    return {"by_kind": totals, "counts": counts, "total": totals_all}


def roofline_terms(flops: float, hbm_bytes: float, coll_bytes: float,
                   d2d_s: float = 0.0,
                   peak_flops: float | None = None) -> dict:
    """The roofline time terms; ``d2d_s`` (partition-plan collective time
    from ``op_collective_seconds`` / ``plan_collective_seconds``) joins the
    dominance comparison so a D2D-bound sharded op reports as such.
    ``peak_flops`` overrides the bf16 ceiling — pass
    ``core.precision.peak_flops(policy)`` to price a low-precision sweep
    cell against the MXU rate its compute dtype actually runs at."""
    t_comp = flops / (peak_flops or PEAK_FLOPS)
    t_mem = hbm_bytes / HBM_BW
    t_coll = coll_bytes / LINK_BW
    terms = {"compute_s": t_comp, "memory_s": t_mem, "collective_s": t_coll}
    if d2d_s:
        terms["d2d_s"] = d2d_s
    dom = max(terms, key=terms.get)
    bound = max(terms.values())
    terms["dominant"] = dom
    terms["roofline_fraction"] = t_comp / bound if bound > 0 else 0.0
    return terms


def overlapped_seconds(compute_s: float, d2d_s: float, hops: int) -> float:
    """Pipeline time of an overlappable plan: ``hops`` compute stages with
    the ``hops - 1`` transfers double-buffered behind them.

    The serial model sums the terms (every transfer waits); the overlapped
    schedule issues hop ``t+1``'s transfer before hop ``t``'s compute, so
    per stage only ``max(stage_compute, stage_d2d)`` elapses — plus the
    one un-hideable leading stage:

        u = compute_s / hops            (per-stage compute)
        v = d2d_s / (hops - 1)          (per-stage transfer)
        total = u + (hops - 1) * max(u, v)

    Always <= ``compute_s + d2d_s`` and STRICTLY cheaper whenever both
    terms are positive and ``hops > 1``; compute-bound plans pay no D2D at
    all (``max(u, v) == u``). Degenerates to the serial sum for
    ``hops <= 1`` or no transfer.
    """
    if hops <= 1 or d2d_s <= 0:
        return compute_s + max(d2d_s, 0.0)
    u = compute_s / hops
    v = d2d_s / (hops - 1)
    return u + (hops - 1) * max(u, v)


def overlapped_terms(flops: float, hbm_bytes: float, coll_bytes: float,
                     d2d_s: float, hops: int,
                     peak_flops: float | None = None) -> dict:
    """``roofline_terms`` under the overlapped schedule: the per-hop D2D
    time hides behind per-hop compute, so only the EXPOSED remainder joins
    the dominance comparison.

    The base (non-collective) stage time is ``max(compute_s, memory_s)``
    — the device-local roofline — pipelined over ``hops`` stages against
    ``d2d_s`` of transfer. Returns the usual terms dict with ``d2d_s``
    replaced by the exposed time (dropped entirely when compute fully
    covers the transfers, so a hidden ring stops reporting d2d-bound),
    plus ``serial_s`` / ``overlapped_s`` / ``d2d_exposed_s`` for the
    serial-vs-overlapped comparison the dry-run cells print.
    """
    t_comp = flops / (peak_flops or PEAK_FLOPS)
    t_mem = hbm_bytes / HBM_BW
    base = max(t_comp, t_mem)
    total = overlapped_seconds(base, d2d_s, hops)
    exposed = max(total - base, 0.0)
    terms = roofline_terms(flops, hbm_bytes, coll_bytes, d2d_s=exposed,
                           peak_flops=peak_flops)
    terms["serial_s"] = base + d2d_s
    terms["overlapped_s"] = total
    terms["d2d_exposed_s"] = exposed
    return terms


def plan_collective_seconds_by_level(plan) -> dict:
    """Price one partition plan's collectives per mesh level.

    Returns ``{axis: seconds}`` — e.g. ``{"model": ..., "pod": ...}`` for a
    two-level plan — where each collective is priced through the topology
    bandwidth model at its own level's link bandwidth (on-chiplet ICI for
    ``model``, the D2D link for ``pod``) and its own participant count
    (``CollectiveCost.n``; 0 falls back to the plan's total shard count).
    Empty dict for replication."""
    if plan is None:
        return {}
    out: dict[str, float] = {}
    for c in plan.collectives:
        n = c.n or plan.n
        out[c.axis] = out.get(c.axis, 0.0) + topology.collective_seconds(
            c.kind, c.nbytes, c.axis, n
        )
    return out


def plan_collective_seconds(plan) -> float:
    """Total collective time of one partition plan: the per-level prices of
    ``plan_collective_seconds_by_level`` summed (the single ``d2d_s``
    roofline term)."""
    return sum(plan_collective_seconds_by_level(plan).values())


def op_collective_seconds(op: str, mesh, *args, **kwargs) -> float:
    """Per-op D2D term: resolve the op's PartitionRule against ``mesh`` (a
    Mesh or a device-free partition.MeshSpec) and price its collectives.
    0.0 when the op runs replicated — replication moves no D2D bytes."""
    from repro.kernels import partition

    return plan_collective_seconds(partition.plan_for(op, mesh, *args, **kwargs))


def min_bytes_per_device(cfg, shape, n_dev: int, tp: int = 16) -> float:
    """Analytic lower bound on HBM traffic per device per step — the floor
    the memory roofline term is judged against (catches re-read waste).

    train:  params read twice (fwd + remat bwd) + grad write (bf16) +
            optimizer m/v read+write (fp32) + param write + saved layer
            activations (write + read) + logits.
    prefill: params read once (TP-sharded) + activations + logits.
    decode:  params read once + KV/state cache read + tiny writes.
    """
    p = cfg.num_params()
    bf2 = 2
    B, S = shape.global_batch, shape.seq_len
    d, L_ = cfg.d_model, cfg.num_layers
    if shape.kind == "train":
        param_traffic = p * (2 * bf2 + 2 * bf2 + bf2 + bf2) + p * 4 * 4  # r/w
        acts = 2 * L_ * B * S * d * bf2  # boundary save + bwd read
        logits = 2 * B * S * cfg.vocab_size * bf2
        return (param_traffic + acts + logits) / n_dev
    tp_eff = n_dev if cfg.weights_2d_tp else tp
    if shape.kind == "prefill":
        acts = L_ * B * S * d * bf2
        logits = B * S * cfg.vocab_size * bf2
        return p * bf2 / tp_eff + (acts + logits) / n_dev
    # decode: weights + cache stream per token
    hd = cfg.resolved_head_dim()
    cache = 2 * L_ * B * cfg.num_kv_heads * S * hd * bf2 if not cfg.attention_free else 0
    if cfg.family in ("ssm", "hybrid"):
        nh = cfg.resolved_d_inner() // max(cfg.ssm_head_dim, 1) if cfg.family == "hybrid" else cfg.d_model // hd
        cache += L_ * B * nh * cfg.ssm_state * max(cfg.ssm_head_dim, hd) * 4
        if cfg.family == "hybrid":
            cache += 2 * L_ * B * cfg.num_kv_heads * S * hd * bf2
    return p * bf2 / tp_eff + cache / n_dev


def model_flops(cfg, shape) -> float:
    """6*N*D (train) or 2*N*D (inference) with N = active params."""
    n = cfg.num_active_params()
    if shape.kind == "train":
        tokens = shape.global_batch * shape.seq_len
        return 6.0 * n * tokens
    if shape.kind == "prefill":
        tokens = shape.global_batch * shape.seq_len
        return 2.0 * n * tokens
    return 2.0 * n * shape.global_batch  # decode: one token per sequence
