"""Representative operand shapes per partitioned op (GPT-J / Fig. 9 scale).

One table, two consumers: ``launch.dryrun --op-roofline`` prices each case's
partition plan into D2D-costed roofline cells, and ``repro.analysis`` plan
rules resolve the same cases against production MeshSpecs to prove mesh
divisibility and ladder liveness. The table lives here — NOT in dryrun —
because dryrun pins the host device count at import time
(``ensure_host_device_count(512)``); the analyzer must stay free of that
side effect, and partition plans resolve from ShapeDtypeStructs alone.
"""
from __future__ import annotations

import jax
import jax.numpy as jnp


def op_roofline_cases() -> list[tuple]:
    """The per-op case table, as (op, args, kwargs, flops, bytes) tuples.

    Args are ``jax.ShapeDtypeStruct`` abstract values — nothing here touches
    devices. ``flops``/``bytes`` are the analytic per-call totals the
    roofline cells divide by the plan's device count. Every op registered in
    ``kernels.partition``'s ladder has exactly one case; the analyzer's
    mesh-divisibility rule iterates this list, so adding a partitioned op
    without a case here is itself a finding.
    """
    import numpy as np

    bf2, f4 = 2, 4
    S = jax.ShapeDtypeStruct
    # GPT-J attention geometry at long context: Sq large enough that the
    # per-hop ring kernel outweighs the per-hop KV transfer, so the
    # overlapped schedule can hide the D2D term the serial model exposes
    B, H, K, Sq, D = 1, 16, 16, 32768, 128
    M = N = Kd = 4096  # dense GEMM
    R = C = 4096
    L = 32  # ELL nnz/row
    F = 128
    T, tbm, tbk = 512, 8, 128  # BSR tiles
    X = Y = Z = 128
    offs = np.array(
        [(0, 0, 0), (1, 0, 0), (-1, 0, 0), (0, 1, 0), (0, -1, 0),
         (0, 0, 1), (0, 0, -1)], np.int32,
    )
    w = np.full((len(offs),), 1.0 / len(offs), np.float32)
    att = (S((B, H, Sq, D), jnp.bfloat16), S((B, K, Sq, D), jnp.bfloat16),
           S((B, K, Sq, D), jnp.bfloat16))
    la = tuple(S((B, H, Sq, 64), jnp.float32) for _ in range(4))
    return [
        ("gemm", (S((M, Kd), jnp.bfloat16), S((Kd, N), jnp.bfloat16)), {},
         2 * M * Kd * N, (M * Kd + Kd * N + M * N) * bf2),
        ("flash_attention", att, {},
         4 * B * H * Sq * Sq * D, (B * (H + 2 * K) * Sq * D * 2) * bf2),
        ("decode_attention",
         (S((8, H, D), jnp.bfloat16), S((8, K, Sq, D), jnp.bfloat16),
          S((8, K, Sq, D), jnp.bfloat16), S((8,), jnp.int32)), {},
         4 * 8 * H * Sq * D, 8 * 2 * K * Sq * D * bf2),
        ("linear_attention", la, {},
         4 * B * H * Sq * 64 * 64, 4 * B * H * Sq * 64 * f4),
        ("spmm", (S((R, L), jnp.float32), S((R, L), jnp.int32),
                  S((C, F), jnp.float32)), {},
         2 * R * L * F, (2 * R * L + C * F + R * F) * f4),
        ("bsr_spmm", (S((T, tbm, tbk), jnp.float32), S((T,), jnp.int32),
                      S((T,), jnp.int32), S((Kd, 512), jnp.float32)),
         {"num_rows": R},
         2 * T * tbm * tbk * 512, (T * tbm * tbk + Kd * 512 + R * 512) * f4),
        ("spmspm", (S((R, L), jnp.float32), S((R, L), jnp.int32),
                    S((C, L), jnp.float32), S((C, L), jnp.int32)),
         {"contraction_dim": Kd},
         2 * R * C * L, (4 * R * L + R * C) * f4),
        ("stencil", (S((X, Y, Z), jnp.float32),),
         {"offsets": offs, "weights": w},
         2 * len(offs) * X * Y * Z, 2 * X * Y * Z * f4),
    ]
