"""Render dry-run JSONL results into the EXPERIMENTS.md tables."""
from __future__ import annotations

import argparse
import json
from collections import OrderedDict


def _fmt_bytes(b):
    if b is None:
        return "-"
    if b >= 1e12:
        return f"{b/1e12:.2f}T"
    if b >= 1e9:
        return f"{b/1e9:.2f}G"
    return f"{b/1e6:.1f}M"


def load(paths):
    rows = OrderedDict()
    for path in paths:
        with open(path) as f:
            for line in f:
                if not line.strip():
                    continue
                r = json.loads(line)
                rows[(r["arch"], r["shape"], r.get("mesh", "?"))] = r
    return rows


def dryrun_table(rows) -> str:
    out = ["| arch | shape | mesh | compile | bytes/dev (arg+tmp) | collectives (full HLO) | status |",
           "|---|---|---|---|---|---|---|"]
    for (arch, shape, mesh), r in rows.items():
        if "error" in r:
            out.append(f"| {arch} | {shape} | {mesh} | - | - | - | ERROR: {r['error'][:80]} |")
            continue
        if "skipped" in r:
            out.append(f"| {arch} | {shape} | {mesh} | - | - | - | skipped: {r['skipped'][:60]} |")
            continue
        mem = r.get("memory", {})
        argb = mem.get("argument_size_in_bytes")
        tmpb = mem.get("temp_size_in_bytes")
        coll = r.get("collective_full_hlo", {})
        cstr = " ".join(f"{k.split('-')[-1][:4]}:{v}" for k, v in coll.items() if v)
        out.append(
            f"| {arch} | {shape} | {mesh} | {r.get('compile_s','-')}s | "
            f"{_fmt_bytes(argb)}+{_fmt_bytes(tmpb)} | {cstr} | OK |"
        )
    return "\n".join(out)


def roofline_table(rows) -> str:
    out = ["| arch | shape | T_comp | T_mem | T_coll | dominant | roofline frac | mem eff | useful FLOPs | dominant collective |",
           "|---|---|---|---|---|---|---|---|---|---|"]
    for (arch, shape, mesh), r in rows.items():
        if mesh != "16x16" or "roofline" not in r:
            continue
        t = r["roofline"]
        kinds = r.get("coll_by_kind", {})
        top = max(kinds, key=kinds.get) if kinds else "-"
        out.append(
            f"| {arch} | {shape} | {t['compute_s']*1e3:.1f}ms | "
            f"{t['memory_s']*1e3:.1f}ms | {t['collective_s']*1e3:.1f}ms | "
            f"{t['dominant'].replace('_s','')} | {t['roofline_fraction']:.3f} | "
            f"{t.get('memory_efficiency', 0):.2f} | "
            f"{r.get('useful_flops_ratio', 0):.2f} | "
            f"{top}:{_fmt_bytes(kinds.get(top, 0))} |"
        )
    return "\n".join(out)


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("jsonl", nargs="+")
    ap.add_argument("--kind", choices=["dryrun", "roofline"], default="roofline")
    args = ap.parse_args()
    rows = load(args.jsonl)
    print(dryrun_table(rows) if args.kind == "dryrun" else roofline_table(rows))


if __name__ == "__main__":
    main()
