"""Measured block-size autotuner over the registry override table.

Occamy's headline utilizations come from matching stream/tile geometry to the
memory hierarchy (the C4 double-buffering discipline); mistuned tiles show up
directly as lost FPU cycles. This module closes that loop for the TPU
translation: per **(op, operand shapes, dtypes, backend, impl)** it

1. generates candidate block geometries around the registry defaults,
2. prunes infeasible candidates *analytically* — each candidate's
   ``StreamProgram.vmem_bytes()`` (block footprint x double-buffering +
   scratch) is checked against the VMEM budget before anything compiles,
3. times the survivors through the **normal registry dispatch** (each
   candidate is staged with ``registry.block_override`` so the measured path
   is exactly the production path),
4. writes the winner through ``registry.set_block_override`` — the seam the
   registry reserved for this — and
5. persists a JSON tuning record that later runs load deterministically
   (``load_record`` + ``apply_record`` re-apply the selections without
   re-searching).

A candidate is only selected if it measured strictly faster than the
default geometry, so a recorded selection is never worse than the default
it replaced.

**Tuning under a mesh.** When a ``mesh`` is passed (``benchmarks/run.py
--autotune --mesh DxM`` or ``PxDxM``), every case is timed through the
sharded dispatch (``ops.* (mesh=...)``) and — the part that matters for
record validity — the entry is keyed by the **local shard geometry**
(``partition.local_operand_structs``), not the global operand shapes: the
kernel the block override feeds only ever sees the per-device shard, so a
record tuned at global shape 256x256 over a 4-way K-shard is really
evidence about 256x64 tiles. Records carry the mesh they were tuned under
and ``record_matches_environment`` refuses to silently apply one across
mesh boundaries.

CLI::

    PYTHONPATH=src python -m repro.launch.autotune --out autotune_record.json

or through the benchmark harness: ``python -m benchmarks.run --autotune``
(also triggered by ``REPRO_AUTOTUNE=1``).
"""
from __future__ import annotations

import argparse
import dataclasses
import json
import os
import time
from typing import Any, Callable

import jax
import jax.numpy as jnp
import numpy as np

from repro.core.streams import StreamProgram
from repro.kernels import ops, registry

# ~16 MB/core of VMEM; the budget caps what one pipelined StreamProgram may
# hold resident (double-buffered stream blocks + scratch)
VMEM_BUDGET_BYTES = int(os.environ.get("REPRO_VMEM_BUDGET", 16 * 2**20))
RECORD_VERSION = 1


@dataclasses.dataclass
class TuneCase:
    """One tunable call.

    Fields: ``op`` — the registry op name; ``args`` — the jax array
    operands, passed positionally to ``fn``; ``fn`` — the dispatch-level
    callable ``fn(*args, mesh=None)`` routed through ``ops.*`` (the measured
    path is exactly the production path, sharded when a mesh is given);
    ``candidates`` — partial block dicts, merged onto the registry defaults;
    ``program`` — the StreamProgram builder the VMEM feasibility probe
    uses; ``plan_kwargs`` — extra keyword operands the op's PartitionRule
    needs to resolve a plan (e.g. ``num_rows`` for bsr_spmm, ``offsets`` /
    ``weights`` for stencil); ``mesh`` — the mesh the case is tuned under
    (None for single-device tuning; set by ``autotune``, not by factories);
    ``precision`` — the ``core.precision`` policy NAME the case dispatches
    under (None = the legacy full-precision path). Timings of the scaled
    kernel are not evidence about the unscaled one (different stream
    count, operand widths, and rescale epilogue), so the policy joins the
    record key and gates ``apply_record``. ``consumer`` — the call-site
    shape class the timings are evidence about (``"prefill"`` = batched
    B x S operands, ``"decode"`` = single-position B x 1 operands). An
    attention geometry tuned at prefill shape says nothing about the
    decode step's one-row grid (and vice versa), so the consumer tag joins
    the record key and gates ``apply_record`` exactly like the policy.
    """

    op: str
    args: tuple
    fn: Callable
    candidates: list[dict[str, int]]
    program: Callable[[dict[str, int]], StreamProgram]
    plan_kwargs: dict = dataclasses.field(default_factory=dict)
    mesh: Any = None
    precision: str | None = None
    consumer: str | None = None


def mesh_tag(mesh) -> str | None:
    """Canonical record tag for the mesh a search ran under: ``"2x4"`` /
    ``"2x2x2"`` style (axis sizes in axis order), or None for no mesh.
    Works for a Mesh or a device-free partition.MeshSpec."""
    if mesh is None:
        return None
    return "x".join(str(int(mesh.shape[a])) for a in mesh.axis_names)


def local_case_shapes(case: TuneCase, impl: str) -> tuple:
    """The operand geometry that keys ``case``'s record entry.

    Args: ``case`` — the TuneCase (its ``mesh`` decides); ``impl`` — the
    resolved registry impl the plan would dispatch to.

    Without a mesh this is just ``case.args``. Under a mesh it is the
    per-device shard geometry from ``partition.local_operand_structs`` —
    the shapes the kernel actually runs on, which is the only geometry a
    tuned block size is evidence about. A case whose plan resolves to
    replication keys identically to the unmeshed case (same local kernel,
    same record entry — deliberately shared).
    """
    if case.mesh is None:
        return case.args
    from repro.kernels import partition

    plan = partition.plan_for(
        case.op, case.mesh, *case.args, impl=impl, **case.plan_kwargs
    )
    return partition.local_operand_structs(plan, case.mesh, case.args)


def case_key(op: str, arrays, backend: str, impl: str,
             precision: str | None = None,
             consumer: str | None = None) -> str:
    """Record key for one tuning entry: ``op|shapes:dtypes|backend|impl``
    (``|precision`` appended for policy-scoped entries, ``#consumer`` for
    consumer-scoped ones).

    Args: ``op`` — op name; ``arrays`` — the operands whose shape/dtype
    identify the tuned kernel geometry (pass the *local shard* structs when
    tuning under a mesh — see ``local_case_shapes``); ``backend`` /
    ``impl`` — the jax backend and registry impl the timings belong to;
    ``precision`` — the policy name for scaled-path cases. The dispatch
    operands of a scaled case are the same fp32 arrays as the legacy case
    (quantization happens inside the impl), so without the suffix the two
    would collide on one record entry. ``consumer`` — the call-site shape
    class (``"prefill"``/``"decode"``); it rides the key so a serving
    session can hold BOTH a prefill-tuned and a decode-tuned entry for the
    same op without one clobbering the other, even when a suite probes
    them at overlapping operand geometry.
    """
    shapes = ",".join(
        f"{'x'.join(map(str, a.shape))}:{a.dtype}" for a in arrays
    )
    key = f"{op}|{shapes}|{backend}|{impl}"
    if precision is not None:
        key = f"{key}|{precision}"
    return key if consumer is None else f"{key}#{consumer}"


def _time_call(fn, args, *, reps: int, warmup: int = 1) -> float:
    """Median wall-time of ``fn(*args)`` per call in seconds over ``reps``
    measured calls (jit compile paid in ``warmup`` untimed calls)."""
    for _ in range(warmup):
        out = fn(*args)
    jax.block_until_ready(out)
    times = []
    for _ in range(reps):
        t0 = time.perf_counter()
        out = fn(*args)
        jax.block_until_ready(out)
        times.append(time.perf_counter() - t0)
    times.sort()
    return times[len(times) // 2]


def candidate_prior_seconds(case: TuneCase, blocks: dict) -> float:
    """Analytic warm-start prior for one candidate geometry: ``case``'s
    StreamProgram built at ``blocks``, priced as modeled HBM stream time
    ``traffic_bytes() / HBM_BW``.

    Small blocks re-fetch shared operands more often (more grid steps over
    the same data), so per-candidate traffic differs even at fixed problem
    size — exactly the effect measured tuning keeps rediscovering. Pricing
    it analytically lets the search measure candidates cheapest-first and
    lets a trial budget cut the modeled-slow tail instead of a random one.
    """
    from repro.launch import roofline

    return case.program(blocks).traffic_bytes() / roofline.HBM_BW


def autotune_case(
    case: TuneCase,
    *,
    budget_bytes: int = VMEM_BUDGET_BYTES,
    reps: int = 3,
    trial_budget: int | None = None,
    time_candidate: Callable | None = None,
) -> dict:
    """Search one case. Returns the record entry (winner + full audit trail).

    Args: ``case`` — the TuneCase to search (its ``mesh`` field, when set,
    routes every timed call through the sharded dispatch); ``budget_bytes``
    — the VMEM ceiling the analytic prune checks candidates against;
    ``reps`` — measured repetitions per candidate; ``trial_budget`` — when
    set, at most this many candidates are actually timed, taken in
    warm-start order (the default geometry is always timed regardless, so
    the strictly-faster selection keeps its baseline); ``time_candidate
    (case, blocks)`` — may be injected for tests; the default jits a fresh
    wrapper per candidate (a shared jit cache would silently reuse the
    first candidate's compiled geometry).

    Warm start: feasible candidates are timed in ascending order of the
    roofline prior (``candidate_prior_seconds``), so the modeled-best
    geometry is measured first and a trial budget spends its measurements
    on the candidates the analytic model already favours.

    Invariant: a non-default candidate is recorded only if it measured
    strictly faster than the default geometry.
    """
    defaults = registry.block_defaults(case.op, overrides=False)

    # normalize to full dicts, defaults first, order-preserving dedupe
    seen, ordered = set(), []
    for cand in [{}] + list(case.candidates):
        full = {**defaults, **cand}
        sig = tuple(sorted(full.items()))
        if sig not in seen:
            seen.add(sig)
            ordered.append(full)

    pruned, feasible = [], []
    for full in ordered:
        vmem = case.program(full).vmem_bytes()
        if vmem > budget_bytes:
            pruned.append({"blocks": full, "vmem_bytes": vmem})
        else:
            feasible.append(full)

    # warm start: measure in analytic-prior order (stable sort — ties keep
    # the candidate-list order, so the defaults-first convention survives)
    priors = {id(f): candidate_prior_seconds(case, f) for f in feasible}
    feasible.sort(key=lambda f: priors[id(f)])

    skipped = []
    if trial_budget is not None:
        keep = feasible[: max(int(trial_budget), 1)]
        if defaults in feasible and defaults not in keep:
            # the baseline must stay measured even when the prior ranks it
            # below the cut — without it no candidate could be recorded
            keep.append(next(f for f in feasible if f == defaults))
        skipped = [
            {"blocks": f, "prior_s": priors[id(f)]}
            for f in feasible
            if not any(f is k for k in keep)
        ]
        feasible = keep

    if time_candidate is None:

        def time_candidate(case, blocks):
            # fresh wrapper, fresh cache; the mesh (if any) rides the closure
            fn = jax.jit(lambda *a: case.fn(*a, mesh=case.mesh))
            return _time_call(fn, case.args, reps=reps)

    timed = []
    for full in feasible:
        with registry.block_override(case.op, **full):
            timed.append({
                "blocks": full,
                "us_per_call": time_candidate(case, full) * 1e6,
                "prior_s": priors[id(full)],
            })

    default_entry = next(
        (t for t in timed if t["blocks"] == defaults), None
    )
    # strictly-faster-than-default selection: the recorded winner is never
    # worse than the default it replaces (ties keep the default)
    best = default_entry or (timed[0] if timed else None)
    for t in timed:
        if best is None or t["us_per_call"] < best["us_per_call"]:
            best = t
    return {
        "op": case.op,
        "precision": case.precision,
        "consumer": case.consumer,
        "blocks": best["blocks"] if best else defaults,
        "us_per_call": best["us_per_call"] if best else None,
        "default_blocks": defaults,
        "default_us": default_entry["us_per_call"] if default_entry else None,
        "timed": timed,
        "pruned": pruned,
        "skipped_by_budget": skipped,
        "trial_budget": trial_budget,
        "vmem_budget_bytes": budget_bytes,
    }


# ---------------------------------------------------------------------------
# Default suite: one representative call per op with a block table
# ---------------------------------------------------------------------------


def _gemm_case(rng) -> TuneCase:
    from repro.kernels.gemm import gemm_program

    m = k = n = 256
    a = jnp.asarray(rng.standard_normal((m, k)), jnp.float32)
    b = jnp.asarray(rng.standard_normal((k, n)), jnp.float32)

    def program(bl):
        bm, bk, bn = min(bl["bm"], m), min(bl["bk"], k), min(bl["bn"], n)
        return gemm_program(
            m + (-m) % bm, n + (-n) % bn, k + (-k) % bk, bm, bn, bk,
            a_dtype=a.dtype, b_dtype=b.dtype, out_dtype=a.dtype,
            accum_dtype=jnp.float32,
        )

    return TuneCase(
        "gemm", (a, b), lambda a, b, mesh=None: ops.gemm(a, b, mesh=mesh),
        [{"bm": s, "bk": s, "bn": s} for s in (64, 128, 256)], program,
    )


def _flash_attention_case(rng) -> TuneCase:
    from repro.kernels.flash_attention import flash_attention_program

    B, H, S, D = 1, 4, 256, 64
    q, k, v = (
        jnp.asarray(rng.standard_normal((B, H, S, D)), jnp.float32)
        for _ in range(3)
    )

    def program(bl):
        bq, bk = min(bl["bq"], S), min(bl["bk"], S)
        nq, nk = -(-S // bq), -(-S // bk)
        return flash_attention_program(
            B, H, 1, nq * bq, D, nq, nk, bq, bk, q.dtype, k.dtype, v.dtype,
            scale=1.0, causal=True, window=0, q_offset=0, sk=S,
        )

    return TuneCase(
        "flash_attention", (q, k, v),
        lambda q, k, v, mesh=None: ops.flash_attention(
            q, k, v, causal=True, mesh=mesh),
        [{"bk": s} for s in (32, 64, 128, 256)], program,
    )


def _linear_attention_case(rng) -> TuneCase:
    from repro.kernels.rwkv6 import linear_attention_program

    B, H, T, N = 1, 2, 256, 64
    r, k, v = (
        jnp.asarray(rng.standard_normal((B, H, T, N)), jnp.float32)
        for _ in range(3)
    )
    w = jnp.asarray(-rng.uniform(0.01, 1.0, (B, H, T, N)), jnp.float32)

    def program(bl):
        chunk = min(bl["chunk"], T)
        return linear_attention_program(
            B * H, T + (-T) % chunk, N, N, chunk, ssd=True,
            r_dtype=r.dtype, k_dtype=k.dtype, v_dtype=v.dtype,
            w_dtype=w.dtype, o_dtype=v.dtype,
        )

    return TuneCase(
        "linear_attention", (r, k, v, w),
        lambda r, k, v, w, mesh=None: ops.linear_attention(
            r, k, v, w, mesh=mesh),
        [{"chunk": s} for s in (8, 16, 32)], program,
    )


def _spmm_case(rng) -> TuneCase:
    from repro.core.sparse import random_ell
    from repro.kernels.spmm import ell_spmm_program

    R, C, F = 512, 256, 64
    A = random_ell(rng, R, C, 0.05)
    dense = jnp.asarray(rng.standard_normal((C, F)), jnp.float32)
    L = A.values.shape[1]

    def program(bl):
        bm = min(bl["bm"], R)
        return ell_spmm_program(
            R + (-R) % bm, L, C, F, bm, A.values.dtype, dense.dtype
        )

    return TuneCase(
        "spmm", (A.values, A.cols, dense),
        lambda v, c, d, mesh=None: ops.spmm(v, c, d, mesh=mesh),
        [{"bm": s} for s in (32, 64, 128, 256)], program,
    )


def _bsr_spmm_case(rng) -> TuneCase:
    from repro.core.sparse import dense_to_bsr
    from repro.kernels.spmm import bsr_spmm_program

    R, K, F = 256, 256, 512
    mat = np.zeros((R, K), np.float32)
    mask = rng.random((R, K)) < 0.05
    mat[mask] = rng.standard_normal(mask.sum())
    A = dense_to_bsr(mat, bm=8, bk=128)
    dense = jnp.asarray(rng.standard_normal((K, F)), jnp.float32)
    T, bm, bk = A.tile_values.shape

    def program(bl):
        bf = min(bl["bf"], F)
        return bsr_spmm_program(
            A.tile_rows, A.tile_cols, T, bm, bk, bf, F + (-F) % bf, R,
            A.tile_values.dtype, dense.dtype,
        )

    return TuneCase(
        "bsr_spmm", (A.tile_values, A.tile_rows, A.tile_cols, dense),
        lambda tv, tr, tc, d, mesh=None: ops.bsr_spmm(
            tv, tr, tc, d, R, mesh=mesh),
        [{"bf": s} for s in (128, 256, 512)], program,
        plan_kwargs={"num_rows": R},
    )


def _spmspm_case(rng) -> TuneCase:
    from repro.core.sparse import random_ell
    from repro.kernels.spmspm import spmspm_program

    R, C, K = 128, 128, 256
    A = random_ell(rng, R, K, 0.05)
    B = random_ell(rng, C, K, 0.05)
    La, Lb = A.values.shape[1], B.values.shape[1]

    def program(bl):
        bm, bn = min(bl["bm"], R), min(bl["bn"], C)
        return spmspm_program(
            R + (-R) % bm, C + (-C) % bn, La, Lb, bm, bn,
            A.values.dtype, B.values.dtype,
        )

    return TuneCase(
        "spmspm", (A.values, A.cols, B.values, B.cols),
        lambda av, ac, bv, br, mesh=None: ops.spmspm(
            av, ac, bv, br, K, mesh=mesh),
        [{"bm": m, "bn": n} for m in (8, 16, 32) for n in (64, 128)], program,
        plan_kwargs={"contraction_dim": K},
    )


def _stencil_case(rng) -> TuneCase:
    from repro.kernels.stencil import stencil_program

    X, Y, Z = 64, 32, 32
    grid = jnp.asarray(rng.standard_normal((X, Y, Z)), jnp.float32)
    offsets = np.array(
        [(0, 0, 0), (1, 0, 0), (-1, 0, 0), (0, 1, 0), (0, -1, 0),
         (0, 0, 1), (0, 0, -1)], np.int32,
    )
    weights = np.full(len(offsets), 1.0 / len(offsets), np.float32)

    def program(bl):
        bx = min(bl["bx"], X)
        return stencil_program(X, Y, Z, bx, offsets, weights, grid.dtype)

    return TuneCase(
        "stencil", (grid,),
        lambda g, mesh=None: ops.stencil(g, offsets, weights, mesh=mesh),
        [{"bx": s} for s in (4, 8, 16, 32)], program,
        plan_kwargs={"offsets": offsets, "weights": weights},
    )


def _decode_attention_case(rng) -> TuneCase:
    """decode has no StreamProgram (its blocked form is the xla impl), so
    the VMEM probe uses a stream DESCRIPTION of that impl's cache traffic:
    one resident q block plus double-buffered (bs x D) K/V cache tiles per
    grid step — the same footprint the online-softmax scan carries."""
    from repro.core.streams import AffineStream, StreamProgram

    B, H, K, S, D = 2, 8, 4, 1024, 64
    q = jnp.asarray(rng.standard_normal((B, H, D)), jnp.float32)
    k = jnp.asarray(rng.standard_normal((B, K, S, D)), jnp.float32)
    v = jnp.asarray(rng.standard_normal((B, K, S, D)), jnp.float32)
    pos = jnp.full((B,), S - 1, jnp.int32)

    def program(bl):
        bs = min(bl["bs"], S)
        Sp = S + (-S) % bs
        cache = AffineStream((B, K, bs, D), lambda i: (0, 0, i, 0), dtype=k.dtype)
        head = AffineStream((B, H, D), lambda i: (0, 0, 0), dtype=q.dtype)
        return StreamProgram(
            name="decode_attention",
            body=lambda *_: None,  # feasibility probe only; never executed
            grid=(Sp // bs,),
            in_streams=(head, cache, cache),
            out_streams=(head,),
            out_shapes=(jax.ShapeDtypeStruct((B, H, D), q.dtype),),
        )

    return TuneCase(
        "decode_attention", (q, k, v, pos),
        lambda q, k, v, p, mesh=None: ops.decode_attention(
            q, k, v, p, mesh=mesh),
        [{"bs": s} for s in (128, 256, 512, 1024)], program,
    )


DEFAULT_SUITE: dict[str, Callable] = {
    "gemm": _gemm_case,
    "flash_attention": _flash_attention_case,
    "linear_attention": _linear_attention_case,
    "spmm": _spmm_case,
    "bsr_spmm": _bsr_spmm_case,
    "spmspm": _spmspm_case,
    "stencil": _stencil_case,
    "decode_attention": _decode_attention_case,
}


def _gemm_precision_case(policy: str) -> Callable:
    """Factory-of-factory for the policy-scoped gemm cases: same operand
    geometry as ``_gemm_case`` but dispatched with ``precision=policy``,
    feasibility-probed through ``gemm_scaled_program`` (whose narrow value
    streams plus fp32 scale streams give the analytic prune and the
    roofline warm-start prior per-policy traffic, not fp32 traffic)."""

    def factory(rng) -> TuneCase:
        from repro.core import precision as prec
        from repro.kernels.gemm import gemm_scaled_program

        p = prec.resolve(policy)
        m = k = n = 256
        a = jnp.asarray(rng.standard_normal((m, k)), jnp.float32)
        b = jnp.asarray(rng.standard_normal((k, n)), jnp.float32)

        def program(bl):
            bm, bk, bn = min(bl["bm"], m), min(bl["bk"], k), min(bl["bn"], n)
            return gemm_scaled_program(
                m + (-m) % bm, n + (-n) % bn, k + (-k) % bk, bm, bn, bk,
                compute_dtype=p.compute_dtype, out_dtype=jnp.float32,
                accum_dtype=p.accum_dtype,
            )

        return TuneCase(
            "gemm", (a, b),
            lambda a, b, mesh=None: ops.gemm(a, b, precision=p, mesh=mesh),
            [{"bm": s, "bk": s, "bn": s} for s in (64, 128, 256)], program,
            plan_kwargs={"precision": p}, precision=p.name,
        )

    return factory


# policy-scoped cases: the scaled gemm path tuned under fp8 and bf16. Kept
# out of DEFAULT_SUITE so existing records and the CI smoke stay stable;
# ``full_suite()`` is the merged table the analyzer sweeps.
PRECISION_SUITE: dict[str, Callable] = {
    "gemm@fp8": _gemm_precision_case("fp8"),
    "gemm@bf16": _gemm_precision_case("bf16"),
}


def _flash_attention_consumer_case(consumer: str) -> Callable:
    """Factory-of-factory for the consumer-scoped flash cases: the same op
    probed at the shape each serving call site actually dispatches —
    ``prefill`` runs the batched B x S geometry, ``decode`` a one-query-row
    B x 1 geometry (speculative / chunked single-step flash). The two grids
    share no tiling evidence: a bk that wins when 256 query rows amortize
    each K tile streams the whole cache per single row at decode."""

    def factory(rng) -> TuneCase:
        from repro.kernels.flash_attention import flash_attention_program

        B, H, S, D = 1, 4, 256, 64
        sq = S if consumer == "prefill" else 1
        q = jnp.asarray(rng.standard_normal((B, H, sq, D)), jnp.float32)
        k, v = (
            jnp.asarray(rng.standard_normal((B, H, S, D)), jnp.float32)
            for _ in range(2)
        )
        q_offset = 0 if consumer == "prefill" else S - 1

        def program(bl):
            bq, bk = min(bl["bq"], sq), min(bl["bk"], S)
            nq, nk = -(-sq // bq), -(-S // bk)
            return flash_attention_program(
                B, H, 1, nq * bq, D, nq, nk, bq, bk, q.dtype, k.dtype,
                v.dtype, scale=1.0, causal=True, window=0,
                q_offset=q_offset, sk=S,
            )

        return TuneCase(
            "flash_attention", (q, k, v),
            lambda q, k, v, mesh=None: ops.flash_attention(
                q, k, v, causal=True, q_offset=q_offset, mesh=mesh),
            [{"bk": s} for s in (32, 64, 128, 256)], program,
            consumer=consumer,
        )

    return factory


def _decode_attention_consumer_case() -> Callable:
    """``decode_attention`` tagged with its (only) consumer class, so the
    serving engine's ``apply_record(consumer="decode")`` picks it up and an
    untagged legacy record entry for the same geometry cannot collide."""

    def factory(rng) -> TuneCase:
        case = _decode_attention_case(rng)
        case.consumer = "decode"
        return case

    return factory


# consumer-scoped cases: the attention ops probed per call-site shape
# class (prefill B x S vs decode B x 1). Same record-stability reasoning
# as PRECISION_SUITE for keeping them out of DEFAULT_SUITE.
CONSUMER_SUITE: dict[str, Callable] = {
    "flash_attention#prefill": _flash_attention_consumer_case("prefill"),
    "flash_attention#decode": _flash_attention_consumer_case("decode"),
    "decode_attention#decode": _decode_attention_consumer_case(),
}


def full_suite() -> dict[str, Callable]:
    """DEFAULT_SUITE plus the policy-scoped PRECISION_SUITE and the
    consumer-scoped CONSUMER_SUITE cases — the complete factory table the
    CLI searches and the ``repro.analysis`` plan rules (vmem-budget,
    accum-dtype-widening) sweep."""
    return {**DEFAULT_SUITE, **PRECISION_SUITE, **CONSUMER_SUITE}


# ---------------------------------------------------------------------------
# Record: search, persist, deterministic re-apply
# ---------------------------------------------------------------------------


def autotune(
    ops_subset=None,
    *,
    budget_bytes: int = VMEM_BUDGET_BYTES,
    reps: int = 3,
    seed: int = 0,
    suite: dict[str, Callable] | None = None,
    mesh: Any = None,
    trial_budget: int | None = None,
    time_candidate: Callable | None = None,
) -> dict:
    """Search every suite case and return the tuning record.

    Args: ``ops_subset`` — restrict to these op names (KeyError on unknown
    names); ``budget_bytes`` — VMEM ceiling for the analytic prune;
    ``reps`` — measured repetitions per candidate; ``seed`` — operand RNG
    seed (records are deterministic given a seed); ``suite`` — factory
    table, defaulting to DEFAULT_SUITE; ``mesh`` — tune through the sharded
    dispatch over this mesh, keying every entry by the LOCAL shard geometry
    (see ``local_case_shapes``); ``trial_budget`` — per-case cap on how
    many candidates are timed, spent in roofline-prior order (the default
    geometry always stays measured); ``time_candidate`` — test injection
    forwarded to ``autotune_case``.

    Returns the record dict (version, backend, impl, mesh tag, entries).
    Winners are NOT yet applied — call ``apply_record``.
    """
    suite = DEFAULT_SUITE if suite is None else suite
    if ops_subset:
        unknown = set(ops_subset) - set(suite)
        if unknown:
            raise KeyError(
                f"unknown autotune ops {sorted(unknown)}; known: {sorted(suite)}"
            )
    backend = jax.default_backend()
    impl = registry.resolve_impl(None)
    rng = np.random.default_rng(seed)
    entries = {}
    for name, factory in suite.items():
        if ops_subset and name not in ops_subset:
            continue
        case = factory(rng)
        case.mesh = mesh
        entry = autotune_case(
            case, budget_bytes=budget_bytes, reps=reps,
            trial_budget=trial_budget, time_candidate=time_candidate,
        )
        key = case_key(case.op, local_case_shapes(case, impl), backend, impl,
                       precision=case.precision, consumer=case.consumer)
        entries[key] = entry
    return {
        "version": RECORD_VERSION,
        "backend": backend,
        "impl": impl,
        "mesh": mesh_tag(mesh),
        "entries": entries,
    }


def save_record(record: dict, path: str) -> None:
    """Persist ``record`` to ``path`` as deterministic (sorted, indented)
    JSON with a trailing newline."""
    with open(path, "w") as f:
        json.dump(record, f, indent=2, sort_keys=True)
        f.write("\n")


def load_record(path: str) -> dict:
    """Load a tuning record from ``path``; raises ValueError when its
    version is not the RECORD_VERSION this module writes."""
    with open(path) as f:
        record = json.load(f)
    if record.get("version") != RECORD_VERSION:
        raise ValueError(
            f"{path}: tuning record version {record.get('version')!r} != "
            f"{RECORD_VERSION}; re-run the autotuner"
        )
    return record


def record_matches_environment(record: dict, *, mesh: Any = None) -> bool:
    """Was ``record`` tuned for the current (backend, impl) and for ``mesh``?

    Geometry tuned for one impl is not evidence about another; likewise a
    record tuned under one mesh keys (and tuned) the local shard shapes of
    THAT mesh, so it only applies where the same mesh (by ``mesh_tag``) is
    in play. Records predating the mesh field match ``mesh=None``.
    """
    return (
        record.get("backend") == jax.default_backend()
        and record.get("impl") == registry.resolve_impl(None)
        and record.get("mesh") == mesh_tag(mesh)
    )


def apply_record(record: dict, *, force: bool = False,
                 mesh: Any = None,
                 precision: str | None = None,
                 consumer: str | None = None) -> dict[str, dict[str, int]]:
    """Write every recorded winner through ``registry.set_block_override``
    (deterministic: no timing, no search).

    Args: ``record`` — a dict from ``autotune``/``load_record``; ``force``
    — skip the environment check; ``mesh`` — the mesh this session
    dispatches kernels over (None for single-device), matched against the
    record's tuned mesh; ``precision`` — apply only entries tuned under
    this policy name (None = the legacy full-precision entries). The
    registry's block-override table has no precision axis, so a session
    must pick which policy's winners drive it: an fp8-tuned geometry is
    measured through the scaled kernel and is not evidence about the
    unscaled one (and vice versa) — entries never cross-apply.
    ``consumer`` — likewise for the call-site shape axis: apply only
    entries tuned for this consumer class (None = untagged legacy
    entries). A serving engine applies the ``"decode"`` winners before its
    decode loop and the ``"prefill"`` winners around admission prefill;
    a prefill-tuned geometry never leaks into the decode step's one-row
    grid through a shared record. Returns {op: blocks} applied.

    Raises if the record was tuned for a different backend/impl/mesh than
    the one currently dispatching — applying it would silently mistune, the
    exact bug class the tuner exists to remove. ``force=True`` overrides.
    """
    if not force and not record_matches_environment(record, mesh=mesh):
        raise ValueError(
            f"tuning record is for backend={record.get('backend')!r} "
            f"impl={record.get('impl')!r} mesh={record.get('mesh')!r} but "
            f"this session dispatches backend={jax.default_backend()!r} "
            f"impl={registry.resolve_impl(None)!r} mesh={mesh_tag(mesh)!r}; "
            f"re-run the autotuner (or pass force=True)"
        )
    applied = {}
    for entry in record["entries"].values():
        if entry.get("precision") != precision:
            continue
        if entry.get("consumer") != consumer:
            continue
        blocks = {k: int(v) for k, v in entry["blocks"].items()}
        registry.set_block_override(entry["op"], **blocks)
        applied[entry["op"]] = blocks
    return applied


def record_deltas(record: dict) -> dict[str, dict]:
    """Tuned-vs-default summary per op of one tuning ``record`` — the
    perf-harness reporting view. Returns {op: {blocks, default_blocks,
    us_per_call, default_us, delta_pct, non_default}} with None times
    preserved (a case whose candidates were all pruned has no timing)."""
    out = {}
    for entry in record["entries"].values():
        tuned, default = entry["us_per_call"], entry["default_us"]
        delta = (
            (tuned - default) / default * 100.0
            if tuned is not None and default
            else None
        )
        name = entry["op"]
        if entry.get("precision"):
            name = f"{name}@{entry['precision']}"
        if entry.get("consumer"):
            name = f"{name}#{entry['consumer']}"
        out[name] = {
            "blocks": entry["blocks"],
            "default_blocks": entry["default_blocks"],
            "us_per_call": tuned,
            "default_us": default,
            "delta_pct": delta,
            "non_default": entry["blocks"] != entry["default_blocks"],
        }
    return out


def main(argv=None) -> None:
    """CLI entry point: search, persist, and report. ``argv`` defaults to
    sys.argv (see ``--help`` for the flags)."""
    ap = argparse.ArgumentParser(
        description="benchmark-driven block-size autotuner; persists a JSON "
        "tuning record later runs load deterministically"
    )
    ap.add_argument("--out", default="autotune_record.json")
    ap.add_argument("--ops", default=None,
                    help="comma-separated subset of "
                         f"{sorted(full_suite())} (``op@policy`` names are "
                         "the precision-scoped scaled-path cases)")
    ap.add_argument("--reps", type=int, default=3)
    ap.add_argument("--budget-bytes", type=int, default=VMEM_BUDGET_BYTES)
    ap.add_argument("--budget", type=int, default=None, metavar="N",
                    help="time at most N candidates per case, spent in "
                    "roofline-prior order (the default geometry is always "
                    "measured); unset = time every feasible candidate")
    ap.add_argument("--impl", default=None,
                    help="pin a registry impl for the search (default: the "
                    "normal dispatch resolution)")
    args = ap.parse_args(argv)

    subset = args.ops.split(",") if args.ops else None
    with registry.default_impl(args.impl):
        record = autotune(
            subset, budget_bytes=args.budget_bytes, reps=args.reps,
            trial_budget=args.budget, suite=full_suite(),
        )
    save_record(record, args.out)
    print(f"wrote {args.out}")
    for op, d in sorted(record_deltas(record).items()):
        tuned_us = (
            "n/a (all candidates pruned)" if d["us_per_call"] is None
            else f"{d['us_per_call']:.1f}us"
        )
        default_us = (
            "n/a" if d["default_us"] is None else f"{d['default_us']:.1f}us"
        )
        delta = (
            "n/a" if d["delta_pct"] is None else f"{d['delta_pct']:+.1f}%"
        )
        print(
            f"{op}: {d['blocks']} {tuned_us} "
            f"(default {d['default_blocks']} {default_us}, delta {delta})"
        )


if __name__ == "__main__":
    main()
