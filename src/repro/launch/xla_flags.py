"""XLA_FLAGS bootstrap shared by the launcher entry points.

Both dry-run style launchers (``launch/dryrun.py``, ``launch/hillclimb.py``)
need ``--xla_force_host_platform_device_count`` in the environment BEFORE
anything imports jax. The one correct way to put it there is to APPEND to
whatever the caller already exported: assigning ``os.environ["XLA_FLAGS"]``
outright silently discards the user's own flags (dump directories, a
caller-chosen device count, ...) — the regression both launchers now guard
against via ``tests/test_registry.py``.

This module deliberately imports nothing beyond the stdlib so launchers can
call it on their very first line.
"""
from __future__ import annotations

import os

_DEVICE_FLAG = "--xla_force_host_platform_device_count"


def ensure_host_device_count(n: int = 512) -> None:
    """Append ``--xla_force_host_platform_device_count=n`` to ``XLA_FLAGS``.

    Args: ``n`` — the forced host device count the launcher wants.

    Preserves every caller-set flag, is idempotent, and never overrides a
    caller-chosen device count (XLA parses flags last-wins, so matching is
    by flag name, not full token). Must run before any jax import.
    """
    existing = os.environ.get("XLA_FLAGS", "")
    if not any(t.split("=", 1)[0] == _DEVICE_FLAG for t in existing.split()):
        os.environ["XLA_FLAGS"] = f"{existing} {_DEVICE_FLAG}={n}".strip()
