"""Training launcher.

CPU-container scale:   PYTHONPATH=src python -m repro.launch.train \
                          --arch gemma-2b --reduced --steps 100 --batch 8 --seq 128
Production scale: the same entry point with --mesh 16x16 (or 2x16x16 through
the dry-run path) builds the pjit train step with the full sharding rules.
"""
from __future__ import annotations

import argparse

import jax

from repro.configs.base import SHAPES, get_config
from repro.runtime import train_loop
from repro.runtime.fault_tolerance import FailureInjector


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", required=True)
    ap.add_argument("--shape", default="train_4k")
    ap.add_argument("--reduced", action="store_true")
    ap.add_argument("--steps", type=int, default=100)
    ap.add_argument("--batch", type=int, default=None)
    ap.add_argument("--seq", type=int, default=None)
    ap.add_argument("--seed", type=int, default=0)
    ap.add_argument("--ckpt-dir", default=None)
    ap.add_argument("--ckpt-every", type=int, default=50)
    ap.add_argument("--microbatches", type=int, default=1)
    ap.add_argument("--grad-compression", action="store_true")
    ap.add_argument("--mesh", default=None, help="e.g. 1x1 / 4x2 (data x model)")
    ap.add_argument("--inject-crash-at", type=int, default=None)
    args = ap.parse_args()

    cfg = get_config(args.arch, reduced=args.reduced)
    shape = SHAPES[args.shape]
    mesh = None
    if args.mesh:
        d, m = (int(x) for x in args.mesh.split("x"))
        mesh = jax.make_mesh((d, m), ("data", "model"))

    injector = (
        FailureInjector({args.inject_crash_at: "crash"})
        if args.inject_crash_at
        else None
    )
    try:
        state, losses, monitor = train_loop.run_training(
            cfg, shape, mesh,
            num_steps=args.steps,
            seed=args.seed,
            ckpt_dir=args.ckpt_dir,
            ckpt_every=args.ckpt_every,
            batch_override=args.batch,
            seq_override=args.seq,
            microbatches=args.microbatches,
            grad_compression=args.grad_compression,
            failure_injector=injector,
        )
    except RuntimeError as e:
        print(f"[fault] {e} — restart this command to resume from checkpoint")
        raise SystemExit(42)
    print(
        f"done: {len(losses)} steps, loss {losses[0]:.4f} -> {losses[-1]:.4f},"
        f" straggle events {monitor.events}"
    )


if __name__ == "__main__":
    main()
