from repro.launch.xla_flags import ensure_host_device_count

# append, don't clobber: the caller's own XLA_FLAGS must survive, including
# a caller-chosen device count (the shared launcher bootstrap)
ensure_host_device_count(512)

# isort: split
import argparse
import json

from repro.configs.base import get_config
from repro.launch.dryrun import lower_cell

"""§Perf hillclimb driver: re-lower one cell with config-override variants and
report the roofline-term deltas vs the recorded baseline.

  PYTHONPATH=src python -m repro.launch.hillclimb --arch grok-1-314b \
      --shape train_4k --set tp_reduce_bf16=True --set microbatches=2
"""


def parse_override(kv: str):
    k, v = kv.split("=", 1)
    for cast in (int, float):
        try:
            return k, cast(v)
        except ValueError:
            pass
    if v in ("True", "False"):
        return k, v == "True"
    return k, v


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", required=True)
    ap.add_argument("--shape", required=True)
    ap.add_argument("--set", action="append", default=[],
                    help="cfg overrides, e.g. tp_reduce_bf16=True")
    ap.add_argument("--skip-full", action="store_true",
                    help="cost probes only (skip the full-depth compile)")
    ap.add_argument("--autotune-record", default=None,
                    help="apply a block-size tuning record "
                         "(repro.launch.autotune) before lowering and attach "
                         "the tuned-vs-default us_per_call deltas")
    ap.add_argument("--out", default=None)
    args = ap.parse_args()

    autotune = None
    if args.autotune_record:
        from repro.launch import autotune as at

        record = at.load_record(args.autotune_record)
        at.apply_record(record)  # deterministic: no re-search
        autotune = at.record_deltas(record)

    overrides = dict(parse_override(s) for s in args.set)
    cfg = get_config(args.arch).replace(**overrides)
    res = lower_cell(args.arch, args.shape, multi_pod=False,
                     cfg_override=cfg, skip_full=args.skip_full)
    res["overrides"] = overrides
    if autotune is not None:
        res["autotune"] = autotune
    line = json.dumps(res)
    print(line)
    if args.out:
        with open(args.out, "a") as f:
            f.write(line + "\n")


if __name__ == "__main__":
    main()
