"""Batched serving launcher: prefill a batch of prompts, then decode.

CPU-container demo: PYTHONPATH=src python -m repro.launch.serve \
    --arch occamy-gptj --reduced --batch 4 --prompt-len 32 --gen 16
"""
from __future__ import annotations

import argparse
import time

import jax
import jax.numpy as jnp
import numpy as np

from repro.configs.base import get_config
from repro.models import registry, transformer, multimodal


def scan_prefill(params, cfg, cache, tokens):
    """Prompt prefill for recurrent-cache families (ssm/hybrid/audio):
    scan ``registry.decode_step`` over the prompt inside one jit. Returns
    (last-token logits (B, V_pad), cache after the full prompt)."""
    B, S0 = tokens.shape

    def run(params, cache, tokens):
        def body(c, xs):
            tok, t = xs
            lg, c = registry.decode_step(
                params, cfg, c,
                {"token": tok, "position": jnp.full((B,), t, jnp.int32)},
            )
            return c, lg

        xs = (tokens.T, jnp.arange(S0, dtype=jnp.int32))
        cache, logits = jax.lax.scan(body, cache, xs)
        return logits[-1], cache

    return jax.jit(run, donate_argnums=(1,))(params, cache, tokens)


def generate(cfg, params, tokens, gen_len: int, max_len: int,
             extra_batch: dict | None = None, greedy: bool = True):
    """tokens: (B, S0) prompt; returns (B, S0+gen_len)."""
    B, S0 = tokens.shape
    if cfg.family in ("dense", "moe", "vlm"):
        batch = {"tokens": tokens}
        if cfg.family == "vlm" and extra_batch:
            batch["patches"] = extra_batch["patches"]
        logits, cache = transformer.prefill_step(params, cfg, batch, max_len)
        pos0 = S0 + (cfg.num_patches if cfg.family == "vlm" else 0)
    else:
        # ssm / hybrid / audio: feed the prompt through decode_step — as ONE
        # jitted lax.scan over the prompt axis, not a per-token Python loop
        # (the old loop retraced/dispatched decode_step S0 times un-jitted;
        # the scan traces the body once, so prefill cost is one compile +
        # one device launch regardless of prompt length)
        cache = registry.init_cache(cfg, B, max_len)
        if cfg.family == "audio" and extra_batch:
            ck, cv = multimodal.build_cross_cache(
                params, cfg, extra_batch["frames"]
            )
            cache["cross_k"], cache["cross_v"] = ck, cv
        logits, cache = scan_prefill(params, cfg, cache, tokens)
        logits = logits[:, None, :]
        pos0 = S0

    step = jax.jit(lambda p, c, b: registry.decode_step(p, cfg, c, b),
                   donate_argnums=(1,))
    last = jnp.argmax(logits[:, -1, :cfg.vocab_size], axis=-1).astype(jnp.int32)
    out = [last]
    for i in range(gen_len - 1):
        logits_i, cache = step(
            params, cache,
            {"token": last, "position": jnp.full((B,), pos0 + i, jnp.int32)},
        )
        last = jnp.argmax(logits_i[:, : cfg.vocab_size], axis=-1).astype(jnp.int32)
        out.append(last)
    return jnp.concatenate([tokens, jnp.stack(out, 1)], axis=1)


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", default="occamy-gptj")
    ap.add_argument("--reduced", action="store_true", default=True)
    ap.add_argument("--batch", type=int, default=4)
    ap.add_argument("--prompt-len", type=int, default=32)
    ap.add_argument("--gen", type=int, default=16)
    args = ap.parse_args()

    cfg = get_config(args.arch, reduced=args.reduced)
    rng = np.random.default_rng(0)
    params = registry.init_params(cfg, jax.random.PRNGKey(0))
    tokens = jnp.asarray(
        rng.integers(0, cfg.vocab_size, (args.batch, args.prompt_len)),
        jnp.int32,
    )
    extra = None
    if cfg.family == "vlm":
        extra = {"patches": jnp.asarray(
            rng.standard_normal((args.batch, cfg.num_patches, cfg.d_model)),
            jnp.dtype(cfg.dtype))}
    if cfg.family == "audio":
        extra = {"frames": jnp.asarray(
            rng.standard_normal((args.batch, cfg.encoder_seq, cfg.d_model)),
            jnp.dtype(cfg.dtype))}

    max_len = args.prompt_len + args.gen + (cfg.num_patches or 0) + 1
    t0 = time.time()
    out = generate(cfg, params, tokens, args.gen, max_len, extra)
    dt = time.time() - t0
    toks = args.batch * args.gen
    print(f"generated {out.shape} in {dt:.2f}s = {toks/dt:.1f} tok/s")
    print("sample:", np.asarray(out[0, -args.gen:]))


if __name__ == "__main__":
    main()
