"""Generated op-reference: the kernel registry rendered as markdown.

``python -m repro.launch.docgen`` regenerates ``docs/op-reference.md`` from
the live registry — per op: the registered impls, the default block
geometry from ``registry.resolve_blocks``, and the partition rule resolved
against both production meshes (single-pod 16×16 and two-pod 2×16×16
device-free MeshSpecs), including its per-level collectives and halo
metadata. The representative operand shapes are the shared
``launch.op_cases.op_roofline_cases`` table (GPT-J / Fig. 9 scale), so the
doc shows the same plans the roofline cells cost.

The output is deterministic (sorted ops, no timestamps); CI regenerates it
with ``--check`` and fails on drift, so the committed doc can never lag the
registry.
"""
from __future__ import annotations

import argparse
import sys

HEADER = """\
# Op reference

<!-- GENERATED FILE - do not edit by hand.
     Regenerate with:  PYTHONPATH=src python -m repro.launch.docgen
     CI runs `python -m repro.launch.docgen --check` and fails on drift. -->

Every op dispatches through `kernels/ops.py` along three axes: **impl**
(pallas / interpret / xla / ref, resolved by `registry.resolve_impl`),
**block geometry** (`registry.resolve_blocks`: explicit kwarg >
`set_block_override` > table default), and **partitioning**
(`kernels/partition.py`: the op's `PartitionRule` resolved against the
`mesh=` kwarg or the `sharding.use_mesh` context). See
[docs/partitioning.md](partitioning.md) for how plans resolve and
[ARCHITECTURE.md](../ARCHITECTURE.md) for the layering.

Partition columns below show each rule resolved at a representative
operand geometry (the dry-run's op-roofline cases) against the production
meshes: single-pod `data=16, model=16` and two-pod `pod=2, data=16,
model=16`, where plans resolve two-level with per-level collectives
(intra-pod at ICI bandwidth, cross-pod at D2D bandwidth).
"""


def _collectives_cell(plan) -> str:
    if plan is None:
        return "—"
    if not plan.collectives:
        return "none"
    # run-length encode: a ring plan fires dozens of identical per-hop
    # permutes; "30× permute@data(...)" reads, thirty repeats don't
    parts, runs = [], []
    for c in plan.collectives:
        cell = f"{c.kind}@{c.axis}(n={c.n}, {c.nbytes} B)"
        if runs and runs[-1][0] == cell:
            runs[-1][1] += 1
        else:
            runs.append([cell, 1])
    for cell, count in runs:
        parts.append(cell if count == 1 else f"{count}× {cell}")
    return "; ".join(parts)


def _partition_cell(plan) -> str:
    if plan is None:
        return "replicated"
    return plan.note


def _overlap_cell(plan) -> str:
    # the overlap-capability column: which plans run the double-buffered
    # ring/halo schedule (kernels/partition.py `overlappable`), and over
    # how many pipeline stages the transfers hide
    if plan is None or not plan.overlappable:
        return "—"
    return f"yes ({plan.hops} hops)"


def generate() -> str:
    """Render the op-reference markdown (deterministic; returns the text)."""
    from repro.core import precision
    from repro.kernels import ops as _ops  # noqa: F401  (registers the ops)
    from repro.kernels import partition, registry
    from repro.launch.op_cases import op_roofline_cases

    cases = {c[0]: c for c in op_roofline_cases()}
    single = partition.MeshSpec({"data": 16, "model": 16})
    multi = partition.MeshSpec({"pod": 2, "data": 16, "model": 16})

    lines = [HEADER]
    lines.append("## Dispatch table\n")
    lines.append("| op | impls | default blocks | precisions |")
    lines.append("|---|---|---|---|")
    for op in registry.registered_ops():
        impls = ", ".join(registry.implementations(op))
        blocks = registry.resolve_blocks(op)
        blocks_s = ", ".join(f"{k}={v}" for k, v in sorted(blocks.items()))
        precs = ", ".join(precision.supported_policies(op))
        lines.append(f"| `{op}` | {impls} | {blocks_s} | {precs} |")
    lines.append("")
    lines.append(
        "The precisions column lists the `core/precision.py` policies each "
        "op's kernels accept via `precision=` (fp32 is the `precision=None` "
        "legacy path; everything else dispatches the block-scaled "
        "quantized kernels — see the precision ladder section in "
        "[ARCHITECTURE.md](../ARCHITECTURE.md)). Ops listing only fp32 "
        "have no scaled path.\n"
    )

    for mesh, title, tag in (
        (single, "Partitioning on the single-pod mesh (`data=16, model=16`)",
         "one level: the chiplet crossbar (`model`)"),
        (multi, "Partitioning on the two-pod mesh (`pod=2, data=16, "
         "model=16`)",
         "two levels: pods (D2D link) above the chiplet crossbar"),
    ):
        lines.append(f"## {title}\n")
        lines.append(f"Plans resolve over {tag}.\n")
        lines.append("| op | partition plan | levels | overlap | collectives |")
        lines.append("|---|---|---|---|---|")
        for op in registry.registered_ops():
            if op not in cases:
                lines.append(f"| `{op}` | (no representative case) | | | |")
                continue
            _, args, kwargs, _, _ = cases[op]
            plan = partition.plan_for(op, mesh, *args, **kwargs)
            levels = (
                ", ".join(f"{a}={n}" for a, n in plan.levels)
                if plan else "—"
            )
            lines.append(
                f"| `{op}` | {_partition_cell(plan)} | {levels} | "
                f"{_overlap_cell(plan)} | {_collectives_cell(plan)} |"
            )
        lines.append("")

    lines.append(
        "The overlap column marks plans that run the double-buffered "
        "latency-tolerant schedule (`overlap=True`, the default): the next "
        "hop's transfer is issued before the current hop's kernel, so up "
        "to `hops - 1` transfers hide behind compute "
        "(`roofline.overlapped_seconds`). Pass `overlap=False` on the op "
        "call for the synchronous oracle schedule.\n"
    )
    lines.append(
        "Collective cells read `kind@axis(n=ring size, payload bytes)`; "
        "`pod`-axis entries are priced at the D2D link bandwidth, all "
        "others at on-chiplet ICI bandwidth "
        "(`core/topology.py::collective_seconds`). An op that resolves to "
        "fewer levels than the mesh offers walked the replication fallback "
        "ladder (its dimensions divide the chiplet axis but not "
        "pod×model).\n"
    )
    return "\n".join(lines)


def main(argv=None) -> int:
    """CLI: write (default) or drift-check the generated op reference.

    ``argv`` defaults to sys.argv. ``--out`` picks the target file
    (default docs/op-reference.md); ``--check`` regenerates in memory,
    compares against the committed file, and returns exit code 2 on drift
    (the CI gate).
    """
    ap = argparse.ArgumentParser(description=__doc__)
    ap.add_argument("--out", default="docs/op-reference.md")
    ap.add_argument("--check", action="store_true",
                    help="fail (exit 2) if the committed file is stale")
    args = ap.parse_args(argv)

    text = generate()
    if args.check:
        try:
            with open(args.out) as f:
                committed = f.read()
        except FileNotFoundError:
            print(f"docgen --check: {args.out} does not exist; run "
                  f"`python -m repro.launch.docgen` and commit it",
                  file=sys.stderr)
            return 2
        if committed != text:
            print(f"docgen --check: {args.out} is stale; regenerate with "
                  f"`PYTHONPATH=src python -m repro.launch.docgen` and "
                  f"commit the result", file=sys.stderr)
            return 2
        print(f"docgen --check: {args.out} is up to date")
        return 0
    with open(args.out, "w") as f:
        f.write(text)
    print(f"wrote {args.out}")
    return 0


if __name__ == "__main__":
    raise SystemExit(main())
