"""Family dispatch: one uniform API over all model families.

  init_params(cfg, rng)            -> params pytree
  forward(params, cfg, batch)      -> (logits, aux)   [train / prefill]
  loss_fn(params, cfg, batch)      -> scalar
  cache_spec / init_cache          -> decode-state pytree (ShapeDtypeStructs / zeros)
  decode_step(params, cfg, cache, batch) -> (logits, cache)
  input_specs(cfg, shape)          -> dict of ShapeDtypeStruct (dry-run stand-ins)
  make_batch(cfg, shape, rng, batch_override) -> concrete synthetic batch
"""
from __future__ import annotations

import jax
import jax.numpy as jnp
import numpy as np

from repro.configs.base import ModelConfig, ShapeSpec
from repro.models import hybrid, multimodal, ssm, transformer


def _family_mod(cfg: ModelConfig):
    return {
        "dense": transformer,
        "moe": transformer,
        "vlm": transformer,
        "ssm": ssm,
        "hybrid": hybrid,
        "audio": multimodal,
    }[cfg.family]


def init_params(cfg, rng):
    return _family_mod(cfg).init_params(cfg, rng)


def param_shapes(cfg):
    """Parameter ShapeDtypeStructs without allocating (dry-run path)."""
    return jax.eval_shape(
        lambda: init_params(cfg, jax.random.PRNGKey(0))
    )


def forward(params, cfg, batch, **kw):
    return _family_mod(cfg).forward(params, cfg, batch, **kw)


def loss_fn(params, cfg, batch, **kw):
    return _family_mod(cfg).loss_fn(params, cfg, batch, **kw)


def cache_spec(cfg, batch: int, max_len: int):
    return _family_mod(cfg).cache_spec(cfg, batch, max_len)


def init_cache(cfg, batch: int, max_len: int):
    return _family_mod(cfg).init_cache(cfg, batch, max_len)


def decode_step(params, cfg, cache, batch):
    return _family_mod(cfg).decode_step(params, cfg, cache, batch)


# ---------------------------------------------------------------------------
# input specs per (arch x shape) cell
# ---------------------------------------------------------------------------


def input_specs(cfg: ModelConfig, shape: ShapeSpec) -> dict:
    B, S = shape.global_batch, shape.seq_len
    i32 = jnp.int32
    emb = jnp.dtype(cfg.dtype)
    d = cfg.d_model

    if shape.kind in ("train", "prefill"):
        specs = {}
        s_text = S
        if cfg.family == "vlm":
            s_text = S - cfg.num_patches
            specs["patches"] = jax.ShapeDtypeStruct((B, cfg.num_patches, d), emb)
        if cfg.family == "audio":
            specs["frames"] = jax.ShapeDtypeStruct((B, cfg.encoder_seq, d), emb)
        specs["tokens"] = jax.ShapeDtypeStruct((B, s_text), i32)
        if shape.kind == "train":
            specs["labels"] = jax.ShapeDtypeStruct((B, S), i32)
        return specs

    assert shape.kind == "decode"
    return {
        "token": jax.ShapeDtypeStruct((B,), i32),
        "position": jax.ShapeDtypeStruct((B,), i32),
    }


def make_batch(cfg: ModelConfig, shape: ShapeSpec, rng=None,
               batch_override: int | None = None, seq_override: int | None = None):
    """Concrete synthetic batch matching input_specs (for smoke tests)."""
    rng = rng if rng is not None else np.random.default_rng(0)
    B = batch_override or shape.global_batch
    S = seq_override or shape.seq_len
    d = cfg.d_model
    out = {}
    if shape.kind in ("train", "prefill"):
        s_text = S
        if cfg.family == "vlm":
            s_text = S - cfg.num_patches
            out["patches"] = jnp.asarray(
                rng.standard_normal((B, cfg.num_patches, d)), jnp.dtype(cfg.dtype)
            )
        if cfg.family == "audio":
            out["frames"] = jnp.asarray(
                rng.standard_normal((B, cfg.encoder_seq, d)), jnp.dtype(cfg.dtype)
            )
        out["tokens"] = jnp.asarray(
            rng.integers(0, cfg.vocab_size, (B, s_text)), jnp.int32
        )
        if shape.kind == "train":
            labels = rng.integers(0, cfg.vocab_size, (B, S))
            if cfg.family == "vlm":
                labels[:, : cfg.num_patches] = -1  # no loss on image positions
            out["labels"] = jnp.asarray(labels, jnp.int32)
    else:
        out["token"] = jnp.asarray(rng.integers(0, cfg.vocab_size, (B,)), jnp.int32)
        out["position"] = jnp.asarray(
            rng.integers(S // 2, S - 1, (B,)), jnp.int32
        )
    return out
