"""Hymba-style hybrid blocks: parallel attention + mamba(SSD) heads.

Each block feeds one normed input to BOTH a GQA attention path (sliding
window, a few global layers) and a mamba/SSD path (data-dependent scalar
decay per head via the shared chunked linear-attention kernel); the two
normalized outputs are averaged (arXiv:2411.13676). Meta-tokens and the
depthwise conv of the reference model are omitted (noted in DESIGN.md).
"""
from __future__ import annotations

import functools
import math

import jax
import jax.numpy as jnp

from repro.kernels import ops
from repro.models import layers as L
from repro.models import transformer as T
from repro.parallel.sharding import constrain


def ssm_heads(cfg) -> int:
    return cfg.resolved_d_inner() // cfg.ssm_head_dim


def global_layer_mask(cfg) -> jnp.ndarray:
    """(L,) bool — which layers use full attention (first/middle/last...)."""
    nl, ng = cfg.num_layers, cfg.num_global_layers
    if ng <= 0:
        return jnp.zeros((nl,), bool)
    idx = jnp.round(jnp.linspace(0, nl - 1, ng)).astype(jnp.int32)
    return jnp.zeros((nl,), bool).at[idx].set(True)


def init_params(cfg, rng):
    kg = L.KeyGen(rng)
    dtype = jnp.dtype(cfg.dtype)
    d, f, nl = cfg.d_model, cfg.d_ff, cfg.num_layers
    hd = cfg.resolved_head_dim()
    H, K = cfg.num_heads, cfg.num_kv_heads
    di, N = cfg.resolved_d_inner(), cfg.ssm_state
    nh = ssm_heads(cfg)
    vp = L.padded_vocab(cfg.vocab_size)

    layers = {
        "attn_norm": jnp.ones((nl, d), dtype),
        "wq": L.dense_init(kg(), (nl, d, H * hd), dtype=dtype),
        "wk": L.dense_init(kg(), (nl, d, K * hd), dtype=dtype),
        "wv": L.dense_init(kg(), (nl, d, K * hd), dtype=dtype),
        "wo": L.dense_init(kg(), (nl, H * hd, d),
                           scale=1.0 / math.sqrt(H * hd), dtype=dtype),
        "ssm_in": L.dense_init(kg(), (nl, d, 2 * di), dtype=dtype),
        "ssm_dt": L.dense_init(kg(), (nl, d, nh), dtype=dtype),
        "ssm_bc": L.dense_init(kg(), (nl, d, 2 * N), dtype=dtype),
        "ssm_out": L.dense_init(kg(), (nl, di, d),
                                scale=1.0 / math.sqrt(di), dtype=dtype),
        "dt_bias": jnp.zeros((nl, nh), jnp.float32),
        "ssm_D": jnp.ones((nl, nh), jnp.float32),
        "attn_out_norm": jnp.ones((nl, d), dtype),
        "ssm_out_norm": jnp.ones((nl, d), dtype),
        "mlp_norm": jnp.ones((nl, d), dtype),
        "wi": L.dense_init(kg(), (nl, d, f), dtype=dtype),
        "wg": L.dense_init(kg(), (nl, d, f), dtype=dtype),
        "wo_mlp": L.dense_init(kg(), (nl, f, d),
                               scale=1.0 / math.sqrt(f), dtype=dtype),
    }
    return {
        "embed": L.dense_init(kg(), (vp, d), scale=0.02, dtype=dtype),
        "layers": layers,
        "final_norm": jnp.ones((d,), dtype),
        "lm_head": L.dense_init(kg(), (d, vp), dtype=dtype),
    }


def _ssd_inputs(p, cfg, x):
    """x: (B,S,d) -> (r, k, v, w_log) in (B, nh, S, ...) layout + (z, x_ssm)."""
    B, S, _ = x.shape
    di, N = cfg.resolved_d_inner(), cfg.ssm_state
    hd, nh = cfg.ssm_head_dim, ssm_heads(cfg)
    xz = jnp.einsum("bsd,de->bse", x, p["ssm_in"],
                    preferred_element_type=jnp.float32).astype(x.dtype)
    x_ssm, z = jnp.split(xz, 2, axis=-1)
    dt = jax.nn.softplus(
        jnp.einsum("bsd,dh->bsh", x, p["ssm_dt"],
                   preferred_element_type=jnp.float32) + p["dt_bias"]
    )  # (B,S,nh) fp32
    bc = jnp.einsum("bsd,dn->bsn", x, p["ssm_bc"],
                    preferred_element_type=jnp.float32).astype(x.dtype)
    Bm, Cm = jnp.split(bc, 2, axis=-1)  # (B,S,N) shared across heads
    v = x_ssm.reshape(B, S, nh, hd) * dt[..., None].astype(x.dtype)
    v = v.transpose(0, 2, 1, 3)  # (B,nh,S,hd)
    r = jnp.broadcast_to(Cm[:, None], (B, nh, S, N))
    k = jnp.broadcast_to(Bm[:, None], (B, nh, S, N))
    w_log = jnp.broadcast_to(
        -dt.transpose(0, 2, 1)[..., None], (B, nh, S, N)
    )
    return r, k, v, w_log, z, x_ssm


def mamba_path(p, cfg, x, state=None):
    B, S, _ = x.shape
    di = cfg.resolved_d_inner()
    hd, nh = cfg.ssm_head_dim, ssm_heads(cfg)
    r, k, v, w_log, z, x_ssm = _ssd_inputs(p, cfg, x)
    o, S_out = ops.linear_attention(r, k, v, w_log, u=None, s0=state)
    o = o + p["ssm_D"][None, :, None, None].astype(o.dtype) * (
        x_ssm.reshape(B, S, nh, hd).transpose(0, 2, 1, 3)
    )
    y = o.transpose(0, 2, 1, 3).reshape(B, S, di)
    y = y * jax.nn.silu(z.astype(jnp.float32)).astype(y.dtype)
    return jnp.einsum("bse,ed->bsd", y, p["ssm_out"],
                      preferred_element_type=jnp.float32).astype(x.dtype), S_out


def block(p, cfg, h, cos, sin, is_global):
    n = L.rms_norm(h, p["attn_norm"], cfg.norm_eps)
    def attn(w):
        return T.attention(p, cfg, n, cos, sin, window=w)

    a = jax.lax.cond(
        is_global,
        lambda: attn(0),
        lambda: attn(cfg.sliding_window),
    )
    m, _ = mamba_path(p, cfg, n)
    fused = 0.5 * (
        L.rms_norm(a, p["attn_out_norm"], cfg.norm_eps)
        + L.rms_norm(m, p["ssm_out_norm"], cfg.norm_eps)
    )
    h = h + fused
    n = L.rms_norm(h, p["mlp_norm"], cfg.norm_eps)
    h = h + L.mlp(T._mlp_p(p), n, cfg.activation)
    return constrain(h, "residual")


def forward(params, cfg, batch, *, q_offset=0):
    h = jnp.take(params["embed"], batch["tokens"], axis=0)
    h = constrain(h, "residual")
    S = h.shape[1]
    hd = cfg.resolved_head_dim()
    cos, sin = L.rope_cos_sin(jnp.arange(S) + q_offset, hd, cfg.rope_theta)
    blk = T.remat_wrap(cfg, functools.partial(block, cfg=cfg))

    def body(h, xs):
        lp, g = xs
        return blk(lp, h=h, cos=cos, sin=sin, is_global=g), None

    h, _ = jax.lax.scan(body, h, (params["layers"], global_layer_mask(cfg)),
                        unroll=cfg.scan_unroll)
    h = L.rms_norm(h, params["final_norm"], cfg.norm_eps)
    logits = jnp.einsum(
        "bsd,dv->bsv", h, params["lm_head"], preferred_element_type=jnp.float32
    ).astype(h.dtype)
    return constrain(logits, "logits"), jnp.float32(0.0)


def loss_fn(params, cfg, batch, *, q_offset=0):
    logits, aux = forward(params, cfg, batch, q_offset=q_offset)
    return L.cross_entropy_loss(logits, batch["labels"], cfg.vocab_size) + aux


# ---------------------------------------------------------------------------
# decode
# ---------------------------------------------------------------------------


def cache_spec(cfg, batch: int, max_len: int):
    hd = cfg.resolved_head_dim()
    K, nl = cfg.num_kv_heads, cfg.num_layers
    nh, N, sd = ssm_heads(cfg), cfg.ssm_state, cfg.ssm_head_dim
    dt = jnp.dtype(cfg.dtype)
    return {
        "k": jax.ShapeDtypeStruct((nl, batch, K, max_len, hd), dt),
        "v": jax.ShapeDtypeStruct((nl, batch, K, max_len, hd), dt),
        "ssm_state": jax.ShapeDtypeStruct((nl, batch, nh, N, sd), jnp.float32),
    }


def init_cache(cfg, batch: int, max_len: int):
    return jax.tree.map(
        lambda s: jnp.zeros(s.shape, s.dtype), cache_spec(cfg, batch, max_len)
    )


def decode_step(params, cfg, cache, batch):
    tokens, position = batch["token"], batch["position"]
    hd = cfg.resolved_head_dim()
    sd, nh, N = cfg.ssm_head_dim, ssm_heads(cfg), cfg.ssm_state
    h = jnp.take(params["embed"], tokens, axis=0)
    cos, sin = L.rope_cos_sin(position, hd, cfg.rope_theta)

    def body(h, xs):
        lp, kc, vc, S, is_global = xs
        n = L.rms_norm(h, lp["attn_norm"], cfg.norm_eps)
        a, kc, vc = jax.lax.cond(
            is_global,
            lambda: T.attention_decode(
                lp, cfg, n, cos, sin, kc, vc, position, window=0
            ),
            lambda: T.attention_decode(
                lp, cfg, n, cos, sin, kc, vc, position,
                window=cfg.sliding_window,
            ),
        )
        # mamba step
        r, k, v, w_log, z, x_ssm = _ssd_inputs(lp, cfg, n[:, None, :])
        o, S = ops.linear_attention_step(
            r[:, :, 0], k[:, :, 0], v[:, :, 0], w_log[:, :, 0], None, S
        )
        o = o + lp["ssm_D"][None, :, None].astype(o.dtype) * x_ssm.reshape(
            -1, nh, sd
        )
        y = o.reshape(-1, nh * sd) * jax.nn.silu(
            z[:, 0].astype(jnp.float32)
        ).astype(o.dtype)
        m = (y @ lp["ssm_out"]).astype(h.dtype)
        fused = 0.5 * (
            L.rms_norm(a, lp["attn_out_norm"], cfg.norm_eps)
            + L.rms_norm(m, lp["ssm_out_norm"], cfg.norm_eps)
        )
        h = h + fused
        n = L.rms_norm(h, lp["mlp_norm"], cfg.norm_eps)
        mo = L.mlp(T._mlp_p(lp), n[:, None, :], cfg.activation)[:, 0]
        h = h + mo
        return h, (kc, vc, S)

    h, (ks, vs, Ss) = jax.lax.scan(
        body,
        h,
        (params["layers"], cache["k"], cache["v"], cache["ssm_state"],
         global_layer_mask(cfg)),
        unroll=cfg.scan_unroll,
    )
    h = L.rms_norm(h, params["final_norm"], cfg.norm_eps)
    logits = jnp.einsum(
        "bd,dv->bv", h, params["lm_head"], preferred_element_type=jnp.float32
    )
    return logits, {"k": ks, "v": vs, "ssm_state": Ss}
