"""RWKV6 ("Finch"): attention-free LM with data-dependent per-channel decay.

Time-mix uses the chunked linear-attention kernel (kernels/rwkv6.py); the
recurrence S_t = diag(w_t) S_{t-1} + k_t v_t^T with w_t produced by a low-rank
data-dependent projection is the Finch contribution. Token-shift mixing uses
static interpolation factors (the full ddlerp LoRA is simplified; noted in
DESIGN.md). Channel-mix is the squared-ReLU RWKV FFN.
"""
from __future__ import annotations

import functools
import math

import jax
import jax.numpy as jnp

from repro.kernels import ops
from repro.models import layers as L
from repro.parallel.compat import shard_map
from repro.parallel.sharding import constrain

LORA_RANK = 64


def _num_heads(cfg) -> int:
    return cfg.d_model // cfg.resolved_head_dim()


def init_params(cfg, rng):
    kg = L.KeyGen(rng)
    dtype = jnp.dtype(cfg.dtype)
    d, f, nl = cfg.d_model, cfg.d_ff, cfg.num_layers
    N = cfg.resolved_head_dim()
    H = _num_heads(cfg)
    vp = L.padded_vocab(cfg.vocab_size)

    decay_bias = jnp.tile(
        jnp.linspace(-5.0, -0.5, d, dtype=jnp.float32)[None, :], (nl, 1)
    )
    layers = {
        "tm_norm": jnp.ones((nl, d), dtype),
        "cm_norm": jnp.ones((nl, d), dtype),
        "mu_r": jnp.full((nl, d), 0.5, dtype),
        "mu_k": jnp.full((nl, d), 0.5, dtype),
        "mu_v": jnp.full((nl, d), 0.5, dtype),
        "mu_g": jnp.full((nl, d), 0.5, dtype),
        "mu_w": jnp.full((nl, d), 0.5, dtype),
        "mu_ck": jnp.full((nl, d), 0.5, dtype),
        "mu_cr": jnp.full((nl, d), 0.5, dtype),
        "wr_t": L.dense_init(kg(), (nl, d, d), dtype=dtype),
        "wk_t": L.dense_init(kg(), (nl, d, d), dtype=dtype),
        "wv_t": L.dense_init(kg(), (nl, d, d), dtype=dtype),
        "wg_t": L.dense_init(kg(), (nl, d, d), dtype=dtype),
        "wo_t": L.dense_init(kg(), (nl, d, d), dtype=dtype),
        "w0": decay_bias,  # fp32: decay dynamics are sensitive
        "w_lora_a": L.dense_init(kg(), (nl, d, LORA_RANK), scale=0.01, dtype=dtype),
        "w_lora_b": L.dense_init(
            kg(), (nl, LORA_RANK, d), scale=0.01, dtype=dtype
        ),
        "u": L.dense_init(kg(), (nl, H, N), scale=0.5, dtype=jnp.float32),
        "ln_x": jnp.ones((nl, d), dtype),
        "wk_c": L.dense_init(kg(), (nl, d, f), dtype=dtype),
        "wv_c": L.dense_init(kg(), (nl, f, d), scale=1.0 / math.sqrt(f), dtype=dtype),
        "wr_c": L.dense_init(kg(), (nl, d, d), dtype=dtype),
    }
    params = {
        "embed": L.dense_init(kg(), (vp, d), scale=0.02, dtype=dtype),
        "layers": layers,
        "final_norm": jnp.ones((d,), dtype),
        "lm_head": L.dense_init(kg(), (d, vp), dtype=dtype),
    }
    return params


def _shift(x, cfg=None):  # (B, S, d): x_prev[t] = x[t-1]; zero at seq start
    """Token shift. With halo_shift and a seq-sharded residual, exchange ONLY
    the boundary column over `model` (ppermute; absent sources yield the
    zero column) instead of letting GSPMD permute full tensors — the fix for
    the 241 GB/step collective-permutes measured on hymba/rwkv (§Perf)."""
    from jax.sharding import PartitionSpec as P

    from repro.parallel.sharding import current_mesh, dp_axes

    mesh = current_mesh() if cfg is not None and cfg.halo_shift else None
    if (
        mesh is not None
        and cfg.seq_shard_activations
        and x.shape[1] % mesh.shape["model"] == 0
    ):
        n = mesh.shape["model"]
        dp = dp_axes(mesh)

        def local(xl):  # (B, S/n, d) on each model rank
            last = xl[:, -1:, :]
            prev = jax.lax.ppermute(
                last, "model", [(i, i + 1) for i in range(n - 1)]
            )  # rank 0 receives zeros == sequence start
            return jnp.concatenate([prev, xl[:, :-1, :]], axis=1)

        return shard_map(
            local, mesh=mesh,
            in_specs=P(dp, "model", None), out_specs=P(dp, "model", None),
            check_vma=False,
        )(x)
    return jnp.pad(x[:, :-1], ((0, 0), (1, 0), (0, 0)))


def _heads(x, H, N):  # (B, S, H*N) -> (B, H, S, N)
    B, S, _ = x.shape
    return x.reshape(B, S, H, N).transpose(0, 2, 1, 3)


def _unheads(x):  # (B, H, S, N) -> (B, S, H*N)
    B, H, S, N = x.shape
    return x.transpose(0, 2, 1, 3).reshape(B, S, H * N)


def _decay_log(p, mixed_w):
    """w_log = -exp(w0 + tanh(x A) B), the Finch data-dependent decay."""
    lora = jnp.einsum(
        "bsr,rd->bsd",
        jnp.tanh(
            jnp.einsum("bsd,dr->bsr", mixed_w, p["w_lora_a"],
                       preferred_element_type=jnp.float32)
        ),
        p["w_lora_b"],
        preferred_element_type=jnp.float32,
    )
    return -jnp.exp(p["w0"] + lora)


def time_mix(p, cfg, x, x_prev, state=None):
    """x: (B,S,d). state: (B,H,N,N) incoming wkv state (None => zeros).
    Returns (out, final_state)."""
    N = cfg.resolved_head_dim()
    H = _num_heads(cfg)

    def mix(mu):
        return x * mu + x_prev * (1.0 - mu)

    r = mix(p["mu_r"]) @ p["wr_t"]
    k = mix(p["mu_k"]) @ p["wk_t"]
    v = mix(p["mu_v"]) @ p["wv_t"]
    g = mix(p["mu_g"]) @ p["wg_t"]
    w_log = _decay_log(p, mix(p["mu_w"]))

    o, S = ops.linear_attention(
        _heads(r, H, N), _heads(k, H, N), _heads(v, H, N),
        _heads(w_log, H, N), p["u"], s0=state,
    )
    o = _unheads(o)
    # per-head group norm + learned scale
    B_, S_, _ = o.shape
    o = L.rms_norm(o.reshape(B_, S_, H, N), jnp.ones((N,), o.dtype), cfg.norm_eps)
    o = (o.reshape(B_, S_, H * N) * p["ln_x"]).astype(x.dtype)
    o = o * jax.nn.silu(g.astype(jnp.float32)).astype(x.dtype)
    return o @ p["wo_t"], S


def channel_mix(p, cfg, x, x_prev):
    def mix(mu):
        return x * mu + x_prev * (1.0 - mu)

    kk = jnp.square(
        jax.nn.relu(
            jnp.einsum("bsd,df->bsf", mix(p["mu_ck"]), p["wk_c"],
                       preferred_element_type=jnp.float32)
        )
    ).astype(x.dtype)
    out = jnp.einsum("bsf,fd->bsd", kk, p["wv_c"],
                     preferred_element_type=jnp.float32).astype(x.dtype)
    rr = jax.nn.sigmoid(
        jnp.einsum("bsd,de->bse", mix(p["mu_cr"]), p["wr_c"],
                   preferred_element_type=jnp.float32)
    ).astype(x.dtype)
    return rr * out


def block(p, cfg, h):
    x = L.rms_norm(h, p["tm_norm"], cfg.norm_eps)
    o, _ = time_mix(p, cfg, x, _shift(x, cfg))
    h = h + o
    x = L.rms_norm(h, p["cm_norm"], cfg.norm_eps)
    h = h + channel_mix(p, cfg, x, _shift(x, cfg))
    return constrain(h, "residual")


def forward(params, cfg, batch, *, q_offset=0):
    from repro.models import transformer as T

    h = jnp.take(params["embed"], batch["tokens"], axis=0)
    h = constrain(h, "residual")
    blk = T.remat_wrap(cfg, functools.partial(block, cfg=cfg))

    def body(h, lp):
        return blk(lp, h=h), None

    h, _ = jax.lax.scan(body, h, params["layers"], unroll=cfg.scan_unroll)
    h = L.rms_norm(h, params["final_norm"], cfg.norm_eps)
    logits = jnp.einsum(
        "bsd,dv->bsv", h, params["lm_head"], preferred_element_type=jnp.float32
    ).astype(h.dtype)
    return constrain(logits, "logits"), jnp.float32(0.0)


def loss_fn(params, cfg, batch, *, q_offset=0):
    logits, aux = forward(params, cfg, batch, q_offset=q_offset)
    return L.cross_entropy_loss(logits, batch["labels"], cfg.vocab_size) + aux


# ---------------------------------------------------------------------------
# decode: constant-size state (B,H,N,N) + two token-shift states
# ---------------------------------------------------------------------------


def cache_spec(cfg, batch: int, max_len: int):
    del max_len  # constant-size state: the point of the ssm family
    d = cfg.d_model
    N = cfg.resolved_head_dim()
    H = _num_heads(cfg)
    return {
        "ssm_state": jax.ShapeDtypeStruct((cfg.num_layers, batch, H, N, N),
                                          jnp.float32),
        "ts_time": jax.ShapeDtypeStruct((cfg.num_layers, batch, d),
                                        jnp.dtype(cfg.dtype)),
        "ts_chan": jax.ShapeDtypeStruct((cfg.num_layers, batch, d),
                                        jnp.dtype(cfg.dtype)),
    }


def init_cache(cfg, batch: int, max_len: int):
    return jax.tree.map(
        lambda s: jnp.zeros(s.shape, s.dtype), cache_spec(cfg, batch, max_len)
    )


def decode_step(params, cfg, cache, batch):
    tokens = batch["token"]
    N = cfg.resolved_head_dim()
    H = _num_heads(cfg)
    h = jnp.take(params["embed"], tokens, axis=0)  # (B, d)

    def body(h, xs):
        lp, S, ts1, ts2 = xs
        x = L.rms_norm(h, lp["tm_norm"], cfg.norm_eps)
        def mix(mu, xp):
            return x * mu + xp * (1.0 - mu)

        r = mix(lp["mu_r"], ts1) @ lp["wr_t"]
        k = mix(lp["mu_k"], ts1) @ lp["wk_t"]
        v = mix(lp["mu_v"], ts1) @ lp["wv_t"]
        g = mix(lp["mu_g"], ts1) @ lp["wg_t"]
        wl = -jnp.exp(
            lp["w0"]
            + jnp.tanh(mix(lp["mu_w"], ts1) @ lp["w_lora_a"]) @ lp["w_lora_b"]
        )
        def hv(t):
            return t.reshape(-1, H, N)

        o, S = ops.linear_attention_step(
            hv(r), hv(k), hv(v), hv(wl), lp["u"], S
        )
        o = L.rms_norm(o, jnp.ones((N,), o.dtype), cfg.norm_eps)
        o = (o.reshape(-1, H * N) * lp["ln_x"]).astype(h.dtype)
        o = o * jax.nn.silu(g.astype(jnp.float32)).astype(h.dtype)
        h = h + o @ lp["wo_t"]
        ts1_new = x
        x2 = L.rms_norm(h, lp["cm_norm"], cfg.norm_eps)
        def mix2(mu):
            return x2 * mu + ts2 * (1.0 - mu)

        kk = jnp.square(jax.nn.relu(mix2(lp["mu_ck"]) @ lp["wk_c"])).astype(h.dtype)
        out = kk @ lp["wv_c"]
        rr = jax.nn.sigmoid(mix2(lp["mu_cr"]) @ lp["wr_c"]).astype(h.dtype)
        h = h + rr * out
        return h, (S, ts1_new, x2)

    h, (S, ts1, ts2) = jax.lax.scan(
        body, h, (params["layers"], cache["ssm_state"], cache["ts_time"],
                  cache["ts_chan"]),
        unroll=cfg.scan_unroll,
    )
    h = L.rms_norm(h, params["final_norm"], cfg.norm_eps)
    logits = jnp.einsum(
        "bd,dv->bv", h, params["lm_head"], preferred_element_type=jnp.float32
    )
    return logits, {"ssm_state": S, "ts_time": ts1, "ts_chan": ts2}
