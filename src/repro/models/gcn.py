"""GCN layer (paper Sec. V-C, Fig. 11): sparse-dense aggregation + dense
feature recombination — the paper's mixed dense/sparse ML workload.

H' = act( Â (H W) ) with Â an ``EllMatrix`` pytree and the aggregation
executed through the spmm kernel (the SU-indirection analogue). Both ops
resolve through the kernel registry, so the whole forward — sparse adjacency
included — passes through ``jax.jit`` as one traced function.

Passing ``mesh=`` (or calling under ``sharding.use_mesh``) runs the whole
forward chiplet-sharded: the ELL adjacency rows split over the mesh's
partition axis for the aggregation, the recombination GEMM follows its own
PartitionRule — no spec plumbing in the model, just the kernel signatures.
"""
from __future__ import annotations

import jax
import jax.numpy as jnp

from repro.core.sparse import EllMatrix
from repro.kernels import ops
from repro.models import layers as L


def init_params(rng, feature_dims: list[int], dtype=jnp.float32):
    kg = L.KeyGen(rng)
    return [
        L.dense_init(kg(), (fi, fo), dtype=dtype)
        for fi, fo in zip(feature_dims[:-1], feature_dims[1:])
    ]


def gcn_layer(w, adj: EllMatrix, feats, *, activate=True, mesh=None):
    """One layer: recombine (dense GEMM) then aggregate (SpMM)."""
    h = ops.gemm(feats, w, mesh=mesh)  # dense recombination
    h = ops.spmm(adj, h, mesh=mesh)  # sparse aggregation (row-sharded)
    return jax.nn.relu(h) if activate else h


def forward(params, adj: EllMatrix, feats, *, mesh=None):
    h = feats
    for i, w in enumerate(params):
        h = gcn_layer(w, adj, h, activate=i < len(params) - 1, mesh=mesh)
    return h
