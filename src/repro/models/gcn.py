"""GCN layer (paper Sec. V-C, Fig. 11): sparse-dense aggregation + dense
feature recombination — the paper's mixed dense/sparse ML workload.

H' = act( Â (H W) ) with Â in the ELL value/index format and the aggregation
executed through the spmm kernel (the SU-indirection analogue).
"""
from __future__ import annotations

import jax
import jax.numpy as jnp

from repro.kernels import ops
from repro.models import layers as L


def init_params(rng, feature_dims: list[int], dtype=jnp.float32):
    kg = L.KeyGen(rng)
    return [
        L.dense_init(kg(), (fi, fo), dtype=dtype)
        for fi, fo in zip(feature_dims[:-1], feature_dims[1:])
    ]


def gcn_layer(w, adj_values, adj_cols, feats, *, activate=True, impl=None):
    """One layer: recombine (dense GEMM) then aggregate (SpMM)."""
    h = ops.gemm(feats, w, impl=impl)  # dense recombination
    h = ops.spmm(adj_values, adj_cols, h, impl=impl)  # sparse aggregation
    return jax.nn.relu(h) if activate else h


def forward(params, adj_values, adj_cols, feats, *, impl=None):
    h = feats
    for i, w in enumerate(params):
        h = gcn_layer(w, adj_values, adj_cols, h,
                      activate=i < len(params) - 1, impl=impl)
    return h
