"""Whisper-style encoder-decoder backbone (audio family).

The conv frontend is a STUB per the assignment: ``input_specs`` supplies
precomputed frame embeddings (B, encoder_seq, d_model); a learned projection
adapts them. Backbone dims (layers/heads/d_ff/vocab) are exact; norm and
positional encoding are unified to RMSNorm+RoPE (DESIGN.md §6).
"""
from __future__ import annotations

import math

import jax
import jax.numpy as jnp

from repro.kernels import ops
from repro.models import layers as L
from repro.models import transformer as T
from repro.parallel.sharding import constrain


def _attn_mlp_params(kg, cfg, nl, dtype, cross: bool = False):
    d, f = cfg.d_model, cfg.d_ff
    hd = cfg.resolved_head_dim()
    H, K = cfg.num_heads, cfg.num_kv_heads
    pre = "c" if cross else ""
    p = {
        pre + "wq": L.dense_init(kg(), (nl, d, H * hd), dtype=dtype),
        pre + "wk": L.dense_init(kg(), (nl, d, K * hd), dtype=dtype),
        pre + "wv": L.dense_init(kg(), (nl, d, K * hd), dtype=dtype),
        pre + "wo": L.dense_init(kg(), (nl, H * hd, d),
                                 scale=1.0 / math.sqrt(H * hd), dtype=dtype),
    }
    if cfg.qkv_bias:
        p[pre + "bq"] = jnp.zeros((nl, H * hd), dtype)
        p[pre + "bk"] = jnp.zeros((nl, K * hd), dtype)
        p[pre + "bv"] = jnp.zeros((nl, K * hd), dtype)
    return p


def init_params(cfg, rng):
    kg = L.KeyGen(rng)
    dtype = jnp.dtype(cfg.dtype)
    d, f = cfg.d_model, cfg.d_ff
    fm = 2 if L.is_gated(cfg.activation) else 1
    vp = L.padded_vocab(cfg.vocab_size)
    nl, ne = cfg.num_layers, cfg.encoder_layers

    def mlp_params(n):
        p = {
            "mlp_norm": jnp.ones((n, d), dtype),
            "wi": L.dense_init(kg(), (n, d, f), dtype=dtype),
            "wo_mlp": L.dense_init(kg(), (n, f, d),
                                   scale=1.0 / math.sqrt(f), dtype=dtype),
        }
        if fm == 2:
            p["wg"] = L.dense_init(kg(), (n, d, f), dtype=dtype)
        return p

    enc_layers = {"attn_norm": jnp.ones((ne, d), dtype)}
    enc_layers.update(_attn_mlp_params(kg, cfg, ne, dtype))
    enc_layers.update(mlp_params(ne))

    dec_layers = {
        "attn_norm": jnp.ones((nl, d), dtype),
        "cross_norm": jnp.ones((nl, d), dtype),
    }
    dec_layers.update(_attn_mlp_params(kg, cfg, nl, dtype))
    dec_layers.update(_attn_mlp_params(kg, cfg, nl, dtype, cross=True))
    dec_layers.update(mlp_params(nl))

    return {
        "frontend_proj": L.dense_init(kg(), (d, d), dtype=dtype),
        "enc_layers": enc_layers,
        "enc_final_norm": jnp.ones((d,), dtype),
        "embed": L.dense_init(kg(), (vp, d), scale=0.02, dtype=dtype),
        "layers": dec_layers,
        "final_norm": jnp.ones((d,), dtype),
        "lm_head": L.dense_init(kg(), (d, vp), dtype=dtype),
    }


def _cross_p(lp):
    p = {"wq": lp["cwq"], "wk": lp["cwk"], "wv": lp["cwv"], "wo": lp["cwo"]}
    if "cbq" in lp:
        p.update({"bq": lp["cbq"], "bk": lp["cbk"], "bv": lp["cbv"]})
    return p


def encode(params, cfg, frames):
    """frames: (B, encoder_seq, d) from the stubbed conv frontend."""
    h = (frames.astype(jnp.dtype(cfg.dtype)) @ params["frontend_proj"])
    h = constrain(h, "residual")
    hd = cfg.resolved_head_dim()
    cos, sin = L.rope_cos_sin(jnp.arange(h.shape[1]), hd, cfg.rope_theta)

    def blk(lp, h):
        n = L.rms_norm(h, lp["attn_norm"], cfg.norm_eps)
        h = h + T.attention(lp, cfg, n, cos, sin, causal=False)
        n = L.rms_norm(h, lp["mlp_norm"], cfg.norm_eps)
        h = h + L.mlp(T._mlp_p(lp), n, cfg.activation)
        return constrain(h, "residual")

    blk = T.remat_wrap(cfg, blk)
    h, _ = jax.lax.scan(lambda c, lp: (blk(lp, c), None), h,
                        params["enc_layers"], unroll=cfg.scan_unroll)
    return L.rms_norm(h, params["enc_final_norm"], cfg.norm_eps)


def forward(params, cfg, batch, *, q_offset=0):
    enc = encode(params, cfg, batch["frames"])
    h = jnp.take(params["embed"], batch["tokens"], axis=0)
    h = constrain(h, "residual")
    S = h.shape[1]
    hd = cfg.resolved_head_dim()
    cos, sin = L.rope_cos_sin(jnp.arange(S) + q_offset, hd, cfg.rope_theta)

    def blk(lp, h, enc):
        n = L.rms_norm(h, lp["attn_norm"], cfg.norm_eps)
        h = h + T.attention(lp, cfg, n, cos, sin, causal=True,
                            q_offset=q_offset)
        n = L.rms_norm(h, lp["cross_norm"], cfg.norm_eps)
        h = h + T.attention(_cross_p(lp), cfg, n, None, None, causal=False,
                            kv_input=enc)
        n = L.rms_norm(h, lp["mlp_norm"], cfg.norm_eps)
        h = h + L.mlp(T._mlp_p(lp), n, cfg.activation)
        return constrain(h, "residual")

    blk = T.remat_wrap(cfg, blk)
    h, _ = jax.lax.scan(lambda c, lp: (blk(lp, c, enc), None), h,
                        params["layers"], unroll=cfg.scan_unroll)
    h = L.rms_norm(h, params["final_norm"], cfg.norm_eps)
    logits = jnp.einsum(
        "bsd,dv->bsv", h, params["lm_head"], preferred_element_type=jnp.float32
    ).astype(h.dtype)
    return constrain(logits, "logits"), jnp.float32(0.0)


def loss_fn(params, cfg, batch, *, q_offset=0):
    logits, aux = forward(params, cfg, batch, q_offset=q_offset)
    return L.cross_entropy_loss(logits, batch["labels"], cfg.vocab_size) + aux


# ---------------------------------------------------------------------------
# decode: self-attention cache + fixed cross-attention cache
# ---------------------------------------------------------------------------


def cache_spec(cfg, batch: int, max_len: int):
    hd = cfg.resolved_head_dim()
    K, nl = cfg.num_kv_heads, cfg.num_layers
    dt = jnp.dtype(cfg.dtype)
    return {
        "k": jax.ShapeDtypeStruct((nl, batch, K, max_len, hd), dt),
        "v": jax.ShapeDtypeStruct((nl, batch, K, max_len, hd), dt),
        "cross_k": jax.ShapeDtypeStruct((nl, batch, K, cfg.encoder_seq, hd), dt),
        "cross_v": jax.ShapeDtypeStruct((nl, batch, K, cfg.encoder_seq, hd), dt),
    }


def init_cache(cfg, batch: int, max_len: int):
    return jax.tree.map(
        lambda s: jnp.zeros(s.shape, s.dtype), cache_spec(cfg, batch, max_len)
    )


def build_cross_cache(params, cfg, frames):
    """Run the encoder once and project K/V for every decoder layer."""
    enc = encode(params, cfg, frames)  # (B, Te, d)
    B, Te, _ = enc.shape
    hd, K = cfg.resolved_head_dim(), cfg.num_kv_heads

    def per_layer(lp):
        k = enc @ lp["cwk"]
        v = enc @ lp["cwv"]
        if "cbk" in lp:
            k, v = k + lp["cbk"], v + lp["cbv"]
        def to(t):
            return t.reshape(B, Te, K, hd).transpose(0, 2, 1, 3)

        return to(k.astype(enc.dtype)), to(v.astype(enc.dtype))

    ks, vs = jax.lax.map(per_layer, params["layers"])
    return ks, vs  # (L, B, K, Te, hd)


def decode_step(params, cfg, cache, batch):
    tokens, position = batch["token"], batch["position"]
    hd = cfg.resolved_head_dim()
    H = cfg.num_heads
    h = jnp.take(params["embed"], tokens, axis=0)
    cos, sin = L.rope_cos_sin(position, hd, cfg.rope_theta)
    B = tokens.shape[0]
    cross_pos = jnp.full((B,), cfg.encoder_seq - 1, jnp.int32)

    def body(h, xs):
        lp, kc, vc, ck, cv = xs
        n = L.rms_norm(h, lp["attn_norm"], cfg.norm_eps)
        a, kc, vc = T.attention_decode(lp, cfg, n, cos, sin, kc, vc, position)
        h = h + a
        n = L.rms_norm(h, lp["cross_norm"], cfg.norm_eps)
        cp = _cross_p(lp)
        q = (n @ cp["wq"]).astype(h.dtype)
        if "bq" in cp:
            q = q + cp["bq"]
        q = q.reshape(B, H, hd)
        o = ops.decode_attention(q, ck, cv, cross_pos)
        o = o.reshape(B, H * hd)
        h = h + jnp.einsum("bh,hd->bd", o, cp["wo"],
                           preferred_element_type=jnp.float32).astype(h.dtype)
        n = L.rms_norm(h, lp["mlp_norm"], cfg.norm_eps)
        h = h + L.mlp(T._mlp_p(lp), n[:, None, :], cfg.activation)[:, 0]
        return h, (kc, vc)

    h, (ks, vs) = jax.lax.scan(
        body, h,
        (params["layers"], cache["k"], cache["v"], cache["cross_k"],
         cache["cross_v"]),
        unroll=cfg.scan_unroll,
    )
    h = L.rms_norm(h, params["final_norm"], cfg.norm_eps)
    logits = jnp.einsum(
        "bd,dv->bv", h, params["lm_head"], preferred_element_type=jnp.float32
    )
    return logits, {"k": ks, "v": vs, "cross_k": cache["cross_k"],
                    "cross_v": cache["cross_v"]}
