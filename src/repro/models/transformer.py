"""Decoder-only transformer LM: dense, MoE, and VLM families.

One config-driven scaffold covers GQA/MQA, qk-norm, QKV biases, gated/plain
MLPs, parallel-residual blocks, MoE layers, and multimodal prefix embeddings.
Layers are stacked on a leading axis and executed with lax.scan (+ remat),
which keeps compiled HLO size O(1) in depth — essential for the 512-device
dry-run compiles.
"""
from __future__ import annotations

import functools
import math

import jax
import jax.numpy as jnp

from repro.kernels import ops
from repro.models import layers as L
from repro.models import moe as M
from repro.parallel.sharding import constrain


# ---------------------------------------------------------------------------
# init
# ---------------------------------------------------------------------------


def init_params(cfg, rng):
    kg = L.KeyGen(rng)
    dtype = jnp.dtype(cfg.dtype)
    d, f = cfg.d_model, cfg.d_ff
    hd = cfg.resolved_head_dim()
    H, K, nl = cfg.num_heads, cfg.num_kv_heads, cfg.num_layers
    fm = 2 if L.is_gated(cfg.activation) else 1
    vp = L.padded_vocab(cfg.vocab_size)

    layers = {
        "attn_norm": jnp.ones((nl, d), dtype),
        "wq": L.dense_init(kg(), (nl, d, H * hd), dtype=dtype),
        "wk": L.dense_init(kg(), (nl, d, K * hd), dtype=dtype),
        "wv": L.dense_init(kg(), (nl, d, K * hd), dtype=dtype),
        "wo": L.dense_init(
            kg(), (nl, H * hd, d), scale=1.0 / math.sqrt(H * hd), dtype=dtype
        ),
    }
    if cfg.qkv_bias:
        layers["bq"] = jnp.zeros((nl, H * hd), dtype)
        layers["bk"] = jnp.zeros((nl, K * hd), dtype)
        layers["bv"] = jnp.zeros((nl, K * hd), dtype)
    if cfg.qk_norm:
        layers["q_norm"] = jnp.ones((nl, hd), dtype)
        layers["k_norm"] = jnp.ones((nl, hd), dtype)
    if not cfg.parallel_block:
        layers["mlp_norm"] = jnp.ones((nl, d), dtype)
    if cfg.num_experts:
        layers.update(M.init_moe_params(kg, cfg, nl, dtype))
    else:
        layers["wi"] = L.dense_init(kg(), (nl, d, f), dtype=dtype)
        if fm == 2:
            layers["wg"] = L.dense_init(kg(), (nl, d, f), dtype=dtype)
        layers["wo_mlp"] = L.dense_init(
            kg(), (nl, f, d), scale=1.0 / math.sqrt(f), dtype=dtype
        )

    params = {
        "embed": L.dense_init(kg(), (vp, d), scale=0.02, dtype=dtype),
        "layers": layers,
        "final_norm": jnp.ones((d,), dtype),
    }
    if not cfg.tie_embeddings:
        params["lm_head"] = L.dense_init(kg(), (d, vp), dtype=dtype)
    if cfg.family == "vlm":
        params["connector"] = {
            "wi": L.dense_init(kg(), (d, d), dtype=dtype),
            "wo": L.dense_init(kg(), (d, d), dtype=dtype),
        }
    return params


# ---------------------------------------------------------------------------
# attention (shared with the audio/hybrid families)
# ---------------------------------------------------------------------------


def attention(p, cfg, x, cos, sin, *, causal=True, window=0, q_offset=0,
              kv_input=None, kv_cos_sin=None, return_kv=False):
    """x: (B, S, d) -> (B, S, d). kv_input enables cross-attention."""
    B, S, d = x.shape
    hd = cfg.resolved_head_dim()
    H, K = cfg.num_heads, cfg.num_kv_heads
    xkv = x if kv_input is None else kv_input
    Skv = xkv.shape[1]

    q = jnp.einsum("bsd,dh->bsh", x, p["wq"], preferred_element_type=jnp.float32)
    k = jnp.einsum("bsd,dh->bsh", xkv, p["wk"], preferred_element_type=jnp.float32)
    v = jnp.einsum("bsd,dh->bsh", xkv, p["wv"], preferred_element_type=jnp.float32)
    if "bq" in p:
        q, k, v = q + p["bq"], k + p["bk"], v + p["bv"]
    q = q.astype(x.dtype).reshape(B, S, H, hd)
    k = k.astype(x.dtype).reshape(B, Skv, K, hd)
    v = v.astype(x.dtype).reshape(B, Skv, K, hd)
    if "q_norm" in p:
        q = L.head_rms_norm(q, p["q_norm"], cfg.norm_eps)
        k = L.head_rms_norm(k, p["k_norm"], cfg.norm_eps)
    if cos is not None and kv_input is None:  # no rope in cross-attention
        q = L.apply_rope(q, cos, sin)
        k = L.apply_rope(k, cos, sin)
    elif cos is not None:
        q = L.apply_rope(q, cos, sin)
        if kv_cos_sin is not None:
            k = L.apply_rope(k, *kv_cos_sin)
    q = constrain(q, "attn_q")
    k = constrain(k, "attn_kv")
    v = constrain(v, "attn_kv")

    o = ops.flash_attention(
        q.transpose(0, 2, 1, 3),
        k.transpose(0, 2, 1, 3),
        v.transpose(0, 2, 1, 3),
        causal=causal,
        window=window,
        q_offset=q_offset,
    )
    o = o.transpose(0, 2, 1, 3).reshape(B, S, H * hd)
    out = jnp.einsum(
        "bsh,hd->bsd", o, p["wo"], preferred_element_type=jnp.float32
    ).astype(x.dtype)
    if return_kv:
        return out, (k.transpose(0, 2, 1, 3), v.transpose(0, 2, 1, 3))
    return out


def attention_decode(p, cfg, x, cos, sin, k_cache, v_cache, position, *,
                     window=0, update_cache=True):
    """x: (B, d); caches (B, K, Smax, hd); position (B,) absolute index."""
    B, d = x.shape
    hd = cfg.resolved_head_dim()
    H, K = cfg.num_heads, cfg.num_kv_heads

    q = (x @ p["wq"]).astype(x.dtype)
    if update_cache:
        k = (x @ p["wk"]).astype(x.dtype)
        v = (x @ p["wv"]).astype(x.dtype)
        if "bq" in p:
            q, k, v = q + p["bq"], k + p["bk"], v + p["bv"]
        k = k.reshape(B, K, hd)
        v = v.reshape(B, K, hd)
    elif "bq" in p:
        q = q + p["bq"]
    q = q.reshape(B, H, hd)
    if "q_norm" in p:
        q = L.head_rms_norm(q, p["q_norm"], cfg.norm_eps)
        if update_cache:
            k = L.head_rms_norm(k, p["k_norm"], cfg.norm_eps)
    if cos is not None:
        # cos/sin: (B, hd/2) from per-row positions
        q = L.apply_rope(q[:, None], cos[:, None], sin[:, None])[:, 0]
        if update_cache:
            k = L.apply_rope(k[:, None], cos[:, None], sin[:, None])[:, 0]

    if update_cache:
        def upd(cache, new, pos):
            return jax.lax.dynamic_update_slice_in_dim(
                cache, new[:, None, :], pos, axis=1
            )

        k_cache = jax.vmap(upd)(k_cache, k, position)
        v_cache = jax.vmap(upd)(v_cache, v, position)

    o = ops.decode_attention(q, k_cache, v_cache, position, window=window)
    o = o.reshape(B, H * hd)
    o = jnp.einsum(
        "bh,hd->bd", o, p["wo"], preferred_element_type=jnp.float32
    ).astype(x.dtype)
    return o, k_cache, v_cache


# ---------------------------------------------------------------------------
# blocks
# ---------------------------------------------------------------------------


def _mlp_p(p):
    q = {"wi": p["wi"], "wo": p["wo_mlp"]}
    if "wg" in p:
        q["wg"] = p["wg"]
    return q


def _ffn(p, cfg, x):
    if cfg.num_experts:
        return M.moe_mlp(p, x, cfg)
    return L.mlp(_mlp_p(p), x, cfg.activation), 0.0


def block(p, cfg, h, cos, sin, *, window=0, q_offset=0):
    if cfg.parallel_block:
        n = L.rms_norm(h, p["attn_norm"], cfg.norm_eps)
        a = attention(p, cfg, n, cos, sin, window=window, q_offset=q_offset)
        m, aux = _ffn(p, cfg, n)
        h = h + a + m
    else:
        n = L.rms_norm(h, p["attn_norm"], cfg.norm_eps)
        h = h + attention(p, cfg, n, cos, sin, window=window, q_offset=q_offset)
        n = L.rms_norm(h, p["mlp_norm"], cfg.norm_eps)
        m, aux = _ffn(p, cfg, n)
        h = h + m
    return constrain(h, "residual"), aux


def remat_wrap(cfg, fn):
    if cfg.remat == "none":
        return fn
    if cfg.gather_save_policy:
        # save cross-device-gathered tensors so the backward pass does not
        # re-issue the TP/FSDP all-gathers (collective bytes vs memory trade)
        policy = jax.checkpoint_policies.save_only_these_names("gathered")
    elif cfg.remat == "dots":
        policy = jax.checkpoint_policies.dots_with_no_batch_dims_saveable
    else:
        policy = None
    return jax.checkpoint(fn, policy=policy)


# ---------------------------------------------------------------------------
# forward (train / prefill)
# ---------------------------------------------------------------------------


def embed_inputs(params, cfg, batch):
    """Token (+ multimodal prefix) embedding. Returns (h, label_offset)."""
    tokens = batch["tokens"]
    h = jnp.take(params["embed"], tokens, axis=0)
    if cfg.family == "vlm":
        patches = batch["patches"].astype(h.dtype)  # (B, P, d) from the stub
        c = params["connector"]
        pe = jnp.einsum("bpd,de->bpe", jax.nn.gelu(patches @ c["wi"]), c["wo"])
        h = jnp.concatenate([pe.astype(h.dtype), h], axis=1)
    return constrain(h, "residual")


def forward(params, cfg, batch, *, q_offset=0):
    """-> (logits (B, S_total, V_pad), aux_loss)."""
    h = embed_inputs(params, cfg, batch)
    S = h.shape[1]
    positions = jnp.arange(S) + q_offset
    hd = cfg.resolved_head_dim()
    cos, sin = (
        L.rope_cos_sin(positions, hd, cfg.rope_theta)
        if cfg.rope_theta
        else (None, None)
    )

    blk = remat_wrap(
        cfg,
        functools.partial(
            block, cfg=cfg, window=cfg.sliding_window, q_offset=q_offset
        ),
    )

    def body(carry, lp):
        h, aux = carry
        h, a = blk(lp, h=h, cos=cos, sin=sin)
        return (h, aux + a), None

    (h, aux), _ = jax.lax.scan(body, (h, jnp.float32(0.0)), params["layers"],
                               unroll=cfg.scan_unroll)
    h = L.rms_norm(h, params["final_norm"], cfg.norm_eps)
    head = params.get("lm_head")
    if head is None:
        head = params["embed"].T
    logits = jnp.einsum(
        "bsd,dv->bsv", h, head, preferred_element_type=jnp.float32
    ).astype(h.dtype)
    return constrain(logits, "logits"), aux


def loss_fn(params, cfg, batch, *, q_offset=0):
    logits, aux = forward(params, cfg, batch, q_offset=q_offset)
    return L.cross_entropy_loss(logits, batch["labels"], cfg.vocab_size) + aux


def prefill_step(params, cfg, batch, max_len: int):
    """Process a full prompt, returning (logits, cache) for decode to extend."""
    h = embed_inputs(params, cfg, batch)
    S = h.shape[1]
    hd = cfg.resolved_head_dim()
    positions = jnp.arange(S)
    cos, sin = (
        L.rope_cos_sin(positions, hd, cfg.rope_theta)
        if cfg.rope_theta
        else (None, None)
    )

    def blk(lp, h):
        if cfg.parallel_block:
            n = L.rms_norm(h, lp["attn_norm"], cfg.norm_eps)
            a, kv = attention(lp, cfg, n, cos, sin,
                              window=cfg.sliding_window, return_kv=True)
            m, _ = _ffn(lp, cfg, n)
            h = h + a + m
        else:
            n = L.rms_norm(h, lp["attn_norm"], cfg.norm_eps)
            a, kv = attention(lp, cfg, n, cos, sin,
                              window=cfg.sliding_window, return_kv=True)
            h = h + a
            n = L.rms_norm(h, lp["mlp_norm"], cfg.norm_eps)
            m, _ = _ffn(lp, cfg, n)
            h = h + m
        return constrain(h, "residual"), kv

    def body(h, lp):
        h, kv = blk(lp, h)
        return h, kv

    h, (ks, vs) = jax.lax.scan(body, h, params["layers"],
                               unroll=cfg.scan_unroll)
    pad = max_len - S
    if pad > 0:
        ks = jnp.pad(ks, ((0, 0), (0, 0), (0, 0), (0, pad), (0, 0)))
        vs = jnp.pad(vs, ((0, 0), (0, 0), (0, 0), (0, pad), (0, 0)))
    h = L.rms_norm(h, params["final_norm"], cfg.norm_eps)
    head = params.get("lm_head")
    if head is None:
        head = params["embed"].T
    logits = jnp.einsum(
        "bsd,dv->bsv", h, head, preferred_element_type=jnp.float32
    ).astype(h.dtype)
    return logits, {"k": ks, "v": vs}


# ---------------------------------------------------------------------------
# decode
# ---------------------------------------------------------------------------


def cache_spec(cfg, batch: int, max_len: int):
    hd = cfg.resolved_head_dim()
    K, nl = cfg.num_kv_heads, cfg.num_layers
    dt = jnp.dtype(cfg.dtype)
    kv = jax.ShapeDtypeStruct((nl, batch, K, max_len, hd), dt)
    return {"k": kv, "v": kv}


def init_cache(cfg, batch: int, max_len: int):
    return jax.tree.map(
        lambda s: jnp.zeros(s.shape, s.dtype), cache_spec(cfg, batch, max_len)
    )


def decode_step(params, cfg, cache, batch):
    """batch: {"token": (B,), "position": (B,)} -> (logits (B, V_pad), cache)."""
    tokens, position = batch["token"], batch["position"]
    h = jnp.take(params["embed"], tokens, axis=0)  # (B, d)
    hd = cfg.resolved_head_dim()
    cos, sin = (
        L.rope_cos_sin(position, hd, cfg.rope_theta)
        if cfg.rope_theta
        else (None, None)
    )

    def body(h, xs):
        lp, kc, vc = xs
        n = L.rms_norm(h, lp["attn_norm"], cfg.norm_eps)
        a, kc, vc = attention_decode(
            lp, cfg, n, cos, sin, kc, vc, position, window=cfg.sliding_window
        )
        if cfg.parallel_block:
            m, _ = _ffn_decode(lp, cfg, n)
            h = h + a + m
        else:
            h = h + a
            n = L.rms_norm(h, lp["mlp_norm"], cfg.norm_eps)
            m, _ = _ffn_decode(lp, cfg, n)
            h = h + m
        return h, (kc, vc)

    h, (ks, vs) = jax.lax.scan(
        body, h, (params["layers"], cache["k"], cache["v"]),
        unroll=cfg.scan_unroll,
    )
    h = L.rms_norm(h, params["final_norm"], cfg.norm_eps)
    head = params.get("lm_head")
    if head is None:
        head = params["embed"].T
    logits = jnp.einsum(
        "bd,dv->bv", h, head, preferred_element_type=jnp.float32
    )
    return logits, {"k": ks, "v": vs}


def _ffn_decode(p, cfg, x):
    if cfg.num_experts:
        return M.moe_mlp_decode(p, x, cfg)
    out = L.mlp(_mlp_p(p), x[:, None, :], cfg.activation)[:, 0]
    return out, 0.0


# ---------------------------------------------------------------------------
# paged decode (serving engine: block-table KV cache)
# ---------------------------------------------------------------------------


def attention_decode_paged(p, cfg, x, cos, sin, k_pool, v_pool, k_scale,
                           v_scale, block_table, position, *, window=0,
                           policy=None, attn_fn=None):
    """One layer's decode against a paged KV pool.

    ``k_pool``/``v_pool``: (P, K, bs, hd) physical pages (+ per-row fp32
    scales when ``policy`` holds the cache narrow); ``block_table``:
    (B, NB) int32 pool slots per sequence; ``position``: (B,). The new
    token's K/V is written into page ``block_table[b, pos // bs]`` at row
    ``pos % bs`` (quantized per row under ``policy`` — the same
    quantization ``precision.quantize_kv_cache`` applies), then attention
    runs through the registered paged ``decode_attention`` — or through
    ``attn_fn(q, k_pool, v_pool, k_scale, v_scale, block_table, position,
    window)`` when the serving layer injects a distribution (ring decode).
    Every row writes every step: inactive slots point at the shared
    scratch page, which live prefixes never reference."""
    B, d = x.shape
    hd = cfg.resolved_head_dim()
    H, K = cfg.num_heads, cfg.num_kv_heads
    bs = k_pool.shape[2]

    q = (x @ p["wq"]).astype(x.dtype)
    k = (x @ p["wk"]).astype(x.dtype)
    v = (x @ p["wv"]).astype(x.dtype)
    if "bq" in p:
        q, k, v = q + p["bq"], k + p["bk"], v + p["bv"]
    q = q.reshape(B, H, hd)
    k = k.reshape(B, K, hd)
    v = v.reshape(B, K, hd)
    if "q_norm" in p:
        q = L.head_rms_norm(q, p["q_norm"], cfg.norm_eps)
        k = L.head_rms_norm(k, p["k_norm"], cfg.norm_eps)
    if cos is not None:
        q = L.apply_rope(q[:, None], cos[:, None], sin[:, None])[:, 0]
        k = L.apply_rope(k[:, None], cos[:, None], sin[:, None])[:, 0]

    phys = jnp.take_along_axis(block_table, (position // bs)[:, None],
                               axis=1)[:, 0]
    offset = position % bs
    heads = jnp.arange(K)[None, :]
    def at(pool):
        return pool.at[phys[:, None], heads, offset[:, None]]

    if policy is not None:
        from repro.core import precision as prec

        kq, ks, vq, vs = prec.quantize_kv_cache(k, v, policy)
        k_pool = at(k_pool).set(kq.astype(k_pool.dtype))
        v_pool = at(v_pool).set(vq.astype(v_pool.dtype))
        k_scale = at(k_scale).set(ks)
        v_scale = at(v_scale).set(vs)
    else:
        k_pool = at(k_pool).set(k.astype(k_pool.dtype))
        v_pool = at(v_pool).set(v.astype(v_pool.dtype))

    if attn_fn is None:
        o = ops.decode_attention(
            q, k_pool, v_pool, position, paged=True, block_table=block_table,
            k_scale=k_scale, v_scale=v_scale, window=window,
        )
    else:
        o = attn_fn(q, k_pool, v_pool, k_scale, v_scale, block_table,
                    position, window)
    o = o.reshape(B, H * hd)
    o = jnp.einsum(
        "bh,hd->bd", o, p["wo"], preferred_element_type=jnp.float32
    ).astype(x.dtype)
    return o, k_pool, v_pool, k_scale, v_scale


def decode_step_paged(params, cfg, cache, batch, *, attn_fn=None):
    """Paged twin of ``decode_step``: batch additionally carries the
    (B, NB) int32 ``block_table``; ``cache`` is a
    ``serving.paged_cache.PagedKVCache`` (duck-typed — only its pools,
    scales, and static policy are touched, so this module stays below the
    serving layer). Returns (logits (B, V_pad), updated cache)."""
    import dataclasses as _dc

    tokens, position = batch["token"], batch["position"]
    block_table = batch["block_table"]
    h = jnp.take(params["embed"], tokens, axis=0)
    hd = cfg.resolved_head_dim()
    cos, sin = (
        L.rope_cos_sin(position, hd, cfg.rope_theta)
        if cfg.rope_theta
        else (None, None)
    )

    def body(h, xs):
        lp, kp, vp, ks, vs = xs
        n = L.rms_norm(h, lp["attn_norm"], cfg.norm_eps)
        a, kp, vp, ks, vs = attention_decode_paged(
            lp, cfg, n, cos, sin, kp, vp, ks, vs, block_table, position,
            window=cfg.sliding_window, policy=cache.policy, attn_fn=attn_fn,
        )
        if cfg.parallel_block:
            m, _ = _ffn_decode(lp, cfg, n)
            h = h + a + m
        else:
            h = h + a
            n = L.rms_norm(h, lp["mlp_norm"], cfg.norm_eps)
            m, _ = _ffn_decode(lp, cfg, n)
            h = h + m
        return h, (kp, vp, ks, vs)

    h, (kp, vp, ks, vs) = jax.lax.scan(
        body, h,
        (params["layers"], cache.k_pool, cache.v_pool,
         cache.k_scale, cache.v_scale),
        unroll=cfg.scan_unroll,
    )
    h = L.rms_norm(h, params["final_norm"], cfg.norm_eps)
    head = params.get("lm_head")
    if head is None:
        head = params["embed"].T
    logits = jnp.einsum(
        "bd,dv->bv", h, head, preferred_element_type=jnp.float32
    )
    cache = _dc.replace(cache, k_pool=kp, v_pool=vp, k_scale=ks, v_scale=vs)
    return logits, cache
