"""Shared neural-net building blocks (functional, pytree params)."""
from __future__ import annotations

import jax
import jax.numpy as jnp
import numpy as np

from repro.kernels import ops  # noqa: F401  (imports register kernel impls)

VOCAB_PAD_MULTIPLE = 128  # embeddings padded so the vocab dim shards cleanly


def padded_vocab(vocab_size: int, multiple: int = VOCAB_PAD_MULTIPLE) -> int:
    return int(-(-vocab_size // multiple) * multiple)


def rms_norm(x: jax.Array, weight: jax.Array, eps: float = 1e-5) -> jax.Array:
    xf = x.astype(jnp.float32)
    var = jnp.mean(xf * xf, axis=-1, keepdims=True)
    return (xf * jax.lax.rsqrt(var + eps) * weight.astype(jnp.float32)).astype(
        x.dtype
    )


def head_rms_norm(x: jax.Array, weight: jax.Array, eps: float = 1e-5) -> jax.Array:
    """qk-norm: normalize each head's vector (last dim) independently."""
    return rms_norm(x, weight, eps)


def rope_cos_sin(positions: jax.Array, head_dim: int, theta: float):
    """positions: (...,) int -> cos/sin (..., head_dim//2) in float32."""
    half = head_dim // 2
    freqs = 1.0 / (theta ** (jnp.arange(half, dtype=jnp.float32) / half))
    angles = positions.astype(jnp.float32)[..., None] * freqs
    return jnp.cos(angles), jnp.sin(angles)


def apply_rope(x: jax.Array, cos: jax.Array, sin: jax.Array) -> jax.Array:
    """x: (B, S, H, D); cos/sin: (S, D/2) or (B, S, D/2). Half-split pairing."""
    half = x.shape[-1] // 2
    x1, x2 = x[..., :half].astype(jnp.float32), x[..., half:].astype(jnp.float32)
    if cos.ndim == 2:  # (S, half) -> broadcast over batch and heads
        cos, sin = cos[None, :, None, :], sin[None, :, None, :]
    else:  # (B, S, half)
        cos, sin = cos[:, :, None, :], sin[:, :, None, :]
    out = jnp.concatenate(
        [x1 * cos - x2 * sin, x2 * cos + x1 * sin], axis=-1
    )
    return out.astype(x.dtype)


def activation_fn(name: str):
    if name == "swiglu":
        return jax.nn.silu
    if name == "geglu":
        return jax.nn.gelu
    if name == "gelu":
        return jax.nn.gelu
    if name == "relu_sq":
        return lambda x: jnp.square(jax.nn.relu(x))
    raise ValueError(name)


def is_gated(name: str) -> bool:
    return name in ("swiglu", "geglu")


def mlp(p: dict, x: jax.Array, activation: str) -> jax.Array:
    """Gated or plain MLP. Gate/up projections are SEPARATE leaves ("wg"/"wi"):
    a fused (d, 2f) weight would make the activation split halve a TP-sharded
    axis, which GSPMD lowers to per-layer collective-permutes."""
    act = activation_fn(activation)
    h = jnp.einsum("bsd,df->bsf", x, p["wi"], preferred_element_type=jnp.float32)
    if is_gated(activation):
        g = jnp.einsum(
            "bsd,df->bsf", x, p["wg"], preferred_element_type=jnp.float32
        )
        h = act(g) * h
    else:
        h = act(h)
    h = h.astype(x.dtype)
    return jnp.einsum(
        "bsf,fd->bsd", h, p["wo"], preferred_element_type=jnp.float32
    ).astype(x.dtype)


def dense_init(rng, shape, scale=None, dtype=jnp.bfloat16):
    scale = scale if scale is not None else 1.0 / np.sqrt(shape[0])
    return (jax.random.normal(rng, shape, jnp.float32) * scale).astype(dtype)


class KeyGen:
    """Deterministic per-leaf rng stream."""

    def __init__(self, rng):
        self._rng = rng
        self._i = 0

    def __call__(self):
        self._i += 1
        return jax.random.fold_in(self._rng, self._i)


def cross_entropy_loss(
    logits: jax.Array,  # (B, S, V_pad) — padded vocab tail masked here
    labels: jax.Array,  # (B, S) int32; negative = ignore
    vocab_size: int,
) -> jax.Array:
    lf = logits.astype(jnp.float32)
    v_pad = lf.shape[-1]
    vocab_iota = jnp.arange(v_pad)
    if v_pad > vocab_size:
        lf = jnp.where(vocab_iota >= vocab_size, -1e30, lf)
    logz = jax.nn.logsumexp(lf, axis=-1)
    # one-hot product form: stays local when the vocab dim is TP-sharded
    # (take_along_axis would force an all-gather of the logits under GSPMD)
    onehot = (vocab_iota[None, None, :] == labels[..., None]).astype(jnp.float32)
    gold = jnp.sum(lf * onehot, axis=-1)
    valid = labels >= 0
    nll = jnp.where(valid, logz - gold, 0.0)
    return nll.sum() / jnp.maximum(valid.sum(), 1)
