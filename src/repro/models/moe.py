"""Mixture-of-Experts layer with sorted, capacity-bounded dispatch.

The top-k routing indirection is the LM-family instance of the paper's C2
(indirect streams): runtime indices drive a gather -> dense compute -> scatter
pipeline. Dispatch is performed *per batch row* so the token sort is local to
the row — under pjit with batch-sharded activations every device sorts only
its own tokens (no cross-device sort), mirroring how Occamy clusters handle
their local SPM tile before DMA-ing results out.

Experts are TP-sharded on d_ff over the `model` axis (all experts resident on
every model-group, like the paper's group-replicated left matrices); an
all-to-all expert-parallel variant lives in parallel/collectives.py and is
exercised in the §Perf hillclimb.
"""
from __future__ import annotations

import math

import jax
import jax.numpy as jnp
import numpy as np

from jax.sharding import PartitionSpec as P

from repro.models import layers as L
from repro.parallel.compat import shard_map
from repro.parallel.sharding import constrain, current_mesh, dp_axes


def init_moe_params(kg, cfg, num_layers: int, dtype) -> dict:
    d, f, E = cfg.d_model, cfg.d_ff, cfg.num_experts
    fm = 2 if L.is_gated(cfg.activation) else 1
    p = {
        "router": L.dense_init(kg(), (num_layers, d, E), dtype=jnp.float32),
        "moe_wi": L.dense_init(kg(), (num_layers, E, d, f), dtype=dtype),
        "moe_wo": L.dense_init(
            kg(), (num_layers, E, f, d), scale=1.0 / math.sqrt(f), dtype=dtype
        ),
    }
    if fm == 2:
        p["moe_wg"] = L.dense_init(kg(), (num_layers, E, d, f), dtype=dtype)
    return p


def capacity(cfg, seq_len: int) -> int:
    E, k = cfg.num_experts, cfg.experts_per_token
    return max(int(math.ceil(k * seq_len / E * cfg.capacity_factor)), 1)


def _route(p, x, cfg):
    """Router logits/probs in fp32. x: (..., d) -> (probs, topv, topi, aux)."""
    E, k = cfg.num_experts, cfg.experts_per_token
    logits = jnp.einsum(
        "...d,de->...e", x.astype(jnp.float32), p["router"]
    )
    probs = jax.nn.softmax(logits, axis=-1)
    topv, topi = jax.lax.top_k(probs, k)
    topv = topv / jnp.maximum(topv.sum(-1, keepdims=True), 1e-9)
    # Switch-style load-balance aux loss
    hits = jax.nn.one_hot(topi, E, dtype=jnp.float32).sum(-2)  # (..., E)
    f_e = hits.reshape(-1, E).mean(0) / k
    p_e = probs.reshape(-1, E).mean(0)
    aux = E * jnp.sum(f_e * p_e)
    return topv, topi, aux


def moe_mlp(p, x, cfg):
    """x: (B, S, d) -> (out (B, S, d), aux_loss scalar)."""
    B, S, d = x.shape
    E, k = cfg.num_experts, cfg.experts_per_token
    C = capacity(cfg, S)
    act = L.activation_fn(cfg.activation)
    gated = L.is_gated(cfg.activation)

    # routing scatters/gathers along the token axis: pin it unsharded first
    # (a seq-sharded operand makes GSPMD materialize full-shape u32 index
    # tensors and all-gather them -- the Megatron-SP gather belongs HERE)
    x = constrain(x, "moe_tokens")
    topv, topi, aux = _route(p, x, cfg)

    def dispatch_rows(x_loc, topi_loc):
        """(b, S, d), (b, S, k) -> (b, E, C, d) + combine metadata. LOCAL."""

        def row(xb, ib):
            e_flat = ib.reshape(-1)  # (S*k,)
            order = jnp.argsort(e_flat, stable=True)
            se = e_flat[order]
            first = jnp.searchsorted(se, jnp.arange(E), side="left")
            rank = jnp.arange(S * k) - first[se]
            slot = jnp.where(rank < C, se * C + rank, E * C)  # E*C == dropped
            # ONLY int32 vectors are ever scattered; all value movement is
            # gathers (scatters of (n, d) values make XLA materialize
            # full-width index broadcasts — 45 GB of u32 at grok scale)
            inv = (
                jnp.full((E * C,), S * k, jnp.int32)
                .at[slot]
                .set(jnp.arange(S * k, dtype=jnp.int32), mode="drop")
            )
            tok_sorted = order // k
            src_tok = jnp.where(
                inv < S * k, tok_sorted[jnp.minimum(inv, S * k - 1)], S
            )
            disp = jnp.where(
                (src_tok < S)[:, None],
                xb[jnp.minimum(src_tok, S - 1)],
                0,
            )
            return disp.reshape(E, C, d), slot, order

        return jax.vmap(row)(x_loc, topi_loc)

    def combine_rows(y_loc, slot_loc, order_loc, topv_loc):
        """(b, E, C, d), metadata -> (b, S, d). LOCAL."""

        def row(yb, slotb, orderb, vb):
            yf = yb.reshape(E * C, d)
            live = (slotb < E * C)[:, None]
            vals = jnp.where(live, yf[jnp.minimum(slotb, E * C - 1)], 0)
            # inverse permutation via int-only scatter, then gather
            inv_order = (
                jnp.zeros((S * k,), jnp.int32)
                .at[orderb]
                .set(jnp.arange(S * k, dtype=jnp.int32))
            )
            out = vals[inv_order]
            return (out * vb.reshape(-1)[:, None]).reshape(S, k, d).sum(1)

        return jax.vmap(row)(y_loc, slot_loc, order_loc, topv_loc)

    # The dispatch sort/scatter must stay device-LOCAL: under plain pjit,
    # GSPMD shards the sort intermediates over `model` and then materializes
    # and all-gathers full-shape u32 index tensors. shard_map over the dp
    # axes makes locality structural (each "cluster" handles its own SPM
    # tile, paper Sec. III-B); expert einsums stay outside for TP.
    mesh = current_mesh()
    use_shard_map = mesh is not None and B % (
        int(np.prod([mesh.shape[a] for a in dp_axes(mesh)]))
    ) == 0
    if use_shard_map:
        dp = dp_axes(mesh)
        disp, slot, order = shard_map(
            dispatch_rows,
            mesh=mesh,
            in_specs=(P(dp, None, None), P(dp, None, None)),
            out_specs=(P(dp, None, None, None), P(dp, None), P(dp, None)),
            check_vma=False,
        )(x, topi)
    else:
        disp, slot, order = dispatch_rows(x, topi)
    disp = constrain(disp, "moe_dispatch")

    # tp_reduce_bf16 extends to the hidden activations: the (B,E,C,f)
    # buffers are the largest tensors in the step; bf16 halves their traffic
    # (MXU accumulates fp32 internally regardless of the output dtype)
    h_dt = jnp.dtype(x.dtype) if cfg.tp_reduce_bf16 else jnp.float32
    h = jnp.einsum(
        "becd,edf->becf", disp, p["moe_wi"], preferred_element_type=h_dt
    )
    h = constrain(h, "moe_hidden")
    if gated:
        g = jnp.einsum(
            "becd,edf->becf", disp, p["moe_wg"], preferred_element_type=h_dt
        )
        h = act(constrain(g, "moe_hidden").astype(jnp.float32)).astype(h_dt) * h
    h = h.astype(x.dtype)
    # tp_reduce_bf16: emit the expert output in bf16 so the TP all-reduce
    # over `model` moves half the bytes (local MXU accumulation is fp32
    # either way; only the cross-device reduction is lower precision)
    y_dt = jnp.dtype(x.dtype) if cfg.tp_reduce_bf16 else jnp.float32
    y = jnp.einsum(
        "becf,efd->becd", h, p["moe_wo"], preferred_element_type=y_dt
    ).astype(x.dtype)
    y = constrain(y, "moe_dispatch")

    if use_shard_map:
        out = shard_map(
            combine_rows,
            mesh=mesh,
            in_specs=(P(dp, None, None, None), P(dp, None), P(dp, None),
                      P(dp, None, None)),
            out_specs=P(dp, None, None),
            check_vma=False,
        )(y, slot, order, topv.astype(x.dtype))
    else:
        out = combine_rows(y, slot, order, topv.astype(x.dtype))
    return out, aux * cfg.router_aux_weight


def moe_mlp_decode(p, x, cfg):
    """Decode path: (B, d). All experts computed; with a full batch every
    expert's weights stream from HBM anyway, so this costs no extra memory
    traffic (decode is weight-bound)."""
    E = cfg.num_experts
    act = L.activation_fn(cfg.activation)
    gated = L.is_gated(cfg.activation)
    topv, topi, _ = _route(p, x, cfg)
    w = (jax.nn.one_hot(topi, E, dtype=jnp.float32) * topv[..., None]).sum(-2)
    h = jnp.einsum(
        "bd,edf->bef", x, p["moe_wi"], preferred_element_type=jnp.float32
    )
    if gated:
        g = jnp.einsum(
            "bd,edf->bef", x, p["moe_wg"], preferred_element_type=jnp.float32
        )
        h = act(g) * h
    h = h.astype(x.dtype)
    y = jnp.einsum(
        "bef,efd->bed", h, p["moe_wo"], preferred_element_type=jnp.float32
    )
    return jnp.einsum("bed,be->bd", y, w).astype(x.dtype), jnp.float32(0.0)
