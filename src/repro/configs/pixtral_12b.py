"""pixtral-12b [vlm]: 40L d_model=5120 32H (GQA kv=8) d_ff=14336 vocab=131072.
Pixtral-ViT frontend is a STUB (input_specs supplies patch embeddings); the
backbone is the mistral-nemo-style decoder. [hf:mistralai/Pixtral-12B-2409]"""
from repro.configs.base import ModelConfig

CONFIG = ModelConfig(
    name="pixtral-12b",
    family="vlm",
    num_layers=40,
    d_model=5120,
    num_heads=32,
    num_kv_heads=8,
    head_dim=128,  # mistral-nemo uses explicit head_dim=128 (not d_model/H)
    d_ff=14336,
    vocab_size=131072,
    activation="swiglu",
    rope_theta=1_000_000.0,
    num_patches=64,  # vision-tower stub emits this many patch embeddings
    fsdp=True,
)

REDUCED = ModelConfig(
    name="pixtral-reduced",
    family="vlm",
    num_layers=2,
    d_model=64,
    num_heads=4,
    num_kv_heads=2,
    head_dim=16,
    d_ff=128,
    vocab_size=512,
    activation="swiglu",
    num_patches=4,
    fsdp=False,
    dtype="float32",
)
