"""rwkv6-3b [ssm]: 32L d_model=2560 (attention-free) d_ff=8960 vocab=65536.
Finch: linear attention with data-dependent per-channel decay; constant-size
recurrent state => long_500k applicable. [arXiv:2404.05892; hf]"""
from repro.configs.base import ModelConfig

CONFIG = ModelConfig(
    name="rwkv6-3b",
    family="ssm",
    num_layers=32,
    d_model=2560,
    num_heads=40,  # wkv heads, head_size 64
    num_kv_heads=40,
    head_dim=64,
    d_ff=8960,
    vocab_size=65536,
    activation="relu_sq",  # rwkv channel-mix uses squared ReLU
    ssm_state=64,  # per-head state is head_dim x head_dim
    rope_theta=0.0,  # no rope: token-shift provides positional signal
    fsdp=True,
)

REDUCED = ModelConfig(
    name="rwkv6-reduced",
    family="ssm",
    num_layers=2,
    d_model=64,
    num_heads=4,
    num_kv_heads=4,
    head_dim=16,
    d_ff=128,
    vocab_size=512,
    activation="relu_sq",
    ssm_state=16,
    rope_theta=0.0,
    fsdp=False,
    dtype="float32",
)
