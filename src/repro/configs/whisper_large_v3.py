"""whisper-large-v3 [audio]: enc-dec, 32L(+32 enc) d_model=1280 20H (kv=20)
d_ff=5120 vocab=51866, conv frontend STUB (input_specs supplies precomputed
frame embeddings, 1500 frames). [arXiv:2212.04356; unverified]

Backbone-only fidelity: layer/head/dim counts are exact; norms/positional
encoding are unified to the framework's RMSNorm+RoPE (noted in DESIGN.md).
vocab 51866 is not divisible by the model axis => embedding padded internally.
"""
from repro.configs.base import ModelConfig

CONFIG = ModelConfig(
    name="whisper-large-v3",
    family="audio",
    num_layers=32,  # decoder layers
    encoder_layers=32,
    encoder_seq=1500,
    d_model=1280,
    num_heads=20,
    num_kv_heads=20,
    head_dim=64,
    d_ff=5120,
    vocab_size=51866,
    activation="gelu",
    qkv_bias=True,
    rope_theta=10000.0,
    fsdp=True,
)

REDUCED = ModelConfig(
    name="whisper-reduced",
    family="audio",
    num_layers=2,
    encoder_layers=2,
    encoder_seq=16,
    d_model=64,
    num_heads=4,
    num_kv_heads=4,
    head_dim=16,
    d_ff=128,
    vocab_size=512,
    activation="gelu",
    qkv_bias=True,
    fsdp=False,
    dtype="float32",
)
