"""qwen1.5-4b [dense]: 40L d_model=2560 20H (GQA kv=20) d_ff=6912
vocab=151936, QKV bias. [hf:Qwen/Qwen1.5-0.5B; hf]

20 heads are not divisible by the 16-way model axis: the sharding chooser
replicates attention projections and shards d_ff/vocab instead (see
parallel/sharding.py).
"""
from repro.configs.base import ModelConfig

CONFIG = ModelConfig(
    name="qwen1.5-4b",
    family="dense",
    num_layers=40,
    d_model=2560,
    num_heads=20,
    num_kv_heads=20,
    head_dim=128,
    d_ff=6912,
    vocab_size=151936,
    activation="swiglu",
    qkv_bias=True,
    rope_theta=5_000_000.0,
    fsdp=True,
)

REDUCED = ModelConfig(
    name="qwen1.5-reduced",
    family="dense",
    num_layers=2,
    d_model=80,
    num_heads=5,
    num_kv_heads=5,
    head_dim=16,
    d_ff=128,
    vocab_size=512,
    activation="swiglu",
    qkv_bias=True,
    fsdp=False,
    dtype="float32",
)
