"""Config system: model configs, input-shape specs, and the arch registry.

Every assigned architecture gets a ``src/repro/configs/<id>.py`` exposing
``CONFIG`` (the exact published config) and ``REDUCED`` (a tiny same-family
config for CPU smoke tests). ``get_config("grok-1-314b")`` resolves either.
"""
from __future__ import annotations

import dataclasses
import importlib
from dataclasses import dataclass


@dataclass(frozen=True)
class ModelConfig:
    # identity
    name: str
    family: str = "dense"  # dense | moe | vlm | hybrid | ssm | audio

    # transformer backbone
    num_layers: int = 12
    d_model: int = 512
    num_heads: int = 8
    num_kv_heads: int = 8
    head_dim: int = 0  # 0 => d_model // num_heads
    d_ff: int = 2048
    vocab_size: int = 32000
    activation: str = "swiglu"  # swiglu | geglu | gelu
    qkv_bias: bool = False
    qk_norm: bool = False
    parallel_block: bool = False  # GPT-J-style parallel attn+FFN residual
    tie_embeddings: bool = False
    rope_theta: float = 10000.0
    norm_eps: float = 1e-5

    # mixture of experts
    num_experts: int = 0
    experts_per_token: int = 0
    capacity_factor: float = 1.25
    router_aux_weight: float = 0.01

    # ssm / hybrid (rwkv6, hymba)
    ssm_state: int = 0
    d_inner: int = 0  # 0 => 2 * d_model
    ssm_head_dim: int = 64
    sliding_window: int = 0  # 0 = full attention
    num_global_layers: int = 0  # hybrid: this many layers use full attention

    # encoder-decoder (whisper)
    encoder_layers: int = 0
    encoder_seq: int = 0  # fixed frame count from the (stubbed) conv frontend

    # vlm (pixtral)
    num_patches: int = 0  # patch embeddings prepended by the (stubbed) vision tower

    # numerics (paper C6: multi-precision with expanding accumulation)
    dtype: str = "bfloat16"
    accum_dtype: str = "float32"
    optimizer_dtype: str = "float32"

    # distribution knobs
    fsdp: bool = True  # shard params over the data axis during training (ZeRO-3)
    weights_2d_tp: bool = False  # serving: shard big weight dims over data axis too
    remat: str = "full"  # full | dots | none
    seq_shard_activations: bool = True  # Megatron-SP style residual sharding
    scan_unroll: int = 1  # layer-scan unroll (dry-run cost extraction sets >1)
    # §Perf hillclimb knobs (beyond-paper optimizations; defaults = baseline)
    tp_reduce_bf16: bool = False  # cast expert output before the TP all-reduce
    microbatches: int = 1  # gradient accumulation (shrinks activation temps)
    gather_save_policy: bool = False  # remat policy: save TP/FSDP gathers
    explicit_attn_sharding: bool = False  # pin q seq-sharded / kv replicated
    halo_shift: bool = False  # token-shift via 1-column ppermute halo exchange

    # training hyperparameters
    learning_rate: float = 3e-4
    weight_decay: float = 0.1
    beta1: float = 0.9
    beta2: float = 0.95
    grad_clip: float = 1.0
    warmup_steps: int = 100

    def resolved_head_dim(self) -> int:
        return self.head_dim if self.head_dim else self.d_model // self.num_heads

    def resolved_d_inner(self) -> int:
        return self.d_inner if self.d_inner else 2 * self.d_model

    @property
    def q_per_kv(self) -> int:
        return self.num_heads // max(self.num_kv_heads, 1)

    @property
    def attention_free(self) -> bool:
        return self.family == "ssm"

    def replace(self, **kw) -> "ModelConfig":
        return dataclasses.replace(self, **kw)

    def num_params(self) -> int:
        """Analytic parameter count (used for MODEL_FLOPS = 6*N*D)."""
        d, f, hd = self.d_model, self.d_ff, self.resolved_head_dim()
        H, K = self.num_heads, self.num_kv_heads
        gate_mult = 2 if self.activation in ("swiglu", "geglu") else 1
        ffn = d * f * gate_mult + f * d
        if self.num_experts:
            ffn = ffn * self.num_experts + d * self.num_experts  # + router
        attn = d * (H * hd) + 2 * d * (K * hd) + (H * hd) * d
        if self.family == "ssm":
            attn = 0
        per_layer = attn + ffn + 2 * d
        if self.family in ("ssm", "hybrid"):
            di, n = self.resolved_d_inner(), self.ssm_state
            ssm = d * 2 * di + di * n * 2 + di + di * d  # in-proj, B/C, dt, out
            per_layer += ssm
        n_params = self.num_layers * per_layer + self.vocab_size * d
        if not self.tie_embeddings:
            n_params += self.vocab_size * d
        if self.encoder_layers:
            n_params += self.encoder_layers * (attn + d * f * gate_mult + f * d + 2 * d)
            n_params += self.num_layers * (d * (H * hd) + 2 * d * (K * hd) + (H * hd) * d + d)
        return n_params

    def num_active_params(self) -> int:
        """Active parameters per token (MoE: only routed experts count)."""
        if not self.num_experts:
            return self.num_params()
        d, f = self.d_model, self.d_ff
        gate_mult = 2 if self.activation in ("swiglu", "geglu") else 1
        per_expert = d * f * gate_mult + f * d
        inactive = (self.num_experts - self.experts_per_token) * per_expert
        return self.num_params() - self.num_layers * inactive


@dataclass(frozen=True)
class ShapeSpec:
    name: str
    kind: str  # train | prefill | decode
    seq_len: int
    global_batch: int


SHAPES = {
    "train_4k": ShapeSpec("train_4k", "train", 4096, 256),
    "prefill_32k": ShapeSpec("prefill_32k", "prefill", 32768, 32),
    "decode_32k": ShapeSpec("decode_32k", "decode", 32768, 128),
    "long_500k": ShapeSpec("long_500k", "decode", 524288, 1),
}

ARCH_IDS = [
    "grok-1-314b",
    "phi3.5-moe-42b-a6.6b",
    "pixtral-12b",
    "qwen1.5-4b",
    "gemma-2b",
    "qwen3-14b",
    "command-r-35b",
    "hymba-1.5b",
    "rwkv6-3b",
    "whisper-large-v3",
]

PAPER_CONFIG_IDS = ["occamy-gptj"]  # the paper's own LLM workload (Fig. 12)


def shape_applicable(cfg: ModelConfig, shape: ShapeSpec) -> tuple[bool, str]:
    """Which (arch x shape) cells run. long_500k needs sub-quadratic attention."""
    if shape.name == "long_500k" and cfg.family not in ("ssm", "hybrid"):
        return False, "long_500k skipped: pure full-attention arch (quadratic regime)"
    return True, ""


def _module_name(arch_id: str) -> str:
    return arch_id.replace("-", "_").replace(".", "p")


def get_config(arch_id: str, reduced: bool = False) -> ModelConfig:
    mod = importlib.import_module(f"repro.configs.{_module_name(arch_id)}")
    return mod.REDUCED if reduced else mod.CONFIG


def all_arch_ids(include_paper: bool = True) -> list[str]:
    return ARCH_IDS + (PAPER_CONFIG_IDS if include_paper else [])
