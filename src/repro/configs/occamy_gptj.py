"""occamy-gptj: the paper's own LLM inference workload (Section V-C, Fig. 12).
GPT-J-6B: 28L d_model=4096 16H d_ff=16384 vocab=50400, parallel residual
block, run in FP16 (here bf16) non-autoregressive (= prefill) mode."""
from repro.configs.base import ModelConfig

CONFIG = ModelConfig(
    name="occamy-gptj",
    family="dense",
    num_layers=28,
    d_model=4096,
    num_heads=16,
    num_kv_heads=16,
    head_dim=256,
    d_ff=16384,
    vocab_size=50400,
    activation="gelu",
    parallel_block=True,  # GPT-J computes attn and FFN from the same input
    rope_theta=10000.0,
    fsdp=True,
)

REDUCED = ModelConfig(
    name="occamy-gptj-reduced",
    family="dense",
    num_layers=2,
    d_model=64,
    num_heads=4,
    num_kv_heads=4,
    head_dim=16,
    d_ff=128,
    vocab_size=512,
    activation="gelu",
    parallel_block=True,
    fsdp=False,
    dtype="float32",
)
