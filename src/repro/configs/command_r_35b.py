"""command-r-35b [dense]: 40L d_model=8192 64H (GQA kv=8) d_ff=22528
vocab=256000, no bias, parallel residual block, tied embeddings.
[hf:CohereForAI/c4ai-command-r-v01; unverified]"""
from repro.configs.base import ModelConfig

CONFIG = ModelConfig(
    name="command-r-35b",
    family="dense",
    num_layers=40,
    d_model=8192,
    num_heads=64,
    num_kv_heads=8,
    head_dim=128,
    d_ff=22528,
    vocab_size=256000,
    activation="swiglu",
    qkv_bias=False,
    parallel_block=True,  # Cohere arch: attn and FFN share the residual input
    tie_embeddings=True,
    rope_theta=8_000_000.0,
    fsdp=True,
)

REDUCED = ModelConfig(
    name="command-r-reduced",
    family="dense",
    num_layers=2,
    d_model=64,
    num_heads=4,
    num_kv_heads=2,
    head_dim=16,
    d_ff=128,
    vocab_size=512,
    activation="swiglu",
    parallel_block=True,
    tie_embeddings=True,
    fsdp=False,
    dtype="float32",
)
