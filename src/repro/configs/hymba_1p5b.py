"""hymba-1.5b [hybrid]: 32L d_model=1600 25H (GQA kv=5) d_ff=5504 vocab=32001,
ssm_state=16 — parallel attn+mamba heads per block; sliding-window attention
with a few global layers makes long_500k tractable. [arXiv:2411.13676; hf]"""
from repro.configs.base import ModelConfig

CONFIG = ModelConfig(
    name="hymba-1.5b",
    family="hybrid",
    num_layers=32,
    d_model=1600,
    num_heads=25,
    num_kv_heads=5,
    head_dim=64,
    d_ff=5504,
    vocab_size=32001,
    activation="swiglu",
    ssm_state=16,
    d_inner=3200,
    ssm_head_dim=64,
    sliding_window=1024,
    num_global_layers=3,  # first / middle / last layers use full attention
    rope_theta=10000.0,
    fsdp=True,
)

REDUCED = ModelConfig(
    name="hymba-reduced",
    family="hybrid",
    num_layers=3,
    d_model=64,
    num_heads=4,
    num_kv_heads=2,
    head_dim=16,
    d_ff=128,
    vocab_size=512,
    activation="swiglu",
    ssm_state=8,
    d_inner=128,
    ssm_head_dim=16,
    sliding_window=8,
    num_global_layers=1,
    fsdp=False,
    dtype="float32",
)
