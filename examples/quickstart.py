"""Quickstart: the Occamy programming model on TPU, in four acts.

1. Affine streams (paper Fig. 4a): GEMM via the stream_compute front-end.
2. Indirect/sparse compute (Fig. 4b): SpMM with a value/index ELL matrix.
3. Multi-precision expanding accumulation (Fig. 10): fp32/bf16/fp8 GEMM.
4. A tiny LM training run on the full framework stack.

Runs on CPU (kernels in interpret mode). `PYTHONPATH=src python examples/quickstart.py`
"""
import jax
import jax.numpy as jnp
import numpy as np

from repro.core import precision, sparse, streams
from repro.kernels import ops, ref


def act1_affine_streams():
    M = N = K = 256
    bm = bn = bk = 128
    a = jnp.asarray(np.random.default_rng(0).standard_normal((M, K)), jnp.float32)
    b = jnp.asarray(np.random.default_rng(1).standard_normal((K, N)), jnp.float32)

    grid, in_streams, out_stream = streams.gemm_streams(
        M, N, K, bm, bn, bk, dtype=jnp.float32
    )

    from jax.experimental.pallas import tpu as pltpu
    import jax.experimental.pallas as pl

    def body(a_ref, b_ref, o_ref, acc_ref):
        @pl.when(pl.program_id(2) == 0)
        def _():
            acc_ref[...] = jnp.zeros_like(acc_ref)

        acc_ref[...] += jnp.dot(a_ref[...], b_ref[...],
                                preferred_element_type=jnp.float32)

        @pl.when(pl.program_id(2) == K // bk - 1)
        def _():
            o_ref[...] = acc_ref[...]

    program = streams.StreamProgram(
        name="quickstart_gemm",
        body=body,
        grid=grid,
        in_streams=tuple(in_streams),
        out_streams=(out_stream,),
        out_shapes=(jax.ShapeDtypeStruct((M, N), jnp.float32),),
        scratch=(pltpu.VMEM((bm, bn), jnp.float32),),
    )
    out = streams.stream_compute(program, a, b, interpret=True)
    err = float(jnp.max(jnp.abs(out - a @ b)))
    print(f"[1] affine-stream GEMM  max|err| = {err:.2e}  "
          f"({program.steps} stream steps, "
          f"{program.traffic_bytes() / 1e6:.1f} MB streamed bound)")


def act2_sparse():
    rng = np.random.default_rng(0)
    A = sparse.random_ell(rng, 128, 256, density=0.05)
    D = jnp.asarray(rng.standard_normal((256, 64)), jnp.float32)
    out = ops.spmm(A, D, impl="interpret")  # EllMatrix pytree operand
    want = jnp.asarray(A.todense()) @ D
    print(f"[2] indirect-stream SpMM (density 5%)  max|err| = "
          f"{float(jnp.max(jnp.abs(out - want))):.2e}")


def act3_precision():
    rng = np.random.default_rng(0)
    a = jnp.asarray(rng.standard_normal((256, 256)), jnp.float32)
    b = jnp.asarray(rng.standard_normal((256, 256)), jnp.float32)
    exact = a @ b
    for pol in ("fp32", "bf16", "fp8"):
        out = precision.expanding_gemm(a, b, pol, impl="ref")
        rel = float(jnp.linalg.norm(out - exact) / jnp.linalg.norm(exact))
        peak = precision.peak_flops(pol) / 1e12
        print(f"[3] {pol:8s} expanding-accum GEMM rel_err {rel:.1e} "
              f"(peak {peak:.0f} TFLOP/s/chip)")


def act4_train():
    from repro.configs.base import SHAPES, get_config
    from repro.runtime import train_loop

    cfg = get_config("occamy-gptj", reduced=True)
    state, losses, _ = train_loop.run_training(
        cfg, SHAPES["train_4k"], num_steps=10, batch_override=4,
        seq_override=64, log_every=5,
    )
    print(f"[4] trained tiny GPT-J 10 steps: loss {losses[0]:.3f} -> {losses[-1]:.3f}")


if __name__ == "__main__":
    act1_affine_streams()
    act2_sparse()
    act3_precision()
    act4_train()
