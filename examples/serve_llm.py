"""Batched LLM serving on the framework stack: prefill + KV-cache decode.

Mirrors the paper's GPT-J evaluation (Sec. V-C): the same blocked-attention
dataflow (FlashAttention-2) runs the prefill, and decode extends the cache
one token per step. Reports tok/s like Fig. 12.

  PYTHONPATH=src python examples/serve_llm.py
"""
import time

import jax
import jax.numpy as jnp
import numpy as np

from repro.configs.base import get_config
from repro.launch.serve import generate
from repro.models import registry

CFG = get_config("occamy-gptj", reduced=True).replace(
    num_layers=4, d_model=256, num_heads=4, num_kv_heads=4, head_dim=64,
    d_ff=1024, vocab_size=8192,
)


def main():
    rng = np.random.default_rng(0)
    params = registry.init_params(CFG, jax.random.PRNGKey(0))
    for batch, prompt_len, gen_len in [(4, 64, 32), (16, 64, 32)]:
        tokens = jnp.asarray(
            rng.integers(0, CFG.vocab_size, (batch, prompt_len)), jnp.int32
        )
        max_len = prompt_len + gen_len + 1
        t0 = time.time()
        out = generate(CFG, params, tokens, gen_len, max_len)
        dt = time.time() - t0
        print(
            f"batch {batch:3d}: prefill {prompt_len} + decode {gen_len} "
            f"-> {batch * gen_len / dt:7.1f} tok/s  (shape {out.shape})"
        )


if __name__ == "__main__":
    main()
