"""Batched LLM serving on the framework stack: prefill + KV-cache decode.

Mirrors the paper's GPT-J evaluation (Sec. V-C): the same blocked-attention
dataflow (FlashAttention-2) runs the prefill, and decode extends the cache
one token per step. Reports tok/s like Fig. 12.

Part two switches to the continuous-batching engine (docs/serving.md): the
same model behind a paged KV cache, requests arriving open-loop, admission
and preemption handled by the scheduler — the serving shape the one-shot
``generate`` path can't express.

  PYTHONPATH=src python examples/serve_llm.py
"""
import time

import jax
import jax.numpy as jnp
import numpy as np

from repro.configs.base import get_config
from repro.launch.serve import generate
from repro.models import registry
from repro.serving.engine import Request, ServingEngine

CFG = get_config("occamy-gptj", reduced=True).replace(
    num_layers=4, d_model=256, num_heads=4, num_kv_heads=4, head_dim=64,
    d_ff=1024, vocab_size=8192,
)


def batch_generate(params, rng):
    for batch, prompt_len, gen_len in [(4, 64, 32), (16, 64, 32)]:
        tokens = jnp.asarray(
            rng.integers(0, CFG.vocab_size, (batch, prompt_len)), jnp.int32
        )
        max_len = prompt_len + gen_len + 1
        t0 = time.time()
        out = generate(CFG, params, tokens, gen_len, max_len)
        dt = time.time() - t0
        print(
            f"batch {batch:3d}: prefill {prompt_len} + decode {gen_len} "
            f"-> {batch * gen_len / dt:7.1f} tok/s  (shape {out.shape})"
        )


def continuous_batching(params, rng):
    # Pool sized tight on purpose: 11 usable pages for up to 4 concurrent
    # sequences forces the grow/preempt/resume machinery to run.
    engine = ServingEngine.with_model(
        CFG, params,
        num_blocks=12, block_size=16, max_slots=4, max_blocks_per_seq=6,
        eos_id=None,
    )
    for rid in range(12):
        plen = int(rng.integers(8, 48))
        engine.submit(Request(
            rid=rid,
            prompt=tuple(int(t) for t in rng.integers(1, CFG.vocab_size, plen)),
            max_new_tokens=int(rng.integers(8, 24)),
            priority=int(rid % 2),        # mixed priority classes
            arrival=rid // 2,             # staggered open-loop arrivals
        ))
    t0 = time.time()
    completed = engine.run()
    dt = time.time() - t0
    tokens = sum(len(v) for v in completed.values())
    preempts = sum(1 for e in engine.scheduler.events if e[0] == "preempt")
    print(
        f"engine: {len(completed)}/12 requests, {tokens} tokens in "
        f"{engine.step_count} steps -> {tokens / dt:7.1f} tok/s  "
        f"(preemptions {preempts}, leaked blocks {engine.leaked_blocks()})"
    )
    assert engine.leaked_blocks() == 0


def main():
    rng = np.random.default_rng(0)
    params = registry.init_params(CFG, jax.random.PRNGKey(0))
    batch_generate(params, rng)
    continuous_batching(params, rng)


if __name__ == "__main__":
    main()
