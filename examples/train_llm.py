"""End-to-end driver: train a ~100M-param GPT-J-family LM for a few hundred
steps on the full framework stack (data pipeline, AdamW, checkpointing,
straggler monitor, crash-restart).

  PYTHONPATH=src python examples/train_llm.py            # ~200 steps
  PYTHONPATH=src python examples/train_llm.py --steps 50 # quicker

A crash is injected mid-run; the driver restarts from the last checkpoint and
finishes — demonstrating the paper-C7 fault-tolerance path end to end.
"""
import argparse
import shutil
import tempfile

from repro.configs.base import SHAPES, get_config
from repro.runtime import train_loop
from repro.runtime.fault_tolerance import FailureInjector

# ~100M params: 12L x d512 x ffn2048, vocab 32k
CFG = get_config("occamy-gptj", reduced=True).replace(
    name="gptj-100m",
    num_layers=12,
    d_model=512,
    num_heads=8,
    num_kv_heads=8,
    head_dim=64,
    d_ff=2048,
    vocab_size=32000,
    learning_rate=1e-3,
    warmup_steps=20,
)


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--steps", type=int, default=200)
    ap.add_argument("--batch", type=int, default=4)
    ap.add_argument("--seq", type=int, default=128)
    ap.add_argument("--crash-at", type=int, default=None)
    args = ap.parse_args()
    crash_at = args.crash_at if args.crash_at is not None else args.steps // 2

    n = CFG.num_params()
    print(f"model: {CFG.name}  params ~{n/1e6:.0f}M  steps {args.steps}")
    ckpt_dir = tempfile.mkdtemp(prefix="repro_llm_")
    injector = FailureInjector({crash_at: "crash"})
    try:
        try:
            train_loop.run_training(
                CFG, SHAPES["train_4k"], num_steps=args.steps,
                batch_override=args.batch, seq_override=args.seq,
                ckpt_dir=ckpt_dir, ckpt_every=25,
                failure_injector=injector, log_every=10,
            )
        except RuntimeError as e:
            print(f"[fault] {e} -> restarting from checkpoint")
            state, losses, mon = train_loop.run_training(
                CFG, SHAPES["train_4k"], num_steps=args.steps,
                batch_override=args.batch, seq_override=args.seq,
                ckpt_dir=ckpt_dir, ckpt_every=25, log_every=10,
            )
            print(
                f"finished after restart: final loss {losses[-1]:.4f} "
                f"({len(losses)} post-restart steps)"
            )
    finally:
        shutil.rmtree(ckpt_dir, ignore_errors=True)


if __name__ == "__main__":
    main()
