"""The paper's sparse-compute trio on the SU-analogue kernels:

  SpMM   (Fig. 9c)  — indirect streams: real-world-like unstructured sparsity
  SpMSpM (Fig. 9d)  — index intersection, GCOMP/s figure of merit
  Stencil (Fig. 9b) — offset index streams (SARIS), star and box shapes

All three Pallas kernels run in interpret mode on CPU and are checked against
their jnp oracles. `PYTHONPATH=src python examples/sparse_demo.py`
"""
import time

import jax.numpy as jnp
import numpy as np

from repro.core import sparse
from repro.kernels import ops, ref


def spmm_demo():
    rng = np.random.default_rng(0)
    for density in (0.003, 0.01, 0.028):  # the paper's 0.12%..2.8% range
        A = sparse.random_ell(rng, 512, 1024, density)
        D = jnp.asarray(rng.standard_normal((1024, 128)), jnp.float32)
        out = ops.spmm(jnp.asarray(A.values), jnp.asarray(A.cols), D,
                       impl="interpret")
        want = ref.spmm_ref(jnp.asarray(A.values), jnp.asarray(A.cols), D)
        err = float(jnp.max(jnp.abs(out - want)))
        print(f"[SpMM]   density {density*100:5.2f}%  nnz {A.nnz:6d}  "
              f"max|err| {err:.1e}")


def spmspm_demo():
    rng = np.random.default_rng(1)
    A = sparse.random_ell(rng, 256, 512, 0.01)
    B = sparse.random_ell(rng, 256, 512, 0.01)  # columns of B
    out = ops.spmspm(jnp.asarray(A.values), jnp.asarray(A.cols),
                     jnp.asarray(B.values), jnp.asarray(B.cols), 512,
                     impl="interpret")
    want = ref.spmspm_ref(jnp.asarray(A.values), jnp.asarray(A.cols),
                          jnp.asarray(B.values), jnp.asarray(B.cols), 512)
    comps = ref.spmspm_comparisons(jnp.asarray(A.cols), jnp.asarray(B.cols))
    err = float(jnp.max(jnp.abs(out - want)))
    print(f"[SpMSpM] {comps/1e6:.2f} M index comparisons  max|err| {err:.1e}")


def stencil_demo():
    rng = np.random.default_rng(2)
    g = jnp.asarray(rng.standard_normal((16, 64, 64)), jnp.float32)
    shapes = {
        "j3d7pt (star r=1)": np.array(
            [[0, 0, 0], [1, 0, 0], [-1, 0, 0], [0, 1, 0], [0, -1, 0],
             [0, 0, 1], [0, 0, -1]]),
        "j3d27pt (box r=1)": np.array(
            [[dx, dy, dz] for dx in (-1, 0, 1) for dy in (-1, 0, 1)
             for dz in (-1, 0, 1)]),
    }
    for name, offs in shapes.items():
        w = rng.standard_normal(len(offs)).astype(np.float32)
        out = ops.stencil(g, offs, w, impl="interpret")
        want = ref.stencil_ref(g, offs, w)
        err = float(jnp.max(jnp.abs(out - want)))
        flops = 2 * g.size * len(offs)
        print(f"[Stencil] {name:18s} {len(offs):2d} points  "
              f"{flops/1e6:.1f} MFLOP/iter  max|err| {err:.1e}")


if __name__ == "__main__":
    spmm_demo()
    spmspm_demo()
    stencil_demo()
