"""GCN layer inference (paper Sec. V-C, Fig. 11): mixed dense + sparse-dense
compute on citation-style graphs.

The paper evaluates webkb / cora / citeseer (avg degree 1.4-2.0). We generate
synthetic graphs with matched size/degree, run the 144-feature GCN layer the
paper uses, and report achieved GFLOP/s for the sparse aggregation.

  PYTHONPATH=src python examples/gcn_inference.py
"""
import time

import jax
import jax.numpy as jnp
import numpy as np

from repro.core import sparse
from repro.models import gcn

# (name, nodes, avg_degree) — matching the paper's three citation graphs
GRAPHS = [("webkb", 877, 1.8), ("cora", 2708, 2.0), ("citeseer", 3327, 1.4)]
FEATURES = 144  # the paper's hidden layer width


def adjacency(rng, n, deg):
    """Symmetric-normalized adjacency with self loops, ELL format."""
    L = max(int(round(deg)) + 1, 2)
    cols = rng.integers(0, n, (n, L)).astype(np.int32)
    cols[:, 0] = np.arange(n)  # self loop
    vals = jnp.full((n, L), 1.0 / L, jnp.float32)
    return sparse.EllMatrix(vals, jnp.asarray(cols), (n, n))


def main():
    rng = np.random.default_rng(0)
    params = gcn.init_params(jax.random.PRNGKey(0), [FEATURES, FEATURES, FEATURES])
    for name, n, deg in GRAPHS:
        adj = adjacency(rng, n, deg)
        feats = jnp.asarray(rng.standard_normal((n, FEATURES)), jnp.float32)
        fwd = jax.jit(lambda a, f: gcn.forward(params, a, f))
        out = fwd(adj, feats)  # compile: the EllMatrix passes through jit
        t0 = time.time()
        reps = 20
        for _ in range(reps):
            out = fwd(adj, feats)
        out.block_until_ready()
        dt = (time.time() - t0) / reps
        dense_flops = 2 * n * FEATURES * FEATURES * len(params)
        sparse_flops = 2 * adj.nnz * FEATURES * len(params)
        print(
            f"{name:10s} n={n:5d} deg={deg:.1f}: {dt*1e3:7.2f} ms/layer-stack "
            f"({(dense_flops + sparse_flops)/dt/1e9:6.2f} GFLOP/s, "
            f"out {out.shape}, finite={bool(jnp.all(jnp.isfinite(out)))})"
        )


if __name__ == "__main__":
    main()
